#!/usr/bin/env python3
"""Render results/*.json into the EXPERIMENTS.md results section."""
import json, os, sys

R = sys.argv[1] if len(sys.argv) > 1 else "results"

def load(name):
    p = os.path.join(R, f"{name}.json")
    return json.load(open(p)) if os.path.exists(p) else None

out = []
w = out.append

d = load("fig2c")
if d:
    w("## Fig. 2(c) — motivation: group vs independent retraining\n")
    w("| setting | steady mAP | response (s) |")
    w("|---|---|---|")
    for s_ in d["settings"]:
        w(f"| {s_['name']} | {s_['steady']:.3f} | {s_['response_s']:.0f} |")
    w("")
    w("Paper shape: group(3 GPU) > independent(3 GPU); group(1 GPU) ~ independent(3 GPU). ✓\n")

d = load("fig5")
if d:
    w("## Fig. 5 — sampling-config profiling\n")
    for b in d["best"]:
        w(f"* best for **{b['camera']}**: {b['fps']} fps @ res {int(b['res'])} (mAP {b['acc']:.3f})")
    w("\nPaper shape: optimum differs by camera type — static spends the pixel budget on resolution, mobile on frame rate.\n")

d = load("tab1")
if d:
    w("## Table 1 — equal vs GPU-proportional bandwidth\n")
    w("| scheme | cam A | cam B | overall |")
    w("|---|---|---|---|")
    for r in d["schemes"]:
        w(f"| {r['scheme']} | {r['camA']:.3f} | {r['camB']:.3f} | {r['overall']:.3f} |")
    w("\nPaper shape: proportional wins overall (theirs 32.1 vs 30.4). Per-camera direction is noisier at our scale.\n")

for task in ["det", "seg"]:
    d = load(f"fig6{task}")
    if not d: continue
    w(f"## Fig. 6 ({task}) — end-to-end sweeps (6 cameras, steady mAP)\n")
    rows = d["rows"]
    for sweep, unit in [("gpus", "GPU"), ("bandwidth", "Mbps")]:
        xs = sorted({r["x"] for r in rows if r["sweep"] == sweep})
        w(f"**vs {sweep}**\n")
        w("| policy | " + " | ".join(f"{x:g} {unit}" for x in xs) + " |")
        w("|" + "---|" * (len(xs) + 1))
        for p in ["ecco", "recl", "ekya", "naive"]:
            vals = [next((r["steady"] for r in rows if r["sweep"]==sweep and r["x"]==x and r["policy"]==p), float("nan")) for x in xs]
            w(f"| {p} | " + " | ".join(f"{v:.3f}" for v in vals) + " |")
        w("")

d = load("fig7")
if d:
    w("## Fig. 7 — scalability (4 GPUs, 50 Mbps)\n")
    rows = d["rows"]
    xs = sorted({int(r["cams"]) for r in rows})
    for metric, label in [("steady", "steady mAP"), ("response_s", "mean response (s)")]:
        w(f"**{label}**\n")
        w("| policy | " + " | ".join(f"{x} cams" for x in xs) + " |")
        w("|" + "---|" * (len(xs) + 1))
        for p in ["ecco", "recl", "ekya", "naive"]:
            vals = [next((r[metric] for r in rows if int(r["cams"])==x and r["policy"]==p), float("nan")) for x in xs]
            fmt = "{:.3f}" if metric == "steady" else "{:.0f}"
            w(f"| {p} | " + " | ".join(fmt.format(v) for v in vals) + " |")
        w("")

d = load("fig8")
if d:
    w("## Fig. 8 — camera-similarity ablation\n")
    w("| similarity | group mAP | independent mAP | group gain |")
    w("|---|---|---|---|")
    rows = d["rows"]
    for lvl in ["high", "medium", "low"]:
        g = next(r["mAP"] for r in rows if r["similarity"]==lvl and r["mode"]=="group")
        i = next(r["mAP"] for r in rows if r["similarity"]==lvl and r["mode"]=="independent")
        w(f"| {lvl} | {g:.3f} | {i:.3f} | {g-i:+.3f} |")
    w("\nPaper shape: the grouping gain shrinks with similarity and ~vanishes at low similarity.\n")

d = load("fig9")
if d:
    w("## Fig. 9 — dynamic grouping timeline\n")
    w(f"* started as one group: yes; divergence detected and camera re-grouped: {'yes' if d['split_observed'] else 'NO'}")
    accs = d["cam_acc"]
    w(f"* camera 2 accuracy: pre-split ~{max(accs[2][:5]):.2f} -> tunnel dip {min(accs[2]):.2f} -> recovered {accs[2][-1]:.2f}\n")

d = load("fig10")
if d:
    w("## Fig. 10 — GPU allocator vs RECL's allocator\n")
    w("| allocator | G1(3 cams) final | G2(1 cam) final | max gap | G1 GPU share |")
    w("|---|---|---|---|---|")
    for r in d["runs"]:
        w(f"| {r['allocator']} | {r['acc_group1'][-1]:.3f} | {r['acc_group2'][-1]:.3f} | {r['max_gap']:.3f} | {r['g1_share']*100:.0f}% |")
    w("\nPaper shape: ECCO's allocator reduces the inter-group accuracy gap at comparable overall accuracy. (In our dynamics the single-camera job learns faster per GPU-second, so the utility allocator's bias lands on the *large* group — the starved side flips, the fairness story is the same.)\n")

d = load("fig11")
if d:
    w("## Fig. 11 — transmission-controller ablation\n")
    rows = d["rows"]
    xs = sorted({r["bw"] for r in rows})
    w("| mode | " + " | ".join(f"{x:g} Mbps" for x in xs) + " |")
    w("|" + "---|" * (len(xs) + 1))
    for m in ["ecco-controller", "fixed+AIMD"]:
        vals = [next((r["mAP"] for r in rows if r["bw"]==x and r["mode"]==m), float("nan")) for x in xs]
        w(f"| {m} | " + " | ".join(f"{v:.3f}" for v in vals) + " |")
    w("")
    for t in d.get("traces", []):
        bw = "/".join(f"{v:.2f}" for v in t["group_bw"])
        sh = "/".join(f"{v:.2f}" for v in t["gpu_shares"])
        w(f"* {t['mode']} @9 Mbps: group bandwidth {bw} Mbps vs GPU shares {sh}")
    w("\nPaper shape: the controller wins under tight bandwidth and approximates GPU-proportional group shares; the fixed baseline splits equally regardless.\n")

d = load("fig12")
if d:
    w("## Fig. 12 — natural model reuse (staggered joins at w0/w2/w4)\n")
    w("| policy | cam1 @join | cam2 @join | cam3 @join |")
    w("|---|---|---|---|")
    for r in d["runs"]:
        ia = r["initial_acc"]
        w(f"| {r['policy']} | {ia[0]:.3f} | {ia[1]:.3f} | {ia[2]:.3f} |")
    w("\nPaper shape: RECL best for the FIRST camera (a matching historical model); ECCO variants ahead for the later cameras, which inherit the partially-retrained group model.\n")

d = load("fig13")
if d:
    w("## Fig. 13 — response time vs per-camera uplink\n")
    rows = d["rows"]
    xs = sorted({r["uplink"] for r in rows})
    w("| policy | " + " | ".join(f"{x:g} Mbps" for x in xs) + " |")
    w("|" + "---|" * (len(xs) + 1))
    for p in ["ecco+recl", "ecco", "recl", "ekya"]:
        vals = [next((r["response_s"] for r in rows if r["uplink"]==x and r["policy"]==p), float("nan")) for x in xs]
        w(f"| {p} | " + " | ".join(f"{v:.0f} s" for v in vals) + " |")
    w("\nPaper shape: group retraining's data aggregation cuts response time by multiples under starved uplinks; ECCO+RECL best overall.\n")

for name, title in [("abl_alpha_beta", "Ablation: Eq. 1 alpha/beta"), ("abl_filter", "Ablation: metadata pre-filter"), ("abl_teacher", "Ablation: teacher quality")]:
    d = load(name)
    if not d: continue
    w(f"## {title}\n")
    w("```json")
    w(json.dumps(d["rows"], indent=1))
    w("```\n")

print("\n".join(out))

//! Fleet scalability, two modes:
//!
//! * default — compare ECCO vs baselines on a 6-camera fleet (two
//!   correlated triples) under a constrained GPU + bandwidth budget (the
//!   Fig. 6 setting, small) via the `ecco::api` façade. The four policy
//!   arms run **concurrently** over one shared engine through
//!   `api::run_fleet`; reports come back in arm order, each identical to
//!   its sequential run.
//! * `--scale N [--budget-secs S]` — one city-scale ECCO run with N
//!   cameras in a single process: event-driven scheduler, degree-6
//!   topology-pruned grouping, capped micro-windows. Prints per-window
//!   wall-clock; with `--budget-secs` the process exits non-zero if the
//!   run overshoots the budget (used by the `rust-scale` CI job at
//!   N = 1000).
//!
//!   cargo run --release --example fleet_scalability -- --scale 1000
use anyhow::Result;
use ecco::api::{run_fleet, RunSpec, RuntimeOpts, Session};
use ecco::runtime::{Engine, Task};
use ecco::scene::scenario;
use ecco::server::{Policy, Scheduler};
use ecco::util::pool;

fn scale_run(cams: usize, budget_secs: Option<f64>) -> Result<()> {
    let engine = Engine::open_default()?;
    let threads = pool::default_threads();
    let windows = 2usize;
    println!("scale: {cams} cams, {windows} windows, degree-6 topology, {threads} eval workers");
    let spec = RunSpec::new(Task::Det, Policy::ecco())
        .scenario(scenario::town(cams, 42))
        .gpus(8.0)
        .shared_mbps(64.0)
        .uplink_mbps(20.0)
        .windows(windows)
        .seed(42)
        .topology_degree(6)
        .runtime(RuntimeOpts::new().threads(threads).scheduler(Scheduler::EventDriven))
        .configure(|cfg| {
            // City-scale trims: short windows, few eval frames, a light
            // pretrain, and the capped micro-window budget that keeps
            // per-window coordination linear in the fleet size.
            cfg.window_secs = 20.0;
            cfg.micro_windows = 2;
            cfg.max_micro_windows = 8;
            cfg.eval_frames = 4;
            cfg.pretrain_steps = 40;
        });
    let t0 = std::time::Instant::now();
    let mut session = Session::new(&engine, spec)?;
    let built = t0.elapsed().as_secs_f64();
    println!("  built system in {built:.1}s");
    for _ in 0..windows {
        let w0 = std::time::Instant::now();
        let report = session.step_window()?;
        println!(
            "  window {}: {:.1}s wall, {} jobs, mean mAP {:.3}",
            report.window,
            w0.elapsed().as_secs_f64(),
            report.jobs,
            report.mean_acc
        );
    }
    let total = t0.elapsed().as_secs_f64();
    println!("{cams} cams x {windows} windows in {total:.1}s wall (one process)");
    if let Some(budget) = budget_secs {
        if total > budget {
            eprintln!("FAIL: {total:.1}s exceeds the {budget:.0}s budget");
            std::process::exit(1);
        }
        println!("within the {budget:.0}s budget");
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--scale") {
        let cams: usize = args.get(i + 1).map(|s| s.parse().unwrap()).unwrap_or(1000);
        let budget = args
            .iter()
            .position(|a| a == "--budget-secs")
            .and_then(|j| args.get(j + 1))
            .map(|s| s.parse().unwrap());
        return scale_run(cams, budget);
    }
    let engine = Engine::open_default()?;
    let gpus: f64 = args.get(1).map(|s| s.parse().unwrap()).unwrap_or(2.0);
    let bw: f64 = args.get(2).map(|s| s.parse().unwrap()).unwrap_or(6.0);
    let windows: usize = args.get(3).map(|s| s.parse().unwrap()).unwrap_or(8);
    let threads = pool::default_threads();
    println!(
        "fleet: 6 cams (3+3 correlated), {gpus} GPUs, {bw} Mbps shared, {windows} windows, \
         {threads} concurrent runs"
    );
    let policies = [Policy::ecco(), Policy::recl(), Policy::ekya(), Policy::naive()];
    let specs: Vec<RunSpec> = policies
        .iter()
        .map(|policy| {
            RunSpec::new(Task::Det, policy.clone())
                .scenario(scenario::grouped_static(&[3, 3], 0.06, 30.0, 42))
                .gpus(gpus)
                .shared_mbps(bw)
                .uplink_mbps(20.0)
                .windows(windows)
        })
        .collect();
    let t0 = std::time::Instant::now();
    let reports = run_fleet(&engine, specs, threads)?;
    for report in &reports {
        let series: Vec<String> = report.window_acc.iter().map(|a| format!("{a:.3}")).collect();
        println!(
            "{:<8} steady={:.3} final={:.3} resp={:.0}s jobs={} [{}]",
            report.name,
            report.steady,
            report.final_acc,
            report.response_s,
            report.jobs,
            series.join(" "),
        );
    }
    println!(
        "{} arms in {:.0}s wall on {} workers",
        reports.len(),
        t0.elapsed().as_secs_f64(),
        threads
    );
    Ok(())
}

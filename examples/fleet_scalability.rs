//! Compare ECCO vs baselines on a 6-camera fleet (two correlated triples)
//! under a constrained GPU + bandwidth budget — the Fig. 6 setting, small —
//! via the `ecco::api` façade. The four policy arms run **concurrently**
//! over one shared engine through `api::run_fleet`; reports come back in
//! arm order, each identical to its sequential run.
use anyhow::Result;
use ecco::api::{run_fleet, RunSpec};
use ecco::runtime::{Engine, Task};
use ecco::scene::scenario;
use ecco::server::Policy;
use ecco::util::pool;

fn main() -> Result<()> {
    let engine = Engine::open_default()?;
    let gpus: f64 = std::env::args().nth(1).map(|s| s.parse().unwrap()).unwrap_or(2.0);
    let bw: f64 = std::env::args().nth(2).map(|s| s.parse().unwrap()).unwrap_or(6.0);
    let windows: usize = std::env::args().nth(3).map(|s| s.parse().unwrap()).unwrap_or(8);
    let threads = pool::default_threads();
    println!(
        "fleet: 6 cams (3+3 correlated), {gpus} GPUs, {bw} Mbps shared, {windows} windows, \
         {threads} concurrent runs"
    );
    let policies = [Policy::ecco(), Policy::recl(), Policy::ekya(), Policy::naive()];
    let specs: Vec<RunSpec> = policies
        .iter()
        .map(|policy| {
            RunSpec::new(Task::Det, policy.clone())
                .scenario(scenario::grouped_static(&[3, 3], 0.06, 30.0, 42))
                .gpus(gpus)
                .shared_mbps(bw)
                .uplink_mbps(20.0)
                .windows(windows)
        })
        .collect();
    let t0 = std::time::Instant::now();
    let reports = run_fleet(&engine, specs, threads)?;
    for report in &reports {
        let series: Vec<String> = report.window_acc.iter().map(|a| format!("{a:.3}")).collect();
        println!(
            "{:<8} steady={:.3} final={:.3} resp={:.0}s jobs={} [{}]",
            report.name,
            report.steady,
            report.final_acc,
            report.response_s,
            report.jobs,
            series.join(" "),
        );
    }
    println!(
        "{} arms in {:.0}s wall on {} workers",
        reports.len(),
        t0.elapsed().as_secs_f64(),
        threads
    );
    Ok(())
}

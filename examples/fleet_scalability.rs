//! Compare ECCO vs baselines on a 6-camera fleet (two correlated triples)
//! under a constrained GPU + bandwidth budget — the Fig. 6 setting, small.
use anyhow::Result;
use ecco::runtime::{Engine, Task};
use ecco::scene::scenario;
use ecco::server::{Policy, System, SystemConfig};

fn main() -> Result<()> {
    let mut engine = Engine::open_default()?;
    let gpus: f64 = std::env::args().nth(1).map(|s| s.parse().unwrap()).unwrap_or(2.0);
    let bw: f64 = std::env::args().nth(2).map(|s| s.parse().unwrap()).unwrap_or(6.0);
    let windows: usize = std::env::args().nth(3).map(|s| s.parse().unwrap()).unwrap_or(8);
    println!("fleet: 6 cams (3+3 correlated), {gpus} GPUs, {bw} Mbps shared, {windows} windows");
    for policy in [Policy::ecco(), Policy::recl(), Policy::ekya(), Policy::naive()] {
        let name = policy.name;
        let sc = scenario::grouped_static(&[3, 3], 0.06, 30.0, 42);
        let mut cfg = SystemConfig::new(Task::Det, policy);
        cfg.gpus = gpus;
        let mut sys = System::new(cfg, sc.world, &[20.0; 6], bw, &mut engine)?;
        if sys.cfg.policy.zoo_warm_start {
            sys.populate_zoo_from_initial(40)?;
        }
        let t0 = std::time::Instant::now();
        let mut series = Vec::new();
        for _ in 0..windows {
            sys.run_window()?;
            series.push(format!("{:.3}", sys.mean_accuracy()));
        }
        println!(
            "{name:<8} steady={:.3} final={:.3} resp={:.0}s jobs={} [{}] ({:.0}s wall)",
            sys.history.steady_mean(0.4),
            sys.mean_accuracy(),
            sys.tracker.mean_response(windows as f64 * 60.0),
            sys.jobs.len(),
            series.join(" "),
            t0.elapsed().as_secs_f64()
        );
    }
    Ok(())
}

//! Compare ECCO vs baselines on a 6-camera fleet (two correlated triples)
//! under a constrained GPU + bandwidth budget — the Fig. 6 setting, small —
//! via the `ecco::api` façade (zoo warm-start policies are prefilled
//! automatically by `Session::new`).
use anyhow::Result;
use ecco::api::{RunSpec, Session};
use ecco::runtime::{Engine, Task};
use ecco::scene::scenario;
use ecco::server::Policy;

fn main() -> Result<()> {
    let mut engine = Engine::open_default()?;
    let gpus: f64 = std::env::args().nth(1).map(|s| s.parse().unwrap()).unwrap_or(2.0);
    let bw: f64 = std::env::args().nth(2).map(|s| s.parse().unwrap()).unwrap_or(6.0);
    let windows: usize = std::env::args().nth(3).map(|s| s.parse().unwrap()).unwrap_or(8);
    println!("fleet: 6 cams (3+3 correlated), {gpus} GPUs, {bw} Mbps shared, {windows} windows");
    for policy in [Policy::ecco(), Policy::recl(), Policy::ekya(), Policy::naive()] {
        let name = policy.name;
        let spec = RunSpec::new(Task::Det, policy)
            .scenario(scenario::grouped_static(&[3, 3], 0.06, 30.0, 42))
            .gpus(gpus)
            .shared_mbps(bw)
            .uplink_mbps(20.0)
            .windows(windows);
        let t0 = std::time::Instant::now();
        let report = Session::new(&mut engine, spec)?.run()?;
        let series: Vec<String> = report.window_acc.iter().map(|a| format!("{a:.3}")).collect();
        println!(
            "{name:<8} steady={:.3} final={:.3} resp={:.0}s jobs={} [{}] ({:.0}s wall)",
            report.steady,
            report.final_acc,
            report.response_s,
            report.jobs,
            series.join(" "),
            t0.elapsed().as_secs_f64()
        );
    }
    Ok(())
}

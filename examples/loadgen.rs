//! Load generator for `ecco serve`: N concurrent clients, each submitting
//! a session over TCP and draining its full event stream, then fetching
//! the final report. One client can be made deliberately slow (`--slow`)
//! to exercise the server's bounded subscriber buffers — it must still
//! receive its `end` frame, with drop markers accounting for every frame
//! it missed, and no other client may be affected.
//!
//! Exits non-zero if any session fails, any stream ends without a report,
//! or the whole run overshoots `--budget-secs`. Used by the `rust-serve`
//! CI job at 32 clients:
//!
//!   cargo run --release --bin ecco -- serve --listen 127.0.0.1:7433 &
//!   cargo run --release --example loadgen -- --clients 32 --slow 20 --shutdown

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};
use ecco::api::{RunSpec, SimOpts};
use ecco::runtime::Task;
use ecco::server::Policy;
use ecco::util::cli::Args;
use ecco::util::json::Json;

/// Connect with retry — the server may still be binding when we start.
fn connect(addr: &str, deadline: Duration) -> Result<TcpStream> {
    let t0 = Instant::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => return Ok(stream),
            Err(_) if t0.elapsed() < deadline => {
                thread::sleep(Duration::from_millis(100));
            }
            Err(e) => return Err(e).with_context(|| format!("connecting to {addr}")),
        }
    }
}

struct ClientStats {
    events: u64,
    dropped: u64,
    final_acc: f64,
}

/// One client: submit, drain the stream to its end frame, fetch the
/// report — all on a single connection.
fn run_client(addr: &str, id: usize, windows: usize, cams: usize, slow_ms: u64) -> Result<ClientStats> {
    let stream = connect(addr, Duration::from_secs(10))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    let mut request = |writer: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| -> Result<Json> {
        writeln!(writer, "{req}")?;
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            bail!("connection closed by server");
        }
        Json::parse(line.trim_end())
    };

    let spec = RunSpec::new(Task::Det, Policy::ecco())
        .cams(cams)
        .gpus(1.0)
        .shared_mbps(10.0)
        .windows(windows)
        .seed(1000 + id as u64)
        .sim(
            SimOpts::new()
                .window_secs(30.0)
                .micro_windows(2)
                .eval_frames(4)
                .pretrain_steps(40),
        )
        .to_wire_json()
        .to_string_compact();
    let submit = format!(
        r#"{{"cmd":"submit","spec":{spec},"events":true,"throttle_ms":{slow_ms}}}"#
    );
    let resp = request(&mut writer, &mut reader, &submit)?;
    if !matches!(resp.opt("ok"), Some(Json::Bool(true))) {
        bail!("submit rejected: {}", resp.to_string_compact());
    }
    let session = resp.get("session")?.as_usize()?;

    // Drain frames until the end frame; count events and dropped frames.
    let mut events = 0u64;
    let mut dropped = 0u64;
    loop {
        let mut frame = String::new();
        if reader.read_line(&mut frame)? == 0 {
            bail!("stream closed before end frame (session {session})");
        }
        let j = Json::parse(frame.trim_end())?;
        match j.get("frame")?.as_str()? {
            "event" => events += 1,
            "dropped" => dropped += j.get("count")?.as_usize()? as u64,
            "end" => {
                let state = j.get("state")?.as_str()?.to_string();
                if state != "done" {
                    bail!("session {session} ended {state}");
                }
                break;
            }
            other => bail!("unexpected frame kind {other:?}"),
        }
    }

    let resp = request(
        &mut writer,
        &mut reader,
        &format!(r#"{{"cmd":"report","session":{session}}}"#),
    )?;
    let final_acc = resp
        .get("final")
        .and_then(|v| v.as_f64())
        .map_err(|e| anyhow!("session {session} report missing final acc: {e}"))?;
    Ok(ClientStats {
        events,
        dropped,
        final_acc,
    })
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let mut args = args;
    args.normalize_flags(&["shutdown"]);
    args.reject_unknown(
        &["connect", "clients", "windows", "cams", "budget-secs", "slow"],
        &["shutdown"],
    )?;
    let addr = args.str_or("connect", "127.0.0.1:7433");
    let clients = args.usize_or("clients", 32)?.max(1);
    let windows = args.usize_or("windows", 3)?.max(1);
    let cams = args.usize_or("cams", 3)?.max(2);
    let budget = args.f64_or("budget-secs", 0.0)?;
    let slow_ms = args.u64_or("slow", 0)?;

    println!(
        "loadgen: {clients} clients -> {addr}, {windows} windows x {cams} cams each{}",
        if slow_ms > 0 {
            format!(", client 0 throttled {slow_ms}ms/frame")
        } else {
            String::new()
        }
    );
    let t0 = Instant::now();
    let results: Vec<(usize, Result<ClientStats>)> = thread::scope(|scope| {
        let addr = &addr;
        let handles: Vec<_> = (0..clients)
            .map(|i| {
                let throttle = if i == 0 { slow_ms } else { 0 };
                scope.spawn(move || (i, run_client(addr, i, windows, cams, throttle)))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed().as_secs_f64();

    let mut failures = 0usize;
    let mut total_events = 0u64;
    let mut total_dropped = 0u64;
    for (i, result) in &results {
        match result {
            Ok(stats) => {
                total_events += stats.events;
                total_dropped += stats.dropped;
                if stats.dropped > 0 {
                    println!(
                        "client {i:>3}: {} events, {} dropped (slow consumer), final {:.3}",
                        stats.events, stats.dropped, stats.final_acc
                    );
                }
            }
            Err(e) => {
                failures += 1;
                eprintln!("client {i:>3}: FAILED: {e:#}");
            }
        }
    }
    println!(
        "loadgen: {} sessions ok, {failures} failed, {total_events} events \
         ({total_dropped} dropped at slow consumers), {wall:.1}s wall",
        clients - failures
    );

    if args.flag("shutdown") {
        let mut conn = connect(&addr, Duration::from_secs(5))?;
        writeln!(conn, "{}", r#"{"cmd":"shutdown"}"#)?;
        println!("loadgen: sent shutdown");
    }
    if failures > 0 {
        bail!("{failures} of {clients} sessions failed");
    }
    if slow_ms > 0 && total_dropped == 0 {
        bail!("expected the throttled client to exercise drop accounting, saw none");
    }
    if budget > 0.0 && wall > budget {
        bail!("run took {wall:.1}s, over the {budget:.1}s budget");
    }
    Ok(())
}

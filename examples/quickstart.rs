//! Quickstart: three correlated cameras hit by a drift event; ECCO groups
//! them into one retraining job and recovers accuracy with 1 simulated GPU.
//!
//! The documented code path is the `ecco::api` façade: build a [`RunSpec`],
//! open a [`Session`], step windows, read [`WindowReport`]s.
//!
//! Run with: `cargo run --release --example quickstart`
//!
//! The same session can be hosted remotely: `ecco serve` exposes submit /
//! event-stream / snapshot / resume over a socket (see `ecco::serve` and
//! `examples/loadgen.rs` for a many-client driver).

use anyhow::Result;
use ecco::api::{RunSpec, Session};
use ecco::runtime::{Engine, Task};
use ecco::scene::scenario;
use ecco::server::Policy;

fn main() -> Result<()> {
    let engine = Engine::open_default()?;
    println!("loaded {} artifacts", engine.manifest.artifacts.len());

    // Three static cameras in one region (correlated drift at t=30s).
    let spec = RunSpec::new(Task::Det, Policy::ecco())
        .scenario(scenario::grouped_static(&[3], 0.06, 30.0, 42))
        .uplink_mbps(20.0) // per-camera uplinks (Mbit/s)
        .shared_mbps(6.0) // shared bottleneck
        .windows(8)
        .seed(42);
    let mut session = Session::new(&engine, spec)?;

    println!("window |  t(s) | jobs | mean mAP | per-camera mAP");
    for _ in 0..8 {
        let w = session.step_window()?;
        let accs: Vec<String> = w.cam_acc.iter().map(|a| format!("{a:.3}")).collect();
        println!(
            "{:>6} | {:>5.0} | {:>4} |   {:.3}  | {}",
            w.window,
            w.time,
            w.jobs,
            w.mean_acc,
            accs.join(" ")
        );
    }

    let stats = session.engine_stats();
    println!(
        "\nengine: {} train steps, {} infer calls, {} feature calls, {:.2}s in the engine",
        stats.train_steps,
        stats.infer_calls,
        stats.feature_calls,
        stats.exec_nanos as f64 / 1e9
    );
    println!(
        "teacher annotated {} frames; response: {}/{} requests satisfied",
        session.teacher_annotated(),
        session.requests_satisfied(),
        session.requests_total()
    );
    Ok(())
}

//! Quickstart: three correlated cameras hit by a drift event; ECCO groups
//! them into one retraining job and recovers accuracy with 1 simulated GPU.
//!
//! Run with: `cargo run --release --example quickstart`

use anyhow::Result;
use ecco::runtime::{Engine, Task};
use ecco::scene::scenario;
use ecco::server::{Policy, System, SystemConfig};

fn main() -> Result<()> {
    let mut engine = Engine::open_default()?;
    println!("loaded {} artifacts", engine.manifest.artifacts.len());

    // Three static cameras in one region (correlated drift at t=30s).
    let scenario = scenario::grouped_static(&[3], 0.06, 30.0, 42);
    let cfg = SystemConfig::new(Task::Det, Policy::ecco());
    let mut system = System::new(
        cfg,
        scenario.world,
        &[20.0, 20.0, 20.0], // uplinks (Mbit/s)
        6.0,                 // shared bottleneck
        &mut engine,
    )?;

    println!("window |  t(s) | jobs | mean mAP | per-camera mAP");
    for w in 0..8 {
        system.run_window()?;
        let accs: Vec<String> = system
            .cams
            .iter()
            .map(|c| format!("{:.3}", c.last_acc))
            .collect();
        println!(
            "{:>6} | {:>5.0} | {:>4} |   {:.3}  | {}",
            w,
            system.now(),
            system.jobs.len(),
            system.mean_accuracy(),
            accs.join(" ")
        );
    }

    let stats = &system.engine.stats;
    println!(
        "\nengine: {} train steps, {} infer calls, {} feature calls, {:.2}s in PJRT",
        stats.train_steps,
        stats.infer_calls,
        stats.feature_calls,
        stats.exec_nanos as f64 / 1e9
    );
    println!(
        "teacher annotated {} frames; response: {}/{} requests satisfied",
        system.teacher.annotated,
        system.tracker.satisfied(),
        system.tracker.total()
    );
    Ok(())
}

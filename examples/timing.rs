//! Raw engine-call latency probe (train/infer/features per resolution).
//! Like `drift_playground`, this sits below the `ecco::api` façade on
//! purpose: it times bare engine calls. System runs go through
//! `ecco::api::RunSpec` / `Session`.
use ecco::runtime::{Engine, Task, TrainBatch, Labels};
use std::time::Instant;
fn main() -> anyhow::Result<()> {
    let e = Engine::open_default()?;
    let m = e.manifest.clone();
    for &r in &[16usize, 32, 48] {
        let mut st = e.init_model(Task::Det)?;
        let b = TrainBatch { res: r, pixels: vec![0.3; m.train_batch*r*r*3],
            labels: Labels::Det { obj: vec![0.0; m.train_batch*16], cls: vec![0.0; m.train_batch*64] } };
        e.train_step(&mut st, &b, 0.01)?; // compile
        let t0 = Instant::now();
        for _ in 0..10 { e.train_step(&mut st, &b, 0.01)?; }
        println!("train r{r}: {:.1} ms/step", t0.elapsed().as_secs_f64()*100.0);
        let px = vec![0.3; m.infer_batch*r*r*3];
        e.infer_det(&st.theta, r, &px)?;
        let t0 = Instant::now();
        for _ in 0..10 { e.infer_det(&st.theta, r, &px)?; }
        println!("infer r{r}: {:.1} ms/call", t0.elapsed().as_secs_f64()*100.0);
    }
    let px = vec![0.3; m.infer_batch*32*32*3];
    e.features(&px)?;
    let t0 = Instant::now();
    for _ in 0..10 { e.features(&px)?; }
    println!("features: {:.1} ms/call", t0.elapsed().as_secs_f64()*100.0);
    Ok(())
}

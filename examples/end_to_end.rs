//! End-to-end driver: the full ECCO stack on a realistic small workload,
//! driven entirely through the `ecco::api` façade.
//!
//! Eight cameras at three intersections (3+3+2 correlated groups) hit by
//! staggered drift events; ECCO and the Naive baseline run side by side on
//! identical worlds with 2 simulated GPUs and a 8 Mbit/s shared uplink.
//! Every layer is exercised: scene rendering -> encoder/network simulation
//! (GAIMD) -> teacher labelling -> grouping (Alg. 2) -> GPU allocation
//! (Alg. 1) -> real SGD through the engine backend -> mAP evaluation.
//!
//! Run with: `cargo run --release --example end_to_end`
//! (record the output in EXPERIMENTS.md §End-to-end.)

use anyhow::Result;
use ecco::api::{RunSpec, Session};
use ecco::runtime::{Engine, Task};
use ecco::scene::scenario;
use ecco::server::Policy;

const WINDOWS: usize = 10;
const CAMS: usize = 8;

fn main() -> Result<()> {
    let t_start = std::time::Instant::now();
    let engine = Engine::open_default()?;
    println!(
        "engine: {} artifacts, det params {}, seg params {}",
        engine.manifest.artifacts.len(),
        engine.manifest.tasks["det"].param_count,
        engine.manifest.tasks["seg"].param_count,
    );

    let mut summary = Vec::new();
    for policy in [Policy::ecco(), Policy::naive()] {
        let name = policy.name;
        println!("\n=== running {name} ({CAMS} cameras, 2 GPUs, 8 Mbps shared) ===");
        let spec = RunSpec::new(Task::Det, policy)
            .scenario(scenario::grouped_static(&[3, 3, 2], 0.06, 45.0, 1234))
            .gpus(2.0)
            .shared_mbps(8.0)
            .uplink_mbps(20.0)
            .windows(WINDOWS)
            .seed(1234);
        let mut session = Session::new(&engine, spec)?;

        println!("window |  t(s) | jobs | mean mAP | min mAP | engine train-steps");
        for _ in 0..WINDOWS {
            let w = session.step_window()?;
            let min = w.cam_acc.iter().cloned().fold(f32::INFINITY, f32::min);
            println!(
                "{:>6} | {:>5.0} | {:>4} |  {:.3}   |  {:.3}  | {}",
                w.window,
                w.time,
                w.jobs,
                w.mean_acc,
                min,
                session.engine_stats().train_steps
            );
        }
        println!(
            "{name}: steady mAP {:.3}, response {:.0}s ({}/{} satisfied), {} jobs, teacher labels {}",
            session.steady_mean(0.4),
            session.mean_response(),
            session.requests_satisfied(),
            session.requests_total(),
            session.jobs(),
            session.teacher_annotated(),
        );
        summary.push((name, session.steady_mean(0.4), session.mean_response()));
    }

    let stats = engine.stats();
    println!("\n=== end-to-end summary ===");
    for (name, steady, resp) in &summary {
        println!("{name:<6} steady mAP {steady:.3}  mean response {resp:.0}s");
    }
    let (en, es, _) = summary[0];
    let (bn, bs, _) = summary[1];
    println!(
        "{en} vs {bn}: +{:.1} mAP points at identical compute/communication budgets",
        (es - bs) * 100.0
    );
    println!(
        "engine totals: {} train steps, {} infer calls, {:.1}s inside the engine, wall {:.0}s",
        stats.train_steps,
        stats.infer_calls,
        stats.exec_nanos as f64 / 1e9,
        t_start.elapsed().as_secs_f64()
    );
    Ok(())
}

//! Diagnostic: how much does each drift flavour hurt the pretrained
//! student, and how much headroom does retraining recover?
//!
//! This probe deliberately drives the engine below the `ecco::api` façade
//! (no `Session`): it measures raw model/drift interactions, not system
//! behaviour. Full-system drivers should start from `ecco::api::RunSpec`.
use anyhow::Result;
use ecco::runtime::{Engine, Task};
use ecco::scene::{DriftEvent, DriftProcess, SceneState, Zone};
use ecco::server::{eval_model, pretrain};
use ecco::util::rng::Pcg32;
use ecco::scene::render;

fn eval_on(engine: &Engine, theta: &[f32], s: &SceneState, salt: u64) -> Result<f32> {
    let frames: Vec<_> = (0..16).map(|i| render(s, 32, salt + i)).collect();
    eval_model(engine, Task::Det, theta, &frames)
}

fn main() -> Result<()> {
    let engine = Engine::open_default()?;
    let pre = pretrain::pretrained_default(&engine, Task::Det, 300, 0.03, 0x7 ^ 0xbeef)?;
    let day = SceneState::default_day();
    println!("pretrained on default_day: {:.3}", eval_on(&engine, &pre.theta, &day, 1000)?);

    let events: Vec<(&str, DriftEvent)> = vec![
        ("rain 0.85", DriftEvent::Rain(0.85)),
        ("lighting 0.45", DriftEvent::Lighting(0.45)),
        ("palette shift", DriftEvent::Palette([0.62, 0.5, 0.35])),
        ("class shift", DriftEvent::ClassShift([2.2, 0.3, 1.8, 0.2])),
        ("tunnel", DriftEvent::ZoneChange(Zone::Tunnel)),
        ("urban", DriftEvent::ZoneChange(Zone::Urban)),
    ];
    for (name, ev) in events {
        let mut p = DriftProcess::new(day.clone(), 0.015, 5);
        p.apply(&ev);
        let drifted = p.state.clone();
        let acc0 = eval_on(&engine, &pre.theta, &drifted, 2000)?;
        // Retrain to convergence on the drifted distribution.
        let mut model = ecco::runtime::ModelState::from_theta(Task::Det, pre.theta.clone());
        let m = engine.manifest.clone();
        let mut rng = Pcg32::seeded(9);
        let pool: Vec<_> = (0..96).map(|i| render(&drifted, 32, 5000 + i)).collect();
        for step in 0..400 {
            let picks: Vec<usize> = (0..m.train_batch).map(|_| rng.index(pool.len())).collect();
            let frames: Vec<_> = picks.iter().map(|&i| &pool[i]).collect();
            let truths: Vec<_> = picks.iter().map(|&i| &pool[i].truth).collect();
            let tb = ecco::runtime::batch::train_batch(Task::Det, &frames, &truths, m.train_batch, 32, m.classes, m.grid);
            engine.train_step(&mut model, &tb, 0.03)?;
            if step == 49 || step == 199 {
                let a = eval_on(&engine, &model.theta, &drifted, 2000)?;
                print!(" [{}st: {:.3}]", step + 1, a);
            }
        }
        let acc_final = eval_on(&engine, &model.theta, &drifted, 2000)?;
        println!("  {name:<16} drop-> {acc0:.3}, retrained(400)-> {acc_final:.3}");
    }
    Ok(())
}

//! Chaos walkthrough: six cameras in two correlated triples run under the
//! `Heavy` fault preset — every window ≥30% of the fleet flaps, one uplink
//! goes fully dark, and a straggler plus a corrupted probe are thrown in.
//! The system must complete every window without panicking, and the report
//! gains resilience metrics (accuracy under fault, windows-to-recover).
//!
//! The fault schedule is part of the [`RunSpec`]: same plan + same seed →
//! byte-identical event logs at any thread count, exactly like healthy
//! runs (see `ecco::faults`).
//!
//! Run with: `cargo run --release --example chaos`

use anyhow::Result;
use ecco::api::{Event, RunSpec, Session};
use ecco::faults::{FaultPlan, FaultScenario};
use ecco::runtime::{Engine, Task};
use ecco::scene::scenario;
use ecco::server::Policy;

fn main() -> Result<()> {
    let engine = Engine::open_default()?;
    let windows = 6;
    let plan = FaultPlan::scenario(FaultScenario::Heavy, 6, windows, 0xfa17);
    println!("fault plan: {} scheduled events over {windows} windows", plan.len());

    let spec = RunSpec::new(Task::Det, Policy::ecco())
        .scenario(scenario::grouped_static(&[3, 3], 0.06, 30.0, 42))
        .uplink_mbps(20.0)
        .shared_mbps(6.0)
        .windows(windows)
        .seed(42)
        .faults(plan);
    let mut session = Session::new(&engine, spec)?;

    println!("window |  t(s) | jobs | mean mAP | down | link | degraded");
    let mut seen = 0;
    for _ in 0..windows {
        let w = session.step_window()?;
        // Count the fault-side events this window emitted.
        let fresh = &session.events()[seen..];
        seen = session.events().len();
        let count = |k: &str| fresh.iter().filter(|e| e.kind() == k).count();
        println!(
            "{:>6} | {:>5.0} | {:>4} |   {:.3}  | {:>4} | {:>4} | {:>8}",
            w.window,
            w.time,
            w.jobs,
            w.mean_acc,
            count("camera_down"),
            count("link_degraded"),
            count("degraded"),
        );
    }

    let recovered: Vec<&Event> = session
        .events()
        .iter()
        .filter(|e| e.kind() == "fault_recovered")
        .collect();
    println!("\n{} recoveries completed during the run", recovered.len());

    let r = session.resilience();
    println!(
        "resilience: {} fault-active windows, mAP under fault {:.3}, \
         {} recoveries, mean {:.1} windows to recover",
        r.fault_windows, r.acc_under_fault, r.recoveries, r.windows_to_recover
    );
    Ok(())
}

//! Microbenchmarks for the simulation substrates: scene rendering, network
//! simulation, encoding, metrics. These are the §Perf probes for everything
//! that runs per-frame or per-tick in the window loop.
//!
//! Run: `cargo bench --bench substrates` (optionally with a filter).

use ecco::metrics::det_map;
use ecco::net::NetSim;
use ecco::runtime::DetPred;
use ecco::scene::{render, GroundTruth, SceneState};
use ecco::util::bench::{black_box, BenchSuite};
use ecco::util::rng::Pcg32;
use ecco::video::{degrade, transport_window, SamplingConfig};

fn main() {
    let mut b = BenchSuite::new("substrates");
    let state = SceneState::default_day();

    for res in [16usize, 32, 48] {
        let mut seed = 0u64;
        b.bench(&format!("render_frame_r{res}"), || {
            seed += 1;
            render(&state, res, seed)
        });
    }

    b.bench("degrade_frame_r32_q0.4", || {
        let mut px = vec![0.5f32; 32 * 32 * 3];
        degrade(&mut px, 32, 0.4, 7);
        px
    });

    b.bench("transport_window", || {
        transport_window(SamplingConfig { fps: 5.0, res: 48 }, 60.0, 3.0)
    });

    // Network: 22 flows over a shared bottleneck, one 60s window.
    b.bench_timed("netsim_60s_22flows", || {
        let mut sim = NetSim::star(&vec![20.0; 22], 50.0);
        for i in 0..22 {
            sim.add_camera_flow(i, 1.0, 0.5).unwrap();
        }
        let t0 = std::time::Instant::now();
        sim.run(60.0);
        black_box(sim.delivered_mbit(ecco::net::FlowId(0)));
        t0.elapsed()
    });

    // Metrics: mAP over a 16-frame eval batch.
    let frames: Vec<_> = (0..16).map(|i| render(&state, 32, 100 + i)).collect();
    let truths: Vec<&GroundTruth> = frames.iter().map(|f| &f.truth).collect();
    let mut rng = Pcg32::seeded(3);
    let pred = DetPred {
        batch: 16,
        grid: 4,
        classes: 4,
        obj: (0..16 * 16).map(|_| rng.f32()).collect(),
        cls: (0..16 * 16 * 4).map(|_| rng.f32()).collect(),
    };
    b.bench("det_map_16frames", || det_map(&pred, &truths, 16));

    b.finish();
}

//! Coordinator benchmarks: allocator decisions, grouping decisions, and the
//! end-to-end retraining window (the paper's operational unit). The window
//! bench is the one a deployment sizes hardware against — it corresponds to
//! the per-window work behind every table in §5.
//!
//! Run: `cargo bench --bench coordinator`

use ecco::alloc::{Allocator, EccoAllocator, JobView, UniformAllocator, UtilityAllocator};
use ecco::api::{RunSpec, Session};
use ecco::grouping::{group_request, metadata_correlated, GroupJob, GroupingPolicy, RequestMeta};
use ecco::runtime::{Engine, Task};
use ecco::scene::scenario;
use ecco::server::Policy;
use ecco::util::bench::{black_box, BenchSuite};

fn jobs(n: usize) -> Vec<JobView> {
    (0..n)
        .map(|id| JobView {
            id,
            n_cams: 1 + id % 4,
            acc: 0.2 + 0.05 * (id % 7) as f32,
            acc_gain: 0.01 * (id % 5) as f32,
            micro_windows: 1,
            lifetime_mw: 1 + id,
        })
        .collect()
}

fn main() {
    let mut b = BenchSuite::new("coordinator");

    // Allocator decision latency at fleet scale.
    for n in [4usize, 22, 128] {
        let views = jobs(n);
        let mut ecco_alloc = EccoAllocator::default();
        b.bench(&format!("alloc_pick_ecco_{n}jobs"), || {
            ecco_alloc.pick(black_box(&views))
        });
        let mut util = UtilityAllocator;
        b.bench(&format!("alloc_pick_utility_{n}jobs"), || {
            util.pick(black_box(&views))
        });
        let mut uni = UniformAllocator;
        b.bench(&format!("alloc_pick_uniform_{n}jobs"), || {
            uni.pick(black_box(&views))
        });
        let e2 = EccoAllocator::default();
        b.bench(&format!("alloc_share_estimates_{n}jobs"), || {
            e2.share_estimates(black_box(&views))
        });
    }

    // Grouping: metadata filter + request placement over a job population.
    let policy = GroupingPolicy::default();
    let mut gjobs: Vec<GroupJob> = (0..64)
        .map(|i| {
            GroupJob::new(
                i,
                RequestMeta {
                    cam: i,
                    time: 10.0 * i as f64,
                    loc: (0.01 * i as f32, 0.5),
                    acc: 0.2,
                },
            )
        })
        .collect();
    let req = RequestMeta {
        cam: 999,
        time: 320.0,
        loc: (0.3, 0.5),
        acc: 0.2,
    };
    b.bench("grouping_metadata_filter_64jobs", || {
        gjobs
            .iter()
            .filter(|j| metadata_correlated(&policy, j, &req))
            .count()
    });
    let mut next_id = 1000;
    b.bench("grouping_request_64jobs", || {
        let mut jobs2 = gjobs.clone();
        group_request(&mut jobs2, &mut next_id, &policy, req.clone(), |_| 0.1)
    });
    gjobs.truncate(64);

    // End-to-end: one full retraining window of the real system (engine
    // training, network sim, teacher, metrics) at the Fig. 6 scale,
    // assembled through the api façade.
    let engine = Engine::open_default().expect("engine should open");
    b.bench_timed("e2e_window_6cams_ecco", || {
        let spec = RunSpec::new(Task::Det, Policy::ecco())
            .scenario(scenario::grouped_static(&[3, 3], 0.06, 10.0, 42))
            .gpus(2.0)
            .shared_mbps(6.0)
            .uplink_mbps(20.0)
            .windows(1)
            .seed(42)
            .configure(|cfg| cfg.pretrain_steps = 120);
        let mut session = Session::new(&engine, spec).unwrap();
        let t0 = std::time::Instant::now();
        let report = session.step_window().unwrap();
        let dt = t0.elapsed();
        black_box(report.mean_acc);
        dt
    });

    b.finish();
}

//! Serve-host benchmarks, socket-free: protocol parse cost, wire-spec
//! round-trip cost, and registry event fan-out to N subscribers (the
//! per-event price every runner thread pays while streams are attached).
//!
//! Run: `cargo bench --bench serve`

use ecco::api::{Event, RunSpec, SimOpts};
use ecco::runtime::Task;
use ecco::serve::{Registry, ServeConfig};
use ecco::server::Policy;
use ecco::util::bench::{black_box, BenchSuite};

fn wide_spec() -> RunSpec {
    RunSpec::new(Task::Det, Policy::ecco())
        .cams(22)
        .gpus(4.0)
        .shared_mbps(12.0)
        .uplinks((0..22).map(|c| 8.0 + c as f64).collect())
        .topology_degree(6)
        .windows(20)
        .seed(42)
        .sim(SimOpts::new().window_secs(40.0).micro_windows(4))
}

fn main() {
    let mut b = BenchSuite::new("serve");

    // Request-line parse latency (the per-request floor on every conn).
    let submit_line = format!(
        r#"{{"cmd":"submit","spec":{},"events":true,"throttle_ms":0}}"#,
        wide_spec().to_wire_json().to_string_compact()
    );
    b.bench("protocol_parse_submit_22cams", || {
        ecco::serve::protocol::parse_request(black_box(&submit_line)).unwrap()
    });
    let status_line = r#"{"cmd":"status","session":17}"#;
    b.bench("protocol_parse_status", || {
        ecco::serve::protocol::parse_request(black_box(status_line)).unwrap()
    });

    // Wire spec export + re-validate (paid once per submit and resume).
    let wire = wide_spec().to_wire_json();
    b.bench("spec_wire_round_trip_22cams", || {
        let spec = RunSpec::from_wire_json(black_box(&wire)).unwrap();
        spec.to_wire_json()
    });

    // Event fan-out through the registry: render + bounded push to N
    // attached subscribers (nobody draining — worst case, all drops after
    // the buffer fills).
    for subs in [1usize, 8, 32] {
        let registry = Registry::new(ServeConfig::default());
        let (id, _sub) = registry
            .submit(wire.clone(), 20, 0, None, true)
            .unwrap();
        for _ in 1..subs {
            registry.subscribe(id).unwrap();
        }
        registry.next_job().unwrap();
        registry.begin(id).unwrap();
        let event = Event::WindowClosed {
            time: 120.0,
            window: 3,
            mean_acc: 0.412,
            cam_acc: vec![0.4; 22],
            membership: vec![(0, (0..11).collect()), (1, (11..22).collect())],
        };
        b.bench(&format!("registry_publish_{subs}subs"), || {
            registry.publish_event(black_box(id), black_box(&event), true)
        });
    }

    b.finish();
}

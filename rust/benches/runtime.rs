//! PJRT runtime benchmarks: per-call latency of the AOT executables — the
//! L3 hot path's dominant cost. Paper-table analogue: the per-step training
//! cost that the GPU allocator budgets (§3.1).
//!
//! Run: `cargo bench --bench runtime`

use ecco::runtime::{Engine, Labels, Task, TrainBatch};
use ecco::util::bench::BenchSuite;

fn main() {
    let engine = Engine::open_default().expect("engine should open");
    let m = engine.manifest.clone();
    let mut b = BenchSuite::new("runtime");

    for &res in &m.resolutions.clone() {
        let mut state = engine.init_model(Task::Det).unwrap();
        let batch = TrainBatch {
            res,
            pixels: vec![0.3; m.train_batch * res * res * 3],
            labels: Labels::Det {
                obj: vec![0.0; m.train_batch * m.grid * m.grid],
                cls: vec![0.0; m.train_batch * m.grid * m.grid * m.classes],
            },
        };
        engine.train_step(&mut state, &batch, 0.01).unwrap(); // compile
        b.bench(&format!("train_step_det_r{res}"), || {
            engine.train_step(&mut state, &batch, 0.01).unwrap()
        });

        let px = vec![0.3; m.infer_batch * res * res * 3];
        engine.infer_det(&state.theta, res, &px).unwrap();
        b.bench(&format!("infer_det_r{res}"), || {
            engine.infer_det(&state.theta, res, &px).unwrap()
        });
    }

    // Seg at the default eval resolution.
    let mut seg = engine.init_model(Task::Seg).unwrap();
    let res = 32;
    let s = res / 4;
    let batch = TrainBatch {
        res,
        pixels: vec![0.3; m.train_batch * res * res * 3],
        labels: Labels::Seg {
            mask: {
                let mut v = vec![0.0; m.train_batch * s * s * (m.classes + 1)];
                for c in v.chunks_mut(m.classes + 1) {
                    c[m.classes] = 1.0;
                }
                v
            },
        },
    };
    engine.train_step(&mut seg, &batch, 0.01).unwrap();
    b.bench("train_step_seg_r32", || {
        engine.train_step(&mut seg, &batch, 0.01).unwrap()
    });

    let px = vec![0.3; m.infer_batch * m.feature_res * m.feature_res * 3];
    engine.features(&px).unwrap();
    b.bench("features_b16", || engine.features(&px).unwrap());

    b.finish();
    let stats = engine.stats();
    println!(
        "engine stats: {} train steps, {} infer calls, {:.2}s total in the engine",
        stats.train_steps,
        stats.infer_calls,
        stats.exec_nanos as f64 / 1e9
    );
}

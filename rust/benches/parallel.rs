//! Parallelism benchmarks: the batch-sharded native train step, the
//! per-window eval fan-out, and the fleet driver at 1 vs N worker
//! threads. The printed pair per workload is the number a deployment
//! cares about — how much wall-clock the worker pool buys on this
//! machine's cores (determinism is unaffected either way; see the
//! threading notes in `ecco`'s crate docs).
//!
//! Run: `cargo bench --bench parallel`

use std::collections::BTreeSet;

use ecco::api::{run_fleet, RunSpec, RuntimeOpts};
use ecco::grouping::topology::Topology;
use ecco::grouping::{group_request_pruned, Decision, GroupJob, GroupingPolicy, RequestMeta};
use ecco::runtime::native::{self, Exec};
use ecco::runtime::{CoalesceOpts, Engine, Labels, Task, TrainBatch};
use ecco::scene::scenario;
use ecco::server::sched::{EventWheel, SchedEvent};
use ecco::server::{eval_model, Policy};
use ecco::util::bench::{black_box, BenchSuite};
use ecco::util::pool::{self, Pool};
use ecco::util::rng::Pcg32;

fn main() {
    let engine = Engine::open_default().expect("engine should open");
    let mut b = BenchSuite::new("parallel");
    let n_threads = pool::default_threads().max(2);

    // Batch-sharded native train step: one SGD step (res 48, batch 8) at
    // 1 vs N kernel threads over explicit pools. The per-sample shards
    // reduce in sample order, so both rows compute bit-identical steps —
    // the ratio is pure wall-clock.
    {
        let r = 48usize;
        let bsz = native::TRAIN_BATCH;
        let theta0 = native::he_init(Task::Det, 77);
        let mom0 = vec![0.0f32; theta0.len()];
        let mut rng = Pcg32::new(77, 0xbe7);
        let pixels: Vec<f32> = (0..bsz * r * r * 3).map(|_| rng.f32()).collect();
        let obj: Vec<f32> = (0..bsz * native::GRID * native::GRID)
            .map(|_| if rng.chance(0.3) { 1.0 } else { 0.0 })
            .collect();
        let mut cls = vec![0.0f32; bsz * native::GRID * native::GRID * native::K];
        for (i, chunk) in cls.chunks_mut(native::K).enumerate() {
            chunk[i % native::K] = 1.0;
        }
        let batch = TrainBatch {
            res: r,
            pixels,
            labels: Labels::Det { obj, cls },
        };
        for threads in [1usize, n_threads] {
            let kernel_pool = Pool::new(threads.saturating_sub(1));
            let exec = Exec {
                pool: &kernel_pool,
                threads,
            };
            b.bench(&format!("train_step_shard_res48_{threads}threads"), || {
                let mut theta = theta0.clone();
                let mut mom = mom0.clone();
                native::train_step(Task::Det, &mut theta, &mut mom, &batch, bsz, 0.01, exec)
            });
        }
    }

    // Eval fan-out: one model evaluated on 16 cameras' held-out batches —
    // the shape of the end-of-window per-camera pass. The engine under
    // test gets a SERIAL kernel pool (ECCO_THREADS=1 at construction), so
    // these rows isolate the outer per-camera fan-out; kernel sharding is
    // measured by the train_step rows above.
    std::env::set_var("ECCO_THREADS", "1");
    let engine_serial = Engine::open_default().expect("engine should open");
    std::env::remove_var("ECCO_THREADS");
    let sc = scenario::town(16, 7);
    let world = sc.world;
    let model = engine_serial.init_model(Task::Det).expect("init model");
    let cams: Vec<usize> = (0..16).collect();
    for threads in [1usize, n_threads] {
        b.bench(&format!("eval_fanout_16cams_{threads}threads"), || {
            pool::try_map(threads, &cams, |_, &cam| {
                let frames = world.eval_frames(cam, 32, 16, 0xbe7 + cam as u64);
                eval_model(&engine_serial, Task::Det, &model.theta, &frames)
            })
            .expect("eval fan-out")
        });
    }

    // Micro-batched eval fan-out: the same end-of-window shape, but with
    // the engine's coalescing submission layer on vs off. Eight cameras
    // evaluate ONE shared model, so with >=2 outer threads the coalesced
    // rows merge per-camera infer calls into mega-batched launches; at 1
    // thread a lone submitter skips the coalesce window entirely, so the
    // coalesced row should be no slower than per-call. Results are
    // bit-identical across all four rows (per-sample pure kernels).
    {
        let cams8: Vec<usize> = (0..8).collect();
        for threads in [1usize, n_threads] {
            for (tag, opts) in [
                ("percall", CoalesceOpts::default()),
                ("coalesced", CoalesceOpts::on()),
            ] {
                engine_serial.set_coalesce(opts);
                b.bench(&format!("infer_endwindow_8cams_{tag}_{threads}t"), || {
                    pool::try_map(threads, &cams8, |_, &cam| {
                        let frames = world.eval_frames(cam, 32, 16, 0x5eed + cam as u64);
                        eval_model(&engine_serial, Task::Det, &model.theta, &frames)
                    })
                    .expect("micro-batched eval fan-out")
                });
            }
        }
        engine_serial.set_coalesce(CoalesceOpts::default());
    }

    // Fleet driver: four policy arms of a small end-to-end run sharing the
    // engine (the exp-runner sweep shape). Timed per fleet, not per run.
    // Since PR 5 every layer (fleet workers, eval fan-out, kernel shards)
    // rides the ONE bounded engine pool, so the 1-vs-N ratio measures how
    // much of a run's serial, non-kernel work (net sim, teacher, batching)
    // fleet concurrency can overlap on top of always-on kernel sharding —
    // expect a smaller ratio than the pre-PR-5 scoped-thread numbers.
    for threads in [1usize, n_threads] {
        b.bench_timed(&format!("fleet_4runs_{threads}threads"), || {
            let specs: Vec<RunSpec> = [
                Policy::ecco(),
                Policy::recl(),
                Policy::ekya(),
                Policy::naive(),
            ]
            .into_iter()
            .map(|policy| {
                // Pin each run to one eval worker so per-run eval fan-outs
                // don't additionally contend for the shared pool.
                RunSpec::new(Task::Det, policy)
                    .scenario(scenario::grouped_static(&[2], 0.05, 20.0, 40))
                    .gpus(1.0)
                    .shared_mbps(10.0)
                    .uplink_mbps(20.0)
                    .windows(2)
                    .seed(40)
                    .runtime(RuntimeOpts::new().threads(1))
                    .configure(|cfg| {
                        cfg.micro_windows = 4;
                        cfg.window_secs = 40.0;
                        cfg.eval_frames = 8;
                        cfg.pretrain_steps = 80;
                    })
            })
            .collect();
            let t0 = std::time::Instant::now();
            let reports = run_fleet(&engine, specs, threads).expect("fleet");
            let dt = t0.elapsed();
            black_box(reports.len());
            dt
        });
    }

    // Scheduler time wheel at fleet scale: build + drain one window's
    // worth of per-camera capture/probe events plus the training lanes at
    // w_eff = 8 slots (the fleet cap). The per-window coordination cost of
    // the event driver is exactly this heap churn, so the 100 -> 1k -> 10k
    // rows should scale near-linearly (O(n log n)), not quadratically.
    for n in [100usize, 1_000, 10_000] {
        let w_eff = 8usize;
        b.bench(&format!("sched_wheel_{n}cams"), || {
            let mut wheel = EventWheel::new();
            for cam in 0..n {
                for slot in 1..=w_eff {
                    wheel.push(SchedEvent::capture(slot, cam));
                    wheel.push(SchedEvent::probe(slot, cam));
                }
            }
            for mw in 0..w_eff {
                wheel.push(SchedEvent::train(mw + 1, mw));
            }
            let mut drained = 0usize;
            for slot in 1..=w_eff {
                while let Some(ev) = wheel.pop_due(slot) {
                    drained = drained.wrapping_add(ev.cam);
                }
            }
            drained
        });
    }

    // Grouping placement at fleet scale: one request per camera, placed
    // sequentially with camera -> job tracking (the System's shape). The
    // eval closure spins a fixed arithmetic load standing in for a model
    // eval — eval count x cost dominates real runs, so the all-pairs rows
    // grow quadratically with the fleet while the degree-8 topology rows
    // stay near-linear. The metadata filter is off (worst case for
    // all-pairs, per the §3.3 ablation) and every eval fails the
    // performance check so the job list grows to n, the city-scale regime.
    for n in [100usize, 1_000] {
        let sc = scenario::town(n, 11);
        let positions: Vec<(f32, f32)> = sc.world.cameras.iter().map(|c| c.pos).collect();
        // O(n^2) build, done once out here — the rows time placement only.
        let topo = Topology::from_positions(&positions, 8);
        let policy = GroupingPolicy {
            metadata_filter: false,
            ..GroupingPolicy::default()
        };
        for (tag, topo) in [("allpairs", None), ("topo8", Some(&topo))] {
            b.bench(&format!("group_place_{n}cams_{tag}"), || {
                let mut jobs: Vec<GroupJob> = Vec::new();
                let mut next_id = 0usize;
                let mut cam_job = vec![usize::MAX; n];
                let mut evals = 0usize;
                for cam in 0..n {
                    let req = RequestMeta {
                        cam,
                        time: 0.0,
                        loc: positions[cam],
                        acc: 0.5,
                    };
                    let candidates: Option<BTreeSet<usize>> = topo.map(|t| {
                        t.neighbors(cam)
                            .iter()
                            .filter_map(|&nb| match cam_job[nb] {
                                usize::MAX => None,
                                id => Some(id),
                            })
                            .collect()
                    });
                    let decision = group_request_pruned(
                        &mut jobs,
                        &mut next_id,
                        &policy,
                        candidates.as_ref(),
                        req,
                        |_job| {
                            evals += 1;
                            let mut x = 0.37f32;
                            for i in 0..400u32 {
                                x = (x * 1.000_001 + i as f32 * 1e-7).fract();
                            }
                            black_box(x) * 1e-6 // always below req.acc
                        },
                    );
                    cam_job[cam] = match decision {
                        Decision::Joined(id) | Decision::NewJob(id) => id,
                    };
                }
                (jobs.len(), evals)
            });
        }
    }

    b.finish();
}

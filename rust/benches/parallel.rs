//! Parallelism benchmarks: the per-window eval fan-out and the fleet
//! driver at 1 vs N worker threads. The printed pair per workload is the
//! number a deployment cares about — how much wall-clock the worker pool
//! buys on this machine's cores (determinism is unaffected either way; see
//! the threading notes in `ecco`'s crate docs).
//!
//! Run: `cargo bench --bench parallel`

use ecco::api::{run_fleet, RunSpec};
use ecco::runtime::{Engine, Task};
use ecco::scene::scenario;
use ecco::server::{eval_model, Policy};
use ecco::util::bench::{black_box, BenchSuite};
use ecco::util::pool;

fn main() {
    let engine = Engine::open_default().expect("engine should open");
    let mut b = BenchSuite::new("parallel");
    let n_threads = pool::default_threads().max(2);

    // Eval fan-out: one model evaluated on 16 cameras' held-out batches —
    // the shape of the end-of-window per-camera pass.
    let sc = scenario::town(16, 7);
    let world = sc.world;
    let model = engine.init_model(Task::Det).expect("init model");
    let cams: Vec<usize> = (0..16).collect();
    for threads in [1usize, n_threads] {
        b.bench(&format!("eval_fanout_16cams_{threads}threads"), || {
            pool::try_map(threads, &cams, |_, &cam| {
                let frames = world.eval_frames(cam, 32, 16, 0xbe7 + cam as u64);
                eval_model(&engine, Task::Det, &model.theta, &frames)
            })
            .expect("eval fan-out")
        });
    }

    // Fleet driver: four policy arms of a small end-to-end run sharing the
    // engine (the exp-runner sweep shape). Timed per fleet, not per run.
    for threads in [1usize, n_threads] {
        b.bench_timed(&format!("fleet_4runs_{threads}threads"), || {
            let specs: Vec<RunSpec> = [
                Policy::ecco(),
                Policy::recl(),
                Policy::ekya(),
                Policy::naive(),
            ]
            .into_iter()
            .map(|policy| {
                // Pin each run to one eval worker so the 1-vs-N comparison
                // isolates FLEET concurrency (run_fleet would otherwise
                // redistribute the same cores to per-run eval workers and
                // flatten the ratio).
                RunSpec::new(Task::Det, policy)
                    .scenario(scenario::grouped_static(&[2], 0.05, 20.0, 40))
                    .gpus(1.0)
                    .shared_mbps(10.0)
                    .uplink_mbps(20.0)
                    .windows(2)
                    .seed(40)
                    .eval_threads(1)
                    .configure(|cfg| {
                        cfg.micro_windows = 4;
                        cfg.window_secs = 40.0;
                        cfg.eval_frames = 8;
                        cfg.pretrain_steps = 80;
                    })
            })
            .collect();
            let t0 = std::time::Instant::now();
            let reports = run_fleet(&engine, specs, threads).expect("fleet");
            let dt = t0.elapsed();
            black_box(reports.len());
            dt
        });
    }

    b.finish();
}

//! A lightweight Rust lexer for the lint pass.
//!
//! This is not a compiler front-end: it splits source text into just
//! enough structure for token-pattern rules — identifiers, single-char
//! punctuation, opaque literals, lifetimes — while keeping **comments**
//! (with line numbers) as a separate stream, because two of the lint
//! rules are *about* comments: `// SAFETY:` adjacency (D004) and
//! `// ecco-lint: allow(..)` suppressions. The tricky parts it must get
//! right so rules never fire inside non-code text:
//!
//! * line and nested block comments;
//! * string/char literals, including raw strings (`r#"..."#`), byte and
//!   C-string prefixes, and escapes — `"lock().unwrap()"` in a string is
//!   a literal, not a call;
//! * lifetimes vs char literals (`'a` vs `'a'`);
//! * numbers with tuple access, ranges, and exponents (`x.0`, `0..n`,
//!   `1e-5`) so the `.` punctuation rules see is really method syntax.

/// One code token. Comments are *not* tokens — see [`Comment`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Token {
    pub kind: Tok,
    /// 1-based source line of the token's first character.
    pub line: usize,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword (`unwrap`, `unsafe`, `HashMap`, ...).
    Ident(String),
    /// A single punctuation character (`.`, `(`, `!`, ...). Multi-char
    /// operators arrive as consecutive tokens; the rules only ever match
    /// single chars.
    Punct(char),
    /// String/char/number literal, content discarded.
    Literal,
    /// `'a`, `'static` — kept distinct so they can't be mistaken for
    /// unterminated char literals.
    Lifetime,
}

/// One comment, line (`// ...`) or block (`/* ... */`), doc or plain.
/// Block comments spanning multiple lines keep their full text and the
/// line they *start* on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Comment {
    pub text: String,
    /// 1-based line of the comment's first character.
    pub line: usize,
}

/// Lexed file: code tokens and comments as parallel streams.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src`. Never fails: malformed input (unterminated strings and the
/// like) degrades to consuming the rest of the file as a literal, which
/// is the safe direction for a linter (no token patterns can fire there).
pub fn lex(src: &str) -> Lexed {
    Lexer {
        cs: src.chars().collect(),
        i: 0,
        line: 1,
        out: Lexed::default(),
    }
    .run()
}

struct Lexer {
    cs: Vec<char>,
    i: usize,
    line: usize,
    out: Lexed,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.cs.get(self.i + ahead).copied()
    }

    /// Advance one char, tracking newlines.
    fn bump(&mut self) -> Option<char> {
        let c = self.peek(0)?;
        self.i += 1;
        if c == '\n' {
            self.line += 1;
        }
        Some(c)
    }

    fn push(&mut self, kind: Tok, line: usize) {
        self.out.tokens.push(Token { kind, line });
    }

    fn run(mut self) -> Lexed {
        while let Some(c) = self.peek(0) {
            let line = self.line;
            if c.is_whitespace() {
                self.bump();
            } else if c == '/' && self.peek(1) == Some('/') {
                self.line_comment(line);
            } else if c == '/' && self.peek(1) == Some('*') {
                self.block_comment(line);
            } else if c == '"' {
                self.string();
                self.push(Tok::Literal, line);
            } else if c == '\'' {
                self.quote(line);
            } else if c.is_ascii_digit() {
                self.number();
                self.push(Tok::Literal, line);
            } else if is_ident_start(c) {
                self.ident_or_prefixed(line);
            } else {
                self.bump();
                self.push(Tok::Punct(c), line);
            }
        }
        self.out
    }

    fn line_comment(&mut self, line: usize) {
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            text.push(c);
            self.bump();
        }
        self.out.comments.push(Comment { text, line });
    }

    fn block_comment(&mut self, line: usize) {
        let mut text = String::new();
        let mut depth = 0usize;
        while let Some(c) = self.peek(0) {
            if c == '/' && self.peek(1) == Some('*') {
                depth += 1;
                text.push_str("/*");
                self.bump();
                self.bump();
            } else if c == '*' && self.peek(1) == Some('/') {
                depth -= 1;
                text.push_str("*/");
                self.bump();
                self.bump();
                if depth == 0 {
                    break;
                }
            } else {
                text.push(c);
                self.bump();
            }
        }
        self.out.comments.push(Comment { text, line });
    }

    /// A `"`-delimited string with escapes; the opening quote is current.
    fn string(&mut self) {
        self.bump(); // opening "
        while let Some(c) = self.bump() {
            if c == '\\' {
                self.bump(); // whatever is escaped, incl. \" and \\
            } else if c == '"' {
                break;
            }
        }
    }

    /// A raw string with `hashes` hash marks; positioned at the opening
    /// quote.
    fn raw_string(&mut self, hashes: usize) {
        self.bump(); // opening "
        'outer: while let Some(c) = self.bump() {
            if c == '"' {
                for k in 0..hashes {
                    if self.peek(k) != Some('#') {
                        continue 'outer;
                    }
                }
                for _ in 0..hashes {
                    self.bump();
                }
                break;
            }
        }
    }

    /// `'` disambiguation: lifetime (`'a`) vs char literal (`'a'`, `'\n'`).
    fn quote(&mut self, line: usize) {
        let one = self.peek(1);
        let two = self.peek(2);
        let is_lifetime = one.is_some_and(is_ident_start) && two != Some('\'');
        self.bump(); // the '
        if is_lifetime {
            while self.peek(0).is_some_and(is_ident_continue) {
                self.bump();
            }
            self.push(Tok::Lifetime, line);
            return;
        }
        // Char literal: consume up to the closing quote, honoring escapes.
        while let Some(c) = self.bump() {
            if c == '\\' {
                self.bump();
            } else if c == '\'' {
                break;
            }
        }
        self.push(Tok::Literal, line);
    }

    /// Number literal: integers, floats, suffixes, hex, exponents. Stops
    /// before `..` (ranges) and before `.method` / `.0`-style access so
    /// the dot stays a punct token.
    fn number(&mut self) {
        self.digits_and_suffix();
        if self.peek(0) == Some('.') && self.peek(1).is_some_and(|c| c.is_ascii_digit()) {
            self.bump();
            self.digits_and_suffix();
        }
    }

    /// `[0-9a-zA-Z_]*` plus an exponent sign immediately after `e`/`E`.
    fn digits_and_suffix(&mut self) {
        let mut prev = '\0';
        while let Some(c) = self.peek(0) {
            if is_ident_continue(c) {
                prev = c;
                self.bump();
            } else if (c == '+' || c == '-')
                && (prev == 'e' || prev == 'E')
                && self.peek(1).is_some_and(|d| d.is_ascii_digit())
            {
                prev = c;
                self.bump();
            } else {
                break;
            }
        }
    }

    /// An identifier, unless it turns out to be a string prefix
    /// (`r"`, `r#"`, `b"`, `br#"`, `c"`, ...) or a raw identifier
    /// (`r#type`).
    fn ident_or_prefixed(&mut self, line: usize) {
        let start = self.i;
        while self.peek(0).is_some_and(is_ident_continue) {
            self.bump();
        }
        let name: String = self.cs[start..self.i].iter().collect();
        let next = self.peek(0);
        let string_prefix = matches!(name.as_str(), "r" | "b" | "c" | "br" | "cr" | "rb");
        if string_prefix && next == Some('"') {
            if name.contains('r') {
                self.raw_string(0);
            } else {
                self.string();
            }
            self.push(Tok::Literal, line);
            return;
        }
        if string_prefix && next == Some('#') {
            let mut hashes = 0;
            while self.peek(hashes) == Some('#') {
                hashes += 1;
            }
            if self.peek(hashes) == Some('"') {
                for _ in 0..hashes {
                    self.bump();
                }
                self.raw_string(hashes);
                self.push(Tok::Literal, line);
                return;
            }
            if name == "r" && self.peek(1).is_some_and(is_ident_start) {
                // Raw identifier r#type: emit the bare name.
                self.bump(); // #
                let s2 = self.i;
                while self.peek(0).is_some_and(is_ident_continue) {
                    self.bump();
                }
                let raw: String = self.cs[s2..self.i].iter().collect();
                self.push(Tok::Ident(raw), line);
                return;
            }
        }
        self.push(Tok::Ident(name), line);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter_map(|t| match t.kind {
                Tok::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn code_inside_strings_and_comments_is_not_tokenized() {
        let src = r###"
            let a = "x.lock().unwrap()"; // y.lock().unwrap()
            /* z.lock().unwrap() /* nested */ still comment */
            let b = r#"raw "quoted" .unwrap()"#;
        "###;
        let ids = idents(src);
        assert!(!ids.contains(&"unwrap".to_string()), "{ids:?}");
        assert_eq!(lex(src).comments.len(), 2);
    }

    #[test]
    fn comment_lines_are_recorded() {
        let src = "let x = 1;\n// first\nlet y = 2; // second\n";
        let lexed = lex(src);
        let lines: Vec<(usize, &str)> = lexed
            .comments
            .iter()
            .map(|c| (c.line, c.text.as_str()))
            .collect();
        assert_eq!(lines, vec![(2, "// first"), (3, "// second")]);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> char { 'x' }";
        let lexed = lex(src);
        let lifetimes = lexed.tokens.iter().filter(|t| t.kind == Tok::Lifetime).count();
        assert_eq!(lifetimes, 2);
        // The 'x' char literal must not swallow the closing brace.
        assert_eq!(lexed.tokens.last().map(|t| t.kind.clone()), Some(Tok::Punct('}')));
    }

    #[test]
    fn numbers_leave_method_dots_alone() {
        // Tuple access, ranges, float exponents: the dots that matter for
        // rules (method call syntax) must survive as Punct('.').
        let src = "let a = x.0; for i in 0..n {} let b = 1e-5; y.1.lock()";
        let lexed = lex(src);
        let has = |name: &str| lexed.tokens.iter().any(|t| t.kind == Tok::Ident(name.to_string()));
        assert!(has("lock"));
        // `1e-5` is one literal: no stray identifier `e` appears.
        assert!(!has("e"));
        // The range's two dots are two puncts between two literals.
        let dots = lexed.tokens.iter().filter(|t| t.kind == Tok::Punct('.')).count();
        assert!(dots >= 4, "tuple + range + chained access dots: {dots}");
    }

    #[test]
    fn token_lines_are_one_based_and_accurate() {
        let src = "a\nb\n\nc";
        let lexed = lex(src);
        let got: Vec<usize> = lexed.tokens.iter().map(|t| t.line).collect();
        assert_eq!(got, vec![1, 2, 4]);
    }

    #[test]
    fn raw_identifiers_yield_bare_names() {
        assert_eq!(idents("let r#type = 1;"), vec!["let", "type"]);
    }
}

//! Rendering and baseline handling for lint findings.
//!
//! The JSON output is CI's interface: `{"findings":[..],"total":N}` with
//! sorted keys (the in-tree [`Json`] writer is BTreeMap-backed), so a
//! saved report is byte-stable and can be fed straight back in as a
//! `--baseline` to suppress known findings — the round-trip the
//! integration test pins.

use std::collections::BTreeSet;

use anyhow::{Context, Result};

use crate::util::json::{arr, num, obj, s, Json};

use super::rules::{rule_meta, Finding};

/// Outcome of linting a file set.
#[derive(Debug, Default)]
pub struct Report {
    /// All findings after suppression filtering, file order then line.
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Drop findings present in `baseline` (matched on rule + path +
    /// line). Returns how many were baselined out.
    pub fn apply_baseline(&mut self, baseline: &Baseline) -> usize {
        let before = self.findings.len();
        self.findings.retain(|f| !baseline.contains(f));
        before - self.findings.len()
    }

    /// Human-readable listing, one finding per line, optionally followed
    /// by per-rule fix hints.
    pub fn render_text(&self, fix_hints: bool) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}:{}: {} [{}]\n", f.path, f.line, f.message, f.rule));
        }
        if fix_hints {
            let rules: BTreeSet<&str> = self.findings.iter().map(|f| f.rule.as_str()).collect();
            for id in rules {
                if let Some(meta) = rule_meta(id) {
                    out.push_str(&format!("hint[{id}]: {}\n", meta.hint));
                }
            }
        }
        out.push_str(&format!(
            "{} finding(s) across {} file(s)\n",
            self.findings.len(),
            self.files_scanned
        ));
        out
    }

    /// Machine-readable report; parseable back into a [`Baseline`].
    pub fn render_json(&self) -> String {
        let findings = self
            .findings
            .iter()
            .map(|f| {
                obj(vec![
                    ("line", num(f.line as f64)),
                    ("message", s(&f.message)),
                    ("path", s(&f.path)),
                    ("rule", s(&f.rule)),
                ])
            })
            .collect();
        obj(vec![
            ("files", num(self.files_scanned as f64)),
            ("findings", arr(findings)),
            ("total", num(self.findings.len() as f64)),
        ])
        .to_string_pretty()
    }
}

/// A set of known findings to ignore, keyed `(rule, path, line)`.
#[derive(Debug, Default)]
pub struct Baseline {
    entries: BTreeSet<(String, String, usize)>,
}

impl Baseline {
    /// Parse a baseline from JSON — either the exact shape
    /// [`Report::render_json`] emits or a bare array of finding objects.
    pub fn parse(text: &str) -> Result<Baseline> {
        let j = Json::parse(text).context("parsing baseline json")?;
        let list = match &j {
            Json::Arr(_) => &j,
            _ => j.get("findings").context("baseline: no findings array")?,
        };
        let mut entries = BTreeSet::new();
        for item in list.as_arr().context("baseline findings")? {
            entries.insert((
                item.get("rule")?.as_str()?.to_string(),
                item.get("path")?.as_str()?.to_string(),
                item.get("line")?.as_usize()?,
            ));
        }
        Ok(Baseline { entries })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn contains(&self, f: &Finding) -> bool {
        self.entries.contains(&(f.rule.clone(), f.path.clone(), f.line))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        Report {
            findings: vec![
                Finding {
                    rule: "D001".to_string(),
                    path: "serve/x.rs".to_string(),
                    line: 3,
                    message: ".unwrap() in hot-path module".to_string(),
                },
                Finding {
                    rule: "D006".to_string(),
                    path: "zoo/y.rs".to_string(),
                    line: 9,
                    message: "lock(..) unwrapped".to_string(),
                },
            ],
            files_scanned: 2,
        }
    }

    #[test]
    fn json_round_trips_as_a_baseline() {
        let mut report = sample();
        let rendered = report.render_json();
        let baseline = Baseline::parse(&rendered).expect("parse own output");
        assert_eq!(baseline.len(), 2);
        assert_eq!(report.apply_baseline(&baseline), 2);
        assert!(report.clean());
    }

    #[test]
    fn baseline_matches_exactly() {
        let mut report = sample();
        // Same rule+path, different line: not baselined.
        let baseline = Baseline::parse(r#"[{"rule":"D001","path":"serve/x.rs","line":4}]"#)
            .expect("parse");
        assert_eq!(report.apply_baseline(&baseline), 0);
        assert_eq!(report.findings.len(), 2);
    }

    #[test]
    fn text_render_lists_findings_and_hints() {
        let text = sample().render_text(true);
        assert!(text.contains("serve/x.rs:3:"), "{text}");
        assert!(text.contains("[D001]"), "{text}");
        assert!(text.contains("hint[D006]:"), "{text}");
        assert!(text.contains("2 finding(s)"), "{text}");
        // Without hints the hint lines disappear.
        assert!(!sample().render_text(false).contains("hint["));
    }

    #[test]
    fn malformed_baseline_errors() {
        assert!(Baseline::parse("not json").is_err());
        assert!(Baseline::parse(r#"{"nope":1}"#).is_err());
        assert!(Baseline::parse(r#"[{"rule":"D001"}]"#).is_err());
    }
}

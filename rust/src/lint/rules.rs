//! The determinism & safety rules (D001–D006) and the per-file analysis
//! they share: `#[cfg(test)]` region exclusion and `// ecco-lint:
//! allow(..)` suppressions.
//!
//! Every rule is a token-pattern matcher over [`lexer::Lexed`] output —
//! deliberately syntactic. The rules encode *project* invariants (which
//! modules are hot paths, which containers may appear on the wire), so a
//! few false-negative shapes a type checker would catch (a re-exported
//! `HashMap` alias, a bare float `<` on scores) are out of scope; the
//! fixture tests pin exactly what each rule does and does not catch.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{self, Tok, Token};

/// Static metadata for one rule, used by `--fix-hints` and the docs.
#[derive(Debug, Clone, Copy)]
pub struct RuleMeta {
    pub id: &'static str,
    pub title: &'static str,
    /// What the rule protects, shown with `--fix-hints`.
    pub hint: &'static str,
}

pub const RULES: &[RuleMeta] = &[
    RuleMeta {
        id: "D001",
        title: "unwrap/expect/panic in hot-path modules",
        hint: "return a typed error (Result + bail!/context) instead; if the \
               invariant is real, document it with an ecco-lint suppression",
    },
    RuleMeta {
        id: "D002",
        title: "hash-ordered container in event/wire code",
        hint: "use BTreeMap/BTreeSet so iteration order (and thus event and \
               wire bytes) is deterministic",
    },
    RuleMeta {
        id: "D003",
        title: "wall-clock or randomness outside perf-counter sites",
        hint: "route timing through perf counters only (never events or \
               accuracies) and randomness through util::rng seeds; suppress \
               with a reason at genuine perf/IO-pacing sites",
    },
    RuleMeta {
        id: "D004",
        title: "undocumented or stray unsafe",
        hint: "add an adjacent // SAFETY: comment (# Safety doc section for \
               unsafe fn), or move the code into an allowlisted module",
    },
    RuleMeta {
        id: "D005",
        title: "NaN-unsafe float comparison",
        hint: "use f32::total_cmp/f64::total_cmp instead of partial_cmp",
    },
    RuleMeta {
        id: "D006",
        title: "lock()/wait() unwrapped without poison handling",
        hint: "use util::sync::{plock, pwait, pwait_timeout} (every lock in \
               this crate restores invariants before unlock, so recovering \
               the guard is sound)",
    },
];

pub fn rule_meta(id: &str) -> Option<&'static RuleMeta> {
    RULES.iter().find(|r| r.id == id)
}

/// Modules D001 treats as hot paths (panics there kill runners, servers,
/// or whole processes instead of failing one request).
const HOT_DIRS: &[&str] = &[
    "server/", "runtime/", "serve/", "net/", "transmission/", "alloc/",
];

/// Modules whose containers can reach the determinism surface (events,
/// wire frames, reports): hash iteration order is forbidden here (D002).
const WIRE_DIRS: &[&str] = &[
    "api/", "serve/", "server/", "net/", "transmission/", "alloc/",
    "faults/", "grouping/", "metrics/", "exp/",
];

/// Files allowed to read wall clocks freely (D003): the bench harness and
/// the logger's timestamp, which are perf/diagnostic surfaces by
/// definition and never feed results.
const CLOCK_ALLOWED_FILES: &[&str] = &["util/bench.rs", "util/logger.rs"];

/// Modules allowed to contain `unsafe` at all (D004); everywhere else any
/// `unsafe` is a violation regardless of comments.
const UNSAFE_ALLOWED_FILES: &[&str] = &["util/pool.rs", "runtime/microbatch.rs"];

/// One lint violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: String,
    /// Path relative to the scanned root, `/`-separated.
    pub path: String,
    /// 1-based line.
    pub line: usize,
    pub message: String,
}

/// Lint one file; `rel` is its root-relative path with `/` separators.
pub fn check_file(rel: &str, src: &str) -> Vec<Finding> {
    let lexed = lexer::lex(src);
    let test_lines = test_regions(&lexed.tokens);
    let comment_lines: BTreeMap<usize, String> = lexed
        .comments
        .iter()
        .map(|c| (c.line, c.text.clone()))
        .collect();
    let (suppressed, mut findings) = suppressions(rel, &lexed.comments, &lexed.tokens);

    let f = |out: &mut Vec<Finding>, rule: &str, line: usize, msg: String| {
        if test_lines.contains(&line) {
            return;
        }
        if suppressed
            .get(rule)
            .is_some_and(|lines| lines.contains(&line))
        {
            return;
        }
        out.push(Finding {
            rule: rule.to_string(),
            path: rel.to_string(),
            line,
            message: msg,
        });
    };

    let toks = &lexed.tokens;
    d001(rel, toks, &mut |r, l, m| f(&mut findings, r, l, m));
    d002(rel, toks, &mut |r, l, m| f(&mut findings, r, l, m));
    d003(rel, toks, &mut |r, l, m| f(&mut findings, r, l, m));
    d004(rel, toks, &comment_lines, &mut |r, l, m| f(&mut findings, r, l, m));
    d005(toks, &mut |r, l, m| f(&mut findings, r, l, m));
    d006(toks, &mut |r, l, m| f(&mut findings, r, l, m));

    findings.sort_by(|a, b| (a.line, &a.rule).cmp(&(b.line, &b.rule)));
    findings
}

// ---------------------------------------------------------------------------
// Shared analysis
// ---------------------------------------------------------------------------

fn ident(t: &Token) -> Option<&str> {
    match &t.kind {
        Tok::Ident(s) => Some(s),
        _ => None,
    }
}

fn is_punct(t: &Token, c: char) -> bool {
    t.kind == Tok::Punct(c)
}

/// Lines covered by `#[cfg(test)]`-guarded items (including
/// `cfg(all(test, ..))`, excluding `cfg(not(test))`): attribute line
/// through the matching close brace of the item that follows.
fn test_regions(toks: &[Token]) -> BTreeSet<usize> {
    let mut lines = BTreeSet::new();
    let mut i = 0;
    while i < toks.len() {
        if !(is_punct(&toks[i], '#') && i + 1 < toks.len() && is_punct(&toks[i + 1], '[')) {
            i += 1;
            continue;
        }
        // Find the attribute's closing bracket.
        let mut depth = 0usize;
        let mut end = i + 1;
        while end < toks.len() {
            if is_punct(&toks[end], '[') {
                depth += 1;
            } else if is_punct(&toks[end], ']') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            end += 1;
        }
        let attr = &toks[i..=end.min(toks.len() - 1)];
        if !attr_gates_on_test(attr) {
            i = end + 1;
            continue;
        }
        // Skip any further attributes between this one and the item.
        let mut j = end + 1;
        while j + 1 < toks.len() && is_punct(&toks[j], '#') && is_punct(&toks[j + 1], '[') {
            let mut d = 0usize;
            while j < toks.len() {
                if is_punct(&toks[j], '[') {
                    d += 1;
                } else if is_punct(&toks[j], ']') {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
                j += 1;
            }
            j += 1;
        }
        // The guarded item: everything to the matching close of its first
        // brace (covers `mod tests { .. }`, `fn`, `impl`, `struct { .. }`).
        let mut brace = 0usize;
        let mut k = j;
        let mut entered = false;
        while k < toks.len() {
            if is_punct(&toks[k], '{') {
                brace += 1;
                entered = true;
            } else if is_punct(&toks[k], '}') {
                brace -= 1;
                if entered && brace == 0 {
                    break;
                }
            } else if !entered && is_punct(&toks[k], ';') {
                break; // braceless item, e.g. `mod tests;`
            }
            k += 1;
        }
        let start_line = toks[i].line;
        let end_line = toks[k.min(toks.len() - 1)].line;
        lines.extend(start_line..=end_line);
        i = k + 1;
    }
    lines
}

/// Does this attribute token slice gate on `test` (outside `not(..)`)?
/// Matches `cfg(test)`, `cfg(all(test, ..))`, and `cfg_attr(test, ..)`;
/// rejects `cfg(not(test))` and unrelated attributes.
fn attr_gates_on_test(attr: &[Token]) -> bool {
    let head = attr.iter().skip(2).find_map(ident);
    if head != Some("cfg") && head != Some("cfg_attr") {
        return false;
    }
    let mut stack: Vec<String> = Vec::new();
    let mut last_ident: Option<&str> = None;
    for t in attr {
        match &t.kind {
            Tok::Ident(s) => {
                if s == "test" && !stack.iter().any(|f| f == "not") {
                    return true;
                }
                last_ident = Some(s);
            }
            Tok::Punct('(') => {
                stack.push(last_ident.unwrap_or_default().to_string());
                last_ident = None;
            }
            Tok::Punct(')') => {
                stack.pop();
            }
            _ => last_ident = None,
        }
    }
    false
}

/// Parse `// ecco-lint: allow(D00x) reason` comments. Returns, per rule,
/// the set of source lines the suppressions cover (the comment's own line
/// plus the first code line at or below it, so a comment block directly
/// above the offending line works), plus findings for malformed
/// suppressions — an allow without a reason, or for an unknown rule.
///
/// A comment is a suppression only if it *starts* with `ecco-lint` once
/// comment markers are stripped — prose that mentions the syntax
/// mid-sentence (like this doc comment, or the crate docs) is not one.
fn suppressions(
    rel: &str,
    comments: &[lexer::Comment],
    toks: &[Token],
) -> (BTreeMap<String, BTreeSet<usize>>, Vec<Finding>) {
    let code_lines: BTreeSet<usize> = toks.iter().map(|t| t.line).collect();
    let mut map: BTreeMap<String, BTreeSet<usize>> = BTreeMap::new();
    let mut findings = Vec::new();
    for c in comments {
        let stripped = c.text.trim_start_matches(|ch: char| {
            ch == '/' || ch == '*' || ch == '!' || ch.is_whitespace()
        });
        let Some(after) = stripped.strip_prefix("ecco-lint") else {
            continue;
        };
        let Some(rest) = after.strip_prefix(':').map(str::trim_start) else {
            findings.push(Finding {
                rule: "LINT".to_string(),
                path: rel.to_string(),
                line: c.line,
                message: format!(
                    "malformed suppression (expected `ecco-lint: allow(D00x) reason`): {}",
                    c.text.trim()
                ),
            });
            continue;
        };
        let Some(body) = rest.strip_prefix("allow(") else {
            findings.push(Finding {
                rule: "LINT".to_string(),
                path: rel.to_string(),
                line: c.line,
                message: format!(
                    "malformed suppression (expected `ecco-lint: allow(D00x) reason`): {}",
                    c.text.trim()
                ),
            });
            continue;
        };
        let Some(close) = body.find(')') else {
            findings.push(Finding {
                rule: "LINT".to_string(),
                path: rel.to_string(),
                line: c.line,
                message: "unclosed ecco-lint allow(..)".to_string(),
            });
            continue;
        };
        let rule = body[..close].trim().to_string();
        let reason = body[close + 1..].trim();
        if rule_meta(&rule).is_none() {
            findings.push(Finding {
                rule: "LINT".to_string(),
                path: rel.to_string(),
                line: c.line,
                message: format!("suppression names unknown rule {rule:?}"),
            });
            continue;
        }
        if reason.is_empty() {
            findings.push(Finding {
                rule: "LINT".to_string(),
                path: rel.to_string(),
                line: c.line,
                message: format!(
                    "suppression of {rule} has no reason — every allow must say why"
                ),
            });
            continue;
        }
        let entry = map.entry(rule).or_default();
        entry.insert(c.line);
        if let Some(&target) = code_lines.range(c.line..).next() {
            entry.insert(target);
        }
    }
    (map, findings)
}

// ---------------------------------------------------------------------------
// The rules
// ---------------------------------------------------------------------------

fn in_dirs(rel: &str, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| rel.starts_with(d))
}

/// D001: `.unwrap()` / `.expect(` / panic-family macros in hot-path
/// modules. A panic in these modules takes down a runner thread, a serve
/// session, or the whole coordinator instead of failing one request.
fn d001(rel: &str, toks: &[Token], emit: &mut dyn FnMut(&str, usize, String)) {
    if !in_dirs(rel, HOT_DIRS) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        let Some(name) = ident(t) else { continue };
        match name {
            "unwrap" | "expect" => {
                let dotted = i > 0 && is_punct(&toks[i - 1], '.');
                let called = toks.get(i + 1).is_some_and(|n| is_punct(n, '('));
                if dotted && called {
                    emit("D001", t.line, format!(".{name}() in hot-path module"));
                }
            }
            "panic" | "unreachable" | "todo" | "unimplemented" => {
                if toks.get(i + 1).is_some_and(|n| is_punct(n, '!')) {
                    emit("D001", t.line, format!("{name}! in hot-path module"));
                }
            }
            _ => {}
        }
    }
}

/// D002: `HashMap`/`HashSet` in modules whose data reaches events or the
/// wire — hash iteration order would leak into the determinism surface.
fn d002(rel: &str, toks: &[Token], emit: &mut dyn FnMut(&str, usize, String)) {
    if !in_dirs(rel, WIRE_DIRS) {
        return;
    }
    for t in toks {
        if let Some(name @ ("HashMap" | "HashSet")) = ident(t) {
            emit("D002", t.line, format!("{name} in event/wire-serializing module"));
        }
    }
}

/// D003: wall-clock reads (`Instant::now`, `SystemTime::now`), sleeps,
/// and entropy-based RNG outside the allowlisted perf surfaces. Wall
/// time must only ever feed perf counters; events and accuracies must be
/// byte-stable across machines and thread counts.
fn d003(rel: &str, toks: &[Token], emit: &mut dyn FnMut(&str, usize, String)) {
    if CLOCK_ALLOWED_FILES.contains(&rel) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        let Some(name) = ident(t) else { continue };
        match name {
            "Instant" | "SystemTime" => {
                let qualified_now = is_punct_at(toks, i + 1, ':')
                    && is_punct_at(toks, i + 2, ':')
                    && toks.get(i + 3).and_then(ident) == Some("now");
                if qualified_now {
                    emit("D003", t.line, format!("{name}::now() wall-clock read"));
                }
            }
            "sleep" => {
                if toks.get(i + 1).is_some_and(|n| is_punct(n, '(')) {
                    emit("D003", t.line, "sleep() call".to_string());
                }
            }
            "thread_rng" | "from_entropy" | "OsRng" | "getrandom" => {
                emit("D003", t.line, format!("{name}: entropy-seeded randomness"));
            }
            _ => {}
        }
    }
}

fn is_punct_at(toks: &[Token], i: usize, c: char) -> bool {
    toks.get(i).is_some_and(|t| is_punct(t, c))
}

/// D004: `unsafe` discipline. Outside the allowlisted modules any
/// `unsafe` is a violation; inside them every `unsafe` block or impl
/// needs an adjacent `// SAFETY:` comment and every named `unsafe fn` a
/// `# Safety` doc section. `unsafe fn(..)` in *type* position (a fn
/// pointer) carries no body to justify and is exempt.
fn d004(
    rel: &str,
    toks: &[Token],
    comment_lines: &BTreeMap<usize, String>,
    emit: &mut dyn FnMut(&str, usize, String),
) {
    let allowed = UNSAFE_ALLOWED_FILES.contains(&rel);
    for (i, t) in toks.iter().enumerate() {
        if ident(t) != Some("unsafe") {
            continue;
        }
        let next = toks.get(i + 1).and_then(ident);
        if next == Some("fn") && is_punct_at(toks, i + 2, '(') {
            continue; // fn-pointer type, nothing to document
        }
        if !allowed {
            emit("D004", t.line, "unsafe outside allowlisted modules".to_string());
            continue;
        }
        if next == Some("fn") {
            if !adjacent_comment_contains(comment_lines, t.line, "# Safety") {
                emit("D004", t.line, "unsafe fn without a `# Safety` doc section".to_string());
            }
        } else if !adjacent_comment_contains(comment_lines, t.line, "SAFETY:") {
            let what = if next == Some("impl") { "impl" } else { "block" };
            emit(
                "D004",
                t.line,
                format!("unsafe {what} without an adjacent // SAFETY: comment"),
            );
        }
    }
}

/// Is there a comment containing `marker` on `line` itself or in the
/// contiguous run of comment lines directly above it?
fn adjacent_comment_contains(
    comment_lines: &BTreeMap<usize, String>,
    line: usize,
    marker: &str,
) -> bool {
    if comment_lines.get(&line).is_some_and(|t| t.contains(marker)) {
        return true;
    }
    let mut l = line;
    while l > 1 {
        l -= 1;
        match comment_lines.get(&l) {
            Some(text) if text.contains(marker) => return true,
            Some(_) => continue,
            None => return false,
        }
    }
    false
}

/// D005: `partial_cmp` — the repo's most recurrent bug class. A NaN
/// anywhere in a score column turns `partial_cmp(..).unwrap()` into a
/// panic and a NaN-tolerant fallback into an unstable order; `total_cmp`
/// is well-defined for every bit pattern.
fn d005(toks: &[Token], emit: &mut dyn FnMut(&str, usize, String)) {
    for (i, t) in toks.iter().enumerate() {
        if ident(t) == Some("partial_cmp") && is_punct_at(toks, i + 1, '(') {
            emit("D005", t.line, "partial_cmp on floats (NaN-unsafe ordering)".to_string());
        }
    }
}

/// D006: `.lock(..).unwrap()` / `.wait(..).expect(..)` — poison from one
/// panicked thread cascades into every later locker. The blessed helpers
/// in `util::sync` recover the guard instead.
fn d006(toks: &[Token], emit: &mut dyn FnMut(&str, usize, String)) {
    for (i, t) in toks.iter().enumerate() {
        let Some(name @ ("lock" | "wait" | "wait_timeout")) = ident(t) else {
            continue;
        };
        if !is_punct_at(toks, i + 1, '(') {
            continue;
        }
        // Skip to the call's matching close paren.
        let mut depth = 0usize;
        let mut j = i + 1;
        while j < toks.len() {
            if is_punct(&toks[j], '(') {
                depth += 1;
            } else if is_punct(&toks[j], ')') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            j += 1;
        }
        let chained = is_punct_at(toks, j + 1, '.')
            && matches!(toks.get(j + 2).and_then(ident), Some("unwrap" | "expect"));
        if chained {
            emit(
                "D006",
                t.line,
                format!("{name}(..) unwrapped without poison handling"),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_fired(rel: &str, src: &str) -> Vec<String> {
        check_file(rel, src).into_iter().map(|f| f.rule).collect()
    }

    #[test]
    fn d001_fires_in_hot_paths_only() {
        let bad = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        assert_eq!(rules_fired("serve/x.rs", bad), vec!["D001"]);
        assert_eq!(rules_fired("runtime/x.rs", "fn f() { panic!(\"no\") }"), vec!["D001"]);
        // Same code outside a hot dir is fine.
        assert!(rules_fired("scene/x.rs", bad).is_empty());
        // unwrap_or_else is not unwrap.
        let or_else = "fn f(x: Option<u32>) { x.unwrap_or_else(|| 0); }";
        assert!(rules_fired("serve/x.rs", or_else).is_empty());
    }

    #[test]
    fn d001_skips_cfg_test_regions() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { Some(1).unwrap(); }\n}\n";
        assert!(rules_fired("serve/x.rs", src).is_empty());
        // ...but cfg(not(test)) regions still count.
        let gated = "#[cfg(not(test))]\nfn f(x: Option<u32>) -> u32 { x.unwrap() }";
        assert_eq!(rules_fired("serve/x.rs", gated), vec!["D001"]);
    }

    #[test]
    fn d002_fires_on_hash_containers_in_wire_dirs() {
        let bad = "use std::collections::HashMap;\nfn f() -> HashMap<u8, u8> { HashMap::new() }";
        let fired = rules_fired("api/x.rs", bad);
        assert!(fired.iter().all(|r| r == "D002"));
        assert_eq!(fired.len(), 3);
        assert!(rules_fired("runtime/x.rs", bad).is_empty(), "runtime is lookup-only");
        assert!(rules_fired("api/x.rs", "use std::collections::BTreeMap;").is_empty());
    }

    #[test]
    fn d003_fires_on_clocks_sleeps_and_entropy() {
        let clock = "fn f() { let t = Instant::now(); }";
        assert_eq!(rules_fired("grouping/x.rs", clock), vec!["D003"]);
        assert_eq!(rules_fired("scene/x.rs", "fn f() { thread::sleep(d); }"), vec!["D003"]);
        let entropy = "fn f() { let r = rand::thread_rng(); }";
        assert_eq!(rules_fired("zoo/x.rs", entropy), vec!["D003"]);
        // The import alone (no ::now) is fine, as are the allowlisted files.
        assert!(rules_fired("scene/x.rs", "use std::time::Instant;").is_empty());
        assert!(rules_fired("util/bench.rs", "fn f() { Instant::now(); }").is_empty());
    }

    #[test]
    fn d004_requires_safety_comments_and_allowlisted_modules() {
        let undocumented = "fn f(p: *const u32) -> u32 { unsafe { *p } }";
        // Outside the allowlist: stray unsafe.
        let fired = check_file("scene/x.rs", undocumented);
        assert_eq!(fired[0].rule, "D004");
        assert!(fired[0].message.contains("outside"), "{}", fired[0].message);
        // Inside the allowlist but uncommented: missing SAFETY.
        let fired = check_file("util/pool.rs", undocumented);
        assert_eq!(fired[0].rule, "D004");
        assert!(fired[0].message.contains("SAFETY"), "{}", fired[0].message);
        // A SAFETY comment directly above satisfies it.
        let ok = "fn f(p: *const u8) -> u8 {\n    // SAFETY: ok.\n    unsafe { *p }\n}";
        assert!(rules_fired("util/pool.rs", ok).is_empty());
        // unsafe fn needs a # Safety doc section...
        let f_bad = "unsafe fn g(p: *const u32) -> u32 { *p }";
        assert_eq!(rules_fired("util/pool.rs", f_bad), vec!["D004"]);
        let f_ok = "/// x.\n/// # Safety\n/// ok.\nunsafe fn g(p: *const u8) -> u8 { *p }";
        assert!(rules_fired("util/pool.rs", f_ok).is_empty());
        // ...but an fn-pointer type position is exempt.
        assert!(rules_fired("util/pool.rs", "struct J { call: unsafe fn(*const ()) }").is_empty());
    }

    #[test]
    fn d005_fires_on_partial_cmp() {
        let bad = "fn f(v: &mut [f64]) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }";
        assert_eq!(rules_fired("metrics/x.rs", bad), vec!["D005"]);
        let ok = "fn f(v: &mut [f64]) { v.sort_by(|a, b| a.total_cmp(b)); }";
        assert!(rules_fired("metrics/x.rs", ok).is_empty());
    }

    #[test]
    fn d006_fires_on_unwrapped_locks_anywhere() {
        assert_eq!(
            rules_fired("zoo/x.rs", "fn f(m: &Mutex<u32>) { let g = m.lock().unwrap(); }"),
            vec!["D006"]
        );
        assert_eq!(
            rules_fired("zoo/x.rs", "fn f() { g = cv.wait(g).expect(\"poisoned\"); }"),
            vec!["D006"]
        );
        let fine = "fn f(m: &Mutex<u8>) { m.lock().unwrap_or_else(PoisonError::into_inner); }";
        assert!(rules_fired("zoo/x.rs", fine).is_empty());
    }

    #[test]
    fn suppressions_cover_the_next_code_line_and_require_reasons() {
        let ok = [
            "fn f(x: Option<u32>) -> u32 {",
            "    // ecco-lint: allow(D001) invariant: x is Some by construction",
            "    // (second comment line still counts as the same block)",
            "    x.unwrap()",
            "}",
        ]
        .join("\n");
        assert!(rules_fired("serve/x.rs", &ok).is_empty());
        // Same-line suppression works too.
        let inline = "fn f(x: Option<u32>) -> u32 { x.unwrap() } // ecco-lint: allow(D001) fixture";
        assert!(rules_fired("serve/x.rs", inline).is_empty());
        // No reason: the original finding stays and a LINT finding appears.
        let bare = "fn f(x: Option<u8>) -> u8 {\n    // ecco-lint: allow(D001)\n    x.unwrap()\n}";
        let fired = rules_fired("serve/x.rs", bare);
        assert!(fired.contains(&"LINT".to_string()), "{fired:?}");
        assert!(fired.contains(&"D001".to_string()), "{fired:?}");
        // Unknown rule id is called out.
        let unknown = "// ecco-lint: allow(D099) whatever\nfn f() {}";
        assert_eq!(rules_fired("scene/x.rs", unknown), vec!["LINT"]);
        // A suppression for rule A does not silence rule B.
        let wrong = [
            "fn f(x: Option<u32>) -> u32 {",
            "    // ecco-lint: allow(D005) mismatched rule",
            "    x.unwrap()",
            "}",
        ]
        .join("\n");
        assert!(rules_fired("serve/x.rs", &wrong).contains(&"D001".to_string()));
    }

    #[test]
    fn findings_carry_paths_lines_and_messages() {
        let src = "fn a() {}\nfn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let fs = check_file("net/x.rs", src);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].path, "net/x.rs");
        assert_eq!(fs[0].line, 2);
        assert!(fs[0].message.contains("unwrap"), "{}", fs[0].message);
    }
}

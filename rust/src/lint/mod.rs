//! `ecco lint` — the determinism & safety static-analysis pass.
//!
//! The repo's core invariant is the **determinism contract**: event logs
//! and accuracies are byte-identical at any thread count, on any machine.
//! PRs 4–9 each re-discovered a violation of it by hand (NaN-unsafe
//! sorts, hot-loop `unwrap`s, hash-ordered folds); this subsystem
//! enforces the contract mechanically, as named rules over the crate's
//! own sources:
//!
//! | rule | protects against |
//! |------|------------------|
//! | D001 | panics (`unwrap`/`expect`/`panic!`) in hot-path modules |
//! | D002 | hash iteration order reaching events or the wire |
//! | D003 | wall-clock/entropy reaching results |
//! | D004 | undocumented or stray `unsafe` |
//! | D005 | NaN-unsafe float ordering (`partial_cmp`) |
//! | D006 | poison cascades from unwrapped locks |
//!
//! Everything is std-only, consistent with the offline build: a
//! [lightweight lexer](lexer) feeds [token-pattern rules](rules), and
//! [report] renders text or CI-consumable JSON (which doubles as the
//! `--baseline` format). Findings inside `#[cfg(test)]` regions are
//! ignored; intentional exceptions carry an inline
//! `// ecco-lint: allow(D00x) reason` with a mandatory written reason.
//!
//! The CLI surface is `ecco lint [DIR] [--fix-hints] [--baseline FILE]
//! [--format text|json]`; exit status 0 means clean.

pub mod lexer;
pub mod report;
pub mod rules;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub use report::{Baseline, Report};
pub use rules::{Finding, RuleMeta, RULES};

/// Lint every `.rs` file under `root` (recursively, deterministic
/// name-sorted order, `target/` skipped). Paths in findings are
/// root-relative with `/` separators.
pub fn lint_root(root: &Path) -> Result<Report> {
    let mut files = Vec::new();
    collect_rs_files(root, root, &mut files)?;
    files.sort();
    let mut report = Report::default();
    for rel in files {
        let abs = root.join(&rel);
        let src = std::fs::read_to_string(&abs)
            .with_context(|| format!("reading {}", abs.display()))?;
        let rel_slash = rel.to_string_lossy().replace(std::path::MAIN_SEPARATOR, "/");
        report.findings.extend(rules::check_file(&rel_slash, &src));
        report.files_scanned += 1;
    }
    Ok(report)
}

fn collect_rs_files(root: &Path, dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries = std::fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))?;
    for entry in entries {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if name == "target" || name.starts_with('.') {
                continue;
            }
            collect_rs_files(root, &path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path.strip_prefix(root).unwrap_or(&path).to_path_buf());
        }
    }
    Ok(())
}

/// CLI entry point for `ecco lint`. Returns `Ok(clean)`; the caller maps
/// `false` to a non-zero exit status.
pub fn run_cli(
    root: &Path,
    baseline_path: Option<&str>,
    format: &str,
    fix_hints: bool,
) -> Result<bool> {
    let mut report = lint_root(root)?;
    if let Some(bp) = baseline_path {
        let text =
            std::fs::read_to_string(bp).with_context(|| format!("reading baseline {bp}"))?;
        let baseline = Baseline::parse(&text)?;
        report.apply_baseline(&baseline);
    }
    match format {
        "json" => println!("{}", report.render_json()),
        "text" => print!("{}", report.render_text(fix_hints)),
        other => bail!("--format must be text or json, got {other:?}"),
    }
    Ok(report.clean())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The shipped tree must be clean: this is the same assertion CI's
    /// `rust-lint` job makes via the binary, kept here as a unit test so
    /// a violation fails `cargo test` even without the CLI.
    #[test]
    fn shipped_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let report = lint_root(&root).expect("lint src tree");
        assert!(
            report.clean(),
            "lint findings in shipped tree:\n{}",
            report.render_text(true)
        );
        assert!(report.files_scanned > 30, "scanned {}", report.files_scanned);
    }

    #[test]
    fn every_rule_fires_on_its_fixture() {
        // (rule, path the rule scopes to, known-bad snippet)
        let fixtures: &[(&str, &str, &str)] = &[
            ("D001", "serve/f.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }"),
            ("D002", "api/f.rs", "use std::collections::HashMap;"),
            ("D003", "scene/f.rs", "fn f() { let t = Instant::now(); }"),
            ("D004", "scene/f.rs", "fn f(p: *const u32) -> u32 { unsafe { *p } }"),
            ("D005", "metrics/f.rs", "fn f(a: f64, b: f64) { a.partial_cmp(&b); }"),
            ("D006", "zoo/f.rs", "fn f(m: &Mutex<u32>) { m.lock().unwrap(); }"),
        ];
        for (rule, path, src) in fixtures {
            let findings = rules::check_file(path, src);
            assert!(
                findings.iter().any(|f| f.rule == *rule),
                "{rule} did not fire on its fixture: {findings:?}"
            );
        }
    }
}

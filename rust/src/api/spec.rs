//! [`RunSpec`]: the validated description of one system run.
//!
//! A spec is (task, policy) plus the resource envelope (GPUs, shared
//! bottleneck, per-camera uplinks), the horizon in retraining windows, the
//! seed, and the scenario world. [`super::Session::new`] consumes a spec;
//! validation happens before any engine work, so malformed sweeps fail
//! fast with a typed [`SpecError`].

use std::fmt;

use crate::faults::FaultPlan;
use crate::runtime::Task;
use crate::scene::scenario::{self, Scenario};
use crate::server::{Policy, SystemConfig};

/// A validation failure in a [`RunSpec`].
#[derive(Debug, Clone, PartialEq)]
pub enum SpecError {
    /// The run must cover at least one retraining window.
    NoWindows,
    /// GPU count must be positive and finite.
    NonPositiveGpus(f64),
    /// The shared bottleneck bandwidth must be positive and finite.
    NonPositiveBandwidth(f64),
    /// A per-camera uplink must be positive and finite.
    NonPositiveUplink { cam: usize, mbps: f64 },
    /// Explicit per-camera uplinks must match the camera count.
    UplinkCountMismatch { cams: usize, uplinks: usize },
    /// The scenario (or default-world camera count) has no cameras.
    NoCameras,
    /// The fault plan targets a camera index the scenario doesn't have.
    FaultCamOutOfRange { cam: usize, cams: usize },
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::NoWindows => write!(f, "run spec: windows must be >= 1"),
            SpecError::NonPositiveGpus(g) => {
                write!(f, "run spec: gpus must be positive, got {g}")
            }
            SpecError::NonPositiveBandwidth(b) => {
                write!(f, "run spec: shared bandwidth must be positive, got {b} Mbps")
            }
            SpecError::NonPositiveUplink { cam, mbps } => {
                write!(f, "run spec: camera {cam} uplink must be positive, got {mbps} Mbps")
            }
            SpecError::UplinkCountMismatch { cams, uplinks } => write!(
                f,
                "run spec: {uplinks} uplinks for {cams} cameras (counts must match)"
            ),
            SpecError::NoCameras => write!(f, "run spec: scenario has no cameras"),
            SpecError::FaultCamOutOfRange { cam, cams } => write!(
                f,
                "run spec: fault plan targets camera {cam} but the scenario has {cams} cameras"
            ),
        }
    }
}

impl std::error::Error for SpecError {}

/// Per-camera uplink capacities.
enum Uplinks {
    /// Every camera gets the same uplink (Mbit/s).
    Uniform(f64),
    /// Explicit per-camera uplinks; length must match the camera count.
    PerCamera(Vec<f64>),
}

/// Builder for one system run. Defaults mirror the quick-driver CLI:
/// 6 cameras in two correlated triples, 1 GPU, 6 Mbps shared / 20 Mbps
/// uplinks, 8 windows, seed 7.
pub struct RunSpec {
    pub(crate) task: Task,
    pub(crate) policy: Policy,
    pub(crate) cams: usize,
    pub(crate) gpus: f64,
    pub(crate) shared_mbps: f64,
    uplinks: Uplinks,
    pub(crate) windows: usize,
    pub(crate) seed: u64,
    pub(crate) scenario: Option<Scenario>,
    /// Deterministic fault-injection schedule ([`FaultPlan::none`] by
    /// default — guaranteed zero-cost, see [`crate::faults`]).
    faults: FaultPlan,
    /// Zoo-prefill fine-tune steps when the policy warm-starts from a zoo.
    pub(crate) zoo_init_steps: usize,
    /// Config hooks, applied in order after the built-in knobs. `Send +
    /// Sync` so whole specs can be shipped to fleet-driver workers.
    #[allow(clippy::type_complexity)]
    pub(crate) hooks: Vec<Box<dyn Fn(&mut SystemConfig) + Send + Sync>>,
}

impl RunSpec {
    pub fn new(task: Task, policy: Policy) -> RunSpec {
        RunSpec {
            task,
            policy,
            cams: 6,
            gpus: 1.0,
            shared_mbps: 6.0,
            uplinks: Uplinks::Uniform(20.0),
            windows: 8,
            seed: 7,
            scenario: None,
            faults: FaultPlan::none(),
            zoo_init_steps: 40,
            hooks: Vec::new(),
        }
    }

    /// Camera count for the default scenario (ignored with an explicit
    /// [`RunSpec::scenario`]).
    pub fn cams(mut self, n: usize) -> Self {
        self.cams = n;
        self
    }

    /// Simulated edge GPUs.
    pub fn gpus(mut self, gpus: f64) -> Self {
        self.gpus = gpus;
        self
    }

    /// Shared bottleneck bandwidth (Mbit/s).
    pub fn shared_mbps(mut self, mbps: f64) -> Self {
        self.shared_mbps = mbps;
        self
    }

    /// One uplink capacity (Mbit/s) for every camera.
    pub fn uplink_mbps(mut self, mbps: f64) -> Self {
        self.uplinks = Uplinks::Uniform(mbps);
        self
    }

    /// Explicit per-camera uplinks (Mbit/s); length must match the camera
    /// count or validation fails.
    pub fn uplinks(mut self, mbps: Vec<f64>) -> Self {
        self.uplinks = Uplinks::PerCamera(mbps);
        self
    }

    /// Horizon in retraining windows.
    pub fn windows(mut self, n: usize) -> Self {
        self.windows = n;
        self
    }

    /// Seed for the scenario, system, and all simulators.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run on an explicit scenario world instead of the default
    /// two-triple static world.
    pub fn scenario(mut self, sc: Scenario) -> Self {
        self.scenario = Some(sc);
        self
    }

    /// Attach a deterministic fault-injection schedule (see
    /// [`crate::faults`]). [`FaultPlan::none`] — the default — is
    /// guaranteed zero-cost: event logs stay byte-identical to a run
    /// without a plan.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = plan;
        self
    }

    /// Override the zoo-prefill fine-tune steps (0 disables the prefill;
    /// only relevant when the policy has `zoo_warm_start`).
    pub fn zoo_init_steps(mut self, steps: usize) -> Self {
        self.zoo_init_steps = steps;
        self
    }

    /// Arbitrary [`SystemConfig`] tweak, applied after the built-in knobs
    /// (gpus/seed); hooks run in registration order.
    pub fn configure<F: Fn(&mut SystemConfig) + Send + Sync + 'static>(mut self, hook: F) -> Self {
        self.hooks.push(Box::new(hook));
        self
    }

    /// Worker threads for the system's evaluation fan-outs (see
    /// `SystemConfig::eval_threads`). Runs are byte-identical at any value;
    /// defaults to the machine's parallelism (`ECCO_THREADS` overrides).
    pub fn eval_threads(self, n: usize) -> Self {
        self.configure(move |cfg| cfg.eval_threads = n.max(1))
    }

    /// Enable/disable the per-window eval-frame render cache (see
    /// `SystemConfig::frame_cache`; on by default). Runs are byte-identical
    /// either way — disabling only trades wall-clock to verify that claim.
    pub fn frame_cache(self, enabled: bool) -> Self {
        self.configure(move |cfg| cfg.frame_cache = enabled)
    }

    /// Like [`RunSpec::eval_threads`], but registered *before* every other
    /// hook so an explicit `eval_threads` (or any user hook) still wins.
    /// The fleet driver uses this to divide eval workers by the fleet
    /// concurrency instead of oversubscribing the CPU.
    pub(crate) fn eval_threads_floor(mut self, n: usize) -> Self {
        self.hooks
            .insert(0, Box::new(move |cfg| cfg.eval_threads = n.max(1)));
        self
    }

    /// Camera count this spec will run with.
    pub fn n_cams(&self) -> usize {
        match &self.scenario {
            Some(sc) => sc.world.cameras.len(),
            None => self.cams,
        }
    }

    /// Check the spec without building anything.
    pub fn validate(&self) -> Result<(), SpecError> {
        if self.windows == 0 {
            return Err(SpecError::NoWindows);
        }
        if !(self.gpus.is_finite() && self.gpus > 0.0) {
            return Err(SpecError::NonPositiveGpus(self.gpus));
        }
        if !(self.shared_mbps.is_finite() && self.shared_mbps > 0.0) {
            return Err(SpecError::NonPositiveBandwidth(self.shared_mbps));
        }
        let n = self.n_cams();
        if n == 0 {
            return Err(SpecError::NoCameras);
        }
        if let Uplinks::PerCamera(ups) = &self.uplinks {
            if ups.len() != n {
                return Err(SpecError::UplinkCountMismatch {
                    cams: n,
                    uplinks: ups.len(),
                });
            }
        }
        let check = |cam: usize, mbps: f64| -> Result<(), SpecError> {
            if !(mbps.is_finite() && mbps > 0.0) {
                return Err(SpecError::NonPositiveUplink { cam, mbps });
            }
            Ok(())
        };
        match &self.uplinks {
            Uplinks::Uniform(mbps) => check(0, *mbps)?,
            Uplinks::PerCamera(ups) => {
                for (cam, &mbps) in ups.iter().enumerate() {
                    check(cam, mbps)?;
                }
            }
        }
        if let Some(cam) = self.faults.max_cam() {
            if cam >= n {
                return Err(SpecError::FaultCamOutOfRange { cam, cams: n });
            }
        }
        Ok(())
    }

    /// Resolve the scenario (building the default world if none was set)
    /// and the per-camera uplink vector. Call after [`RunSpec::validate`].
    pub(crate) fn into_parts(self) -> (Scenario, Vec<f64>, RunSpecRest) {
        let sc = self.scenario.unwrap_or_else(|| {
            let split = if self.cams < 2 {
                vec![self.cams]
            } else {
                vec![self.cams / 2, self.cams - self.cams / 2]
            };
            scenario::grouped_static(&split, 0.06, 30.0, self.seed)
        });
        let n = sc.world.cameras.len();
        let uplinks = match self.uplinks {
            Uplinks::Uniform(mbps) => vec![mbps; n],
            Uplinks::PerCamera(ups) => ups,
        };
        (
            sc,
            uplinks,
            RunSpecRest {
                task: self.task,
                policy: self.policy,
                gpus: self.gpus,
                shared_mbps: self.shared_mbps,
                windows: self.windows,
                seed: self.seed,
                faults: self.faults,
                zoo_init_steps: self.zoo_init_steps,
                hooks: self.hooks,
            },
        )
    }
}

/// The non-world remainder of a consumed [`RunSpec`].
pub(crate) struct RunSpecRest {
    pub(crate) task: Task,
    pub(crate) policy: Policy,
    pub(crate) gpus: f64,
    pub(crate) shared_mbps: f64,
    pub(crate) windows: usize,
    pub(crate) seed: u64,
    pub(crate) faults: FaultPlan,
    pub(crate) zoo_init_steps: usize,
    #[allow(clippy::type_complexity)]
    pub(crate) hooks: Vec<Box<dyn Fn(&mut SystemConfig) + Send + Sync>>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> RunSpec {
        RunSpec::new(Task::Det, Policy::ecco())
    }

    #[test]
    fn defaults_validate() {
        assert_eq!(base().validate(), Ok(()));
    }

    #[test]
    fn rejects_zero_windows() {
        assert_eq!(base().windows(0).validate(), Err(SpecError::NoWindows));
    }

    #[test]
    fn rejects_bad_resources() {
        assert_eq!(
            base().gpus(0.0).validate(),
            Err(SpecError::NonPositiveGpus(0.0))
        );
        assert_eq!(
            base().shared_mbps(-1.0).validate(),
            Err(SpecError::NonPositiveBandwidth(-1.0))
        );
        assert_eq!(
            base().uplink_mbps(0.0).validate(),
            Err(SpecError::NonPositiveUplink { cam: 0, mbps: 0.0 })
        );
    }

    #[test]
    fn rejects_mismatched_uplinks() {
        assert_eq!(
            base().cams(3).uplinks(vec![10.0, 10.0]).validate(),
            Err(SpecError::UplinkCountMismatch {
                cams: 3,
                uplinks: 2
            })
        );
        assert_eq!(base().cams(2).uplinks(vec![10.0, 5.0]).validate(), Ok(()));
    }

    #[test]
    fn uplink_count_checked_against_explicit_scenario() {
        let sc = scenario::grouped_static(&[3], 0.06, 10.0, 1);
        let spec = base().scenario(sc).uplinks(vec![20.0; 5]);
        assert_eq!(
            spec.validate(),
            Err(SpecError::UplinkCountMismatch {
                cams: 3,
                uplinks: 5
            })
        );
    }

    #[test]
    fn rejects_zero_cameras() {
        assert_eq!(base().cams(0).validate(), Err(SpecError::NoCameras));
    }

    #[test]
    fn rejects_fault_plan_targeting_missing_camera() {
        use crate::faults::{FaultKind, FaultPlan};
        let plan = FaultPlan::none().at(0, 0, 9, FaultKind::CameraDown);
        assert_eq!(
            base().cams(4).faults(plan.clone()).validate(),
            Err(SpecError::FaultCamOutOfRange { cam: 9, cams: 4 })
        );
        assert_eq!(base().cams(10).faults(plan).validate(), Ok(()));
    }

    #[test]
    fn errors_display_readably() {
        let msg = SpecError::UplinkCountMismatch {
            cams: 4,
            uplinks: 2,
        }
        .to_string();
        assert!(msg.contains("4 cameras") || msg.contains("2 uplinks"), "{msg}");
    }
}

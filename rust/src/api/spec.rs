//! [`RunSpec`]: the validated description of one system run.
//!
//! A spec is (task, policy) plus the resource envelope (GPUs, shared
//! bottleneck, per-camera uplinks), the horizon in retraining windows, the
//! seed, and the scenario world. [`super::Session::new`] consumes a spec;
//! validation happens before any engine work, so malformed sweeps fail
//! fast with a typed [`SpecError`].
//!
//! Per-camera knobs (uplink, window length, phase) layer onto the fleet
//! defaults through [`RunSpec::camera`] + [`CameraSpec`]; process-level
//! runtime knobs (eval workers, frame cache, scheduler) are grouped in
//! [`RuntimeOpts`] and applied with [`RunSpec::runtime`].

use std::collections::BTreeMap;
use std::fmt;

use crate::faults::FaultPlan;
use crate::runtime::{CoalesceOpts, Task};
use crate::scene::scenario::{self, Scenario};
use crate::server::{CamWindow, Policy, Scheduler, SystemConfig};
use crate::util::json::{arr, num, obj, s, Json};

/// A validation failure in a [`RunSpec`].
#[derive(Debug, Clone, PartialEq)]
pub enum SpecError {
    /// The run must cover at least one retraining window.
    NoWindows,
    /// GPU count must be positive and finite.
    NonPositiveGpus(f64),
    /// The shared bottleneck bandwidth must be positive and finite.
    NonPositiveBandwidth(f64),
    /// A per-camera uplink must be positive and finite.
    NonPositiveUplink { cam: usize, mbps: f64 },
    /// Explicit per-camera uplinks must match the camera count.
    UplinkCountMismatch { cams: usize, uplinks: usize },
    /// The scenario (or default-world camera count) has no cameras.
    NoCameras,
    /// The fault plan targets a camera index the scenario doesn't have.
    FaultCamOutOfRange { cam: usize, cams: usize },
    /// A [`RunSpec::camera`] override targets a camera index the scenario
    /// doesn't have.
    UnknownCamera { cam: usize, cams: usize },
    /// A per-camera window length must be positive and finite.
    ZeroWindowLen { cam: usize, secs: f64 },
    /// A per-camera phase must be finite, non-negative, and strictly less
    /// than the camera's window length (when one is set on the spec).
    PhaseOutOfRange {
        cam: usize,
        phase: f64,
        window_len: Option<f64>,
    },
    /// A wire spec ([`RunSpec::from_wire_json`]) was structurally invalid:
    /// wrong JSON shape, a field of the wrong type, or an unparsable
    /// sub-object. `detail` names the offending field.
    Malformed { detail: String },
    /// A wire spec carried a top-level or nested key the protocol doesn't
    /// define (catches client-side typos instead of silently ignoring
    /// them).
    UnknownField { field: String },
    /// A wire enum field (`task`, `policy`, `runtime.scheduler`) named a
    /// variant that doesn't exist.
    UnknownName { field: &'static str, value: String },
    /// A `sim` override was out of range (zero/negative/non-finite).
    BadSimOpt { field: &'static str, value: f64 },
    /// A `runtime.coalesce` knob was out of range (zero mega-batch cap, or
    /// a coalesce window past the 1 s sanity bound).
    BadCoalesceOpt { field: &'static str, value: u64 },
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::NoWindows => write!(f, "run spec: windows must be >= 1"),
            SpecError::NonPositiveGpus(g) => {
                write!(f, "run spec: gpus must be positive, got {g}")
            }
            SpecError::NonPositiveBandwidth(b) => {
                write!(f, "run spec: shared bandwidth must be positive, got {b} Mbps")
            }
            SpecError::NonPositiveUplink { cam, mbps } => {
                write!(f, "run spec: camera {cam} uplink must be positive, got {mbps} Mbps")
            }
            SpecError::UplinkCountMismatch { cams, uplinks } => write!(
                f,
                "run spec: {uplinks} uplinks for {cams} cameras (counts must match)"
            ),
            SpecError::NoCameras => write!(f, "run spec: scenario has no cameras"),
            SpecError::FaultCamOutOfRange { cam, cams } => write!(
                f,
                "run spec: fault plan targets camera {cam} but the scenario has {cams} cameras"
            ),
            SpecError::UnknownCamera { cam, cams } => write!(
                f,
                "run spec: camera override targets camera {cam} but the scenario has {cams} cameras"
            ),
            SpecError::ZeroWindowLen { cam, secs } => write!(
                f,
                "run spec: camera {cam} window length must be positive, got {secs} s"
            ),
            SpecError::PhaseOutOfRange {
                cam,
                phase,
                window_len,
            } => match window_len {
                Some(len) => write!(
                    f,
                    "run spec: camera {cam} phase {phase} s must lie in [0, {len}) s"
                ),
                None => write!(
                    f,
                    "run spec: camera {cam} phase must be finite and >= 0, got {phase} s"
                ),
            },
            SpecError::Malformed { detail } => write!(f, "run spec: malformed: {detail}"),
            SpecError::UnknownField { field } => {
                write!(f, "run spec: unknown field {field:?}")
            }
            SpecError::UnknownName { field, value } => {
                write!(f, "run spec: unknown {field} {value:?}")
            }
            SpecError::BadSimOpt { field, value } => {
                write!(f, "run spec: sim.{field} out of range: {value}")
            }
            SpecError::BadCoalesceOpt { field, value } => {
                write!(f, "run spec: runtime.coalesce.{field} out of range: {value}")
            }
        }
    }
}

impl std::error::Error for SpecError {}

/// Per-camera uplink capacities.
enum Uplinks {
    /// Every camera gets the same uplink (Mbit/s).
    Uniform(f64),
    /// Explicit per-camera uplinks; length must match the camera count.
    PerCamera(Vec<f64>),
}

/// Per-camera overrides, built with [`RunSpec::camera`]. Every field is
/// optional: unset fields keep the fleet-wide default (the spec's uplink
/// setting, the global window length, zero phase).
///
/// ```
/// use ecco::api::{CameraSpec, RunSpec};
/// use ecco::runtime::Task;
/// use ecco::server::Policy;
///
/// let spec = RunSpec::new(Task::Det, Policy::ecco())
///     .cams(4)
///     .camera(2, |c: CameraSpec| c.uplink_mbps(8.0).window_len(30.0))
///     .camera(3, |c| c.phase(10.0));
/// assert_eq!(spec.validate(), Ok(()));
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct CameraSpec {
    uplink_mbps: Option<f64>,
    window_len: Option<f64>,
    phase: Option<f64>,
}

impl CameraSpec {
    /// Override this camera's uplink capacity (Mbit/s).
    pub fn uplink_mbps(mut self, mbps: f64) -> Self {
        self.uplink_mbps = Some(mbps);
        self
    }

    /// Give this camera its own retraining-window length (seconds). Any
    /// heterogeneous length forces the event-driven scheduler.
    pub fn window_len(mut self, secs: f64) -> Self {
        self.window_len = Some(secs);
        self
    }

    /// Stagger this camera's window boundaries by `secs` from the server
    /// clock origin; must lie in `[0, window_len)`. Any non-zero phase
    /// forces the event-driven scheduler.
    pub fn phase(mut self, secs: f64) -> Self {
        self.phase = Some(secs);
        self
    }
}

/// Process-level runtime options, applied with [`RunSpec::runtime`].
/// Unset fields keep the [`SystemConfig`] defaults, so `RuntimeOpts::new()`
/// is a no-op.
///
/// ```
/// use ecco::api::{RunSpec, RuntimeOpts};
/// use ecco::runtime::Task;
/// use ecco::server::{Policy, Scheduler};
///
/// let spec = RunSpec::new(Task::Det, Policy::ecco())
///     .runtime(RuntimeOpts::new().threads(4).scheduler(Scheduler::EventDriven));
/// assert_eq!(spec.validate(), Ok(()));
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RuntimeOpts {
    threads: Option<usize>,
    frame_cache: Option<bool>,
    scheduler: Option<Scheduler>,
    coalesce: Option<CoalesceOpts>,
}

impl RuntimeOpts {
    pub fn new() -> RuntimeOpts {
        RuntimeOpts::default()
    }

    /// Worker threads for the evaluation fan-outs (clamped to >= 1).
    /// Byte-identical at any value; only trades wall-clock for cores.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n.max(1));
        self
    }

    /// Enable/disable the per-window eval-frame render cache (on by
    /// default; byte-identical either way).
    pub fn frame_cache(mut self, enabled: bool) -> Self {
        self.frame_cache = Some(enabled);
        self
    }

    /// Pick the per-window driver. Heterogeneous camera windows force
    /// [`Scheduler::EventDriven`] regardless of this setting.
    pub fn scheduler(mut self, scheduler: Scheduler) -> Self {
        self.scheduler = Some(scheduler);
        self
    }

    /// Micro-batch coalescing for the engine's inference submission layer
    /// ([`crate::runtime::microbatch`]). Off by default; byte-identical
    /// results either way — only the kernel-launch count changes.
    pub fn coalesce(mut self, opts: CoalesceOpts) -> Self {
        self.coalesce = Some(opts);
        self
    }
}

/// Simulation-granularity overrides, applied with [`RunSpec::sim`]. Unset
/// fields keep the [`SystemConfig`] defaults. These are the knobs fast
/// tests and serve clients use to shrink a run; they change the simulated
/// workload (unlike [`RuntimeOpts`], which never changes results).
///
/// ```
/// use ecco::api::{RunSpec, SimOpts};
/// use ecco::runtime::Task;
/// use ecco::server::Policy;
///
/// let spec = RunSpec::new(Task::Det, Policy::ecco())
///     .sim(SimOpts::new().window_secs(40.0).micro_windows(4).eval_frames(8));
/// assert_eq!(spec.validate(), Ok(()));
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SimOpts {
    window_secs: Option<f64>,
    micro_windows: Option<usize>,
    eval_frames: Option<usize>,
    pretrain_steps: Option<usize>,
}

impl SimOpts {
    pub fn new() -> SimOpts {
        SimOpts::default()
    }

    /// Retraining-window length in simulated seconds. Non-finite or
    /// non-positive values are ignored (the hook only applies valid
    /// lengths); the wire parser rejects them with
    /// [`SpecError::BadSimOpt`].
    pub fn window_secs(mut self, secs: f64) -> Self {
        self.window_secs = Some(secs);
        self
    }

    /// Micro-windows per retraining window (clamped to >= 1).
    pub fn micro_windows(mut self, n: usize) -> Self {
        self.micro_windows = Some(n.max(1));
        self
    }

    /// Held-out frames per evaluation pass (clamped to >= 1).
    pub fn eval_frames(mut self, n: usize) -> Self {
        self.eval_frames = Some(n.max(1));
        self
    }

    /// Fine-tune steps for the window-0 pretrain phase.
    pub fn pretrain_steps(mut self, n: usize) -> Self {
        self.pretrain_steps = Some(n);
        self
    }
}

/// Builder for one system run. Defaults mirror the quick-driver CLI:
/// 6 cameras in two correlated triples, 1 GPU, 6 Mbps shared / 20 Mbps
/// uplinks, 8 windows, seed 7.
pub struct RunSpec {
    pub(crate) task: Task,
    pub(crate) policy: Policy,
    pub(crate) cams: usize,
    pub(crate) gpus: f64,
    pub(crate) shared_mbps: f64,
    uplinks: Uplinks,
    /// Per-camera overrides, layered over `uplinks` / the global window.
    cameras: BTreeMap<usize, CameraSpec>,
    /// Prune Alg. 2 candidate scans to each camera's k spatial neighbors.
    topology_degree: Option<usize>,
    pub(crate) windows: usize,
    pub(crate) seed: u64,
    pub(crate) scenario: Option<Scenario>,
    /// Deterministic fault-injection schedule ([`FaultPlan::none`] by
    /// default — guaranteed zero-cost, see [`crate::faults`]).
    faults: FaultPlan,
    /// Zoo-prefill fine-tune steps when the policy warm-starts from a zoo.
    pub(crate) zoo_init_steps: usize,
    /// Merged [`RunSpec::runtime`] calls, kept alongside the hook so the
    /// spec can be exported to the wire ([`RunSpec::to_wire_json`]).
    runtime_wire: RuntimeOpts,
    /// Merged [`RunSpec::sim`] calls, kept for the same reason.
    sim_wire: SimOpts,
    /// Config hooks, applied in order after the built-in knobs. `Send +
    /// Sync` so whole specs can be shipped to fleet-driver workers.
    #[allow(clippy::type_complexity)]
    pub(crate) hooks: Vec<Box<dyn Fn(&mut SystemConfig) + Send + Sync>>,
}

impl RunSpec {
    pub fn new(task: Task, policy: Policy) -> RunSpec {
        RunSpec {
            task,
            policy,
            cams: 6,
            gpus: 1.0,
            shared_mbps: 6.0,
            uplinks: Uplinks::Uniform(20.0),
            cameras: BTreeMap::new(),
            topology_degree: None,
            windows: 8,
            seed: 7,
            scenario: None,
            faults: FaultPlan::none(),
            zoo_init_steps: 40,
            runtime_wire: RuntimeOpts::default(),
            sim_wire: SimOpts::default(),
            hooks: Vec::new(),
        }
    }

    /// Camera count for the default scenario (ignored with an explicit
    /// [`RunSpec::scenario`]).
    pub fn cams(mut self, n: usize) -> Self {
        self.cams = n;
        self
    }

    /// Simulated edge GPUs.
    pub fn gpus(mut self, gpus: f64) -> Self {
        self.gpus = gpus;
        self
    }

    /// Shared bottleneck bandwidth (Mbit/s).
    pub fn shared_mbps(mut self, mbps: f64) -> Self {
        self.shared_mbps = mbps;
        self
    }

    /// One uplink capacity (Mbit/s) for every camera.
    pub fn uplink_mbps(mut self, mbps: f64) -> Self {
        self.uplinks = Uplinks::Uniform(mbps);
        self
    }

    /// Explicit per-camera uplinks (Mbit/s); length must match the camera
    /// count or validation fails. Equivalent to calling
    /// [`RunSpec::camera`] with `uplink_mbps` per index; per-camera
    /// overrides win over this base vector.
    pub fn uplinks(mut self, mbps: Vec<f64>) -> Self {
        self.uplinks = Uplinks::PerCamera(mbps);
        self
    }

    /// Per-camera overrides: fetch (or default) camera `cam`'s
    /// [`CameraSpec`], run it through `f`, and store the result. Repeated
    /// calls for the same camera compose — each sees the accumulated spec.
    pub fn camera(mut self, cam: usize, f: impl FnOnce(CameraSpec) -> CameraSpec) -> Self {
        let entry = self.cameras.get(&cam).copied().unwrap_or_default();
        self.cameras.insert(cam, f(entry));
        self
    }

    /// Prune dynamic grouping's candidate scan (Alg. 2) to each camera's
    /// `degree` nearest spatial neighbors, derived from the scenario's
    /// camera placement. `degree >= n - 1` reproduces the all-pairs scan
    /// exactly; smaller degrees drop the per-request cost from O(n) to
    /// O(degree) with a periodic long-range probe window as the safety
    /// net. Only affects group-retraining policies.
    pub fn topology_degree(mut self, degree: usize) -> Self {
        self.topology_degree = Some(degree);
        self
    }

    /// Horizon in retraining windows.
    pub fn windows(mut self, n: usize) -> Self {
        self.windows = n;
        self
    }

    /// Seed for the scenario, system, and all simulators.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run on an explicit scenario world instead of the default
    /// two-triple static world.
    pub fn scenario(mut self, sc: Scenario) -> Self {
        self.scenario = Some(sc);
        self
    }

    /// Attach a deterministic fault-injection schedule (see
    /// [`crate::faults`]). [`FaultPlan::none`] — the default — is
    /// guaranteed zero-cost: event logs stay byte-identical to a run
    /// without a plan.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = plan;
        self
    }

    /// Override the zoo-prefill fine-tune steps (0 disables the prefill;
    /// only relevant when the policy has `zoo_warm_start`).
    pub fn zoo_init_steps(mut self, steps: usize) -> Self {
        self.zoo_init_steps = steps;
        self
    }

    /// Arbitrary [`SystemConfig`] tweak, applied after the built-in knobs
    /// (gpus/seed); hooks run in registration order.
    pub fn configure<F: Fn(&mut SystemConfig) + Send + Sync + 'static>(mut self, hook: F) -> Self {
        self.hooks.push(Box::new(hook));
        self
    }

    /// Apply a batch of process-level runtime options (threads, frame
    /// cache, scheduler). Only fields explicitly set on `opts` are
    /// applied; like any hook, later calls win over earlier ones.
    pub fn runtime(mut self, opts: RuntimeOpts) -> Self {
        if let Some(n) = opts.threads {
            self.runtime_wire.threads = Some(n);
        }
        if let Some(cache) = opts.frame_cache {
            self.runtime_wire.frame_cache = Some(cache);
        }
        if let Some(scheduler) = opts.scheduler {
            self.runtime_wire.scheduler = Some(scheduler);
        }
        if let Some(coalesce) = opts.coalesce {
            self.runtime_wire.coalesce = Some(coalesce);
        }
        self.configure(move |cfg| {
            if let Some(n) = opts.threads {
                cfg.eval_threads = n;
            }
            if let Some(cache) = opts.frame_cache {
                cfg.frame_cache = cache;
            }
            if let Some(scheduler) = opts.scheduler {
                cfg.scheduler = scheduler;
            }
            if let Some(coalesce) = opts.coalesce {
                cfg.coalesce = Some(coalesce);
            }
        })
    }

    /// Apply simulation-granularity overrides (window length,
    /// micro-windows, eval frames, pretrain steps). Only fields explicitly
    /// set on `opts` are applied; later calls win over earlier ones. These
    /// ride the wire (see [`RunSpec::to_wire_json`]) so serve clients can
    /// size their runs without config hooks.
    pub fn sim(mut self, opts: SimOpts) -> Self {
        if let Some(secs) = opts.window_secs {
            self.sim_wire.window_secs = Some(secs);
        }
        if let Some(n) = opts.micro_windows {
            self.sim_wire.micro_windows = Some(n);
        }
        if let Some(n) = opts.eval_frames {
            self.sim_wire.eval_frames = Some(n);
        }
        if let Some(n) = opts.pretrain_steps {
            self.sim_wire.pretrain_steps = Some(n);
        }
        self.configure(move |cfg| {
            if let Some(secs) = opts.window_secs {
                if secs.is_finite() && secs > 0.0 {
                    cfg.window_secs = secs;
                }
            }
            if let Some(n) = opts.micro_windows {
                cfg.micro_windows = n;
            }
            if let Some(n) = opts.eval_frames {
                cfg.eval_frames = n;
            }
            if let Some(n) = opts.pretrain_steps {
                cfg.pretrain_steps = n;
            }
        })
    }

    /// Worker threads for the system's evaluation fan-outs (see
    /// `SystemConfig::eval_threads`). Runs are byte-identical at any value;
    /// defaults to the machine's parallelism (`ECCO_THREADS` overrides).
    ///
    /// Deprecated in favor of
    /// [`RunSpec::runtime`]`(RuntimeOpts::new().threads(n))`; kept as a
    /// thin wrapper.
    pub fn eval_threads(self, n: usize) -> Self {
        self.runtime(RuntimeOpts::new().threads(n))
    }

    /// Enable/disable the per-window eval-frame render cache (see
    /// `SystemConfig::frame_cache`; on by default). Runs are byte-identical
    /// either way — disabling only trades wall-clock to verify that claim.
    ///
    /// Deprecated in favor of
    /// [`RunSpec::runtime`]`(RuntimeOpts::new().frame_cache(enabled))`;
    /// kept as a thin wrapper.
    pub fn frame_cache(self, enabled: bool) -> Self {
        self.runtime(RuntimeOpts::new().frame_cache(enabled))
    }

    /// Like [`RunSpec::eval_threads`], but registered *before* every other
    /// hook so an explicit `eval_threads` (or any user hook) still wins.
    /// The fleet driver uses this to divide eval workers by the fleet
    /// concurrency instead of oversubscribing the CPU.
    pub(crate) fn eval_threads_floor(mut self, n: usize) -> Self {
        self.hooks
            .insert(0, Box::new(move |cfg| cfg.eval_threads = n.max(1)));
        self
    }

    /// Camera count this spec will run with.
    pub fn n_cams(&self) -> usize {
        match &self.scenario {
            Some(sc) => sc.world.cameras.len(),
            None => self.cams,
        }
    }

    /// Check the spec without building anything.
    pub fn validate(&self) -> Result<(), SpecError> {
        if self.windows == 0 {
            return Err(SpecError::NoWindows);
        }
        if !(self.gpus.is_finite() && self.gpus > 0.0) {
            return Err(SpecError::NonPositiveGpus(self.gpus));
        }
        if !(self.shared_mbps.is_finite() && self.shared_mbps > 0.0) {
            return Err(SpecError::NonPositiveBandwidth(self.shared_mbps));
        }
        let n = self.n_cams();
        if n == 0 {
            return Err(SpecError::NoCameras);
        }
        if let Uplinks::PerCamera(ups) = &self.uplinks {
            if ups.len() != n {
                return Err(SpecError::UplinkCountMismatch {
                    cams: n,
                    uplinks: ups.len(),
                });
            }
        }
        let check = |cam: usize, mbps: f64| -> Result<(), SpecError> {
            if !(mbps.is_finite() && mbps > 0.0) {
                return Err(SpecError::NonPositiveUplink { cam, mbps });
            }
            Ok(())
        };
        match &self.uplinks {
            Uplinks::Uniform(mbps) => check(0, *mbps)?,
            Uplinks::PerCamera(ups) => {
                for (cam, &mbps) in ups.iter().enumerate() {
                    check(cam, mbps)?;
                }
            }
        }
        if let Some(cam) = self.faults.max_cam() {
            if cam >= n {
                return Err(SpecError::FaultCamOutOfRange { cam, cams: n });
            }
        }
        for (&cam, cspec) in &self.cameras {
            if cam >= n {
                return Err(SpecError::UnknownCamera { cam, cams: n });
            }
            if let Some(mbps) = cspec.uplink_mbps {
                check(cam, mbps)?;
            }
            if let Some(len) = cspec.window_len {
                if !(len.is_finite() && len > 0.0) {
                    return Err(SpecError::ZeroWindowLen { cam, secs: len });
                }
            }
            if let Some(phase) = cspec.phase {
                let bad = !(phase.is_finite() && phase >= 0.0)
                    || cspec.window_len.is_some_and(|len| phase >= len);
                if bad {
                    return Err(SpecError::PhaseOutOfRange {
                        cam,
                        phase,
                        window_len: cspec.window_len,
                    });
                }
            }
        }
        if let Some(c) = self.runtime_wire.coalesce {
            if c.max_batch == 0 {
                return Err(SpecError::BadCoalesceOpt {
                    field: "max_batch",
                    value: 0,
                });
            }
            // A coalesce window is scheduling jitter, not a batching
            // schedule; past 1 s it can only be a units mistake.
            if c.window_us > 1_000_000 {
                return Err(SpecError::BadCoalesceOpt {
                    field: "window_us",
                    value: c.window_us,
                });
            }
        }
        Ok(())
    }

    /// Export the wire-representable surface of this spec as the JSON
    /// object the `ecco serve` protocol accepts in `submit`. Inverse of
    /// [`RunSpec::from_wire_json`] for that surface: two process-local
    /// pieces do NOT ride the wire — an explicit [`RunSpec::scenario`]
    /// world (only its camera count is exported; the importer rebuilds the
    /// default world at that count) and [`RunSpec::configure`] hooks
    /// (closures aren't serializable; use [`RunSpec::runtime`] /
    /// [`RunSpec::sim`], which are). Seeds above 2^53 lose precision
    /// (numbers travel as f64).
    pub fn to_wire_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("task", s(self.task.name())),
            ("policy", s(self.policy.name)),
            ("cams", num(self.n_cams() as f64)),
            ("gpus", num(self.gpus)),
            ("shared_mbps", num(self.shared_mbps)),
            ("windows", num(self.windows as f64)),
            ("seed", num(self.seed as f64)),
            ("zoo_init_steps", num(self.zoo_init_steps as f64)),
        ];
        match &self.uplinks {
            Uplinks::Uniform(mbps) => fields.push(("uplink_mbps", num(*mbps))),
            Uplinks::PerCamera(ups) => {
                fields.push(("uplinks", arr(ups.iter().map(|&m| num(m)).collect())));
            }
        }
        if !self.cameras.is_empty() {
            let m: BTreeMap<String, Json> = self
                .cameras
                .iter()
                .map(|(&cam, c)| {
                    let mut cf: Vec<(&str, Json)> = Vec::new();
                    if let Some(mbps) = c.uplink_mbps {
                        cf.push(("uplink_mbps", num(mbps)));
                    }
                    if let Some(len) = c.window_len {
                        cf.push(("window_len", num(len)));
                    }
                    if let Some(phase) = c.phase {
                        cf.push(("phase", num(phase)));
                    }
                    (cam.to_string(), obj(cf))
                })
                .collect();
            fields.push(("cameras", Json::Obj(m)));
        }
        if let Some(d) = self.topology_degree {
            fields.push(("topology_degree", num(d as f64)));
        }
        if !self.faults.is_empty() {
            fields.push(("faults", self.faults.to_json()));
        }
        let rt = &self.runtime_wire;
        if *rt != RuntimeOpts::default() {
            let mut rf: Vec<(&str, Json)> = Vec::new();
            if let Some(n) = rt.threads {
                rf.push(("threads", num(n as f64)));
            }
            if let Some(cache) = rt.frame_cache {
                rf.push(("frame_cache", Json::Bool(cache)));
            }
            if let Some(sched) = rt.scheduler {
                rf.push(("scheduler", s(sched.name())));
            }
            if let Some(c) = rt.coalesce {
                rf.push((
                    "coalesce",
                    obj(vec![
                        ("enabled", Json::Bool(c.enabled)),
                        ("window_us", num(c.window_us as f64)),
                        ("max_batch", num(c.max_batch as f64)),
                    ]),
                ));
            }
            fields.push(("runtime", obj(rf)));
        }
        let sim = &self.sim_wire;
        if *sim != SimOpts::default() {
            let mut sf: Vec<(&str, Json)> = Vec::new();
            if let Some(secs) = sim.window_secs {
                sf.push(("window_secs", num(secs)));
            }
            if let Some(n) = sim.micro_windows {
                sf.push(("micro_windows", num(n as f64)));
            }
            if let Some(n) = sim.eval_frames {
                sf.push(("eval_frames", num(n as f64)));
            }
            if let Some(n) = sim.pretrain_steps {
                sf.push(("pretrain_steps", num(n as f64)));
            }
            fields.push(("sim", obj(sf)));
        }
        obj(fields)
    }

    /// Parse and validate a wire spec (see [`RunSpec::to_wire_json`] for
    /// the schema). Every key is checked: unknown fields, wrong types, and
    /// out-of-range values all map to a typed [`SpecError`], and the
    /// returned spec has already passed [`RunSpec::validate`].
    pub fn from_wire_json(j: &Json) -> Result<RunSpec, SpecError> {
        let map = match j {
            Json::Obj(m) => m,
            _ => {
                return Err(SpecError::Malformed {
                    detail: "spec must be a JSON object".into(),
                })
            }
        };
        let mut spec = RunSpec::new(Task::Det, Policy::ecco());
        let mut runtime = RuntimeOpts::new();
        let mut sim = SimOpts::new();
        for (key, val) in map {
            match key.as_str() {
                "task" => {
                    let name = wire_str(val, "task")?;
                    spec.task = Task::parse(name).map_err(|_| SpecError::UnknownName {
                        field: "task",
                        value: name.to_string(),
                    })?;
                }
                "policy" => {
                    let name = wire_str(val, "policy")?;
                    spec.policy = Policy::by_name(name).ok_or_else(|| SpecError::UnknownName {
                        field: "policy",
                        value: name.to_string(),
                    })?;
                }
                "cams" => spec.cams = wire_usize(val, "cams")?,
                "gpus" => spec.gpus = wire_f64(val, "gpus")?,
                "shared_mbps" => spec.shared_mbps = wire_f64(val, "shared_mbps")?,
                "windows" => spec.windows = wire_usize(val, "windows")?,
                "seed" => spec.seed = wire_u64(val, "seed")?,
                "zoo_init_steps" => spec.zoo_init_steps = wire_usize(val, "zoo_init_steps")?,
                "uplink_mbps" => {
                    spec.uplinks = Uplinks::Uniform(wire_f64(val, "uplink_mbps")?);
                }
                "uplinks" => {
                    let items = val.as_arr().map_err(|e| wire_err("uplinks", &e))?;
                    let mut ups = Vec::with_capacity(items.len());
                    for (i, item) in items.iter().enumerate() {
                        ups.push(wire_f64(item, &format!("uplinks[{i}]"))?);
                    }
                    spec.uplinks = Uplinks::PerCamera(ups);
                }
                "cameras" => {
                    let cmap = val.as_obj().map_err(|e| wire_err("cameras", &e))?;
                    for (cam_key, cval) in cmap {
                        let cam: usize = cam_key.parse().map_err(|_| SpecError::Malformed {
                            detail: format!("cameras key {cam_key:?} is not a camera index"),
                        })?;
                        let cobj = cval
                            .as_obj()
                            .map_err(|e| wire_err(&format!("cameras.{cam_key}"), &e))?;
                        let mut cs = CameraSpec::default();
                        for (ck, cv) in cobj {
                            let label = format!("cameras.{cam_key}.{ck}");
                            match ck.as_str() {
                                "uplink_mbps" => cs.uplink_mbps = Some(wire_f64(cv, &label)?),
                                "window_len" => cs.window_len = Some(wire_f64(cv, &label)?),
                                "phase" => cs.phase = Some(wire_f64(cv, &label)?),
                                _ => return Err(SpecError::UnknownField { field: label }),
                            }
                        }
                        spec.cameras.insert(cam, cs);
                    }
                }
                "topology_degree" => {
                    spec.topology_degree = Some(wire_usize(val, "topology_degree")?);
                }
                "faults" => {
                    spec.faults = FaultPlan::from_json(val)
                        .map_err(|detail| SpecError::Malformed { detail })?;
                }
                "runtime" => {
                    let rmap = val.as_obj().map_err(|e| wire_err("runtime", &e))?;
                    for (rk, rv) in rmap {
                        match rk.as_str() {
                            "threads" => {
                                runtime = runtime.threads(wire_usize(rv, "runtime.threads")?);
                            }
                            "frame_cache" => {
                                runtime =
                                    runtime.frame_cache(wire_bool(rv, "runtime.frame_cache")?);
                            }
                            "scheduler" => {
                                let name = wire_str(rv, "runtime.scheduler")?;
                                let sched = Scheduler::by_name(name).ok_or_else(|| {
                                    SpecError::UnknownName {
                                        field: "runtime.scheduler",
                                        value: name.to_string(),
                                    }
                                })?;
                                runtime = runtime.scheduler(sched);
                            }
                            "coalesce" => {
                                let cmap =
                                    rv.as_obj().map_err(|e| wire_err("runtime.coalesce", &e))?;
                                let mut c = CoalesceOpts::default();
                                for (ck, cv) in cmap {
                                    match ck.as_str() {
                                        "enabled" => {
                                            c.enabled =
                                                wire_bool(cv, "runtime.coalesce.enabled")?;
                                        }
                                        "window_us" => {
                                            c.window_us =
                                                wire_u64(cv, "runtime.coalesce.window_us")?;
                                        }
                                        "max_batch" => {
                                            c.max_batch =
                                                wire_usize(cv, "runtime.coalesce.max_batch")?;
                                        }
                                        other => {
                                            return Err(SpecError::UnknownField {
                                                field: format!("runtime.coalesce.{other}"),
                                            })
                                        }
                                    }
                                }
                                runtime = runtime.coalesce(c);
                            }
                            other => {
                                return Err(SpecError::UnknownField {
                                    field: format!("runtime.{other}"),
                                })
                            }
                        }
                    }
                }
                "sim" => {
                    let smap = val.as_obj().map_err(|e| wire_err("sim", &e))?;
                    for (sk, sv) in smap {
                        match sk.as_str() {
                            "window_secs" => {
                                let secs = wire_f64(sv, "sim.window_secs")?;
                                if !(secs.is_finite() && secs > 0.0) {
                                    return Err(SpecError::BadSimOpt {
                                        field: "window_secs",
                                        value: secs,
                                    });
                                }
                                sim = sim.window_secs(secs);
                            }
                            "micro_windows" => {
                                let n = wire_usize(sv, "sim.micro_windows")?;
                                if n == 0 {
                                    return Err(SpecError::BadSimOpt {
                                        field: "micro_windows",
                                        value: 0.0,
                                    });
                                }
                                sim = sim.micro_windows(n);
                            }
                            "eval_frames" => {
                                let n = wire_usize(sv, "sim.eval_frames")?;
                                if n == 0 {
                                    return Err(SpecError::BadSimOpt {
                                        field: "eval_frames",
                                        value: 0.0,
                                    });
                                }
                                sim = sim.eval_frames(n);
                            }
                            "pretrain_steps" => {
                                sim = sim.pretrain_steps(wire_usize(sv, "sim.pretrain_steps")?);
                            }
                            other => {
                                return Err(SpecError::UnknownField {
                                    field: format!("sim.{other}"),
                                })
                            }
                        }
                    }
                }
                other => {
                    return Err(SpecError::UnknownField {
                        field: other.to_string(),
                    })
                }
            }
        }
        if runtime != RuntimeOpts::default() {
            spec = spec.runtime(runtime);
        }
        if sim != SimOpts::default() {
            spec = spec.sim(sim);
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Resolve the scenario (building the default world if none was set)
    /// and the per-camera uplink vector. Call after [`RunSpec::validate`].
    pub(crate) fn into_parts(self) -> (Scenario, Vec<f64>, RunSpecRest) {
        let sc = self.scenario.unwrap_or_else(|| {
            let split = if self.cams < 2 {
                vec![self.cams]
            } else {
                vec![self.cams / 2, self.cams - self.cams / 2]
            };
            scenario::grouped_static(&split, 0.06, 30.0, self.seed)
        });
        let n = sc.world.cameras.len();
        let mut uplinks = match self.uplinks {
            Uplinks::Uniform(mbps) => vec![mbps; n],
            Uplinks::PerCamera(ups) => ups,
        };
        for (&cam, cspec) in &self.cameras {
            if let (Some(mbps), Some(slot)) = (cspec.uplink_mbps, uplinks.get_mut(cam)) {
                *slot = mbps;
            }
        }
        let cam_windows: BTreeMap<usize, CamWindow> = self
            .cameras
            .iter()
            .filter(|(_, c)| c.window_len.is_some() || c.phase.is_some())
            .map(|(&cam, c)| {
                (
                    cam,
                    CamWindow {
                        len_secs: c.window_len,
                        phase_secs: c.phase.unwrap_or(0.0),
                    },
                )
            })
            .collect();
        (
            sc,
            uplinks,
            RunSpecRest {
                task: self.task,
                policy: self.policy,
                gpus: self.gpus,
                shared_mbps: self.shared_mbps,
                windows: self.windows,
                seed: self.seed,
                faults: self.faults,
                zoo_init_steps: self.zoo_init_steps,
                cam_windows,
                topology_degree: self.topology_degree,
                hooks: self.hooks,
            },
        )
    }
}

// -- wire parsing helpers: map Json accessor errors onto the typed
// SpecError::Malformed with the offending field named. ------------------

fn wire_err(field: &str, detail: &dyn fmt::Display) -> SpecError {
    SpecError::Malformed {
        detail: format!("{field}: {detail}"),
    }
}

fn wire_f64(v: &Json, field: &str) -> Result<f64, SpecError> {
    v.as_f64().map_err(|e| wire_err(field, &e))
}

fn wire_usize(v: &Json, field: &str) -> Result<usize, SpecError> {
    v.as_usize().map_err(|e| wire_err(field, &e))
}

fn wire_u64(v: &Json, field: &str) -> Result<u64, SpecError> {
    let n = wire_f64(v, field)?;
    if !(n.is_finite() && n >= 0.0 && n.fract() == 0.0) {
        return Err(wire_err(field, &format!("not a non-negative integer: {n}")));
    }
    Ok(n as u64)
}

fn wire_bool(v: &Json, field: &str) -> Result<bool, SpecError> {
    match v {
        Json::Bool(b) => Ok(*b),
        other => Err(wire_err(field, &format!("not a bool: {other:?}"))),
    }
}

fn wire_str<'a>(v: &'a Json, field: &str) -> Result<&'a str, SpecError> {
    v.as_str().map_err(|e| wire_err(field, &e))
}

/// The non-world remainder of a consumed [`RunSpec`].
pub(crate) struct RunSpecRest {
    pub(crate) task: Task,
    pub(crate) policy: Policy,
    pub(crate) gpus: f64,
    pub(crate) shared_mbps: f64,
    pub(crate) windows: usize,
    pub(crate) seed: u64,
    pub(crate) faults: FaultPlan,
    pub(crate) zoo_init_steps: usize,
    pub(crate) cam_windows: BTreeMap<usize, CamWindow>,
    pub(crate) topology_degree: Option<usize>,
    #[allow(clippy::type_complexity)]
    pub(crate) hooks: Vec<Box<dyn Fn(&mut SystemConfig) + Send + Sync>>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> RunSpec {
        RunSpec::new(Task::Det, Policy::ecco())
    }

    #[test]
    fn defaults_validate() {
        assert_eq!(base().validate(), Ok(()));
    }

    #[test]
    fn rejects_zero_windows() {
        assert_eq!(base().windows(0).validate(), Err(SpecError::NoWindows));
    }

    #[test]
    fn rejects_bad_resources() {
        assert_eq!(
            base().gpus(0.0).validate(),
            Err(SpecError::NonPositiveGpus(0.0))
        );
        assert_eq!(
            base().shared_mbps(-1.0).validate(),
            Err(SpecError::NonPositiveBandwidth(-1.0))
        );
        assert_eq!(
            base().uplink_mbps(0.0).validate(),
            Err(SpecError::NonPositiveUplink { cam: 0, mbps: 0.0 })
        );
    }

    #[test]
    fn rejects_mismatched_uplinks() {
        assert_eq!(
            base().cams(3).uplinks(vec![10.0, 10.0]).validate(),
            Err(SpecError::UplinkCountMismatch {
                cams: 3,
                uplinks: 2
            })
        );
        assert_eq!(base().cams(2).uplinks(vec![10.0, 5.0]).validate(), Ok(()));
    }

    #[test]
    fn uplink_count_checked_against_explicit_scenario() {
        let sc = scenario::grouped_static(&[3], 0.06, 10.0, 1);
        let spec = base().scenario(sc).uplinks(vec![20.0; 5]);
        assert_eq!(
            spec.validate(),
            Err(SpecError::UplinkCountMismatch {
                cams: 3,
                uplinks: 5
            })
        );
    }

    #[test]
    fn rejects_zero_cameras() {
        assert_eq!(base().cams(0).validate(), Err(SpecError::NoCameras));
    }

    #[test]
    fn rejects_fault_plan_targeting_missing_camera() {
        use crate::faults::{FaultKind, FaultPlan};
        let plan = FaultPlan::none().at(0, 0, 9, FaultKind::CameraDown);
        assert_eq!(
            base().cams(4).faults(plan.clone()).validate(),
            Err(SpecError::FaultCamOutOfRange { cam: 9, cams: 4 })
        );
        assert_eq!(base().cams(10).faults(plan).validate(), Ok(()));
    }

    #[test]
    fn camera_overrides_validate_with_typed_errors() {
        // Index past the fleet.
        assert_eq!(
            base().cams(4).camera(9, |c| c.uplink_mbps(5.0)).validate(),
            Err(SpecError::UnknownCamera { cam: 9, cams: 4 })
        );
        // Bad uplink override reuses the uplink error.
        assert_eq!(
            base().camera(1, |c| c.uplink_mbps(0.0)).validate(),
            Err(SpecError::NonPositiveUplink { cam: 1, mbps: 0.0 })
        );
        // Zero / non-finite window length.
        assert_eq!(
            base().camera(0, |c| c.window_len(0.0)).validate(),
            Err(SpecError::ZeroWindowLen { cam: 0, secs: 0.0 })
        );
        // Phase at/after the camera's own window boundary.
        assert_eq!(
            base().camera(2, |c| c.window_len(30.0).phase(30.0)).validate(),
            Err(SpecError::PhaseOutOfRange {
                cam: 2,
                phase: 30.0,
                window_len: Some(30.0)
            })
        );
        // Negative phase fails even without a window-length override.
        assert_eq!(
            base().camera(2, |c| c.phase(-1.0)).validate(),
            Err(SpecError::PhaseOutOfRange {
                cam: 2,
                phase: -1.0,
                window_len: None
            })
        );
        // A well-formed heterogeneous fleet passes.
        assert_eq!(
            base()
                .camera(0, |c| c.window_len(30.0).phase(10.0))
                .camera(5, |c| c.uplink_mbps(4.0))
                .validate(),
            Ok(())
        );
    }

    #[test]
    fn camera_calls_compose_and_layer_over_uplink_vector() {
        let spec = base()
            .cams(3)
            .uplinks(vec![10.0, 11.0, 12.0])
            .camera(1, |c| c.uplink_mbps(99.0))
            .camera(1, |c| c.window_len(30.0)); // must keep the uplink
        assert_eq!(spec.validate(), Ok(()));
        let (_, uplinks, rest) = spec.into_parts();
        assert_eq!(uplinks, vec![10.0, 99.0, 12.0]);
        let cw = rest.cam_windows.get(&1).copied().unwrap();
        assert_eq!(cw.len_secs, Some(30.0));
        assert_eq!(cw.phase_secs, 0.0);
        // Uplink-only overrides don't create window entries.
        let (_, _, rest2) = base().camera(0, |c| c.uplink_mbps(5.0)).into_parts();
        assert!(rest2.cam_windows.is_empty());
    }

    #[test]
    fn runtime_opts_unset_fields_are_no_ops() {
        let mut cfg = SystemConfig::new(Task::Det, Policy::ecco());
        let baseline = (cfg.eval_threads, cfg.frame_cache, cfg.scheduler);
        let spec = base().runtime(RuntimeOpts::new());
        for hook in &spec.hooks {
            hook(&mut cfg);
        }
        assert_eq!((cfg.eval_threads, cfg.frame_cache, cfg.scheduler), baseline);
        let spec = base().runtime(
            RuntimeOpts::new()
                .threads(0)
                .frame_cache(false)
                .scheduler(Scheduler::EventDriven),
        );
        for hook in &spec.hooks {
            hook(&mut cfg);
        }
        assert_eq!(cfg.eval_threads, 1, "threads clamp to >= 1");
        assert!(!cfg.frame_cache);
        assert_eq!(cfg.scheduler, Scheduler::EventDriven);
    }

    #[test]
    fn errors_display_readably() {
        let msg = SpecError::UplinkCountMismatch {
            cams: 4,
            uplinks: 2,
        }
        .to_string();
        assert!(msg.contains("4 cameras") || msg.contains("2 uplinks"), "{msg}");
    }

    fn full_spec() -> RunSpec {
        use crate::faults::{FaultKind, FaultPlan};
        base()
            .cams(4)
            .gpus(2.0)
            .shared_mbps(8.0)
            .uplinks(vec![20.0, 18.0, 16.0, 14.0])
            .camera(1, |c| c.uplink_mbps(9.0).window_len(60.0).phase(10.0))
            .camera(3, |c| c.uplink_mbps(5.0))
            .topology_degree(2)
            .windows(5)
            .seed(1234)
            .zoo_init_steps(20)
            .faults(FaultPlan::none().at(1, 0, 2, FaultKind::CameraDown))
            .runtime(
                RuntimeOpts::new()
                    .threads(2)
                    .frame_cache(false)
                    .scheduler(Scheduler::EventDriven)
                    .coalesce(CoalesceOpts::on().window_us(150).max_batch(96)),
            )
            .sim(
                SimOpts::new()
                    .window_secs(40.0)
                    .micro_windows(4)
                    .eval_frames(8)
                    .pretrain_steps(120),
            )
    }

    #[test]
    fn wire_json_round_trips_the_full_surface() {
        let spec = full_spec();
        let wire = spec.to_wire_json();
        let back = RunSpec::from_wire_json(&wire).expect("wire spec must re-validate");
        // RunSpec carries closures, so compare through the wire form: a
        // re-imported spec must export byte-identically.
        assert_eq!(back.to_wire_json().to_string_compact(), wire.to_string_compact());
        // The wire text itself parses back to the same value.
        let reparsed = Json::parse(&wire.to_string_compact()).unwrap();
        assert_eq!(
            RunSpec::from_wire_json(&reparsed).unwrap().to_wire_json(),
            wire
        );
        // Defaults export minimally and round-trip too.
        let d = base().to_wire_json();
        assert_eq!(RunSpec::from_wire_json(&d).unwrap().to_wire_json(), d);
    }

    #[test]
    fn wire_json_applies_runtime_and_sim_to_the_config() {
        let spec = RunSpec::from_wire_json(&full_spec().to_wire_json()).unwrap();
        let mut cfg = SystemConfig::new(Task::Det, Policy::ecco());
        for hook in &spec.hooks {
            hook(&mut cfg);
        }
        assert_eq!(cfg.eval_threads, 2);
        assert!(!cfg.frame_cache);
        assert_eq!(cfg.scheduler, Scheduler::EventDriven);
        assert_eq!(
            cfg.coalesce,
            Some(CoalesceOpts::on().window_us(150).max_batch(96))
        );
        assert_eq!(cfg.window_secs, 40.0);
        assert_eq!(cfg.micro_windows, 4);
        assert_eq!(cfg.eval_frames, 8);
        assert_eq!(cfg.pretrain_steps, 120);
    }

    #[test]
    fn wire_json_rejects_with_typed_errors() {
        // RunSpec holds closures (no PartialEq/Debug), so compare errors.
        let parse = |text: &str| RunSpec::from_wire_json(&Json::parse(text).unwrap()).err();
        assert_eq!(
            parse("[1,2]"),
            Some(SpecError::Malformed {
                detail: "spec must be a JSON object".into()
            })
        );
        assert_eq!(
            parse(r#"{"polciy":"ecco"}"#),
            Some(SpecError::UnknownField {
                field: "polciy".into()
            })
        );
        assert_eq!(
            parse(r#"{"policy":"sota"}"#),
            Some(SpecError::UnknownName {
                field: "policy",
                value: "sota".into()
            })
        );
        assert_eq!(
            parse(r#"{"task":"cls"}"#),
            Some(SpecError::UnknownName {
                field: "task",
                value: "cls".into()
            })
        );
        assert_eq!(
            parse(r#"{"runtime":{"scheduler":"fifo"}}"#),
            Some(SpecError::UnknownName {
                field: "runtime.scheduler",
                value: "fifo".into()
            })
        );
        assert_eq!(
            parse(r#"{"sim":{"window_secs":0}}"#),
            Some(SpecError::BadSimOpt {
                field: "window_secs",
                value: 0.0
            })
        );
        assert_eq!(
            parse(r#"{"runtime":{"coalesce":{"enabled":true,"max_batch":0}}}"#),
            Some(SpecError::BadCoalesceOpt {
                field: "max_batch",
                value: 0
            })
        );
        assert_eq!(
            parse(r#"{"runtime":{"coalesce":{"window_us":2000000}}}"#),
            Some(SpecError::BadCoalesceOpt {
                field: "window_us",
                value: 2_000_000
            })
        );
        assert_eq!(
            parse(r#"{"runtime":{"coalesce":{"window":5}}}"#),
            Some(SpecError::UnknownField {
                field: "runtime.coalesce.window".into()
            })
        );
        assert_eq!(
            parse(r#"{"sim":{"micro_windows":0}}"#),
            Some(SpecError::BadSimOpt {
                field: "micro_windows",
                value: 0.0
            })
        );
        assert_eq!(
            parse(r#"{"cameras":{"two":{"phase":1}}}"#),
            Some(SpecError::Malformed {
                detail: "cameras key \"two\" is not a camera index".into()
            })
        );
        assert_eq!(
            parse(r#"{"cameras":{"2":{"jitter":1}}}"#),
            Some(SpecError::UnknownField {
                field: "cameras.2.jitter".into()
            })
        );
        // Wrong types surface as Malformed naming the field.
        for bad in [
            r#"{"windows":"eight"}"#,
            r#"{"seed":-1}"#,
            r#"{"gpus":[1]}"#,
            r#"{"uplinks":[20,"fast"]}"#,
            r#"{"runtime":{"frame_cache":1}}"#,
            r#"{"faults":{"window":0}}"#,
        ] {
            match parse(bad) {
                Some(SpecError::Malformed { .. }) => {}
                other => panic!("{bad} should be Malformed, got {other:?}"),
            }
        }
        // Semantic validation still runs on the imported spec.
        assert_eq!(parse(r#"{"windows":0}"#), Some(SpecError::NoWindows));
        assert_eq!(
            parse(r#"{"cams":3,"uplinks":[10,10]}"#),
            Some(SpecError::UplinkCountMismatch {
                cams: 3,
                uplinks: 2
            })
        );
    }

    #[test]
    fn wire_json_never_panics_on_garbage_values() {
        // Fuzz-ish: drive the parser with structurally valid JSON carrying
        // pseudo-random nonsense; from_wire_json must reject (or accept)
        // without panicking.
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(0x5eed, 42);
        let keys = [
            "task", "policy", "cams", "gpus", "shared_mbps", "windows", "seed",
            "zoo_init_steps", "uplink_mbps", "uplinks", "cameras", "topology_degree",
            "faults", "runtime", "sim", "bogus",
        ];
        for _ in 0..200 {
            let mut fields = Vec::new();
            for _ in 0..rng.index(4) + 1 {
                let key = keys[rng.index(keys.len())];
                let val = match rng.index(5) {
                    0 => Json::Null,
                    1 => Json::Bool(rng.index(2) == 0),
                    2 => num(rng.f64() * 1e6 - 1e3),
                    3 => s("zzz"),
                    _ => arr(vec![num(rng.f64()), Json::Null]),
                };
                fields.push((key, val));
            }
            let _ = RunSpec::from_wire_json(&obj(fields));
        }
    }
}

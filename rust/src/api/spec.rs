//! [`RunSpec`]: the validated description of one system run.
//!
//! A spec is (task, policy) plus the resource envelope (GPUs, shared
//! bottleneck, per-camera uplinks), the horizon in retraining windows, the
//! seed, and the scenario world. [`super::Session::new`] consumes a spec;
//! validation happens before any engine work, so malformed sweeps fail
//! fast with a typed [`SpecError`].
//!
//! Per-camera knobs (uplink, window length, phase) layer onto the fleet
//! defaults through [`RunSpec::camera`] + [`CameraSpec`]; process-level
//! runtime knobs (eval workers, frame cache, scheduler) are grouped in
//! [`RuntimeOpts`] and applied with [`RunSpec::runtime`].

use std::collections::BTreeMap;
use std::fmt;

use crate::faults::FaultPlan;
use crate::runtime::Task;
use crate::scene::scenario::{self, Scenario};
use crate::server::{CamWindow, Policy, Scheduler, SystemConfig};

/// A validation failure in a [`RunSpec`].
#[derive(Debug, Clone, PartialEq)]
pub enum SpecError {
    /// The run must cover at least one retraining window.
    NoWindows,
    /// GPU count must be positive and finite.
    NonPositiveGpus(f64),
    /// The shared bottleneck bandwidth must be positive and finite.
    NonPositiveBandwidth(f64),
    /// A per-camera uplink must be positive and finite.
    NonPositiveUplink { cam: usize, mbps: f64 },
    /// Explicit per-camera uplinks must match the camera count.
    UplinkCountMismatch { cams: usize, uplinks: usize },
    /// The scenario (or default-world camera count) has no cameras.
    NoCameras,
    /// The fault plan targets a camera index the scenario doesn't have.
    FaultCamOutOfRange { cam: usize, cams: usize },
    /// A [`RunSpec::camera`] override targets a camera index the scenario
    /// doesn't have.
    UnknownCamera { cam: usize, cams: usize },
    /// A per-camera window length must be positive and finite.
    ZeroWindowLen { cam: usize, secs: f64 },
    /// A per-camera phase must be finite, non-negative, and strictly less
    /// than the camera's window length (when one is set on the spec).
    PhaseOutOfRange {
        cam: usize,
        phase: f64,
        window_len: Option<f64>,
    },
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::NoWindows => write!(f, "run spec: windows must be >= 1"),
            SpecError::NonPositiveGpus(g) => {
                write!(f, "run spec: gpus must be positive, got {g}")
            }
            SpecError::NonPositiveBandwidth(b) => {
                write!(f, "run spec: shared bandwidth must be positive, got {b} Mbps")
            }
            SpecError::NonPositiveUplink { cam, mbps } => {
                write!(f, "run spec: camera {cam} uplink must be positive, got {mbps} Mbps")
            }
            SpecError::UplinkCountMismatch { cams, uplinks } => write!(
                f,
                "run spec: {uplinks} uplinks for {cams} cameras (counts must match)"
            ),
            SpecError::NoCameras => write!(f, "run spec: scenario has no cameras"),
            SpecError::FaultCamOutOfRange { cam, cams } => write!(
                f,
                "run spec: fault plan targets camera {cam} but the scenario has {cams} cameras"
            ),
            SpecError::UnknownCamera { cam, cams } => write!(
                f,
                "run spec: camera override targets camera {cam} but the scenario has {cams} cameras"
            ),
            SpecError::ZeroWindowLen { cam, secs } => write!(
                f,
                "run spec: camera {cam} window length must be positive, got {secs} s"
            ),
            SpecError::PhaseOutOfRange {
                cam,
                phase,
                window_len,
            } => match window_len {
                Some(len) => write!(
                    f,
                    "run spec: camera {cam} phase {phase} s must lie in [0, {len}) s"
                ),
                None => write!(
                    f,
                    "run spec: camera {cam} phase must be finite and >= 0, got {phase} s"
                ),
            },
        }
    }
}

impl std::error::Error for SpecError {}

/// Per-camera uplink capacities.
enum Uplinks {
    /// Every camera gets the same uplink (Mbit/s).
    Uniform(f64),
    /// Explicit per-camera uplinks; length must match the camera count.
    PerCamera(Vec<f64>),
}

/// Per-camera overrides, built with [`RunSpec::camera`]. Every field is
/// optional: unset fields keep the fleet-wide default (the spec's uplink
/// setting, the global window length, zero phase).
///
/// ```
/// use ecco::api::{CameraSpec, RunSpec};
/// use ecco::runtime::Task;
/// use ecco::server::Policy;
///
/// let spec = RunSpec::new(Task::Det, Policy::ecco())
///     .cams(4)
///     .camera(2, |c: CameraSpec| c.uplink_mbps(8.0).window_len(30.0))
///     .camera(3, |c| c.phase(10.0));
/// assert_eq!(spec.validate(), Ok(()));
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct CameraSpec {
    uplink_mbps: Option<f64>,
    window_len: Option<f64>,
    phase: Option<f64>,
}

impl CameraSpec {
    /// Override this camera's uplink capacity (Mbit/s).
    pub fn uplink_mbps(mut self, mbps: f64) -> Self {
        self.uplink_mbps = Some(mbps);
        self
    }

    /// Give this camera its own retraining-window length (seconds). Any
    /// heterogeneous length forces the event-driven scheduler.
    pub fn window_len(mut self, secs: f64) -> Self {
        self.window_len = Some(secs);
        self
    }

    /// Stagger this camera's window boundaries by `secs` from the server
    /// clock origin; must lie in `[0, window_len)`. Any non-zero phase
    /// forces the event-driven scheduler.
    pub fn phase(mut self, secs: f64) -> Self {
        self.phase = Some(secs);
        self
    }
}

/// Process-level runtime options, applied with [`RunSpec::runtime`].
/// Unset fields keep the [`SystemConfig`] defaults, so `RuntimeOpts::new()`
/// is a no-op.
///
/// ```
/// use ecco::api::{RunSpec, RuntimeOpts};
/// use ecco::runtime::Task;
/// use ecco::server::{Policy, Scheduler};
///
/// let spec = RunSpec::new(Task::Det, Policy::ecco())
///     .runtime(RuntimeOpts::new().threads(4).scheduler(Scheduler::EventDriven));
/// assert_eq!(spec.validate(), Ok(()));
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RuntimeOpts {
    threads: Option<usize>,
    frame_cache: Option<bool>,
    scheduler: Option<Scheduler>,
}

impl RuntimeOpts {
    pub fn new() -> RuntimeOpts {
        RuntimeOpts::default()
    }

    /// Worker threads for the evaluation fan-outs (clamped to >= 1).
    /// Byte-identical at any value; only trades wall-clock for cores.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n.max(1));
        self
    }

    /// Enable/disable the per-window eval-frame render cache (on by
    /// default; byte-identical either way).
    pub fn frame_cache(mut self, enabled: bool) -> Self {
        self.frame_cache = Some(enabled);
        self
    }

    /// Pick the per-window driver. Heterogeneous camera windows force
    /// [`Scheduler::EventDriven`] regardless of this setting.
    pub fn scheduler(mut self, scheduler: Scheduler) -> Self {
        self.scheduler = Some(scheduler);
        self
    }
}

/// Builder for one system run. Defaults mirror the quick-driver CLI:
/// 6 cameras in two correlated triples, 1 GPU, 6 Mbps shared / 20 Mbps
/// uplinks, 8 windows, seed 7.
pub struct RunSpec {
    pub(crate) task: Task,
    pub(crate) policy: Policy,
    pub(crate) cams: usize,
    pub(crate) gpus: f64,
    pub(crate) shared_mbps: f64,
    uplinks: Uplinks,
    /// Per-camera overrides, layered over `uplinks` / the global window.
    cameras: BTreeMap<usize, CameraSpec>,
    /// Prune Alg. 2 candidate scans to each camera's k spatial neighbors.
    topology_degree: Option<usize>,
    pub(crate) windows: usize,
    pub(crate) seed: u64,
    pub(crate) scenario: Option<Scenario>,
    /// Deterministic fault-injection schedule ([`FaultPlan::none`] by
    /// default — guaranteed zero-cost, see [`crate::faults`]).
    faults: FaultPlan,
    /// Zoo-prefill fine-tune steps when the policy warm-starts from a zoo.
    pub(crate) zoo_init_steps: usize,
    /// Config hooks, applied in order after the built-in knobs. `Send +
    /// Sync` so whole specs can be shipped to fleet-driver workers.
    #[allow(clippy::type_complexity)]
    pub(crate) hooks: Vec<Box<dyn Fn(&mut SystemConfig) + Send + Sync>>,
}

impl RunSpec {
    pub fn new(task: Task, policy: Policy) -> RunSpec {
        RunSpec {
            task,
            policy,
            cams: 6,
            gpus: 1.0,
            shared_mbps: 6.0,
            uplinks: Uplinks::Uniform(20.0),
            cameras: BTreeMap::new(),
            topology_degree: None,
            windows: 8,
            seed: 7,
            scenario: None,
            faults: FaultPlan::none(),
            zoo_init_steps: 40,
            hooks: Vec::new(),
        }
    }

    /// Camera count for the default scenario (ignored with an explicit
    /// [`RunSpec::scenario`]).
    pub fn cams(mut self, n: usize) -> Self {
        self.cams = n;
        self
    }

    /// Simulated edge GPUs.
    pub fn gpus(mut self, gpus: f64) -> Self {
        self.gpus = gpus;
        self
    }

    /// Shared bottleneck bandwidth (Mbit/s).
    pub fn shared_mbps(mut self, mbps: f64) -> Self {
        self.shared_mbps = mbps;
        self
    }

    /// One uplink capacity (Mbit/s) for every camera.
    pub fn uplink_mbps(mut self, mbps: f64) -> Self {
        self.uplinks = Uplinks::Uniform(mbps);
        self
    }

    /// Explicit per-camera uplinks (Mbit/s); length must match the camera
    /// count or validation fails. Equivalent to calling
    /// [`RunSpec::camera`] with `uplink_mbps` per index; per-camera
    /// overrides win over this base vector.
    pub fn uplinks(mut self, mbps: Vec<f64>) -> Self {
        self.uplinks = Uplinks::PerCamera(mbps);
        self
    }

    /// Per-camera overrides: fetch (or default) camera `cam`'s
    /// [`CameraSpec`], run it through `f`, and store the result. Repeated
    /// calls for the same camera compose — each sees the accumulated spec.
    pub fn camera(mut self, cam: usize, f: impl FnOnce(CameraSpec) -> CameraSpec) -> Self {
        let entry = self.cameras.get(&cam).copied().unwrap_or_default();
        self.cameras.insert(cam, f(entry));
        self
    }

    /// Prune dynamic grouping's candidate scan (Alg. 2) to each camera's
    /// `degree` nearest spatial neighbors, derived from the scenario's
    /// camera placement. `degree >= n - 1` reproduces the all-pairs scan
    /// exactly; smaller degrees drop the per-request cost from O(n) to
    /// O(degree) with a periodic long-range probe window as the safety
    /// net. Only affects group-retraining policies.
    pub fn topology_degree(mut self, degree: usize) -> Self {
        self.topology_degree = Some(degree);
        self
    }

    /// Horizon in retraining windows.
    pub fn windows(mut self, n: usize) -> Self {
        self.windows = n;
        self
    }

    /// Seed for the scenario, system, and all simulators.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run on an explicit scenario world instead of the default
    /// two-triple static world.
    pub fn scenario(mut self, sc: Scenario) -> Self {
        self.scenario = Some(sc);
        self
    }

    /// Attach a deterministic fault-injection schedule (see
    /// [`crate::faults`]). [`FaultPlan::none`] — the default — is
    /// guaranteed zero-cost: event logs stay byte-identical to a run
    /// without a plan.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = plan;
        self
    }

    /// Override the zoo-prefill fine-tune steps (0 disables the prefill;
    /// only relevant when the policy has `zoo_warm_start`).
    pub fn zoo_init_steps(mut self, steps: usize) -> Self {
        self.zoo_init_steps = steps;
        self
    }

    /// Arbitrary [`SystemConfig`] tweak, applied after the built-in knobs
    /// (gpus/seed); hooks run in registration order.
    pub fn configure<F: Fn(&mut SystemConfig) + Send + Sync + 'static>(mut self, hook: F) -> Self {
        self.hooks.push(Box::new(hook));
        self
    }

    /// Apply a batch of process-level runtime options (threads, frame
    /// cache, scheduler). Only fields explicitly set on `opts` are
    /// applied; like any hook, later calls win over earlier ones.
    pub fn runtime(self, opts: RuntimeOpts) -> Self {
        self.configure(move |cfg| {
            if let Some(n) = opts.threads {
                cfg.eval_threads = n;
            }
            if let Some(cache) = opts.frame_cache {
                cfg.frame_cache = cache;
            }
            if let Some(scheduler) = opts.scheduler {
                cfg.scheduler = scheduler;
            }
        })
    }

    /// Worker threads for the system's evaluation fan-outs (see
    /// `SystemConfig::eval_threads`). Runs are byte-identical at any value;
    /// defaults to the machine's parallelism (`ECCO_THREADS` overrides).
    ///
    /// Deprecated in favor of
    /// [`RunSpec::runtime`]`(RuntimeOpts::new().threads(n))`; kept as a
    /// thin wrapper.
    pub fn eval_threads(self, n: usize) -> Self {
        self.runtime(RuntimeOpts::new().threads(n))
    }

    /// Enable/disable the per-window eval-frame render cache (see
    /// `SystemConfig::frame_cache`; on by default). Runs are byte-identical
    /// either way — disabling only trades wall-clock to verify that claim.
    ///
    /// Deprecated in favor of
    /// [`RunSpec::runtime`]`(RuntimeOpts::new().frame_cache(enabled))`;
    /// kept as a thin wrapper.
    pub fn frame_cache(self, enabled: bool) -> Self {
        self.runtime(RuntimeOpts::new().frame_cache(enabled))
    }

    /// Like [`RunSpec::eval_threads`], but registered *before* every other
    /// hook so an explicit `eval_threads` (or any user hook) still wins.
    /// The fleet driver uses this to divide eval workers by the fleet
    /// concurrency instead of oversubscribing the CPU.
    pub(crate) fn eval_threads_floor(mut self, n: usize) -> Self {
        self.hooks
            .insert(0, Box::new(move |cfg| cfg.eval_threads = n.max(1)));
        self
    }

    /// Camera count this spec will run with.
    pub fn n_cams(&self) -> usize {
        match &self.scenario {
            Some(sc) => sc.world.cameras.len(),
            None => self.cams,
        }
    }

    /// Check the spec without building anything.
    pub fn validate(&self) -> Result<(), SpecError> {
        if self.windows == 0 {
            return Err(SpecError::NoWindows);
        }
        if !(self.gpus.is_finite() && self.gpus > 0.0) {
            return Err(SpecError::NonPositiveGpus(self.gpus));
        }
        if !(self.shared_mbps.is_finite() && self.shared_mbps > 0.0) {
            return Err(SpecError::NonPositiveBandwidth(self.shared_mbps));
        }
        let n = self.n_cams();
        if n == 0 {
            return Err(SpecError::NoCameras);
        }
        if let Uplinks::PerCamera(ups) = &self.uplinks {
            if ups.len() != n {
                return Err(SpecError::UplinkCountMismatch {
                    cams: n,
                    uplinks: ups.len(),
                });
            }
        }
        let check = |cam: usize, mbps: f64| -> Result<(), SpecError> {
            if !(mbps.is_finite() && mbps > 0.0) {
                return Err(SpecError::NonPositiveUplink { cam, mbps });
            }
            Ok(())
        };
        match &self.uplinks {
            Uplinks::Uniform(mbps) => check(0, *mbps)?,
            Uplinks::PerCamera(ups) => {
                for (cam, &mbps) in ups.iter().enumerate() {
                    check(cam, mbps)?;
                }
            }
        }
        if let Some(cam) = self.faults.max_cam() {
            if cam >= n {
                return Err(SpecError::FaultCamOutOfRange { cam, cams: n });
            }
        }
        for (&cam, cspec) in &self.cameras {
            if cam >= n {
                return Err(SpecError::UnknownCamera { cam, cams: n });
            }
            if let Some(mbps) = cspec.uplink_mbps {
                check(cam, mbps)?;
            }
            if let Some(len) = cspec.window_len {
                if !(len.is_finite() && len > 0.0) {
                    return Err(SpecError::ZeroWindowLen { cam, secs: len });
                }
            }
            if let Some(phase) = cspec.phase {
                let bad = !(phase.is_finite() && phase >= 0.0)
                    || cspec.window_len.is_some_and(|len| phase >= len);
                if bad {
                    return Err(SpecError::PhaseOutOfRange {
                        cam,
                        phase,
                        window_len: cspec.window_len,
                    });
                }
            }
        }
        Ok(())
    }

    /// Resolve the scenario (building the default world if none was set)
    /// and the per-camera uplink vector. Call after [`RunSpec::validate`].
    pub(crate) fn into_parts(self) -> (Scenario, Vec<f64>, RunSpecRest) {
        let sc = self.scenario.unwrap_or_else(|| {
            let split = if self.cams < 2 {
                vec![self.cams]
            } else {
                vec![self.cams / 2, self.cams - self.cams / 2]
            };
            scenario::grouped_static(&split, 0.06, 30.0, self.seed)
        });
        let n = sc.world.cameras.len();
        let mut uplinks = match self.uplinks {
            Uplinks::Uniform(mbps) => vec![mbps; n],
            Uplinks::PerCamera(ups) => ups,
        };
        for (&cam, cspec) in &self.cameras {
            if let (Some(mbps), Some(slot)) = (cspec.uplink_mbps, uplinks.get_mut(cam)) {
                *slot = mbps;
            }
        }
        let cam_windows: BTreeMap<usize, CamWindow> = self
            .cameras
            .iter()
            .filter(|(_, c)| c.window_len.is_some() || c.phase.is_some())
            .map(|(&cam, c)| {
                (
                    cam,
                    CamWindow {
                        len_secs: c.window_len,
                        phase_secs: c.phase.unwrap_or(0.0),
                    },
                )
            })
            .collect();
        (
            sc,
            uplinks,
            RunSpecRest {
                task: self.task,
                policy: self.policy,
                gpus: self.gpus,
                shared_mbps: self.shared_mbps,
                windows: self.windows,
                seed: self.seed,
                faults: self.faults,
                zoo_init_steps: self.zoo_init_steps,
                cam_windows,
                topology_degree: self.topology_degree,
                hooks: self.hooks,
            },
        )
    }
}

/// The non-world remainder of a consumed [`RunSpec`].
pub(crate) struct RunSpecRest {
    pub(crate) task: Task,
    pub(crate) policy: Policy,
    pub(crate) gpus: f64,
    pub(crate) shared_mbps: f64,
    pub(crate) windows: usize,
    pub(crate) seed: u64,
    pub(crate) faults: FaultPlan,
    pub(crate) zoo_init_steps: usize,
    pub(crate) cam_windows: BTreeMap<usize, CamWindow>,
    pub(crate) topology_degree: Option<usize>,
    #[allow(clippy::type_complexity)]
    pub(crate) hooks: Vec<Box<dyn Fn(&mut SystemConfig) + Send + Sync>>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> RunSpec {
        RunSpec::new(Task::Det, Policy::ecco())
    }

    #[test]
    fn defaults_validate() {
        assert_eq!(base().validate(), Ok(()));
    }

    #[test]
    fn rejects_zero_windows() {
        assert_eq!(base().windows(0).validate(), Err(SpecError::NoWindows));
    }

    #[test]
    fn rejects_bad_resources() {
        assert_eq!(
            base().gpus(0.0).validate(),
            Err(SpecError::NonPositiveGpus(0.0))
        );
        assert_eq!(
            base().shared_mbps(-1.0).validate(),
            Err(SpecError::NonPositiveBandwidth(-1.0))
        );
        assert_eq!(
            base().uplink_mbps(0.0).validate(),
            Err(SpecError::NonPositiveUplink { cam: 0, mbps: 0.0 })
        );
    }

    #[test]
    fn rejects_mismatched_uplinks() {
        assert_eq!(
            base().cams(3).uplinks(vec![10.0, 10.0]).validate(),
            Err(SpecError::UplinkCountMismatch {
                cams: 3,
                uplinks: 2
            })
        );
        assert_eq!(base().cams(2).uplinks(vec![10.0, 5.0]).validate(), Ok(()));
    }

    #[test]
    fn uplink_count_checked_against_explicit_scenario() {
        let sc = scenario::grouped_static(&[3], 0.06, 10.0, 1);
        let spec = base().scenario(sc).uplinks(vec![20.0; 5]);
        assert_eq!(
            spec.validate(),
            Err(SpecError::UplinkCountMismatch {
                cams: 3,
                uplinks: 5
            })
        );
    }

    #[test]
    fn rejects_zero_cameras() {
        assert_eq!(base().cams(0).validate(), Err(SpecError::NoCameras));
    }

    #[test]
    fn rejects_fault_plan_targeting_missing_camera() {
        use crate::faults::{FaultKind, FaultPlan};
        let plan = FaultPlan::none().at(0, 0, 9, FaultKind::CameraDown);
        assert_eq!(
            base().cams(4).faults(plan.clone()).validate(),
            Err(SpecError::FaultCamOutOfRange { cam: 9, cams: 4 })
        );
        assert_eq!(base().cams(10).faults(plan).validate(), Ok(()));
    }

    #[test]
    fn camera_overrides_validate_with_typed_errors() {
        // Index past the fleet.
        assert_eq!(
            base().cams(4).camera(9, |c| c.uplink_mbps(5.0)).validate(),
            Err(SpecError::UnknownCamera { cam: 9, cams: 4 })
        );
        // Bad uplink override reuses the uplink error.
        assert_eq!(
            base().camera(1, |c| c.uplink_mbps(0.0)).validate(),
            Err(SpecError::NonPositiveUplink { cam: 1, mbps: 0.0 })
        );
        // Zero / non-finite window length.
        assert_eq!(
            base().camera(0, |c| c.window_len(0.0)).validate(),
            Err(SpecError::ZeroWindowLen { cam: 0, secs: 0.0 })
        );
        // Phase at/after the camera's own window boundary.
        assert_eq!(
            base().camera(2, |c| c.window_len(30.0).phase(30.0)).validate(),
            Err(SpecError::PhaseOutOfRange {
                cam: 2,
                phase: 30.0,
                window_len: Some(30.0)
            })
        );
        // Negative phase fails even without a window-length override.
        assert_eq!(
            base().camera(2, |c| c.phase(-1.0)).validate(),
            Err(SpecError::PhaseOutOfRange {
                cam: 2,
                phase: -1.0,
                window_len: None
            })
        );
        // A well-formed heterogeneous fleet passes.
        assert_eq!(
            base()
                .camera(0, |c| c.window_len(30.0).phase(10.0))
                .camera(5, |c| c.uplink_mbps(4.0))
                .validate(),
            Ok(())
        );
    }

    #[test]
    fn camera_calls_compose_and_layer_over_uplink_vector() {
        let spec = base()
            .cams(3)
            .uplinks(vec![10.0, 11.0, 12.0])
            .camera(1, |c| c.uplink_mbps(99.0))
            .camera(1, |c| c.window_len(30.0)); // must keep the uplink
        assert_eq!(spec.validate(), Ok(()));
        let (_, uplinks, rest) = spec.into_parts();
        assert_eq!(uplinks, vec![10.0, 99.0, 12.0]);
        let cw = rest.cam_windows.get(&1).copied().unwrap();
        assert_eq!(cw.len_secs, Some(30.0));
        assert_eq!(cw.phase_secs, 0.0);
        // Uplink-only overrides don't create window entries.
        let (_, _, rest2) = base().camera(0, |c| c.uplink_mbps(5.0)).into_parts();
        assert!(rest2.cam_windows.is_empty());
    }

    #[test]
    fn runtime_opts_unset_fields_are_no_ops() {
        let mut cfg = SystemConfig::new(Task::Det, Policy::ecco());
        let baseline = (cfg.eval_threads, cfg.frame_cache, cfg.scheduler);
        let spec = base().runtime(RuntimeOpts::new());
        for hook in &spec.hooks {
            hook(&mut cfg);
        }
        assert_eq!((cfg.eval_threads, cfg.frame_cache, cfg.scheduler), baseline);
        let spec = base().runtime(
            RuntimeOpts::new()
                .threads(0)
                .frame_cache(false)
                .scheduler(Scheduler::EventDriven),
        );
        for hook in &spec.hooks {
            hook(&mut cfg);
        }
        assert_eq!(cfg.eval_threads, 1, "threads clamp to >= 1");
        assert!(!cfg.frame_cache);
        assert_eq!(cfg.scheduler, Scheduler::EventDriven);
    }

    #[test]
    fn errors_display_readably() {
        let msg = SpecError::UplinkCountMismatch {
            cams: 4,
            uplinks: 2,
        }
        .to_string();
        assert!(msg.contains("4 cameras") || msg.contains("2 uplinks"), "{msg}");
    }
}

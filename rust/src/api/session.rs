//! [`Session`]: a running system behind a typed handle.
//!
//! `Session::new(engine, spec)` validates the spec, builds the world and
//! the [`System`], and wires the event stream. Drivers then either call
//! [`Session::run`] for the whole horizon or [`Session::step_window`] in a
//! loop (scripted experiments interleave [`Session::request_now`] /
//! [`Session::force_group`] calls between windows). All observation goes
//! through [`WindowReport`] / [`RunReport`] / the event stream — `System`
//! internals are `pub(crate)` and no longer reachable from drivers.

use anyhow::Result;

use crate::alloc::Allocator;
use crate::api::event::{self, Event, EventSink};
use crate::api::report::{Resilience, RunReport, WindowReport};
use crate::api::spec::RunSpec;
use crate::grouping::topology::Topology;
use crate::net::trace::Traces;
use crate::runtime::{Engine, EngineStats};
use crate::server::system::{MembershipSnapshot, System};
use crate::server::SystemConfig;

/// A live run: owns the [`System`] and the engine borrow for its lifetime.
pub struct Session<'e> {
    sys: System<'e>,
    name: String,
    windows: usize,
    stepped: usize,
    t0: std::time::Instant,
    /// Engine stats at session creation; [`Session::into_report`] reports
    /// the delta so per-run infer request/launch counts survive engine
    /// sharing (sessions interleaved on one engine each see engine-wide
    /// activity during their lifetime — a perf observation, not part of
    /// the deterministic result surface, like `wall_secs`).
    stats0: EngineStats,
}

impl<'e> Session<'e> {
    /// Validate `spec` and assemble the system (pretraining the deployment
    /// student, prefilling the model zoo for zoo-warm-start policies).
    ///
    /// The engine borrow is shared: engines are `Sync` (immutable manifest
    /// + atomic stats), so any number of sessions — including concurrent
    /// ones driven by [`run_fleet`] — can share one engine. Call sites
    /// holding `&mut Engine` coerce without change.
    pub fn new(engine: &'e Engine, spec: RunSpec) -> Result<Session<'e>> {
        spec.validate()?;
        let (sc, uplinks, rest) = spec.into_parts();
        let mut cfg = SystemConfig::new(rest.task, rest.policy);
        cfg.gpus = rest.gpus;
        cfg.seed = rest.seed;
        cfg.faults = rest.faults;
        cfg.cam_windows = rest.cam_windows;
        for hook in &rest.hooks {
            hook(&mut cfg);
        }
        // Derive the spatial pruning graph from the scenario's camera
        // placement, unless a hook installed an explicit topology.
        if let Some(degree) = rest.topology_degree {
            if cfg.policy.group_retraining && cfg.grouping.topology.is_none() {
                let positions: Vec<(f32, f32)> = sc.world.cameras.iter().map(|c| c.pos).collect();
                cfg.grouping.topology = Some(Topology::from_positions(&positions, degree));
            }
        }
        let name = cfg.policy.name.to_string();
        let zoo_prefill = cfg.policy.zoo_warm_start && rest.zoo_init_steps > 0;
        // Apply the spec's micro-batch coalescing knobs to the shared
        // engine (engine-wide, last writer wins; results are bit-identical
        // either way — see `runtime::microbatch`).
        if let Some(coalesce) = cfg.coalesce {
            engine.set_coalesce(coalesce);
        }
        let mut sys = System::new(cfg, sc.world, &uplinks, rest.shared_mbps, engine)?;
        if zoo_prefill {
            sys.populate_zoo_from_initial(rest.zoo_init_steps)?;
        }
        let stats0 = engine.stats();
        Ok(Session {
            sys,
            name,
            windows: rest.windows,
            stepped: 0,
            // ecco-lint: allow(D003) wall-clock start for the wall_secs
            // perf counter only; never reaches events or accuracies.
            t0: std::time::Instant::now(),
            stats0,
        })
    }

    /// Attach an additional [`EventSink`] (e.g. a
    /// [`JsonlSink`](crate::api::event::JsonlSink)); the built-in recorder
    /// keeps running regardless.
    pub fn add_sink(&mut self, sink: Box<dyn EventSink>) {
        self.sys.events.sinks.push(sink);
    }

    /// Run one retraining window and report what it produced.
    pub fn step_window(&mut self) -> Result<WindowReport> {
        let events_before = self.sys.events.record.events.len();
        self.sys.run_window()?;
        let window = self.stepped;
        self.stepped += 1;
        let allocs = event::alloc_triples(&self.sys.events.record.events[events_before..]);
        Ok(WindowReport {
            window,
            time: self.sys.now(),
            jobs: self.sys.jobs.len(),
            mean_acc: self.sys.mean_accuracy(),
            cam_acc: self.camera_accuracies(),
            membership: self.membership(),
            allocs,
        })
    }

    /// Run any remaining windows of the planned horizon and aggregate the
    /// full report.
    pub fn run(mut self) -> Result<RunReport> {
        while self.stepped < self.windows {
            self.step_window()?;
        }
        Ok(self.into_report())
    }

    /// Aggregate whatever has run so far into a [`RunReport`] (used by
    /// step-driven experiments; [`Session::run`] completes the horizon
    /// first).
    pub fn into_report(self) -> RunReport {
        let horizon = self.sys.now();
        let st = self.sys.engine.stats();
        let record = &self.sys.events.record;
        let cam_acc: Vec<Vec<f32>> = self
            .sys
            .history
            .series
            .iter()
            .map(|series| series.iter().map(|&(_, a)| a).collect())
            .collect();
        RunReport {
            name: self.name.clone(),
            window_acc: record.window_acc(),
            cam_acc,
            steady: self.sys.history.steady_mean(0.4),
            final_acc: self.sys.mean_accuracy(),
            response_s: self.sys.tracker.mean_response(horizon),
            satisfied: self.sys.tracker.satisfied(),
            requests: self.sys.tracker.total(),
            jobs: self.sys.jobs.len(),
            alloc_log: record.alloc_log(),
            membership: record.membership_log(),
            events: record.events.clone(),
            resilience: resilience_of(&self.sys),
            wall_secs: self.t0.elapsed().as_secs_f64(),
            infer_requests: st.infer_requests.saturating_sub(self.stats0.infer_requests),
            infer_calls: st.infer_calls.saturating_sub(self.stats0.infer_calls),
        }
    }

    // ------------------------------------------------------------------
    // Scripted control (Figs. 8, 10, 11, 12 and the ablations)
    // ------------------------------------------------------------------

    /// Scripted retraining request from `cam` (requires
    /// `auto_request = false` setups to do anything interesting).
    pub fn request_now(&mut self, cam: usize) -> Result<()> {
        self.sys.request_now(cam)
    }

    /// Create a job with fixed membership, bypassing Alg. 2; returns the
    /// job id.
    pub fn force_group(&mut self, cams: &[usize]) -> Result<usize> {
        self.sys.force_group(cams)
    }

    /// Swap the GPU allocator (ablation experiments).
    pub fn set_allocator(&mut self, allocator: Box<dyn Allocator>) {
        self.sys.set_allocator(allocator);
    }

    /// Start recording per-flow bandwidth traces at `sample_dt` seconds.
    pub fn record_net(&mut self, sample_dt: f64) {
        self.sys.net.record(sample_dt);
    }

    /// Stop recording and take the collected bandwidth traces.
    pub fn take_net_traces(&mut self) -> Option<Traces> {
        self.sys.net.take_traces()
    }

    // ------------------------------------------------------------------
    // Observation
    // ------------------------------------------------------------------

    /// Simulated time (seconds).
    pub fn now(&self) -> f64 {
        self.sys.now()
    }

    /// Windows stepped so far.
    pub fn windows_run(&self) -> usize {
        self.stepped
    }

    /// Mean camera accuracy at the latest window.
    pub fn mean_accuracy(&self) -> f32 {
        self.sys.mean_accuracy()
    }

    /// Steady-state mean accuracy over the last `frac` of windows.
    pub fn steady_mean(&self, frac: f64) -> f32 {
        self.sys.history.steady_mean(frac)
    }

    /// Live accuracy of one camera (as of the last window boundary).
    pub fn camera_accuracy(&self, cam: usize) -> f32 {
        self.sys.cams[cam].last_acc
    }

    /// Live accuracy of every camera.
    pub fn camera_accuracies(&self) -> Vec<f32> {
        self.sys.cams.iter().map(|c| c.last_acc).collect()
    }

    /// Number of active retraining jobs.
    pub fn jobs(&self) -> usize {
        self.sys.jobs.len()
    }

    /// Current group membership: (job id, member cameras).
    pub fn membership(&self) -> MembershipSnapshot {
        self.sys
            .jobs
            .iter()
            .map(|j| (j.id, j.members.clone()))
            .collect()
    }

    /// Whether the grouping bookkeeping is a valid partition (each camera
    /// in at most one job) — an invariant check for tests.
    pub fn is_partition(&self) -> bool {
        crate::grouping::is_partition(&self.sys.group_meta)
    }

    /// Last window's GPU-share estimate per active job, in job order;
    /// jobs with no estimate yet get the uniform share.
    pub fn job_shares(&self) -> Vec<(usize, f64)> {
        let n = self.sys.jobs.len().max(1);
        self.sys
            .jobs
            .iter()
            .map(|j| {
                (
                    j.id,
                    self.sys
                        .shares
                        .get(&j.id)
                        .copied()
                        .unwrap_or(1.0 / n as f64),
                )
            })
            .collect()
    }

    /// Retraining requests issued so far.
    pub fn requests_total(&self) -> usize {
        self.sys.tracker.total()
    }

    /// Requests whose camera re-crossed the accuracy threshold.
    pub fn requests_satisfied(&self) -> usize {
        self.sys.tracker.satisfied()
    }

    /// Mean response time with unresolved requests counted at the current
    /// horizon.
    pub fn mean_response(&self) -> f64 {
        self.sys.tracker.mean_response(self.sys.now())
    }

    /// Frames the teacher has annotated.
    pub fn teacher_annotated(&self) -> u64 {
        self.sys.teacher.annotated
    }

    /// Model-zoo entry count (RECL-style policies).
    pub fn zoo_len(&self) -> usize {
        self.sys.zoo.len()
    }

    /// Snapshot of the engine's execution statistics.
    pub fn engine_stats(&self) -> EngineStats {
        self.sys.engine.stats()
    }

    /// Resilience metrics accumulated so far (all-zero without a fault
    /// plan, or before any fault has fired).
    pub fn resilience(&self) -> Resilience {
        resilience_of(&self.sys)
    }

    /// Events recorded so far (the built-in recorder's stream).
    pub fn events(&self) -> &[Event] {
        &self.sys.events.record.events
    }

    /// `(window, micro_window, job)` GPU grants recorded so far.
    pub fn alloc_log(&self) -> Vec<(usize, usize, usize)> {
        self.sys.events.record.alloc_log()
    }
}

/// Aggregate the system's fault counters into report-ready metrics.
fn resilience_of(sys: &System<'_>) -> Resilience {
    let (fault_windows, acc_sum, recoveries) = sys.fault_summary();
    Resilience {
        fault_windows,
        acc_under_fault: if fault_windows > 0 {
            (acc_sum / fault_windows as f64) as f32
        } else {
            0.0
        },
        recoveries: recoveries.len(),
        windows_to_recover: if recoveries.is_empty() {
            0.0
        } else {
            recoveries.iter().sum::<usize>() as f64 / recoveries.len() as f64
        },
    }
}

/// Run a batch of independent specs to completion over **one shared
/// engine**, up to `threads` runs in flight at a time.
///
/// Each run owns its own `System` (world, network, RNG streams, event
/// recorder), so runs never interact; the engine is the only shared state
/// and is `Sync` by construction. Reports come back **in spec order**
/// regardless of which run finishes first, and each report is identical to
/// what a sequential `Session::new(engine, spec)?.run()` would have
/// produced — policy arms and scenario sweeps parallelize without
/// renumbering or reseeding anything.
///
/// On error the lowest-index failure is returned (deterministic, like the
/// sequential loop's first error). Engine stats aggregate across all runs,
/// as they do for sequential runs sharing an engine.
///
/// To avoid oversubscribing the CPU (fleet workers x per-run eval workers),
/// each spec's `eval_threads` default is divided by the fleet concurrency;
/// an explicit [`RunSpec::eval_threads`] on a spec still wins. Determinism
/// is unaffected either way.
pub fn run_fleet(engine: &Engine, specs: Vec<RunSpec>, threads: usize) -> Result<Vec<RunReport>> {
    let per_run = crate::util::pool::per_run_threads(threads, specs.len());
    let specs: Vec<RunSpec> = specs
        .into_iter()
        .map(|s| s.eval_threads_floor(per_run))
        .collect();
    engine
        .pool()
        .map_owned(threads, specs, |_, spec| Session::new(engine, spec)?.run())
        .into_iter()
        .collect()
}

//! The typed event stream: every observable state change the system makes
//! during a run, delivered to pluggable [`EventSink`]s.
//!
//! This replaces field scraping (`sys.alloc_log`, `sys.membership_log`,
//! `sys.cams[i].last_acc`, …) as the observation surface: the [`System`]
//! loop emits an [`Event`] at each decision point, a [`RecordingSink`] is
//! always attached so [`super::Session`] can rebuild reports and the
//! legacy log shapes, and a [`JsonlSink`] streams the same events to disk
//! for `scripts/render_results.py`-style offline analysis.
//!
//! [`System`]: crate::server::system::System

use std::io::Write;

use crate::server::system::MembershipSnapshot;
use crate::util::json::{arr, num, obj, s, Json};

/// One observable state change during a run.
///
/// `window` is the retraining-window index the event occurred in; `time`
/// is simulated seconds.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A camera issued a retraining request (drift detected, scripted, or
    /// an Alg. 2 eviction re-entering the pipeline).
    RetrainRequest {
        time: f64,
        window: usize,
        cam: usize,
        /// The camera's own-model accuracy on the request probe.
        acc: f32,
    },
    /// A new retraining job was created with `cam` as its first member.
    GroupFormed {
        time: f64,
        window: usize,
        job: usize,
        cam: usize,
    },
    /// A camera's request was merged into an existing job (Alg. 2).
    GroupJoined {
        time: f64,
        window: usize,
        job: usize,
        cam: usize,
    },
    /// A camera was evicted from its job at a regrouping boundary.
    GroupSplit {
        time: f64,
        window: usize,
        job: usize,
        cam: usize,
    },
    /// Alg. 1 granted a micro-window's GPUs to `job` (Fig. 10's one-hot
    /// bars are exactly this stream).
    Alloc {
        window: usize,
        micro_window: usize,
        job: usize,
    },
    /// A job's retrained model was pushed to its member devices.
    ModelPublished {
        time: f64,
        window: usize,
        job: usize,
        cams: Vec<usize>,
    },
    /// A retraining window finished: per-camera live accuracy and the
    /// pre-regroup membership snapshot (Fig. 9's grouping bars).
    WindowClosed {
        time: f64,
        window: usize,
        mean_acc: f32,
        cam_acc: Vec<f32>,
        membership: MembershipSnapshot,
    },
    /// An injected fault took the camera offline (see [`crate::faults`]).
    CameraDown { time: f64, window: usize, cam: usize },
    /// The camera rejoined the fleet after a dropout; it re-enters
    /// placement through the normal drift-probe path.
    CameraUp { time: f64, window: usize, cam: usize },
    /// The camera's uplink was degraded to `factor` of its healthy
    /// capacity (`0.0` = full outage).
    LinkDegraded {
        time: f64,
        window: usize,
        cam: usize,
        factor: f64,
    },
    /// A fault cleared: `kind` is `"camera"` (accuracy re-crossed the
    /// response threshold after a dropout) or `"uplink"` (capacity
    /// restored); `windows` is the retraining windows from onset to
    /// recovery.
    FaultRecovered {
        time: f64,
        window: usize,
        cam: usize,
        kind: &'static str,
        windows: usize,
    },
    /// The system degraded gracefully instead of failing: a discarded
    /// corrupt probe, a deferred model publish, a detached stale
    /// assignment, a skipped micro-window. `component` names the layer,
    /// `detail` is human-readable.
    Degraded {
        time: f64,
        window: usize,
        component: &'static str,
        detail: String,
    },
}

impl Event {
    /// Stable machine-readable event name.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::RetrainRequest { .. } => "retrain_request",
            Event::GroupFormed { .. } => "group_formed",
            Event::GroupJoined { .. } => "group_joined",
            Event::GroupSplit { .. } => "group_split",
            Event::Alloc { .. } => "alloc",
            Event::ModelPublished { .. } => "model_published",
            Event::WindowClosed { .. } => "window_closed",
            Event::CameraDown { .. } => "camera_down",
            Event::CameraUp { .. } => "camera_up",
            Event::LinkDegraded { .. } => "link_degraded",
            Event::FaultRecovered { .. } => "fault_recovered",
            Event::Degraded { .. } => "degraded",
        }
    }

    /// The window index the event belongs to.
    pub fn window(&self) -> usize {
        match self {
            Event::RetrainRequest { window, .. }
            | Event::GroupFormed { window, .. }
            | Event::GroupJoined { window, .. }
            | Event::GroupSplit { window, .. }
            | Event::Alloc { window, .. }
            | Event::ModelPublished { window, .. }
            | Event::WindowClosed { window, .. }
            | Event::CameraDown { window, .. }
            | Event::CameraUp { window, .. }
            | Event::LinkDegraded { window, .. }
            | Event::FaultRecovered { window, .. }
            | Event::Degraded { window, .. } => *window,
        }
    }

    /// JSON representation (one object per event; `type` discriminates).
    pub fn to_json(&self) -> Json {
        let membership_json = |m: &MembershipSnapshot| {
            arr(m
                .iter()
                .map(|(job, members)| {
                    obj(vec![
                        ("job", num(*job as f64)),
                        (
                            "members",
                            arr(members.iter().map(|&c| num(c as f64)).collect()),
                        ),
                    ])
                })
                .collect())
        };
        match self {
            Event::RetrainRequest {
                time,
                window,
                cam,
                acc,
            } => obj(vec![
                ("type", s(self.kind())),
                ("time", num(*time)),
                ("window", num(*window as f64)),
                ("cam", num(*cam as f64)),
                ("acc", num(*acc as f64)),
            ]),
            Event::GroupFormed {
                time,
                window,
                job,
                cam,
            }
            | Event::GroupJoined {
                time,
                window,
                job,
                cam,
            }
            | Event::GroupSplit {
                time,
                window,
                job,
                cam,
            } => obj(vec![
                ("type", s(self.kind())),
                ("time", num(*time)),
                ("window", num(*window as f64)),
                ("job", num(*job as f64)),
                ("cam", num(*cam as f64)),
            ]),
            Event::Alloc {
                window,
                micro_window,
                job,
            } => obj(vec![
                ("type", s(self.kind())),
                ("window", num(*window as f64)),
                ("micro_window", num(*micro_window as f64)),
                ("job", num(*job as f64)),
            ]),
            Event::ModelPublished {
                time,
                window,
                job,
                cams,
            } => obj(vec![
                ("type", s(self.kind())),
                ("time", num(*time)),
                ("window", num(*window as f64)),
                ("job", num(*job as f64)),
                ("cams", arr(cams.iter().map(|&c| num(c as f64)).collect())),
            ]),
            Event::WindowClosed {
                time,
                window,
                mean_acc,
                cam_acc,
                membership,
            } => obj(vec![
                ("type", s(self.kind())),
                ("time", num(*time)),
                ("window", num(*window as f64)),
                ("mean_acc", num(*mean_acc as f64)),
                (
                    "cam_acc",
                    arr(cam_acc.iter().map(|&a| num(a as f64)).collect()),
                ),
                ("membership", membership_json(membership)),
            ]),
            Event::CameraDown { time, window, cam }
            | Event::CameraUp { time, window, cam } => obj(vec![
                ("type", s(self.kind())),
                ("time", num(*time)),
                ("window", num(*window as f64)),
                ("cam", num(*cam as f64)),
            ]),
            Event::LinkDegraded {
                time,
                window,
                cam,
                factor,
            } => obj(vec![
                ("type", s(self.kind())),
                ("time", num(*time)),
                ("window", num(*window as f64)),
                ("cam", num(*cam as f64)),
                ("factor", num(*factor)),
            ]),
            Event::FaultRecovered {
                time,
                window,
                cam,
                kind,
                windows,
            } => obj(vec![
                ("type", s(self.kind())),
                ("time", num(*time)),
                ("window", num(*window as f64)),
                ("cam", num(*cam as f64)),
                ("kind", s(kind)),
                ("windows", num(*windows as f64)),
            ]),
            Event::Degraded {
                time,
                window,
                component,
                detail,
            } => obj(vec![
                ("type", s(self.kind())),
                ("time", num(*time)),
                ("window", num(*window as f64)),
                ("component", s(component)),
                ("detail", s(detail)),
            ]),
        }
    }
}

/// Extract `(window, micro_window, job)` GPU-grant triples from a slice of
/// events (the old `alloc_log` shape). Shared by [`RecordingSink`] and the
/// per-window report assembly so the two can never drift.
pub fn alloc_triples(events: &[Event]) -> Vec<(usize, usize, usize)> {
    events
        .iter()
        .filter_map(|e| match e {
            Event::Alloc {
                window,
                micro_window,
                job,
            } => Some((*window, *micro_window, *job)),
            _ => None,
        })
        .collect()
}

/// A consumer of the event stream. Sinks must not assume any buffering:
/// events arrive in emission order, during the run.
pub trait EventSink {
    fn on_event(&mut self, event: &Event);
}

/// Accumulates the full event stream in memory; reconstructs the legacy
/// log shapes the experiment runners used to scrape off `System`.
#[derive(Debug, Clone, Default)]
pub struct RecordingSink {
    pub events: Vec<Event>,
}

impl RecordingSink {
    pub fn new() -> RecordingSink {
        RecordingSink::default()
    }

    /// `(window, micro_window, job)` triples — the old `sys.alloc_log`.
    pub fn alloc_log(&self) -> Vec<(usize, usize, usize)> {
        alloc_triples(&self.events)
    }

    /// Per-window membership snapshots — the old `sys.membership_log`.
    pub fn membership_log(&self) -> Vec<(usize, MembershipSnapshot)> {
        self.events
            .iter()
            .filter_map(|e| match e {
                Event::WindowClosed {
                    window, membership, ..
                } => Some((*window, membership.clone())),
                _ => None,
            })
            .collect()
    }

    /// Mean camera accuracy per closed window.
    pub fn window_acc(&self) -> Vec<f32> {
        self.events
            .iter()
            .filter_map(|e| match e {
                Event::WindowClosed { mean_acc, .. } => Some(*mean_acc),
                _ => None,
            })
            .collect()
    }
}

impl EventSink for RecordingSink {
    fn on_event(&mut self, event: &Event) {
        self.events.push(event.clone());
    }
}

/// Streams events as JSON Lines to any writer (a file for offline
/// analysis, a buffer for tests, a serve subscriber). Flushes on drop.
///
/// A write failure never kills the run: the line is dropped, a counter is
/// bumped, and one summary warning is logged when the sink closes
/// ([`JsonlSink::into_inner`] or drop) — not one warning per event.
pub struct JsonlSink<W: Write> {
    out: Option<W>,
    /// Event lines dropped on write errors, reported once at close.
    write_errors: u64,
}

impl JsonlSink<std::io::BufWriter<std::fs::File>> {
    /// Create (truncate) a `.jsonl` file sink at `path`.
    pub fn create(path: &str) -> anyhow::Result<Self> {
        let file = std::fs::File::create(path)?;
        Ok(JsonlSink::new(std::io::BufWriter::new(file)))
    }
}

impl<W: Write> JsonlSink<W> {
    pub fn new(out: W) -> Self {
        JsonlSink {
            out: Some(out),
            write_errors: 0,
        }
    }

    /// Flush and hand back the underlying writer, reporting (once) any
    /// write errors accumulated during the run.
    pub fn into_inner(mut self) -> W {
        let mut out = self.out.take().expect("writer present until into_inner");
        let flush_err = out.flush().err();
        self.report_errors(flush_err);
        out
    }

    /// Event lines dropped on write errors so far (0 on a healthy sink).
    pub fn write_errors(&self) -> u64 {
        self.write_errors
    }

    fn report_errors(&mut self, flush_err: Option<std::io::Error>) {
        if self.write_errors > 0 || flush_err.is_some() {
            let flush_note = match &flush_err {
                Some(e) => format!("; final flush failed: {e}"),
                None => String::new(),
            };
            crate::util::logger::log(
                crate::util::logger::Level::Warn,
                module_path!(),
                &format!(
                    "jsonl event sink dropped {} line(s) on write errors{flush_note}",
                    self.write_errors
                ),
            );
        }
        self.write_errors = 0;
    }
}

impl<W: Write> EventSink for JsonlSink<W> {
    fn on_event(&mut self, event: &Event) {
        // A sink write failure must not kill the simulation; count the
        // dropped line and report once at close.
        if let Some(out) = &mut self.out {
            if writeln!(out, "{}", event.to_json().to_string_compact()).is_err() {
                self.write_errors += 1;
            }
        }
    }
}

impl<W: Write> Drop for JsonlSink<W> {
    fn drop(&mut self) {
        let flush_err = self.out.as_mut().and_then(|out| out.flush().err());
        self.report_errors(flush_err);
    }
}

/// The system-side fan-out point: an always-on [`RecordingSink`] (reports
/// are built from it) plus any user-attached sinks.
#[derive(Default)]
pub(crate) struct EventBus {
    pub(crate) record: RecordingSink,
    pub(crate) sinks: Vec<Box<dyn EventSink>>,
}

impl EventBus {
    pub(crate) fn new() -> EventBus {
        EventBus::default()
    }

    pub(crate) fn emit(&mut self, event: Event) {
        for sink in &mut self.sinks {
            sink.on_event(&event);
        }
        self.record.on_event(&event);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<Event> {
        vec![
            Event::RetrainRequest {
                time: 1.0,
                window: 0,
                cam: 2,
                acc: 0.12,
            },
            Event::GroupFormed {
                time: 1.0,
                window: 0,
                job: 0,
                cam: 2,
            },
            Event::Alloc {
                window: 0,
                micro_window: 3,
                job: 0,
            },
            Event::WindowClosed {
                time: 60.0,
                window: 0,
                mean_acc: 0.4,
                cam_acc: vec![0.4, 0.4],
                membership: vec![(0, vec![2])],
            },
        ]
    }

    #[test]
    fn recording_sink_rebuilds_logs() {
        let mut sink = RecordingSink::new();
        for e in sample_events() {
            sink.on_event(&e);
        }
        assert_eq!(sink.alloc_log(), vec![(0, 3, 0)]);
        assert_eq!(sink.membership_log(), vec![(0, vec![(0, vec![2])])]);
        assert_eq!(sink.window_acc(), vec![0.4]);
        assert_eq!(sink.events.len(), 4);
    }

    #[test]
    fn jsonl_sink_emits_parseable_lines() {
        let mut sink = JsonlSink::new(Vec::new());
        for e in sample_events() {
            sink.on_event(&e);
        }
        let buf = sink.into_inner();
        let text = String::from_utf8(buf).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        for line in &lines {
            let j = Json::parse(line).unwrap();
            assert!(j.get("type").unwrap().as_str().is_ok());
        }
        assert!(lines[0].contains("retrain_request"));
        assert!(lines[3].contains("window_closed"));
    }

    /// Fails every write/flush, like a full disk or a closed pipe.
    struct FailingWriter;

    impl Write for FailingWriter {
        fn write(&mut self, _buf: &[u8]) -> std::io::Result<usize> {
            Err(std::io::Error::other("disk full"))
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Err(std::io::Error::other("disk full"))
        }
    }

    #[test]
    fn jsonl_sink_survives_a_failing_writer() {
        let mut sink = JsonlSink::new(FailingWriter);
        for e in sample_events() {
            sink.on_event(&e);
        }
        assert_eq!(sink.write_errors(), 4, "every line dropped, none panicked");
        // Drop flushes (which also fails) and reports once; must not panic.
        drop(sink);
        // into_inner on a failing writer must not panic either.
        let mut sink = JsonlSink::new(FailingWriter);
        sink.on_event(&sample_events()[0]);
        let _writer = sink.into_inner();
    }

    #[test]
    fn jsonl_sink_healthy_writer_reports_zero_errors() {
        let mut sink = JsonlSink::new(Vec::new());
        for e in sample_events() {
            sink.on_event(&e);
        }
        assert_eq!(sink.write_errors(), 0);
    }

    #[test]
    fn event_window_accessor() {
        for e in sample_events() {
            assert_eq!(e.window(), 0);
        }
    }

    #[test]
    fn fault_events_serialize_with_discriminants() {
        let events = vec![
            Event::CameraDown {
                time: 10.0,
                window: 1,
                cam: 3,
            },
            Event::CameraUp {
                time: 50.0,
                window: 2,
                cam: 3,
            },
            Event::LinkDegraded {
                time: 12.0,
                window: 1,
                cam: 0,
                factor: 0.5,
            },
            Event::FaultRecovered {
                time: 90.0,
                window: 3,
                cam: 3,
                kind: "camera",
                windows: 2,
            },
            Event::Degraded {
                time: 14.0,
                window: 1,
                component: "probe",
                detail: "cam 2: corrupt probe embedding discarded".into(),
            },
        ];
        let kinds: Vec<&str> = events.iter().map(|e| e.kind()).collect();
        assert_eq!(
            kinds,
            vec![
                "camera_down",
                "camera_up",
                "link_degraded",
                "fault_recovered",
                "degraded"
            ]
        );
        for e in &events {
            let j = Json::parse(&e.to_json().to_string_compact()).unwrap();
            assert_eq!(j.get("type").unwrap().as_str().unwrap(), e.kind());
            assert_eq!(
                j.get("window").unwrap().as_f64().unwrap() as usize,
                e.window()
            );
        }
        assert_eq!(events[3].window(), 3);
    }
}

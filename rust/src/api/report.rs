//! Typed run results: [`WindowReport`] per stepped window, [`RunReport`]
//! for a whole run. Both are rebuilt from the event stream plus the
//! session's trackers — no field scraping.

use crate::api::event::Event;
use crate::server::system::MembershipSnapshot;
use crate::util::json::{arr, f32s, num, obj, s, Json};

/// What one retraining window produced.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowReport {
    /// Zero-based window index.
    pub window: usize,
    /// Simulated time at the window boundary (seconds).
    pub time: f64,
    /// Active retraining jobs after the window (post-regroup).
    pub jobs: usize,
    /// Mean live-model accuracy across cameras.
    pub mean_acc: f32,
    /// Per-camera live-model accuracy.
    pub cam_acc: Vec<f32>,
    /// Post-window group membership: (job id, member cameras).
    pub membership: MembershipSnapshot,
    /// `(window, micro_window, job)` GPU grants made during this window.
    pub allocs: Vec<(usize, usize, usize)>,
}

/// Aggregate results of a full run (the JSON shape matches what the
/// experiment runners have always written to `results/*.json`).
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Policy name (report label).
    pub name: String,
    /// Mean accuracy per window (over cameras).
    pub window_acc: Vec<f32>,
    /// Per-camera accuracy series: `cam_acc[cam][window]`.
    pub cam_acc: Vec<Vec<f32>>,
    /// Steady-state mean accuracy (last 40% of windows).
    pub steady: f32,
    pub final_acc: f32,
    /// Mean response time (seconds; unresolved counted at horizon).
    pub response_s: f64,
    pub satisfied: usize,
    pub requests: usize,
    /// Final number of retraining jobs.
    pub jobs: usize,
    /// `(window, micro-window, job id)` allocation log (Fig. 10's bars).
    pub alloc_log: Vec<(usize, usize, usize)>,
    /// Pre-regroup membership snapshots per window (Fig. 9's bars).
    pub membership: Vec<(usize, MembershipSnapshot)>,
    /// The full typed event stream the run emitted.
    pub events: Vec<Event>,
    /// Accuracy-under-fault and recovery metrics (all-zero without a
    /// fault plan).
    pub resilience: Resilience,
    pub wall_secs: f64,
    /// Logical inference submissions during this session (engine-stats
    /// delta over the session lifetime; see `Session` docs for the
    /// engine-sharing caveat).
    pub infer_requests: u64,
    /// Inference kernel launches during this session. Equals
    /// `infer_requests` with micro-batch coalescing off; fewer with it on.
    /// Timing-dependent when coalescing — a perf observation like
    /// `wall_secs`, never part of the deterministic event/accuracy
    /// surface.
    pub infer_calls: u64,
}

/// Resilience metrics for runs with a fault plan attached (see
/// [`crate::faults`]). A run without faults reports the default
/// (all-zero) value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Resilience {
    /// Windows during which at least one fault was active.
    pub fault_windows: usize,
    /// Mean end-of-window fleet accuracy over fault-active windows.
    pub acc_under_fault: f32,
    /// Completed recoveries (camera rejoins back above the response
    /// threshold, uplink restores).
    pub recoveries: usize,
    /// Mean windows from fault onset to recovery (0 when none completed).
    pub windows_to_recover: f64,
}

impl RunReport {
    /// The legacy results-JSON shape (`scripts/render_results.py` input).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            ("window_acc", f32s(&self.window_acc)),
            (
                "cam_acc",
                arr(self.cam_acc.iter().map(|c| f32s(c)).collect()),
            ),
            ("steady", num(self.steady as f64)),
            ("final", num(self.final_acc as f64)),
            ("response_s", num(self.response_s)),
            ("satisfied", num(self.satisfied as f64)),
            ("requests", num(self.requests as f64)),
            ("jobs", num(self.jobs as f64)),
            ("fault_windows", num(self.resilience.fault_windows as f64)),
            (
                "acc_under_fault",
                num(self.resilience.acc_under_fault as f64),
            ),
            ("recoveries", num(self.resilience.recoveries as f64)),
            (
                "windows_to_recover",
                num(self.resilience.windows_to_recover),
            ),
            ("wall_secs", num(self.wall_secs)),
            ("infer_requests", num(self.infer_requests as f64)),
            ("infer_calls", num(self.infer_calls as f64)),
            ("coalesce_ratio", num(self.coalesce_ratio())),
        ])
    }

    /// Micro-batch coalescing ratio: logical inference requests per
    /// kernel launch (1.0 = no coalescing; higher = bigger mega-batches).
    pub fn coalesce_ratio(&self) -> f64 {
        if self.infer_calls == 0 {
            1.0
        } else {
            self.infer_requests as f64 / self.infer_calls as f64
        }
    }
}

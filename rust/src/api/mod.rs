//! `ecco::api` — the public entry point for running the system.
//!
//! Three pieces replace the old positional `System::new` + field-scraping
//! pattern:
//!
//! * [`RunSpec`] — a validated builder for one run: task + policy, the
//!   resource envelope (GPUs, shared bandwidth, per-camera uplinks), the
//!   horizon, seed, and scenario.
//! * [`Session`] — the live handle: [`Session::run`] for a whole horizon,
//!   or [`Session::step_window`] with scripted control
//!   ([`Session::request_now`], [`Session::force_group`]) in between.
//! * the typed event stream — [`Event`]s delivered to [`EventSink`]s; the
//!   always-on [`RecordingSink`] backs [`WindowReport`] / [`RunReport`],
//!   and [`JsonlSink`] streams the run to disk.
//!
//! For sweeps, [`run_fleet`] runs many specs concurrently over one shared
//! engine with results in spec order — every report identical to its
//! sequential equivalent (see the threading notes in [`crate`] docs).
//!
//! Specs also travel over the wire: [`RunSpec::to_wire_json`] exports the
//! serializable surface (everything except `scenario` worlds and
//! `configure` hooks) and [`RunSpec::from_wire_json`] validates it back
//! with typed [`SpecError`]s — the contract behind `ecco serve`
//! ([`crate::serve`]), which hosts many sessions in one process with
//! FIFO admission, per-consumer back-pressure, and deterministic
//! snapshot/resume.
//!
//! Two sub-builders refine a spec without new top-level setters:
//! [`RunSpec::camera`] layers per-camera overrides ([`CameraSpec`]: uplink,
//! window length, phase) over the fleet defaults, and
//! [`RunSpec::runtime`] groups process-level knobs ([`RuntimeOpts`]:
//! eval threads, frame cache, lockstep vs event-driven scheduler, and
//! micro-batch inference coalescing via [`CoalesceOpts`]).
//! City-scale fleets add [`RunSpec::topology_degree`] to prune grouping's
//! candidate scan to spatial neighbors:
//!
//! ```no_run
//! use ecco::api::{RunSpec, RuntimeOpts, Session};
//! use ecco::runtime::{Engine, Task};
//! use ecco::scene::scenario;
//! use ecco::server::{Policy, Scheduler};
//!
//! fn main() -> anyhow::Result<()> {
//!     let engine = Engine::open_default()?;
//!     let spec = RunSpec::new(Task::Det, Policy::ecco())
//!         .scenario(scenario::town(1000, 42))
//!         .topology_degree(6)
//!         .camera(0, |c| c.uplink_mbps(8.0).window_len(30.0).phase(10.0))
//!         .runtime(RuntimeOpts::new().threads(4).scheduler(Scheduler::EventDriven))
//!         .windows(4);
//!     let report = Session::new(&engine, spec)?.run()?;
//!     println!("final mAP {:.3}", report.final_acc);
//!     Ok(())
//! }
//! ```
//!
//! ```no_run
//! use ecco::api::{RunSpec, Session};
//! use ecco::runtime::{Engine, Task};
//! use ecco::server::Policy;
//!
//! fn main() -> anyhow::Result<()> {
//!     let engine = Engine::open_default()?;
//!     let spec = RunSpec::new(Task::Det, Policy::ecco())
//!         .cams(6)
//!         .gpus(2.0)
//!         .shared_mbps(6.0)
//!         .windows(8)
//!         .seed(7);
//!     let report = Session::new(&engine, spec)?.run()?;
//!     println!("steady mAP {:.3}", report.steady);
//!     Ok(())
//! }
//! ```

pub mod event;
pub mod report;
pub mod session;
pub mod spec;

pub use event::{Event, EventSink, JsonlSink, RecordingSink};
pub use report::{Resilience, RunReport, WindowReport};
pub use session::{run_fleet, Session};
pub use crate::runtime::CoalesceOpts;
pub use spec::{CameraSpec, RunSpec, RuntimeOpts, SimOpts, SpecError};

//! `ecco` — CLI for the ECCO reproduction.
//!
//! Subcommands:
//!   run        — run one policy on a scenario via the `ecco::api` façade
//!                and print the accuracy timeline (quick interactive driver)
//!   exp <id>   — regenerate a paper table/figure
//!                (fig2c fig5 tab1 fig6det fig6seg fig7 fig8 fig9 fig10
//!                 fig11 fig12 fig13, or `all`)
//!   serve      — host many concurrent sessions over a socket
//!                (line-JSON protocol; see `ecco::serve`)
//!   lint       — static-analysis pass enforcing the determinism &
//!                safety rules D001–D006 (see `ecco::lint`)
//!   info       — print manifest / artifact inventory
//!
//! Common options: --task det|seg --gpus N --bw MBPS --windows N --seed N
//!                 --out results/   (JSON results directory)
//! Unknown options are rejected with a "did you mean" hint.

use anyhow::{bail, Result};
use ecco::api::{JsonlSink, RunSpec, Session};
use ecco::exp;
use ecco::faults::{FaultPlan, FaultScenario};
use ecco::runtime::{Engine, Task};
use ecco::serve::{Bind, ServeConfig, Server};
use ecco::server::Policy;
use ecco::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.command.as_deref() {
        Some("run") => cmd_run(&args),
        Some("exp") => cmd_exp(&args),
        Some("serve") => cmd_serve(&args),
        Some("lint") => cmd_lint(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: ecco <run|exp|serve|lint|info> [options]\n\
                 \n\
                 ecco run [--policy ecco|naive|ekya|recl] [--task det|seg]\n\
                 \x20        [--cams N] [--gpus G] [--bw MBPS] [--windows N] [--seed S]\n\
                 \x20        [--events run.jsonl] [--faults none|light|heavy] [--fault-seed S]\n\
                 ecco exp <fig2c|fig5|tab1|fig6det|fig6seg|fig7|fig8|fig9|fig10|fig11|fig12|fig13|all>\n\
                 \x20        [--out results] [--seed S] [--fast] [--threads N]\n\
                 ecco serve [--listen 127.0.0.1:7433] [--unix PATH] [--runners N]\n\
                 \x20        [--queue-cap N] [--sub-buffer N]\n\
                 ecco lint [DIR] [--fix-hints] [--baseline FILE] [--format text|json]\n\
                 ecco info"
            );
            bail!("missing or unknown subcommand");
        }
    }
}

fn policy_by_name(name: &str) -> Result<Policy> {
    Policy::by_name(name).ok_or_else(|| anyhow::anyhow!("unknown policy {name:?}"))
}

fn cmd_run(args: &Args) -> Result<()> {
    args.reject_unknown(
        &[
            "policy",
            "task",
            "cams",
            "gpus",
            "bw",
            "windows",
            "seed",
            "events",
            "faults",
            "fault-seed",
        ],
        &[],
    )?;
    let task = Task::parse(&args.str_or("task", "det"))?;
    let policy = policy_by_name(&args.str_or("policy", "ecco"))?;
    let windows = args.usize_or("windows", 8)?;
    let cams = args.usize_or("cams", 6)?;
    let fault_arg = args.str_or("faults", "none");
    let fault_seed = args.u64_or("fault-seed", 0xfa17)?;
    let faults = match fault_arg.as_str() {
        "none" => FaultPlan::none(),
        "light" => FaultPlan::scenario(FaultScenario::Light, cams, windows, fault_seed),
        "heavy" => FaultPlan::scenario(FaultScenario::Heavy, cams, windows, fault_seed),
        other => bail!("unknown fault preset {other:?} (use none|light|heavy)"),
    };
    let chaos = !faults.is_empty();

    let engine = Engine::open_default()?;
    let spec = RunSpec::new(task, policy)
        .cams(cams)
        .gpus(args.f64_or("gpus", 2.0)?)
        .shared_mbps(args.f64_or("bw", 6.0)?)
        .windows(windows)
        .seed(args.u64_or("seed", 7)?)
        .faults(faults);
    let mut session = Session::new(&engine, spec)?;
    if let Some(path) = args.get("events") {
        session.add_sink(Box::new(JsonlSink::create(path)?));
        println!("# streaming events to {path}");
    }

    println!("# window t mean_mAP jobs per_cam...");
    for _ in 0..windows {
        let w = session.step_window()?;
        let per: Vec<String> = w.cam_acc.iter().map(|a| format!("{a:.3}")).collect();
        println!(
            "{} {:.0} {:.3} {} {}",
            w.window,
            w.time,
            w.mean_acc,
            w.jobs,
            per.join(" ")
        );
    }
    if chaos {
        let r = session.resilience();
        println!(
            "# resilience: {} fault windows, mAP under fault {:.3}, \
             {} recoveries (mean {:.1} windows)",
            r.fault_windows, r.acc_under_fault, r.recoveries, r.windows_to_recover
        );
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    // `--fast` takes no value; recover a positional the parser may have
    // bound to it (`ecco exp --fast fig6det`).
    let mut args = args.clone();
    args.normalize_flags(&["fast"]);
    args.reject_unknown(&["out", "seed", "threads"], &["fast"])?;
    let Some(id) = args.positional.first() else {
        bail!("exp requires an experiment id (or `all`)");
    };
    let out_dir = args.str_or("out", "results");
    std::fs::create_dir_all(&out_dir)?;
    let fast = args.flag("fast");
    let seed = args.u64_or("seed", 7)?;
    let threads = args
        .usize_or("threads", ecco::util::pool::default_threads())?
        .max(1);
    let engine = Engine::open_default()?;
    if threads > engine.pool().parallelism() {
        // The engine's persistent pool (sized from ECCO_THREADS / machine
        // parallelism at startup) bounds real concurrency; say so instead
        // of silently capping the flag.
        eprintln!(
            "[ecco] --threads {threads} exceeds the engine pool's parallelism ({}); \
             concurrency is capped there (raise ECCO_THREADS to widen the pool)",
            engine.pool().parallelism()
        );
    }
    let ctx = exp::ExpContext {
        out_dir,
        fast,
        seed,
        threads,
        out: exp::OutSink::stdout(),
    };
    exp::run_experiment(&engine, id, &ctx)
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.reject_unknown(&["listen", "unix", "runners", "queue-cap", "sub-buffer"], &[])?;
    let defaults = ServeConfig::default();
    let cfg = ServeConfig {
        runners: args.usize_or("runners", defaults.runners)?.max(1),
        queue_cap: args.usize_or("queue-cap", defaults.queue_cap)?.max(1),
        sub_buffer: args.usize_or("sub-buffer", defaults.sub_buffer)?.max(1),
    };
    let bind = match args.get("unix") {
        #[cfg(unix)]
        Some(path) => Bind::Unix(std::path::PathBuf::from(path)),
        #[cfg(not(unix))]
        Some(_) => bail!("--unix is only available on unix platforms"),
        None => Bind::Tcp(args.str_or("listen", "127.0.0.1:7433")),
    };
    let engine = Engine::open_default()?;
    let server = Server::bind(&engine, &bind, cfg)?;
    match (&bind, server.local_addr()) {
        (_, Some(addr)) => println!("# ecco serve listening on tcp://{addr}"),
        (Bind::Tcp(addr), None) => println!("# ecco serve listening on tcp://{addr}"),
        #[cfg(unix)]
        (Bind::Unix(path), None) => {
            println!("# ecco serve listening on unix://{}", path.display())
        }
    }
    println!(
        "# runners {}, queue cap {}, subscriber buffer {} frames",
        cfg.runners, cfg.queue_cap, cfg.sub_buffer
    );
    server.run()
}

fn cmd_lint(args: &Args) -> Result<()> {
    // `--fix-hints` takes no value; recover a positional the parser may
    // have bound to it (`ecco lint --fix-hints src`).
    let mut args = args.clone();
    args.normalize_flags(&["fix-hints"]);
    args.reject_unknown(&["baseline", "format"], &["fix-hints"])?;
    let root = match args.positional.first() {
        Some(dir) => std::path::PathBuf::from(dir),
        // Default: the crate's own sources, wherever the binary was built
        // from — `ecco lint` with no args lints this repo.
        None => std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src"),
    };
    let clean = ecco::lint::run_cli(
        &root,
        args.get("baseline"),
        &args.str_or("format", "text"),
        args.flag("fix-hints"),
    )?;
    if !clean {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.reject_unknown(&[], &[])?;
    let engine = Engine::open_default()?;
    let m = &engine.manifest;
    println!("artifacts dir: {:?}", m.dir);
    println!(
        "tasks: det ({} params), seg ({} params)",
        m.tasks["det"].param_count, m.tasks["seg"].param_count
    );
    println!("resolutions: {:?}", m.resolutions);
    println!(
        "batches: train {}, infer {}; grid {}, classes {}",
        m.train_batch, m.infer_batch, m.grid, m.classes
    );
    println!("{} artifacts:", m.artifacts.len());
    for (name, a) in &m.artifacts {
        println!(
            "  {name:<18} {} inputs, {} outputs, {:?}",
            a.inputs.len(),
            a.outputs.len(),
            a.file.file_name().unwrap()
        );
    }
    Ok(())
}

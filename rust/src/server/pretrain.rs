//! Student pretraining: the "representative initial data" fit every camera
//! ships with (§2.1). Results are cached on disk keyed by the recipe so
//! repeated experiment runs skip the work.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::runtime::{batch, Engine, ModelState, Task};
use crate::scene::{render, SceneState};
use crate::teacher::{Teacher, TeacherConfig};
use crate::util::rng::Pcg32;

/// Pretrain a student on a scene distribution for `steps` SGD steps at
/// resolution 32; deterministic in `seed`.
pub fn pretrain_on(
    engine: &Engine,
    task: Task,
    state0: &SceneState,
    steps: usize,
    lr: f32,
    seed: u64,
) -> Result<ModelState> {
    let m = &engine.manifest;
    let mut model = engine.init_model(task)?;
    let mut teacher = Teacher::new(TeacherConfig::oracle(), seed);
    let mut rng = Pcg32::new(seed, 55);
    let res = 32;
    // A modest pool of frames re-sampled into batches (mimics a recorded
    // representative dataset rather than infinite fresh data).
    let pool: Vec<_> = (0..96)
        .map(|i| render(state0, res, seed.wrapping_mul(31).wrapping_add(i)))
        .collect();
    let labels: Vec<_> = pool.iter().map(|f| teacher.annotate(&f.truth)).collect();
    for _ in 0..steps {
        let picks: Vec<usize> = (0..m.train_batch).map(|_| rng.index(pool.len())).collect();
        let frames: Vec<_> = picks.iter().map(|&i| &pool[i]).collect();
        let truths: Vec<_> = picks.iter().map(|&i| &labels[i]).collect();
        let tb = batch::train_batch(task, &frames, &truths, m.train_batch, res, m.classes, m.grid);
        engine.train_step(&mut model, &tb, lr)?;
    }
    Ok(model)
}

fn cache_path(engine: &Engine, task: Task, steps: usize, lr: f32, seed: u64) -> PathBuf {
    // The key carries every input the checkpoint depends on — lr included
    // (as raw bits: lossless and filename-safe), so an lr ablation never
    // reuses a checkpoint pretrained at a different rate.
    engine.manifest.dir.join(format!(
        "cache_pretrain_{}_{steps}_{seed}_lr{:08x}.bin",
        task.name(),
        lr.to_bits()
    ))
}

/// Read a cached pretrain checkpoint if it exists and has the right size.
fn read_cached(path: &Path, task: Task, count: usize) -> Option<ModelState> {
    let bytes = std::fs::read(path).ok()?;
    if bytes.len() != count * 4 {
        return None;
    }
    let theta: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Some(ModelState::from_theta(task, theta))
}

/// Pretrain on the default-day distribution with a disk cache.
pub fn pretrained_default(
    engine: &Engine,
    task: Task,
    steps: usize,
    lr: f32,
    seed: u64,
) -> Result<ModelState> {
    let path = cache_path(engine, task, steps, lr, seed);
    let count = engine.manifest.task(task).param_count;
    if let Some(model) = read_cached(&path, task, count) {
        return Ok(model);
    }
    // Cache miss: serialize the (expensive) pretrain across in-process
    // threads so concurrent fleet arms sharing a recipe don't all redo it —
    // whoever wins the lock computes and writes; the rest re-read the
    // cache. Distinct recipes serialize too, but a pretrain costs the same
    // either way and mixed-recipe fleets are rare.
    static PRETRAIN_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    let _guard = PRETRAIN_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    if let Some(model) = read_cached(&path, task, count) {
        return Ok(model);
    }
    let model = pretrain_on(engine, task, &SceneState::default_day(), steps, lr, seed)?;
    let bytes: Vec<u8> = model.theta.iter().flat_map(|v| v.to_le_bytes()).collect();
    // Cache failure is non-fatal; the directory may not exist yet when the
    // native backend runs without generated artifacts. Write-then-rename so
    // concurrent readers (parallel tests) never observe a torn file. The
    // tmp name carries a process-wide counter as well as the pid: fleet
    // runs pretrain concurrently on threads within one process.
    if let Some(parent) = path.parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let tmp = path.with_extension(format!("tmp{}_{seq}", std::process::id()));
    if std::fs::write(&tmp, bytes).is_ok() {
        let _ = std::fs::rename(&tmp, &path);
    }
    Ok(model)
}

//! Student pretraining: the "representative initial data" fit every camera
//! ships with (§2.1). Results are cached on disk keyed by the recipe so
//! repeated experiment runs skip the work.

use std::path::PathBuf;

use anyhow::Result;

use crate::runtime::{batch, Engine, ModelState, Task};
use crate::scene::{render, SceneState};
use crate::teacher::{Teacher, TeacherConfig};
use crate::util::rng::Pcg32;

/// Pretrain a student on a scene distribution for `steps` SGD steps at
/// resolution 32; deterministic in `seed`.
pub fn pretrain_on(
    engine: &mut Engine,
    task: Task,
    state0: &SceneState,
    steps: usize,
    lr: f32,
    seed: u64,
) -> Result<ModelState> {
    let m = engine.manifest.clone();
    let mut model = engine.init_model(task)?;
    let mut teacher = Teacher::new(TeacherConfig::oracle(), seed);
    let mut rng = Pcg32::new(seed, 55);
    let res = 32;
    // A modest pool of frames re-sampled into batches (mimics a recorded
    // representative dataset rather than infinite fresh data).
    let pool: Vec<_> = (0..96)
        .map(|i| render(state0, res, seed.wrapping_mul(31).wrapping_add(i)))
        .collect();
    let labels: Vec<_> = pool.iter().map(|f| teacher.annotate(&f.truth)).collect();
    for _ in 0..steps {
        let picks: Vec<usize> = (0..m.train_batch).map(|_| rng.index(pool.len())).collect();
        let frames: Vec<_> = picks.iter().map(|&i| &pool[i]).collect();
        let truths: Vec<_> = picks.iter().map(|&i| &labels[i]).collect();
        let tb = batch::train_batch(task, &frames, &truths, m.train_batch, res, m.classes, m.grid);
        engine.train_step(&mut model, &tb, lr)?;
    }
    Ok(model)
}

fn cache_path(engine: &Engine, task: Task, steps: usize, seed: u64) -> PathBuf {
    engine
        .manifest
        .dir
        .join(format!("cache_pretrain_{}_{steps}_{seed}.bin", task.name()))
}

/// Pretrain on the default-day distribution with a disk cache.
pub fn pretrained_default(
    engine: &mut Engine,
    task: Task,
    steps: usize,
    lr: f32,
    seed: u64,
) -> Result<ModelState> {
    let path = cache_path(engine, task, steps, seed);
    let count = engine.manifest.task(task).param_count;
    if let Ok(bytes) = std::fs::read(&path) {
        if bytes.len() == count * 4 {
            let theta: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            return Ok(ModelState::from_theta(task, theta));
        }
    }
    let model = pretrain_on(engine, task, &SceneState::default_day(), steps, lr, seed)?;
    let bytes: Vec<u8> = model.theta.iter().flat_map(|v| v.to_le_bytes()).collect();
    // Cache failure is non-fatal; the directory may not exist yet when the
    // native backend runs without generated artifacts. Write-then-rename so
    // concurrent readers (parallel tests) never observe a torn file.
    if let Some(parent) = path.parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    let tmp = path.with_extension(format!("tmp{}", std::process::id()));
    if std::fs::write(&tmp, bytes).is_ok() {
        let _ = std::fs::rename(&tmp, &path);
    }
    Ok(model)
}

//! Event/time-wheel scheduler for the per-window loop.
//!
//! The legacy lockstep loop advances every camera in unison, one
//! micro-window at a time. The event scheduler replaces that control flow
//! with a min-heap of [`SchedEvent`]s keyed by *slot* — the global
//! micro-tick (1-based) the event is due at — so cameras with
//! heterogeneous window lengths and staggered phases can advance
//! independently while the world/network clock still moves in exact
//! `mw_secs` increments.
//!
//! # Clock model
//!
//! Time is deliberately slot-quantised: the driver advances the world by
//! exactly `window_secs / w_eff` per slot (the same repeated-increment
//! float accumulation the lockstep loop performs) and then drains all
//! events due at that slot. Events never carry float instants — a
//! heterogeneous camera's own grid instants are quantised to their
//! enclosing tick by [`slots_for_grid`]. This is what makes the
//! uniform-window case *byte-identical* to lockstep rather than merely
//! equivalent: both paths execute the identical sequence of
//! `advance(mw_secs)` calls, so every simulated timestamp matches to the
//! last ULP.
//!
//! # Ordering
//!
//! Within a slot, events fire in `(Action, cam)` order, which encodes the
//! lockstep body: all captures (by camera id), then all probes (by camera
//! id), then the training micro-window, then any per-camera window
//! boundaries. Ties are therefore deterministic by construction — the
//! heap order *is* the derived `Ord`.
//!
//! Fault-plan drains are deliberately NOT wheel events: the lockstep
//! cursor applies the events of micro-window coordinate `m` *before* the
//! slot's time advance, so the driver keeps them as a fixed pre-advance
//! step of the slot loop (reusing the exact cursor), followed by the
//! end-of-window drain after the last slot.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// What fires when a [`SchedEvent`] comes due. Variant order is the
/// within-slot priority (captures before probes before training before
/// camera window boundaries) — it mirrors the statement order of the
/// lockstep loop body and must not be reordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Action {
    /// Ingest the camera's frames delivered since its last capture.
    Capture,
    /// Camera-side drift probe + (possibly) a retraining request.
    Probe,
    /// One global GPU micro-window (Alg. 1); payload = micro-window index.
    Train(usize),
    /// A heterogeneous camera's own window boundary: publish + measure.
    CamWindowEnd,
}

/// One scheduled event. The derived lexicographic `Ord` over
/// `(slot, action, cam)` is the heap priority: earlier slots first, then
/// the action priority, then camera id as the tie-break.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct SchedEvent {
    /// Global micro-tick this event is due at (1-based within the window).
    pub slot: usize,
    pub action: Action,
    /// Camera id for per-camera actions; 0 for the global lanes.
    pub cam: usize,
}

impl SchedEvent {
    pub fn capture(slot: usize, cam: usize) -> SchedEvent {
        SchedEvent {
            slot,
            action: Action::Capture,
            cam,
        }
    }

    pub fn probe(slot: usize, cam: usize) -> SchedEvent {
        SchedEvent {
            slot,
            action: Action::Probe,
            cam,
        }
    }

    pub fn train(slot: usize, mw: usize) -> SchedEvent {
        SchedEvent {
            slot,
            action: Action::Train(mw),
            cam: 0,
        }
    }

    pub fn cam_window_end(slot: usize, cam: usize) -> SchedEvent {
        SchedEvent {
            slot,
            action: Action::CamWindowEnd,
            cam,
        }
    }
}

/// Min-heap of scheduled events, drained slot by slot.
#[derive(Debug, Default)]
pub struct EventWheel {
    heap: BinaryHeap<Reverse<SchedEvent>>,
}

impl EventWheel {
    pub fn new() -> EventWheel {
        EventWheel::default()
    }

    pub fn push(&mut self, ev: SchedEvent) {
        self.heap.push(Reverse(ev));
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Pop the highest-priority event due at or before `slot`; `None`
    /// when the head (if any) is scheduled later.
    pub fn pop_due(&mut self, slot: usize) -> Option<SchedEvent> {
        match self.heap.peek() {
            Some(Reverse(ev)) if ev.slot <= slot => self.heap.pop().map(|Reverse(e)| e),
            _ => None,
        }
    }

    pub fn peek(&self) -> Option<SchedEvent> {
        self.heap.peek().map(|&Reverse(e)| e)
    }
}

/// Global slots (1-based, strictly increasing, clamped to `[1, w_eff]`)
/// at which the arithmetic grid `{phase + k·step : k ∈ ℕ}` has instants
/// strictly inside the server window `(t0, t0 + window_secs]`. Each
/// instant is quantised *up* to its enclosing micro-tick — an event can
/// only fire once its instant has passed on the slot clock. Instants
/// landing in the same tick are deduplicated.
pub fn slots_for_grid(
    t0: f64,
    window_secs: f64,
    mw_secs: f64,
    phase: f64,
    step: f64,
    w_eff: usize,
) -> Vec<usize> {
    let mut slots = Vec::new();
    if !(step.is_finite() && step > 0.0 && mw_secs > 0.0 && w_eff > 0) {
        return slots;
    }
    // First k with phase + k·step strictly after t0.
    let mut k = if t0 <= phase {
        0.0
    } else {
        ((t0 - phase) / step).floor()
    };
    while phase + k * step <= t0 {
        k += 1.0;
    }
    let end = t0 + window_secs;
    // Bounded by construction (step > 0), but guard float pathologies and
    // absurdly dense grids (dedup caps useful output at w_eff slots anyway).
    let max_iters = ((window_secs / step).ceil() as usize + 2).min(1_000_000);
    for _ in 0..=max_iters {
        let t = phase + k * step;
        // Tolerate the last grid point landing one ULP past the window end.
        if t > end + window_secs * 1e-12 {
            break;
        }
        let rel = (t - t0).max(0.0);
        let slot = ((rel / mw_secs).ceil() as usize).clamp(1, w_eff);
        if slots.last() != Some(&slot) {
            slots.push(slot);
        }
        k += 1.0;
    }
    slots
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn within_slot_priority_matches_lockstep_body() {
        let mut w = EventWheel::new();
        // Insert deliberately out of order.
        w.push(SchedEvent::probe(1, 1));
        w.push(SchedEvent::cam_window_end(1, 0));
        w.push(SchedEvent::train(1, 0));
        w.push(SchedEvent::capture(1, 1));
        w.push(SchedEvent::probe(1, 0));
        w.push(SchedEvent::capture(1, 0));
        let mut order = Vec::new();
        while let Some(ev) = w.pop_due(1) {
            order.push((ev.action, ev.cam));
        }
        assert_eq!(
            order,
            vec![
                (Action::Capture, 0),
                (Action::Capture, 1),
                (Action::Probe, 0),
                (Action::Probe, 1),
                (Action::Train(0), 0),
                (Action::CamWindowEnd, 0),
            ]
        );
    }

    #[test]
    fn pop_due_respects_slots() {
        let mut w = EventWheel::new();
        w.push(SchedEvent::capture(2, 0));
        w.push(SchedEvent::capture(1, 0));
        assert_eq!(w.len(), 2);
        assert_eq!(w.pop_due(1), Some(SchedEvent::capture(1, 0)));
        assert_eq!(w.pop_due(1), None, "slot-2 event must wait");
        assert_eq!(w.pop_due(2), Some(SchedEvent::capture(2, 0)));
        assert!(w.is_empty());
        assert_eq!(w.peek(), None);
    }

    #[test]
    fn uniform_grid_hits_every_slot() {
        // step == mw_secs, zero phase: exactly the lockstep tick grid.
        let w_eff = 6;
        let mw = 60.0 / w_eff as f64;
        for window in 0..4 {
            let t0 = window as f64 * 60.0;
            let slots = slots_for_grid(t0, 60.0, mw, 0.0, mw, w_eff);
            assert_eq!(slots, (1..=w_eff).collect::<Vec<_>>());
        }
    }

    #[test]
    fn dense_grid_dedupes_to_ticks() {
        // step = mw/3: three instants per tick collapse to one slot each.
        let slots = slots_for_grid(0.0, 60.0, 10.0, 0.0, 10.0 / 3.0, 6);
        assert_eq!(slots, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn sparse_grid_skips_ticks() {
        // A camera window of 30s inside a 60s/6-tick server window:
        // boundaries at 30 and 60 quantise to slots 3 and 6.
        let slots = slots_for_grid(0.0, 60.0, 10.0, 0.0, 30.0, 6);
        assert_eq!(slots, vec![3, 6]);
    }

    #[test]
    fn phase_staggers_slots() {
        // phase 15, step 30 → instants 15, 45 → slots 2, 5.
        let slots = slots_for_grid(0.0, 60.0, 10.0, 15.0, 30.0, 6);
        assert_eq!(slots, vec![2, 5]);
        // Second window (t0 = 60): instants 75, 105 → rel 15, 45.
        let slots2 = slots_for_grid(60.0, 60.0, 10.0, 15.0, 30.0, 6);
        assert_eq!(slots2, vec![2, 5]);
    }

    #[test]
    fn grid_boundary_is_exclusive_at_start_inclusive_at_end() {
        // An instant exactly at t0 belongs to the *previous* window; one
        // exactly at t0 + T lands on the final slot.
        let slots = slots_for_grid(30.0, 30.0, 5.0, 0.0, 30.0, 6);
        assert_eq!(slots, vec![6], "t=30 excluded, t=60 on slot 6");
    }

    #[test]
    fn degenerate_steps_yield_no_slots() {
        assert!(slots_for_grid(0.0, 60.0, 10.0, 0.0, 0.0, 6).is_empty());
        assert!(slots_for_grid(0.0, 60.0, 10.0, 0.0, f64::NAN, 6).is_empty());
        assert!(slots_for_grid(0.0, 60.0, 10.0, 0.0, -1.0, 6).is_empty());
    }
}

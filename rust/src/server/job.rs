//! A retraining job: one group's shared student model plus its training
//! data buffer and accuracy bookkeeping.

use std::collections::VecDeque;

use anyhow::Result;

use crate::runtime::{batch, Engine, ModelState, Task, TrainBatch};
use crate::scene::{Frame, GroundTruth};
use crate::util::rng::Pcg32;

/// One buffered training sample: a delivered (possibly degraded) frame plus
/// the teacher's labels for it.
#[derive(Debug, Clone)]
pub struct Sample {
    pub frame: Frame,
    pub labels: GroundTruth,
    /// Camera that contributed the sample.
    pub cam: usize,
}

/// A retraining job (Fig. 3: one per camera group).
pub struct Job {
    pub id: usize,
    pub members: Vec<usize>,
    pub model: ModelState,
    /// Ring buffer of recent training samples from all members.
    pub buffer: VecDeque<Sample>,
    pub buffer_cap: usize,
    /// Latest evaluated accuracy (mean over members).
    pub acc: f32,
    /// Accuracy delta over the job's last trained micro-window.
    pub acc_gain: f32,
    /// Micro-windows received in the current retraining window.
    pub micro_windows: usize,
    /// Micro-windows received over the job's lifetime.
    pub lifetime_mw: usize,
    /// Total SGD steps over the job's lifetime.
    pub total_steps: u64,
    /// Simulated time the job was created (for response tracking).
    pub created_at: f64,
}

impl Job {
    pub fn new(id: usize, cam: usize, model: ModelState, buffer_cap: usize, now: f64) -> Job {
        Job {
            id,
            members: vec![cam],
            model,
            buffer: VecDeque::new(),
            buffer_cap,
            acc: 0.0,
            acc_gain: 0.0,
            micro_windows: 0,
            lifetime_mw: 0,
            total_steps: 0,
            created_at: now,
        }
    }

    pub fn n_cams(&self) -> usize {
        self.members.len()
    }

    /// Append a sample, evicting the oldest past capacity.
    pub fn push_sample(&mut self, sample: Sample) {
        self.buffer.push_back(sample);
        while self.buffer.len() > self.buffer_cap {
            self.buffer.pop_front();
        }
    }

    /// Remove a member and its buffered samples (Alg. 2 eviction).
    pub fn remove_member(&mut self, cam: usize) {
        self.members.retain(|&c| c != cam);
        self.buffer.retain(|s| s.cam != cam);
    }

    /// Merge another camera's request into this job: membership only; the
    /// caller moves any sample frames.
    pub fn add_member(&mut self, cam: usize) {
        if !self.members.contains(&cam) {
            self.members.push(cam);
        }
    }

    /// The resolution this job trains at: the modal resolution of its
    /// buffer (samples of other resolutions are skipped when batching).
    pub fn train_res(&self) -> Option<usize> {
        if self.buffer.is_empty() {
            return None;
        }
        let mut counts: Vec<(usize, usize)> = Vec::new();
        for s in &self.buffer {
            match counts.iter_mut().find(|(r, _)| *r == s.frame.res) {
                Some((_, c)) => *c += 1,
                None => counts.push((s.frame.res, 1)),
            }
        }
        counts.into_iter().max_by_key(|&(_, c)| c).map(|(r, _)| r)
    }

    /// Run `steps` SGD steps on batches sampled uniformly from the buffer
    /// (at the modal resolution). Returns the mean loss, or None when the
    /// buffer has no usable data.
    pub fn train(
        &mut self,
        engine: &Engine,
        steps: usize,
        lr: f32,
        rng: &mut Pcg32,
    ) -> Result<Option<f32>> {
        let res = match self.train_res() {
            Some(r) => r,
            None => return Ok(None),
        };
        let usable: Vec<usize> = (0..self.buffer.len())
            .filter(|&i| self.buffer[i].frame.res == res)
            .collect();
        if usable.is_empty() {
            return Ok(None);
        }
        let m = &engine.manifest;
        let task = self.model.task;
        let mut loss_sum = 0.0f32;
        let mut n = 0usize;
        for _ in 0..steps {
            let picks: Vec<usize> = (0..m.train_batch)
                .map(|_| usable[rng.index(usable.len())])
                .collect();
            let frames: Vec<&Frame> = picks.iter().map(|&i| &self.buffer[i].frame).collect();
            let truths: Vec<&GroundTruth> =
                picks.iter().map(|&i| &self.buffer[i].labels).collect();
            let tb: TrainBatch = batch::train_batch(
                task,
                &frames,
                &truths,
                m.train_batch,
                res,
                m.classes,
                m.grid,
            );
            loss_sum += engine.train_step(&mut self.model, &tb, lr)?;
            n += 1;
            self.total_steps += 1;
        }
        Ok(if n == 0 { None } else { Some(loss_sum / n as f32) })
    }
}

/// Evaluate a model (by flat theta) on labelled eval frames: returns mAP.
/// Frames beyond the engine's infer batch are evaluated in chunks.
///
/// Takes `&Engine` (inference never mutates engine state), so callers can
/// fan independent evals out across [`crate::util::pool`] workers sharing
/// one engine.
///
/// This is the eval fan-outs' entry into the engine's micro-batch
/// **submission layer**: each `infer_det`/`infer_seg` call here is a
/// logical request, and with coalescing enabled
/// ([`crate::runtime::CoalesceOpts`]) concurrent workers evaluating the
/// same `(theta, res)` — e.g. every member of a group against the freshly
/// published group model — share single mega-batched kernel launches.
/// Returned mAPs are bit-identical either way, so the fan-outs'
/// index-ordered reduction (and the event log) is unaffected.
pub fn eval_model(
    engine: &Engine,
    task: Task,
    theta: &[f32],
    frames: &[Frame],
) -> Result<f32> {
    if frames.is_empty() {
        return Ok(0.0);
    }
    let m = &engine.manifest;
    let res = frames[0].res;
    let mut maps = Vec::new();
    for chunk in frames.chunks(m.infer_batch) {
        let refs: Vec<&Frame> = chunk.iter().collect();
        let pixels = batch::pixel_tensor(&refs, m.infer_batch, res);
        let truths: Vec<&GroundTruth> = chunk.iter().map(|f| &f.truth).collect();
        let v = match task {
            Task::Det => {
                let pred = engine.infer_det(theta, res, &pixels)?;
                crate::metrics::det_map(&pred, &truths, chunk.len())
            }
            Task::Seg => {
                let pred = engine.infer_seg(theta, res, &pixels)?;
                crate::metrics::seg_map(&pred, &truths, chunk.len())
            }
        };
        maps.push(v);
    }
    Ok(maps.iter().sum::<f32>() / maps.len() as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelState;
    use crate::scene::{render, SceneState};

    fn dummy_model() -> ModelState {
        ModelState::from_theta(Task::Det, vec![0.0; 10])
    }

    fn sample(res: usize, cam: usize, seed: u64) -> Sample {
        let f = render(&SceneState::default_day(), res, seed);
        let labels = f.truth.clone();
        Sample {
            frame: f,
            labels,
            cam,
        }
    }

    #[test]
    fn buffer_caps_and_evicts_fifo() {
        let mut j = Job::new(0, 0, dummy_model(), 3, 0.0);
        for i in 0..5 {
            j.push_sample(sample(32, 0, i));
        }
        assert_eq!(j.buffer.len(), 3);
    }

    #[test]
    fn remove_member_purges_samples() {
        let mut j = Job::new(0, 0, dummy_model(), 10, 0.0);
        j.add_member(1);
        j.push_sample(sample(32, 0, 1));
        j.push_sample(sample(32, 1, 2));
        j.push_sample(sample(32, 1, 3));
        j.remove_member(1);
        assert_eq!(j.members, vec![0]);
        assert!(j.buffer.iter().all(|s| s.cam == 0));
        assert_eq!(j.buffer.len(), 1);
    }

    #[test]
    fn train_res_is_modal() {
        let mut j = Job::new(0, 0, dummy_model(), 10, 0.0);
        assert_eq!(j.train_res(), None);
        j.push_sample(sample(16, 0, 1));
        j.push_sample(sample(32, 0, 2));
        j.push_sample(sample(32, 0, 3));
        assert_eq!(j.train_res(), Some(32));
    }

    #[test]
    fn add_member_idempotent() {
        let mut j = Job::new(0, 0, dummy_model(), 10, 0.0);
        j.add_member(2);
        j.add_member(2);
        assert_eq!(j.members, vec![0, 2]);
    }
}

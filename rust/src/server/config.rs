//! System configuration and the policy presets for ECCO and its baselines.

use crate::alloc::AllocKind;
use crate::faults::FaultPlan;
use crate::grouping::GroupingPolicy;
use crate::runtime::Task;
use crate::teacher::TeacherConfig;

/// How cameras pick sampling configs and congestion-control parameters.
#[derive(Debug, Clone)]
pub enum TransmissionKind {
    /// ECCO's resource-aware controller (§3.2): profile-table sampling +
    /// GPU-share-weighted GAIMD.
    Ecco,
    /// Fixed sampling config + plain AIMD (Naive / Ekya): the paper's
    /// "5 FPS at 960p" default maps to our top resolution tier.
    Fixed { fps: f32, res: usize },
    /// AMS-style content-adaptive frame rate (RECL), plain AIMD.
    Ams { base_fps: f32, res: usize },
}

/// A complete system policy: which of the paper's systems this run is.
#[derive(Debug, Clone)]
pub struct Policy {
    /// Group retraining (ECCO) vs independent retraining (all baselines).
    pub group_retraining: bool,
    pub alloc: AllocKind,
    pub transmission: TransmissionKind,
    /// RECL-style model-zoo warm start for new jobs.
    pub zoo_warm_start: bool,
    /// Human-readable system name for reports.
    pub name: &'static str,
}

impl Policy {
    /// ECCO (the paper's system).
    pub fn ecco() -> Policy {
        Policy {
            group_retraining: true,
            alloc: AllocKind::Ecco,
            transmission: TransmissionKind::Ecco,
            zoo_warm_start: false,
            name: "ecco",
        }
    }

    /// ECCO + RECL model reuse (§5.5).
    pub fn ecco_recl() -> Policy {
        Policy {
            zoo_warm_start: true,
            name: "ecco+recl",
            ..Policy::ecco()
        }
    }

    /// Naive baseline: independent retraining, uniform GPU, fixed sampling,
    /// equal bandwidth sharing.
    pub fn naive() -> Policy {
        Policy {
            group_retraining: false,
            alloc: AllocKind::Uniform,
            transmission: TransmissionKind::Fixed { fps: 5.0, res: 48 },
            zoo_warm_start: false,
            name: "naive",
        }
    }

    /// Ekya: independent retraining with utility-based GPU scheduling.
    pub fn ekya() -> Policy {
        Policy {
            group_retraining: false,
            alloc: AllocKind::Utility,
            transmission: TransmissionKind::Fixed { fps: 5.0, res: 48 },
            zoo_warm_start: false,
            name: "ekya",
        }
    }

    /// RECL: Ekya's allocator + model zoo + AMS sampling adaptation.
    pub fn recl() -> Policy {
        Policy {
            group_retraining: false,
            alloc: AllocKind::Utility,
            transmission: TransmissionKind::Ams {
                base_fps: 5.0,
                res: 48,
            },
            zoo_warm_start: true,
            name: "recl",
        }
    }

    /// Look a preset up by its stable [`Policy::name`] — the inverse used
    /// by the CLI `--policy` flag and the serve-protocol `"policy"` field.
    pub fn by_name(name: &str) -> Option<Policy> {
        match name {
            "ecco" => Some(Policy::ecco()),
            "ecco+recl" => Some(Policy::ecco_recl()),
            "naive" => Some(Policy::naive()),
            "ekya" => Some(Policy::ekya()),
            "recl" => Some(Policy::recl()),
            _ => None,
        }
    }
}

/// Which per-window driver runs the simulation loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Scheduler {
    /// Legacy lockstep loop: every camera advances in unison, one
    /// micro-window at a time.
    #[default]
    Lockstep,
    /// Event/time-wheel driver (see [`crate::server::sched`]): per-camera
    /// capture/probe/window-end events on a slot-quantised clock. With
    /// uniform window lengths and zero phases this replays the lockstep
    /// loop byte-identically; it is selected automatically whenever any
    /// camera has a heterogeneous window.
    EventDriven,
}

impl Scheduler {
    /// Stable machine-readable name (the serve-protocol `"scheduler"`
    /// discriminant).
    pub fn name(self) -> &'static str {
        match self {
            Scheduler::Lockstep => "lockstep",
            Scheduler::EventDriven => "event_driven",
        }
    }

    /// Inverse of [`Scheduler::name`].
    pub fn by_name(name: &str) -> Option<Scheduler> {
        match name {
            "lockstep" => Some(Scheduler::Lockstep),
            "event_driven" => Some(Scheduler::EventDriven),
            _ => None,
        }
    }
}

/// Per-camera window override (see [`crate::api::CameraSpec`]).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct CamWindow {
    /// This camera's own window length in seconds; `None` keeps the
    /// global `window_secs`.
    pub len_secs: Option<f64>,
    /// Offset of the camera's first window boundary from the server's
    /// clock origin; must lie in `[0, len)`.
    pub phase_secs: f64,
}

/// Full system configuration.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub task: Task,
    /// Number of (simulated) GPUs at the edge server.
    pub gpus: f64,
    /// Training throughput of one GPU in pixels/second (§3.2's capacity
    /// unit). Default calibrated so a handful of GPUs retrains our student
    /// within a few windows — the same relative regime as the paper's
    /// 4090s vs YOLO11n.
    pub gpu_pps: f64,
    /// Retraining window length ||T|| (simulated seconds).
    pub window_secs: f64,
    /// Micro-windows per window (Alg. 1's W).
    pub micro_windows: usize,
    /// Eval frames per camera (<= infer batch).
    pub eval_frames: usize,
    /// SGD learning rate.
    pub lr: f32,
    /// Max retained training frames per job.
    pub buffer_cap: usize,
    pub policy: Policy,
    pub teacher: TeacherConfig,
    pub grouping: GroupingPolicy,
    /// Camera-side drift detector threshold (embedding L2 distance).
    pub drift_threshold: f32,
    /// mAP threshold for the response-time metric.
    pub response_threshold: f32,
    /// Pretraining steps for the initial student (before deployment).
    pub pretrain_steps: usize,
    /// RECL zoo maintenance cadence: retrained checkpoints are pushed to the
    /// zoo every this many windows (the paper notes zoo updates carry real
    /// overhead; RECL does not refresh continuously).
    pub zoo_update_interval: usize,
    /// Camera-side automatic drift detection issues retraining requests.
    /// Disable for experiments that script requests manually (Fig. 12) or
    /// force a fixed grouping (Fig. 8).
    pub auto_request: bool,
    /// Periodic regrouping at window boundaries (Alg. 2 UpdateGrouping).
    pub auto_regroup: bool,
    pub seed: u64,
    /// Worker threads for the evaluation fan-outs (candidate evals, job
    /// evals, the per-camera window pass, the regroup matrix). Results are
    /// reduced in index order, so any value >= 1 produces byte-identical
    /// runs; this knob only trades wall-clock for cores.
    pub eval_threads: usize,
    /// Memoise eval-frame renders between world advances: the
    /// twice-per-micro-window job evals, the end-of-window per-camera
    /// pass, and the regroup matrix then render each (camera, salt) batch
    /// once instead of once per consumer. Renders are pure functions of
    /// the frozen world state, so cached batches are bit-identical to
    /// fresh ones (an A/B test asserts the event logs match); disable only
    /// to measure that claim.
    pub frame_cache: bool,
    /// Deterministic fault-injection schedule (see [`crate::faults`]).
    /// [`FaultPlan::none`] (the default) is guaranteed zero-cost: event
    /// logs are byte-identical to a run without the subsystem.
    pub faults: FaultPlan,
    /// Per-window driver; heterogeneous `cam_windows` force
    /// [`Scheduler::EventDriven`] regardless of this setting.
    pub scheduler: Scheduler,
    /// Micro-batch coalescing knobs for the engine's inference
    /// submission layer ([`crate::runtime::microbatch`]). `None` leaves
    /// the shared engine's current setting untouched; `Some` is applied
    /// by `Session::new`. Results are bit-identical either way — the
    /// knob only trades kernel-launch count for batching latency.
    pub coalesce: Option<crate::runtime::CoalesceOpts>,
    /// Per-camera window length/phase overrides (empty = uniform fleet).
    pub cam_windows: std::collections::BTreeMap<usize, CamWindow>,
    /// Upper bound on [`SystemConfig::effective_micro_windows`]. The
    /// Alg. 1 heuristic grows W with the job count so every job gets at
    /// least two slots; at city scale (hundreds of jobs) that would make
    /// per-window coordination quadratic, so fleet runs cap it — jobs
    /// then time-share the capped slot budget via the allocator. The
    /// default (`usize::MAX`) preserves the legacy behavior exactly.
    pub max_micro_windows: usize,
}

impl SystemConfig {
    pub fn new(task: Task, policy: Policy) -> SystemConfig {
        SystemConfig {
            task,
            gpus: 1.0,
            gpu_pps: 10_000.0,
            window_secs: 60.0,
            micro_windows: 6,
            eval_frames: 16,
            lr: 0.03,
            buffer_cap: 512,
            policy,
            teacher: TeacherConfig::strong(),
            grouping: GroupingPolicy::default(),
            drift_threshold: 0.055,
            response_threshold: 0.35,
            pretrain_steps: 300,
            zoo_update_interval: 2,
            auto_request: true,
            auto_regroup: true,
            seed: 7,
            eval_threads: crate::util::pool::default_threads(),
            frame_cache: true,
            faults: FaultPlan::none(),
            scheduler: Scheduler::default(),
            coalesce: None,
            cam_windows: std::collections::BTreeMap::new(),
            max_micro_windows: usize::MAX,
        }
    }

    /// Micro-window duration (seconds) at the configured baseline W.
    pub fn mw_secs(&self) -> f64 {
        self.window_secs / self.micro_windows as f64
    }

    /// Effective micro-windows for a window with `n_jobs` active jobs:
    /// Alg. 1's per-window initial pass must not consume the whole budget,
    /// so W grows with the job count (total GPU-time is unchanged — the
    /// micro-windows just get shorter), clamped to `max_micro_windows`
    /// (never below the configured baseline W) for fleet-scale runs.
    pub fn effective_micro_windows(&self, n_jobs: usize) -> usize {
        self.micro_windows
            .max(2 * n_jobs.max(1))
            .min(self.max_micro_windows.max(self.micro_windows))
    }

    /// SGD steps all G GPUs can run in a micro-window of `mw_secs` seconds
    /// at training resolution `res`.
    pub fn steps_for(&self, res: usize, train_batch: usize, mw_secs: f64) -> usize {
        let pixels = self.gpus * self.gpu_pps * mw_secs;
        let per_step = (res * res * train_batch) as f64;
        (pixels / per_step).floor().max(1.0) as usize
    }

    /// SGD steps per baseline micro-window (convenience for tests/benches).
    pub fn steps_per_mw(&self, res: usize, train_batch: usize) -> usize {
        self.steps_for(res, train_batch, self.mw_secs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_distinct() {
        assert!(Policy::ecco().group_retraining);
        assert!(!Policy::ekya().group_retraining);
        assert!(Policy::recl().zoo_warm_start);
        assert!(!Policy::naive().zoo_warm_start);
        assert_eq!(Policy::naive().alloc, AllocKind::Uniform);
        assert_eq!(Policy::ekya().alloc, AllocKind::Utility);
        assert_eq!(Policy::ecco().alloc, AllocKind::Ecco);
    }

    #[test]
    fn micro_window_cap_bounds_job_growth() {
        let mut cfg = SystemConfig::new(Task::Det, Policy::ecco());
        cfg.micro_windows = 6;
        assert_eq!(cfg.effective_micro_windows(1), 6);
        assert_eq!(cfg.effective_micro_windows(500), 1000, "uncapped default");
        cfg.max_micro_windows = 8;
        assert_eq!(cfg.effective_micro_windows(500), 8);
        assert_eq!(cfg.effective_micro_windows(1), 6, "cap leaves small runs alone");
        // Cap below the baseline W never shrinks below W.
        cfg.max_micro_windows = 2;
        assert_eq!(cfg.effective_micro_windows(500), 6);
    }

    #[test]
    fn steps_budget_scales() {
        let mut cfg = SystemConfig::new(Task::Det, Policy::ecco());
        cfg.gpus = 1.0;
        cfg.gpu_pps = 10_000.0;
        cfg.window_secs = 60.0;
        cfg.micro_windows = 6;
        let s32 = cfg.steps_per_mw(32, 8);
        let s48 = cfg.steps_per_mw(48, 8);
        assert!(s32 > s48, "higher res must cost steps: {s32} vs {s48}");
        cfg.gpus = 4.0;
        assert!(cfg.steps_per_mw(32, 8) >= s32 * 3, "4 GPUs ~4x steps");
    }
}

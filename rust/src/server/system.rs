//! The end-to-end system: cameras + network + teacher + retraining jobs +
//! GPU allocator + grouping, driven in retraining windows (Fig. 3/4).
//!
//! One [`System`] instance is one run of a policy (ECCO or a baseline) on a
//! scenario world. The simulation is faithful to the paper's structure:
//!
//! * time advances in retraining windows split into `W` micro-windows;
//! * within each micro-window the network simulator delivers frame data,
//!   cameras detect drift and issue retraining requests, and exactly one
//!   job trains on all GPUs (Alg. 1 time-sharing);
//! * at window boundaries groups are re-evaluated (Alg. 2), models are
//!   published to devices, and the next window's GPU-share estimates are
//!   pushed to the transmission controllers (§3.2).
//!
//! All retraining is *real*: SGD steps through the AOT-compiled PJRT
//! executables on frames rendered by the scene simulator and degraded by
//! the encoder model.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::alloc::{resplit_shares, Allocator, JobView};
use crate::api::event::{Event, EventBus};
use crate::faults::{embedding_valid, CorruptMode, FaultEvent, FaultKind};
use crate::grouping::{self, Decision, GroupJob, RequestMeta};
use crate::metrics::{AccuracyHistory, ResponseTracker};
use crate::net::{FlowId, NetSim};
use crate::runtime::{batch, Engine, ModelState};
use crate::scene::{Frame, World};
use crate::teacher::Teacher;
use crate::transmission::{baseline_plan, ams_plan, Controller, GpuAllocationInfo, TransmissionPlan};
use crate::util::rng::Pcg32;
use crate::util::stats::l2;
use crate::video::{degrade, transport_window};
use crate::zoo::{mean_embedding, ModelZoo};

use super::config::{Scheduler, SystemConfig, TransmissionKind};
use super::job::{eval_model, Job, Sample};
use super::pretrain::pretrained_default;
use super::sched::{slots_for_grid, Action, EventWheel, SchedEvent};

/// Maximum frames ingested per camera per micro-window (safety bound).
const MAX_FRAMES_PER_MW: usize = 150;

/// Cap on the exponential probe-retry backoff under faults: after this many
/// consecutive lost probes the delay stops doubling.
const MAX_PROBE_RETRIES: u32 = 3;

/// One window's group-membership snapshot: (job id, member cameras).
pub type MembershipSnapshot = Vec<(usize, Vec<usize>)>;
/// Evaluation resolution (the device's live stream).
const EVAL_RES: usize = 32;

/// Memoises [`World::eval_frames`] renders between world advances.
///
/// `World::eval_frames` is a pure function of the frozen world state and
/// its `(cam, res, n, salt)` arguments, and the coordinator re-requests
/// identical batches several times per window: `train_micro_window`
/// evaluates the picked job before *and* after training with the same
/// salts, and every consumer of a job's model re-renders its members'
/// streams. The cache hands all of them one `Arc`'d render per key; the
/// system clears it whenever the world advances (every micro-window), so a
/// hit can never observe stale drift state or camera motion — cached
/// batches are bit-identical to fresh renders by construction, which the
/// cache-on/off A/B test asserts end to end.
///
/// Thread-safe because eval fan-out workers fetch through it concurrently;
/// the lock is held only for lookup/insert, never while rendering (two
/// workers racing on one key render identical frames and keep the first).
pub(crate) struct FrameCache {
    enabled: bool,
    map: Mutex<BTreeMap<(usize, usize, usize, u64), Arc<Vec<Frame>>>>,
}

impl FrameCache {
    fn new(enabled: bool) -> FrameCache {
        FrameCache {
            enabled,
            map: Mutex::new(BTreeMap::new()),
        }
    }

    /// Fetch-or-render camera `cam`'s eval batch.
    fn eval_frames(
        &self,
        world: &World,
        cam: usize,
        res: usize,
        n: usize,
        salt: u64,
    ) -> Arc<Vec<Frame>> {
        if !self.enabled {
            return Arc::new(world.eval_frames(cam, res, n, salt));
        }
        let key = (cam, res, n, salt);
        // A worker that panicked mid-eval poisons the lock but can't leave a
        // partial entry (values are whole `Arc`s, inserted atomically), so
        // recovering the guard is always safe.
        if let Some(hit) = self.lock_map().get(&key) {
            return hit.clone();
        }
        let rendered = Arc::new(world.eval_frames(cam, res, n, salt));
        self.lock_map().entry(key).or_insert(rendered).clone()
    }

    fn lock_map(
        &self,
    ) -> std::sync::MutexGuard<'_, BTreeMap<(usize, usize, usize, u64), Arc<Vec<Frame>>>> {
        crate::util::sync::plock(&self.map)
    }

    /// Drop every entry; called whenever the world advances.
    fn invalidate(&self) {
        self.lock_map().clear();
    }
}

/// Camera-side agent state (indexed by camera id in `System::cams`).
pub(crate) struct CamAgent {
    pub(crate) flow: FlowId,
    pub(crate) controller: Controller,
    /// The device's current local model (flat params).
    pub(crate) theta: Vec<f32>,
    /// Active retraining job, if any.
    pub(crate) job: Option<usize>,
    pub(crate) plan: TransmissionPlan,
    /// Embedding of the distribution the current model was trained for.
    ref_embed: Option<Vec<f32>>,
    /// Previous window's embedding (for AMS scene dynamics).
    last_embed: Option<Vec<f32>>,
    /// Scene dynamics estimate in [0,1] (AMS baseline).
    pub(crate) dynamics: f32,
    pub(crate) last_acc: f32,
    delivered_prev: f64,
    last_request_t: f64,
}

/// Runtime state for the fault-injection subsystem (see [`crate::faults`]).
///
/// With an empty [`crate::faults::FaultPlan`] every field stays at its
/// initial value and every guard that consults it is pass-through, which is
/// what makes the no-fault path byte-identical to a build without faults.
struct FaultRt {
    /// Next unapplied event in the (sorted) plan.
    cursor: usize,
    /// Camera is currently dropped out (ignores probes, evals, publishes).
    cam_down: Vec<bool>,
    /// Current uplink capacity scale per camera (1.0 = healthy, 0.0 = down).
    link_scale: Vec<f64>,
    /// Window at which the camera's uplink first degraded (for recovery
    /// metrics); `None` when healthy.
    link_down_since: Vec<Option<usize>>,
    /// Camera is a straggler this window: its probe and sample uploads are
    /// lost, though transport bits are still spent.
    straggler: Vec<bool>,
    /// Probe embeddings from this camera are corrupted this window.
    corrupt: Vec<Option<CorruptMode>>,
    /// Consecutive lost probes (drives exponential backoff).
    probe_retries: Vec<u32>,
    /// Earliest sim time the camera may probe again after a lost probe.
    next_probe_t: Vec<f64>,
    /// Window at which the camera dropped out; cleared (into
    /// `recovery_windows`) once it is back above the response threshold.
    await_recovery: Vec<Option<usize>>,
    /// Parked models of jobs whose membership collapsed under faults:
    /// (job id, theta) so a rejoining camera resumes from its last state.
    parked: Vec<(usize, Vec<f32>)>,
    /// The job a dropped camera belonged to, for un-parking on rejoin.
    parked_of: Vec<Option<usize>>,
    /// A fault event fired during the current window.
    active_this_window: bool,
    /// Windows during which any fault was active (for the report).
    fault_windows: usize,
    /// Sum of end-of-window mean accuracy over fault-active windows.
    fault_acc_sum: f64,
    /// Windows-to-recover samples, one per completed recovery.
    recovery_windows: Vec<usize>,
}

impl FaultRt {
    fn new(n_cams: usize) -> FaultRt {
        FaultRt {
            cursor: 0,
            cam_down: vec![false; n_cams],
            link_scale: vec![1.0; n_cams],
            link_down_since: vec![None; n_cams],
            straggler: vec![false; n_cams],
            corrupt: vec![None; n_cams],
            probe_retries: vec![0; n_cams],
            next_probe_t: vec![f64::NEG_INFINITY; n_cams],
            await_recovery: vec![None; n_cams],
            parked: Vec::new(),
            parked_of: vec![None; n_cams],
            active_this_window: false,
            fault_windows: 0,
            fault_acc_sum: 0.0,
            recovery_windows: Vec::new(),
        }
    }
}

/// A full system run. Drivers never touch this directly: the only public
/// construction path is [`crate::api::Session`], and observation happens
/// through the typed event stream it wires up.
///
/// The engine borrow is **shared**: the engine's state is immutable
/// (manifest) plus atomic (stats), so independent evaluations fan out
/// across the engine's persistent worker pool and several systems can run
/// concurrently over one engine (the fleet driver). All mutable training
/// state lives in each job's [`ModelState`].
pub(crate) struct System<'e> {
    pub(crate) cfg: SystemConfig,
    pub(crate) world: World,
    pub(crate) engine: &'e Engine,
    pub(crate) net: NetSim,
    pub(crate) teacher: Teacher,
    pub(crate) jobs: Vec<Job>,
    /// Grouping bookkeeping, parallel to `jobs` by id.
    pub(crate) group_meta: Vec<GroupJob>,
    next_job_id: usize,
    pub(crate) cams: Vec<CamAgent>,
    pub(crate) zoo: ModelZoo,
    pub(crate) tracker: ResponseTracker,
    pub(crate) history: AccuracyHistory,
    pub(crate) window_idx: usize,
    allocator: Box<dyn Allocator>,
    /// Last window's GPU-share estimates per job id (p_j).
    pub(crate) shares: BTreeMap<usize, f64>,
    /// The typed observation stream (replaces the old log vectors).
    pub(crate) events: EventBus,
    /// Per-(cam, salt) eval-frame render cache, cleared on world advance.
    eval_cache: FrameCache,
    /// Fault-injection runtime state (inert when `cfg.faults` is empty).
    fault: FaultRt,
    /// Per-camera instant of the last capture event. Only consulted by the
    /// event scheduler for cameras on heterogeneous capture grids (uniform
    /// cameras always ingest exactly one micro-window of delivery).
    last_capture_t: Vec<f64>,
    rng: Pcg32,
    pretrained: Vec<f32>,
}

impl<'e> System<'e> {
    /// Build a system over a scenario world. `local_caps[i]` is camera i's
    /// uplink (Mbit/s); `shared_mbps` the common bottleneck.
    pub(crate) fn new(
        cfg: SystemConfig,
        world: World,
        local_caps: &[f64],
        shared_mbps: f64,
        engine: &'e Engine,
    ) -> Result<System<'e>> {
        if local_caps.len() != world.cameras.len() {
            bail!(
                "{} uplink capacities for {} cameras (counts must match)",
                local_caps.len(),
                world.cameras.len()
            );
        }
        // Per-camera window overrides are validated against the *resolved*
        // global window (configure hooks may have changed `window_secs`
        // after RunSpec validation).
        for (&cam, w) in &cfg.cam_windows {
            if cam >= world.cameras.len() {
                bail!(
                    "cam_windows targets camera {cam} but the scenario has {}",
                    world.cameras.len()
                );
            }
            let len = w.len_secs.unwrap_or(cfg.window_secs);
            if !(len.is_finite() && len > 0.0) {
                bail!("camera {cam}: window length must be positive and finite, got {len}");
            }
            if !(w.phase_secs.is_finite() && w.phase_secs >= 0.0 && w.phase_secs < len) {
                bail!(
                    "camera {cam}: phase {} must lie in [0, {len})",
                    w.phase_secs
                );
            }
        }
        let pretrained = pretrained_default(
            engine,
            cfg.task,
            cfg.pretrain_steps,
            cfg.lr,
            cfg.seed ^ 0xbeef,
        )?
        .theta;
        let mut net = NetSim::star(local_caps, shared_mbps);
        let mut cams = Vec::new();
        for cam in &world.cameras {
            let flow = net.add_camera_flow(cam.id, 1.0, 0.5)?;
            net.set_app_limit(flow, 0.05); // idle until retraining starts
            cams.push(CamAgent {
                flow,
                controller: Controller::for_mount(&cam.mount),
                theta: pretrained.clone(),
                job: None,
                plan: baseline_plan(1.0, EVAL_RES),
                ref_embed: None,
                last_embed: None,
                dynamics: 0.5,
                last_acc: 0.0,
                delivered_prev: 0.0,
                last_request_t: f64::NEG_INFINITY,
            });
        }
        let allocator = cfg.policy.alloc.build();
        let n_cams = cams.len();
        let eval_cache = FrameCache::new(cfg.frame_cache);
        let last_capture_t = vec![world.time; n_cams];
        Ok(System {
            teacher: Teacher::new(cfg.teacher.clone(), cfg.seed ^ 0x7ea),
            tracker: ResponseTracker::new(cfg.response_threshold),
            history: AccuracyHistory::new(n_cams),
            rng: Pcg32::new(cfg.seed, 0xa110c),
            zoo: ModelZoo::new(64),
            cfg,
            world,
            engine,
            net,
            jobs: Vec::new(),
            group_meta: Vec::new(),
            next_job_id: 0,
            cams,
            window_idx: 0,
            allocator,
            shares: BTreeMap::new(),
            events: EventBus::new(),
            eval_cache,
            fault: FaultRt::new(n_cams),
            last_capture_t,
            pretrained,
        })
    }

    /// This camera's own window length (global unless overridden).
    fn cam_window_len(&self, cam: usize) -> f64 {
        self.cfg
            .cam_windows
            .get(&cam)
            .and_then(|w| w.len_secs)
            .unwrap_or(self.cfg.window_secs)
    }

    /// Offset of the camera's first window boundary from the clock origin.
    fn cam_phase(&self, cam: usize) -> f64 {
        self.cfg
            .cam_windows
            .get(&cam)
            .map(|w| w.phase_secs)
            .unwrap_or(0.0)
    }

    /// Does this camera run on the server's own window grid?
    fn cam_uniform(&self, cam: usize) -> bool {
        match self.cfg.cam_windows.get(&cam) {
            None => true,
            Some(w) => {
                w.phase_secs == 0.0 && w.len_secs.is_none_or(|l| l == self.cfg.window_secs)
            }
        }
    }

    /// Any camera off the server grid forces the event scheduler.
    fn heterogeneous(&self) -> bool {
        (0..self.cams.len()).any(|cam| !self.cam_uniform(cam))
    }

    pub(crate) fn now(&self) -> f64 {
        self.world.time
    }

    fn job_index(&self, id: usize) -> Option<usize> {
        self.jobs.iter().position(|j| j.id == id)
    }

    // ------------------------------------------------------------------
    // Probing, drift detection, requests
    // ------------------------------------------------------------------

    /// Render a probe batch from the camera's current distribution and
    /// return (frames, mean embedding).
    fn probe(&mut self, cam: usize, salt: u64) -> Result<(Vec<Frame>, Vec<f32>)> {
        let m = self.engine.manifest.clone();
        let frames = self
            .world
            .eval_frames(cam, m.feature_res, m.infer_batch, salt);
        let refs: Vec<&Frame> = frames.iter().collect();
        let pixels = batch::pixel_tensor(&refs, m.infer_batch, m.feature_res);
        let emb = self.engine.features(&pixels)?;
        let mut mean = mean_embedding(&emb, m.embed_dim);
        // Fault injection: a corrupted probe leaves the frames intact but
        // mangles the embedding the server would act on.
        if let Some(mode) = self.fault.corrupt.get(cam).copied().flatten() {
            match mode {
                CorruptMode::Nan => mean.fill(f32::NAN),
                CorruptMode::Zero => mean.fill(0.0),
            }
        }
        Ok((frames, mean))
    }

    /// Camera-side drift check over the whole fleet (the lockstep cadence:
    /// every camera probes every micro-window).
    fn detect_and_request(&mut self) -> Result<()> {
        for cam in 0..self.cams.len() {
            self.detect_and_request_cam(cam)?;
        }
        Ok(())
    }

    /// One camera's drift check; issues a retraining request when the
    /// embedding moved beyond the threshold (or on the very first probe
    /// after deployment when accuracy already collapsed). The debounce
    /// interval follows the camera's own window length.
    fn detect_and_request_cam(&mut self, cam: usize) -> Result<()> {
        if !self.cfg.auto_request {
            return Ok(());
        }
        if self.fault.cam_down[cam] {
            return Ok(()); // dropped out: no device to probe
        }
        if self.cams[cam].job.is_some() {
            return Ok(()); // already retraining
        }
        if self.now() - self.cams[cam].last_request_t < self.cam_window_len(cam) * 0.5 {
            return Ok(()); // debounce
        }
        if self.now() < self.fault.next_probe_t[cam] {
            return Ok(()); // backing off after a lost probe
        }
        if self.fault.straggler[cam] {
            self.probe_lost(cam);
            return Ok(()); // straggler: the probe never reaches the server
        }
        let salt = (self.window_idx as u64) * 7919 + cam as u64 * 131 + 1;
        let (frames, emb) = self.probe(cam, salt)?;
        if !embedding_valid(&emb) {
            // Corrupted probe: discard rather than poison the drift
            // detector or the grouping metadata, and back off.
            self.probe_lost(cam);
            self.events.emit(Event::Degraded {
                time: self.now(),
                window: self.window_idx,
                component: "probe",
                detail: format!("cam {cam}: corrupt probe embedding discarded"),
            });
            return Ok(());
        }
        self.fault.probe_retries[cam] = 0;
        let drifted = match &self.cams[cam].ref_embed {
            None => {
                self.cams[cam].ref_embed = Some(emb.clone());
                false
            }
            Some(r) => l2(r, &emb) > self.cfg.drift_threshold,
        };
        self.update_dynamics(cam, &emb);
        if drifted {
            self.issue_request(cam, frames, emb)?;
        }
        Ok(())
    }

    fn update_dynamics(&mut self, cam: usize, emb: &[f32]) {
        let c = &mut self.cams[cam];
        if let Some(prev) = &c.last_embed {
            let d = l2(prev, emb);
            // Map embedding motion to [0,1] dynamics with a soft scale. A
            // non-finite distance (corrupt embedding that slipped through)
            // must not poison the EWMA.
            if d.is_finite() {
                let inst = (d / 0.08).clamp(0.0, 1.0);
                c.dynamics = 0.5 * c.dynamics + 0.5 * inst;
            }
        }
        c.last_embed = Some(emb.to_vec());
    }

    /// Register a lost/corrupt probe: bump the retry counter and push the
    /// camera's next probe attempt out by an exponentially growing delay
    /// (capped at 2^[`MAX_PROBE_RETRIES`] micro-windows).
    fn probe_lost(&mut self, cam: usize) {
        let retries = self.fault.probe_retries[cam].min(MAX_PROBE_RETRIES);
        self.fault.probe_retries[cam] = self.fault.probe_retries[cam].saturating_add(1);
        self.fault.next_probe_t[cam] = self.now() + self.cfg.mw_secs() * (1u32 << retries) as f64;
    }

    /// Process a retraining request (Alg. 2 GroupRequest).
    fn issue_request(&mut self, cam: usize, frames: Vec<Frame>, emb: Vec<f32>) -> Result<()> {
        let now = self.now();
        let loc = self.world.cameras[cam].position(now);
        // The admission bar: the camera's own model accuracy on the probe
        // (a micro-batch submission like every eval — see `eval_model`).
        let own_acc = eval_model(self.engine, self.cfg.task, &self.cams[cam].theta, &frames)?;
        let meta = RequestMeta {
            cam,
            time: now,
            loc,
            acc: own_acc,
        };
        self.cams[cam].last_request_t = now;
        self.tracker.request(cam, now);
        self.events.emit(Event::RetrainRequest {
            time: now,
            window: self.window_idx,
            cam,
            acc: own_acc,
        });
        self.place_request(meta, frames, emb)
    }

    /// Jobs the topology graph allows `cam` to consider: any job owning at
    /// least one of its spatial neighbors (O(degree) set construction).
    /// `None` lifts the pruning entirely — no topology configured, or a
    /// long-range probe window.
    fn neighbor_candidate_jobs(&self, cam: usize) -> Option<BTreeSet<usize>> {
        let topo = self.cfg.grouping.topology.as_ref()?;
        if topo.long_range_due(self.window_idx) {
            return None;
        }
        let mut set = BTreeSet::new();
        for &n in topo.neighbors(cam) {
            if let Some(Some(job_id)) = self.cams.get(n).map(|c| c.job) {
                set.insert(job_id);
            }
        }
        Some(set)
    }

    /// Shared by fresh requests and Alg. 2 evictions.
    fn place_request(
        &mut self,
        meta: RequestMeta,
        frames: Vec<Frame>,
        emb: Vec<f32>,
    ) -> Result<()> {
        let cam = meta.cam;
        let decision = if self.cfg.policy.group_retraining {
            // Evaluate candidate jobs' models on the request subsamples.
            // With the metadata filter on, only correlated jobs pay the
            // eval (the whole point of §3.3's pre-filtering); the ablation
            // switch makes EVERY job a candidate and pays for it. A
            // configured topology graph additionally prunes candidates to
            // jobs owning a spatial neighbor of the requester — O(degree)
            // evals per request instead of O(jobs). The candidate evals
            // are independent, so they fan out across the engine's worker
            // pool; index-ordered reduction keeps the decision (and the
            // event stream) identical at any pool size. Each eval submits
            // through the engine's micro-batch layer, so concurrent
            // candidates sharing a model coalesce into one kernel launch
            // when coalescing is enabled (bit-identical results).
            let allowed = self.neighbor_candidate_jobs(cam);
            let mut candidates: Vec<(usize, &[f32])> = Vec::new();
            for job in &self.group_meta {
                if let Some(set) = &allowed {
                    if !set.contains(&job.id) {
                        continue;
                    }
                }
                let candidate = !self.cfg.grouping.metadata_filter
                    || grouping::metadata_correlated(&self.cfg.grouping, job, &meta);
                if candidate {
                    if let Some(idx) = self.job_index(job.id) {
                        candidates.push((job.id, &self.jobs[idx].model.theta));
                    }
                }
            }
            let engine = self.engine;
            let task = self.cfg.task;
            let pool = engine.pool();
            let scored = pool.try_map(self.cfg.eval_threads, &candidates, |_, &(id, theta)| {
                eval_model(engine, task, theta, &frames).map(|acc| (id, acc))
            })?;
            let evals: BTreeMap<usize, f32> = scored.into_iter().collect();
            grouping::group_request_pruned(
                &mut self.group_meta,
                &mut self.next_job_id,
                &self.cfg.grouping,
                allowed.as_ref(),
                meta.clone(),
                |job_id| evals.get(&job_id).copied().unwrap_or(0.0),
            )
        } else {
            // Independent retraining: always a fresh job.
            let id = self.next_job_id;
            self.next_job_id += 1;
            self.group_meta.push(GroupJob::new(id, meta.clone()));
            Decision::NewJob(id)
        };

        match decision {
            Decision::Joined(job_id) => {
                // Grouping metadata normally always has a live training job
                // behind it; if a fault sequence evicted the job between the
                // decision and placement, rebuild one from the camera's own
                // model rather than crashing the coordinator.
                let idx = match self.job_index(job_id) {
                    Some(idx) => idx,
                    None => {
                        self.events.emit(Event::Degraded {
                            time: meta.time,
                            window: self.window_idx,
                            component: "grouping",
                            detail: format!("job {job_id} metadata had no training state; rebuilt"),
                        });
                        let parked = self.fault.parked.iter().position(|(id, _)| *id == job_id);
                        let theta = match parked {
                            Some(i) => self.fault.parked.swap_remove(i).1,
                            None => self.cams[cam].theta.clone(),
                        };
                        let model = ModelState::from_theta(self.cfg.task, theta);
                        self.jobs.push(Job::new(
                            job_id,
                            cam,
                            model,
                            self.cfg.buffer_cap,
                            meta.time,
                        ));
                        self.jobs.len() - 1
                    }
                };
                self.jobs[idx].add_member(cam);
                self.cams[cam].job = Some(job_id);
                self.push_probe_samples(idx, cam, frames);
                self.events.emit(Event::GroupJoined {
                    time: meta.time,
                    window: self.window_idx,
                    job: job_id,
                    cam,
                });
                crate::util::logger::log(
                    crate::util::logger::Level::Debug,
                    module_path!(),
                    &format!("cam {cam} joined job {job_id}"),
                );
            }
            Decision::NewJob(job_id) => {
                // Starting point: the device's own model, or a zoo match.
                let mut theta = self.cams[cam].theta.clone();
                if self.cfg.policy.zoo_warm_start {
                    if let Some(entry) = self.zoo.select(&emb, 0.6) {
                        theta = entry.theta.clone();
                    }
                }
                let model = ModelState::from_theta(self.cfg.task, theta);
                let job = Job::new(job_id, cam, model, self.cfg.buffer_cap, self.now());
                self.jobs.push(job);
                let idx = self.jobs.len() - 1;
                self.cams[cam].job = Some(job_id);
                self.push_probe_samples(idx, cam, frames);
                self.events.emit(Event::GroupFormed {
                    time: meta.time,
                    window: self.window_idx,
                    job: job_id,
                    cam,
                });
                crate::util::logger::log(
                    crate::util::logger::Level::Debug,
                    module_path!(),
                    &format!("cam {cam} started job {job_id}"),
                );
            }
        }
        // The model will be retrained for the *current* distribution.
        self.cams[cam].ref_embed = Some(emb);
        debug_assert!(
            grouping::is_partition(&self.group_meta),
            "request placement broke the one-job-per-camera partition"
        );
        Ok(())
    }

    /// Seed a job's buffer with the request's sampled frames.
    fn push_probe_samples(&mut self, job_idx: usize, cam: usize, frames: Vec<Frame>) {
        for f in frames {
            let labels = self.teacher.annotate(&f.truth);
            self.jobs[job_idx].push_sample(Sample {
                frame: f,
                labels,
                cam,
            });
        }
    }

    // ------------------------------------------------------------------
    // Transmission
    // ------------------------------------------------------------------

    /// Push GPU allocation info to cameras and (re)configure their flows
    /// for the coming window.
    fn apply_transmission_plans(&mut self) {
        let n_jobs = self.jobs.len().max(1);
        for cam in 0..self.cams.len() {
            let Some(job_id) = self.cams[cam].job else {
                let flow = self.cams[cam].flow;
                self.net.set_app_limit(flow, 0.05);
                continue;
            };
            let Some(job_idx) = self.job_index(job_id) else {
                // The camera's job was evicted by a fault mid-window: idle
                // the flow and let the normal drift-probe path re-place it.
                self.events.emit(Event::Degraded {
                    time: self.now(),
                    window: self.window_idx,
                    component: "transmission",
                    detail: format!("cam {cam}: job {job_id} gone; uplink idled"),
                });
                self.cams[cam].job = None;
                let flow = self.cams[cam].flow;
                self.net.set_app_limit(flow, 0.05);
                continue;
            };
            let n_members = self.jobs[job_idx].n_cams();
            let plan = match &self.cfg.policy.transmission {
                TransmissionKind::Ecco => {
                    let p_j = *self
                        .shares
                        .get(&job_id)
                        .unwrap_or(&(1.0 / n_jobs as f64));
                    let budget_pps = p_j * self.cfg.gpus * self.cfg.gpu_pps;
                    let info = GpuAllocationInfo {
                        group_budget_pps: budget_pps,
                        share_weight: p_j,
                        group_size: n_members,
                    };
                    self.cams[cam].controller.plan(info)
                }
                TransmissionKind::Fixed { fps, res } => baseline_plan(*fps, *res),
                TransmissionKind::Ams { base_fps, res } => {
                    ams_plan(*base_fps, *res, self.cams[cam].dynamics)
                }
            };
            let flow = self.cams[cam].flow;
            self.net.set_params(flow, plan.gaimd_alpha, plan.gaimd_beta);
            self.net.set_app_limit(flow, plan.app_limit_mbps);
            self.cams[cam].plan = plan;
        }
    }

    /// Ingest the frames each camera's delivered bandwidth paid for.
    ///
    /// Capture instants are **spread across the micro-window** at the
    /// plan's effective frame spacing: the world's drift processes advance
    /// once per micro-window, but mobile cameras keep moving between
    /// frames and frame content is seeded by the capture instant — so a
    /// higher-fps plan buys genuinely distinct observations instead of
    /// noise-duplicated copies of the micro-window's final timestamp.
    fn collect_data(&mut self, mw_secs: f64) -> Result<()> {
        for cam in 0..self.cams.len() {
            self.collect_cam(cam, mw_secs)?;
        }
        Ok(())
    }

    /// Ingest one camera's delivery over its last `dur_secs` of transport
    /// (one micro-window in lockstep; possibly several ticks for a camera
    /// on a sparse heterogeneous capture grid).
    fn collect_cam(&mut self, cam: usize, dur_secs: f64) -> Result<()> {
        let t_end = self.now();
        self.last_capture_t[cam] = t_end;
        let Some(job_id) = self.cams[cam].job else {
            return Ok(());
        };
        let flow = self.cams[cam].flow;
        let total = self.net.delivered_mbit(flow);
        let delta = (total - self.cams[cam].delivered_prev).max(0.0);
        self.cams[cam].delivered_prev = total;
        if self.fault.straggler[cam] {
            return Ok(()); // straggler: bits were spent but uploads are lost
        }
        let plan = self.cams[cam].plan;
        let outcome = transport_window(plan.config, dur_secs, delta);
        let n = outcome.frames_delivered.min(MAX_FRAMES_PER_MW);
        if n == 0 {
            return Ok(());
        }
        let Some(job_idx) = self.job_index(job_id) else {
            self.events.emit(Event::Degraded {
                time: self.now(),
                window: self.window_idx,
                component: "ingest",
                detail: format!("cam {cam}: job {job_id} gone; {n} frames dropped"),
            });
            self.cams[cam].job = None;
            return Ok(());
        };
        for i in 0..n {
            let t = t_end - dur_secs + ((i + 1) as f64 / n as f64) * dur_secs;
            let mut frame = self.world.capture_at(cam, plan.config.res, t);
            let seed = self.rng.next_u64().wrapping_add(i as u64);
            degrade(&mut frame.pixels, plan.config.res, outcome.quality, seed);
            let labels = self.teacher.annotate(&frame.truth);
            self.jobs[job_idx].push_sample(Sample {
                frame,
                labels,
                cam,
            });
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // GPU micro-window scheduling (Alg. 1)
    // ------------------------------------------------------------------

    /// Mean accuracy of a job's model over its members' live streams. The
    /// per-member evals are independent (held-out frames are derived from
    /// (window, cam) salts, not the run RNG) and fan out across the
    /// engine's worker pool; the sum reduces in member order, so the
    /// result is bit-equal to the serial loop at any pool size. Frames
    /// come from the eval cache: the pre-/post-training eval pair of a
    /// micro-window shares one render per member. Every member evaluates
    /// the same job model, so with micro-batch coalescing enabled the
    /// concurrent submissions merge into mega-batched launches — the
    /// canonical win case for the submission layer.
    fn eval_job(&self, job_idx: usize) -> Result<f32> {
        let job = &self.jobs[job_idx];
        let theta = &job.model.theta;
        let engine = self.engine;
        let task = self.cfg.task;
        let world = &self.world;
        let cache = &self.eval_cache;
        let eval_frames = self.cfg.eval_frames;
        let window = self.window_idx as u64;
        let pool = engine.pool();
        let accs = pool.try_map(self.cfg.eval_threads, &job.members, |_, &cam| {
            let salt = window * 104_729 + cam as u64 * 7 + 3;
            let frames = cache.eval_frames(world, cam, EVAL_RES, eval_frames, salt);
            eval_model(engine, task, theta, &frames)
        })?;
        Ok(accs.iter().sum::<f32>() / job.members.len().max(1) as f32)
    }

    fn job_views(&self) -> Vec<JobView> {
        self.jobs
            .iter()
            .map(|j| {
                debug_assert!(
                    !j.acc_gain.is_nan() && !j.acc.is_nan(),
                    "job {} feeds NaN into the allocator",
                    j.id
                );
                JobView {
                    id: j.id,
                    n_cams: j.n_cams(),
                    acc: j.acc,
                    acc_gain: j.acc_gain,
                    micro_windows: j.micro_windows,
                    lifetime_mw: j.lifetime_mw,
                }
            })
            .collect()
    }

    /// One micro-window: pick a job, train it on all GPUs, re-evaluate
    /// (Alg. 1 MicroRetraining).
    fn train_micro_window(&mut self, mw: usize, mw_secs: f64) -> Result<()> {
        if self.jobs.is_empty() {
            return Ok(());
        }
        let views = self.job_views();
        let pick_id = self.allocator.pick(&views);
        let Some(job_idx) = self.job_index(pick_id) else {
            // An allocator bug must degrade to a skipped micro-window, not
            // a crashed run: the budget is lost but the window completes.
            self.events.emit(Event::Degraded {
                time: self.now(),
                window: self.window_idx,
                component: "alloc",
                detail: format!("allocator picked unknown job {pick_id}; micro-window skipped"),
            });
            return Ok(());
        };
        self.events.emit(Event::Alloc {
            window: self.window_idx,
            micro_window: mw,
            job: pick_id,
        });

        let acc_i = self.eval_job(job_idx)?;
        let res = self.jobs[job_idx].train_res().unwrap_or(EVAL_RES);
        let steps = self
            .cfg
            .steps_for(res, self.engine.manifest.train_batch, mw_secs);
        let lr = self.cfg.lr;
        let mut rng = self.rng.fork(pick_id as u64);
        self.jobs[job_idx].train(self.engine, steps, lr, &mut rng)?;
        let acc_f = self.eval_job(job_idx)?;
        debug_assert!(
            !acc_i.is_nan() && !acc_f.is_nan(),
            "job {pick_id} produced a NaN accuracy"
        );
        let job = &mut self.jobs[job_idx];
        job.acc = acc_f;
        job.acc_gain = acc_f - acc_i;
        job.micro_windows += 1;
        job.lifetime_mw += 1;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Window boundary
    // ------------------------------------------------------------------

    fn end_window(&mut self) -> Result<()> {
        let now = self.now();
        // Publish updated models to member devices. A device that is down
        // or behind a dead uplink cannot receive the push: it keeps serving
        // its last good model and the publish is deferred (the next healthy
        // window's publish covers it).
        for j in 0..self.jobs.len() {
            let theta = self.jobs[j].model.theta.clone();
            let members = self.jobs[j].members.clone();
            let mut published = Vec::with_capacity(members.len());
            for &cam in &members {
                if self.fault.cam_down[cam] || self.fault.link_scale[cam] <= 0.0 {
                    self.events.emit(Event::Degraded {
                        time: now,
                        window: self.window_idx,
                        component: "publish",
                        detail: format!("cam {cam}: model publish deferred (device unreachable)"),
                    });
                    continue;
                }
                self.cams[cam].theta = theta.clone();
                published.push(cam);
            }
            self.events.emit(Event::ModelPublished {
                time: now,
                window: self.window_idx,
                job: self.jobs[j].id,
                cams: published,
            });
        }
        // Per-camera accuracy measurement (live model on live stream),
        // fanned out across the engine's worker pool — one eval per
        // camera, reduced in camera order so downstream bookkeeping is
        // order-identical. Renders go through the eval cache, so cameras
        // sharing a (cam, salt) key with a later consumer this window
        // render once. After a group publish, members hold value-equal
        // theta clones, so their concurrent submissions coalesce into
        // shared kernel launches when micro-batching is enabled (the
        // coalesce key hashes theta *content*, not pointers).
        let accs = {
            let engine = self.engine;
            let task = self.cfg.task;
            let world = &self.world;
            let cache = &self.eval_cache;
            let eval_frames = self.cfg.eval_frames;
            let window = self.window_idx as u64;
            let down = &self.fault.cam_down;
            let pool = engine.pool();
            pool.try_map(self.cfg.eval_threads, &self.cams, |cam, agent| {
                if down[cam] {
                    // No live stream to measure: carry the last known value.
                    return Ok(agent.last_acc);
                }
                let salt = window * 31_337 + cam as u64;
                let frames = cache.eval_frames(world, cam, EVAL_RES, eval_frames, salt);
                eval_model(engine, task, &agent.theta, &frames)
            })?
        };
        for (cam, acc) in accs.into_iter().enumerate() {
            self.cams[cam].last_acc = acc;
            self.history.push(cam, now, acc);
            if !self.fault.cam_down[cam] {
                self.tracker.observe(cam, now, acc);
            }
        }
        // A camera counts as recovered once it is back online and its live
        // accuracy clears the response threshold again.
        for cam in 0..self.cams.len() {
            if self.fault.cam_down[cam] {
                continue;
            }
            let Some(since) = self.fault.await_recovery[cam] else {
                continue;
            };
            if self.cams[cam].last_acc >= self.cfg.response_threshold {
                self.fault.await_recovery[cam] = None;
                let windows = self.window_idx.saturating_sub(since);
                self.fault.recovery_windows.push(windows);
                self.events.emit(Event::FaultRecovered {
                    time: now,
                    window: self.window_idx,
                    cam,
                    kind: "camera",
                    windows,
                });
            }
        }
        // RECL zoo maintenance: store retrained models with signatures
        // (periodically — zoo updates carry overhead, §5.1).
        if self.cfg.policy.zoo_warm_start
            && self.window_idx.is_multiple_of(self.cfg.zoo_update_interval)
        {
            for j in 0..self.jobs.len() {
                if self.jobs[j].micro_windows == 0 {
                    continue;
                }
                let Some(&cam0) = self.jobs[j].members.first() else {
                    continue;
                };
                let salt = (self.window_idx as u64) * 977 + cam0 as u64;
                let (_, emb) = self.probe(cam0, salt)?;
                if !embedding_valid(&emb) {
                    continue; // never key the zoo on a corrupt signature
                }
                let theta = self.jobs[j].model.theta.clone();
                let label = format!("job{}-w{}", self.jobs[j].id, self.window_idx);
                self.zoo.insert(theta, emb, &label);
            }
        }
        // Close the window on the event stream: live accuracies plus the
        // pre-regroup membership snapshot (the timeline plots' shape).
        let snapshot: MembershipSnapshot = self
            .jobs
            .iter()
            .map(|j| (j.id, j.members.clone()))
            .collect();
        let cam_acc: Vec<f32> = self.cams.iter().map(|c| c.last_acc).collect();
        self.events.emit(Event::WindowClosed {
            time: now,
            window: self.window_idx,
            mean_acc: self.history.final_mean(),
            cam_acc,
            membership: snapshot,
        });
        // Resilience accounting: a window counts as fault-active when an
        // event fired in it or a degradation persists from earlier ones.
        if !self.cfg.faults.is_empty() {
            let degraded = self.fault.active_this_window
                || self.fault.cam_down.iter().any(|&d| d)
                || self.fault.link_scale.iter().any(|&s| s < 1.0)
                || self.fault.straggler.iter().any(|&s| s)
                || self.fault.corrupt.iter().any(|c| c.is_some());
            if degraded {
                self.fault.fault_windows += 1;
                self.fault.fault_acc_sum += self.history.final_mean() as f64;
            }
        }
        // Periodic regrouping (Alg. 2 UpdateGrouping).
        if self.cfg.policy.group_retraining && self.cfg.auto_regroup {
            self.regroup()?;
        }
        // GPU-share estimates for the coming window (Alg. 1 line 15), with
        // a small uniform floor: a group estimated at ~zero share would get
        // ~zero bandwidth, hence zero data, hence zero measured gain — a
        // starvation feedback loop the best-effort controller must avoid.
        if !self.jobs.is_empty() {
            let views = self.job_views();
            let shares = self.allocator.share_estimates(&views);
            let n = views.len() as f64;
            let mut next = BTreeMap::new();
            for (v, p) in views.iter().zip(shares) {
                let fresh = 0.8 * p + 0.2 / n;
                // EWMA across windows: single-window gain estimates are
                // noisy, and bandwidth plans should not whipsaw.
                let prev = self.shares.get(&v.id).copied().unwrap_or(1.0 / n);
                next.insert(v.id, 0.5 * prev + 0.5 * fresh);
            }
            // Renormalise (membership may have changed).
            let total: f64 = next.values().sum();
            if total > 0.0 {
                for p in next.values_mut() {
                    *p /= total;
                }
            }
            self.shares = next;
        }
        // Reset per-window counters.
        for j in &mut self.jobs {
            j.micro_windows = 0;
        }
        // Window-scoped faults (stragglers, corrupt probes) expire here.
        if !self.cfg.faults.is_empty() {
            self.fault.active_this_window = false;
            self.fault.straggler.fill(false);
            self.fault.corrupt.fill(None);
        }
        Ok(())
    }

    fn regroup(&mut self) -> Result<()> {
        // Evaluate every (job, member) pair on fresh member data — the
        // largest eval fan-out in the loop (|jobs| x |members| calls), run
        // on the engine's worker pool. Pair order (job-major, member
        // order) matches the old serial nesting, and the BTreeMap
        // reduction is keyed, so the grouping decision is identical at any
        // pool size. The eval cache collapses a camera's render to once
        // per window here no matter how many jobs evaluate it. A job's
        // members all submit the same theta, so the matrix's rows coalesce
        // into mega-batched launches when micro-batching is enabled.
        let evals: BTreeMap<(usize, usize), f32> = {
            let mut pairs: Vec<(usize, usize, &[f32])> = Vec::new();
            for job in &self.jobs {
                for &cam in &job.members {
                    pairs.push((job.id, cam, &job.model.theta));
                }
            }
            let engine = self.engine;
            let task = self.cfg.task;
            let world = &self.world;
            let cache = &self.eval_cache;
            let eval_frames = self.cfg.eval_frames;
            let window = self.window_idx as u64;
            let pool = engine.pool();
            let scored = pool.try_map(self.cfg.eval_threads, &pairs, |_, &(job_id, cam, theta)| {
                let salt = window * 523 + cam as u64 * 11;
                let frames = cache.eval_frames(world, cam, EVAL_RES, eval_frames, salt);
                eval_model(engine, task, theta, &frames).map(|acc| ((job_id, cam), acc))
            })?;
            scored.into_iter().collect()
        };
        let now = self.now();
        let world = &self.world;
        let evicted = grouping::update_grouping(
            &mut self.group_meta,
            &self.cfg.grouping,
            now,
            |cam| world.cameras[cam].position(now),
            |job_id, cam| evals.get(&(job_id, cam)).copied().unwrap_or(0.0),
        );
        for ev in evicted {
            let cam = ev.meta.cam;
            if let Some(idx) = self.job_index(ev.job_id) {
                self.jobs[idx].remove_member(cam);
            }
            self.cams[cam].job = None;
            self.cams[cam].last_request_t = now;
            self.events.emit(Event::GroupSplit {
                time: now,
                window: self.window_idx,
                job: ev.job_id,
                cam,
            });
            crate::util::logger::log(
                crate::util::logger::Level::Debug,
                module_path!(),
                &format!("cam {cam} evicted from job {}", ev.job_id),
            );
            // Re-enter the grouping pipeline as a fresh request.
            let salt = (self.window_idx as u64) * 6151 + cam as u64 * 13 + 9;
            let (frames, emb) = self.probe(cam, salt)?;
            if !embedding_valid(&emb) {
                // Re-placement probe corrupted: defer — the camera retries
                // through the normal drift path with backoff next window.
                self.probe_lost(cam);
                self.events.emit(Event::Degraded {
                    time: now,
                    window: self.window_idx,
                    component: "probe",
                    detail: format!("cam {cam}: re-placement probe corrupt; deferred"),
                });
                continue;
            }
            self.tracker.request(cam, now);
            self.events.emit(Event::RetrainRequest {
                time: now,
                window: self.window_idx,
                cam,
                acc: ev.meta.acc,
            });
            self.place_request(ev.meta, frames, emb)?;
        }
        // Drop empty jobs.
        self.jobs.retain(|j| !j.members.is_empty());
        debug_assert!(
            grouping::is_partition(&self.group_meta),
            "regroup broke the one-job-per-camera partition"
        );
        Ok(())
    }

    // ------------------------------------------------------------------
    // Fault injection (see crate::faults)
    // ------------------------------------------------------------------

    /// Apply every scheduled fault event up to `(window_idx, upto_mw)`.
    /// Returns whether anything was applied. With an empty plan this is a
    /// single branch — the zero-cost guarantee's hot-path cost.
    fn apply_fault_events(&mut self, upto_mw: usize) -> Result<bool> {
        if self.cfg.faults.is_empty() {
            return Ok(false);
        }
        let mut applied = false;
        loop {
            let Some(&ev) = self.cfg.faults.get(self.fault.cursor) else {
                break;
            };
            if ev.window > self.window_idx || (ev.window == self.window_idx && ev.mw > upto_mw) {
                break;
            }
            self.fault.cursor += 1;
            self.apply_fault(ev);
            applied = true;
        }
        if applied {
            self.fault.active_this_window = true;
        }
        Ok(applied)
    }

    /// Apply one fault event. All handlers are idempotent: a plan that
    /// repeats an event (or restores an already-healthy link) is a no-op
    /// rather than a double-count.
    fn apply_fault(&mut self, ev: FaultEvent) {
        let cam = ev.cam;
        if cam >= self.cams.len() {
            return; // plan targets a camera this scenario doesn't have
        }
        let now = self.now();
        match ev.kind {
            FaultKind::CameraDown => {
                if self.fault.cam_down[cam] {
                    return;
                }
                self.fault.cam_down[cam] = true;
                self.fault.await_recovery[cam].get_or_insert(self.window_idx);
                self.events.emit(Event::CameraDown {
                    time: now,
                    window: self.window_idx,
                    cam,
                });
                self.fault_detach(cam);
                let flow = self.cams[cam].flow;
                self.net.set_app_limit(flow, 0.0);
            }
            FaultKind::CameraUp => {
                if !self.fault.cam_down[cam] {
                    return;
                }
                self.fault.cam_down[cam] = false;
                self.events.emit(Event::CameraUp {
                    time: now,
                    window: self.window_idx,
                    cam,
                });
                // Re-arm the probe path: the rejoining device goes through
                // the normal drift-detection pipeline immediately.
                self.cams[cam].last_request_t = f64::NEG_INFINITY;
                self.fault.next_probe_t[cam] = f64::NEG_INFINITY;
                self.fault.probe_retries[cam] = 0;
                // Its delivered-bytes ledger moved while it was detached.
                let flow = self.cams[cam].flow;
                self.cams[cam].delivered_prev = self.net.delivered_mbit(flow);
                // If its old job's model was parked, restore it locally so
                // the device resumes from its last trained state.
                if let Some(job_id) = self.fault.parked_of[cam].take() {
                    if let Some((_, theta)) =
                        self.fault.parked.iter().find(|(id, _)| *id == job_id)
                    {
                        self.cams[cam].theta = theta.clone();
                    }
                }
            }
            FaultKind::UplinkDown => self.set_uplink_scale(cam, 0.0),
            FaultKind::UplinkScale { factor } => {
                self.set_uplink_scale(cam, factor.clamp(0.0, 1.0));
            }
            FaultKind::UplinkRestore => {
                let Some(since) = self.fault.link_down_since[cam].take() else {
                    return; // link already healthy
                };
                self.fault.link_scale[cam] = 1.0;
                let link = self.net.flow_uplink(self.cams[cam].flow);
                self.net.set_link_up(link, true);
                self.net.set_link_capacity_scale(link, 1.0);
                let windows = self.window_idx.saturating_sub(since);
                self.fault.recovery_windows.push(windows);
                self.events.emit(Event::FaultRecovered {
                    time: now,
                    window: self.window_idx,
                    cam,
                    kind: "uplink",
                    windows,
                });
            }
            FaultKind::StragglerWindow => {
                if self.fault.straggler[cam] {
                    return;
                }
                self.fault.straggler[cam] = true;
                self.events.emit(Event::Degraded {
                    time: now,
                    window: self.window_idx,
                    component: "camera",
                    detail: format!("cam {cam}: straggling this window (uploads lost)"),
                });
            }
            FaultKind::CorruptProbe { mode } => {
                self.fault.corrupt[cam] = Some(mode);
            }
        }
    }

    /// Degrade a camera's uplink to `factor` x capacity (0.0 = outage).
    fn set_uplink_scale(&mut self, cam: usize, factor: f64) {
        if self.fault.link_scale[cam] == factor {
            return; // idempotent: no duplicate events
        }
        self.fault.link_scale[cam] = factor;
        if factor < 1.0 {
            self.fault.link_down_since[cam].get_or_insert(self.window_idx);
        }
        let link = self.net.flow_uplink(self.cams[cam].flow);
        if factor <= 0.0 {
            self.net.set_link_up(link, false);
        } else {
            self.net.set_link_up(link, true);
            self.net.set_link_capacity_scale(link, factor);
        }
        self.events.emit(Event::LinkDegraded {
            time: self.now(),
            window: self.window_idx,
            cam,
            factor,
        });
    }

    /// Detach a dead camera from its job without stalling the group: the
    /// survivors keep training; a job emptied by the detach has its model
    /// parked for the camera's eventual rejoin.
    fn fault_detach(&mut self, cam: usize) {
        let Some(job_id) = self.cams[cam].job.take() else {
            return;
        };
        self.fault.parked_of[cam] = Some(job_id);
        if let Some(idx) = self.job_index(job_id) {
            self.jobs[idx].remove_member(cam);
            if self.jobs[idx].members.is_empty() {
                let job = self.jobs.remove(idx);
                self.fault.parked.retain(|(id, _)| *id != job_id);
                self.fault.parked.push((job_id, job.model.theta));
            }
        }
        for meta in &mut self.group_meta {
            if meta.id == job_id {
                meta.members.retain(|m| m.cam != cam);
            }
        }
        self.group_meta.retain(|g| !g.members.is_empty());
        self.events.emit(Event::GroupSplit {
            time: self.now(),
            window: self.window_idx,
            job: job_id,
            cam,
        });
    }

    /// Re-split the GPU budget over the surviving jobs after membership
    /// changed mid-window (dead shares would otherwise starve survivors).
    fn resplit_after_faults(&mut self) {
        let live: Vec<usize> = self.jobs.iter().map(|j| j.id).collect();
        resplit_shares(&mut self.shares, &live);
    }

    /// Resilience counters for the report:
    /// (fault-active windows, their accuracy sum, windows-to-recover samples).
    pub(crate) fn fault_summary(&self) -> (usize, f64, &[usize]) {
        (
            self.fault.fault_windows,
            self.fault.fault_acc_sum,
            &self.fault.recovery_windows,
        )
    }

    // ------------------------------------------------------------------
    // Public driver
    // ------------------------------------------------------------------

    /// Run one retraining window under the configured scheduler. Any
    /// per-camera window override forces the event driver (the lockstep
    /// loop cannot express staggered boundaries).
    pub(crate) fn run_window(&mut self) -> Result<()> {
        if self.cfg.scheduler == Scheduler::EventDriven || self.heterogeneous() {
            self.run_window_events()
        } else {
            self.run_window_lockstep()
        }
    }

    /// The legacy lockstep driver: every camera advances in unison.
    fn run_window_lockstep(&mut self) -> Result<()> {
        if self.apply_fault_events(0)? {
            self.resplit_after_faults();
        }
        if self.window_idx == 0 {
            // Establish the deployment-time drift references before any
            // simulated time passes (the pretraining distribution).
            self.detect_and_request()?;
        }
        self.apply_transmission_plans();
        // Alg. 1: W micro-windows per window; W scales with the job count so
        // the initial training pass leaves room for greedy allocation.
        let w_eff = self.cfg.effective_micro_windows(self.jobs.len());
        let mw_secs = self.cfg.window_secs / w_eff as f64;
        for mw in 0..w_eff {
            if mw > 0 && self.apply_fault_events(mw)? {
                // Membership or link state changed mid-window: re-split the
                // GPU budget over the survivors and re-push plans.
                self.resplit_after_faults();
                self.apply_transmission_plans();
            }
            self.net.run(mw_secs);
            self.world.advance(mw_secs);
            // The world moved: every cached eval render is stale.
            self.eval_cache.invalidate();
            self.collect_data(mw_secs)?;
            self.detect_and_request()?;
            self.train_micro_window(mw, mw_secs)?;
        }
        // Drain events scheduled past the effective micro-window count so
        // no fault is silently skipped when W shrinks.
        if self.apply_fault_events(usize::MAX)? {
            self.resplit_after_faults();
        }
        self.end_window()?;
        self.window_idx += 1;
        Ok(())
    }

    /// The event/time-wheel driver (see [`crate::server::sched`]).
    ///
    /// The clock is slot-quantised: each of the window's `w_eff` ticks
    /// advances the network and world by exactly `mw_secs` — the same
    /// repeated-increment accumulation the lockstep loop performs — and
    /// then drains the wheel's events due at that tick in `(action, cam)`
    /// order. A uniform fleet schedules capture + probe for every camera
    /// at every tick and one training event per tick, which replays the
    /// lockstep body statement for statement; the event log is therefore
    /// byte-identical (a property test pins this). Heterogeneous cameras
    /// instead get events on their own `phase + k·step` grids, plus
    /// mid-window [`Action::CamWindowEnd`] boundaries.
    ///
    /// Fault drains stay inline (not wheel events): the lockstep cursor
    /// applies coordinate `m` *before* tick `m`'s time advance, and the
    /// end-of-window drain runs after the last tick without re-pushing
    /// transmission plans — both reproduced here exactly.
    fn run_window_events(&mut self) -> Result<()> {
        if self.apply_fault_events(0)? {
            self.resplit_after_faults();
        }
        if self.window_idx == 0 {
            self.detect_and_request()?;
        }
        self.apply_transmission_plans();
        let w_eff = self.cfg.effective_micro_windows(self.jobs.len());
        let mw_secs = self.cfg.window_secs / w_eff as f64;
        let t0 = self.now();
        let mut wheel = EventWheel::new();
        for mw in 0..w_eff {
            wheel.push(SchedEvent::train(mw + 1, mw));
        }
        for cam in 0..self.cams.len() {
            if self.cam_uniform(cam) {
                // Server-grid camera: due at every tick, the lockstep
                // cadence.
                for slot in 1..=w_eff {
                    wheel.push(SchedEvent::capture(slot, cam));
                    wheel.push(SchedEvent::probe(slot, cam));
                }
            } else {
                let len = self.cam_window_len(cam);
                let phase = self.cam_phase(cam);
                // The camera's own capture/probe grid: w_eff instants per
                // *its* window, quantised to the global ticks.
                let step = len / w_eff as f64;
                for slot in slots_for_grid(t0, self.cfg.window_secs, mw_secs, phase, step, w_eff) {
                    wheel.push(SchedEvent::capture(slot, cam));
                    wheel.push(SchedEvent::probe(slot, cam));
                }
                // Its own window boundaries that fall strictly inside the
                // server window; the shared boundary is end_window's job.
                for slot in slots_for_grid(t0, self.cfg.window_secs, mw_secs, phase, len, w_eff) {
                    if slot < w_eff {
                        wheel.push(SchedEvent::cam_window_end(slot, cam));
                    }
                }
            }
        }
        for slot in 1..=w_eff {
            let mw = slot - 1;
            if mw > 0 && self.apply_fault_events(mw)? {
                self.resplit_after_faults();
                self.apply_transmission_plans();
            }
            self.net.run(mw_secs);
            self.world.advance(mw_secs);
            self.eval_cache.invalidate();
            while let Some(ev) = wheel.pop_due(slot) {
                match ev.action {
                    Action::Capture => {
                        let dur = if self.cam_uniform(ev.cam) {
                            mw_secs
                        } else {
                            (self.now() - self.last_capture_t[ev.cam]).max(0.0)
                        };
                        self.collect_cam(ev.cam, dur)?;
                    }
                    Action::Probe => self.detect_and_request_cam(ev.cam)?,
                    Action::Train(m) => self.train_micro_window(m, mw_secs)?,
                    Action::CamWindowEnd => self.cam_window_end_boundary(ev.cam, slot)?,
                }
            }
        }
        if self.apply_fault_events(usize::MAX)? {
            self.resplit_after_faults();
        }
        self.end_window()?;
        self.window_idx += 1;
        Ok(())
    }

    /// A heterogeneous camera's own window boundary, mid-server-window
    /// (event scheduler only): refresh the device from its job's current
    /// model when reachable, then measure its live stream so accuracy
    /// history and response tracking run at the camera's own cadence.
    fn cam_window_end_boundary(&mut self, cam: usize, slot: usize) -> Result<()> {
        if self.fault.cam_down[cam] {
            return Ok(()); // no device to publish to or measure
        }
        let now = self.now();
        if let Some(job_id) = self.cams[cam].job {
            if let Some(idx) = self.job_index(job_id) {
                if self.fault.link_scale[cam] > 0.0 {
                    self.cams[cam].theta = self.jobs[idx].model.theta.clone();
                    self.events.emit(Event::ModelPublished {
                        time: now,
                        window: self.window_idx,
                        job: job_id,
                        cams: vec![cam],
                    });
                }
            }
        }
        // The salt folds the slot in so staggered boundaries never collide
        // with the end-of-window measurement pass. This history eval runs
        // serially per boundary, but it still submits through the engine's
        // micro-batch layer, so it can share a launch with whatever the
        // pool is evaluating concurrently (a lone submitter skips the
        // coalesce window and pays nothing).
        let salt = (self.window_idx as u64 * 131 + slot as u64) * 31_337 + cam as u64;
        let frames =
            self.eval_cache
                .eval_frames(&self.world, cam, EVAL_RES, self.cfg.eval_frames, salt);
        let acc = eval_model(self.engine, self.cfg.task, &self.cams[cam].theta, &frames)?;
        self.cams[cam].last_acc = acc;
        self.history.push(cam, now, acc);
        self.tracker.observe(cam, now, acc);
        Ok(())
    }

    /// Mean camera accuracy at the latest window.
    pub(crate) fn mean_accuracy(&self) -> f32 {
        self.history.final_mean()
    }

    /// Populate the model zoo RECL-style: fine-tune the pretrained student
    /// briefly on each camera's *initial* distribution and store it.
    pub(crate) fn populate_zoo_from_initial(&mut self, steps: usize) -> Result<()> {
        for cam in 0..self.cams.len() {
            let state0 = self.world.camera_state(cam);
            let mut model = ModelState::from_theta(self.cfg.task, self.pretrained.clone());
            let m = &self.engine.manifest;
            let mut rng = Pcg32::new(self.cfg.seed ^ 0x200, cam as u64);
            let pool: Vec<Frame> = (0..32)
                .map(|i| crate::scene::render(&state0, EVAL_RES, 0x900d + cam as u64 * 97 + i))
                .collect();
            let labels: Vec<_> = pool
                .iter()
                .map(|f| self.teacher.annotate(&f.truth))
                .collect();
            for _ in 0..steps {
                let picks: Vec<usize> =
                    (0..m.train_batch).map(|_| rng.index(pool.len())).collect();
                let frames: Vec<&Frame> = picks.iter().map(|&i| &pool[i]).collect();
                let truths: Vec<_> = picks.iter().map(|&i| &labels[i]).collect();
                let tb = batch::train_batch(
                    self.cfg.task,
                    &frames,
                    &truths,
                    m.train_batch,
                    EVAL_RES,
                    m.classes,
                    m.grid,
                );
                self.engine.train_step(&mut model, &tb, self.cfg.lr)?;
            }
            let salt = 0xf00d + cam as u64;
            let (_, emb) = self.probe(cam, salt)?;
            self.zoo.insert(model.theta, emb, &format!("init-cam{cam}"));
        }
        Ok(())
    }

    /// Swap the GPU allocator (ablation experiments).
    pub(crate) fn set_allocator(&mut self, allocator: Box<dyn Allocator>) {
        self.allocator = allocator;
    }

    /// Scripted retraining request (Fig. 12-style experiments with
    /// `auto_request = false`): probe the camera now and run it through the
    /// normal grouping pipeline.
    pub(crate) fn request_now(&mut self, cam: usize) -> Result<()> {
        if cam >= self.cams.len() {
            bail!("request_now: camera {cam} out of range (have {})", self.cams.len());
        }
        if self.cams[cam].job.is_some() {
            return Ok(());
        }
        let salt = (self.window_idx as u64) * 7919 + cam as u64 * 131 + 0x5c71;
        let (frames, emb) = self.probe(cam, salt)?;
        self.issue_request(cam, frames, emb)
    }

    /// Create a job with a fixed membership (Fig. 8's manual groups),
    /// bypassing Alg. 2. The job starts from the first member's model.
    ///
    /// A camera that already belongs to a job is detached from it first
    /// (membership, grouping metadata, and buffered samples), preserving
    /// the one-job-per-camera partition invariant; jobs emptied by the
    /// detach are dropped.
    pub(crate) fn force_group(&mut self, cams: &[usize]) -> Result<usize> {
        if cams.is_empty() {
            bail!("force_group: empty camera list");
        }
        if let Some(&bad) = cams.iter().find(|&&c| c >= self.cams.len()) {
            bail!("force_group: camera {bad} out of range (have {})", self.cams.len());
        }
        let now = self.now();
        for &cam in cams {
            if let Some(old_id) = self.cams[cam].job.take() {
                if let Some(idx) = self.job_index(old_id) {
                    self.jobs[idx].remove_member(cam);
                }
                for meta in &mut self.group_meta {
                    if meta.id == old_id {
                        meta.members.retain(|m| m.cam != cam);
                    }
                }
                self.events.emit(Event::GroupSplit {
                    time: now,
                    window: self.window_idx,
                    job: old_id,
                    cam,
                });
            }
        }
        self.jobs.retain(|j| !j.members.is_empty());
        self.group_meta.retain(|g| !g.members.is_empty());
        let id = self.next_job_id;
        self.next_job_id += 1;
        let model = ModelState::from_theta(self.cfg.task, self.cams[cams[0]].theta.clone());
        let mut job = Job::new(id, cams[0], model, self.cfg.buffer_cap, now);
        let mut meta_job: Option<GroupJob> = None;
        for (i, &cam) in cams.iter().enumerate() {
            job.add_member(cam);
            self.cams[cam].job = Some(id);
            self.tracker.request(cam, now);
            self.events.emit(Event::RetrainRequest {
                time: now,
                window: self.window_idx,
                cam,
                acc: 0.0,
            });
            if i == 0 {
                self.events.emit(Event::GroupFormed {
                    time: now,
                    window: self.window_idx,
                    job: id,
                    cam,
                });
            } else {
                self.events.emit(Event::GroupJoined {
                    time: now,
                    window: self.window_idx,
                    job: id,
                    cam,
                });
            }
            let loc = self.world.cameras[cam].position(now);
            let meta = RequestMeta {
                cam,
                time: now,
                loc,
                acc: 0.0,
            };
            match &mut meta_job {
                None => meta_job = Some(GroupJob::new(id, meta)),
                Some(g) => g.members.push(meta),
            }
        }
        // Seed the buffer with a probe from each member.
        self.jobs.push(job);
        let idx = self.jobs.len() - 1;
        for &cam in cams {
            let salt = 0xf0_6ce + cam as u64;
            let (frames, emb) = self.probe(cam, salt)?;
            self.push_probe_samples(idx, cam, frames);
            self.cams[cam].ref_embed = Some(emb);
        }
        // `cams` is non-empty (checked above), so the loop always set this.
        if let Some(g) = meta_job {
            self.group_meta.push(g);
        }
        debug_assert!(
            grouping::is_partition(&self.group_meta),
            "force_group broke the one-job-per-camera partition"
        );
        Ok(id)
    }
}

//! Server-side assembly: retraining jobs, the micro-window scheduler, and
//! the end-to-end [`system::System`] loop that ties cameras, network,
//! teacher, allocator and grouping together.
//!
//! `System` itself is crate-private: drivers run it through
//! [`crate::api::Session`] and observe it through the typed event stream.

pub mod config;
pub mod job;
pub mod pretrain;
pub mod sched;
pub mod system;

pub use config::{CamWindow, Policy, Scheduler, SystemConfig, TransmissionKind};
pub use job::{eval_model, Job, Sample};
pub use system::MembershipSnapshot;

//! Server-side assembly: retraining jobs, the micro-window scheduler, and
//! the end-to-end [`system::System`] that ties cameras, network, teacher,
//! allocator and grouping together.

pub mod config;
pub mod job;
pub mod pretrain;
pub mod system;

pub use config::{Policy, SystemConfig, TransmissionKind};
pub use job::{eval_model, Job, Sample};
pub use system::{CamAgent, System};

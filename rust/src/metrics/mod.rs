//! Accuracy metrics: mAP for detection, mask-mAP for segmentation, plus
//! response-time tracking.
//!
//! Detection AP is computed at grid-cell granularity (the student predicts
//! per-cell objectness + class): for each class, every (frame, cell) pair
//! is a candidate detection scored `obj_prob * cls_prob`, positive when the
//! ground truth places an object of that class in the cell. AP uses
//! PASCAL-style 11-point interpolation; mAP averages classes that appear in
//! the ground truth. Segmentation uses the same machinery over mask cells
//! with score `prob[class]`.
//!
//! This is the cell-level analogue of the paper's IoU-threshold mAP: it
//! preserves the precision/recall semantics and is monotone in detection
//! quality, which is what every comparison in the evaluation consumes.

use crate::runtime::{DetPred, SegPred};
use crate::scene::GroundTruth;
use crate::util::stats::nan_ranks_last;

/// A scored binary candidate (one class's detection).
#[derive(Debug, Clone, Copy)]
struct Candidate {
    score: f32,
    positive: bool,
}

/// Detection confidence floor: cells scored below this are "not detected"
/// for the class (without it, a zero-score ground-truth cell would still be
/// ranked and could fake perfect recall).
const MIN_SCORE: f32 = 0.01;

/// 11-point interpolated average precision.
fn average_precision(mut cands: Vec<Candidate>, n_positive: usize) -> f32 {
    if n_positive == 0 {
        return f32::NAN; // class absent from GT: skipped by the caller
    }
    // NaN fails the `>=` floor, so a NaN-scored cell counts as "not
    // detected" rather than poisoning the ranking.
    cands.retain(|c| c.score >= MIN_SCORE);
    // Descending by score via `total_cmp` on the NaN-last rank key: the
    // comparator is total, so a stray NaN (e.g. diverged model weights)
    // can never panic the sort again.
    cands.sort_by(|a, b| nan_ranks_last(b.score).total_cmp(&nan_ranks_last(a.score)));
    // Precision/recall curve.
    let mut tp = 0usize;
    let mut fp = 0usize;
    let mut prec = Vec::with_capacity(cands.len());
    let mut rec = Vec::with_capacity(cands.len());
    for c in &cands {
        if c.positive {
            tp += 1;
        } else {
            fp += 1;
        }
        prec.push(tp as f32 / (tp + fp) as f32);
        rec.push(tp as f32 / n_positive as f32);
    }
    // 11-point interpolation: max precision at recall >= t.
    let mut ap = 0.0f32;
    for i in 0..=10 {
        let t = i as f32 / 10.0;
        let p = prec
            .iter()
            .zip(&rec)
            .filter(|(_, &r)| r >= t)
            .map(|(&p, _)| p)
            .fold(0.0f32, f32::max);
        ap += p / 11.0;
    }
    ap.clamp(0.0, 1.0)
}

/// Detection mAP over `n` frames of predictions vs ground truths.
/// `preds` covers at least `n` batch slots; `truths.len() == n`.
pub fn det_map(preds: &DetPred, truths: &[&GroundTruth], n: usize) -> f32 {
    assert!(n <= preds.batch && n <= truths.len());
    let k = preds.classes;
    let g = preds.grid;
    let mut aps = Vec::new();
    for class in 0..k {
        let mut cands = Vec::with_capacity(n * g * g);
        let mut n_pos = 0usize;
        for (b, truth) in truths.iter().enumerate().take(n) {
            let (og, cg) = truth.det_grids();
            for gy in 0..g {
                for gx in 0..g {
                    let positive = og[gy][gx] > 0.0 && cg[gy][gx] == class;
                    if positive {
                        n_pos += 1;
                    }
                    let score = preds.obj_at(b, gy, gx) * preds.cls_at(b, gy, gx)[class];
                    cands.push(Candidate { score, positive });
                }
            }
        }
        let ap = average_precision(cands, n_pos);
        if !ap.is_nan() {
            aps.push(ap);
        }
    }
    if aps.is_empty() {
        return 0.0;
    }
    aps.iter().sum::<f32>() / aps.len() as f32
}

/// Segmentation mask-mAP over `n` frames (cell-level AP per foreground
/// class, averaged).
pub fn seg_map(preds: &SegPred, truths: &[&GroundTruth], n: usize) -> f32 {
    assert!(n <= preds.batch && n <= truths.len());
    let s = preds.side;
    let k = preds.classes - 1; // foreground classes
    let mut aps = Vec::new();
    for class in 0..k {
        let mut cands = Vec::with_capacity(n * s * s);
        let mut n_pos = 0usize;
        for (b, truth) in truths.iter().enumerate().take(n) {
            let mask = truth.mask_grid(s);
            for sy in 0..s {
                for sx in 0..s {
                    let positive = mask[sy * s + sx] == class;
                    if positive {
                        n_pos += 1;
                    }
                    let score = preds.probs_at(b, sy, sx)[class];
                    cands.push(Candidate { score, positive });
                }
            }
        }
        let ap = average_precision(cands, n_pos);
        if !ap.is_nan() {
            aps.push(ap);
        }
    }
    if aps.is_empty() {
        return 0.0;
    }
    aps.iter().sum::<f32>() / aps.len() as f32
}

/// Tracks when each camera's accuracy first crosses a threshold after its
/// retraining request — the paper's "response time" metric.
#[derive(Debug, Clone)]
pub struct ResponseTracker {
    threshold: f32,
    /// Per camera: (request time, reach time).
    requests: Vec<(usize, f64, Option<f64>)>,
}

impl ResponseTracker {
    pub fn new(threshold: f32) -> ResponseTracker {
        ResponseTracker {
            threshold,
            requests: Vec::new(),
        }
    }

    /// Register a retraining request from `cam` at simulated time `t`.
    pub fn request(&mut self, cam: usize, t: f64) {
        self.requests.push((cam, t, None));
    }

    /// Report camera accuracy at time `t`; fills open requests that reached
    /// the threshold.
    pub fn observe(&mut self, cam: usize, t: f64, acc: f32) {
        if acc < self.threshold {
            return;
        }
        for r in &mut self.requests {
            if r.0 == cam && r.2.is_none() && t >= r.1 {
                r.2 = Some(t);
            }
        }
    }

    /// Mean response time over satisfied requests; unresolved requests are
    /// counted at `horizon` (pessimistic completion), matching how capped
    /// measurements are usually reported.
    pub fn mean_response(&self, horizon: f64) -> f64 {
        if self.requests.is_empty() {
            return f64::NAN;
        }
        let total: f64 = self
            .requests
            .iter()
            .map(|&(_, t0, t1)| t1.unwrap_or(horizon) - t0)
            .sum();
        total / self.requests.len() as f64
    }

    pub fn satisfied(&self) -> usize {
        self.requests.iter().filter(|r| r.2.is_some()).count()
    }

    pub fn total(&self) -> usize {
        self.requests.len()
    }
}

/// Accuracy history per camera: (time, mAP) samples for plotting/series.
#[derive(Debug, Clone, Default)]
pub struct AccuracyHistory {
    pub series: Vec<Vec<(f64, f32)>>,
}

impl AccuracyHistory {
    pub fn new(n_cams: usize) -> AccuracyHistory {
        AccuracyHistory {
            series: vec![Vec::new(); n_cams],
        }
    }

    pub fn push(&mut self, cam: usize, t: f64, acc: f32) {
        self.series[cam].push((t, acc));
    }

    /// Mean accuracy across cameras over the last `frac` of samples
    /// (steady-state average, skipping warm-up).
    pub fn steady_mean(&self, frac: f64) -> f32 {
        let mut total = 0.0f64;
        let mut n = 0usize;
        for s in &self.series {
            if s.is_empty() {
                continue;
            }
            let start = ((1.0 - frac) * s.len() as f64) as usize;
            for &(_, a) in &s[start.min(s.len() - 1)..] {
                total += a as f64;
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            (total / n as f64) as f32
        }
    }

    /// Mean accuracy across cameras at the final sample.
    pub fn final_mean(&self) -> f32 {
        let finals: Vec<f32> = self
            .series
            .iter()
            .filter_map(|s| s.last().map(|&(_, a)| a))
            .collect();
        if finals.is_empty() {
            0.0
        } else {
            finals.iter().sum::<f32>() / finals.len() as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::Obj;

    fn truth_with(objects: Vec<Obj>) -> GroundTruth {
        GroundTruth { objects }
    }

    /// Build a DetPred from explicit per-cell (obj, class) assignments.
    fn pred_from(
        n: usize,
        cells: &[(usize, usize, usize, usize, f32)], // (frame, gy, gx, class, score)
    ) -> DetPred {
        let (g, k) = (4usize, 4usize);
        let mut obj = vec![0.0f32; n * g * g];
        let mut cls = vec![1.0f32 / k as f32; n * g * g * k];
        for &(b, gy, gx, class, score) in cells {
            obj[(b * g + gy) * g + gx] = score;
            let off = ((b * g + gy) * g + gx) * k;
            for c in 0..k {
                cls[off + c] = if c == class { 0.97 } else { 0.01 };
            }
        }
        DetPred {
            batch: n,
            grid: g,
            classes: k,
            obj,
            cls,
        }
    }

    #[test]
    fn perfect_predictions_score_one() {
        let truths = vec![truth_with(vec![
            Obj { class: 1, cx: 0.12, cy: 0.12, radius: 0.05 },
            Obj { class: 2, cx: 0.9, cy: 0.9, radius: 0.05 },
        ])];
        let pred = pred_from(1, &[(0, 0, 0, 1, 0.99), (0, 3, 3, 2, 0.98)]);
        let trefs: Vec<&GroundTruth> = truths.iter().collect();
        let m = det_map(&pred, &trefs, 1);
        assert!(m > 0.99, "perfect predictions should give mAP ~1: {m}");
    }

    #[test]
    fn wrong_class_scores_poorly() {
        let truths = vec![truth_with(vec![Obj {
            class: 1,
            cx: 0.12,
            cy: 0.12,
            radius: 0.05,
        }])];
        let pred = pred_from(1, &[(0, 0, 0, 3, 0.99)]); // wrong class
        let trefs: Vec<&GroundTruth> = truths.iter().collect();
        let m = det_map(&pred, &trefs, 1);
        assert!(m < 0.3, "wrong class should score low: {m}");
    }

    #[test]
    fn missed_objects_reduce_map() {
        let truths = vec![truth_with(vec![
            Obj { class: 0, cx: 0.12, cy: 0.12, radius: 0.05 },
            Obj { class: 0, cx: 0.9, cy: 0.9, radius: 0.05 },
        ])];
        // Only one of two found.
        let pred = pred_from(1, &[(0, 0, 0, 0, 0.99)]);
        let trefs: Vec<&GroundTruth> = truths.iter().collect();
        let m = det_map(&pred, &trefs, 1);
        assert!(m > 0.3 && m < 0.8, "half recall should be mid-range: {m}");
    }

    #[test]
    fn uniform_noise_scores_low() {
        let truths = vec![truth_with(vec![Obj {
            class: 2,
            cx: 0.6,
            cy: 0.6,
            radius: 0.05,
        }])];
        // All cells weakly predicted with the right class -> low precision.
        let mut cells = Vec::new();
        for gy in 0..4 {
            for gx in 0..4 {
                cells.push((0usize, gy, gx, 2usize, 0.5f32));
            }
        }
        let pred = pred_from(1, &cells);
        let trefs: Vec<&GroundTruth> = truths.iter().collect();
        let m = det_map(&pred, &trefs, 1);
        assert!(m < 0.5, "indiscriminate predictions should score low: {m}");
    }

    #[test]
    fn map_in_unit_interval_randomized() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(3);
        for _ in 0..20 {
            let truths = vec![truth_with(vec![Obj {
                class: rng.index(4),
                cx: rng.range(0.05, 0.95),
                cy: rng.range(0.05, 0.95),
                radius: 0.05,
            }])];
            let mut obj = vec![0.0f32; 16];
            let mut cls = vec![0.25f32; 64];
            for v in obj.iter_mut() {
                *v = rng.f32();
            }
            for v in cls.iter_mut() {
                *v = rng.f32();
            }
            let pred = DetPred {
                batch: 1,
                grid: 4,
                classes: 4,
                obj,
                cls,
            };
            let trefs: Vec<&GroundTruth> = truths.iter().collect();
            let m = det_map(&pred, &trefs, 1);
            assert!((0.0..=1.0).contains(&m));
        }
    }

    #[test]
    fn seg_map_perfect_and_inverted() {
        let truth = truth_with(vec![Obj {
            class: 0,
            cx: 0.5,
            cy: 0.5,
            radius: 0.25,
        }]);
        let s = 8usize;
        let mask = truth.mask_grid(s);
        let mut probs = vec![0.0f32; s * s * 5];
        for (i, &m) in mask.iter().enumerate() {
            probs[i * 5 + m] = 1.0;
        }
        let pred = SegPred {
            batch: 1,
            side: s,
            classes: 5,
            probs: probs.clone(),
        };
        let m_perfect = seg_map(&pred, &[&truth], 1);
        assert!(m_perfect > 0.99, "{m_perfect}");
        // Inverted: background where object is.
        let mut inv = vec![0.0f32; s * s * 5];
        for (i, &m) in mask.iter().enumerate() {
            inv[i * 5 + (if m == 0 { 4 } else { 0 })] = 1.0;
        }
        let pred_bad = SegPred {
            batch: 1,
            side: s,
            classes: 5,
            probs: inv,
        };
        let m_bad = seg_map(&pred_bad, &[&truth], 1);
        assert!(m_bad < 0.2, "{m_bad}");
    }

    #[test]
    fn nan_scores_never_panic_and_rank_last() {
        // Regression: a single NaN confidence (diverged model weights)
        // used to panic the whole mAP computation through the
        // `partial_cmp(..).unwrap()` sort. NaN cells must instead count as
        // "not detected".
        let truths = vec![truth_with(vec![
            Obj { class: 1, cx: 0.12, cy: 0.12, radius: 0.05 },
            Obj { class: 1, cx: 0.9, cy: 0.9, radius: 0.05 },
        ])];
        let mut pred = pred_from(1, &[(0, 0, 0, 1, 0.99), (0, 3, 3, 1, 0.98)]);
        // Poison a handful of cells, including one of the true positives.
        pred.obj[5] = f32::NAN;
        pred.obj[(3 * 4) + 3] = f32::NAN;
        let trefs: Vec<&GroundTruth> = truths.iter().collect();
        let m = det_map(&pred, &trefs, 1);
        assert!(m.is_finite(), "NaN scores must not poison mAP: {m}");
        assert!((0.0..=1.0).contains(&m));
        // The NaN'd true positive is a miss, so recall is capped at 1/2.
        let clean = pred_from(1, &[(0, 0, 0, 1, 0.99), (0, 3, 3, 1, 0.98)]);
        let m_clean = det_map(&clean, &trefs, 1);
        assert!(m < m_clean, "NaN cell must score as a miss: {m} vs {m_clean}");
        // Seg path: NaN probabilities are equally harmless.
        let s = 8usize;
        let mut probs = vec![0.0f32; s * s * 5];
        let truth = truth_with(vec![Obj { class: 0, cx: 0.5, cy: 0.5, radius: 0.25 }]);
        let mask = truth.mask_grid(s);
        for (i, &cell) in mask.iter().enumerate() {
            probs[i * 5 + cell] = 1.0;
        }
        probs[0] = f32::NAN;
        let pred = SegPred { batch: 1, side: s, classes: 5, probs };
        let m_seg = seg_map(&pred, &[&truth], 1);
        assert!(m_seg.is_finite() && (0.0..=1.0).contains(&m_seg));
    }

    #[test]
    fn response_tracker_flow() {
        let mut rt = ResponseTracker::new(0.35);
        rt.request(0, 100.0);
        rt.observe(0, 150.0, 0.2); // below threshold
        rt.observe(0, 200.0, 0.4); // crosses
        rt.request(1, 100.0); // never satisfied
        assert_eq!(rt.satisfied(), 1);
        assert_eq!(rt.total(), 2);
        let mean = rt.mean_response(500.0);
        assert!((mean - (100.0 + 400.0) / 2.0).abs() < 1e-9);
    }

    #[test]
    fn accuracy_history_steady_mean() {
        let mut h = AccuracyHistory::new(2);
        for i in 0..10 {
            h.push(0, i as f64, if i < 5 { 0.1 } else { 0.5 });
            h.push(1, i as f64, if i < 5 { 0.2 } else { 0.6 });
        }
        let sm = h.steady_mean(0.5);
        assert!((sm - 0.55).abs() < 1e-5, "{sm}");
        assert!((h.final_mean() - 0.55).abs() < 1e-5);
    }
}

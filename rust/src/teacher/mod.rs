//! Teacher annotator — the YOLO11x substitute.
//!
//! The paper's teacher is a ~30x-FLOPs model treated as the label source
//! for retraining. Here the scene simulator knows the true objects, so the
//! teacher is ground truth degraded by a configurable noise model (missed
//! detections, class confusion, localisation jitter) plus a throughput
//! account (annotations per GPU-second) so teacher cost can participate in
//! budget accounting. `TeacherConfig::strong()` approximates a YOLO11x-like
//! annotator; `noisy()` stresses label-robustness in tests/ablations.

use crate::scene::{GroundTruth, Obj, K};
use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct TeacherConfig {
    /// Probability an object is missed entirely.
    pub miss_rate: f32,
    /// Probability an object's class label is resampled uniformly.
    pub confuse_rate: f32,
    /// Std of centre jitter (normalised units).
    pub jitter: f32,
    /// Probability of a spurious detection per frame.
    pub hallucinate_rate: f32,
    /// Annotation throughput: frames per (simulated) GPU-second. The paper's
    /// YOLO11x at ~195 BFLOPs on a 4090 annotates a few hundred small frames
    /// per second; this only matters for budget accounting.
    pub frames_per_gpu_sec: f64,
}

impl TeacherConfig {
    /// A strong teacher (close to ground truth).
    pub fn strong() -> TeacherConfig {
        TeacherConfig {
            miss_rate: 0.03,
            confuse_rate: 0.03,
            jitter: 0.006,
            hallucinate_rate: 0.02,
            frames_per_gpu_sec: 250.0,
        }
    }

    /// A deliberately unreliable teacher (for ablations).
    pub fn noisy() -> TeacherConfig {
        TeacherConfig {
            miss_rate: 0.2,
            confuse_rate: 0.15,
            jitter: 0.02,
            hallucinate_rate: 0.1,
            frames_per_gpu_sec: 250.0,
        }
    }

    /// Perfect oracle (tests).
    pub fn oracle() -> TeacherConfig {
        TeacherConfig {
            miss_rate: 0.0,
            confuse_rate: 0.0,
            jitter: 0.0,
            hallucinate_rate: 0.0,
            frames_per_gpu_sec: f64::INFINITY,
        }
    }
}

/// The teacher: stateful only in its RNG and its annotation counter.
#[derive(Debug, Clone)]
pub struct Teacher {
    pub config: TeacherConfig,
    rng: Pcg32,
    /// Total frames annotated (for cost accounting).
    pub annotated: u64,
}

impl Teacher {
    pub fn new(config: TeacherConfig, seed: u64) -> Teacher {
        Teacher {
            config,
            rng: Pcg32::new(seed, 77),
            annotated: 0,
        }
    }

    /// Annotate one frame's ground truth, producing (possibly imperfect)
    /// training labels.
    pub fn annotate(&mut self, truth: &GroundTruth) -> GroundTruth {
        self.annotated += 1;
        let c = &self.config;
        let mut objects = Vec::with_capacity(truth.objects.len());
        for o in &truth.objects {
            if self.rng.chance(c.miss_rate) {
                continue;
            }
            let class = if self.rng.chance(c.confuse_rate) {
                self.rng.index(K)
            } else {
                o.class
            };
            objects.push(Obj {
                class,
                cx: (o.cx + c.jitter * self.rng.normal()).clamp(0.02, 0.98),
                cy: (o.cy + c.jitter * self.rng.normal()).clamp(0.02, 0.98),
                radius: o.radius,
            });
        }
        if self.rng.chance(c.hallucinate_rate) {
            objects.push(Obj {
                class: self.rng.index(K),
                cx: self.rng.range(0.1, 0.9),
                cy: self.rng.range(0.1, 0.9),
                radius: self.rng.range(0.05, 0.12),
            });
        }
        GroundTruth { objects }
    }

    /// GPU-seconds consumed annotating `frames` frames.
    pub fn gpu_cost(&self, frames: usize) -> f64 {
        if self.config.frames_per_gpu_sec.is_infinite() {
            0.0
        } else {
            frames as f64 / self.config.frames_per_gpu_sec
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn truth_n(n: usize) -> GroundTruth {
        GroundTruth {
            objects: (0..n)
                .map(|i| Obj {
                    class: i % K,
                    cx: 0.1 + 0.2 * (i % 4) as f32,
                    cy: 0.1 + 0.2 * (i / 4) as f32,
                    radius: 0.05,
                })
                .collect(),
        }
    }

    #[test]
    fn oracle_is_identity_up_to_order() {
        let mut t = Teacher::new(TeacherConfig::oracle(), 1);
        let truth = truth_n(5);
        let ann = t.annotate(&truth);
        assert_eq!(ann.objects.len(), 5);
        for (a, b) in ann.objects.iter().zip(&truth.objects) {
            assert_eq!(a.class, b.class);
            assert!((a.cx - b.cx).abs() < 1e-6);
        }
    }

    #[test]
    fn strong_teacher_mostly_correct() {
        let mut t = Teacher::new(TeacherConfig::strong(), 2);
        let truth = truth_n(8);
        let mut kept = 0usize;
        let mut correct = 0usize;
        let rounds = 200;
        for _ in 0..rounds {
            let ann = t.annotate(&truth);
            kept += ann.objects.len().min(8);
            correct += ann
                .objects
                .iter()
                .zip(&truth.objects)
                .filter(|(a, b)| a.class == b.class)
                .count();
        }
        let keep_rate = kept as f64 / (rounds * 8) as f64;
        assert!(keep_rate > 0.93, "keep rate {keep_rate}");
        assert!(correct as f64 / kept as f64 > 0.9);
    }

    #[test]
    fn noisy_teacher_noisier_than_strong() {
        let truth = truth_n(8);
        let degraded = |cfg: TeacherConfig| {
            let mut t = Teacher::new(cfg, 3);
            let mut missing = 0usize;
            for _ in 0..200 {
                let ann = t.annotate(&truth);
                missing += 8usize.saturating_sub(ann.objects.len());
            }
            missing
        };
        assert!(degraded(TeacherConfig::noisy()) > degraded(TeacherConfig::strong()) * 2);
    }

    #[test]
    fn annotation_counter_and_cost() {
        let mut t = Teacher::new(TeacherConfig::strong(), 4);
        for _ in 0..10 {
            t.annotate(&truth_n(2));
        }
        assert_eq!(t.annotated, 10);
        assert!((t.gpu_cost(500) - 2.0).abs() < 1e-9);
        let oracle = Teacher::new(TeacherConfig::oracle(), 5);
        assert_eq!(oracle.gpu_cost(1000), 0.0);
    }
}

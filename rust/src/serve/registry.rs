//! Session registry: the shared state behind the serve host.
//!
//! One [`Registry`] multiplexes every client session onto the runner pool.
//! Sessions move through a small state machine
//! (`queued → running → done | cancelled | snapshotted | failed`), runner
//! threads pull work FIFO off the admission queue with [`Registry::next_job`],
//! and each streaming client holds a [`Subscriber`] — a *bounded* frame
//! buffer, so a slow consumer can never wedge a runner or grow memory
//! without limit. When the buffer is full, frames are counted instead of
//! queued, and the count is delivered as a `{"frame":"dropped"}` marker as
//! soon as the consumer catches up.
//!
//! All frames are pre-rendered compact JSON strings. Event frames carry no
//! session id — `{"event":{...},"frame":"event","seq":N}` — which keeps the
//! stream of an interrupted-then-resumed session byte-comparable to an
//! uninterrupted run (see the snapshot test in `tests/serve.rs`).

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};

use crate::api::Event;
use crate::util::json::{num, obj, s, Json};
use crate::util::sync::{plock, pwait};

/// Tunables for the serve host. `Copy` so the CLI can thread it around.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Runner threads sharing the engine (concurrent sessions).
    pub runners: usize,
    /// Max sessions waiting in the admission queue before submits are
    /// rejected with an error response (back-pressure at the front door).
    pub queue_cap: usize,
    /// Per-subscriber frame buffer capacity; overflow is counted and
    /// reported via a `dropped` marker frame, never buffered.
    pub sub_buffer: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            runners: 2,
            queue_cap: 256,
            sub_buffer: 256,
        }
    }
}

/// Lifecycle of one submitted session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessState {
    Queued,
    Running,
    Done,
    Cancelled,
    Snapshotted,
    Failed,
}

impl SessState {
    pub fn name(self) -> &'static str {
        match self {
            SessState::Queued => "queued",
            SessState::Running => "running",
            SessState::Done => "done",
            SessState::Cancelled => "cancelled",
            SessState::Snapshotted => "snapshotted",
            SessState::Failed => "failed",
        }
    }

    /// Terminal states deliver an `end` frame and accept no further work.
    pub fn terminal(self) -> bool {
        !matches!(self, SessState::Queued | SessState::Running)
    }
}

/// What a runner should do after finishing a window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Control {
    Continue,
    Cancel,
    Snapshot,
}

#[derive(Default)]
struct SubState {
    buf: VecDeque<String>,
    /// Frames counted (not queued) while the buffer was full.
    dropped: u64,
    done: bool,
}

/// A bounded frame queue feeding one streaming connection. Producers
/// (runner threads, via the registry) never block on it; the consumer
/// blocks in [`Subscriber::pop`] until a frame or end-of-stream arrives.
pub struct Subscriber {
    state: Mutex<SubState>,
    cv: Condvar,
}

impl Subscriber {
    fn new() -> Arc<Subscriber> {
        Arc::new(Subscriber {
            state: Mutex::new(SubState::default()),
            cv: Condvar::new(),
        })
    }

    /// Queue a frame if there is room; otherwise count it as dropped. A
    /// pending drop count is flushed as a marker frame *before* the next
    /// queued frame, so the consumer always learns how many it missed and
    /// where the gap was.
    fn push(&self, frame: &str, cap: usize) {
        let mut st = plock(&self.state);
        if st.done {
            return;
        }
        if st.buf.len() >= cap.max(1) {
            st.dropped += 1;
            return;
        }
        if st.dropped > 0 {
            let marker = dropped_frame(st.dropped);
            st.dropped = 0;
            st.buf.push_back(marker);
        }
        st.buf.push_back(frame.to_string());
        self.cv.notify_one();
    }

    /// Queue the final frame unconditionally (end frames bypass the cap)
    /// and close the stream. Any pending drop count is flushed first.
    fn push_final(&self, frame: &str) {
        let mut st = plock(&self.state);
        if st.done {
            return;
        }
        if st.dropped > 0 {
            let marker = dropped_frame(st.dropped);
            st.dropped = 0;
            st.buf.push_back(marker);
        }
        st.buf.push_back(frame.to_string());
        st.done = true;
        self.cv.notify_all();
    }

    /// Blocking pop; `None` once the stream is closed and drained.
    pub fn pop(&self) -> Option<String> {
        let mut st = plock(&self.state);
        loop {
            if let Some(frame) = st.buf.pop_front() {
                return Some(frame);
            }
            if st.done {
                return None;
            }
            st = pwait(&self.cv, st);
        }
    }
}

fn dropped_frame(count: u64) -> String {
    obj(vec![("count", num(count as f64)), ("frame", s("dropped"))]).to_string_compact()
}

fn event_frame(event: &Event, seq: u64) -> String {
    obj(vec![
        ("event", event.to_json()),
        ("frame", s("event")),
        ("seq", num(seq as f64)),
    ])
    .to_string_compact()
}

fn end_frame(state: SessState, error: Option<&str>) -> String {
    let mut pairs = vec![("frame", s("end")), ("state", s(state.name()))];
    if let Some(e) = error {
        pairs.push(("error", s(e)));
    }
    obj(pairs).to_string_compact()
}

struct Entry {
    /// Canonical wire spec (the parsed spec re-exported, *not* the client's
    /// raw text) — cloned into snapshots so resume replays the exact run.
    spec: Json,
    windows: usize,
    replay: usize,
    state: SessState,
    windows_done: usize,
    /// Events published so far — counts replayed (suppressed) events too,
    /// so a resumed stream continues seq-contiguously.
    seq: u64,
    /// Global start ordinal (admission order proof for the fairness test).
    started: Option<u64>,
    pause_after: Option<usize>,
    cancel: bool,
    snap_req: bool,
    snapshot: Option<Json>,
    report: Option<Json>,
    error: Option<String>,
    subs: Vec<Arc<Subscriber>>,
}

struct Inner {
    next_id: u64,
    next_start: u64,
    accepting: bool,
    sessions: BTreeMap<u64, Entry>,
    queue: VecDeque<u64>,
}

/// The shared session table. One lock guards everything; the condvar wakes
/// idle runners (new job), snapshot waiters (state change), and shutdown.
/// Lock ordering: registry inner before any subscriber lock, never the
/// reverse.
pub struct Registry {
    inner: Mutex<Inner>,
    cv: Condvar,
    cfg: ServeConfig,
}

impl Registry {
    pub fn new(cfg: ServeConfig) -> Registry {
        Registry {
            inner: Mutex::new(Inner {
                next_id: 1,
                next_start: 0,
                accepting: true,
                sessions: BTreeMap::new(),
                queue: VecDeque::new(),
            }),
            cv: Condvar::new(),
            cfg,
        }
    }

    /// Admit a session (optionally with an attached subscriber, under the
    /// same lock — no submit/subscribe race). `replay` > 0 marks a resume:
    /// that many windows re-run with event forwarding suppressed.
    #[allow(clippy::type_complexity)]
    pub fn submit(
        &self,
        spec: Json,
        windows: usize,
        replay: usize,
        pause_after: Option<usize>,
        subscribe: bool,
    ) -> Result<(u64, Option<Arc<Subscriber>>), String> {
        let mut inner = plock(&self.inner);
        if !inner.accepting {
            return Err("server is shutting down".to_string());
        }
        if inner.queue.len() >= self.cfg.queue_cap {
            return Err(format!(
                "admission queue full ({} sessions queued)",
                inner.queue.len()
            ));
        }
        let id = inner.next_id;
        inner.next_id += 1;
        let sub = subscribe.then(Subscriber::new);
        inner.sessions.insert(
            id,
            Entry {
                spec,
                windows,
                replay,
                state: SessState::Queued,
                windows_done: 0,
                seq: 0,
                started: None,
                pause_after,
                cancel: false,
                snap_req: false,
                snapshot: None,
                report: None,
                error: None,
                subs: sub.iter().cloned().collect(),
            },
        );
        inner.queue.push_back(id);
        self.cv.notify_all();
        Ok((id, sub))
    }

    /// Attach a subscriber to an existing session. On a terminal session
    /// the end frame is delivered immediately.
    pub fn subscribe(&self, id: u64) -> Result<Arc<Subscriber>, String> {
        let mut inner = plock(&self.inner);
        let entry = inner
            .sessions
            .get_mut(&id)
            .ok_or_else(|| format!("unknown session {id}"))?;
        let sub = Subscriber::new();
        if entry.state.terminal() {
            sub.push_final(&end_frame(entry.state, entry.error.as_deref()));
        } else {
            entry.subs.push(Arc::clone(&sub));
        }
        Ok(sub)
    }

    /// Runner loop: block for the next queued session id, FIFO. `None`
    /// once the registry stops accepting and the queue is drained —
    /// already-queued sessions still run during shutdown.
    pub fn next_job(&self) -> Option<u64> {
        let mut inner = plock(&self.inner);
        loop {
            while let Some(id) = inner.queue.pop_front() {
                // Skip entries cancelled or snapshotted while queued.
                if inner.sessions.get(&id).map(|e| e.state) == Some(SessState::Queued) {
                    return Some(id);
                }
            }
            if !inner.accepting {
                return None;
            }
            inner = pwait(&self.cv, inner);
        }
    }

    /// Transition a claimed job to running; returns its canonical spec,
    /// horizon, and replay depth. `None` if it was cancelled in between.
    pub fn begin(&self, id: u64) -> Option<(Json, usize, usize)> {
        let mut inner = plock(&self.inner);
        let start = inner.next_start;
        let entry = inner.sessions.get_mut(&id)?;
        if entry.state != SessState::Queued {
            return None;
        }
        entry.state = SessState::Running;
        entry.windows_done = entry.replay;
        entry.started = Some(start);
        inner.next_start += 1;
        let entry = &inner.sessions[&id];
        Some((entry.spec.clone(), entry.windows, entry.replay))
    }

    /// Count an event against the session's stream and, when `forward` is
    /// set (false during resume replay), fan the rendered frame out to all
    /// subscribers. Producers never block: full buffers count drops.
    pub fn publish_event(&self, id: u64, event: &Event, forward: bool) {
        let mut inner = plock(&self.inner);
        let Some(entry) = inner.sessions.get_mut(&id) else {
            return;
        };
        let seq = entry.seq;
        entry.seq += 1;
        if forward && !entry.subs.is_empty() {
            let frame = event_frame(event, seq);
            for sub in &entry.subs {
                sub.push(&frame, self.cfg.sub_buffer);
            }
        }
    }

    /// Window boundary: record progress and tell the runner whether to
    /// keep going, stop for a cancel, or stop for a snapshot (requested
    /// explicitly or scheduled via `pause_after`).
    pub fn checkpoint(&self, id: u64, windows_done: usize) -> Control {
        let mut inner = plock(&self.inner);
        let Some(entry) = inner.sessions.get_mut(&id) else {
            return Control::Cancel;
        };
        entry.windows_done = windows_done;
        if entry.cancel {
            entry.state = SessState::Cancelled;
            let frame = end_frame(SessState::Cancelled, None);
            for sub in entry.subs.drain(..) {
                sub.push_final(&frame);
            }
            self.cv.notify_all();
            return Control::Cancel;
        }
        if entry.snap_req || entry.pause_after == Some(windows_done) {
            entry.snapshot = Some(obj(vec![
                ("completed", num(windows_done as f64)),
                ("spec", entry.spec.clone()),
            ]));
            entry.snap_req = false;
            entry.state = SessState::Snapshotted;
            let frame = end_frame(SessState::Snapshotted, None);
            for sub in entry.subs.drain(..) {
                sub.push_final(&frame);
            }
            self.cv.notify_all();
            return Control::Snapshot;
        }
        Control::Continue
    }

    /// Mark a session complete and store its report.
    pub fn finish(&self, id: u64, report: Json) {
        let mut inner = plock(&self.inner);
        let Some(entry) = inner.sessions.get_mut(&id) else {
            return;
        };
        entry.state = SessState::Done;
        entry.windows_done = entry.windows;
        entry.report = Some(report);
        let frame = end_frame(SessState::Done, None);
        for sub in entry.subs.drain(..) {
            sub.push_final(&frame);
        }
        self.cv.notify_all();
    }

    /// Mark a session failed; the error rides the end frame and `report`.
    pub fn fail(&self, id: u64, error: String) {
        let mut inner = plock(&self.inner);
        let Some(entry) = inner.sessions.get_mut(&id) else {
            return;
        };
        entry.state = SessState::Failed;
        let frame = end_frame(SessState::Failed, Some(&error));
        entry.error = Some(error);
        for sub in entry.subs.drain(..) {
            sub.push_final(&frame);
        }
        self.cv.notify_all();
    }

    /// Cancel: queued sessions die immediately, running ones at the next
    /// window boundary. Returns the resulting state name.
    pub fn cancel(&self, id: u64) -> Result<&'static str, String> {
        let mut inner = plock(&self.inner);
        let entry = inner
            .sessions
            .get_mut(&id)
            .ok_or_else(|| format!("unknown session {id}"))?;
        match entry.state {
            SessState::Queued => {
                entry.state = SessState::Cancelled;
                let frame = end_frame(SessState::Cancelled, None);
                for sub in entry.subs.drain(..) {
                    sub.push_final(&frame);
                }
                self.cv.notify_all();
                Ok("cancelled")
            }
            SessState::Running => {
                entry.cancel = true;
                Ok("cancelling")
            }
            state => Err(format!("session {id} already {}", state.name())),
        }
    }

    /// Snapshot a session: queued sessions snapshot at zero completed
    /// windows immediately; running ones at the next window boundary
    /// (this call blocks until the runner gets there). The returned JSON
    /// is exactly what `resume` accepts.
    pub fn request_snapshot(&self, id: u64) -> Result<Json, String> {
        let mut inner = plock(&self.inner);
        loop {
            let entry = inner
                .sessions
                .get_mut(&id)
                .ok_or_else(|| format!("unknown session {id}"))?;
            match entry.state {
                SessState::Queued => {
                    let snap = obj(vec![
                        ("completed", num(0.0)),
                        ("spec", entry.spec.clone()),
                    ]);
                    entry.snapshot = Some(snap.clone());
                    entry.state = SessState::Snapshotted;
                    let frame = end_frame(SessState::Snapshotted, None);
                    for sub in entry.subs.drain(..) {
                        sub.push_final(&frame);
                    }
                    self.cv.notify_all();
                    return Ok(snap);
                }
                SessState::Running => {
                    entry.snap_req = true;
                    inner = pwait(&self.cv, inner);
                }
                SessState::Snapshotted => {
                    return entry
                        .snapshot
                        .clone()
                        .ok_or_else(|| format!("session {id} snapshot missing"));
                }
                state => return Err(format!("session {id} already {}", state.name())),
            }
        }
    }

    /// Point-in-time status object for one session.
    pub fn status(&self, id: u64) -> Result<Json, String> {
        let inner = plock(&self.inner);
        let entry = inner
            .sessions
            .get(&id)
            .ok_or_else(|| format!("unknown session {id}"))?;
        Ok(obj(vec![
            ("session", num(id as f64)),
            (
                "started",
                entry.started.map(|n| num(n as f64)).unwrap_or(Json::Null),
            ),
            ("seq", num(entry.seq as f64)),
            ("state", s(entry.state.name())),
            ("windows", num(entry.windows as f64)),
            ("windows_done", num(entry.windows_done as f64)),
        ]))
    }

    /// Final run report (available once the session is done).
    pub fn report(&self, id: u64) -> Result<Json, String> {
        let inner = plock(&self.inner);
        let entry = inner
            .sessions
            .get(&id)
            .ok_or_else(|| format!("unknown session {id}"))?;
        match (&entry.report, &entry.error) {
            (Some(report), _) => Ok(report.clone()),
            (None, Some(error)) => Err(format!("session {id} failed: {error}")),
            (None, None) => Err(format!(
                "session {id} has no report yet (state {})",
                entry.state.name()
            )),
        }
    }

    /// Stop admitting sessions and wake every waiter. Queued sessions
    /// still drain; running ones finish.
    pub fn shutdown(&self) {
        let mut inner = plock(&self.inner);
        inner.accepting = false;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec_stub() -> Json {
        obj(vec![("task", s("det"))])
    }

    #[test]
    fn bounded_buffer_counts_drops_and_flushes_a_marker() {
        let sub = Subscriber::new();
        for i in 0..5 {
            sub.push(&format!("f{i}"), 2);
        }
        sub.push_final("end");
        assert_eq!(sub.pop().as_deref(), Some("f0"));
        assert_eq!(sub.pop().as_deref(), Some("f1"));
        assert_eq!(
            sub.pop().as_deref(),
            Some(r#"{"count":3,"frame":"dropped"}"#)
        );
        assert_eq!(sub.pop().as_deref(), Some("end"));
        assert_eq!(sub.pop(), None);
        // Closed stream ignores further pushes.
        sub.push("late", 2);
        assert_eq!(sub.pop(), None);
    }

    #[test]
    fn queue_is_fifo_and_skips_cancelled_entries() {
        let reg = Registry::new(ServeConfig::default());
        let (a, _) = reg.submit(spec_stub(), 4, 0, None, false).unwrap();
        let (b, _) = reg.submit(spec_stub(), 4, 0, None, false).unwrap();
        let (c, _) = reg.submit(spec_stub(), 4, 0, None, false).unwrap();
        assert_eq!(reg.cancel(b).unwrap(), "cancelled");
        assert_eq!(reg.next_job(), Some(a));
        assert!(reg.begin(a).is_some());
        assert_eq!(reg.next_job(), Some(c));
        reg.shutdown();
        assert_eq!(reg.next_job(), None);
    }

    #[test]
    fn admission_queue_cap_rejects_excess_submits() {
        let cfg = ServeConfig {
            queue_cap: 2,
            ..ServeConfig::default()
        };
        let reg = Registry::new(cfg);
        reg.submit(spec_stub(), 1, 0, None, false).unwrap();
        reg.submit(spec_stub(), 1, 0, None, false).unwrap();
        let err = reg.submit(spec_stub(), 1, 0, None, false).unwrap_err();
        assert!(err.contains("admission queue full"), "{err}");
    }

    #[test]
    fn queued_session_snapshots_immediately_at_zero() {
        let reg = Registry::new(ServeConfig::default());
        let (id, sub) = reg.submit(spec_stub(), 6, 0, None, true).unwrap();
        let snap = reg.request_snapshot(id).unwrap();
        let completed = snap.get("completed").unwrap().as_usize().unwrap();
        assert_eq!(completed, 0);
        assert_eq!(
            snap.get("spec").unwrap().to_string_compact(),
            spec_stub().to_string_compact()
        );
        // The subscriber sees the snapshotted end frame, and the queue
        // entry no longer reaches runners.
        let frame = sub.unwrap().pop().unwrap();
        assert!(frame.contains(r#""state":"snapshotted""#), "{frame}");
        reg.shutdown();
        assert_eq!(reg.next_job(), None);
    }

    #[test]
    fn terminal_subscribe_gets_an_immediate_end_frame() {
        let reg = Registry::new(ServeConfig::default());
        let (id, _) = reg.submit(spec_stub(), 1, 0, None, false).unwrap();
        assert_eq!(reg.next_job(), Some(id));
        reg.begin(id).unwrap();
        reg.finish(id, obj(vec![("final", num(0.5))]));
        let sub = reg.subscribe(id).unwrap();
        let frame = sub.pop().unwrap();
        assert_eq!(frame, r#"{"frame":"end","state":"done"}"#);
        assert_eq!(sub.pop(), None);
        assert!(reg.report(id).is_ok());
    }
}

//! `ecco::serve` — a multi-tenant session host over plain sockets.
//!
//! `ecco serve` turns the library into a long-lived process: clients
//! connect over TCP (or a unix-domain socket), submit [`RunSpec`]s as
//! JSON, and stream typed run events back — many sessions multiplexed
//! onto one shared [`Engine`] and a small runner pool. Std-only: the
//! protocol is line-delimited JSON over a socket, readable with `nc`.
//!
//! # Protocol
//!
//! One JSON object per line, both directions (grammar in [`protocol`]):
//!
//! ```text
//! → {"cmd":"submit","spec":{"task":"det","policy":"ecco","windows":8},"events":true}
//! ← {"ok":true,"session":1}
//! ← {"event":{"kind":"window_closed",...},"frame":"event","seq":42}
//! ← {"frame":"end","state":"done"}
//! ```
//!
//! `submit` admits a session (FIFO queue, bounded by `--queue-cap`;
//! overflow is rejected, not buffered). `events` re-attaches a stream,
//! `status`/`report` poll, `cancel` stops at the next window boundary,
//! and `snapshot`/`resume` implement stop-and-restart (below). `ping`
//! and `shutdown` do what they say; `shutdown` drains queued sessions,
//! finishes running ones, then exits the server.
//!
//! # Back-pressure
//!
//! Producers never block on consumers. Each streaming connection owns a
//! *bounded* frame buffer (`--sub-buffer`); while it is full, frames are
//! counted instead of queued, and the count is delivered as
//! `{"count":N,"frame":"dropped"}` as soon as the consumer catches up.
//! A slow client therefore costs exactly one buffer of memory and loses
//! only its own frames — never another session's, and never the run
//! itself (the authoritative event record lives in the session, not the
//! stream). The `end` frame always arrives.
//!
//! # Snapshot / resume
//!
//! A snapshot is `{"completed":k,"spec":<canonical wire spec>}` — no
//! model weights, no allocator state. Runs are deterministic given the
//! spec (at any thread count), so `resume` rebuilds the session and
//! re-steps the first `k` windows with event forwarding suppressed,
//! then continues live. Sequence numbers count the replayed events, so
//! the resumed stream continues exactly where the snapshot's left off:
//! the concatenation of both streams is byte-identical to an
//! uninterrupted run (pinned by a test).
//!
//! [`RunSpec`]: crate::api::RunSpec
//! [`Engine`]: crate::runtime::Engine

pub mod protocol;
pub mod registry;
pub mod server;

pub use registry::{Registry, ServeConfig, SessState, Subscriber};
pub use server::{Bind, Server};

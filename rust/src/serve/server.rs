//! The socket host: accept loop, per-connection handlers, runner pool.
//!
//! Everything runs inside one [`std::thread::scope`]: `runners` worker
//! threads pull sessions FIFO off the [`Registry`] and drive them over the
//! shared [`Engine`], while the acceptor spawns one handler thread per
//! connection. Listeners are non-blocking (polled against the stop flag);
//! accepted streams are blocking with a short read timeout so handlers
//! notice shutdown promptly. `shutdown` stops admissions, drains the queue,
//! and lets in-flight sessions finish — then every thread unwinds and
//! `run()` returns.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
#[cfg(unix)]
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::api::{Event, EventSink, RunSpec, Session};
use crate::runtime::Engine;
use crate::util::json::{num, Json};
use crate::util::pool;

use super::protocol::{
    err_response, ok_response, parse_request, parse_snapshot, Request,
};
use super::registry::{Control, Registry, ServeConfig, Subscriber};

/// Poll interval for the non-blocking accept loop.
const ACCEPT_POLL: Duration = Duration::from_millis(25);
/// Read timeout on accepted streams — how fast handlers see the stop flag.
const READ_TIMEOUT: Duration = Duration::from_millis(250);
/// Write timeout — a consumer that stalls this long loses its connection.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Where to listen.
#[derive(Debug, Clone)]
pub enum Bind {
    /// TCP address, e.g. `127.0.0.1:7433` (port 0 picks a free port).
    Tcp(String),
    /// Unix-domain socket path (stale files are replaced).
    #[cfg(unix)]
    Unix(PathBuf),
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn accept(&self) -> io::Result<Stream> {
        match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
        }
    }
}

enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    /// Accepted streams are blocking with a short read timeout (so the
    /// handler can poll the stop flag) and a long write timeout (so a
    /// wedged consumer is eventually disconnected, not waited on forever).
    fn set_timeouts(&self) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => {
                s.set_nonblocking(false)?;
                s.set_read_timeout(Some(READ_TIMEOUT))?;
                s.set_write_timeout(Some(WRITE_TIMEOUT))
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                s.set_nonblocking(false)?;
                s.set_read_timeout(Some(READ_TIMEOUT))?;
                s.set_write_timeout(Some(WRITE_TIMEOUT))
            }
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// The serve host. Bind, then [`Server::run`] until a `shutdown` request.
pub struct Server<'e> {
    engine: &'e Engine,
    listener: Listener,
    registry: Arc<Registry>,
    cfg: ServeConfig,
    stop: Arc<AtomicBool>,
}

impl<'e> Server<'e> {
    pub fn bind(engine: &'e Engine, bind: &Bind, cfg: ServeConfig) -> Result<Server<'e>> {
        let listener = match bind {
            Bind::Tcp(addr) => {
                let l = TcpListener::bind(addr)
                    .with_context(|| format!("binding tcp listener on {addr}"))?;
                l.set_nonblocking(true)?;
                Listener::Tcp(l)
            }
            #[cfg(unix)]
            Bind::Unix(path) => {
                if path.exists() {
                    std::fs::remove_file(path)
                        .with_context(|| format!("removing stale socket {}", path.display()))?;
                }
                let l = UnixListener::bind(path)
                    .with_context(|| format!("binding unix listener on {}", path.display()))?;
                l.set_nonblocking(true)?;
                Listener::Unix(l)
            }
        };
        Ok(Server {
            engine,
            listener,
            registry: Arc::new(Registry::new(cfg)),
            cfg,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Bound TCP address (None for unix-domain listeners). Lets tests bind
    /// port 0 and discover the real port.
    pub fn local_addr(&self) -> Option<SocketAddr> {
        match &self.listener {
            Listener::Tcp(l) => l.local_addr().ok(),
            #[cfg(unix)]
            Listener::Unix(_) => None,
        }
    }

    /// Serve until a client sends `shutdown`. Queued sessions drain and
    /// running ones finish before this returns.
    pub fn run(self) -> Result<()> {
        let Server {
            engine,
            listener,
            registry,
            cfg,
            stop,
        } = self;
        thread::scope(|scope| {
            for _ in 0..cfg.runners.max(1) {
                let registry = Arc::clone(&registry);
                scope.spawn(move || runner_loop(engine, &registry, cfg));
            }
            loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                match listener.accept() {
                    Ok(stream) => {
                        let registry = Arc::clone(&registry);
                        let stop = Arc::clone(&stop);
                        scope.spawn(move || handle_conn(stream, &registry, &stop));
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        // ecco-lint: allow(D003) accept-loop poll pacing on
                        // the I/O surface; session results are unaffected.
                        thread::sleep(ACCEPT_POLL);
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(e) => {
                        crate::util::logger::log(
                            crate::util::logger::Level::Warn,
                            module_path!(),
                            &format!("accept failed: {e}"),
                        );
                        // ecco-lint: allow(D003) accept-loop error backoff,
                        // same I/O-surface pacing as the WouldBlock arm.
                        thread::sleep(ACCEPT_POLL);
                    }
                }
            }
            // Acceptor is done; make sure runners unblock and drain.
            registry.shutdown();
        });
        Ok(())
    }
}

/// Forwards session events into the registry. `forward` is false while a
/// resumed session replays already-completed windows — the events still
/// count (seq stays contiguous with the original stream) but no frames go
/// out.
struct RegistrySink {
    registry: Arc<Registry>,
    id: u64,
    forward: Arc<AtomicBool>,
}

impl EventSink for RegistrySink {
    fn on_event(&mut self, event: &Event) {
        self.registry
            .publish_event(self.id, event, self.forward.load(Ordering::Relaxed));
    }
}

fn runner_loop(engine: &Engine, registry: &Arc<Registry>, cfg: ServeConfig) {
    while let Some(id) = registry.next_job() {
        let Some((spec, windows, replay)) = registry.begin(id) else {
            continue;
        };
        if let Err(e) = run_session(engine, registry, cfg, id, &spec, windows, replay) {
            registry.fail(id, format!("{e:#}"));
        }
    }
}

/// Drive one session window-by-window, checking for cancel/snapshot at
/// each boundary. The session is rebuilt from its canonical wire spec, so
/// a resumed run replays deterministically into the same state.
fn run_session(
    engine: &Engine,
    registry: &Arc<Registry>,
    cfg: ServeConfig,
    id: u64,
    spec_json: &Json,
    windows: usize,
    replay: usize,
) -> Result<()> {
    let spec = RunSpec::from_wire_json(spec_json)?;
    // Split eval workers across the runner pool the same way run_fleet
    // does across its fleet threads.
    let spec = spec.eval_threads_floor(pool::per_run_threads(cfg.runners, cfg.runners));
    let forward = Arc::new(AtomicBool::new(replay == 0));
    let mut session = Session::new(engine, spec)?;
    session.add_sink(Box::new(RegistrySink {
        registry: Arc::clone(registry),
        id,
        forward: Arc::clone(&forward),
    }));
    for w in 0..windows {
        if w == replay {
            forward.store(true, Ordering::Relaxed);
        }
        session.step_window()?;
        if w + 1 < windows {
            match registry.checkpoint(id, w + 1) {
                Control::Continue => {}
                Control::Cancel | Control::Snapshot => return Ok(()),
            }
        }
    }
    registry.finish(id, session.into_report().to_json());
    Ok(())
}

/// Read one line, tolerating read timeouts (poll the stop flag) and
/// partial reads. `None` on EOF, hard error, or shutdown.
///
/// `BufReader::read_line` is unusable here: with a read timeout it can
/// time out mid-line and *discard* the partial line. This keeps its own
/// pending buffer instead.
fn read_line(stream: &mut Stream, pending: &mut Vec<u8>, stop: &AtomicBool) -> Option<String> {
    let mut buf = [0u8; 4096];
    loop {
        if let Some(pos) = pending.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = pending.drain(..=pos).collect();
            return Some(String::from_utf8_lossy(&line[..pos]).into_owned());
        }
        match stream.read(&mut buf) {
            Ok(0) => return None,
            Ok(n) => pending.extend_from_slice(&buf[..n]),
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                if stop.load(Ordering::Relaxed) {
                    return None;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return None,
        }
    }
}

/// What a dispatched request asks the connection loop to do.
enum Outcome {
    /// Write one response line, keep reading requests.
    Reply(String),
    /// Write the response, then stream frames until the session ends.
    /// The throttle paces writes (deliberate slow-consumer testing).
    Stream(String, Arc<Subscriber>, u64),
    /// Write the response, then stop the whole server.
    Shutdown(String),
}

fn handle_conn(mut stream: Stream, registry: &Arc<Registry>, stop: &AtomicBool) {
    if stream.set_timeouts().is_err() {
        return;
    }
    let mut pending = Vec::new();
    while let Some(line) = read_line(&mut stream, &mut pending, stop) {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match dispatch(line, registry) {
            Outcome::Reply(resp) => {
                if writeln!(stream, "{resp}").is_err() {
                    return;
                }
            }
            Outcome::Stream(resp, sub, throttle_ms) => {
                if writeln!(stream, "{resp}").is_err() {
                    return;
                }
                while let Some(frame) = sub.pop() {
                    if throttle_ms > 0 {
                        // ecco-lint: allow(D003) client-requested stream
                        // throttle; frame *contents* stay byte-identical.
                        thread::sleep(Duration::from_millis(throttle_ms));
                    }
                    if writeln!(stream, "{frame}").is_err() {
                        return;
                    }
                }
            }
            Outcome::Shutdown(resp) => {
                let _ = writeln!(stream, "{resp}");
                registry.shutdown();
                stop.store(true, Ordering::Relaxed);
                return;
            }
        }
    }
}

fn dispatch(line: &str, registry: &Arc<Registry>) -> Outcome {
    let req = match parse_request(line) {
        Ok(req) => req,
        Err(e) => return Outcome::Reply(err_response(&e)),
    };
    match req {
        Request::Ping => Outcome::Reply(ok_response(vec![])),
        Request::Shutdown => Outcome::Shutdown(ok_response(vec![])),
        Request::Status { session } => Outcome::Reply(result_response(registry.status(session))),
        Request::Report { session } => Outcome::Reply(result_response(registry.report(session))),
        Request::Cancel { session } => Outcome::Reply(match registry.cancel(session) {
            Ok(state) => ok_response(vec![("state", crate::util::json::s(state))]),
            Err(e) => err_response(&e),
        }),
        Request::Snapshot { session } => {
            Outcome::Reply(match registry.request_snapshot(session) {
                Ok(snap) => ok_response(vec![("snapshot", snap)]),
                Err(e) => err_response(&e),
            })
        }
        Request::Submit {
            spec,
            events,
            pause_after,
            throttle_ms,
        } => admit(registry, &spec, 0, events, pause_after, throttle_ms),
        Request::Resume {
            snapshot,
            events,
            pause_after,
            throttle_ms,
        } => match parse_snapshot(&snapshot) {
            Ok((spec, completed)) => {
                admit(registry, &spec, completed, events, pause_after, throttle_ms)
            }
            Err(e) => Outcome::Reply(err_response(&e)),
        },
    }
}

/// Validate a wire spec and admit it — shared by submit (replay 0) and
/// resume. The *canonical* re-export of the parsed spec is what the
/// registry stores, so a snapshot of this session resumes byte-identically
/// regardless of how the client formatted the original spec.
fn admit(
    registry: &Arc<Registry>,
    spec: &Json,
    replay: usize,
    events: bool,
    pause_after: Option<usize>,
    throttle_ms: u64,
) -> Outcome {
    let parsed = match RunSpec::from_wire_json(spec) {
        Ok(parsed) => parsed,
        Err(e) => return Outcome::Reply(err_response(&e.to_string())),
    };
    let windows = parsed.windows;
    if replay > windows {
        return Outcome::Reply(err_response(&format!(
            "snapshot completed {replay} exceeds horizon {windows}"
        )));
    }
    let canonical = parsed.to_wire_json();
    match registry.submit(canonical, windows, replay, pause_after, events) {
        Ok((id, sub)) => {
            let mut extra = vec![("session", num(id as f64))];
            if replay > 0 {
                extra.insert(0, ("replay", num(replay as f64)));
            }
            let resp = ok_response(extra);
            match sub {
                Some(sub) => Outcome::Stream(resp, sub, throttle_ms),
                None => Outcome::Reply(resp),
            }
        }
        Err(e) => Outcome::Reply(err_response(&e)),
    }
}

fn result_response(result: Result<Json, String>) -> String {
    match result {
        Ok(Json::Obj(fields)) => {
            let pairs: Vec<(&str, Json)> = fields
                .iter()
                .map(|(k, v)| (k.as_str(), v.clone()))
                .collect();
            ok_response(pairs)
        }
        Ok(other) => ok_response(vec![("result", other)]),
        Err(e) => err_response(&e),
    }
}

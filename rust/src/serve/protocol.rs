//! Line protocol: one JSON object per line, in both directions.
//!
//! Requests name a `cmd` plus command-specific fields; unknown fields are
//! rejected (typo'd knobs fail loudly instead of silently running the
//! default — same policy as the CLI and the wire spec). Responses always
//! carry `"ok"`: `{"ok":true,...}` on success, `{"error":"...","ok":false}`
//! otherwise. Streaming commands (`submit` with `"events":true`, `events`,
//! `resume`) follow the response with event frames until an `end` frame.
//!
//! Grammar (one line each):
//!
//! ```text
//! {"cmd":"submit","spec":{...},"events":true,"pause_after":4,"throttle_ms":0}
//! {"cmd":"events","session":3,"throttle_ms":0}
//! {"cmd":"status","session":3}
//! {"cmd":"report","session":3}
//! {"cmd":"cancel","session":3}
//! {"cmd":"snapshot","session":3}
//! {"cmd":"resume","snapshot":{"completed":4,"spec":{...}},"events":true}
//! {"cmd":"ping"}
//! {"cmd":"shutdown"}
//! ```
//!
//! `spec` is the [`RunSpec`](crate::api::RunSpec) wire form
//! ([`RunSpec::to_wire_json`](crate::api::RunSpec::to_wire_json)).
//! `throttle_ms` paces the server's frame writes (testing aid: it makes a
//! deliberately slow consumer deterministic instead of depending on OS
//! socket buffering). `pause_after` schedules a snapshot after that many
//! completed windows.

use crate::util::json::{obj, s, Json};

/// One parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Submit {
        spec: Json,
        events: bool,
        pause_after: Option<usize>,
        throttle_ms: u64,
    },
    Events {
        session: u64,
        throttle_ms: u64,
    },
    Status {
        session: u64,
    },
    Report {
        session: u64,
    },
    Cancel {
        session: u64,
    },
    Snapshot {
        session: u64,
    },
    Resume {
        snapshot: Json,
        events: bool,
        pause_after: Option<usize>,
        throttle_ms: u64,
    },
    Ping,
    Shutdown,
}

/// Parse one request line. Every failure is a client error string destined
/// for an `{"ok":false}` response — the connection survives.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let j = Json::parse(line).map_err(|e| format!("malformed json: {e}"))?;
    let Json::Obj(fields) = &j else {
        return Err("request must be a json object".to_string());
    };
    let cmd = match fields.get("cmd") {
        Some(Json::Str(c)) => c.as_str(),
        Some(_) => return Err("cmd must be a string".to_string()),
        None => return Err("missing cmd".to_string()),
    };
    let allowed: &[&str] = match cmd {
        "submit" => &["cmd", "spec", "events", "pause_after", "throttle_ms"],
        "events" => &["cmd", "session", "throttle_ms"],
        "status" | "report" | "cancel" | "snapshot" => &["cmd", "session"],
        "resume" => &["cmd", "snapshot", "events", "pause_after", "throttle_ms"],
        "ping" | "shutdown" => &["cmd"],
        other => return Err(format!("unknown cmd {other:?}")),
    };
    for key in fields.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(format!("unknown field {key:?} for cmd {cmd:?}"));
        }
    }
    match cmd {
        "submit" => Ok(Request::Submit {
            spec: fields
                .get("spec")
                .cloned()
                .ok_or_else(|| "submit requires a spec".to_string())?,
            events: get_bool(fields, "events")?.unwrap_or(false),
            pause_after: get_usize(fields, "pause_after")?,
            throttle_ms: get_u64(fields, "throttle_ms")?.unwrap_or(0),
        }),
        "events" => Ok(Request::Events {
            session: req_session(fields)?,
            throttle_ms: get_u64(fields, "throttle_ms")?.unwrap_or(0),
        }),
        "status" => Ok(Request::Status {
            session: req_session(fields)?,
        }),
        "report" => Ok(Request::Report {
            session: req_session(fields)?,
        }),
        "cancel" => Ok(Request::Cancel {
            session: req_session(fields)?,
        }),
        "snapshot" => Ok(Request::Snapshot {
            session: req_session(fields)?,
        }),
        "resume" => Ok(Request::Resume {
            snapshot: fields
                .get("snapshot")
                .cloned()
                .ok_or_else(|| "resume requires a snapshot".to_string())?,
            events: get_bool(fields, "events")?.unwrap_or(false),
            pause_after: get_usize(fields, "pause_after")?,
            throttle_ms: get_u64(fields, "throttle_ms")?.unwrap_or(0),
        }),
        "ping" => Ok(Request::Ping),
        "shutdown" => Ok(Request::Shutdown),
        // The allowlist match above already rejected every other cmd, but
        // a typed error keeps the protocol layer panic-free even if the
        // two matches ever drift apart.
        other => Err(format!("unknown cmd {other:?}")),
    }
}

/// Validate a snapshot object (`{"completed":k,"spec":{...}}`, exactly
/// those keys) into its parts. The spec itself is validated separately by
/// [`RunSpec::from_wire_json`](crate::api::RunSpec::from_wire_json).
pub fn parse_snapshot(j: &Json) -> Result<(Json, usize), String> {
    let Json::Obj(fields) = j else {
        return Err("snapshot must be a json object".to_string());
    };
    for key in fields.keys() {
        if key != "completed" && key != "spec" {
            return Err(format!("unknown snapshot field {key:?}"));
        }
    }
    let completed = get_usize(fields, "completed")?
        .ok_or_else(|| "snapshot missing completed".to_string())?;
    let spec = fields
        .get("spec")
        .cloned()
        .ok_or_else(|| "snapshot missing spec".to_string())?;
    Ok((spec, completed))
}

/// `{"ok":true,...extra}` — success response.
pub fn ok_response(extra: Vec<(&str, Json)>) -> String {
    let mut pairs = vec![("ok", Json::Bool(true))];
    pairs.extend(extra);
    obj(pairs).to_string_compact()
}

/// `{"error":"...","ok":false}` — failure response; connection stays open.
pub fn err_response(msg: &str) -> String {
    obj(vec![("error", s(msg)), ("ok", Json::Bool(false))]).to_string_compact()
}

type Fields = std::collections::BTreeMap<String, Json>;

fn req_session(fields: &Fields) -> Result<u64, String> {
    get_u64(fields, "session")?.ok_or_else(|| "missing session".to_string())
}

fn get_u64(fields: &Fields, key: &str) -> Result<Option<u64>, String> {
    match fields.get(key) {
        None => Ok(None),
        Some(Json::Num(n)) if n.is_finite() && *n >= 0.0 && n.fract() == 0.0 => {
            Ok(Some(*n as u64))
        }
        Some(_) => Err(format!("{key} must be a non-negative integer")),
    }
}

fn get_usize(fields: &Fields, key: &str) -> Result<Option<usize>, String> {
    Ok(get_u64(fields, key)?.map(|n| n as usize))
}

fn get_bool(fields: &Fields, key: &str) -> Result<Option<bool>, String> {
    match fields.get(key) {
        None => Ok(None),
        Some(Json::Bool(b)) => Ok(Some(*b)),
        Some(_) => Err(format!("{key} must be a boolean")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::num;
    use crate::util::rng::Pcg32;

    #[test]
    fn parses_every_command() {
        let req = parse_request(
            r#"{"cmd":"submit","spec":{"task":"det"},"events":true,"pause_after":2,"throttle_ms":5}"#,
        )
        .unwrap();
        assert_eq!(
            req,
            Request::Submit {
                spec: obj(vec![("task", s("det"))]),
                events: true,
                pause_after: Some(2),
                throttle_ms: 5,
            }
        );
        assert_eq!(
            parse_request(r#"{"cmd":"events","session":3}"#).unwrap(),
            Request::Events {
                session: 3,
                throttle_ms: 0
            }
        );
        assert_eq!(
            parse_request(r#"{"cmd":"status","session":1}"#).unwrap(),
            Request::Status { session: 1 }
        );
        assert_eq!(
            parse_request(r#"{"cmd":"report","session":1}"#).unwrap(),
            Request::Report { session: 1 }
        );
        assert_eq!(
            parse_request(r#"{"cmd":"cancel","session":9}"#).unwrap(),
            Request::Cancel { session: 9 }
        );
        assert_eq!(
            parse_request(r#"{"cmd":"snapshot","session":9}"#).unwrap(),
            Request::Snapshot { session: 9 }
        );
        let resume = parse_request(
            r#"{"cmd":"resume","snapshot":{"completed":4,"spec":{"task":"det"}}}"#,
        )
        .unwrap();
        match resume {
            Request::Resume {
                snapshot,
                events,
                pause_after,
                throttle_ms,
            } => {
                assert!(!events);
                assert_eq!(pause_after, None);
                assert_eq!(throttle_ms, 0);
                let (spec, completed) = parse_snapshot(&snapshot).unwrap();
                assert_eq!(completed, 4);
                assert_eq!(spec.to_string_compact(), r#"{"task":"det"}"#);
            }
            other => panic!("expected resume, got {other:?}"),
        }
        assert_eq!(parse_request(r#"{"cmd":"ping"}"#).unwrap(), Request::Ping);
        assert_eq!(
            parse_request(r#"{"cmd":"shutdown"}"#).unwrap(),
            Request::Shutdown
        );
    }

    #[test]
    fn rejects_malformed_requests_with_useful_errors() {
        for (line, needle) in [
            ("not json", "malformed json"),
            ("[1,2]", "must be a json object"),
            (r#"{"spec":{}}"#, "missing cmd"),
            (r#"{"cmd":17}"#, "cmd must be a string"),
            (r#"{"cmd":"launch"}"#, "unknown cmd"),
            (r#"{"cmd":"ping","extra":1}"#, "unknown field"),
            (r#"{"cmd":"submit"}"#, "requires a spec"),
            (r#"{"cmd":"submit","spec":{},"events":"yes"}"#, "boolean"),
            (r#"{"cmd":"status"}"#, "missing session"),
            (r#"{"cmd":"status","session":-1}"#, "non-negative integer"),
            (r#"{"cmd":"status","session":1.5}"#, "non-negative integer"),
            (r#"{"cmd":"resume"}"#, "requires a snapshot"),
        ] {
            let err = parse_request(line).unwrap_err();
            assert!(err.contains(needle), "{line} -> {err}");
        }
        for (snap, needle) in [
            (s("x"), "must be a json object"),
            (obj(vec![("completed", num(1.0))]), "missing spec"),
            (obj(vec![("spec", obj(vec![]))]), "missing completed"),
            (
                obj(vec![
                    ("completed", num(1.0)),
                    ("spec", obj(vec![])),
                    ("zzz", num(0.0)),
                ]),
                "unknown snapshot field",
            ),
        ] {
            let err = parse_snapshot(&snap).unwrap_err();
            assert!(err.contains(needle), "{needle} -> {err}");
        }
    }

    #[test]
    fn responses_render_compact_with_ok_marker() {
        assert_eq!(ok_response(vec![]), r#"{"ok":true}"#);
        assert_eq!(
            ok_response(vec![("session", num(4.0))]),
            r#"{"ok":true,"session":4}"#
        );
        assert_eq!(
            err_response("bad"),
            r#"{"error":"bad","ok":false}"#
        );
    }

    #[test]
    fn parser_never_panics_on_garbage_lines() {
        let mut rng = Pcg32::new(0x5e21e, 17);
        let keys = [
            "cmd", "spec", "session", "events", "snapshot", "pause_after", "throttle_ms", "zz",
        ];
        let cmds = ["submit", "events", "status", "resume", "ping", "nope"];
        for _ in 0..300 {
            let mut pairs = Vec::new();
            for &key in &keys {
                if rng.chance(0.5) {
                    let val = match rng.below(4) {
                        0 => num(rng.f64() * 10.0 - 2.0),
                        1 => s(cmds[rng.index(cmds.len())]),
                        2 => Json::Bool(rng.chance(0.5)),
                        _ => obj(vec![("completed", num(rng.f64() * 4.0))]),
                    };
                    pairs.push((key, val));
                }
            }
            let line = obj(pairs).to_string_compact();
            let _ = parse_request(&line); // must not panic
        }
        // Truncated lines and raw bytes must not panic either.
        let full = r#"{"cmd":"submit","spec":{"task":"det"}}"#;
        for cut in 0..full.len() {
            let _ = parse_request(&full[..cut]);
        }
    }
}

//! GPU allocation for group retraining — the paper's Algorithm 1 plus the
//! baseline allocators it is compared against.
//!
//! The server time-shares G GPUs across retraining jobs in micro-windows:
//! within each micro-window exactly one job trains on all GPUs. After every
//! micro-window the scheduler re-scores jobs and greedily picks the next.
//!
//! * [`EccoAllocator`] — optimises Eq. 1: a size-weighted (`n_j^beta`)
//!   average-accuracy term scaled by `alpha`, plus a max-min fairness term
//!   implemented as an extra `AccGain` bonus for the currently
//!   lowest-accuracy job (Alg. 1, CalObjectiveGain).
//! * [`UtilityAllocator`] — the Ekya/RECL-style scheduler: maximises total
//!   accuracy improvement, i.e. weights every job by its camera count.
//!   This is the allocator the paper shows starves small groups (Fig. 10).
//! * [`UniformAllocator`] — the naive baseline: round-robin micro-windows.

use std::collections::BTreeMap;

/// Scheduler-visible state of one retraining job (group).
#[derive(Debug, Clone)]
pub struct JobView {
    /// Stable job id.
    pub id: usize,
    /// Number of member cameras `n_j`.
    pub n_cams: usize,
    /// Latest evaluated accuracy `Acc[j]` (mAP in [0,1]).
    pub acc: f32,
    /// Accuracy gain over the job's last micro-window `AccGain[j]`.
    pub acc_gain: f32,
    /// Micro-windows this job has received so far in the current window.
    pub micro_windows: usize,
    /// Micro-windows over the job's lifetime (0 = never trained).
    pub lifetime_mw: usize,
}

/// A micro-window GPU scheduler.
pub trait Allocator {
    /// Pick the job to train next. `jobs` is non-empty.
    fn pick(&mut self, jobs: &[JobView]) -> usize;

    /// Normalised GPU-share estimates `p_j` for the coming window, used by
    /// the transmission controller (Alg. 1 line 15). Defaults to the
    /// allocator's scoring weights normalised over jobs.
    fn share_estimates(&self, jobs: &[JobView]) -> Vec<f64> {
        let scores: Vec<f64> = jobs.iter().map(|j| self.score(j, jobs).max(1e-9)).collect();
        let total: f64 = scores.iter().sum();
        scores.iter().map(|s| s / total).collect()
    }

    /// The job score this allocator maximises (exposed for estimates/tests).
    fn score(&self, job: &JobView, all: &[JobView]) -> f64;

    fn name(&self) -> &'static str;
}

/// Alg. 1 lines 13-14: every window starts with an initial training pass so
/// each job's accuracy-gain estimate is fresh (stale gains would let greedy
/// allocation starve a job forever on an outdated estimate). The server
/// scales W with the number of jobs (see `System::effective_micro_windows`)
/// so the pass never consumes the whole window.
fn initial_pass_pick(jobs: &[JobView]) -> Option<usize> {
    jobs.iter()
        .filter(|j| j.micro_windows == 0)
        .min_by_key(|j| j.id)
        .map(|j| j.id)
}

/// NaN-safe ranking value: a NaN score (e.g. from a NaN `acc_gain` that an
/// upstream bug let through) compares false against everything, which
/// would silently freeze the argmax on `jobs[0]`. Rank it strictly below
/// every real score instead, so a poisoned job can never win a
/// micro-window and ties still break to the lowest id.
fn rankable(s: f64) -> f64 {
    if s.is_nan() {
        f64::NEG_INFINITY
    } else {
        s
    }
}

fn argmax_score<A: Allocator + ?Sized>(alloc: &A, jobs: &[JobView]) -> usize {
    let mut best = &jobs[0];
    let mut best_score = f64::NEG_INFINITY;
    for j in jobs {
        let s = rankable(alloc.score(j, jobs));
        if s > best_score || (s == best_score && j.id < best.id) {
            best = j;
            best_score = s;
        }
    }
    best.id
}

// ---------------------------------------------------------------------------
// ECCO (Algorithm 1)
// ---------------------------------------------------------------------------

/// ECCO's objective-gain allocator (Eq. 1 / Alg. 1).
#[derive(Debug, Clone)]
pub struct EccoAllocator {
    /// Eq. 1 `alpha`: weight of the average-accuracy term relative to the
    /// fairness (min-accuracy) term.
    pub alpha: f64,
    /// Eq. 1 `beta` (<= 1): group-size exponent.
    pub beta: f64,
}

impl Default for EccoAllocator {
    fn default() -> Self {
        // Paper defaults: balanced objective with sublinear size weighting.
        EccoAllocator {
            alpha: 1.0,
            beta: 0.5,
        }
    }
}

impl EccoAllocator {
    /// ObjGain[j] (Alg. 1 lines 9-12).
    fn obj_gain(&self, job: &JobView, all: &[JobView]) -> f64 {
        let size_weight_sum: f64 = all.iter().map(|j| (j.n_cams as f64).powf(self.beta)).sum();
        let w = (job.n_cams as f64).powf(self.beta) / size_weight_sum;
        let mut gain = self.alpha * w * job.acc_gain as f64;
        // Fairness bonus for the lowest-accuracy job. A NaN accuracy is
        // mapped to +inf before comparing so a poisoned job can never claim
        // the bonus (total_cmp alone is not enough: negative NaN — the
        // default quiet NaN on x86 — sorts *below* -inf).
        let acc_key = |j: &JobView| {
            if j.acc.is_nan() {
                f32::INFINITY
            } else {
                j.acc
            }
        };
        let min_id = all
            .iter()
            .min_by(|a, b| acc_key(*a).total_cmp(&acc_key(*b)).then(a.id.cmp(&b.id)))
            .map(|j| j.id);
        if Some(job.id) == min_id {
            gain += job.acc_gain as f64;
        }
        gain
    }
}

impl Allocator for EccoAllocator {
    fn pick(&mut self, jobs: &[JobView]) -> usize {
        if let Some(id) = initial_pass_pick(jobs) {
            return id;
        }
        argmax_score(self, jobs)
    }

    fn score(&self, job: &JobView, all: &[JobView]) -> f64 {
        self.obj_gain(job, all)
    }

    fn name(&self) -> &'static str {
        "ecco"
    }
}

// ---------------------------------------------------------------------------
// Utility (Ekya / RECL style)
// ---------------------------------------------------------------------------

/// Total-accuracy-gain allocator: score = n_j * AccGain[j]. With one camera
/// per job (independent retraining) this is exactly Ekya's/RECL's
/// micro-window scheduling; with groups it exhibits the large-group bias
/// analysed in §3.1.
#[derive(Debug, Clone, Default)]
pub struct UtilityAllocator;

impl Allocator for UtilityAllocator {
    fn pick(&mut self, jobs: &[JobView]) -> usize {
        if let Some(id) = initial_pass_pick(jobs) {
            return id;
        }
        argmax_score(self, jobs)
    }

    fn score(&self, job: &JobView, _all: &[JobView]) -> f64 {
        job.n_cams as f64 * job.acc_gain as f64
    }

    fn name(&self) -> &'static str {
        "utility"
    }
}

// ---------------------------------------------------------------------------
// Uniform (naive)
// ---------------------------------------------------------------------------

/// Round-robin: every job gets the same number of micro-windows.
#[derive(Debug, Clone, Default)]
pub struct UniformAllocator;

impl Allocator for UniformAllocator {
    fn pick(&mut self, jobs: &[JobView]) -> usize {
        jobs.iter()
            .min_by_key(|j| (j.micro_windows, j.id))
            // ecco-lint: allow(D001) the scheduler only calls pick() with
            // a non-empty active-job set, and the Allocator trait has no
            // error channel to thread an empty-set failure through.
            .unwrap()
            .id
    }

    fn score(&self, _job: &JobView, _all: &[JobView]) -> f64 {
        1.0
    }

    fn name(&self) -> &'static str {
        "uniform"
    }
}

/// Which allocator a system run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocKind {
    Ecco,
    Utility,
    Uniform,
}

impl AllocKind {
    pub fn build(self) -> Box<dyn Allocator> {
        match self {
            AllocKind::Ecco => Box::new(EccoAllocator::default()),
            AllocKind::Utility => Box::new(UtilityAllocator),
            AllocKind::Uniform => Box::new(UniformAllocator),
        }
    }
}

/// Re-split the GPU-share estimates when job membership changes
/// mid-window (a fault evicted a camera and possibly emptied its job):
/// drop estimates for jobs that no longer exist and renormalise the
/// survivors, so the transmission controllers immediately see a
/// consistent `p_j` vector instead of shares that sum below 1. Jobs
/// created after the last estimate simply stay absent — their lookup
/// site already falls back to the uniform share, as after a regroup.
pub fn resplit_shares(shares: &mut BTreeMap<usize, f64>, live: &[usize]) {
    shares.retain(|id, _| live.contains(id));
    let total: f64 = shares.values().sum();
    if total > 0.0 && total.is_finite() {
        for p in shares.values_mut() {
            *p /= total;
        }
    } else {
        // Nothing valid to renormalise: clear so every job falls back to
        // the uniform share.
        shares.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn job(id: usize, n: usize, acc: f32, gain: f32, mw: usize) -> JobView {
        JobView {
            id,
            n_cams: n,
            acc,
            acc_gain: gain,
            micro_windows: mw,
            lifetime_mw: mw,
        }
    }

    #[test]
    fn initial_pass_trains_everyone_once() {
        let mut a = EccoAllocator::default();
        let jobs = vec![job(0, 4, 0.3, 0.1, 1), job(1, 1, 0.2, 0.05, 0)];
        assert_eq!(a.pick(&jobs), 1, "unprimed job must go first");
    }

    #[test]
    fn utility_favours_large_groups() {
        let mut a = UtilityAllocator;
        // Same per-model gain; 4-camera group wins on total utility.
        let jobs = vec![job(0, 4, 0.3, 0.10, 1), job(1, 1, 0.28, 0.15, 1)];
        assert_eq!(a.pick(&jobs), 0);
    }

    #[test]
    fn ecco_fairness_bonus_rescues_small_low_acc_group() {
        let mut a = EccoAllocator::default();
        // The paper's G1/G2 example: G1 has 4 cams +10%, G2 1 cam +15%,
        // and G2 is behind on accuracy. ECCO must pick G2.
        let jobs = vec![job(0, 4, 0.40, 0.10, 1), job(1, 1, 0.20, 0.15, 1)];
        assert_eq!(a.pick(&jobs), 1);
    }

    #[test]
    fn ecco_without_fairness_reduces_to_weighted_average() {
        let a = EccoAllocator {
            alpha: 1.0,
            beta: 1.0,
        };
        // Job 1 has the lower accuracy -> gets the bonus; score must exceed
        // its plain weighted term.
        let jobs = vec![job(0, 4, 0.4, 0.1, 1), job(1, 1, 0.2, 0.1, 1)];
        let s1 = a.score(&jobs[1], &jobs);
        let plain = 1.0 * (1.0 / 5.0) * 0.1;
        assert!(s1 > plain, "fairness bonus missing: {s1} vs {plain}");
    }

    #[test]
    fn nan_gain_never_wins_argmax() {
        // A NaN acc_gain used to make every comparison false, silently
        // handing the micro-window to jobs[0]; it must now rank below
        // every real score.
        let mut a = UtilityAllocator;
        let jobs = vec![job(0, 2, 0.3, f32::NAN, 1), job(1, 1, 0.3, 0.05, 1)];
        assert_eq!(a.pick(&jobs), 1, "NaN-scored job must not win");
        // All-NaN degenerates deterministically to the lowest id.
        let jobs = vec![job(1, 1, 0.3, f32::NAN, 1), job(0, 1, 0.2, f32::NAN, 1)];
        assert_eq!(a.pick(&jobs), 0);
        // ECCO's fairness bonus path must not panic on NaN accuracy, and
        // neither sign of NaN may claim the bonus (negative NaN sorts
        // below -inf under total_cmp, so it needs the explicit guard).
        let mut e = EccoAllocator::default();
        let jobs = vec![job(0, 1, f32::NAN, 0.1, 1), job(1, 1, 0.2, 0.1, 1)];
        assert_eq!(e.pick(&jobs), 1, "NaN-acc job must not take the bonus");
        let jobs = vec![job(0, 1, -f32::NAN, 0.1, 1), job(1, 1, 0.2, 0.1, 1)];
        assert_eq!(e.pick(&jobs), 1, "-NaN-acc job must not take the bonus");
    }

    #[test]
    fn exact_score_ties_break_to_lowest_id() {
        let mut a = UtilityAllocator;
        // Declared out of id order to make the tiebreak observable.
        let jobs = vec![job(2, 1, 0.3, 0.1, 1), job(1, 1, 0.3, 0.1, 1)];
        assert_eq!(a.pick(&jobs), 1);
        // ECCO with zero gains: every score is exactly 0.0 (the fairness
        // bonus adds 0.0 too), so the win can only come from argmax's
        // lowest-id tiebreak.
        let mut e = EccoAllocator::default();
        let jobs = vec![job(3, 1, 0.3, 0.0, 1), job(1, 1, 0.3, 0.0, 1), job(2, 1, 0.3, 0.0, 1)];
        assert_eq!(e.pick(&jobs), 1);
    }

    #[test]
    fn uniform_round_robins() {
        let mut a = UniformAllocator;
        let mut jobs = vec![job(0, 3, 0.5, 0.2, 0), job(1, 1, 0.1, 0.0, 0)];
        let first = a.pick(&jobs);
        jobs[first].micro_windows += 1;
        let second = a.pick(&jobs);
        assert_ne!(first, second);
    }

    #[test]
    fn share_estimates_normalised_and_positive() {
        let a = EccoAllocator::default();
        let jobs = vec![
            job(0, 3, 0.5, 0.08, 1),
            job(1, 1, 0.3, 0.12, 1),
            job(2, 2, 0.4, 0.0, 1),
        ];
        let p = a.share_estimates(&jobs);
        assert_eq!(p.len(), 3);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(p.iter().all(|&x| x > 0.0));
        // The low-accuracy high-gain job should get the largest share.
        assert!(p[1] > p[0] && p[1] > p[2], "{p:?}");
    }

    #[test]
    fn prop_budget_conservation_and_no_total_starvation() {
        // Simulate W micro-window picks over synthetic gain dynamics: total
        // assignments == W, and with ECCO no job starves across a full
        // window when it keeps showing positive gain.
        prop::check("alloc-no-starvation", 40, |g| {
            let n_jobs = g.usize(2, 5);
            let w = g.usize(2 * n_jobs, 30);
            let mut jobs: Vec<JobView> = (0..n_jobs)
                .map(|id| job(id, g.usize(1, 5), g.f32(0.05, 0.5), 0.0, 0))
                .collect();
            let mut alloc = EccoAllocator::default();
            let mut assigned = vec![0usize; n_jobs];
            for _ in 0..w {
                let pick = alloc.pick(&jobs);
                if pick >= n_jobs {
                    return Err(format!("picked unknown job {pick}"));
                }
                assigned[pick] += 1;
                jobs[pick].micro_windows += 1;
                jobs[pick].lifetime_mw += 1;
                // Diminishing but positive gains; accuracy saturates at 0.9.
                let j = &mut jobs[pick];
                j.acc_gain = (0.9 - j.acc) * 0.2;
                j.acc += j.acc_gain;
            }
            if assigned.iter().sum::<usize>() != w {
                return Err("budget not conserved".to_string());
            }
            if assigned.iter().any(|&a| a == 0) {
                return Err(format!("a job starved entirely: {assigned:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_utility_biases_to_large_groups_vs_ecco() {
        // Statistical version of Fig. 10: with a big and a small group of
        // equal per-model learning dynamics, utility gives the big group
        // strictly more micro-windows than ECCO does.
        let run = |mut alloc: Box<dyn Allocator>| -> (usize, usize) {
            let mut jobs = vec![job(0, 4, 0.1, 0.0, 0), job(1, 1, 0.1, 0.0, 0)];
            let mut counts = (0usize, 0usize);
            for _ in 0..24 {
                let pick = alloc.pick(&jobs);
                if pick == 0 {
                    counts.0 += 1;
                } else {
                    counts.1 += 1;
                }
                jobs[pick].micro_windows += 1;
                jobs[pick].lifetime_mw += 1;
                let j = &mut jobs[pick];
                j.acc_gain = (0.8 - j.acc) * 0.25;
                j.acc += j.acc_gain;
            }
            counts
        };
        let (ecco_big, ecco_small) = run(Box::new(EccoAllocator::default()));
        let (util_big, util_small) = run(Box::new(UtilityAllocator));
        assert!(util_big > ecco_big, "utility {util_big} !> ecco {ecco_big}");
        assert!(ecco_small > util_small);
        // ECCO keeps the small group within a reasonable band of parity.
        assert!(ecco_small >= 24 / 4, "ecco small-group share too low: {ecco_small}");
    }

    #[test]
    fn resplit_drops_dead_jobs_and_renormalises() {
        let mut shares: BTreeMap<usize, f64> =
            [(0, 0.5), (1, 0.25), (2, 0.25)].into_iter().collect();
        resplit_shares(&mut shares, &[0, 2]);
        assert_eq!(shares.len(), 2);
        assert!((shares[&0] - 2.0 / 3.0).abs() < 1e-12);
        assert!((shares[&2] - 1.0 / 3.0).abs() < 1e-12);
        let total: f64 = shares.values().sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn resplit_with_no_survivors_or_no_mass_clears() {
        let mut shares: BTreeMap<usize, f64> = [(0, 0.6), (1, 0.4)].into_iter().collect();
        resplit_shares(&mut shares, &[]);
        assert!(shares.is_empty());
        // Zero/NaN mass degrades to the uniform fallback (empty map).
        let mut zero: BTreeMap<usize, f64> = [(0, 0.0), (1, 0.0)].into_iter().collect();
        resplit_shares(&mut zero, &[0, 1]);
        assert!(zero.is_empty());
        let mut bad: BTreeMap<usize, f64> = [(0, f64::NAN)].into_iter().collect();
        resplit_shares(&mut bad, &[0]);
        assert!(bad.is_empty());
    }

    #[test]
    fn resplit_is_identity_when_membership_unchanged() {
        let mut shares: BTreeMap<usize, f64> = [(3, 0.75), (5, 0.25)].into_iter().collect();
        let before = shares.clone();
        resplit_shares(&mut shares, &[3, 5]);
        assert_eq!(shares, before, "normalised shares must pass through");
    }
}

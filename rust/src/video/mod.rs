//! Video sampling + encoding model — the FFmpeg substitute.
//!
//! A camera's *sampling configuration* is (frame rate, resolution); its
//! pixel throughput `fps * res^2` is what the GPU budget caps (§3.2.1).
//! During streaming the encoder keeps (f, q) fixed and adapts the
//! *compression level* to track the congestion-controlled sending rate
//! (§3.2.2): more compression = fewer bits per frame = lower fidelity
//! training data. Below a quality floor the encoder drops frames instead
//! of compressing further (matching real rate-controlled encoders).
//!
//! Fidelity loss is modelled physically: quantization of pixel values plus
//! compression noise, applied to the actual training tensors, so poor
//! bandwidth genuinely degrades retraining accuracy end-to-end.

use crate::util::rng::Pcg32;

/// Bits per (channel-)pixel at which encoding is visually lossless.
///
/// PROXY SCALING: our RxR study frames stand in for the paper's 960-line
/// video (a ~20x linear / ~400x pixel-count reduction chosen so CPU-PJRT
/// retraining stays tractable). Bit accounting is scaled by ~32x relative
/// to the study frames so a camera's stream demands sit in the paper's
/// regime: a 48px/5fps stream "costs" ~4.4 Mbit/s near-lossless, and a
/// 1 Mbit/s uplink is a genuinely constrained camera, matching the
/// operating points of §5. Without this, toy-frame streams would be so
/// cheap that no experiment would ever be bandwidth-bound.
pub const BPP_LOSSLESS: f64 = 128.0;
/// Minimum useful bits per channel-pixel; below this frames are dropped.
pub const BPP_FLOOR: f64 = 8.0;

/// Frame-rate choices profiled by the transmission controller (Hz).
pub const FPS_CHOICES: [f32; 6] = [0.5, 1.0, 2.0, 4.0, 6.0, 10.0];
/// Resolution choices (must match the AOT artifact variants).
pub const RES_CHOICES: [usize; 3] = [16, 32, 48];

/// A sampling configuration: frame rate and resolution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingConfig {
    pub fps: f32,
    pub res: usize,
}

impl SamplingConfig {
    /// Training pixel throughput this configuration produces (pixels/s) —
    /// the quantity the GPU budget is expressed in (§3.2).
    pub fn pixels_per_sec(&self) -> f64 {
        self.fps as f64 * (self.res * self.res) as f64
    }

    /// All (fps, res) combinations in profiling order.
    pub fn all() -> Vec<SamplingConfig> {
        let mut out = Vec::new();
        for &res in &RES_CHOICES {
            for &fps in &FPS_CHOICES {
                out.push(SamplingConfig { fps, res });
            }
        }
        out
    }
}

/// Outcome of transporting one window's frame stream under a bandwidth
/// budget with adaptive compression.
#[derive(Debug, Clone, PartialEq)]
pub struct TransportOutcome {
    /// Frames sampled by the camera this window.
    pub frames_sampled: usize,
    /// Frames actually delivered in time (<= sampled).
    pub frames_delivered: usize,
    /// Achieved bits per channel-pixel of delivered frames.
    pub bpp: f64,
    /// Encoder quality in [0,1] (1 = lossless).
    pub quality: f64,
}

/// Compute what survives the uplink: the encoder fits `fps * dur` frames of
/// `res^2*3` channel-pixels into `delivered_mbit` megabits by adapting
/// compression, dropping frames once the quality floor is hit.
pub fn transport_window(
    config: SamplingConfig,
    window_secs: f64,
    delivered_mbit: f64,
) -> TransportOutcome {
    let frames_sampled = (config.fps as f64 * window_secs).floor().max(0.0) as usize;
    if frames_sampled == 0 {
        return TransportOutcome {
            frames_sampled: 0,
            frames_delivered: 0,
            bpp: 0.0,
            quality: 0.0,
        };
    }
    let chan_pixels_per_frame = (config.res * config.res * 3) as f64;
    let total_bits = delivered_mbit * 1e6;
    let bpp_all = total_bits / (frames_sampled as f64 * chan_pixels_per_frame);
    if bpp_all >= BPP_FLOOR {
        let bpp = bpp_all.min(BPP_LOSSLESS);
        TransportOutcome {
            frames_sampled,
            frames_delivered: frames_sampled,
            bpp,
            quality: quality_of(bpp),
        }
    } else {
        // Hold the floor quality; deliver as many frames as fit.
        let per_frame_bits = BPP_FLOOR * chan_pixels_per_frame;
        let deliverable = (total_bits / per_frame_bits).floor() as usize;
        TransportOutcome {
            frames_sampled,
            frames_delivered: deliverable.min(frames_sampled),
            bpp: BPP_FLOOR,
            quality: quality_of(BPP_FLOOR),
        }
    }
}

/// Encoder quality in [0,1] as a function of achieved bits/channel-pixel.
pub fn quality_of(bpp: f64) -> f64 {
    (bpp / BPP_LOSSLESS).clamp(0.0, 1.0).powf(0.75)
}

/// Apply encode/decode degradation to a frame's pixels (HWC, `res` x `res`)
/// in place: value quantization + coding noise + block blur, deterministic
/// in `seed`.
///
/// The blur term is what makes heavy compression *destroy information*
/// rather than merely add noise: real codecs at low bitrate smear small
/// objects into their background (blocking/deblocking), which is exactly
/// the failure mode that makes starved streams poor training data. Without
/// it, quantization noise acts as free data augmentation and low-bitrate
/// frames would paradoxically help.
pub fn degrade(pixels: &mut [f32], res: usize, quality: f64, seed: u64) {
    if quality >= 0.999 {
        return;
    }
    debug_assert_eq!(pixels.len(), res * res * 3);
    let q = quality.max(0.02);
    let levels = (2.0 + 253.0 * q.powf(1.2)) as f32;
    let noise_std = (0.12 * (1.0 - q).powf(1.3)) as f32;
    // Box-blur radius: 0 above q=0.6, 1 down to q=0.3, 2 below.
    let radius = if q >= 0.6 {
        0usize
    } else if q >= 0.3 {
        1
    } else {
        2
    };
    if radius > 0 {
        let src = pixels.to_vec();
        for iy in 0..res {
            for ix in 0..res {
                let y0 = iy.saturating_sub(radius);
                let y1 = (iy + radius).min(res - 1);
                let x0 = ix.saturating_sub(radius);
                let x1 = (ix + radius).min(res - 1);
                let mut acc = [0.0f32; 3];
                let mut n = 0.0f32;
                for y in y0..=y1 {
                    for x in x0..=x1 {
                        let off = (y * res + x) * 3;
                        for c in 0..3 {
                            acc[c] += src[off + c];
                        }
                        n += 1.0;
                    }
                }
                let off = (iy * res + ix) * 3;
                for c in 0..3 {
                    pixels[off + c] = acc[c] / n;
                }
            }
        }
    }
    let mut rng = Pcg32::new(seed, 23);
    for p in pixels.iter_mut() {
        let quantized = (*p * levels).round() / levels;
        let noisy = quantized + noise_std * rng.normal();
        *p = noisy.clamp(0.0, 1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pixels_per_sec_math() {
        let c = SamplingConfig { fps: 5.0, res: 32 };
        assert_eq!(c.pixels_per_sec(), 5.0 * 1024.0);
    }

    #[test]
    fn ample_bandwidth_delivers_everything_losslessly() {
        let c = SamplingConfig { fps: 5.0, res: 32 };
        // 60s * 5fps * 3072 channel-pixels * 128bpp = 118 Mbit; give 200.
        let out = transport_window(c, 60.0, 200.0);
        assert_eq!(out.frames_delivered, out.frames_sampled);
        assert_eq!(out.frames_sampled, 300);
        assert!(out.quality > 0.99, "quality={}", out.quality);
    }

    #[test]
    fn moderate_bandwidth_compresses_but_keeps_frames() {
        let c = SamplingConfig { fps: 5.0, res: 32 };
        let need_lossless = 300.0 * 3072.0 * BPP_LOSSLESS / 1e6; // ~118 Mbit
        let out = transport_window(c, 60.0, need_lossless * 0.3);
        assert_eq!(out.frames_delivered, 300);
        assert!(out.quality < 0.9 && out.quality > 0.2, "q={}", out.quality);
    }

    #[test]
    fn starved_bandwidth_drops_frames() {
        let c = SamplingConfig { fps: 10.0, res: 48 };
        let out = transport_window(c, 60.0, 5.0); // 5 Mbit for 600 frames
        assert!(out.frames_delivered < out.frames_sampled);
        assert!((out.bpp - BPP_FLOOR).abs() < 1e-9);
        // Delivered count matches the floor-rate budget.
        let per_frame = BPP_FLOOR * (48.0 * 48.0 * 3.0);
        assert_eq!(out.frames_delivered, (5.0e6 / per_frame) as usize);
    }

    #[test]
    fn zero_fps_yields_nothing() {
        let c = SamplingConfig { fps: 0.0, res: 32 };
        let out = transport_window(c, 60.0, 10.0);
        assert_eq!(out.frames_sampled, 0);
        assert_eq!(out.frames_delivered, 0);
    }

    #[test]
    fn degrade_noop_at_full_quality() {
        let mut px = vec![0.5; 16 * 16 * 3];
        let orig = px.clone();
        degrade(&mut px, 16, 1.0, 7);
        assert_eq!(px, orig);
    }

    #[test]
    fn degrade_monotone_in_quality() {
        let base: Vec<f32> = (0..32 * 32 * 3).map(|i| (i % 256) as f32 / 255.0).collect();
        let err = |q: f64| {
            let mut px = base.clone();
            degrade(&mut px, 32, q, 7);
            px.iter()
                .zip(&base)
                .map(|(a, b)| (a - b).abs() as f64)
                .sum::<f64>()
                / px.len() as f64
        };
        let e_hi = err(0.9);
        let e_mid = err(0.4);
        let e_lo = err(0.08);
        assert!(e_hi < e_mid && e_mid < e_lo, "{e_hi} {e_mid} {e_lo}");
    }

    #[test]
    fn degrade_deterministic() {
        let mut a: Vec<f32> = (0..10 * 10 * 3).map(|i| i as f32 / 300.0).collect();
        let mut b = a.clone();
        degrade(&mut a, 10, 0.3, 99);
        degrade(&mut b, 10, 0.3, 99);
        assert_eq!(a, b);
    }

    #[test]
    fn degrade_blur_smears_small_objects() {
        // A bright 1-pixel dot on dark background loses most contrast at
        // low quality (the information-destruction property tab1 relies on).
        let res = 16;
        let mut px = vec![0.1f32; res * res * 3];
        let centre = (8 * res + 8) * 3;
        px[centre] = 1.0;
        let before = px[centre] - 0.1;
        degrade(&mut px, res, 0.1, 3);
        let after = px[centre] - 0.1;
        assert!(
            after < before * 0.5,
            "low-q must smear the dot: {before} -> {after}"
        );
    }

    #[test]
    fn quality_of_monotone() {
        assert!(quality_of(128.0) > quality_of(32.0));
        assert!(quality_of(32.0) > quality_of(8.0));
        assert_eq!(quality_of(256.0), 1.0);
    }
}

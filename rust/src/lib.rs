//! # ECCO — cross-camera correlated continuous learning
//!
//! A full-system reproduction of *"ECCO: Leveraging Cross-Camera
//! Correlations for Efficient Live Video Continuous Learning"* (He,
//! Kossmann, Seshan, Steenkiste, 2025) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **L1 (build time)** — `python/compile/kernels/`: the fused-matmul
//!   Pallas kernel every convolution lowers to, plus the patch-statistics
//!   kernel behind drift/grouping descriptors.
//! * **L2 (build time)** — `python/compile/model.py`: the student detector
//!   / segmenter, its SGD train step, inference and feature programs,
//!   AOT-lowered to HLO text in `artifacts/` by `python/compile/aot.py`.
//! * **L3 (this crate)** — the ECCO coordinator and every evaluation
//!   substrate the paper relies on. Python never runs at request time: the
//!   [`runtime`] module loads the HLO artifacts via PJRT (CPU) and all
//!   retraining happens through compiled executables.
//!
//! ## Module map
//!
//! | module | role |
//! |--------|------|
//! | [`runtime`] | PJRT engine: artifact manifest, executable cache, train/infer/features |
//! | [`scene`] | drifting-world simulator (CityFlow/MDOT/CARLA substitute) |
//! | [`video`] | sampling configs + encoder model (FFmpeg substitute) |
//! | [`net`] | fluid GAIMD network simulator (NS-3 substitute) |
//! | [`teacher`] | oracle-with-noise annotator (YOLO11x substitute) |
//! | [`metrics`] | cell-level mAP / mask-mAP, response-time tracking |
//! | [`alloc`] | Alg. 1 GPU allocator + Ekya/RECL/naive baselines |
//! | [`grouping`] | Alg. 2 dynamic camera grouping |
//! | [`transmission`] | §3.2 sampling-config tables + GAIMD parameterisation |
//! | [`zoo`] | RECL-style model zoo |
//! | [`server`] | retraining jobs, micro-window scheduler, the [`server::System`] loop |
//! | [`exp`] | one runner per paper table/figure (`ecco exp <id>`) |
//! | [`util`] | from-scratch substrates: RNG, JSON, CLI, logging, stats, property tests, bench harness |
//!
//! ## Quick start
//!
//! ```bash
//! make artifacts                      # AOT-lower the models (python, once)
//! cargo run --release --example quickstart
//! cargo run --release --bin ecco -- exp all   # regenerate every table/figure
//! ```
pub mod alloc;
pub mod exp;
pub mod grouping;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod scene;
pub mod server;
pub mod teacher;
pub mod transmission;
pub mod util;
pub mod video;
pub mod zoo;

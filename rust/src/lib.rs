//! # ECCO — cross-camera correlated continuous learning
//!
//! A full-system reproduction of *"ECCO: Leveraging Cross-Camera
//! Correlations for Efficient Live Video Continuous Learning"* (He,
//! Kossmann, Seshan, Steenkiste, 2025) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **L1 (build time)** — `python/compile/kernels/`: the fused-matmul
//!   Pallas kernel every convolution lowers to, plus the patch-statistics
//!   kernel behind drift/grouping descriptors.
//! * **L2 (build time)** — `python/compile/model.py`: the student detector
//!   / segmenter, its SGD train step, inference and feature programs,
//!   AOT-lowered to HLO text in `artifacts/` by `python/compile/aot.py`.
//! * **L3 (this crate)** — the ECCO coordinator and every evaluation
//!   substrate the paper relies on. Python never runs at request time: the
//!   [`runtime`] module executes the model programs either through the
//!   pure-Rust reference backend (default, no artifacts needed) or through
//!   PJRT-compiled HLO artifacts (`--features pjrt`).
//!
//! ## Module map
//!
//! | module | role |
//! |--------|------|
//! | [`api`] | **the public entry point**: [`api::RunSpec`] builder, [`api::Session`] handle, typed [`api::Event`] stream |
//! | [`runtime`] | engine backends (native reference / PJRT), artifact manifest, train/infer/features |
//! | [`scene`] | drifting-world simulator (CityFlow/MDOT/CARLA substitute) |
//! | [`video`] | sampling configs + encoder model (FFmpeg substitute) |
//! | [`net`] | fluid GAIMD network simulator (NS-3 substitute) |
//! | [`teacher`] | oracle-with-noise annotator (YOLO11x substitute) |
//! | [`metrics`] | cell-level mAP / mask-mAP, response-time tracking |
//! | [`alloc`] | Alg. 1 GPU allocator + Ekya/RECL/naive baselines |
//! | [`faults`] | deterministic fault injection: seeded [`faults::FaultPlan`]s + graceful-degradation contract |
//! | [`grouping`] | Alg. 2 dynamic camera grouping |
//! | [`transmission`] | §3.2 sampling-config tables + GAIMD parameterisation |
//! | [`zoo`] | RECL-style model zoo |
//! | [`serve`] | multi-tenant socket host: line-JSON protocol, admission queue, back-pressure, snapshot/resume (`ecco serve`) |
//! | [`server`] | retraining jobs, micro-window scheduler, the (crate-private) `System` loop |
//! | [`exp`] | one runner per paper table/figure (`ecco exp <id>`) |
//! | [`lint`] | determinism & safety static analysis over this crate's own sources (`ecco lint`, rules D001–D006) |
//! | [`util`] | from-scratch substrates: RNG, JSON, CLI, logging, stats, property tests, bench harness, persistent worker pool ([`util::pool`]), poison-tolerant lock helpers ([`util::sync`]) |
//!
//! ## Threading model
//!
//! The runtime [`runtime::Engine`] is **shared state**: its manifest is
//! immutable after construction and its statistics are atomics, so every
//! engine method takes `&self` and the type is `Sync`. All mutable
//! training state lives in [`runtime::ModelState`] values owned by the
//! caller.
//!
//! Every engine additionally owns a **persistent worker pool**
//! ([`util::pool::Pool`]): a fixed set of threads spawned once at
//! `Engine::new`, parked on a condvar between fan-outs, and joined when
//! the engine drops. Work is handed out by an atomic cursor, results
//! write back into per-slot cells by item index, and the submitting
//! caller always participates in its own fan-out — which bounds total
//! parallelism by the pool width no matter how the layers below nest, and
//! makes nested fan-outs deadlock-free by construction. Three layers
//! dispatch onto it:
//!
//! * **Kernel batch sharding** — `runtime::native`'s `train_step` /
//!   `infer_det` / `infer_seg` shard the batch dimension (per-sample
//!   forward/backward passes are independent given the batch-global loss
//!   normalisers). Loss partials and gradients reduce in sample-index
//!   order, so every step is **bit-identical at any pool width**.
//! * **Eval fan-out** — the coordinator's per-window evaluation batches
//!   (candidate evals during request placement, per-member job evals, the
//!   per-camera window pass, and the regroup matrix). Results reduce in
//!   item-index order, so event streams, reports, and RNG consumption are
//!   **byte-identical at any thread count** (`SystemConfig::eval_threads`,
//!   [`api::RunSpec::eval_threads`], or the `ECCO_THREADS` env var).
//! * **Fleet fan-out** — [`api::run_fleet`] runs whole specs (policy arms,
//!   scenario sweeps) concurrently over one shared engine, reports in spec
//!   order; the experiment runners take `--threads N`, and `ecco exp all`
//!   fans the independent experiment ids out with per-experiment buffered
//!   printing (whole experiments print in id order).
//!
//! A fourth layer sits between the eval fan-outs and the kernels: the
//! **micro-batch submission layer** ([`runtime::microbatch`]). Every
//! `Engine::infer_det` / `infer_seg` / `features` call is a *submission*
//! into an [`runtime::microbatch::InferQueue`] owned by the engine. With
//! coalescing enabled ([`api::RuntimeOpts::coalesce`] /
//! `Engine::set_coalesce`; **off by default**), concurrent submissions
//! sharing a coalesce key — the program (det/seg/features), the
//! resolution, and a content hash of theta (so per-camera clones of a
//! published group model merge without pointer aliasing) — combine into
//! one mega-batched kernel launch under a bounded coalesce window and
//! mega-batch cap, and each submitter gets back exactly its own
//! per-sample slice. The queue lives as long as the engine; knobs are
//! atomics, so serve sessions reconfigure a shared engine lock-free
//! (last writer wins). The **determinism rule**: inference kernels are
//! per-sample pure with index-ordered concatenation, so results are
//! bit-identical no matter how requests group — event logs and
//! accuracies are byte-equal with coalescing on or off, at any pool
//! width; only the `infer_calls` launch counter (a perf statistic) is
//! timing-dependent. A leader that observes no other in-flight submitter
//! skips the coalesce window entirely, so serial callers pay nothing.
//!
//! The eval fan-outs additionally read rendered frames through a
//! **per-(camera, salt) eval-frame cache** owned by each run: renders are
//! pure functions of the frozen world state, the cache is invalidated on
//! every world advance (each micro-window), and cached batches are
//! therefore bit-identical to fresh renders — the pre-/post-training eval
//! pair of a micro-window and the window-boundary passes share one render
//! per camera instead of re-rasterising (`SystemConfig::frame_cache`
//! force-disables it for A/B verification).
//!
//! Training itself stays sequential across micro-windows by design:
//! Alg. 1 time-shares all GPUs on one job per micro-window, so the serial
//! step loop *is* the semantics being simulated — only the math inside
//! each step is sharded.
//!
//! ## Scheduler clock model
//!
//! Two per-window drivers share all of the machinery above
//! ([`server::Scheduler`], picked via [`api::RuntimeOpts::scheduler`]):
//! the legacy **lockstep** loop advances every camera in unison one
//! micro-window at a time, while the **event-driven** driver
//! ([`server::sched`]) runs a min-heap time wheel so cameras with
//! heterogeneous window lengths and staggered phases
//! ([`api::CameraSpec::window_len`] / [`api::CameraSpec::phase`]) advance
//! independently. The wheel's clock is deliberately *slot-quantised*: the
//! driver performs the identical sequence of `advance(window/W)` calls the
//! lockstep loop would, and events are keyed by the integer micro-tick
//! they fall in, never by float instants. Within a tick, events drain in
//! `(action, camera id)` order — captures, then drift probes, then the
//! training micro-window, then per-camera window boundaries — which is
//! exactly the lockstep statement order, with camera id as the
//! deterministic tie-break. Fault-plan drains are not wheel events: the
//! fault cursor fires as a fixed step *before* each tick's time advance
//! (and once more at the window end), exactly where the lockstep loop
//! applies it. Consequences: with uniform windows the event driver is
//! **byte-identical** to lockstep — same events, same RNG draws, same
//! timestamps to the last ULP (pinned by `rust/tests/scheduler.rs`) — and
//! any heterogeneous camera window forces the event driver automatically.
//! At city scale, grouping's candidate scan is pruned to each camera's
//! spatial neighbors via [`grouping::topology`]
//! ([`api::RunSpec::topology_degree`]), with a periodic long-range window
//! that rescans all pairs so distant-but-correlated cameras still merge.
//!
//! ## Serving model
//!
//! `ecco serve` ([`serve`]) hosts many sessions in one long-lived process:
//! clients connect over TCP or a unix socket, `submit` a wire-form
//! [`api::RunSpec`] ([`api::RunSpec::to_wire_json`] /
//! [`api::RunSpec::from_wire_json`]) as one JSON line, and stream typed
//! [`api::Event`] frames back. Sessions are admitted FIFO into a bounded
//! queue and executed by a small runner pool sharing one engine — the
//! same fan-out discipline as [`api::run_fleet`]. Back-pressure is
//! per-consumer: each streaming connection owns a bounded frame buffer;
//! a slow reader loses (counted, reported) frames, never stalls a runner,
//! and never perturbs the run. `snapshot` captures
//! `{"completed":k,"spec":…}` at a window boundary; because runs are
//! deterministic given the spec, `resume` replays the first `k` windows
//! silently and continues the event stream seq-contiguously — the
//! combined stream is byte-identical to an uninterrupted run (pinned by
//! `rust/tests/serve.rs`). `examples/loadgen.rs` drives the host with
//! dozens of concurrent clients.
//!
//! ## Fault model
//!
//! Deployments churn: cameras flap, uplinks saturate, probes go missing.
//! The [`faults`] module injects exactly that, deterministically — a
//! seeded [`faults::FaultPlan`] (attach via [`api::RunSpec::faults`] or a
//! [`faults::FaultScenario`] preset) schedules camera dropout/rejoin,
//! uplink outage and capacity degradation, straggler windows, and
//! corrupted (NaN/zeroed) probe embeddings at fixed micro-window
//! boundaries. Every layer degrades gracefully instead of panicking:
//!
//! * **server** — a dead camera is evicted from its job without stalling
//!   the group; an emptied job's model is *parked* and restored when the
//!   camera rejoins, which then re-places itself through the normal
//!   drift-probe path with bounded retry/backoff on lost probes.
//! * **net** — links take up/down and capacity-rescale operations; a
//!   camera behind a dead uplink keeps serving its last good model.
//! * **alloc** — GPU shares re-split over the surviving jobs the moment
//!   membership shrinks mid-window.
//! * **transmission** — the controller falls back to its last valid
//!   profile entry when the pushed budget is missing or NaN.
//!
//! Fault activity is visible as typed events
//! ([`api::Event::CameraDown`], [`api::Event::LinkDegraded`],
//! [`api::Event::FaultRecovered`], …) and summarized in the report's
//! resilience metrics (accuracy-under-fault, windows-to-recover). With
//! no plan attached the subsystem is guaranteed zero-cost: event logs
//! are byte-identical to a fault-free build (pinned by
//! `rust/tests/faults.rs`).
//!
//! ## Determinism contract
//!
//! Everything above leans on one invariant: **given a spec, event logs
//! and accuracies are byte-identical at any thread count, on any
//! machine** — it is what makes the A/B claims (coalescing on/off, cache
//! on/off, event-driven vs lockstep, resume vs uninterrupted) checkable
//! at all. The [`lint`] subsystem (`ecco lint`, run in CI) enforces the
//! contract's known failure modes as named rules:
//!
//! * **D001** — no `unwrap`/`expect`/`panic!` in hot-path modules
//!   (`server`, `runtime`, `serve`, `net`, `transmission`, `alloc`): a
//!   panic there takes down a runner, a session, or the process instead
//!   of failing one request. Typed errors or a documented suppression.
//! * **D002** — no `HashMap`/`HashSet` in event-emitting or
//!   wire-serializing modules: hash iteration order would leak into
//!   event and frame bytes. `BTreeMap`/`BTreeSet` only.
//! * **D003** — no wall-clock (`Instant::now`, `SystemTime::now`),
//!   `sleep`, or entropy-seeded randomness outside allowlisted perf
//!   surfaces: wall time may feed perf counters, never events or
//!   accuracies.
//! * **D004** — every `unsafe` lives in an allowlisted module
//!   ([`util::pool`], [`runtime::microbatch`]), carries an adjacent
//!   `// SAFETY:` comment, and every `unsafe fn` a `# Safety` doc
//!   section. The pool's slot protocol is additionally checked under
//!   Miri in CI.
//! * **D005** — no `partial_cmp` on floats (the repo's most recurrent
//!   bug class): one NaN in a score column makes ordering panic or go
//!   unstable. `total_cmp` only.
//! * **D006** — no `.lock().unwrap()` / unhandled poison: one panicked
//!   thread must not cascade into every later locker. Use
//!   [`util::sync::plock`] and friends, which recover the guard (sound
//!   because every lock in this crate restores invariants before
//!   unlock).
//!
//! Intentional exceptions are inline `// ecco-lint: allow(D00x) reason`
//! suppressions with a mandatory written reason; `ecco lint` exits
//! non-zero on any unsuppressed finding, and the shipped tree is clean.
//!
//! ## Quick start
//!
//! Every run goes through [`api::RunSpec`] and [`api::Session`]:
//!
//! ```no_run
//! use ecco::api::{RunSpec, Session};
//! use ecco::runtime::{Engine, Task};
//! use ecco::server::Policy;
//!
//! # fn main() -> anyhow::Result<()> {
//! let engine = Engine::open_default()?;
//! let spec = RunSpec::new(Task::Det, Policy::ecco())
//!     .cams(6)
//!     .gpus(2.0)
//!     .shared_mbps(6.0)
//!     .windows(8)
//!     .seed(7);
//! let mut session = Session::new(&engine, spec)?;
//! for _ in 0..8 {
//!     let w = session.step_window()?;
//!     println!("window {}: mean mAP {:.3}, {} jobs", w.window, w.mean_acc, w.jobs);
//! }
//! # Ok(())
//! # }
//! ```
//!
//! Or from the shell:
//!
//! ```bash
//! cargo run --release --example quickstart
//! cargo run --release --bin ecco -- run --policy ecco --cams 6 --windows 8
//! cargo run --release --bin ecco -- exp all   # regenerate every table/figure
//! ```
//!
//! Generated artifacts (`make artifacts`, python + jax) are only needed
//! for the PJRT backend and the golden-numerics tests; the default native
//! backend runs everywhere.
pub mod alloc;
pub mod api;
pub mod exp;
pub mod faults;
pub mod grouping;
pub mod lint;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod scene;
pub mod serve;
pub mod server;
pub mod teacher;
pub mod transmission;
pub mod util;
pub mod video;
pub mod zoo;

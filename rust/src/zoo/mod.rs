//! RECL-style model zoo: a store of historical student checkpoints plus a
//! selector that warm-starts retraining from the best-matching one.
//!
//! RECL's zoo is keyed by a learned model selector; here each checkpoint
//! carries the mean feature embedding of the data it was trained on, and
//! selection is nearest-neighbour (cosine) between the retraining request's
//! sample embedding and the stored signatures — the same "pick the
//! historical model that matches the current distribution" role.

use crate::util::stats::cosine;

/// One stored checkpoint.
#[derive(Debug, Clone)]
pub struct ZooEntry {
    /// Flat parameter vector of the student.
    pub theta: Vec<f32>,
    /// Mean (unit-norm) feature embedding of its training data.
    pub signature: Vec<f32>,
    /// Provenance label (camera id, scenario tag, ...).
    pub label: String,
}

/// The model zoo.
#[derive(Debug, Clone, Default)]
pub struct ModelZoo {
    pub entries: Vec<ZooEntry>,
    /// Maximum retained entries (RECL prunes its zoo; we keep it simple
    /// with FIFO eviction past the cap).
    pub capacity: usize,
}

impl ModelZoo {
    pub fn new(capacity: usize) -> ModelZoo {
        ModelZoo {
            entries: Vec::new(),
            capacity,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Insert a checkpoint; evicts the oldest entry past capacity.
    pub fn insert(&mut self, theta: Vec<f32>, signature: Vec<f32>, label: &str) {
        self.entries.push(ZooEntry {
            theta,
            signature,
            label: label.to_string(),
        });
        if self.capacity > 0 && self.entries.len() > self.capacity {
            self.entries.remove(0);
        }
    }

    /// Select the entry whose signature best matches `query` (cosine).
    /// Returns `None` when empty or the best match is below `min_sim`.
    pub fn select(&self, query: &[f32], min_sim: f32) -> Option<&ZooEntry> {
        self.entries
            .iter()
            .map(|e| (e, cosine(&e.signature, query)))
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .filter(|(_, sim)| *sim >= min_sim)
            .map(|(e, _)| e)
    }
}

/// Mean of embedding rows (each `dim` long), re-normalised to unit norm.
pub fn mean_embedding(rows: &[f32], dim: usize) -> Vec<f32> {
    assert!(dim > 0 && rows.len().is_multiple_of(dim));
    let n = rows.len() / dim;
    let mut mean = vec![0.0f32; dim];
    for row in rows.chunks(dim) {
        for (m, v) in mean.iter_mut().zip(row) {
            *m += v / n as f32;
        }
    }
    let norm = mean.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-8);
    for m in &mut mean {
        *m /= norm;
    }
    mean
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(dir: usize, dim: usize) -> Vec<f32> {
        let mut v = vec![0.0; dim];
        v[dir] = 1.0;
        v
    }

    #[test]
    fn selects_nearest_signature() {
        let mut zoo = ModelZoo::new(8);
        zoo.insert(vec![1.0], sig(0, 4), "a");
        zoo.insert(vec![2.0], sig(1, 4), "b");
        let mut q = sig(1, 4);
        q[0] = 0.2;
        let best = zoo.select(&q, 0.0).unwrap();
        assert_eq!(best.label, "b");
    }

    #[test]
    fn respects_min_similarity() {
        let mut zoo = ModelZoo::new(8);
        zoo.insert(vec![1.0], sig(0, 4), "a");
        assert!(zoo.select(&sig(1, 4), 0.5).is_none());
        assert!(zoo.select(&sig(0, 4), 0.5).is_some());
    }

    #[test]
    fn fifo_eviction_past_capacity() {
        let mut zoo = ModelZoo::new(2);
        zoo.insert(vec![1.0], sig(0, 4), "a");
        zoo.insert(vec![2.0], sig(1, 4), "b");
        zoo.insert(vec![3.0], sig(2, 4), "c");
        assert_eq!(zoo.len(), 2);
        assert!(zoo.select(&sig(0, 4), 0.9).is_none(), "oldest evicted");
        assert_eq!(zoo.select(&sig(2, 4), 0.9).unwrap().label, "c");
    }

    #[test]
    fn empty_zoo_selects_nothing() {
        let zoo = ModelZoo::new(4);
        assert!(zoo.select(&sig(0, 4), 0.0).is_none());
    }

    #[test]
    fn mean_embedding_unit_norm() {
        let rows = vec![1.0, 0.0, 0.0, 1.0]; // two 2-d rows
        let m = mean_embedding(&rows, 2);
        let norm: f32 = m.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
        assert!((m[0] - m[1]).abs() < 1e-6, "symmetric rows -> diagonal");
    }
}

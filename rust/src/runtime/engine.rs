//! Execution engine: the coordinator's only gateway to model compute.
//!
//! Two interchangeable backends sit behind the same [`Engine`] API:
//!
//! * **native** (default) — the pure-Rust reference implementation in
//!   [`super::native`]: the same trunk/head/loss/SGD math the AOT
//!   artifacts encode, runnable anywhere with no artifacts on disk.
//! * **pjrt** (`--features pjrt`) — the original PJRT/XLA path in
//!   [`super::pjrt`], which loads `artifacts/*.hlo.txt` lowered by
//!   `python/compile/aot.py` and executes them on the CPU PJRT client.
//!   It additionally needs the `xla` bindings crate (not available in the
//!   offline build environment).
//!
//! The coordinator drives everything through three calls:
//!
//! * [`Engine::train_step`] — one SGD step on a model's flat params
//! * [`Engine::infer_det`] / [`Engine::infer_seg`] — batched predictions
//! * [`Engine::features`]  — drift/grouping descriptors
//!
//! Inference calls are **submissions**, not direct launches: they route
//! through the engine's [`InferQueue`](super::microbatch::InferQueue),
//! which (when enabled via [`Engine::set_coalesce`]) merges concurrent
//! requests sharing a `(program, resolution, theta)` key into single
//! mega-batched kernel launches and hands each caller back its own
//! per-sample slice — bit-identical to the per-call path (see
//! [`super::microbatch`] for the determinism argument). Off by default;
//! the disabled path is a zero-overhead passthrough.

#[cfg(not(feature = "pjrt"))]
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};

#[cfg(not(feature = "pjrt"))]
use std::path::Path;

#[cfg(not(feature = "pjrt"))]
use super::manifest::Manifest;
use super::manifest::Task;
#[cfg(not(feature = "pjrt"))]
use super::microbatch::{self, CoalesceOpts, InferOut, InferQueue, InferRequest, ReqKind};
#[cfg(not(feature = "pjrt"))]
use super::native;
#[cfg(not(feature = "pjrt"))]
use crate::util::pool::{self, Pool};

/// Mutable training state of one student model.
#[derive(Debug, Clone)]
pub struct ModelState {
    pub task: Task,
    pub theta: Vec<f32>,
    pub mom: Vec<f32>,
    /// Total SGD steps applied.
    pub steps: u64,
}

impl ModelState {
    pub fn param_count(&self) -> usize {
        self.theta.len()
    }

    /// Fresh momentum (used when warm-starting from another model's theta).
    pub fn from_theta(task: Task, theta: Vec<f32>) -> ModelState {
        let mom = vec![0.0; theta.len()];
        ModelState {
            task,
            theta,
            mom,
            steps: 0,
        }
    }
}

/// Labels for one training batch (already rasterized to tensors).
#[derive(Debug, Clone)]
pub enum Labels {
    /// obj: `[B,G,G]`, cls one-hot: `[B,G,G,K]`.
    Det { obj: Vec<f32>, cls: Vec<f32> },
    /// mask one-hot: `[B,S,S,K+1]`.
    Seg { mask: Vec<f32> },
}

/// One training batch: pixels `[B,r,r,3]` + labels.
#[derive(Debug, Clone)]
pub struct TrainBatch {
    pub res: usize,
    pub pixels: Vec<f32>,
    pub labels: Labels,
}

/// Detection predictions for an inference batch.
#[derive(Debug, Clone)]
pub struct DetPred {
    pub batch: usize,
    pub grid: usize,
    pub classes: usize,
    /// `[B,G,G]` objectness probabilities.
    pub obj: Vec<f32>,
    /// `[B,G,G,K]` class probabilities.
    pub cls: Vec<f32>,
}

impl DetPred {
    pub fn obj_at(&self, b: usize, gy: usize, gx: usize) -> f32 {
        self.obj[(b * self.grid + gy) * self.grid + gx]
    }

    pub fn cls_at(&self, b: usize, gy: usize, gx: usize) -> &[f32] {
        let off = ((b * self.grid + gy) * self.grid + gx) * self.classes;
        &self.cls[off..off + self.classes]
    }
}

/// Segmentation predictions: `[B,S,S,K+1]` class probabilities.
#[derive(Debug, Clone)]
pub struct SegPred {
    pub batch: usize,
    pub side: usize,
    pub classes: usize, // K+1 including background
    pub probs: Vec<f32>,
}

impl SegPred {
    pub fn probs_at(&self, b: usize, sy: usize, sx: usize) -> &[f32] {
        let off = ((b * self.side + sy) * self.side + sx) * self.classes;
        &self.probs[off..off + self.classes]
    }
}

/// Execution statistics snapshot (perf accounting). Obtained from
/// [`Engine::stats`]; the engine itself accumulates these in atomics so
/// concurrent workers can share one `&Engine`.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub train_steps: u64,
    /// Logical inference submissions (`infer_det`/`infer_seg` entries).
    /// Deterministic for a given run.
    pub infer_requests: u64,
    /// Actual inference kernel launches. With coalescing off this equals
    /// `infer_requests`; with it on, launches ≤ requests and the exact
    /// count depends on submission timing (a perf counter, never part of
    /// the deterministic event/accuracy surface).
    pub infer_calls: u64,
    /// Feature-extraction kernel launches (coalesced the same way).
    pub feature_calls: u64,
    pub compile_count: u64,
    pub exec_nanos: u128,
    /// Nanos spent in train-step executions (subset of exec_nanos).
    pub train_nanos: u128,
    /// Nanos spent in inference executions (subset of exec_nanos).
    pub infer_nanos: u128,
}

/// Lock-free accumulator behind [`EngineStats`]: every counter is an
/// atomic so `Engine` methods can take `&self` and the engine can be
/// shared (`Sync`) across the eval worker pool and fleet drivers.
/// Counters use relaxed ordering — they are monotonic tallies, never used
/// for synchronization.
#[derive(Debug, Default)]
pub(crate) struct StatsCell {
    pub(crate) train_steps: AtomicU64,
    pub(crate) infer_requests: AtomicU64,
    pub(crate) infer_calls: AtomicU64,
    pub(crate) feature_calls: AtomicU64,
    pub(crate) compile_count: AtomicU64,
    pub(crate) exec_nanos: AtomicU64,
    pub(crate) train_nanos: AtomicU64,
    pub(crate) infer_nanos: AtomicU64,
}

impl StatsCell {
    pub(crate) fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    pub(crate) fn snapshot(&self) -> EngineStats {
        EngineStats {
            train_steps: self.train_steps.load(Ordering::Relaxed),
            infer_requests: self.infer_requests.load(Ordering::Relaxed),
            infer_calls: self.infer_calls.load(Ordering::Relaxed),
            feature_calls: self.feature_calls.load(Ordering::Relaxed),
            compile_count: self.compile_count.load(Ordering::Relaxed),
            exec_nanos: self.exec_nanos.load(Ordering::Relaxed) as u128,
            train_nanos: self.train_nanos.load(Ordering::Relaxed) as u128,
            infer_nanos: self.infer_nanos.load(Ordering::Relaxed) as u128,
        }
    }
}

/// The native (pure Rust) execution engine. With `--features pjrt` the
/// [`super::pjrt::Engine`] replaces this type under the same name.
///
/// The engine is **shared state**: the manifest is immutable after
/// construction and the stats are atomic, so every method takes `&self`
/// and one engine can serve any number of worker threads or concurrent
/// sessions. Mutable training state lives in the caller's [`ModelState`].
///
/// Each engine additionally owns a **persistent worker pool**
/// ([`Engine::pool`]), spawned once at construction and parked between
/// uses: the coordinator's eval fan-outs, the fleet driver, and the
/// batch-sharded train/infer kernels all dispatch onto it, so total
/// parallelism stays bounded by the pool width no matter how the layers
/// nest. The workers die with the engine.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    pub manifest: Manifest,
    stats: StatsCell,
    pool: Pool,
    /// Micro-batch coalescing submission layer for the infer/feature
    /// paths (see [`super::microbatch`]). Disabled by default.
    queue: InferQueue,
}

// Compile-time statement of the sharing contract the eval fan-outs and
// fleet driver rely on.
#[cfg(not(feature = "pjrt"))]
const _: () = {
    const fn assert_sync<T: Sync + Send>() {}
    assert_sync::<Engine>();
};

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Create an engine over an artifacts directory. When no generated
    /// `manifest.json` exists the engine falls back to the synthetic
    /// manifest (model.py's constants) — the native backend needs no
    /// files. A manifest that exists but fails to load is still a hard
    /// error: silently degrading to the synthetic constants would produce
    /// results that don't correspond to the generated artifacts.
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = if artifacts_dir.join("manifest.json").exists() {
            Manifest::load(artifacts_dir)?
        } else {
            crate::util::logger::log(
                crate::util::logger::Level::Debug,
                module_path!(),
                &format!(
                    "no artifacts at {artifacts_dir:?}; using the synthetic manifest \
                     (native backend)"
                ),
            );
            Manifest::synthetic(artifacts_dir)
        };
        Ok(Engine {
            manifest,
            stats: StatsCell::default(),
            // Caller + workers == default_threads() total concurrency.
            pool: Pool::new(pool::default_threads().saturating_sub(1)),
            queue: InferQueue::new(CoalesceOpts::default()),
        })
    }

    /// Default artifacts location (crate-root `artifacts/`).
    pub fn open_default() -> Result<Engine> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Engine::new(&dir)
    }

    /// The engine's persistent worker set: eval fan-outs, fleet drivers,
    /// and the batch-sharded kernels all run on this pool. Parked when
    /// idle; joined when the engine drops.
    pub fn pool(&self) -> &Pool {
        &self.pool
    }

    /// Kernel execution context: shard on the engine pool at full width.
    fn exec(&self) -> native::Exec<'_> {
        native::Exec {
            pool: &self.pool,
            threads: self.pool.parallelism(),
        }
    }

    /// Snapshot of the execution statistics.
    pub fn stats(&self) -> EngineStats {
        self.stats.snapshot()
    }

    /// Reconfigure the micro-batch coalescing layer. Engine-wide and
    /// lock-free (atomics): sessions sharing an engine see the last
    /// writer's knobs, which affects only batching granularity — results
    /// are bit-identical either way (the [`super::microbatch`] contract).
    pub fn set_coalesce(&self, opts: CoalesceOpts) {
        self.queue.set_opts(opts);
    }

    /// Current micro-batch coalescing knobs.
    pub fn coalesce(&self) -> CoalesceOpts {
        self.queue.opts()
    }

    /// No-op for the native backend (nothing to pre-compile).
    pub fn warmup(&self) -> Result<()> {
        Ok(())
    }

    /// Fresh model state: the AOT init checkpoint when present, otherwise
    /// the deterministic native He init.
    pub fn init_model(&self, task: Task) -> Result<ModelState> {
        let meta = self.manifest.task(task);
        let theta = if meta.init_file.exists() {
            self.manifest.init_params(task)?
        } else {
            native::he_init(task, self.manifest.init_seed)
        };
        Ok(ModelState::from_theta(task, theta))
    }

    /// One SGD+momentum step; mutates `state` and returns the batch loss.
    pub fn train_step(
        &self,
        state: &mut ModelState,
        batch: &TrainBatch,
        lr: f32,
    ) -> Result<f32> {
        let m = &self.manifest;
        let (b, g, k) = (m.train_batch, m.grid, m.classes);
        m.artifact(state.task, "train", batch.res)?; // resolution gate
        let expect_px = b * batch.res * batch.res * 3;
        if batch.pixels.len() != expect_px {
            bail!(
                "train batch pixels: got {}, expected {} (B={b}, r={})",
                batch.pixels.len(),
                expect_px,
                batch.res
            );
        }
        match (&batch.labels, state.task) {
            (Labels::Det { obj, cls }, Task::Det) => {
                if obj.len() != b * g * g || cls.len() != b * g * g * k {
                    bail!("det labels wrong size");
                }
            }
            (Labels::Seg { mask }, Task::Seg) => {
                let s = batch.res / 4;
                if mask.len() != b * s * s * (k + 1) {
                    bail!("seg labels wrong size");
                }
            }
            _ => bail!("label kind does not match task {:?}", state.task),
        }
        // ecco-lint: allow(D003) perf counter: feeds the exec/train_nanos
        // stats atomics only, never events or accuracies.
        let t0 = std::time::Instant::now();
        let loss = native::train_step(
            state.task,
            &mut state.theta,
            &mut state.mom,
            batch,
            b,
            lr,
            self.exec(),
        );
        let dt = t0.elapsed().as_nanos() as u64;
        StatsCell::add(&self.stats.exec_nanos, dt);
        StatsCell::add(&self.stats.train_nanos, dt);
        state.steps += 1;
        StatsCell::add(&self.stats.train_steps, 1);
        Ok(loss)
    }

    /// Batched detection inference. `pixels` is `[B,r,r,3]`, B = infer_batch.
    ///
    /// A **submission**: with coalescing enabled, concurrent calls that
    /// share `(theta, res)` merge into one mega-batched launch and this
    /// call returns exactly its own samples' predictions — bit-identical
    /// to a solo launch.
    pub fn infer_det(&self, theta: &[f32], res: usize, pixels: &[f32]) -> Result<DetPred> {
        let m = &self.manifest;
        let (b, g, k) = (m.infer_batch, m.grid, m.classes);
        m.artifact(Task::Det, "infer", res)?;
        if pixels.len() != b * res * res * 3 {
            bail!("infer batch pixels wrong size");
        }
        StatsCell::add(&self.stats.infer_requests, 1);
        let run = |px: &[f32], n: usize| {
            // ecco-lint: allow(D003) perf counter: infer_nanos stats only.
            let t0 = std::time::Instant::now();
            let (obj, cls) = native::infer_det(theta, px, n, res, self.exec());
            let dt = t0.elapsed().as_nanos() as u64;
            StatsCell::add(&self.stats.exec_nanos, dt);
            StatsCell::add(&self.stats.infer_nanos, dt);
            StatsCell::add(&self.stats.infer_calls, 1);
            InferOut::Det { obj, cls }
        };
        // Hash theta only when coalescing can use it; the disabled path
        // stays a plain launch.
        let out = if self.queue.enabled() {
            let req = InferRequest {
                kind: ReqKind::Det,
                theta_id: microbatch::theta_id(theta),
                res,
                pixels,
                samples: b,
            };
            self.queue.submit(req, theta, run)
        } else {
            run(pixels, b)
        };
        match out {
            InferOut::Det { obj, cls } => Ok(DetPred {
                batch: b,
                grid: g,
                classes: k,
                obj,
                cls,
            }),
            _ => bail!("det submission yielded a non-det output"),
        }
    }

    /// Batched segmentation inference (a submission, like
    /// [`Engine::infer_det`]).
    pub fn infer_seg(&self, theta: &[f32], res: usize, pixels: &[f32]) -> Result<SegPred> {
        let m = &self.manifest;
        let (b, k) = (m.infer_batch, m.classes);
        m.artifact(Task::Seg, "infer", res)?;
        if pixels.len() != b * res * res * 3 {
            bail!("infer batch pixels wrong size");
        }
        StatsCell::add(&self.stats.infer_requests, 1);
        let run = |px: &[f32], n: usize| {
            // ecco-lint: allow(D003) perf counter: infer_nanos stats only.
            let t0 = std::time::Instant::now();
            let probs = native::infer_seg(theta, px, n, res, self.exec());
            let dt = t0.elapsed().as_nanos() as u64;
            StatsCell::add(&self.stats.exec_nanos, dt);
            StatsCell::add(&self.stats.infer_nanos, dt);
            StatsCell::add(&self.stats.infer_calls, 1);
            InferOut::Seg { probs }
        };
        let out = if self.queue.enabled() {
            let req = InferRequest {
                kind: ReqKind::Seg,
                theta_id: microbatch::theta_id(theta),
                res,
                pixels,
                samples: b,
            };
            self.queue.submit(req, theta, run)
        } else {
            run(pixels, b)
        };
        match out {
            InferOut::Seg { probs } => Ok(SegPred {
                batch: b,
                side: res / 4,
                classes: k + 1,
                probs,
            }),
            _ => bail!("seg submission yielded a non-seg output"),
        }
    }

    /// Drift/grouping descriptors for a `[B,32,32,3]` batch -> `[B,96]`.
    ///
    /// Also a submission: concurrent probe batches coalesce (the key is
    /// theta-free — all feature requests at one resolution merge), and a
    /// mega-batch past `native::FEATURE_SHARD_MIN` samples shards across
    /// the pool; smaller launches stay serial (see the cutoff note in
    /// `native.rs`).
    pub fn features(&self, pixels: &[f32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        let (b, r) = (m.infer_batch, m.feature_res);
        if pixels.len() != b * r * r * 3 {
            bail!("feature batch pixels wrong size");
        }
        let run = |px: &[f32], n: usize| {
            // ecco-lint: allow(D003) perf counter: infer_nanos stats only.
            let t0 = std::time::Instant::now();
            let emb = native::features(px, n, r, self.exec());
            let dt = t0.elapsed().as_nanos() as u64;
            StatsCell::add(&self.stats.exec_nanos, dt);
            StatsCell::add(&self.stats.infer_nanos, dt);
            StatsCell::add(&self.stats.feature_calls, 1);
            InferOut::Feat { emb }
        };
        let out = if self.queue.enabled() {
            let req = InferRequest {
                kind: ReqKind::Feat,
                theta_id: microbatch::theta_id(&[]),
                res: r,
                pixels,
                samples: b,
            };
            self.queue.submit(req, &[], run)
        } else {
            run(pixels, b)
        };
        match out {
            InferOut::Feat { emb } => Ok(emb),
            _ => bail!("feature submission yielded a non-feature output"),
        }
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;

    #[test]
    fn engine_opens_without_artifacts() {
        let e = Engine::new(Path::new("/definitely/not/generated")).unwrap();
        assert_eq!(e.manifest.classes, 4);
        let mut state = e.init_model(Task::Det).unwrap();
        assert_eq!(state.param_count(), e.manifest.task(Task::Det).param_count);
        let m = e.manifest.clone();
        let batch = TrainBatch {
            res: 32,
            pixels: vec![0.3; m.train_batch * 32 * 32 * 3],
            labels: Labels::Det {
                obj: vec![0.0; m.train_batch * m.grid * m.grid],
                cls: vec![0.0; m.train_batch * m.grid * m.grid * m.classes],
            },
        };
        let loss = e.train_step(&mut state, &batch, 0.01).unwrap();
        assert!(loss.is_finite());
        assert_eq!(e.stats().train_steps, 1);
    }

    #[test]
    fn infer_requests_equal_calls_without_coalescing() {
        let e = Engine::new(Path::new("/definitely/not/generated")).unwrap();
        let state = e.init_model(Task::Det).unwrap();
        let m = e.manifest.clone();
        let px = vec![0.1; m.infer_batch * 32 * 32 * 3];
        for _ in 0..3 {
            e.infer_det(&state.theta, 32, &px).unwrap();
        }
        let st = e.stats();
        assert_eq!(st.infer_requests, 3);
        assert_eq!(st.infer_calls, 3);
    }

    #[test]
    fn coalesce_knobs_round_trip_and_preserve_results() {
        let e = Engine::new(Path::new("/definitely/not/generated")).unwrap();
        let state = e.init_model(Task::Det).unwrap();
        let m = e.manifest.clone();
        let px: Vec<f32> = (0..m.infer_batch * 32 * 32 * 3)
            .map(|i| ((i % 17) as f32) / 17.0)
            .collect();
        let base = e.infer_det(&state.theta, 32, &px).unwrap();
        let opts = CoalesceOpts::on().window_us(0).max_batch(64);
        e.set_coalesce(opts);
        assert_eq!(e.coalesce(), opts);
        let via_queue = e.infer_det(&state.theta, 32, &px).unwrap();
        assert_eq!(base.obj, via_queue.obj);
        assert_eq!(base.cls, via_queue.cls);
        let st = e.stats();
        assert_eq!(st.infer_requests, 2);
        e.set_coalesce(CoalesceOpts::default());
        assert!(!e.coalesce().enabled);
    }

    #[test]
    fn engine_rejects_bad_shapes() {
        let e = Engine::new(Path::new("/definitely/not/generated")).unwrap();
        let mut state = e.init_model(Task::Det).unwrap();
        let bad = TrainBatch {
            res: 32,
            pixels: vec![0.0; 7],
            labels: Labels::Det {
                obj: vec![],
                cls: vec![],
            },
        };
        assert!(e.train_step(&mut state, &bad, 0.01).is_err());
        // Unsupported resolution is rejected via the manifest gate.
        let m = e.manifest.clone();
        let bad_res = TrainBatch {
            res: 99,
            pixels: vec![0.0; m.train_batch * 99 * 99 * 3],
            labels: Labels::Det {
                obj: vec![0.0; m.train_batch * 16],
                cls: vec![0.0; m.train_batch * 64],
            },
        };
        assert!(e.train_step(&mut state, &bad_res, 0.01).is_err());
    }
}

//! PJRT runtime: loads `artifacts/*.hlo.txt` (AOT-lowered by
//! `python/compile/aot.py`) and executes them on the CPU PJRT client.
//! Python never runs here — the artifacts are the entire ML stack.

pub mod batch;
pub mod engine;
pub mod manifest;

pub use engine::{DetPred, Engine, EngineStats, Labels, ModelState, SegPred, TrainBatch};
pub use manifest::{artifact_key, Manifest, Task};

//! Model runtime: the student's train/infer/feature programs behind one
//! [`Engine`] API.
//!
//! Default backend is [`native`] — a pure-Rust reference implementation of
//! the exact math `python/compile/aot.py` lowers to HLO, so everything
//! runs with no generated artifacts. With `--features pjrt` (and the `xla`
//! bindings crate available) the [`pjrt`] backend loads
//! `artifacts/*.hlo.txt` and executes them on the CPU PJRT client instead.

pub mod batch;
pub mod engine;
pub mod manifest;
pub mod microbatch;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(not(feature = "pjrt"))]
pub use engine::Engine;
#[cfg(feature = "pjrt")]
pub use pjrt::Engine;

pub use engine::{DetPred, EngineStats, Labels, ModelState, SegPred, TrainBatch};
pub use manifest::{artifact_key, Manifest, Task};
pub use microbatch::CoalesceOpts;

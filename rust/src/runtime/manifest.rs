//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Vision task variants the AOT pipeline emits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    Det,
    Seg,
}

impl Task {
    pub fn name(self) -> &'static str {
        match self {
            Task::Det => "det",
            Task::Seg => "seg",
        }
    }

    pub fn parse(s: &str) -> Result<Task> {
        match s {
            "det" => Ok(Task::Det),
            "seg" => Ok(Task::Seg),
            _ => bail!("unknown task {s:?} (expected det|seg)"),
        }
    }
}

/// Tensor spec (dtype is always f32 in this pipeline).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One HLO artifact: file plus input/output signatures.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Per-task metadata.
#[derive(Debug, Clone)]
pub struct TaskMeta {
    pub param_count: usize,
    pub head_out: usize,
    pub init_file: PathBuf,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub classes: usize,
    pub grid: usize,
    pub resolutions: Vec<usize>,
    pub train_batch: usize,
    pub infer_batch: usize,
    pub feature_res: usize,
    pub embed_dim: usize,
    pub init_seed: u64,
    pub tasks: BTreeMap<&'static str, TaskMeta>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;

        let mut tasks = BTreeMap::new();
        for task in [Task::Det, Task::Seg] {
            let tj = j.get("tasks")?.get(task.name())?;
            tasks.insert(
                task.name(),
                TaskMeta {
                    param_count: tj.get("param_count")?.as_usize()?,
                    head_out: tj.get("head_out")?.as_usize()?,
                    init_file: dir.join(tj.get("init_file")?.as_str()?),
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for (name, aj) in j.get("artifacts")?.as_obj()? {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                aj.get(key)?
                    .as_arr()?
                    .iter()
                    .map(|sj| {
                        Ok(TensorSpec {
                            shape: sj.get("shape")?.usize_array()?,
                        })
                    })
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(aj.get("file")?.as_str()?),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                },
            );
        }

        let m = Manifest {
            dir: dir.to_path_buf(),
            classes: j.get("classes")?.as_usize()?,
            grid: j.get("grid")?.as_usize()?,
            resolutions: j.get("resolutions")?.usize_array()?,
            train_batch: j.get("train_batch")?.as_usize()?,
            infer_batch: j.get("infer_batch")?.as_usize()?,
            feature_res: j.get("feature_res")?.as_usize()?,
            embed_dim: j.get("embed_dim")?.as_usize()?,
            init_seed: j.get("init_seed")?.as_f64()? as u64,
            tasks,
            artifacts,
        };
        m.validate()?;
        Ok(m)
    }

    /// A manifest built from `python/compile/model.py`'s constants, for the
    /// native backend when no generated `artifacts/` directory exists. The
    /// referenced files are never read (the native backend implements the
    /// programs directly); `dir` is still recorded so on-disk caches (e.g.
    /// pretraining) land in the usual place.
    pub fn synthetic(dir: &Path) -> Manifest {
        use super::native;
        let mut tasks = BTreeMap::new();
        for task in [Task::Det, Task::Seg] {
            tasks.insert(
                task.name(),
                TaskMeta {
                    param_count: native::param_count(task),
                    head_out: native::HEAD_OUT,
                    init_file: dir.join(format!("init_{}.bin", task.name())),
                },
            );
        }
        let train_batch = native::TRAIN_BATCH;
        let infer_batch = native::INFER_BATCH;
        let grid = native::GRID;
        let classes = native::K;
        let mut artifacts = BTreeMap::new();
        let mut insert = |name: String, inputs: Vec<TensorSpec>, outputs: Vec<TensorSpec>| {
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: dir.join(format!("{name}.hlo.txt")),
                    name,
                    inputs,
                    outputs,
                },
            );
        };
        let shape = |dims: &[usize]| TensorSpec {
            shape: dims.to_vec(),
        };
        for task in [Task::Det, Task::Seg] {
            let p = native::param_count(task);
            for &r in &native::RESOLUTIONS {
                let mut train_in = vec![
                    shape(&[p]),
                    shape(&[p]),
                    shape(&[train_batch, r, r, 3]),
                ];
                match task {
                    Task::Det => {
                        train_in.push(shape(&[train_batch, grid, grid]));
                        train_in.push(shape(&[train_batch, grid, grid, classes]));
                    }
                    Task::Seg => {
                        train_in.push(shape(&[train_batch, r / 4, r / 4, classes + 1]));
                    }
                }
                train_in.push(shape(&[]));
                insert(
                    artifact_key(task, "train", r),
                    train_in,
                    vec![shape(&[p]), shape(&[p]), shape(&[])],
                );
                let infer_out = match task {
                    Task::Det => vec![
                        shape(&[infer_batch, grid, grid]),
                        shape(&[infer_batch, grid, grid, classes]),
                    ],
                    Task::Seg => vec![shape(&[infer_batch, r / 4, r / 4, classes + 1])],
                };
                insert(
                    artifact_key(task, "infer", r),
                    vec![shape(&[p]), shape(&[infer_batch, r, r, 3])],
                    infer_out,
                );
            }
        }
        let fr = native::FEATURE_RES;
        insert(
            "features_r32".to_string(),
            vec![shape(&[infer_batch, fr, fr, 3])],
            vec![shape(&[infer_batch, native::EMBED_DIM])],
        );
        Manifest {
            dir: dir.to_path_buf(),
            classes,
            grid,
            resolutions: native::RESOLUTIONS.to_vec(),
            train_batch,
            infer_batch,
            feature_res: fr,
            embed_dim: native::EMBED_DIM,
            init_seed: 1234,
            tasks,
            artifacts,
        }
    }

    fn validate(&self) -> Result<()> {
        if self.classes == 0 || self.grid == 0 {
            bail!("degenerate manifest: classes/grid zero");
        }
        for task in [Task::Det, Task::Seg] {
            for &r in &self.resolutions {
                for kind in ["train", "infer"] {
                    let key = artifact_key(task, kind, r);
                    let a = self
                        .artifacts
                        .get(&key)
                        .with_context(|| format!("manifest missing artifact {key}"))?;
                    if !a.file.exists() {
                        bail!("artifact file missing: {:?}", a.file);
                    }
                }
            }
            let meta = self
                .tasks
                .get(task.name())
                .with_context(|| format!("manifest missing task entry {:?}", task.name()))?;
            if !meta.init_file.exists() {
                bail!("init params missing: {:?}", meta.init_file);
            }
        }
        if !self.artifacts.contains_key("features_r32") {
            bail!("manifest missing features_r32");
        }
        Ok(())
    }

    pub fn task(&self, task: Task) -> &TaskMeta {
        &self.tasks[task.name()]
    }

    pub fn artifact(&self, task: Task, kind: &str, res: usize) -> Result<&ArtifactSpec> {
        let key = artifact_key(task, kind, res);
        self.artifacts
            .get(&key)
            .with_context(|| format!("no artifact {key} (resolutions: {:?})", self.resolutions))
    }

    /// Load a task's initial parameter vector (raw little-endian f32).
    pub fn init_params(&self, task: Task) -> Result<Vec<f32>> {
        let meta = self.task(task);
        let bytes = std::fs::read(&meta.init_file)
            .with_context(|| format!("reading {:?}", meta.init_file))?;
        if bytes.len() != meta.param_count * 4 {
            bail!(
                "init file {:?} has {} bytes, expected {}",
                meta.init_file,
                bytes.len(),
                meta.param_count * 4
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Canonical artifact key, e.g. `det_train_r32`.
pub fn artifact_key(task: Task, kind: &str, res: usize) -> String {
    format!("{}_{}_r{}", task.name(), kind, res)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Generated artifacts are optional (python + jax, `make artifacts`);
    /// tests that need them skip with a message instead of failing.
    fn generated() -> Option<Manifest> {
        match Manifest::load(&artifacts_dir()) {
            Ok(m) => Some(m),
            Err(_) => {
                eprintln!("skipping: artifacts/ not generated (run `make artifacts`)");
                None
            }
        }
    }

    #[test]
    fn loads_and_validates_manifest() {
        let Some(m) = generated() else { return };
        assert_eq!(m.classes, 4);
        assert_eq!(m.grid, 4);
        assert_eq!(m.resolutions, vec![16, 32, 48]);
        assert_eq!(m.train_batch, 8);
        assert_eq!(m.infer_batch, 16);
        assert_eq!(m.embed_dim, 96);
        assert!(m.task(Task::Det).param_count > 5000);
        assert_eq!(m.task(Task::Det).param_count, m.task(Task::Seg).param_count);
    }

    #[test]
    fn artifact_signatures_consistent() {
        // The synthetic manifest must present the same signatures the AOT
        // pipeline records, so this checks generated artifacts when present
        // and the synthetic fallback otherwise.
        let m = generated().unwrap_or_else(|| Manifest::synthetic(&artifacts_dir()));
        let a = m.artifact(Task::Det, "train", 32).unwrap();
        // (theta, mom, x, y_obj, y_cls, lr)
        assert_eq!(a.inputs.len(), 6);
        assert_eq!(a.inputs[0].shape, vec![m.task(Task::Det).param_count]);
        assert_eq!(a.inputs[2].shape, vec![m.train_batch, 32, 32, 3]);
        assert_eq!(a.inputs[5].shape, Vec::<usize>::new());
        // (theta', mom', loss)
        assert_eq!(a.outputs.len(), 3);
        let i = m.artifact(Task::Det, "infer", 48).unwrap();
        assert_eq!(i.inputs.len(), 2);
        assert_eq!(i.outputs.len(), 2);
        let s = m.artifact(Task::Seg, "infer", 16).unwrap();
        assert_eq!(s.outputs[0].shape, vec![m.infer_batch, 4, 4, m.classes + 1]);
    }

    #[test]
    fn init_params_load() {
        let Some(m) = generated() else { return };
        let theta = m.init_params(Task::Det).unwrap();
        assert_eq!(theta.len(), m.task(Task::Det).param_count);
        // He-init weights: non-trivial spread, finite.
        assert!(theta.iter().all(|v| v.is_finite()));
        let nonzero = theta.iter().filter(|v| **v != 0.0).count();
        assert!(nonzero > theta.len() / 2);
    }

    #[test]
    fn unknown_artifact_errors() {
        let m = generated().unwrap_or_else(|| Manifest::synthetic(&artifacts_dir()));
        assert!(m.artifact(Task::Det, "train", 99).is_err());
    }

    #[test]
    fn synthetic_manifest_matches_model_constants() {
        let m = Manifest::synthetic(&artifacts_dir());
        assert_eq!(m.classes, 4);
        assert_eq!(m.grid, 4);
        assert_eq!(m.resolutions, vec![16, 32, 48]);
        assert_eq!(m.train_batch, 8);
        assert_eq!(m.infer_batch, 16);
        assert_eq!(m.embed_dim, 96);
        assert_eq!(m.task(Task::Det).param_count, m.task(Task::Seg).param_count);
        assert!(m.task(Task::Det).param_count > 5000);
        assert!(m.artifacts.contains_key("features_r32"));
    }
}

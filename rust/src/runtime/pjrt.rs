//! PJRT execution engine (feature `pjrt`): loads the AOT HLO artifacts and
//! runs them on the CPU PJRT client via the `xla` bindings crate.
//!
//! This is the only place the process touches XLA. Artifacts are compiled
//! once per (task, kind, resolution) and cached. Enabling this feature
//! requires an environment that provides the `xla` crate (see Cargo.toml);
//! the default build uses the native reference backend instead, which
//! implements identical math in pure Rust.
//!
//! Like the native backend, every method takes `&self` so the engine can
//! be shared across threads; unlike it, execution is serialized behind the
//! compile-cache lock (this backend exists for golden-numerics parity, not
//! throughput — the native backend is the concurrent hot path).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::engine::{DetPred, EngineStats, Labels, ModelState, SegPred, StatsCell, TrainBatch};
use super::manifest::{Manifest, Task};
use crate::util::pool::{self, Pool};

/// The PJRT engine.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    stats: StatsCell,
    /// Persistent worker set for the coordinator's eval fan-outs and the
    /// fleet driver (this backend's own execution stays serialized behind
    /// the compile-cache lock, so the kernels don't shard here).
    pool: Pool,
}

// Compile-time guard: the coordinator's eval fan-outs and the fleet driver
// share `&Engine` across pool workers, so this backend must be `Sync`
// like the native one. If the `xla` handle types turn out not to be
// thread-safe, this single assertion fails with a clear message instead of
// E0277 at every pool call site — wrap `client`/`executables` in the
// appropriate guards then (see ROADMAP's parallelism follow-ups).
const _: () = {
    const fn assert_sync<T: Sync>() {}
    assert_sync::<Engine>();
};

impl Engine {
    /// Create an engine over an artifacts directory (compiles lazily).
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            executables: Mutex::new(HashMap::new()),
            stats: StatsCell::default(),
            pool: Pool::new(pool::default_threads().saturating_sub(1)),
        })
    }

    /// The engine's persistent worker set (see the native engine's docs).
    pub fn pool(&self) -> &Pool {
        &self.pool
    }

    /// Micro-batch coalescing is a native-backend optimization; the PJRT
    /// path executes per-call, so the knobs are accepted and ignored
    /// (keeps `RuntimeOpts::coalesce` specs portable across backends).
    pub fn set_coalesce(&self, _opts: super::microbatch::CoalesceOpts) {}

    /// Always the disabled default on this backend.
    pub fn coalesce(&self) -> super::microbatch::CoalesceOpts {
        super::microbatch::CoalesceOpts::default()
    }

    /// Default artifacts location (crate-root `artifacts/`).
    pub fn open_default() -> Result<Engine> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Engine::new(&dir)
    }

    /// Snapshot of the execution statistics.
    pub fn stats(&self) -> EngineStats {
        self.stats.snapshot()
    }

    /// Pre-compile every artifact (otherwise compilation is lazy).
    pub fn warmup(&self) -> Result<()> {
        let mut cache = crate::util::sync::plock(&self.executables);
        let keys: Vec<String> = self.manifest.artifacts.keys().cloned().collect();
        for key in keys {
            self.ensure_compiled(&mut cache, &key)?;
        }
        Ok(())
    }

    /// Fresh model state from the AOT init checkpoint.
    pub fn init_model(&self, task: Task) -> Result<ModelState> {
        let theta = self.manifest.init_params(task)?;
        Ok(ModelState::from_theta(task, theta))
    }

    fn ensure_compiled<'a>(
        &self,
        cache: &'a mut HashMap<String, xla::PjRtLoadedExecutable>,
        key: &str,
    ) -> Result<&'a xla::PjRtLoadedExecutable> {
        if !cache.contains_key(key) {
            let spec = self
                .manifest
                .artifacts
                .get(key)
                .with_context(|| format!("unknown artifact {key}"))?;
            let proto = xla::HloModuleProto::from_text_file(
                spec.file
                    .to_str()
                    .with_context(|| format!("non-utf8 path {:?}", spec.file))?,
            )
            .with_context(|| format!("parsing HLO text {:?}", spec.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {key}"))?;
            StatsCell::add(&self.stats.compile_count, 1);
            crate::util::logger::log(
                crate::util::logger::Level::Debug,
                module_path!(),
                &format!("compiled artifact {key}"),
            );
            cache.insert(key.to_string(), exe);
        }
        Ok(&cache[key])
    }

    fn run(&self, key: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        // ecco-lint: allow(D003) perf counter: exec/train/infer_nanos
        // stats atomics only, never events or accuracies.
        let t0 = std::time::Instant::now();
        let mut cache = crate::util::sync::plock(&self.executables);
        let exe = self.ensure_compiled(&mut cache, key)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {key}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {key} result"))?;
        let outs = tuple.to_tuple().context("decomposing result tuple")?;
        let dt = t0.elapsed().as_nanos() as u64;
        StatsCell::add(&self.stats.exec_nanos, dt);
        if key.contains("train") {
            StatsCell::add(&self.stats.train_nanos, dt);
        } else {
            StatsCell::add(&self.stats.infer_nanos, dt);
        }
        Ok(outs)
    }

    /// One SGD+momentum step; mutates `state` and returns the batch loss.
    pub fn train_step(
        &self,
        state: &mut ModelState,
        batch: &TrainBatch,
        lr: f32,
    ) -> Result<f32> {
        let m = &self.manifest;
        let (b, g, k) = (m.train_batch, m.grid, m.classes);
        let spec = m.artifact(state.task, "train", batch.res)?;
        let expect_px = b * batch.res * batch.res * 3;
        if batch.pixels.len() != expect_px {
            bail!(
                "train batch pixels: got {}, expected {} (B={b}, r={})",
                batch.pixels.len(),
                expect_px,
                batch.res
            );
        }
        let key = spec.name.clone();

        let theta = vec1(&state.theta, &[state.theta.len()])?;
        let mom = vec1(&state.mom, &[state.mom.len()])?;
        let x = vec1(&batch.pixels, &[b, batch.res, batch.res, 3])?;
        let lr_lit = xla::Literal::scalar(lr);
        let mut inputs = vec![theta, mom, x];
        match (&batch.labels, state.task) {
            (Labels::Det { obj, cls }, Task::Det) => {
                if obj.len() != b * g * g || cls.len() != b * g * g * k {
                    bail!("det labels wrong size");
                }
                inputs.push(vec1(obj, &[b, g, g])?);
                inputs.push(vec1(cls, &[b, g, g, k])?);
            }
            (Labels::Seg { mask }, Task::Seg) => {
                let s = batch.res / 4;
                if mask.len() != b * s * s * (k + 1) {
                    bail!("seg labels wrong size");
                }
                inputs.push(vec1(mask, &[b, s, s, k + 1])?);
            }
            _ => bail!("label kind does not match task {:?}", state.task),
        }
        inputs.push(lr_lit);

        let outs = self.run(&key, &inputs)?;
        if outs.len() != 3 {
            bail!("train artifact returned {} outputs, expected 3", outs.len());
        }
        state.theta = outs[0].to_vec::<f32>()?;
        state.mom = outs[1].to_vec::<f32>()?;
        state.steps += 1;
        StatsCell::add(&self.stats.train_steps, 1);
        let loss = outs[2].to_vec::<f32>()?[0];
        Ok(loss)
    }

    /// Batched detection inference. `pixels` is `[B,r,r,3]`, B = infer_batch.
    pub fn infer_det(&self, theta: &[f32], res: usize, pixels: &[f32]) -> Result<DetPred> {
        let m = &self.manifest;
        let (b, g, k) = (m.infer_batch, m.grid, m.classes);
        let spec = m.artifact(Task::Det, "infer", res)?;
        if pixels.len() != b * res * res * 3 {
            bail!("infer batch pixels wrong size");
        }
        let key = spec.name.clone();
        let inputs = [vec1(theta, &[theta.len()])?, vec1(pixels, &[b, res, res, 3])?];
        let outs = self.run(&key, &inputs)?;
        StatsCell::add(&self.stats.infer_requests, 1);
        StatsCell::add(&self.stats.infer_calls, 1);
        Ok(DetPred {
            batch: b,
            grid: g,
            classes: k,
            obj: outs[0].to_vec::<f32>()?,
            cls: outs[1].to_vec::<f32>()?,
        })
    }

    /// Batched segmentation inference.
    pub fn infer_seg(&self, theta: &[f32], res: usize, pixels: &[f32]) -> Result<SegPred> {
        let m = &self.manifest;
        let (b, k) = (m.infer_batch, m.classes);
        let spec = m.artifact(Task::Seg, "infer", res)?;
        if pixels.len() != b * res * res * 3 {
            bail!("infer batch pixels wrong size");
        }
        let key = spec.name.clone();
        let inputs = [vec1(theta, &[theta.len()])?, vec1(pixels, &[b, res, res, 3])?];
        let outs = self.run(&key, &inputs)?;
        StatsCell::add(&self.stats.infer_requests, 1);
        StatsCell::add(&self.stats.infer_calls, 1);
        Ok(SegPred {
            batch: b,
            side: res / 4,
            classes: k + 1,
            probs: outs[0].to_vec::<f32>()?,
        })
    }

    /// Drift/grouping descriptors for a `[B,32,32,3]` batch -> `[B,96]`.
    pub fn features(&self, pixels: &[f32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        let (b, r) = (m.infer_batch, m.feature_res);
        if pixels.len() != b * r * r * 3 {
            bail!("feature batch pixels wrong size");
        }
        let inputs = [vec1(pixels, &[b, r, r, 3])?];
        let outs = self.run("features_r32", &inputs)?;
        StatsCell::add(&self.stats.feature_calls, 1);
        Ok(outs[0].to_vec::<f32>()?)
    }
}

fn vec1(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

//! Cross-camera micro-batched inference: the coalescing submission layer.
//!
//! Eval fan-outs ([`crate::server`]) and concurrent serve sessions
//! ([`crate::serve`]) issue one `Engine::infer_*` call per (model,
//! frame-batch), paying per-call kernel overhead for every camera even
//! when many cameras evaluate the *same* published model at the same
//! resolution — exactly the shape of the end-of-window pass and the
//! regroup matrix. [`InferQueue`] closes that gap: concurrent submitters
//! whose requests share a coalesce key `(kind, resolution, theta)` are
//! merged into one mega-batch, a single `native::infer_*` launch runs it,
//! and each submitter gets back exactly its own per-sample slice.
//!
//! # Determinism rule
//!
//! The native inference kernels are **per-sample pure**: each sample is
//! forwarded independently (`map_n` over the batch dimension with an
//! index-ordered concatenation) and there is no batch-global statistic in
//! the inference path. Concatenating K requests into one launch therefore
//! produces, sample by sample, the same bits as K separate launches — so
//! results are independent of how requests happen to group, and event
//! logs stay byte-stable at any pool width with coalescing on or off.
//! The only observable difference is the `infer_calls` perf counter
//! (kernel launches), which is timing-dependent by nature; event logs and
//! accuracies never include it.
//!
//! # Protocol
//!
//! The first submitter for a key becomes the **leader**: it opens a
//! [group](GroupCell), copies its pixels in, and waits a bounded coalesce
//! window for co-submitters (skipped entirely when it is the only
//! in-flight submitter, so a serial caller pays only a hash and two mutex
//! hops). **Joiners** append their pixels, record their sample offset,
//! and park on the group's condvar. When the window expires or the
//! mega-batch fills, the leader closes the group (no further joins),
//! unlinks it from the key map, runs the kernel outside all locks, stores
//! the whole-batch output, and wakes the joiners; everyone slices out
//! their own samples. Lock order is always key-map → group, and followers
//! hold no locks while parked, so the leader's nested batch-sharded
//! kernel can freely use the worker pool.
//!
//! Keys hash theta *content* ([`theta_id`], FNV-1a over the f32 bit
//! patterns), not pointer identity: after a publish, every camera holds
//! its own clone of the group model, and those value-equal clones are
//! precisely the requests worth coalescing. A joiner verifies its theta
//! bitwise against the group's before merging, so a hash collision
//! degrades to a per-call launch instead of a wrong answer.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::sync::{plock, pwait, pwait_timeout};

/// Default leader wait for co-submitters, in microseconds. Small against
/// a multi-millisecond infer launch, large against the scheduling jitter
/// between pool workers entering an eval fan-out together.
pub const DEFAULT_WINDOW_US: u64 = 200;

/// Default mega-batch cap in samples (16 requests of the default
/// 16-sample infer batch).
pub const DEFAULT_MAX_BATCH: usize = 256;

/// Micro-batch coalescing knobs, set per-run via
/// `RuntimeOpts::coalesce` or directly with `Engine::set_coalesce`.
///
/// Defaults to **off** so the per-call path stays byte-for-byte the
/// shipping behavior; the identity contract (see module docs) makes
/// turning it on safe for any workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CoalesceOpts {
    /// Master switch; off = every request is its own kernel launch.
    pub enabled: bool,
    /// How long a leader waits for co-submitters (microseconds).
    pub window_us: u64,
    /// Mega-batch cap in samples; a request that would overflow it
    /// starts a fresh group.
    pub max_batch: usize,
}

impl Default for CoalesceOpts {
    fn default() -> Self {
        CoalesceOpts { enabled: false, window_us: DEFAULT_WINDOW_US, max_batch: DEFAULT_MAX_BATCH }
    }
}

impl CoalesceOpts {
    /// Coalescing on with default window and cap.
    pub fn on() -> Self {
        CoalesceOpts { enabled: true, ..CoalesceOpts::default() }
    }

    /// Set the coalesce window (microseconds).
    pub fn window_us(mut self, us: u64) -> Self {
        self.window_us = us;
        self
    }

    /// Set the mega-batch cap (samples).
    pub fn max_batch(mut self, samples: usize) -> Self {
        self.max_batch = samples;
        self
    }
}

/// Content hash of a parameter vector — the model identity in a coalesce
/// key. FNV-1a over the f32 bit patterns, so value-equal clones (the
/// per-camera copies of a published group model) share an id without any
/// pointer aliasing requirement.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ThetaId(pub u64);

/// Hash `theta` into a [`ThetaId`]. ~6k multiplies for the student model
/// — noise against a single-sample forward pass.
pub fn theta_id(theta: &[f32]) -> ThetaId {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &v in theta {
        h = (h ^ u64::from(v.to_bits())).wrapping_mul(0x0000_0100_0000_01b3);
    }
    ThetaId(h ^ theta.len() as u64)
}

/// Which inference program a request targets. Part of the coalesce key:
/// requests only merge within one program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReqKind {
    /// Detection head (`native::infer_det`).
    Det,
    /// Segmentation head (`native::infer_seg`).
    Seg,
    /// Probe-feature extraction (`native::features`; theta-free, so all
    /// concurrent feature batches at one resolution share a key).
    Feat,
}

/// One logical inference submission: `samples` frames at `res`×`res`
/// against the model identified by `theta_id`.
#[derive(Debug, Clone, Copy)]
pub struct InferRequest<'a> {
    pub kind: ReqKind,
    pub theta_id: ThetaId,
    pub res: usize,
    /// `samples * res * res * 3` floats, sample-major.
    pub pixels: &'a [f32],
    pub samples: usize,
}

/// Whole-batch kernel output, sliceable per submitter.
#[derive(Debug, Clone)]
pub enum InferOut {
    /// `(obj, cls)` from `native::infer_det`.
    Det { obj: Vec<f32>, cls: Vec<f32> },
    /// Per-pixel class probabilities from `native::infer_seg`.
    Seg { probs: Vec<f32> },
    /// L2-normalized descriptors from `native::features`.
    Feat { emb: Vec<f32> },
}

impl InferOut {
    /// Extract samples `[off, off + n)` out of an output covering
    /// `total` samples. Every payload vector is sample-major with a
    /// uniform per-sample stride, so the slice is a pure copy.
    fn slice_samples(&self, total: usize, off: usize, n: usize) -> InferOut {
        fn part(v: &[f32], total: usize, off: usize, n: usize) -> Vec<f32> {
            debug_assert_eq!(v.len() % total, 0);
            let per = v.len() / total;
            v[off * per..(off + n) * per].to_vec()
        }
        match self {
            InferOut::Det { obj, cls } => InferOut::Det {
                obj: part(obj, total, off, n),
                cls: part(cls, total, off, n),
            },
            InferOut::Seg { probs } => InferOut::Seg { probs: part(probs, total, off, n) },
            InferOut::Feat { emb } => InferOut::Feat { emb: part(emb, total, off, n) },
        }
    }
}

/// Coalesce key: requests merge only when the program, the resolution,
/// and the model content all match.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct Key {
    kind: ReqKind,
    res: usize,
    theta: ThetaId,
    theta_len: usize,
}

/// One in-flight mega-batch.
struct Group {
    /// Leader's theta, copied in so joiners can reject hash collisions
    /// bitwise (≈25 KB once per group — noise against the launch).
    theta: Vec<f32>,
    /// Concatenated member pixels, join order.
    pixels: Vec<f32>,
    /// Total samples accumulated.
    total: usize,
    /// Set by the leader once it stops accepting joins.
    closed: bool,
    /// Whole-batch output, set by the leader after the launch.
    out: Option<Arc<InferOut>>,
}

struct GroupCell {
    inner: Mutex<Group>,
    cv: Condvar,
}

/// The coalescing submission layer, one per `Engine`. All knobs are
/// atomics so serve sessions can reconfigure a shared engine without a
/// write lock (last writer wins; results are unaffected either way —
/// only batching granularity changes).
pub struct InferQueue {
    enabled: AtomicBool,
    window_us: AtomicU64,
    max_batch: AtomicUsize,
    /// Submitters currently inside [`InferQueue::submit`]. A leader that
    /// observes itself alone skips the coalesce window entirely, so
    /// serial callers pay no added latency.
    active: AtomicUsize,
    /// Open groups by coalesce key. Lock order: this map, then a group.
    groups: Mutex<HashMap<Key, Arc<GroupCell>>>,
}

impl InferQueue {
    pub fn new(opts: CoalesceOpts) -> InferQueue {
        let q = InferQueue {
            enabled: AtomicBool::new(false),
            window_us: AtomicU64::new(0),
            max_batch: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            groups: Mutex::new(HashMap::new()),
        };
        q.set_opts(opts);
        q
    }

    pub fn set_opts(&self, opts: CoalesceOpts) {
        self.window_us.store(opts.window_us, Ordering::Relaxed);
        self.max_batch.store(opts.max_batch.max(1), Ordering::Relaxed);
        self.enabled.store(opts.enabled, Ordering::Relaxed);
    }

    pub fn opts(&self) -> CoalesceOpts {
        CoalesceOpts {
            enabled: self.enabled.load(Ordering::Relaxed),
            window_us: self.window_us.load(Ordering::Relaxed),
            max_batch: self.max_batch.load(Ordering::Relaxed),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Submit one request. `run(mega_pixels, total_samples)` launches the
    /// kernel over a (possibly merged) batch; the caller gets back
    /// exactly its own samples' worth of output, bit-identical to
    /// `run(req.pixels, req.samples)`.
    ///
    /// `theta` must be the parameter vector `req.theta_id` was hashed
    /// from (empty for [`ReqKind::Feat`]). `run` must not panic — the
    /// engine validates shapes before submitting — and may itself fan
    /// out over the worker pool (followers park without holding locks).
    pub fn submit<F>(&self, req: InferRequest<'_>, theta: &[f32], run: F) -> InferOut
    where
        F: Fn(&[f32], usize) -> InferOut,
    {
        if !self.enabled() {
            return run(req.pixels, req.samples);
        }
        self.active.fetch_add(1, Ordering::SeqCst);
        let out = self.submit_coalescing(req, theta, run);
        self.active.fetch_sub(1, Ordering::SeqCst);
        out
    }

    fn submit_coalescing<F>(&self, req: InferRequest<'_>, theta: &[f32], run: F) -> InferOut
    where
        F: Fn(&[f32], usize) -> InferOut,
    {
        let key = Key {
            kind: req.kind,
            res: req.res,
            theta: req.theta_id,
            theta_len: theta.len(),
        };
        let max_batch = self.max_batch.load(Ordering::Relaxed).max(req.samples);

        // Join an open group if one fits, else install ourselves as the
        // leader of a fresh one (evicting a closed/full/mismatched entry
        // from the map — its members still hold it via Arc).
        let cell = {
            let mut map = plock(&self.groups);
            let joinable = map.get(&key).cloned().and_then(|c| {
                let mut g = plock(&c.inner);
                if !g.closed && g.total + req.samples <= max_batch && same_bits(&g.theta, theta) {
                    let off = g.total;
                    g.pixels.extend_from_slice(req.pixels);
                    g.total += req.samples;
                    let full = g.total >= max_batch;
                    drop(g);
                    if full {
                        c.cv.notify_all();
                    }
                    Some((c.clone(), off))
                } else {
                    None
                }
            });
            if let Some((c, off)) = joinable {
                drop(map);
                return self.follow(&c, off, req.samples);
            }
            let fresh = Arc::new(GroupCell {
                inner: Mutex::new(Group {
                    theta: theta.to_vec(),
                    pixels: req.pixels.to_vec(),
                    total: req.samples,
                    closed: false,
                    out: None,
                }),
                cv: Condvar::new(),
            });
            map.insert(key, fresh.clone());
            fresh
        };
        self.lead(key, &cell, req.samples, max_batch, run)
    }

    /// Leader: wait out the coalesce window, close, launch, publish.
    fn lead<F>(
        &self,
        key: Key,
        cell: &Arc<GroupCell>,
        own_samples: usize,
        max_batch: usize,
        run: F,
    ) -> InferOut
    where
        F: Fn(&[f32], usize) -> InferOut,
    {
        let window = Duration::from_micros(self.window_us.load(Ordering::Relaxed));
        let mut g = plock(&cell.inner);
        if !window.is_zero() {
            // ecco-lint: allow(D003) coalesce-window pacing only: the clock
            // bounds how long a leader waits for joiners and never reaches
            // results or events (the identity contract in the module docs).
            let deadline = Instant::now() + window;
            // Wait only while someone else is in-flight who could still
            // join; a lone submitter closes immediately.
            while g.total < max_batch && self.active.load(Ordering::SeqCst) > 1 {
                // ecco-lint: allow(D003) same window pacing as above.
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                g = pwait_timeout(&cell.cv, g, deadline - now).0;
            }
        }
        g.closed = true;
        let mega = std::mem::take(&mut g.pixels);
        let total = g.total;
        drop(g);

        // Unlink so new submitters start a fresh group (unless a joiner
        // that found us full already replaced the entry).
        {
            let mut map = plock(&self.groups);
            if matches!(map.get(&key), Some(c) if Arc::ptr_eq(c, cell)) {
                map.remove(&key);
            }
        }

        let out = Arc::new(run(&mega, total));
        let mine = out.slice_samples(total, 0, own_samples);
        let mut g = plock(&cell.inner);
        g.out = Some(out);
        drop(g);
        cell.cv.notify_all();
        mine
    }

    /// Follower: park until the leader publishes, then slice.
    fn follow(&self, cell: &GroupCell, off: usize, n: usize) -> InferOut {
        let mut g = plock(&cell.inner);
        loop {
            if let Some(out) = &g.out {
                let total = g.total;
                return out.slice_samples(total, off, n);
            }
            g = pwait(&cell.cv, g);
        }
    }
}

/// Bitwise slice equality — NaN-proof (a theta containing NaN simply
/// never coalesces with a value-equal clone via `==`, which would forfeit
/// batching; bit comparison keeps it working).
fn same_bits(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det_run(px: &[f32], n: usize) -> InferOut {
        // Stand-in kernel: per-sample pure, shape 2 floats per sample.
        let per = px.len() / n;
        let mut obj = Vec::with_capacity(n);
        let mut cls = Vec::with_capacity(n);
        for s in 0..n {
            let chunk = &px[s * per..(s + 1) * per];
            obj.push(chunk.iter().sum::<f32>());
            cls.push(chunk.iter().fold(0.0f32, |a, &v| a.max(v)));
        }
        InferOut::Det { obj, cls }
    }

    fn req(theta: &[f32], px: &[f32], samples: usize) -> InferRequest<'_> {
        InferRequest {
            kind: ReqKind::Det,
            theta_id: theta_id(theta),
            res: 16,
            pixels: px,
            samples,
        }
    }

    #[test]
    fn theta_id_is_content_keyed() {
        let a = vec![1.0f32, -2.5, 0.0];
        let b = a.clone();
        let c = vec![1.0f32, -2.5, 0.5];
        assert_eq!(theta_id(&a), theta_id(&b));
        assert_ne!(theta_id(&a), theta_id(&c));
        // Length is folded in: a prefix must not collide with the whole.
        assert_ne!(theta_id(&a[..2]), theta_id(&a));
    }

    #[test]
    fn disabled_queue_is_a_passthrough() {
        let q = InferQueue::new(CoalesceOpts::default());
        let theta = vec![0.25f32; 8];
        let px: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let direct = det_run(&px, 4);
        let via = q.submit(req(&theta, &px, 4), &theta, det_run);
        match (direct, via) {
            (InferOut::Det { obj: o1, cls: c1 }, InferOut::Det { obj: o2, cls: c2 }) => {
                assert_eq!(o1, o2);
                assert_eq!(c1, c2);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn lone_submitter_skips_the_window() {
        let q = InferQueue::new(CoalesceOpts::on().window_us(1_000_000));
        let theta = vec![1.5f32; 8];
        let px = vec![2.0f32; 6];
        let t0 = Instant::now();
        let out = q.submit(req(&theta, &px, 3), &theta, det_run);
        // A 1 s window must not be waited out when active == 1.
        assert!(t0.elapsed() < Duration::from_millis(500));
        match out {
            InferOut::Det { obj, .. } => assert_eq!(obj, vec![4.0f32; 3]),
            _ => unreachable!(),
        }
    }

    #[test]
    fn concurrent_submitters_coalesce_and_slice_correctly() {
        let q = InferQueue::new(CoalesceOpts::on().window_us(50_000));
        let theta = vec![0.5f32; 16];
        let launches = AtomicUsize::new(0);
        let n_threads = 4;
        let samples = 3;
        let outs: Vec<(usize, InferOut)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n_threads)
                .map(|t| {
                    let (q, theta, launches) = (&q, &theta, &launches);
                    s.spawn(move || {
                        let px: Vec<f32> = (0..samples * 2).map(|i| (t * 100 + i) as f32).collect();
                        let out = q.submit(req(theta, &px, samples), theta, |mega, n| {
                            launches.fetch_add(1, Ordering::SeqCst);
                            det_run(mega, n)
                        });
                        (t, out)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // Each submitter must get exactly its own samples back.
        for (t, out) in &outs {
            let px: Vec<f32> = (0..samples * 2).map(|i| (t * 100 + i) as f32).collect();
            let want = det_run(&px, samples);
            match (out, &want) {
                (InferOut::Det { obj, cls }, InferOut::Det { obj: wo, cls: wc }) => {
                    assert_eq!(obj, wo, "submitter {t} got someone else's slice");
                    assert_eq!(cls, wc);
                }
                _ => unreachable!(),
            }
        }
        // And at least some coalescing must have happened under a wide
        // window with 4 concurrent submitters.
        assert!(launches.load(Ordering::SeqCst) <= n_threads);
        assert!(launches.load(Ordering::SeqCst) >= 1);
    }

    #[test]
    fn mismatched_theta_never_merges() {
        let q = InferQueue::new(CoalesceOpts::on().window_us(20_000));
        let t1 = vec![1.0f32; 8];
        let t2 = vec![2.0f32; 8];
        std::thread::scope(|s| {
            for theta in [&t1, &t2] {
                let q = &q;
                s.spawn(move || {
                    let px = vec![theta[0]; 4];
                    let out = q.submit(req(theta, &px, 2), theta, det_run);
                    match out {
                        InferOut::Det { obj, .. } => {
                            assert_eq!(obj, vec![theta[0] * 2.0; 2]);
                        }
                        _ => unreachable!(),
                    }
                });
            }
        });
    }

    #[test]
    fn max_batch_splits_groups() {
        // Cap of 4 samples: two 3-sample requests can never share a
        // group, but both must still complete with correct slices.
        let q = InferQueue::new(CoalesceOpts::on().window_us(10_000).max_batch(4));
        let theta = vec![3.0f32; 8];
        std::thread::scope(|s| {
            for t in 0..2 {
                let (q, theta) = (&q, &theta);
                s.spawn(move || {
                    let px = vec![(t + 1) as f32; 6];
                    let out = q.submit(req(theta, &px, 3), theta, det_run);
                    match out {
                        InferOut::Det { obj, .. } => {
                            assert_eq!(obj, vec![(t + 1) as f32 * 2.0; 3]);
                        }
                        _ => unreachable!(),
                    }
                });
            }
        });
    }
}

//! Pure-Rust reference backend: the student model's exact math, no PJRT.
//!
//! This implements the same programs `python/compile/model.py` lowers to
//! HLO — the 3-conv im2col trunk, the det/seg heads, their losses, one
//! SGD+momentum step with global-norm gradient clipping, and the
//! patch-statistics feature descriptor — as straight Rust over flat `f32`
//! vectors. It is the default execution backend (the `xla` bindings crate
//! behind the `pjrt` feature is unavailable offline), keeps every test and
//! experiment runnable without generated artifacts, and doubles as an
//! executable specification of the artifact programs.
//!
//! Numerics match the JAX pipeline up to float summation order; the
//! bit-exact golden comparisons in `tests/golden_numerics.rs` only apply
//! to the PJRT backend.

use crate::util::pool::Pool;
use crate::util::rng::Pcg32;

use super::engine::{Labels, TrainBatch};
use super::manifest::Task;

/// Execution context for the batch-sharded kernels: which worker pool to
/// shard the batch dimension on and how many threads the call may use.
/// Per-sample results always reduce in sample-index order, so any `Exec`
/// produces bit-identical outputs — including [`Exec::serial`], which runs
/// the same per-sample code on the caller alone.
#[derive(Clone, Copy)]
pub struct Exec<'p> {
    pub pool: &'p Pool,
    pub threads: usize,
}

impl Exec<'static> {
    /// The serial path: no workers, caller-only.
    pub fn serial() -> Exec<'static> {
        Exec {
            pool: Pool::serial(),
            threads: 1,
        }
    }
}

/// Object classes (model.py `K`).
pub const K: usize = 4;
/// Detection grid (model.py `GRID`).
pub const GRID: usize = 4;
/// Head output channels: det `1+K`, seg `K+1` — both 5.
pub const HEAD_OUT: usize = 5;
/// SGD momentum coefficient.
pub const MOMENTUM: f32 = 0.9;
/// Global-norm gradient clip.
pub const GRAD_CLIP: f32 = 5.0;
/// Supported square resolutions.
pub const RESOLUTIONS: [usize; 3] = [16, 32, 48];
pub const TRAIN_BATCH: usize = 8;
pub const INFER_BATCH: usize = 16;
pub const FEATURE_RES: usize = 32;
/// patch_stats output: 4x4 patches x 3 channels x 2 moments.
pub const EMBED_DIM: usize = 96;
/// Descriptor patch grid side.
const PATCHES: usize = 4;

/// Conv trunk: (in_features = 9 * cin, out_features) per 3x3 layer.
const TRUNK: [(usize, usize); 3] = [(3 * 9, 8), (8 * 9, 16), (16 * 9, 32)];

/// Flat-vector parameter layout: (name, rows, cols); biases have rows = 0.
fn layout() -> Vec<(&'static str, usize, usize)> {
    let mut l = Vec::new();
    for (i, &(fin, fout)) in TRUNK.iter().enumerate() {
        let names = [
            ("conv1_w", "conv1_b"),
            ("conv2_w", "conv2_b"),
            ("conv3_w", "conv3_b"),
        ][i];
        l.push((names.0, fin, fout));
        l.push((names.1, 0, fout));
    }
    l.push(("head_w", 32, HEAD_OUT));
    l.push(("head_b", 0, HEAD_OUT));
    l
}

/// Total parameter count (identical for det and seg: both heads are 5-wide).
pub fn param_count(_task: Task) -> usize {
    layout()
        .iter()
        .map(|&(_, r, c)| if r == 0 { c } else { r * c })
        .sum()
}

/// Deterministic He initialisation (weights ~ N(0, 2/fan_in), biases 0).
///
/// Matches model.py's recipe, not its bit pattern (JAX PRNG is not
/// reproduced); only used when no `init_{task}.bin` artifact exists.
pub fn he_init(_task: Task, seed: u64) -> Vec<f32> {
    let mut theta = Vec::with_capacity(param_count(_task));
    for (idx, (_, rows, cols)) in layout().into_iter().enumerate() {
        if rows == 0 {
            theta.extend(vec![0.0f32; cols]);
        } else {
            let mut rng = Pcg32::new(seed ^ 0x4e17, idx as u64 + 0x11);
            let scale = (2.0 / rows as f32).sqrt();
            theta.extend((0..rows * cols).map(|_| rng.normal() * scale));
        }
    }
    theta
}

/// Borrowed views of the flat parameter vector.
struct Params<'a> {
    conv_w: [&'a [f32]; 3],
    conv_b: [&'a [f32]; 3],
    head_w: &'a [f32],
    head_b: &'a [f32],
}

/// Mutable gradient views with the same layout.
struct Grads<'a> {
    conv_w: [&'a mut [f32]; 3],
    conv_b: [&'a mut [f32]; 3],
    head_w: &'a mut [f32],
    head_b: &'a mut [f32],
}

fn split_params(theta: &[f32]) -> Params<'_> {
    let (c1w, rest) = theta.split_at(TRUNK[0].0 * TRUNK[0].1);
    let (c1b, rest) = rest.split_at(TRUNK[0].1);
    let (c2w, rest) = rest.split_at(TRUNK[1].0 * TRUNK[1].1);
    let (c2b, rest) = rest.split_at(TRUNK[1].1);
    let (c3w, rest) = rest.split_at(TRUNK[2].0 * TRUNK[2].1);
    let (c3b, rest) = rest.split_at(TRUNK[2].1);
    let (hw, hb) = rest.split_at(32 * HEAD_OUT);
    Params {
        conv_w: [c1w, c2w, c3w],
        conv_b: [c1b, c2b, c3b],
        head_w: hw,
        head_b: hb,
    }
}

fn split_grads(grad: &mut [f32]) -> Grads<'_> {
    let (c1w, rest) = grad.split_at_mut(TRUNK[0].0 * TRUNK[0].1);
    let (c1b, rest) = rest.split_at_mut(TRUNK[0].1);
    let (c2w, rest) = rest.split_at_mut(TRUNK[1].0 * TRUNK[1].1);
    let (c2b, rest) = rest.split_at_mut(TRUNK[1].1);
    let (c3w, rest) = rest.split_at_mut(TRUNK[2].0 * TRUNK[2].1);
    let (c3b, rest) = rest.split_at_mut(TRUNK[2].1);
    let (hw, hb) = rest.split_at_mut(32 * HEAD_OUT);
    Grads {
        conv_w: [c1w, c2w, c3w],
        conv_b: [c1b, c2b, c3b],
        head_w: hw,
        head_b: hb,
    }
}

// ---------------------------------------------------------------------------
// Dense primitives
// ---------------------------------------------------------------------------

/// Output-row block for the tiled matmul: this many rows of `a` share
/// each `b`-row load.
const MM_ROW_BLOCK: usize = 4;

/// Widest `n` the tiled matmul keeps in a stack tile. Every forward-pass
/// call site fits (conv widths 8/16/32, head width 5); anything wider
/// falls back to the row-at-a-time loop.
const MM_N_MAX: usize = 32;

/// `out[m,n] += a[m,k] @ b[k,n]` (row-major), skipping zero lhs entries —
/// im2col patches are full of padding zeros.
///
/// Register-blocked/tiled for the infer forward pass: [`MM_ROW_BLOCK`]
/// output rows are accumulated together in a stack tile (small enough for
/// the compiler to keep in vector registers, since `n <= MM_N_MAX` at
/// every call site), so each `b` row is loaded once per block instead of
/// once per row, and the contiguous inner loop over `n` autovectorizes.
/// Bit-identical to the naive loop by construction: every output element
/// still accumulates its `k` terms in ascending order with the same
/// per-element zero-skip, and moving f32 values through the tile changes
/// no bits. Remainder rows (`m % MM_ROW_BLOCK`) and wide-`n` calls take
/// [`matmul_acc_rows`], the original row-at-a-time loop.
fn matmul_acc(out: &mut [f32], a: &[f32], m: usize, k: usize, b: &[f32], n: usize) {
    if n > MM_N_MAX {
        return matmul_acc_rows(out, a, m, k, b, n);
    }
    let mut i = 0;
    while i + MM_ROW_BLOCK <= m {
        let mut tile = [[0.0f32; MM_N_MAX]; MM_ROW_BLOCK];
        for (r, trow) in tile.iter_mut().enumerate() {
            trow[..n].copy_from_slice(&out[(i + r) * n..(i + r) * n + n]);
        }
        for kk in 0..k {
            let brow = &b[kk * n..kk * n + n];
            for (r, trow) in tile.iter_mut().enumerate() {
                let av = a[(i + r) * k + kk];
                if av == 0.0 {
                    continue;
                }
                for (o, &bv) in trow[..n].iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        for (r, trow) in tile.iter().enumerate() {
            out[(i + r) * n..(i + r) * n + n].copy_from_slice(&trow[..n]);
        }
        i += MM_ROW_BLOCK;
    }
    matmul_acc_rows(&mut out[i * n..], &a[i * k..], m - i, k, b, n);
}

/// Row-at-a-time fallback (remainder rows; `n > MM_N_MAX`).
fn matmul_acc_rows(out: &mut [f32], a: &[f32], m: usize, k: usize, b: &[f32], n: usize) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// SAME-padded 3x3 im2col: `[B,H,W,C] -> [B*H*W, 9C]`, column order
/// `(dy*3+dx)*C + c` (matching model.py's concatenation order).
fn im2col3x3(x: &[f32], b: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let pc = 9 * c;
    let mut out = vec![0.0f32; b * h * w * pc];
    for bi in 0..b {
        for y in 0..h {
            for xx in 0..w {
                let row = ((bi * h + y) * w + xx) * pc;
                for dy in 0..3usize {
                    let sy = y + dy;
                    if sy < 1 || sy > h {
                        continue; // zero padding row
                    }
                    let sy = sy - 1;
                    for dx in 0..3usize {
                        let sx = xx + dx;
                        if sx < 1 || sx > w {
                            continue;
                        }
                        let sx = sx - 1;
                        let src = ((bi * h + sy) * w + sx) * c;
                        let dst = row + (dy * 3 + dx) * c;
                        out[dst..dst + c].copy_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
    }
    out
}

/// Scatter `[B*H*W, 9C]` patch gradients back to `[B,H,W,C]` (col2im).
fn col2im3x3(dpatches: &[f32], b: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let pc = 9 * c;
    let mut dx_out = vec![0.0f32; b * h * w * c];
    for bi in 0..b {
        for y in 0..h {
            for xx in 0..w {
                let row = ((bi * h + y) * w + xx) * pc;
                for dy in 0..3usize {
                    let sy = y + dy;
                    if sy < 1 || sy > h {
                        continue;
                    }
                    let sy = sy - 1;
                    for dx in 0..3usize {
                        let sx = xx + dx;
                        if sx < 1 || sx > w {
                            continue;
                        }
                        let sx = sx - 1;
                        let dst = ((bi * h + sy) * w + sx) * c;
                        let src = row + (dy * 3 + dx) * c;
                        for ch in 0..c {
                            dx_out[dst + ch] += dpatches[src + ch];
                        }
                    }
                }
            }
        }
    }
    dx_out
}

/// One trunk conv layer's forward cache.
struct ConvCache {
    patches: Vec<f32>, // [rows, 9*cin]
    out: Vec<f32>,     // [rows, cout], post-ReLU
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
}

/// `relu(im2col(x) @ w + bias)` with cached patches/outputs for backward.
fn conv3x3_relu(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    wmat: &[f32],
    bias: &[f32],
) -> ConvCache {
    let cout = bias.len();
    let rows = b * h * w;
    let patches = im2col3x3(x, b, h, w, cin);
    let mut out = vec![0.0f32; rows * cout];
    for row in out.chunks_mut(cout) {
        row.copy_from_slice(bias);
    }
    matmul_acc(&mut out, &patches, rows, 9 * cin, wmat, cout);
    for v in out.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
    ConvCache {
        patches,
        out,
        h,
        w,
        cin,
        cout,
    }
}

/// Backward through one conv layer: consumes `d_out` (gradient w.r.t. the
/// post-ReLU output), accumulates `dw`/`db`, returns gradient w.r.t. input.
fn conv3x3_relu_backward(
    cache: &ConvCache,
    b: usize,
    mut d_out: Vec<f32>,
    wmat: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
) -> Vec<f32> {
    let (h, w, cin, cout) = (cache.h, cache.w, cache.cin, cache.cout);
    let rows = b * h * w;
    // ReLU mask from the cached post-activation output.
    for (g, &o) in d_out.iter_mut().zip(&cache.out) {
        if o <= 0.0 {
            *g = 0.0;
        }
    }
    // db = column sums; dw = patches^T @ d_out.
    for i in 0..rows {
        let gr = &d_out[i * cout..(i + 1) * cout];
        for (dbj, &g) in db.iter_mut().zip(gr) {
            *dbj += g;
        }
        let prow = &cache.patches[i * 9 * cin..(i + 1) * 9 * cin];
        for (p, &pv) in prow.iter().enumerate() {
            if pv == 0.0 {
                continue;
            }
            let dwrow = &mut dw[p * cout..(p + 1) * cout];
            for (d, &g) in dwrow.iter_mut().zip(gr) {
                *d += pv * g;
            }
        }
    }
    // dpatches = d_out @ w^T, then fold back to the input grid.
    let mut dpatches = vec![0.0f32; rows * 9 * cin];
    for i in 0..rows {
        let gr = &d_out[i * cout..(i + 1) * cout];
        let drow = &mut dpatches[i * 9 * cin..(i + 1) * 9 * cin];
        for (p, d) in drow.iter_mut().enumerate() {
            let wrow = &wmat[p * cout..(p + 1) * cout];
            let mut acc = 0.0f32;
            for (&g, &wv) in gr.iter().zip(wrow) {
                acc += g * wv;
            }
            *d = acc;
        }
    }
    col2im3x3(&dpatches, b, h, w, cin)
}

/// 2x2 mean pool: `[B,H,W,C] -> [B,H/2,W/2,C]`.
fn pool2(x: &[f32], b: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let (h2, w2) = (h / 2, w / 2);
    let mut out = vec![0.0f32; b * h2 * w2 * c];
    for bi in 0..b {
        for y in 0..h2 {
            for xx in 0..w2 {
                let dst = ((bi * h2 + y) * w2 + xx) * c;
                for (u, v) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                    let src = ((bi * h + 2 * y + u) * w + 2 * xx + v) * c;
                    for ch in 0..c {
                        out[dst + ch] += 0.25 * x[src + ch];
                    }
                }
            }
        }
    }
    out
}

/// Backward of [`pool2`]: spread each output gradient over its 2x2 window.
fn pool2_backward(dy: &[f32], b: usize, h2: usize, w2: usize, c: usize) -> Vec<f32> {
    let (h, w) = (h2 * 2, w2 * 2);
    let mut dx = vec![0.0f32; b * h * w * c];
    for bi in 0..b {
        for y in 0..h2 {
            for xx in 0..w2 {
                let src = ((bi * h2 + y) * w2 + xx) * c;
                for (u, v) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                    let dst = ((bi * h + 2 * y + u) * w + 2 * xx + v) * c;
                    for ch in 0..c {
                        dx[dst + ch] += 0.25 * dy[src + ch];
                    }
                }
            }
        }
    }
    dx
}

/// `[B,S,S,C] -> [B,G,G,C]` average pool with factor `f = S/G`.
fn grid_pool(h: &[f32], b: usize, s: usize, c: usize) -> Vec<f32> {
    let f = s / GRID;
    let inv = 1.0 / (f * f) as f32;
    let mut out = vec![0.0f32; b * GRID * GRID * c];
    for bi in 0..b {
        for gy in 0..GRID {
            for gx in 0..GRID {
                let dst = ((bi * GRID + gy) * GRID + gx) * c;
                for i in 0..f {
                    for j in 0..f {
                        let src = ((bi * s + gy * f + i) * s + gx * f + j) * c;
                        for ch in 0..c {
                            out[dst + ch] += inv * h[src + ch];
                        }
                    }
                }
            }
        }
    }
    out
}

fn grid_pool_backward(dg: &[f32], b: usize, s: usize, c: usize) -> Vec<f32> {
    let f = s / GRID;
    let inv = 1.0 / (f * f) as f32;
    let mut dh = vec![0.0f32; b * s * s * c];
    for bi in 0..b {
        for gy in 0..GRID {
            for gx in 0..GRID {
                let src = ((bi * GRID + gy) * GRID + gx) * c;
                for i in 0..f {
                    for j in 0..f {
                        let dst = ((bi * s + gy * f + i) * s + gx * f + j) * c;
                        for ch in 0..c {
                            dh[dst + ch] += inv * dg[src + ch];
                        }
                    }
                }
            }
        }
    }
    dh
}

/// Full trunk forward: `[B,R,R,3] -> [B,R/4,R/4,32]` with layer caches.
fn trunk_forward(p: &Params, x: &[f32], b: usize, r: usize) -> (Vec<ConvCache>, Vec<f32>) {
    let c1 = conv3x3_relu(x, b, r, r, 3, p.conv_w[0], p.conv_b[0]);
    let p1 = pool2(&c1.out, b, r, r, 8);
    let r2 = r / 2;
    let c2 = conv3x3_relu(&p1, b, r2, r2, 8, p.conv_w[1], p.conv_b[1]);
    let p2 = pool2(&c2.out, b, r2, r2, 16);
    let r4 = r / 4;
    let c3 = conv3x3_relu(&p2, b, r4, r4, 16, p.conv_w[2], p.conv_b[2]);
    let h = c3.out.clone();
    (vec![c1, c2, c3], h)
}

/// Backward through the trunk given `dh` at `[B,R/4,R/4,32]`.
fn trunk_backward(
    caches: &[ConvCache],
    b: usize,
    r: usize,
    dh: Vec<f32>,
    p: &Params,
    g: &mut Grads,
) {
    let (r2, r4) = (r / 2, r / 4);
    let d_p2 = conv3x3_relu_backward(&caches[2], b, dh, p.conv_w[2], g.conv_w[2], g.conv_b[2]);
    let d_c2 = pool2_backward(&d_p2, b, r4, r4, 16);
    let d_p1 = conv3x3_relu_backward(&caches[1], b, d_c2, p.conv_w[1], g.conv_w[1], g.conv_b[1]);
    let d_c1 = pool2_backward(&d_p1, b, r2, r2, 8);
    conv3x3_relu_backward(&caches[0], b, d_c1, p.conv_w[0], g.conv_w[0], g.conv_b[0]);
}

/// 1x1 head: `[rows,32] @ [32,5] + b`. Returns logits.
fn head_forward(p: &Params, hin: &[f32], rows: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * HEAD_OUT];
    for row in out.chunks_mut(HEAD_OUT) {
        row.copy_from_slice(p.head_b);
    }
    matmul_acc(&mut out, hin, rows, 32, p.head_w, HEAD_OUT);
    out
}

/// Head backward: returns gradient w.r.t. the head input.
fn head_backward(
    hin: &[f32],
    rows: usize,
    dlogits: &[f32],
    p: &Params,
    g: &mut Grads,
) -> Vec<f32> {
    for i in 0..rows {
        let gr = &dlogits[i * HEAD_OUT..(i + 1) * HEAD_OUT];
        for (dbj, &gv) in g.head_b.iter_mut().zip(gr) {
            *dbj += gv;
        }
        let hrow = &hin[i * 32..(i + 1) * 32];
        for (ci, &hv) in hrow.iter().enumerate() {
            if hv == 0.0 {
                continue;
            }
            let dwrow = &mut g.head_w[ci * HEAD_OUT..(ci + 1) * HEAD_OUT];
            for (d, &gv) in dwrow.iter_mut().zip(gr) {
                *d += hv * gv;
            }
        }
    }
    let mut dhin = vec![0.0f32; rows * 32];
    for i in 0..rows {
        let gr = &dlogits[i * HEAD_OUT..(i + 1) * HEAD_OUT];
        let drow = &mut dhin[i * 32..(i + 1) * 32];
        for (ci, d) in drow.iter_mut().enumerate() {
            let wrow = &p.head_w[ci * HEAD_OUT..(ci + 1) * HEAD_OUT];
            let mut acc = 0.0f32;
            for (&gv, &wv) in gr.iter().zip(wrow) {
                acc += gv * wv;
            }
            *d = acc;
        }
    }
    dhin
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// In-place softmax over one 4-wide (det classes) or 5-wide (seg) row.
fn softmax_row(row: &mut [f32]) {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - m).exp();
        z += *v;
    }
    for v in row.iter_mut() {
        *v /= z;
    }
}

/// Det loss partials over one row range (one batch shard): raw BCE sum,
/// CE sum, and the logit gradient. `n_total` and `obj_sum` are the
/// batch-global normalisers, so per-sample shards sum (in sample order) to
/// exactly the whole-batch loss and gradient.
fn det_loss_grad_rows(
    logits: &[f32],
    y_obj: &[f32],
    y_cls: &[f32],
    n_total: usize,
    obj_sum: f32,
) -> (f32, f32, Vec<f32>) {
    let rows = y_obj.len();
    let mut dlogits = vec![0.0f32; logits.len()];
    let mut bce = 0.0f32;
    let mut ce = 0.0f32;
    for i in 0..rows {
        let lo = logits[i * HEAD_OUT];
        let y = y_obj[i];
        bce += lo.max(0.0) - lo * y + (-lo.abs()).exp().ln_1p();
        dlogits[i * HEAD_OUT] = (sigmoid(lo) - y) / n_total as f32;

        // Class CE on the 4 class logits, masked by objectness.
        let mut probs = [0.0f32; K];
        probs.copy_from_slice(&logits[i * HEAD_OUT + 1..(i + 1) * HEAD_OUT]);
        let m = probs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for p in probs.iter_mut() {
            *p = (*p - m).exp();
            z += *p;
        }
        let logz = z.ln();
        for (k, p) in probs.iter_mut().enumerate() {
            let yk = y_cls[i * K + k];
            let log_softmax = logits[i * HEAD_OUT + 1 + k] - m - logz;
            ce += -y * yk * log_softmax / obj_sum;
            dlogits[i * HEAD_OUT + 1 + k] = y * (*p / z - yk) / obj_sum;
        }
    }
    (bce, ce, dlogits)
}

/// Seg loss partials over one row range; `n_total` is the batch-global
/// cell count, so per-sample shards sum to the whole-batch loss.
fn seg_loss_grad_rows(logits: &[f32], y_mask: &[f32], n_total: usize) -> (f32, Vec<f32>) {
    let rows = logits.len() / HEAD_OUT;
    let n = n_total;
    let mut dlogits = vec![0.0f32; logits.len()];
    let mut loss = 0.0f32;
    for i in 0..rows {
        let row = &logits[i * HEAD_OUT..(i + 1) * HEAD_OUT];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        let mut exps = [0.0f32; HEAD_OUT];
        for (k, &v) in row.iter().enumerate() {
            exps[k] = (v - m).exp();
            z += exps[k];
        }
        let logz = z.ln();
        for k in 0..HEAD_OUT {
            let yk = y_mask[i * HEAD_OUT + k];
            loss += -yk * (row[k] - m - logz) / n as f32;
            dlogits[i * HEAD_OUT + k] = (exps[k] / z - yk) / n as f32;
        }
    }
    (loss, dlogits)
}

/// Read-only state shared by every batch shard of one kernel call.
struct ShardCtx<'a> {
    p: Params<'a>,
    /// Whole-batch pixels `[B,R,R,3]`.
    x: &'a [f32],
    r: usize,
    n_params: usize,
}

/// One sample's det loss partials and parameter gradient: (raw BCE sum,
/// CE sum, grad). Pure in `(ctx, labels, s)`, so shards run on any thread.
fn det_sample_grad(
    ctx: &ShardCtx,
    obj: &[f32],
    cls: &[f32],
    s: usize,
    n_rows: usize,
    obj_sum: f32,
) -> (f32, f32, Vec<f32>) {
    let r = ctx.r;
    let px = &ctx.x[s * r * r * 3..(s + 1) * r * r * 3];
    let mut g_all = vec![0.0f32; ctx.n_params];
    let mut g = split_grads(&mut g_all);
    let (caches, h) = trunk_forward(&ctx.p, px, 1, r);
    let sd = r / 4;
    let pooled = grid_pool(&h, 1, sd, 32);
    let rows = GRID * GRID;
    let logits = head_forward(&ctx.p, &pooled, rows);
    let (bce, ce, dlogits) = det_loss_grad_rows(
        &logits,
        &obj[s * rows..(s + 1) * rows],
        &cls[s * rows * K..(s + 1) * rows * K],
        n_rows,
        obj_sum,
    );
    let dpooled = head_backward(&pooled, rows, &dlogits, &ctx.p, &mut g);
    let dh = grid_pool_backward(&dpooled, 1, sd, 32);
    trunk_backward(&caches, 1, r, dh, &ctx.p, &mut g);
    (bce, ce, g_all)
}

/// One sample's seg loss partial and parameter gradient.
fn seg_sample_grad(ctx: &ShardCtx, mask: &[f32], s: usize, n_cells: usize) -> (f32, f32, Vec<f32>) {
    let r = ctx.r;
    let px = &ctx.x[s * r * r * 3..(s + 1) * r * r * 3];
    let mut g_all = vec![0.0f32; ctx.n_params];
    let mut g = split_grads(&mut g_all);
    let (caches, h) = trunk_forward(&ctx.p, px, 1, r);
    let sd = r / 4;
    let rows = sd * sd;
    let logits = head_forward(&ctx.p, &h, rows);
    let mask_s = &mask[s * rows * HEAD_OUT..(s + 1) * rows * HEAD_OUT];
    let (loss, dlogits) = seg_loss_grad_rows(&logits, mask_s, n_cells);
    let dh = head_backward(&h, rows, &dlogits, &ctx.p, &mut g);
    trunk_backward(&caches, 1, r, dh, &ctx.p, &mut g);
    (loss, 0.0, g_all)
}

/// One SGD+momentum step; mutates `theta`/`mom` in place, returns the loss.
/// `b` is the (padded) batch size; pixel/label sizes are checked by the
/// engine before this is called.
///
/// The per-sample forward/backward passes are independent given the
/// batch-global loss normalisers, so they **shard across `exec`'s pool**;
/// loss partials and gradients then reduce on the caller in sample-index
/// order, making the step bit-identical at any pool width (the serial
/// path runs the exact same per-sample code).
pub fn train_step(
    task: Task,
    theta: &mut [f32],
    mom: &mut [f32],
    batch: &TrainBatch,
    b: usize,
    lr: f32,
    exec: Exec,
) -> f32 {
    let (x, labels, r) = (&batch.pixels, &batch.labels, batch.res);
    let n_params = theta.len();
    let n_grid = b * GRID * GRID;
    let sd = r / 4;
    let n_cells = b * sd * sd;
    let shards: Vec<(f32, f32, Vec<f32>)> = {
        let ctx = ShardCtx {
            p: split_params(theta),
            x,
            r,
            n_params,
        };
        let ctx = &ctx;
        match (task, labels) {
            (Task::Det, Labels::Det { obj, cls }) => {
                let obj_sum: f32 = obj.iter().sum::<f32>() + 1e-6;
                exec.pool.map_n(exec.threads, b, |s| {
                    det_sample_grad(ctx, obj, cls, s, n_grid, obj_sum)
                })
            }
            (Task::Seg, Labels::Seg { mask }) => {
                let shard = |s: usize| seg_sample_grad(ctx, mask, s, n_cells);
                exec.pool.map_n(exec.threads, b, shard)
            }
            // ecco-lint: allow(D001) the engine's train() rejects
            // mismatched label kinds before this kernel is reachable, and
            // the closure's return type leaves no Result channel here.
            _ => unreachable!("label kind checked against task by the engine"),
        }
    };
    // Sample-index-order reduction (the determinism contract).
    let mut grad = vec![0.0f32; n_params];
    let mut loss_main = 0.0f32;
    let mut loss_aux = 0.0f32;
    for (main, aux, gs) in shards {
        loss_main += main;
        loss_aux += aux;
        for (acc, &gv) in grad.iter_mut().zip(&gs) {
            *acc += gv;
        }
    }
    let loss = match task {
        Task::Det => loss_main / n_grid as f32 + loss_aux,
        Task::Seg => loss_main,
    };
    // Global-norm clip, then heavy-ball momentum.
    let norm = (grad.iter().map(|g| g * g).sum::<f32>() + 1e-12).sqrt();
    let scale = (GRAD_CLIP / norm).min(1.0);
    for ((t, m), g) in theta.iter_mut().zip(mom.iter_mut()).zip(&grad) {
        *m = MOMENTUM * *m + g * scale;
        *t -= lr * *m;
    }
    loss
}

/// Detection inference: `(obj sigmoid [B,G,G], class softmax [B,G,G,K])`.
/// Samples are independent end to end, so the batch shards across `exec`'s
/// pool; per-sample outputs concatenate in sample order.
pub fn infer_det(
    theta: &[f32],
    pixels: &[f32],
    b: usize,
    r: usize,
    exec: Exec,
) -> (Vec<f32>, Vec<f32>) {
    let p = split_params(theta);
    let pr = &p;
    let rows = GRID * GRID;
    let per: Vec<(Vec<f32>, Vec<f32>)> = exec.pool.map_n(exec.threads, b, |s| {
        let px = &pixels[s * r * r * 3..(s + 1) * r * r * 3];
        let (_, h) = trunk_forward(pr, px, 1, r);
        let pooled = grid_pool(&h, 1, r / 4, 32);
        let logits = head_forward(pr, &pooled, rows);
        let mut obj = Vec::with_capacity(rows);
        let mut cls = Vec::with_capacity(rows * K);
        for i in 0..rows {
            obj.push(sigmoid(logits[i * HEAD_OUT]));
            let mut row = [0.0f32; K];
            row.copy_from_slice(&logits[i * HEAD_OUT + 1..(i + 1) * HEAD_OUT]);
            softmax_row(&mut row);
            cls.extend_from_slice(&row);
        }
        (obj, cls)
    });
    let mut obj = Vec::with_capacity(b * rows);
    let mut cls = Vec::with_capacity(b * rows * K);
    for (o, c) in per {
        obj.extend(o);
        cls.extend(c);
    }
    (obj, cls)
}

/// Segmentation inference: class softmax `[B,S,S,K+1]`, batch-sharded like
/// [`infer_det`].
pub fn infer_seg(theta: &[f32], pixels: &[f32], b: usize, r: usize, exec: Exec) -> Vec<f32> {
    let p = split_params(theta);
    let pr = &p;
    let sd = r / 4;
    let rows = sd * sd;
    let per: Vec<Vec<f32>> = exec.pool.map_n(exec.threads, b, |s| {
        let px = &pixels[s * r * r * 3..(s + 1) * r * r * 3];
        let (_, h) = trunk_forward(pr, px, 1, r);
        let mut logits = head_forward(pr, &h, rows);
        for row in logits.chunks_mut(HEAD_OUT) {
            softmax_row(row);
        }
        logits
    });
    let mut out = Vec::with_capacity(b * rows * HEAD_OUT);
    for chunk in per {
        out.extend(chunk);
    }
    out
}

/// Descriptor batch size at which [`features`] starts sharding across the
/// pool. One sample is ~15k flops (a few µs) — below the pool's per-wake
/// handout cost — so the default 16-sample probe batch stays on the
/// serial fast path; only coalesced mega-batches (the micro-batch layer
/// merging concurrent probes) get large enough for sharding to pay.
pub const FEATURE_SHARD_MIN: usize = 64;

/// Patch-statistics descriptors: `[B,R,R,3] -> [B,96]`, L2-normalised.
///
/// Mirrors `python/compile/kernels/patchstats.py`: a 4x4 patch grid, each
/// patch contributing per-channel (mean, sqrt(var + 1e-6)). Serial below
/// [`FEATURE_SHARD_MIN`] samples, batch-sharded (index-ordered, so
/// bit-identical to serial) at or above it.
pub fn features(x: &[f32], b: usize, r: usize, exec: Exec) -> Vec<f32> {
    if b >= FEATURE_SHARD_MIN && exec.threads > 1 {
        let per: Vec<[f32; EMBED_DIM]> =
            exec.pool.map_n(exec.threads, b, |bi| feature_sample(x, bi, r));
        let mut out = Vec::with_capacity(b * EMBED_DIM);
        for emb in per {
            out.extend_from_slice(&emb);
        }
        return out;
    }
    let mut out = Vec::with_capacity(b * EMBED_DIM);
    for bi in 0..b {
        out.extend_from_slice(&feature_sample(x, bi, r));
    }
    out
}

/// One sample's descriptor — the shared body of both [`features`] paths.
fn feature_sample(x: &[f32], bi: usize, r: usize) -> [f32; EMBED_DIM] {
    let patch = r / PATCHES;
    let inv_n = 1.0 / (patch * patch) as f32;
    let mut emb = [0.0f32; EMBED_DIM];
    for py in 0..PATCHES {
        for px in 0..PATCHES {
            let mut s1 = [0.0f32; 3];
            let mut s2 = [0.0f32; 3];
            for y in 0..patch {
                for xx in 0..patch {
                    let src = ((bi * r + py * patch + y) * r + px * patch + xx) * 3;
                    for c in 0..3 {
                        let v = x[src + c];
                        s1[c] += v;
                        s2[c] += v * v;
                    }
                }
            }
            for c in 0..3 {
                let mean = s1[c] * inv_n;
                let var = (s2[c] * inv_n - mean * mean).max(0.0);
                let base = ((py * PATCHES + px) * 3 + c) * 2;
                emb[base] = mean;
                emb[base + 1] = (var + 1e-6).sqrt();
            }
        }
    }
    let norm = emb.iter().map(|v| v * v).sum::<f32>().sqrt() + 1e-8;
    for v in emb.iter_mut() {
        *v /= norm;
    }
    emb
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg(n: usize, seed: u32) -> Vec<f32> {
        crate::util::rng::GoldenLcg::new(seed).fill(n)
    }

    /// Whole-batch det loss + gradient over the sharded row kernel (what
    /// `train_step` reduces to; the finite-difference check differentiates
    /// this composition directly).
    fn det_loss_grad(logits: &[f32], y_obj: &[f32], y_cls: &[f32]) -> (f32, Vec<f32>) {
        let n = y_obj.len();
        let obj_sum: f32 = y_obj.iter().sum::<f32>() + 1e-6;
        let (bce, ce, dlogits) = det_loss_grad_rows(logits, y_obj, y_cls, n, obj_sum);
        (bce / n as f32 + ce, dlogits)
    }

    #[test]
    fn param_count_matches_layout() {
        // conv1 27x8+8, conv2 72x16+16, conv3 144x32+32, head 32x5+5.
        assert_eq!(param_count(Task::Det), 224 + 1168 + 4640 + 165);
        assert_eq!(param_count(Task::Det), param_count(Task::Seg));
    }

    #[test]
    fn he_init_is_deterministic_and_spread() {
        let a = he_init(Task::Det, 1234);
        let b = he_init(Task::Det, 1234);
        assert_eq!(a, b);
        assert_eq!(a.len(), param_count(Task::Det));
        let nonzero = a.iter().filter(|v| **v != 0.0).count();
        assert!(nonzero > a.len() / 2);
        assert!(a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn det_training_reduces_loss() {
        let (b, r) = (TRAIN_BATCH, 16usize);
        let mut theta = he_init(Task::Det, 7);
        let mut mom = vec![0.0; theta.len()];
        let x = lcg(b * r * r * 3, 7);
        let obj: Vec<f32> = lcg(b * GRID * GRID, 11)
            .into_iter()
            .map(|v| if v > 0.7 { 1.0 } else { 0.0 })
            .collect();
        let mut cls = vec![0.0f32; b * GRID * GRID * K];
        for (i, chunk) in cls.chunks_mut(K).enumerate() {
            chunk[i % K] = 1.0;
        }
        let batch = TrainBatch {
            res: r,
            pixels: x,
            labels: Labels::Det { obj, cls },
        };
        let first = train_step(Task::Det, &mut theta, &mut mom, &batch, b, 0.03, Exec::serial());
        let mut best = first;
        for _ in 0..40 {
            let l = train_step(Task::Det, &mut theta, &mut mom, &batch, b, 0.03, Exec::serial());
            best = best.min(l);
        }
        assert!(first.is_finite() && best.is_finite());
        assert!(
            best < first * 0.8,
            "loss should drop on a fixed batch: {first} -> best {best}"
        );
    }

    #[test]
    fn seg_training_reduces_loss() {
        let (b, r) = (TRAIN_BATCH, 16usize);
        let s = r / 4;
        let mut theta = he_init(Task::Seg, 9);
        let mut mom = vec![0.0; theta.len()];
        let x = lcg(b * r * r * 3, 13);
        let mut mask = vec![0.0f32; b * s * s * HEAD_OUT];
        for (i, chunk) in mask.chunks_mut(HEAD_OUT).enumerate() {
            chunk[i % HEAD_OUT] = 1.0;
        }
        let batch = TrainBatch {
            res: r,
            pixels: x,
            labels: Labels::Seg { mask },
        };
        let first = train_step(Task::Seg, &mut theta, &mut mom, &batch, b, 0.03, Exec::serial());
        let mut best = first;
        for _ in 0..40 {
            let l = train_step(Task::Seg, &mut theta, &mut mom, &batch, b, 0.03, Exec::serial());
            best = best.min(l);
        }
        assert!(best < first * 0.8, "{first} -> best {best}");
    }

    #[test]
    fn gradient_matches_finite_difference() {
        // Check a few random parameters' analytic gradient against central
        // differences on the det loss (the whole backward path in one go).
        let (b, r) = (2usize, 16usize);
        let theta0 = he_init(Task::Det, 3);
        let x = lcg(b * r * r * 3, 5);
        let obj: Vec<f32> = (0..b * GRID * GRID)
            .map(|i| if i % 3 == 0 { 1.0 } else { 0.0 })
            .collect();
        let mut cls = vec![0.0f32; b * GRID * GRID * K];
        for (i, chunk) in cls.chunks_mut(K).enumerate() {
            chunk[(i * 2 + 1) % K] = 1.0;
        }

        let loss_at = |theta: &[f32]| -> f32 {
            let p = split_params(theta);
            let (_, h) = trunk_forward(&p, &x, b, r);
            let pooled = grid_pool(&h, b, r / 4, 32);
            let logits = head_forward(&p, &pooled, b * GRID * GRID);
            det_loss_grad(&logits, &obj, &cls).0
        };

        // Analytic gradient (pre-clip) via a zero-momentum, tiny-lr step:
        // theta' = theta - lr * clip_scale * grad, so grad is recoverable
        // only if clipping is inactive — compute it directly instead.
        let mut grad = vec![0.0f32; theta0.len()];
        {
            let p = split_params(&theta0);
            let mut g = split_grads(&mut grad);
            let (caches, h) = trunk_forward(&p, &x, b, r);
            let pooled = grid_pool(&h, b, r / 4, 32);
            let logits = head_forward(&p, &pooled, b * GRID * GRID);
            let (_, dlogits) = det_loss_grad(&logits, &obj, &cls);
            let dpooled = head_backward(&pooled, b * GRID * GRID, &dlogits, &p, &mut g);
            let dh = grid_pool_backward(&dpooled, b, r / 4, 32);
            trunk_backward(&caches, b, r, dh, &p, &mut g);
        }

        let eps = 1e-2f32;
        // Probe indices across all layers: conv1_w, conv2_w, conv3_w, head.
        for &idx in &[0usize, 100, 300, 1400, 2000, 6035, 6190] {
            let mut tp = theta0.clone();
            tp[idx] += eps;
            let mut tm = theta0.clone();
            tm[idx] -= eps;
            let fd = (loss_at(&tp) - loss_at(&tm)) / (2.0 * eps);
            let g = grad[idx];
            assert!(
                (fd - g).abs() <= 2e-3 + 0.05 * fd.abs().max(g.abs()),
                "grad[{idx}]: analytic {g} vs finite-diff {fd}"
            );
        }
    }

    #[test]
    fn infer_outputs_are_probabilities() {
        let (b, r) = (INFER_BATCH, 32usize);
        let theta = he_init(Task::Det, 21);
        let x = lcg(b * r * r * 3, 23);
        let (obj, cls) = infer_det(&theta, &x, b, r, Exec::serial());
        assert_eq!(obj.len(), b * GRID * GRID);
        assert_eq!(cls.len(), b * GRID * GRID * K);
        assert!(obj.iter().all(|p| (0.0..=1.0).contains(p)));
        for row in cls.chunks(K) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
        let theta_s = he_init(Task::Seg, 22);
        let probs = infer_seg(&theta_s, &x, b, r, Exec::serial());
        assert_eq!(probs.len(), b * (r / 4) * (r / 4) * HEAD_OUT);
        for row in probs.chunks(HEAD_OUT) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn features_unit_norm_and_shape() {
        let b = 4usize;
        let x = lcg(b * 32 * 32 * 3, 29);
        let emb = features(&x, b, 32, Exec::serial());
        assert_eq!(emb.len(), b * EMBED_DIM);
        for row in emb.chunks(EMBED_DIM) {
            let norm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-3, "norm={norm}");
        }
        // A constant image has zero variance everywhere: stds collapse to
        // sqrt(eps), means dominate.
        let flat = vec![0.5f32; 32 * 32 * 3];
        let e = features(&flat, 1, 32, Exec::serial());
        assert!(e[0] > e[1], "mean channel should dominate std channel");
    }

    #[test]
    fn features_sharded_bit_identical_to_serial() {
        // Past FEATURE_SHARD_MIN the batch shards across the pool with an
        // index-ordered concat — pinned bitwise equal to the serial loop.
        let b = FEATURE_SHARD_MIN + 3;
        let x = lcg(b * 32 * 32 * 3, 53);
        let serial = features(&x, b, 32, Exec::serial());
        let pool = Pool::new(3);
        let sharded = features(
            &x,
            b,
            32,
            Exec {
                pool: &pool,
                threads: 4,
            },
        );
        assert_eq!(serial, sharded);
    }

    #[test]
    fn tiled_matmul_bit_identical_to_row_loop() {
        // The register-blocked matmul must preserve every bit of the
        // row-at-a-time reference, including remainder rows and the
        // zero-skip path, across the widths the forward pass uses.
        for &(m, k, n) in &[(16usize, 27usize, 8usize), (7, 72, 16), (9, 144, 32), (16, 32, 5)] {
            let mut a = lcg(m * k, (m * 31 + n) as u32);
            // Sprinkle exact zeros like im2col padding does.
            for (i, v) in a.iter_mut().enumerate() {
                if i % 5 == 0 {
                    *v = 0.0;
                }
            }
            let bm = lcg(k * n, (k * 7 + n) as u32);
            let mut out_tiled = lcg(m * n, 11);
            let mut out_ref = out_tiled.clone();
            matmul_acc(&mut out_tiled, &a, m, k, &bm, n);
            matmul_acc_rows(&mut out_ref, &a, m, k, &bm, n);
            assert_eq!(out_tiled, out_ref, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn all_resolutions_run() {
        for &r in &RESOLUTIONS {
            let mut theta = he_init(Task::Det, 31);
            let mut mom = vec![0.0; theta.len()];
            let batch = TrainBatch {
                res: r,
                pixels: lcg(TRAIN_BATCH * r * r * 3, 31),
                labels: Labels::Det {
                    obj: vec![0.0; TRAIN_BATCH * GRID * GRID],
                    cls: vec![0.0; TRAIN_BATCH * GRID * GRID * K],
                },
            };
            let loss = train_step(
                Task::Det,
                &mut theta,
                &mut mom,
                &batch,
                TRAIN_BATCH,
                0.01,
                Exec::serial(),
            );
            assert!(loss.is_finite(), "det r{r}");
        }
    }

    /// Batch sharding's determinism contract: pool widths 1 and 4 produce
    /// bit-identical parameters, momentum, losses, and inference outputs.
    #[test]
    fn sharded_kernels_bit_identical_at_pool_sizes_1_and_4() {
        let par_pool = Pool::new(3);
        let par = Exec {
            pool: &par_pool,
            threads: 4,
        };
        let (b, r) = (TRAIN_BATCH, 16usize);
        let x = lcg(b * r * r * 3, 41);
        let obj: Vec<f32> = lcg(b * GRID * GRID, 43)
            .into_iter()
            .map(|v| if v > 0.6 { 1.0 } else { 0.0 })
            .collect();
        let mut cls = vec![0.0f32; b * GRID * GRID * K];
        for (i, chunk) in cls.chunks_mut(K).enumerate() {
            chunk[i % K] = 1.0;
        }
        let det_batch = TrainBatch {
            res: r,
            pixels: x.clone(),
            labels: Labels::Det { obj, cls },
        };
        let sd = r / 4;
        let mut mask = vec![0.0f32; b * sd * sd * HEAD_OUT];
        for (i, chunk) in mask.chunks_mut(HEAD_OUT).enumerate() {
            chunk[(i * 3 + 1) % HEAD_OUT] = 1.0;
        }
        let seg_batch = TrainBatch {
            res: r,
            pixels: x.clone(),
            labels: Labels::Seg { mask },
        };
        for (task, batch) in [(Task::Det, &det_batch), (Task::Seg, &seg_batch)] {
            let mut theta_a = he_init(task, 47);
            let mut mom_a = vec![0.0f32; theta_a.len()];
            let mut theta_b = theta_a.clone();
            let mut mom_b = mom_a.clone();
            for step in 0..5 {
                let la = train_step(task, &mut theta_a, &mut mom_a, batch, b, 0.03, Exec::serial());
                let lb = train_step(task, &mut theta_b, &mut mom_b, batch, b, 0.03, par);
                assert_eq!(la.to_bits(), lb.to_bits(), "{task:?} loss step {step}");
            }
            let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&theta_a), bits(&theta_b), "{task:?} theta diverged");
            assert_eq!(bits(&mom_a), bits(&mom_b), "{task:?} momentum diverged");
        }
        // Inference: identical outputs, bit for bit.
        let theta = he_init(Task::Det, 53);
        let xi = lcg(INFER_BATCH * 32 * 32 * 3, 59);
        let (obj_s, cls_s) = infer_det(&theta, &xi, INFER_BATCH, 32, Exec::serial());
        let (obj_p, cls_p) = infer_det(&theta, &xi, INFER_BATCH, 32, par);
        assert_eq!(obj_s, obj_p);
        assert_eq!(cls_s, cls_p);
        let theta_seg = he_init(Task::Seg, 61);
        let seg_s = infer_seg(&theta_seg, &xi, INFER_BATCH, 32, Exec::serial());
        let seg_p = infer_seg(&theta_seg, &xi, INFER_BATCH, 32, par);
        assert_eq!(seg_s, seg_p);
    }
}

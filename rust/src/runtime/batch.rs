//! Batch assembly: frames + ground truth -> padded tensors for the engine.
//!
//! The AOT artifacts have fixed batch sizes (8 train / 16 infer), so
//! partial batches are padded by cycling earlier frames; for inference the
//! caller should ignore outputs past the real count (helpers here track it).

use crate::scene::{Frame, GroundTruth};

use super::engine::{Labels, TrainBatch};
use super::manifest::Task;

/// Flatten and pad frame pixels into a `[B,r,r,3]` tensor.
/// Panics if `frames` is empty or resolutions mismatch.
pub fn pixel_tensor(frames: &[&Frame], batch: usize, res: usize) -> Vec<f32> {
    assert!(!frames.is_empty(), "cannot build a batch from zero frames");
    let mut out = Vec::with_capacity(batch * res * res * 3);
    for i in 0..batch {
        let f = frames[i % frames.len()];
        assert_eq!(f.res, res, "frame resolution mismatch");
        out.extend_from_slice(&f.pixels);
    }
    out
}

/// Detection labels from ground truths (teacher output), padded to `batch`.
pub fn det_labels(truths: &[&GroundTruth], batch: usize, grid: usize, classes: usize) -> Labels {
    let mut obj = Vec::with_capacity(batch * grid * grid);
    let mut cls = Vec::with_capacity(batch * grid * grid * classes);
    for i in 0..batch {
        let t = truths[i % truths.len()];
        let (og, cg) = t.det_grids();
        for gy in 0..grid {
            for gx in 0..grid {
                obj.push(og[gy][gx]);
                for c in 0..classes {
                    cls.push(if cg[gy][gx] == c && og[gy][gx] > 0.0 {
                        1.0
                    } else {
                        0.0
                    });
                }
            }
        }
    }
    Labels::Det { obj, cls }
}

/// Segmentation labels (one-hot masks at side `s = res/4`), padded.
pub fn seg_labels(truths: &[&GroundTruth], batch: usize, side: usize, classes: usize) -> Labels {
    let bg = classes; // background channel index
    let mut mask = Vec::with_capacity(batch * side * side * (classes + 1));
    for i in 0..batch {
        let t = truths[i % truths.len()];
        let grid = t.mask_grid(side);
        for &cell in &grid {
            for c in 0..=bg {
                mask.push(if cell == c { 1.0 } else { 0.0 });
            }
        }
    }
    Labels::Seg { mask }
}

/// Build a full training batch for `task` from labelled frames.
pub fn train_batch(
    task: Task,
    frames: &[&Frame],
    truths: &[&GroundTruth],
    batch: usize,
    res: usize,
    classes: usize,
    grid: usize,
) -> TrainBatch {
    assert_eq!(frames.len(), truths.len());
    let pixels = pixel_tensor(frames, batch, res);
    let labels = match task {
        Task::Det => det_labels(truths, batch, grid, classes),
        Task::Seg => seg_labels(truths, batch, res / 4, classes),
    };
    TrainBatch {
        res,
        pixels,
        labels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::{render, SceneState};

    fn mk_frames(n: usize, res: usize) -> Vec<Frame> {
        let s = SceneState::default_day();
        (0..n).map(|i| render(&s, res, 1000 + i as u64)).collect()
    }

    #[test]
    fn pixel_tensor_pads_by_cycling() {
        let frames = mk_frames(3, 16);
        let refs: Vec<&Frame> = frames.iter().collect();
        let t = pixel_tensor(&refs, 8, 16);
        assert_eq!(t.len(), 8 * 16 * 16 * 3);
        let fsz = 16 * 16 * 3;
        // Slot 3 should repeat frame 0.
        assert_eq!(&t[3 * fsz..4 * fsz], &t[0..fsz]);
    }

    #[test]
    fn det_labels_one_hot_when_present() {
        let frames = mk_frames(2, 32);
        let truths: Vec<&GroundTruth> = frames.iter().map(|f| &f.truth).collect();
        match det_labels(&truths, 4, 4, 4) {
            Labels::Det { obj, cls } => {
                assert_eq!(obj.len(), 4 * 16);
                assert_eq!(cls.len(), 4 * 16 * 4);
                for (i, &o) in obj.iter().enumerate() {
                    let row: f32 = cls[i * 4..(i + 1) * 4].iter().sum();
                    if o > 0.0 {
                        assert_eq!(row, 1.0, "occupied cell must be one-hot");
                    } else {
                        assert_eq!(row, 0.0, "empty cell must be all-zero");
                    }
                }
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn seg_labels_one_hot_everywhere() {
        let frames = mk_frames(2, 32);
        let truths: Vec<&GroundTruth> = frames.iter().map(|f| &f.truth).collect();
        match seg_labels(&truths, 3, 8, 4) {
            Labels::Seg { mask } => {
                assert_eq!(mask.len(), 3 * 8 * 8 * 5);
                for cell in mask.chunks(5) {
                    assert_eq!(cell.iter().sum::<f32>(), 1.0);
                }
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn train_batch_shapes() {
        let frames = mk_frames(5, 48);
        let refs: Vec<&Frame> = frames.iter().collect();
        let truths: Vec<&GroundTruth> = frames.iter().map(|f| &f.truth).collect();
        let b = train_batch(Task::Seg, &refs, &truths, 8, 48, 4, 4);
        assert_eq!(b.pixels.len(), 8 * 48 * 48 * 3);
        match b.labels {
            Labels::Seg { mask } => assert_eq!(mask.len(), 8 * 12 * 12 * 5),
            _ => unreachable!(),
        }
    }
}

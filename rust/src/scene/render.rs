//! Frame renderer: SceneState -> pixels + ground truth.
//!
//! Frames are HWC f32 tensors in [0,1] at any of the supported resolutions.
//! The renderer is deterministic in `(scene state, frame seed)` so a video
//! "frame" can be regenerated for teacher labelling, training, and held-out
//! evaluation without storing pixels.
//!
//! Object classes are distinguishable by shape AND colour:
//!   0 = square (warm red), 1 = disc (green), 2 = triangle (blue),
//!   3 = cross (yellow).
//! Illumination / palette / rain modulate both background and objects, so a
//! student fit on one SceneState degrades under another — the drift signal
//! the whole system runs on.

use super::drift::{SceneState, GRID, K};
use crate::util::rng::Pcg32;

/// Base (pre-illumination) colour of each object class.
pub const CLASS_COLORS: [[f32; 3]; K] = [
    [0.85, 0.25, 0.2],
    [0.2, 0.8, 0.3],
    [0.25, 0.35, 0.9],
    [0.9, 0.85, 0.2],
];

/// One rendered object instance.
#[derive(Debug, Clone)]
pub struct Obj {
    /// Class index in 0..K.
    pub class: usize,
    /// Centre in normalised [0,1) frame coordinates.
    pub cx: f32,
    pub cy: f32,
    /// Radius in normalised units.
    pub radius: f32,
}

impl Obj {
    /// Grid cell containing the object centre.
    pub fn cell(&self) -> (usize, usize) {
        let gy = ((self.cy * GRID as f32) as usize).min(GRID - 1);
        let gx = ((self.cx * GRID as f32) as usize).min(GRID - 1);
        (gy, gx)
    }

    /// Signed membership test in normalised coordinates.
    pub fn contains(&self, x: f32, y: f32) -> bool {
        let dx = x - self.cx;
        let dy = y - self.cy;
        let r = self.radius;
        match self.class {
            0 => dx.abs() < r * 0.85 && dy.abs() < r * 0.85,
            1 => dx * dx + dy * dy < r * r,
            2 => {
                // Upward triangle: apex at cy-r, base at cy+r.
                dy > -r && dy < r && dx.abs() < (dy + r) * 0.5
            }
            _ => (dx.abs() < r * 0.35 && dy.abs() < r) || (dy.abs() < r * 0.35 && dx.abs() < r),
        }
    }
}

/// Ground truth attached to a frame.
#[derive(Debug, Clone)]
pub struct GroundTruth {
    pub objects: Vec<Obj>,
}

impl GroundTruth {
    /// Detection labels: objectness [GRID][GRID] and class grid.
    /// When multiple objects land in one cell the larger one wins.
    pub fn det_grids(&self) -> ([[f32; GRID]; GRID], [[usize; GRID]; GRID]) {
        let mut obj = [[0.0f32; GRID]; GRID];
        let mut cls = [[0usize; GRID]; GRID];
        let mut best = [[0.0f32; GRID]; GRID];
        for o in &self.objects {
            let (gy, gx) = o.cell();
            if o.radius > best[gy][gx] {
                best[gy][gx] = o.radius;
                obj[gy][gx] = 1.0;
                cls[gy][gx] = o.class;
            }
        }
        (obj, cls)
    }

    /// Segmentation label grid at an s x s resolution: class K = background,
    /// otherwise the class of the topmost object covering the cell centre.
    pub fn mask_grid(&self, s: usize) -> Vec<usize> {
        let mut mask = vec![K; s * s];
        for iy in 0..s {
            for ix in 0..s {
                let x = (ix as f32 + 0.5) / s as f32;
                let y = (iy as f32 + 0.5) / s as f32;
                for o in self.objects.iter().rev() {
                    if o.contains(x, y) {
                        mask[iy * s + ix] = o.class;
                        break;
                    }
                }
            }
        }
        mask
    }
}

/// A rendered frame: pixels (HWC, res*res*3) + truth + provenance.
#[derive(Debug, Clone)]
pub struct Frame {
    pub res: usize,
    pub pixels: Vec<f32>,
    pub truth: GroundTruth,
}

impl Frame {
    /// Raw byte size of this frame before encoding (3 channels, 1 byte per
    /// channel as a camera would capture).
    pub fn raw_bytes(&self) -> usize {
        self.res * self.res * 3
    }
}

/// Sample the object population for one frame from the scene state.
pub fn sample_objects(state: &SceneState, rng: &mut Pcg32) -> Vec<Obj> {
    // Object count: clutter +- 1, at least 0, at most GRID*GRID/2.
    let base = state.clutter;
    let n = (base + rng.range(-1.0, 1.0)).round().max(0.0) as usize;
    let n = n.min(GRID * GRID / 2);
    let mut cells: Vec<usize> = (0..GRID * GRID).collect();
    rng.shuffle(&mut cells);
    let mut objs = Vec::with_capacity(n);
    for &cell in cells.iter().take(n) {
        let gy = cell / GRID;
        let gx = cell % GRID;
        let class = rng.weighted(&state.class_mix);
        let jitter = 0.25 / GRID as f32;
        let cx = (gx as f32 + 0.5) / GRID as f32 + rng.range(-jitter, jitter);
        let cy = (gy as f32 + 0.5) / GRID as f32 + rng.range(-jitter, jitter);
        let radius = state.obj_scale * rng.range(0.28, 0.44) / GRID as f32;
        objs.push(Obj {
            class,
            cx: cx.clamp(0.02, 0.98),
            cy: cy.clamp(0.02, 0.98),
            radius,
        });
    }
    objs
}

/// Sample unlabeled distractor shapes: background furniture (signage,
/// shadows, vegetation blobs) that shares geometry with real classes but is
/// NOT ground truth. Distractors are what keeps the detection task honest —
/// a student must learn appearance, not "any blob is an object".
pub fn sample_distractors(state: &SceneState, rng: &mut Pcg32) -> Vec<Obj> {
    let n = (state.clutter * 0.9 + rng.range(0.0, 1.5)) as usize;
    (0..n)
        .map(|_| Obj {
            class: rng.index(K),
            cx: rng.range(0.05, 0.95),
            cy: rng.range(0.05, 0.95),
            radius: state.obj_scale * rng.range(0.2, 0.45) / GRID as f32,
        })
        .collect()
}

/// Render a frame at `res` from `state`, deterministically in `seed`.
pub fn render(state: &SceneState, res: usize, seed: u64) -> Frame {
    let mut rng = Pcg32::new(seed, 11);
    // Per-frame exposure wobble: consecutive frames of the same scene are
    // not identical, so a student needs more data to generalise (and frame
    // rate genuinely buys information).
    let mut frame_state = state.clone();
    frame_state.illumination = (state.illumination * rng.range(0.82, 1.18)).clamp(0.2, 1.5);
    let objects = sample_objects(&frame_state, &mut rng);
    let distractors = sample_distractors(&frame_state, &mut rng);
    let pixels = rasterize(&frame_state, &objects, &distractors, res, seed);
    Frame {
        res,
        pixels,
        truth: GroundTruth { objects },
    }
}

/// Rasterize background + distractors + objects into an HWC buffer.
pub fn rasterize(
    state: &SceneState,
    objects: &[Obj],
    distractors: &[Obj],
    res: usize,
    seed: u64,
) -> Vec<f32> {
    let mut px = vec![0.0f32; res * res * 3];
    let noise_seed = (seed ^ 0x5eed_ba5e) as u32;
    let inv = 1.0 / res as f32;
    let rain_seed = (seed ^ 0x4a1d_5eed) as u32;
    for iy in 0..res {
        let y = (iy as f32 + 0.5) * inv;
        for ix in 0..res {
            let x = (ix as f32 + 0.5) * inv;
            // Background: palette * illumination, textured by value noise.
            let n = value_noise(
                x * state.texture_freq,
                y * state.texture_freq,
                noise_seed,
            );
            let tex = 1.0 + state.contrast * 0.6 * (n - 0.5);
            let mut c = [
                state.palette[0] * state.illumination * tex,
                state.palette[1] * state.illumination * tex,
                state.palette[2] * state.illumination * tex,
            ];
            // Rain: darken + vertical streaks.
            if state.rain > 0.0 {
                let streak = value_noise(x * 40.0, y * 4.0, rain_seed);
                let wet = 1.0 - 0.35 * state.rain;
                for ch in &mut c {
                    *ch *= wet;
                }
                if streak > 1.0 - 0.15 * state.rain {
                    for ch in &mut c {
                        *ch = (*ch + 0.25).min(1.0);
                    }
                }
            }
            // Distractors first (under real objects): class-shaped and
            // class-coloured but dimmer/washed-out — the false-positive bait
            // that keeps the task from saturating. Only brightness and a
            // palette wash distinguish them from real objects.
            for (di, d) in distractors.iter().enumerate() {
                if d.contains(x, y) {
                    let base = shifted_color(CLASS_COLORS[d.class], state.hue_shift);
                    let lum = state.obj_brightness * (0.6 + 0.4 * state.illumination);
                    // Brightness range overlaps the real objects' (0.72-1.18)
                    // so the task has irreducible ambiguity at the margin.
                    let dim = 0.55 + 0.08 * ((di % 5) as f32);
                    for ch in 0..3 {
                        let ghost = base[ch] * lum * dim + state.palette[ch] * 0.2;
                        c[ch] = c[ch] * 0.2 + ghost * 0.8;
                    }
                }
            }
            // Real objects (topmost last), with deterministic per-object
            // brightness variation.
            for o in objects {
                if o.contains(x, y) {
                    let base = shifted_color(CLASS_COLORS[o.class], state.hue_shift);
                    let ob = 0.72
                        + 0.46
                            * hash2(
                                (o.cx * 4096.0) as i32,
                                (o.cy * 4096.0) as i32,
                                noise_seed ^ 0xb0b,
                            );
                    let lum = ob * state.obj_brightness * (0.6 + 0.4 * state.illumination);
                    let blur = if state.rain > 0.5 { 0.75 } else { 1.0 };
                    for ch in 0..3 {
                        c[ch] = c[ch] * (1.0 - blur) + base[ch] * lum * blur;
                    }
                }
            }
            // Sensor noise: a floor plus a dark-scene term (tunnel/rain
            // drift is genuinely harder, as for real cameras at night).
            let noise_std = 0.025 + 0.06 * (1.0 - state.illumination).max(0.0);
            let off = (iy * res + ix) * 3;
            for ch in 0..3 {
                let n = noise_std * gauss_hash(ix as u32, iy as u32, ch as u32, noise_seed);
                px[off + ch] = (c[ch] + n).clamp(0.0, 1.0);
            }
        }
    }
    px
}

/// Object colour under an appearance shift: rotates RGB towards the
/// channel-permuted colour as `hue_shift` grows (sodium lamps, white
/// balance, new liveries). At shift 1.0 the colour is fully permuted, so a
/// class's colour identity is completely remapped.
#[inline]
pub fn shifted_color(base: [f32; 3], hue_shift: f32) -> [f32; 3] {
    let rot = [base[1], base[2], base[0]];
    [
        base[0] + (rot[0] - base[0]) * hue_shift,
        base[1] + (rot[1] - base[1]) * hue_shift,
        base[2] + (rot[2] - base[2]) * hue_shift,
    ]
}

/// Cheap deterministic approximately-gaussian noise in ~[-2.2, 2.2]:
/// sum of three independent uniforms, centred (Irwin-Hall n=3).
#[inline]
fn gauss_hash(ix: u32, iy: u32, ch: u32, seed: u32) -> f32 {
    let mut acc = 0.0f32;
    for s in 0..3u32 {
        acc += hash2(
            (ix.wrapping_mul(3).wrapping_add(s)) as i32,
            (iy.wrapping_mul(5).wrapping_add(ch)) as i32,
            seed.wrapping_add(s.wrapping_mul(0x9e37)),
        );
    }
    (acc - 1.5) * 2.0
}

#[inline]
fn hash2(ix: i32, iy: i32, seed: u32) -> f32 {
    let mut h = (ix as u32).wrapping_mul(0x85eb_ca6b)
        ^ (iy as u32).wrapping_mul(0xc2b2_ae35)
        ^ seed.wrapping_mul(0x27d4_eb2f);
    h ^= h >> 15;
    h = h.wrapping_mul(0x2c1b_3c6d);
    h ^= h >> 12;
    h = h.wrapping_mul(0x297a_2d39);
    h ^= h >> 15;
    (h & 0x00ff_ffff) as f32 / 16_777_216.0
}

/// Bilinear value noise in [0,1].
pub fn value_noise(x: f32, y: f32, seed: u32) -> f32 {
    let ix = x.floor() as i32;
    let iy = y.floor() as i32;
    let fx = x - ix as f32;
    let fy = y - iy as f32;
    let sx = fx * fx * (3.0 - 2.0 * fx);
    let sy = fy * fy * (3.0 - 2.0 * fy);
    let v00 = hash2(ix, iy, seed);
    let v10 = hash2(ix + 1, iy, seed);
    let v01 = hash2(ix, iy + 1, seed);
    let v11 = hash2(ix + 1, iy + 1, seed);
    let a = v00 + (v10 - v00) * sx;
    let b = v01 + (v11 - v01) * sx;
    a + (b - a) * sy
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::drift::SceneState;

    #[test]
    fn render_deterministic() {
        let s = SceneState::default_day();
        let a = render(&s, 32, 99);
        let b = render(&s, 32, 99);
        assert_eq!(a.pixels, b.pixels);
        assert_eq!(a.truth.objects.len(), b.truth.objects.len());
    }

    #[test]
    fn different_seeds_differ() {
        let s = SceneState::default_day();
        let a = render(&s, 32, 1);
        let b = render(&s, 32, 2);
        assert_ne!(a.pixels, b.pixels);
    }

    #[test]
    fn pixels_in_unit_range() {
        let s = SceneState::default_day();
        let f = render(&s, 48, 5);
        assert_eq!(f.pixels.len(), 48 * 48 * 3);
        assert!(f.pixels.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn illumination_changes_brightness() {
        let mut bright = SceneState::default_day();
        bright.illumination = 1.3;
        let mut dark = bright.clone();
        dark.illumination = 0.3;
        let fb = rasterize(&bright, &[], &[], 32, 7);
        let fd = rasterize(&dark, &[], &[], 32, 7);
        let mb: f32 = fb.iter().sum::<f32>() / fb.len() as f32;
        let md: f32 = fd.iter().sum::<f32>() / fd.len() as f32;
        assert!(mb > md * 1.8, "bright {mb} vs dark {md}");
    }

    #[test]
    fn objects_visible_in_pixels() {
        let s = SceneState::default_day();
        let obj = Obj {
            class: 0,
            cx: 0.5,
            cy: 0.5,
            radius: 0.12,
        };
        let with = rasterize(&s, std::slice::from_ref(&obj), &[], 32, 7);
        let without = rasterize(&s, &[], &[], 32, 7);
        let diff: f32 = with
            .iter()
            .zip(&without)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1.0, "object did not change pixels: {diff}");
    }

    #[test]
    fn det_grids_mark_object_cells() {
        let truth = GroundTruth {
            objects: vec![
                Obj { class: 2, cx: 0.1, cy: 0.1, radius: 0.05 },
                Obj { class: 1, cx: 0.9, cy: 0.6, radius: 0.05 },
            ],
        };
        let (obj, cls) = truth.det_grids();
        assert_eq!(obj[0][0], 1.0);
        assert_eq!(cls[0][0], 2);
        assert_eq!(obj[2][3], 1.0);
        assert_eq!(cls[2][3], 1);
        let total: f32 = obj.iter().flatten().sum();
        assert_eq!(total, 2.0);
    }

    #[test]
    fn mask_grid_covers_object() {
        let truth = GroundTruth {
            objects: vec![Obj { class: 1, cx: 0.5, cy: 0.5, radius: 0.2 }],
        };
        let mask = truth.mask_grid(8);
        assert_eq!(mask[4 * 8 + 4], 1, "centre cell must be class 1");
        assert_eq!(mask[0], K, "corner must be background");
        let covered = mask.iter().filter(|&&m| m == 1).count();
        assert!(covered >= 4, "disc should cover several cells: {covered}");
    }

    #[test]
    fn class_mix_biases_sampling() {
        let mut s = SceneState::default_day();
        s.class_mix = [4.0, 0.02, 0.02, 0.02];
        s.clutter = 4.0;
        let mut rng = Pcg32::seeded(1);
        let mut counts = [0usize; K];
        for _ in 0..200 {
            for o in sample_objects(&s, &mut rng) {
                counts[o.class] += 1;
            }
        }
        assert!(
            counts[0] > 10 * (counts[1] + 1),
            "class 0 should dominate: {counts:?}"
        );
    }

    #[test]
    fn value_noise_smooth_and_bounded() {
        for i in 0..100 {
            let v = value_noise(i as f32 * 0.13, i as f32 * 0.07, 9);
            assert!((0.0..=1.0).contains(&v));
        }
        // Smoothness: adjacent samples close.
        let a = value_noise(1.50, 2.50, 9);
        let b = value_noise(1.51, 2.50, 9);
        assert!((a - b).abs() < 0.1);
    }
}

//! Prebuilt worlds for the paper's experiments.
//!
//! Each builder returns a [`World`] plus the experiment-relevant structure
//! (which cameras are truly correlated), so experiment runners and tests
//! can validate behaviour against ground truth.

use super::drift::{DriftEvent, DriftProcess, SceneState, Zone};
use super::{offset_seed, Camera, Mount, World, ZoneMap};

/// Default OU volatility for ambient drift: high enough that the
/// distribution keeps moving within an experiment, so sustained accuracy
/// requires sustained retraining throughput (the paper's operating regime).
pub const AMBIENT_VOL: f32 = 0.04;

/// A scenario: a world plus ground-truth correlation structure.
pub struct Scenario {
    pub world: World,
    /// Ground-truth grouping: `groups[g]` lists camera ids that share a
    /// region (and therefore drift together).
    pub groups: Vec<Vec<usize>>,
}

impl Scenario {
    /// k-nearest-neighbor pruning graph over this scenario's camera
    /// placement (see [`crate::grouping::topology`]): each camera links to
    /// its `degree` closest peers by mount position. `degree >= n - 1`
    /// yields the complete graph, i.e. all-pairs grouping.
    pub fn topology(&self, degree: usize) -> crate::grouping::topology::Topology {
        let positions: Vec<(f32, f32)> = self.world.cameras.iter().map(|c| c.pos).collect();
        crate::grouping::topology::Topology::from_positions(&positions, degree)
    }
}

/// N static cameras split into correlated groups; `cams_per_group[i]`
/// cameras share region `i`. All groups get a synchronized drift event at
/// `drift_at` seconds (each region gets its own flavour so groups remain
/// mutually distinct).
pub fn grouped_static(
    cams_per_group: &[usize],
    offset_scale: f32,
    drift_at: f64,
    seed: u64,
) -> Scenario {
    let mut regions = Vec::new();
    let mut cameras = Vec::new();
    let mut groups = Vec::new();
    let mut id = 0;
    for (g, &n) in cams_per_group.iter().enumerate() {
        regions.push(DriftProcess::new(
            SceneState::default_day().with_offset(seed ^ (g as u64 * 7 + 1), 0.25),
            AMBIENT_VOL,
            seed.wrapping_add(g as u64 * 131),
        ));
        let mut members = Vec::new();
        for i in 0..n {
            // Intersections are geographically separated (inter-group
            // distance >= 0.3) while co-located cameras sit within ~0.16,
            // so Alg. 2's location filter can actually discriminate.
            cameras.push(Camera {
                id,
                region: g,
                pos: (
                    0.1 + 0.3 * (g % 3) as f32,
                    0.12 + 0.3 * (g / 3) as f32 + 0.08 * i as f32,
                ),
                mount: Mount::StaticHigh,
                offset_seed: offset_seed(seed, id),
                offset_scale,
            });
            members.push(id);
            id += 1;
        }
        groups.push(members);
    }
    let mut world = World::new(regions, ZoneMap::uniform(Zone::Suburban), cameras);
    if drift_at >= 0.0 {
        // Each region gets a composite drift: an appearance remap (the
        // component that truly breaks the student) plus a region-specific
        // environmental change.
        let mut events = Vec::new();
        for g in 0..cams_per_group.len() {
            let hue = 0.5 + 0.12 * ((g % 4) as f32);
            events.push((drift_at, g, DriftEvent::Appearance(hue)));
            let env = match g % 4 {
                0 => DriftEvent::Rain(0.85),
                1 => DriftEvent::Lighting(0.4),
                2 => DriftEvent::Palette([0.66, 0.48, 0.3]),
                _ => DriftEvent::ClassShift([2.4, 0.2, 1.8, 0.2]),
            };
            events.push((drift_at, g, env));
        }
        world.schedule(events);
    }
    Scenario { world, groups }
}

/// The Fig. 2(c) motivation scenario: three mobile cameras "flying in
/// formation" (one shared region, small offsets), drift event at t=0+eps.
pub fn convoy(n: usize, seed: u64) -> Scenario {
    let region = DriftProcess::new(SceneState::default_day(), AMBIENT_VOL, seed);
    let waypoints = vec![(0.05, 0.5), (0.95, 0.5)];
    let cameras = (0..n)
        .map(|id| Camera {
            id,
            region: 0,
            pos: waypoints[0],
            mount: Mount::Mobile {
                waypoints: waypoints.clone(),
                speed: 0.001,
            },
            offset_seed: offset_seed(seed, id),
            offset_scale: 0.06,
        })
        .collect();
    let map = ZoneMap {
        cells: vec![vec![Zone::Suburban, Zone::Suburban, Zone::Urban, Zone::Urban]],
    };
    let mut world = World::new(vec![region], map, cameras);
    world.schedule(vec![
        (1.0, 0, DriftEvent::Appearance(0.55)),
        (1.0, 0, DriftEvent::Palette([0.6, 0.45, 0.3])),
    ]);
    Scenario {
        world,
        groups: vec![(0..n).collect()],
    }
}

/// Fig. 8 similarity scenario: three groups of three cameras each at
/// high / medium / low similarity, rain event at `drift_at`.
/// Returns (scenario, group names).
pub fn similarity_triads(drift_at: f64, seed: u64) -> (Scenario, Vec<&'static str>) {
    // Build three regions; camera triads differ in offset scale AND in how
    // far apart their regions sit (low similarity = distinct regions).
    let mut regions = Vec::new();
    let mut cameras = Vec::new();
    let mut groups = Vec::new();
    let specs: [(&str, f32, bool); 3] = [
        ("high", 0.04, false),  // shared region, tiny offsets
        ("medium", 0.28, false), // shared region, medium offsets
        ("low", 0.12, true),    // three DIFFERENT regions
    ];
    let mut id = 0;
    for (g, (_, offset, distinct_regions)) in specs.iter().enumerate() {
        let mut members = Vec::new();
        if *distinct_regions {
            for i in 0..3 {
                let ridx = regions.len();
                // Visually similar starting points (small offsets) that will
                // drift to CONFLICTING appearance mappings: the shared model
                // cannot disambiguate by background context, which is what
                // makes low-similarity grouping genuinely unprofitable.
                regions.push(DriftProcess::new(
                    SceneState::default_day()
                        .with_offset(seed ^ (0xd00d + g as u64 * 31 + i as u64), 0.3),
                    AMBIENT_VOL,
                    seed.wrapping_add(900 + g as u64 * 13 + i as u64),
                ));
                cameras.push(Camera {
                    id,
                    region: ridx,
                    pos: (0.06 * id as f32, 0.8),
                    mount: Mount::StaticHigh,
                    offset_seed: offset_seed(seed, id),
                    offset_scale: *offset,
                });
                members.push(id);
                id += 1;
            }
        } else {
            let ridx = regions.len();
            regions.push(DriftProcess::new(
                SceneState::default_day().with_offset(seed ^ (g as u64 + 5), 0.2),
                AMBIENT_VOL,
                seed.wrapping_add(g as u64 * 17),
            ));
            for _ in 0..3 {
                cameras.push(Camera {
                    id,
                    region: ridx,
                    pos: (0.06 * id as f32, 0.2),
                    mount: Mount::StaticHigh,
                    offset_seed: offset_seed(seed, id),
                    offset_scale: *offset,
                });
                members.push(id);
                id += 1;
            }
        }
        groups.push(members);
    }
    let n_regions = regions.len();
    let mut world = World::new(regions, ZoneMap::uniform(Zone::Suburban), cameras);
    // Weather (rain) hits the whole area; but the appearance response is
    // scene-specific: shared-region triads drift identically, while the
    // low-similarity triad's three distinct scenes drift to DIFFERENT
    // appearance points (different materials/liveries under the same
    // weather) — so one shared model must fit conflicting mappings.
    let mut weather: Vec<(f64, usize, DriftEvent)> = Vec::new();
    for r in 0..n_regions {
        weather.push((drift_at, r, DriftEvent::Rain(0.85)));
        let hue = if r < 2 { 0.5 } else { 0.2 + 0.35 * (r - 2) as f32 };
        weather.push((drift_at, r, DriftEvent::Appearance(hue)));
        if r >= 2 {
            let mixes = [
                [2.5, 0.2, 1.5, 0.2],
                [0.2, 2.5, 0.2, 1.5],
                [1.5, 0.2, 0.2, 2.5],
            ];
            weather.push((drift_at, r, DriftEvent::ClassShift(mixes[(r - 2) % 3])));
        }
    }
    world.schedule(weather);
    (
        Scenario {
            world,
            groups,
        },
        specs.iter().map(|(n, _, _)| *n).collect(),
    )
}

/// Fig. 9 dynamic-grouping scenario: three mobile cameras drive
/// suburban -> urban together; at `split_t`, camera `split_cam` diverges
/// into a tunnel zone while the others continue on the city road.
pub fn route_split(split_cam: usize, split_t: f64, seed: u64) -> Scenario {
    let map = ZoneMap {
        cells: vec![
            // Row 0: the city road (suburban then urban).
            vec![Zone::Suburban, Zone::Suburban, Zone::Urban, Zone::Urban],
            // Row 1: the tunnel branch.
            vec![Zone::Suburban, Zone::Tunnel, Zone::Tunnel, Zone::Tunnel],
        ],
    };
    let region = DriftProcess::new(SceneState::default_day(), AMBIENT_VOL, seed);
    let speed = 0.0025f32;
    let cameras = (0..3)
        .map(|id| {
            // All start on the road; the split camera's waypoints dip into
            // row 1 (the tunnel) at split progress.
            let split_x = ((speed as f64 * split_t) as f32).clamp(0.1, 0.8);
            let waypoints = if id == split_cam {
                // Turn off the road at the split point and descend into the
                // tunnel row of the zone map.
                vec![
                    (0.05, 0.25),
                    (split_x, 0.25),
                    (split_x, 0.75),
                    (0.95, 0.75),
                ]
            } else {
                vec![(0.05, 0.25), (0.95, 0.25)]
            };
            Camera {
                id,
                region: 0,
                pos: (0.05, 0.25),
                mount: Mount::Mobile {
                    waypoints,
                    speed,
                },
                offset_seed: offset_seed(seed, id),
                offset_scale: 0.05,
            }
        })
        .collect();
    let world = World::new(vec![region], map, cameras);
    Scenario {
        world,
        groups: vec![vec![0, 1, 2]],
    }
}

/// Fig. 10 allocator scenario: two groups — three co-located drones plus one
/// distant loner — hit by the SAME drift flavour at t≈0 (so per-model
/// learning dynamics are comparable and the allocator is the only variable).
pub fn three_plus_one(seed: u64) -> Scenario {
    let mut sc = grouped_static(&[3, 1], 0.06, -1.0, seed);
    let mut events = Vec::new();
    for r in 0..2 {
        events.push((1.0, r, DriftEvent::Appearance(0.5)));
        events.push((1.0, r, DriftEvent::Rain(0.85)));
    }
    sc.world.schedule(events);
    sc
}

/// Fig. 7 scalability scenario: `n` static cameras spread over a town with
/// one region per intersection (pairs of cameras share a region), all hit
/// by a city-wide lighting + weather change.
pub fn town(n: usize, seed: u64) -> Scenario {
    let per_region = 2;
    let n_regions = n.div_ceil(per_region);
    let sizes: Vec<usize> = (0..n_regions)
        .map(|r| per_region.min(n - r * per_region))
        .collect();
    grouped_static(&sizes, 0.07, 1.0, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grouped_static_structure() {
        let s = grouped_static(&[3, 2, 1], 0.1, 5.0, 42);
        assert_eq!(s.world.cameras.len(), 6);
        assert_eq!(s.groups, vec![vec![0, 1, 2], vec![3, 4], vec![5]]);
        assert_eq!(s.world.regions.len(), 3);
    }

    #[test]
    fn intra_group_more_similar_than_inter() {
        let mut s = grouped_static(&[3, 3], 0.06, 1.0, 7);
        s.world.advance(30.0);
        let d_intra = s.world.camera_state(0).distance(&s.world.camera_state(1));
        let d_inter = s.world.camera_state(0).distance(&s.world.camera_state(3));
        assert!(
            d_intra < d_inter,
            "intra {d_intra} should be < inter {d_inter}"
        );
    }

    #[test]
    fn similarity_triads_ordering() {
        let (mut s, names) = similarity_triads(1.0, 11);
        assert_eq!(names, vec!["high", "medium", "low"]);
        s.world.advance(30.0);
        let mean_intra = |ids: &[usize]| {
            let mut total = 0.0;
            let mut cnt = 0;
            for (i, &a) in ids.iter().enumerate() {
                for &b in ids.iter().skip(i + 1) {
                    total += s.world.camera_state(a).distance(&s.world.camera_state(b));
                    cnt += 1;
                }
            }
            total / cnt as f32
        };
        let hi = mean_intra(&s.groups[0]);
        let md = mean_intra(&s.groups[1]);
        let lo = mean_intra(&s.groups[2]);
        assert!(hi < md, "high {hi} !< medium {md}");
        assert!(md < lo, "medium {md} !< low {lo}");
    }

    #[test]
    fn route_split_diverges_after_split() {
        let mut s = route_split(2, 300.0, 3);
        s.world.advance(100.0);
        let early = s.world.camera_state(2).distance(&s.world.camera_state(0));
        s.world.advance(400.0); // past the split
        let late = s.world.camera_state(2).distance(&s.world.camera_state(0));
        assert!(
            late > early + 0.2,
            "cam 2 should diverge: early {early}, late {late}"
        );
        // The two cameras on the road stay close.
        let road = s.world.camera_state(0).distance(&s.world.camera_state(1));
        assert!(road < late * 0.7, "road pair {road} vs split {late}");
    }

    #[test]
    fn town_scales() {
        let s = town(22, 9);
        assert_eq!(s.world.cameras.len(), 22);
        assert_eq!(s.groups.iter().map(|g| g.len()).sum::<usize>(), 22);
    }

    #[test]
    fn scenario_topology_degree_bounds() {
        let s = town(10, 4);
        let pruned = s.topology(3);
        assert_eq!(pruned.n_cams(), 10);
        for cam in 0..10 {
            assert!(!pruned.neighbors(cam).is_empty());
        }
        // degree n-1 reproduces the complete graph.
        let full = s.topology(9);
        for cam in 0..10 {
            assert_eq!(full.neighbors(cam).len(), 9);
        }
    }

    #[test]
    fn convoy_shares_one_region() {
        let s = convoy(3, 1);
        assert!(s.world.cameras.iter().all(|c| c.region == 0));
    }
}

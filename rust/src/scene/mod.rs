//! Scene simulator: the dataset substrate.
//!
//! Replaces the paper's CityFlow / MDOT / CARLA footage with a synthetic
//! world (see DESIGN.md §2 for the substitution argument): regions own
//! drift processes, cameras (static or mobile) observe a region's state
//! plus a per-camera offset, and [`render`] turns a state into pixels +
//! ground truth. Mobile cameras traverse a zone map, so their appearance
//! distribution changes with position — the Fig. 9 route-divergence
//! scenario falls out of camera trajectories.

pub mod drift;
pub mod render;
pub mod scenario;

pub use drift::{DriftEvent, DriftProcess, SceneState, Zone, GRID, K};
pub use render::{render, Frame, GroundTruth, Obj};

use crate::util::rng::Pcg32;

/// Camera mount type: governs both motion and appearance characteristics.
#[derive(Debug, Clone)]
pub enum Mount {
    /// High pole/roof mount: small, distant objects (traffic cameras).
    StaticHigh,
    /// Low mount: larger objects.
    StaticLow,
    /// Vehicle/drone mount following waypoints (normalised map coords);
    /// scene content shifts quickly with motion.
    Mobile {
        waypoints: Vec<(f32, f32)>,
        /// Map units per second.
        speed: f32,
    },
}

/// A camera in the world.
#[derive(Debug, Clone)]
pub struct Camera {
    pub id: usize,
    /// Region whose drift process this camera observes.
    pub region: usize,
    /// Static position, or starting point for mobile cameras.
    pub pos: (f32, f32),
    pub mount: Mount,
    /// Seed of the fixed per-camera appearance offset.
    pub offset_seed: u64,
    /// Magnitude of that offset: 0 = identical to region state. This is the
    /// similarity knob (Fig. 8).
    pub offset_scale: f32,
}

impl Camera {
    /// Position at time `t` (static cameras never move).
    pub fn position(&self, t: f64) -> (f32, f32) {
        match &self.mount {
            Mount::StaticHigh | Mount::StaticLow => self.pos,
            Mount::Mobile { waypoints, speed } => {
                if waypoints.len() < 2 {
                    return self.pos;
                }
                let mut remaining = (*speed as f64 * t) as f32;
                let mut prev = waypoints[0];
                for &next in &waypoints[1..] {
                    let seg = ((next.0 - prev.0).powi(2) + (next.1 - prev.1).powi(2)).sqrt();
                    if remaining <= seg || seg == 0.0 {
                        let w = if seg == 0.0 { 0.0 } else { remaining / seg };
                        return (prev.0 + (next.0 - prev.0) * w, prev.1 + (next.1 - prev.1) * w);
                    }
                    remaining -= seg;
                    prev = next;
                }
                *waypoints.last().unwrap()
            }
        }
    }

    fn mount_state(&self, mut state: SceneState) -> SceneState {
        match self.mount {
            Mount::StaticHigh => {
                // High mounts see small, distant objects: resolution matters.
                state.obj_scale *= 0.55;
                state.clutter *= 1.2;
            }
            Mount::StaticLow => {}
            Mount::Mobile { .. } => {
                // Mobile mounts see nearer, larger objects.
                state.obj_scale *= 1.15;
            }
        }
        state.clamp();
        state
    }
}

/// A rectangular zone map for mobile scenarios (normalised [0,1)^2 coords).
#[derive(Debug, Clone)]
pub struct ZoneMap {
    pub cells: Vec<Vec<Zone>>,
}

impl ZoneMap {
    pub fn uniform(zone: Zone) -> ZoneMap {
        ZoneMap {
            cells: vec![vec![zone]],
        }
    }

    /// Zone at a normalised position.
    pub fn zone_at(&self, pos: (f32, f32)) -> Zone {
        let rows = self.cells.len();
        let cols = self.cells[0].len();
        let iy = ((pos.1.clamp(0.0, 0.999)) * rows as f32) as usize;
        let ix = ((pos.0.clamp(0.0, 0.999)) * cols as f32) as usize;
        self.cells[iy.min(rows - 1)][ix.min(cols - 1)]
    }
}

/// The simulated world: regions (drift processes), a zone map, cameras,
/// and a schedule of drift events.
pub struct World {
    pub regions: Vec<DriftProcess>,
    pub map: ZoneMap,
    pub cameras: Vec<Camera>,
    /// (time, region, event), sorted by time; applied during [`advance`].
    pub events: Vec<(f64, usize, DriftEvent)>,
    pub time: f64,
    next_event: usize,
    frame_counter: u64,
}

impl World {
    pub fn new(regions: Vec<DriftProcess>, map: ZoneMap, cameras: Vec<Camera>) -> World {
        World {
            regions,
            map,
            cameras,
            events: Vec::new(),
            time: 0.0,
            next_event: 0,
            frame_counter: 0,
        }
    }

    /// Schedule events (must be called before advancing past their times).
    pub fn schedule(&mut self, mut events: Vec<(f64, usize, DriftEvent)>) {
        self.events.append(&mut events);
        self.events.sort_by(|a, b| a.0.total_cmp(&b.0));
        self.next_event = self
            .events
            .iter()
            .position(|(t, _, _)| *t >= self.time)
            .unwrap_or(self.events.len());
    }

    /// Advance simulated time by `dt` seconds, stepping drift processes and
    /// firing due events.
    pub fn advance(&mut self, dt: f64) {
        let target = self.time + dt;
        while self.next_event < self.events.len() && self.events[self.next_event].0 <= target {
            let (t, region, event) = self.events[self.next_event].clone();
            // Step processes up to the event time first.
            let step = t - self.time;
            if step > 0.0 {
                for r in &mut self.regions {
                    r.step(step);
                }
                self.time = t;
            }
            self.regions[region].apply(&event);
            self.next_event += 1;
        }
        let step = target - self.time;
        if step > 0.0 {
            for r in &mut self.regions {
                r.step(step);
            }
        }
        self.time = target;
    }

    /// The effective appearance distribution camera `cam` observes *now*.
    pub fn camera_state(&self, cam: usize) -> SceneState {
        self.camera_state_at(cam, self.time)
    }

    /// The distribution camera `cam` observed at instant `t` (<= now).
    /// Region drift states are not rewound — they advance once per
    /// simulation step — but a mobile camera's position (and therefore its
    /// zone) is evaluated at `t`, so captures spread across a micro-window
    /// see the camera's motion rather than one frozen viewpoint.
    pub fn camera_state_at(&self, cam: usize, t: f64) -> SceneState {
        let camera = &self.cameras[cam];
        let mut state = self.regions[camera.region].state.clone();
        if let Mount::Mobile { .. } = camera.mount {
            // The zone under the camera sets the absolute operating point;
            // the region's drift delta composes on top (see compose_on).
            let zone = self.map.zone_at(camera.position(t));
            state = state.compose_on(&zone.base_state());
        }
        let state = camera.mount_state(state);
        state.with_offset(camera.offset_seed, camera.offset_scale)
    }

    /// Render one frame from camera `cam` at resolution `res`. Consecutive
    /// calls produce distinct frames (fresh object populations) from the
    /// current distribution.
    pub fn capture(&mut self, cam: usize, res: usize) -> Frame {
        self.capture_at(cam, res, self.time)
    }

    /// Render one frame observed at instant `t` (clamped to now). The
    /// server spreads a micro-window's deliveries across the window with
    /// this: both the frame seed and a mobile camera's viewpoint follow
    /// `t`, so high-fps plans buy distinct observations instead of
    /// duplicates of the window's final timestamp.
    pub fn capture_at(&mut self, cam: usize, res: usize, t: f64) -> Frame {
        let t = t.min(self.time);
        let state = self.camera_state_at(cam, t);
        self.frame_counter += 1;
        let seed = frame_seed(cam as u64, t, self.frame_counter);
        render(&state, res, seed)
    }

    /// Render an evaluation batch: `n` fresh frames from camera `cam`'s
    /// *current* distribution, seeded independently of training captures so
    /// eval data is held out.
    pub fn eval_frames(&self, cam: usize, res: usize, n: usize, salt: u64) -> Vec<Frame> {
        let state = self.camera_state(cam);
        (0..n)
            .map(|i| {
                let seed = frame_seed(cam as u64 ^ 0xe7a1, self.time, salt.wrapping_add(i as u64));
                render(&state, res, seed)
            })
            .collect()
    }
}

fn frame_seed(cam: u64, t: f64, counter: u64) -> u64 {
    let tq = (t * 10.0) as u64;
    let mut h = cam
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(tq.wrapping_mul(0xbf58_476d_1ce4_e5b9))
        .wrapping_add(counter.wrapping_mul(0x94d0_49bb_1331_11eb));
    h ^= h >> 31;
    h = h.wrapping_mul(0xd6e8_feb8_6659_fd93);
    h ^= h >> 29;
    h
}

/// Deterministic per-camera offset seed derived from a scenario seed.
pub fn offset_seed(scenario_seed: u64, cam: usize) -> u64 {
    let mut rng = Pcg32::new(scenario_seed, cam as u64 + 101);
    rng.next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_region_world(n_cams: usize, offset_scale: f32) -> World {
        let region = DriftProcess::new(SceneState::default_day(), 0.02, 5);
        let cameras = (0..n_cams)
            .map(|id| Camera {
                id,
                region: 0,
                pos: (0.5, 0.5),
                mount: Mount::StaticHigh,
                offset_seed: offset_seed(1, id),
                offset_scale,
            })
            .collect();
        World::new(vec![region], ZoneMap::uniform(Zone::Suburban), cameras)
    }

    #[test]
    fn colocated_cameras_correlate() {
        let mut w = one_region_world(3, 0.08);
        w.schedule(vec![(10.0, 0, DriftEvent::Rain(0.8))]);
        w.advance(20.0);
        let s0 = w.camera_state(0);
        let s1 = w.camera_state(1);
        // Both cameras must see the rain event.
        assert!(s0.rain > 0.5 && s1.rain > 0.5);
        assert!(s0.distance(&s1) < 0.6, "offsets too large");
    }

    #[test]
    fn offset_scale_controls_similarity() {
        let w_tight = one_region_world(2, 0.03);
        let w_loose = one_region_world(2, 0.9);
        let d_tight = w_tight.camera_state(0).distance(&w_tight.camera_state(1));
        let d_loose = w_loose.camera_state(0).distance(&w_loose.camera_state(1));
        assert!(d_tight < d_loose, "{d_tight} !< {d_loose}");
    }

    #[test]
    fn events_fire_in_order() {
        let mut w = one_region_world(1, 0.0);
        w.schedule(vec![
            (30.0, 0, DriftEvent::Lighting(0.5)),
            (10.0, 0, DriftEvent::Rain(1.0)),
        ]);
        w.advance(15.0);
        assert!(w.camera_state(0).rain > 0.6, "rain due at t=10");
        let illum_before = w.regions[0].anchor.illumination;
        w.advance(20.0);
        assert!(w.regions[0].anchor.illumination < illum_before);
    }

    #[test]
    fn mobile_camera_moves_and_changes_zone() {
        let map = ZoneMap {
            cells: vec![vec![Zone::Suburban, Zone::Urban]],
        };
        let region = DriftProcess::new(SceneState::default_day(), 0.0, 6);
        let cam = Camera {
            id: 0,
            region: 0,
            pos: (0.1, 0.5),
            mount: Mount::Mobile {
                waypoints: vec![(0.1, 0.5), (0.9, 0.5)],
                speed: 0.01,
            },
            offset_seed: 3,
            offset_scale: 0.0,
        };
        let mut w = World::new(vec![region], map, vec![cam]);
        let early = w.camera_state(0);
        w.advance(70.0); // moved 0.7 across the map: now in Urban half
        let late = w.camera_state(0);
        assert!(w.cameras[0].position(w.time).0 > 0.6);
        assert!(early.distance(&late) > 0.2, "zone change must shift state");
    }

    #[test]
    fn capture_produces_labelled_frames() {
        let mut w = one_region_world(1, 0.0);
        let f = w.capture(0, 32);
        assert_eq!(f.pixels.len(), 32 * 32 * 3);
        // Default clutter ~2 objects on average; over 20 frames some objects
        // must appear.
        let total: usize = (0..20).map(|_| w.capture(0, 32).truth.objects.len()).sum();
        assert!(total > 5);
    }

    #[test]
    fn eval_frames_are_heldout_and_fresh() {
        let mut w = one_region_world(1, 0.0);
        let train = w.capture(0, 32);
        let evals = w.eval_frames(0, 32, 4, 42);
        assert_eq!(evals.len(), 4);
        assert_ne!(evals[0].pixels, train.pixels);
        assert_ne!(evals[0].pixels, evals[1].pixels);
        // Same salt regenerates identical eval set (needed for fair A/B).
        let again = w.eval_frames(0, 32, 4, 42);
        assert_eq!(evals[0].pixels, again[0].pixels);
    }

    #[test]
    fn static_camera_never_moves() {
        let w = one_region_world(1, 0.0);
        assert_eq!(w.cameras[0].position(0.0), w.cameras[0].position(1e4));
    }

    #[test]
    fn spread_captures_observe_distinct_states_at_high_fps() {
        // Regression for the collect_data bug: all frames of a micro-window
        // used to be captured at the world's (single) post-advance
        // timestamp, so a mobile camera's whole delivery was one frozen
        // viewpoint. With capture instants spread across the micro-window,
        // the truth states must differ.
        let map = ZoneMap {
            cells: vec![vec![Zone::Suburban, Zone::Urban]],
        };
        let region = DriftProcess::new(SceneState::default_day(), 0.0, 6);
        let cam = Camera {
            id: 0,
            region: 0,
            pos: (0.0, 0.5),
            mount: Mount::Mobile {
                waypoints: vec![(0.0, 0.5), (1.0, 0.5)],
                speed: 0.05,
            },
            offset_seed: 3,
            offset_scale: 0.0,
        };
        let mut w = World::new(vec![region], map, vec![cam]);
        let mw_secs = 10.0;
        w.advance(mw_secs); // one micro-window: camera moved 0.5 across
        let n = 20;
        let states: Vec<SceneState> = (0..n)
            .map(|i| {
                let t = w.time - mw_secs + (i + 1) as f64 / n as f64 * mw_secs;
                w.camera_state_at(0, t)
            })
            .collect();
        assert!(
            states[0].distance(states.last().unwrap()) > 0.05,
            "spread captures must track the camera's motion"
        );
        // And the capture path itself tracks the instant: the first and
        // last capture instants sit in different zones, so the rendering
        // distributions differ. (Pixel inequality alone would be vacuous —
        // the per-capture frame counter already changes the seed — so the
        // guard is on the instant-derived states the captures render from.)
        let t_start = w.time - mw_secs + 0.5;
        let _f_start = w.capture_at(0, 32, t_start);
        let _f_end = w.capture_at(0, 32, w.time);
        assert!(
            w.camera_state_at(0, t_start).distance(&w.camera_state_at(0, w.time)) > 0.05,
            "capture instants must map to distinct distributions"
        );
        // All frames at the SAME instant share a distribution (sanity):
        let s_same_a = w.camera_state_at(0, w.time);
        let s_same_b = w.camera_state_at(0, w.time);
        assert!(s_same_a.distance(&s_same_b) < 1e-6);
    }
}

//! Latent scene state and its drift dynamics.
//!
//! Every camera's appearance distribution is governed by a [`SceneState`]:
//! illumination, background palette/texture, weather, object-class mix,
//! object scale and clutter. Data drift — the phenomenon ECCO exists to
//! handle — is a trajectory through this state space: a slow
//! Ornstein-Uhlenbeck wander plus discrete [`DriftEvent`]s (rain onset,
//! lighting shifts, zone transitions).
//!
//! Cameras in the same *region* share one drift process (spatially
//! correlated drift); each camera adds a small fixed offset so co-located
//! cameras are similar but not identical. The offset magnitude is the
//! similarity knob used by the Fig. 8 experiment.

use crate::util::rng::Pcg32;

/// Number of object classes (matches python/compile/model.py K).
pub const K: usize = 4;
/// Detection grid (matches model GRID).
pub const GRID: usize = 4;

/// The latent appearance distribution of a scene at an instant.
#[derive(Debug, Clone, PartialEq)]
pub struct SceneState {
    /// Global light level, ~0.25 (night) .. 1.4 (noon).
    pub illumination: f32,
    /// Background base colour (RGB, 0..1).
    pub palette: [f32; 3],
    /// Spatial frequency of background texture (1..8).
    pub texture_freq: f32,
    /// Texture contrast (0..1).
    pub contrast: f32,
    /// Rain intensity (0..1): darkens scene, adds streaks, blurs objects.
    pub rain: f32,
    /// Relative frequency of each object class (unnormalised, >= 0).
    pub class_mix: [f32; K],
    /// Object size relative to a grid cell (0.4..1.4).
    pub obj_scale: f32,
    /// Expected number of objects per frame (0.5..4).
    pub clutter: f32,
    /// Object brightness multiplier (0.4..1.5).
    pub obj_brightness: f32,
    /// Appearance shift in [0,1]: rotates object colours (sodium lighting,
    /// sensor white-balance, novel object liveries). This is the drift
    /// component that directly invalidates a student's class-colour
    /// associations — the "new data patterns" axis of the paper's drift.
    pub hue_shift: f32,
}

impl SceneState {
    /// A neutral daytime suburban scene — the distribution the students are
    /// pre-trained on.
    pub fn default_day() -> SceneState {
        SceneState {
            illumination: 1.0,
            palette: [0.45, 0.5, 0.42],
            texture_freq: 3.0,
            contrast: 0.5,
            rain: 0.0,
            class_mix: [1.0, 1.0, 1.0, 1.0],
            obj_scale: 1.0,
            clutter: 2.2,
            obj_brightness: 1.0,
            hue_shift: 0.0,
        }
    }

    /// Clamp every component into its physical range.
    pub fn clamp(&mut self) {
        self.illumination = self.illumination.clamp(0.25, 1.4);
        for c in &mut self.palette {
            *c = c.clamp(0.05, 0.95);
        }
        self.texture_freq = self.texture_freq.clamp(1.0, 8.0);
        self.contrast = self.contrast.clamp(0.05, 1.0);
        self.rain = self.rain.clamp(0.0, 1.0);
        for m in &mut self.class_mix {
            *m = m.clamp(0.02, 4.0);
        }
        self.obj_scale = self.obj_scale.clamp(0.4, 1.4);
        self.clutter = self.clutter.clamp(0.5, 4.0);
        self.obj_brightness = self.obj_brightness.clamp(0.4, 1.5);
        self.hue_shift = self.hue_shift.clamp(0.0, 1.0);
    }

    /// Weighted distance between two states — the "true" drift magnitude
    /// (used by tests and as ground truth when validating grouping).
    pub fn distance(&self, other: &SceneState) -> f32 {
        let mut d = 0.0f32;
        d += 2.0 * (self.illumination - other.illumination).powi(2);
        for i in 0..3 {
            d += 2.0 * (self.palette[i] - other.palette[i]).powi(2);
        }
        d += 0.05 * (self.texture_freq - other.texture_freq).powi(2);
        d += (self.contrast - other.contrast).powi(2);
        d += 2.0 * (self.rain - other.rain).powi(2);
        for i in 0..K {
            d += 0.25 * (self.class_mix[i] - other.class_mix[i]).powi(2);
        }
        d += (self.obj_scale - other.obj_scale).powi(2);
        d += 0.1 * (self.clutter - other.clutter).powi(2);
        d += (self.obj_brightness - other.obj_brightness).powi(2);
        d += 3.0 * (self.hue_shift - other.hue_shift).powi(2);
        d.sqrt()
    }

    /// Blend two states: `self*(1-w) + other*w`.
    pub fn blend(&self, other: &SceneState, w: f32) -> SceneState {
        let lerp = |a: f32, b: f32| a + (b - a) * w;
        let mut out = SceneState {
            illumination: lerp(self.illumination, other.illumination),
            palette: [
                lerp(self.palette[0], other.palette[0]),
                lerp(self.palette[1], other.palette[1]),
                lerp(self.palette[2], other.palette[2]),
            ],
            texture_freq: lerp(self.texture_freq, other.texture_freq),
            contrast: lerp(self.contrast, other.contrast),
            rain: lerp(self.rain, other.rain),
            class_mix: [
                lerp(self.class_mix[0], other.class_mix[0]),
                lerp(self.class_mix[1], other.class_mix[1]),
                lerp(self.class_mix[2], other.class_mix[2]),
                lerp(self.class_mix[3], other.class_mix[3]),
            ],
            obj_scale: lerp(self.obj_scale, other.obj_scale),
            clutter: lerp(self.clutter, other.clutter),
            obj_brightness: lerp(self.obj_brightness, other.obj_brightness),
            hue_shift: lerp(self.hue_shift, other.hue_shift),
        };
        out.clamp();
        out
    }

    /// Compose this (region drift) state on top of a zone base: the zone
    /// provides the absolute appearance, and this state's *delta from the
    /// default day* rides on top. With a Suburban zone (== default day) the
    /// result is exactly this state, so static and mobile cameras share
    /// drift semantics; entering a tunnel shifts the whole operating point
    /// while region-wide events (rain, appearance shifts) still apply fully.
    pub fn compose_on(&self, zone_base: &SceneState) -> SceneState {
        let d = SceneState::default_day();
        let add = |z: f32, s: f32, r: f32| z + (s - r);
        let mut out = SceneState {
            illumination: add(zone_base.illumination, self.illumination, d.illumination),
            palette: [
                add(zone_base.palette[0], self.palette[0], d.palette[0]),
                add(zone_base.palette[1], self.palette[1], d.palette[1]),
                add(zone_base.palette[2], self.palette[2], d.palette[2]),
            ],
            texture_freq: add(zone_base.texture_freq, self.texture_freq, d.texture_freq),
            contrast: add(zone_base.contrast, self.contrast, d.contrast),
            rain: add(zone_base.rain, self.rain, d.rain),
            class_mix: [
                add(zone_base.class_mix[0], self.class_mix[0], d.class_mix[0]),
                add(zone_base.class_mix[1], self.class_mix[1], d.class_mix[1]),
                add(zone_base.class_mix[2], self.class_mix[2], d.class_mix[2]),
                add(zone_base.class_mix[3], self.class_mix[3], d.class_mix[3]),
            ],
            obj_scale: add(zone_base.obj_scale, self.obj_scale, d.obj_scale),
            clutter: add(zone_base.clutter, self.clutter, d.clutter),
            obj_brightness: add(zone_base.obj_brightness, self.obj_brightness, d.obj_brightness),
            hue_shift: add(zone_base.hue_shift, self.hue_shift, d.hue_shift),
        };
        out.clamp();
        out
    }

    /// Apply a deterministic per-camera perturbation of magnitude `scale`.
    pub fn with_offset(&self, seed: u64, scale: f32) -> SceneState {
        let mut rng = Pcg32::new(seed, 17);
        let mut s = self.clone();
        s.illumination += scale * 0.15 * rng.normal();
        for c in &mut s.palette {
            *c += scale * 0.08 * rng.normal();
        }
        s.texture_freq += scale * 0.8 * rng.normal();
        s.contrast += scale * 0.1 * rng.normal();
        for m in &mut s.class_mix {
            *m += scale * 0.4 * rng.normal();
        }
        s.obj_scale += scale * 0.12 * rng.normal();
        s.clutter += scale * 0.5 * rng.normal();
        s.obj_brightness += scale * 0.12 * rng.normal();
        s.hue_shift += scale * 0.08 * rng.normal().abs();
        s.clamp();
        s
    }
}

/// A discrete drift event applied to a region's state.
#[derive(Debug, Clone)]
pub enum DriftEvent {
    /// Sudden rain with the given intensity (Fig. 8's weather drift).
    Rain(f32),
    /// Lighting change (e.g. dusk): multiplies illumination.
    Lighting(f32),
    /// Background palette shift towards a target colour.
    Palette([f32; 3]),
    /// Object class mix replacement (e.g. trucks appear).
    ClassShift([f32; K]),
    /// Composite urban transition (palette+texture+clutter), Fig. 9 style.
    ZoneChange(Zone),
    /// Object appearance shift (colour remap) of the given strength.
    Appearance(f32),
}

/// Zone archetypes for the region map (mobile-camera scenarios).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Zone {
    Suburban,
    Urban,
    Tunnel,
    Park,
    Highway,
}

impl Zone {
    /// The base appearance of each zone archetype.
    pub fn base_state(self) -> SceneState {
        let mut s = SceneState::default_day();
        match self {
            Zone::Suburban => {}
            Zone::Urban => {
                s.palette = [0.55, 0.55, 0.6];
                s.texture_freq = 6.0;
                s.contrast = 0.75;
                s.clutter = 3.2;
                s.obj_scale = 0.8;
                s.hue_shift = 0.3; // different vehicle liveries downtown
                s.class_mix = [1.6, 0.7, 1.3, 0.4];
            }
            Zone::Tunnel => {
                s.illumination = 0.28;
                s.palette = [0.25, 0.22, 0.2];
                s.texture_freq = 1.5;
                s.contrast = 0.25;
                s.clutter = 1.2;
                s.obj_brightness = 0.5;
                s.hue_shift = 0.8; // sodium tunnel lighting remaps colours
                s.class_mix = [1.2, 1.2, 0.3, 0.3];
            }
            Zone::Park => {
                s.palette = [0.3, 0.6, 0.3];
                s.texture_freq = 4.5;
                s.contrast = 0.6;
                s.clutter = 1.5;
                s.class_mix = [0.4, 1.8, 0.6, 1.2];
            }
            Zone::Highway => {
                s.palette = [0.5, 0.5, 0.52];
                s.texture_freq = 2.0;
                s.clutter = 2.8;
                s.obj_scale = 1.1;
                s.class_mix = [2.0, 0.4, 1.2, 0.6];
            }
        }
        s
    }
}

/// Ornstein-Uhlenbeck drift around an anchor state plus event jumps.
#[derive(Debug, Clone)]
pub struct DriftProcess {
    /// Current state.
    pub state: SceneState,
    /// Anchor the OU process reverts towards (events move the anchor).
    pub anchor: SceneState,
    /// Wander volatility (per sqrt-second).
    pub volatility: f32,
    /// Mean-reversion rate (per second).
    pub reversion: f32,
    rng: Pcg32,
}

impl DriftProcess {
    pub fn new(initial: SceneState, volatility: f32, seed: u64) -> DriftProcess {
        DriftProcess {
            anchor: initial.clone(),
            state: initial,
            volatility,
            reversion: 0.02,
            rng: Pcg32::new(seed, 3),
        }
    }

    /// Advance the process by `dt` seconds.
    pub fn step(&mut self, dt: f64) {
        let dt = dt as f32;
        let sq = dt.sqrt() * self.volatility;
        let rv = self.reversion * dt;
        let mut n = |s: &mut f32, a: f32, w: f32| {
            *s += (a - *s) * rv + sq * w * self.rng.normal();
        };
        let anchor = self.anchor.clone();
        n(&mut self.state.illumination, anchor.illumination, 0.06);
        for i in 0..3 {
            n(&mut self.state.palette[i], anchor.palette[i], 0.03);
        }
        n(&mut self.state.texture_freq, anchor.texture_freq, 0.25);
        n(&mut self.state.contrast, anchor.contrast, 0.04);
        n(&mut self.state.rain, anchor.rain, 0.02);
        for i in 0..K {
            n(&mut self.state.class_mix[i], anchor.class_mix[i], 0.12);
        }
        n(&mut self.state.obj_scale, anchor.obj_scale, 0.04);
        n(&mut self.state.clutter, anchor.clutter, 0.15);
        n(&mut self.state.obj_brightness, anchor.obj_brightness, 0.04);
        n(&mut self.state.hue_shift, anchor.hue_shift, 0.03);
        self.state.clamp();
    }

    /// Apply an event: moves both anchor and current state (a jump the OU
    /// wander then orbits).
    pub fn apply(&mut self, event: &DriftEvent) {
        match event {
            DriftEvent::Rain(intensity) => {
                self.anchor.rain = *intensity;
                self.state.rain = *intensity;
                self.anchor.illumination *= 1.0 - 0.35 * intensity;
                self.state.illumination *= 1.0 - 0.35 * intensity;
                self.anchor.contrast *= 1.0 - 0.3 * intensity;
                self.state.contrast *= 1.0 - 0.3 * intensity;
            }
            DriftEvent::Lighting(mult) => {
                self.anchor.illumination *= mult;
                self.state.illumination *= mult;
                self.anchor.obj_brightness *= mult.sqrt();
                self.state.obj_brightness *= mult.sqrt();
            }
            DriftEvent::Palette(target) => {
                self.anchor.palette = *target;
                self.state.palette = *target;
            }
            DriftEvent::ClassShift(mix) => {
                self.anchor.class_mix = *mix;
                self.state.class_mix = *mix;
            }
            DriftEvent::Appearance(h) => {
                self.anchor.hue_shift = *h;
                self.state.hue_shift = *h;
            }
            DriftEvent::ZoneChange(zone) => {
                let base = zone.base_state();
                self.anchor = base.clone();
                // The visible state snaps most of the way (drive into a
                // tunnel: the change is fast), keeping a trace of history.
                self.state = self.state.blend(&base, 0.85);
            }
        }
        self.anchor.clamp();
        self.state.clamp();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_state_within_ranges() {
        let mut s = SceneState::default_day();
        let before = s.clone();
        s.clamp();
        assert_eq!(s, before, "default state must already be in range");
    }

    #[test]
    fn distance_zero_iff_same() {
        let s = SceneState::default_day();
        assert_eq!(s.distance(&s), 0.0);
        let mut t = s.clone();
        t.illumination = 0.5;
        assert!(s.distance(&t) > 0.1);
    }

    #[test]
    fn blend_endpoints() {
        let a = SceneState::default_day();
        let b = Zone::Tunnel.base_state();
        assert!(a.blend(&b, 0.0).distance(&a) < 1e-6);
        assert!(a.blend(&b, 1.0).distance(&b) < 1e-6);
        let mid = a.blend(&b, 0.5);
        assert!(mid.distance(&a) > 0.0 && mid.distance(&b) > 0.0);
    }

    #[test]
    fn offsets_deterministic_and_scaled() {
        let s = SceneState::default_day();
        let a = s.with_offset(42, 0.2);
        let b = s.with_offset(42, 0.2);
        assert!(a.distance(&b) < 1e-6);
        let small = s.distance(&s.with_offset(7, 0.05));
        let large = s.distance(&s.with_offset(7, 0.8));
        assert!(small < large, "offset scale must grow distance: {small} vs {large}");
    }

    #[test]
    fn ou_wanders_but_reverts() {
        let mut p = DriftProcess::new(SceneState::default_day(), 0.05, 1);
        let anchor = p.anchor.clone();
        for _ in 0..600 {
            p.step(1.0);
        }
        // Should wander, but stay in the anchor's neighbourhood.
        let d = p.state.distance(&anchor);
        assert!(d > 0.0 && d < 1.5, "drifted too far or not at all: {d}");
    }

    #[test]
    fn rain_event_darkens() {
        let mut p = DriftProcess::new(SceneState::default_day(), 0.01, 2);
        let before = p.state.illumination;
        p.apply(&DriftEvent::Rain(0.9));
        assert!(p.state.rain > 0.8);
        assert!(p.state.illumination < before);
    }

    #[test]
    fn zone_change_moves_towards_base() {
        let mut p = DriftProcess::new(SceneState::default_day(), 0.01, 3);
        let tunnel = Zone::Tunnel.base_state();
        let before = p.state.distance(&tunnel);
        p.apply(&DriftEvent::ZoneChange(Zone::Tunnel));
        let after = p.state.distance(&tunnel);
        assert!(after < before * 0.5, "{after} !< {before}");
    }

    #[test]
    fn zones_are_mutually_distant() {
        let zones = [Zone::Suburban, Zone::Urban, Zone::Tunnel, Zone::Park];
        for (i, a) in zones.iter().enumerate() {
            for b in zones.iter().skip(i + 1) {
                assert!(
                    a.base_state().distance(&b.base_state()) > 0.3,
                    "{a:?} vs {b:?} too similar"
                );
            }
        }
    }
}

//! `ecco::faults` — deterministic fault injection for the camera fleet.
//!
//! A [`FaultPlan`] is a seeded, pre-computed schedule of [`FaultEvent`]s,
//! each pinned to a `(window, micro-window, camera)` coordinate. The
//! coordinator applies due events at micro-window boundaries, so a plan
//! perturbs the run at exactly the same simulated instants regardless of
//! thread count — fault runs inherit the same byte-identical determinism
//! contract as healthy runs.
//!
//! What can fail, and the degradation guarantee per layer:
//!
//! * **Camera dropout / rejoin** ([`FaultKind::CameraDown`] /
//!   [`FaultKind::CameraUp`]): the coordinator detaches the camera from
//!   its job without stalling the group; if the dropout empties the job,
//!   the model is *parked* instead of lost, and a rejoining camera
//!   resumes from it, then re-enters placement through the normal
//!   drift-probe path.
//! * **Uplink outage / degradation** ([`FaultKind::UplinkDown`],
//!   [`FaultKind::UplinkScale`], [`FaultKind::UplinkRestore`]):
//!   `net::NetSim` takes the link down or rescales its capacity; the
//!   camera keeps serving its last good model until a window boundary
//!   after restoration publishes a fresh one.
//! * **Stragglers** ([`FaultKind::StragglerWindow`]): probe and frame
//!   delivery arrive after the micro-window closes — probes count as
//!   lost (bounded retry/backoff), delivered bits are wasted.
//! * **Corrupted probes** ([`FaultKind::CorruptProbe`]): NaN or zeroed
//!   embeddings are detected by [`embedding_valid`] and discarded at
//!   every consumer (drift detection, placement, zoo signatures) so they
//!   can never poison references, dynamics estimates, or the model zoo.
//!
//! The hard zero-cost rule: with [`FaultPlan::none`] attached (the
//! default), the coordinator's fault checks all collapse to cold
//! always-false branches, no extra events are emitted, and no RNG is
//! consumed — event logs stay byte-identical to a build without the
//! subsystem. `rust/tests/faults.rs` pins this A/B.

use crate::util::rng::Pcg32;

/// How a corrupted probe embedding manifests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorruptMode {
    /// Every channel is NaN (a poisoned reduction upstream).
    Nan,
    /// Every channel is zero (a truncated/empty payload).
    Zero,
}

/// One kind of injectable fault. All kinds are idempotent at the
/// application site: re-applying a state a camera is already in is a
/// no-op, so hand-built plans cannot corrupt the runtime bookkeeping.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The camera process dies: detached from its job, no probes, no
    /// frames, no model publishes until [`FaultKind::CameraUp`].
    CameraDown,
    /// The camera rejoins the fleet and re-enters placement through the
    /// normal drift-probe path.
    CameraUp,
    /// The camera's uplink goes fully dark (capacity 0).
    UplinkDown,
    /// The camera's uplink capacity is rescaled by `factor` in `(0, 1)`.
    UplinkScale {
        /// Multiplier on the healthy capacity, clamped to `[0, 1]`.
        factor: f64,
    },
    /// The camera's uplink returns to full capacity.
    UplinkRestore,
    /// For the rest of this window, the camera's probe and frame
    /// delivery land after the micro-window closes.
    StragglerWindow,
    /// For the rest of this window, the camera's probe embeddings are
    /// corrupted.
    CorruptProbe {
        /// How the corruption manifests.
        mode: CorruptMode,
    },
}

/// One scheduled fault: `kind` strikes camera `cam` at the boundary of
/// micro-window `mw` of retraining window `window`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Retraining window index the event fires in.
    pub window: usize,
    /// Micro-window boundary within the window (0 = window start).
    pub mw: usize,
    /// Target camera index.
    pub cam: usize,
    /// What happens.
    pub kind: FaultKind,
}

/// Built-in fault intensity presets for [`FaultPlan::scenario`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultScenario {
    /// Occasional churn: a camera flap every few windows, a transient
    /// capacity dip, a rare straggler.
    Light,
    /// Dense churn: every window flaps ≥30% of the fleet, takes one
    /// uplink fully dark, and throws in a straggler plus a corrupted
    /// probe. The chaos-smoke preset.
    Heavy,
}

/// A deterministic, time-sorted schedule of fault events.
///
/// Events are kept sorted by `(window, mw)`; insertion order breaks
/// ties, so a recovery scheduled while generating window `w` applies
/// before a new fault inserted later at the same coordinate.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan: guaranteed zero-cost (see module docs).
    pub fn none() -> Self {
        FaultPlan { events: Vec::new() }
    }

    /// True when no events are scheduled.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Number of scheduled events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// The `i`-th event in schedule order.
    pub fn get(&self, i: usize) -> Option<&FaultEvent> {
        self.events.get(i)
    }

    /// Iterate events in schedule order.
    pub fn iter(&self) -> impl Iterator<Item = &FaultEvent> {
        self.events.iter()
    }

    /// Highest camera index any event targets (validated against the
    /// fleet size at the `RunSpec` boundary).
    pub fn max_cam(&self) -> Option<usize> {
        self.events.iter().map(|e| e.cam).max()
    }

    /// Insert an event, keeping the schedule sorted by `(window, mw)`
    /// with stable (insertion-order) tie-breaking.
    pub fn push(&mut self, ev: FaultEvent) {
        let at = self
            .events
            .partition_point(|e| (e.window, e.mw) <= (ev.window, ev.mw));
        self.events.insert(at, ev);
    }

    /// Builder-style [`FaultPlan::push`].
    pub fn at(mut self, window: usize, mw: usize, cam: usize, kind: FaultKind) -> Self {
        self.push(FaultEvent {
            window,
            mw,
            cam,
            kind,
        });
        self
    }

    /// Generate a preset plan for `n_cams` cameras over `windows`
    /// retraining windows. Generation draws only from a plan-local
    /// [`Pcg32`] — it never touches the run's RNG, so attaching a plan
    /// perturbs the simulation exclusively through the scheduled events.
    pub fn scenario(preset: FaultScenario, n_cams: usize, windows: usize, seed: u64) -> Self {
        let mut plan = FaultPlan::none();
        if n_cams == 0 || windows == 0 {
            return plan;
        }
        let mut rng = Pcg32::new(seed, 0xfa17);
        match preset {
            FaultScenario::Light => {
                for w in 0..windows {
                    if w % 3 == 0 {
                        let cam = rng.index(n_cams);
                        plan.push(FaultEvent {
                            window: w,
                            mw: 0,
                            cam,
                            kind: FaultKind::CameraDown,
                        });
                        if w + 1 < windows {
                            plan.push(FaultEvent {
                                window: w + 1,
                                mw: 0,
                                cam,
                                kind: FaultKind::CameraUp,
                            });
                        }
                    }
                    if w % 2 == 1 {
                        let cam = rng.index(n_cams);
                        plan.push(FaultEvent {
                            window: w,
                            mw: 0,
                            cam,
                            kind: FaultKind::UplinkScale { factor: 0.5 },
                        });
                        if w + 1 < windows {
                            plan.push(FaultEvent {
                                window: w + 1,
                                mw: 0,
                                cam,
                                kind: FaultKind::UplinkRestore,
                            });
                        }
                    }
                    if rng.chance(0.25) {
                        plan.push(FaultEvent {
                            window: w,
                            mw: 0,
                            cam: rng.index(n_cams),
                            kind: FaultKind::StragglerWindow,
                        });
                    }
                }
            }
            FaultScenario::Heavy => {
                // ceil(0.3 * n_cams), at least one: the "≥30% flapping"
                // density guarantee.
                let flappers = (3 * n_cams).div_ceil(10).max(1);
                for w in 0..windows {
                    let mut order: Vec<usize> = (0..n_cams).collect();
                    rng.shuffle(&mut order);
                    for &cam in order.iter().take(flappers) {
                        let mw = rng.index(2);
                        plan.push(FaultEvent {
                            window: w,
                            mw,
                            cam,
                            kind: FaultKind::CameraDown,
                        });
                        if w + 1 < windows {
                            // The rejoin sorts before any window-(w+1)
                            // re-flap of the same camera (stable ties).
                            plan.push(FaultEvent {
                                window: w + 1,
                                mw: 0,
                                cam,
                                kind: FaultKind::CameraUp,
                            });
                        }
                    }
                    // Exactly one full uplink outage per window.
                    let victim = rng.index(n_cams);
                    plan.push(FaultEvent {
                        window: w,
                        mw: 0,
                        cam: victim,
                        kind: FaultKind::UplinkDown,
                    });
                    if w + 1 < windows {
                        plan.push(FaultEvent {
                            window: w + 1,
                            mw: 0,
                            cam: victim,
                            kind: FaultKind::UplinkRestore,
                        });
                    }
                    plan.push(FaultEvent {
                        window: w,
                        mw: 0,
                        cam: rng.index(n_cams),
                        kind: FaultKind::StragglerWindow,
                    });
                    let mode = if w % 2 == 0 {
                        CorruptMode::Nan
                    } else {
                        CorruptMode::Zero
                    };
                    plan.push(FaultEvent {
                        window: w,
                        mw: 0,
                        cam: rng.index(n_cams),
                        kind: FaultKind::CorruptProbe { mode },
                    });
                }
            }
        }
        plan
    }
}

/// A usable probe embedding: finite everywhere and not the all-zero
/// vector. Genuine embeddings always pass — `runtime::native::features`
/// includes per-channel std terms of at least `sqrt(1e-6)` before unit
/// normalization, so a real embedding can never be all-zero — which
/// makes this check free on healthy runs and exact on
/// [`CorruptMode::Zero`] corruption.
pub fn embedding_valid(emb: &[f32]) -> bool {
    !emb.is_empty()
        && emb.iter().all(|v| v.is_finite())
        && emb.iter().any(|&v| v != 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_empty_and_zero_cost_shaped() {
        let p = FaultPlan::none();
        assert!(p.is_empty());
        assert_eq!(p.len(), 0);
        assert_eq!(p.max_cam(), None);
        assert_eq!(p, FaultPlan::default());
    }

    #[test]
    fn push_keeps_schedule_sorted_with_stable_ties() {
        let p = FaultPlan::none()
            .at(2, 0, 0, FaultKind::CameraDown)
            .at(0, 1, 1, FaultKind::UplinkDown)
            .at(0, 0, 2, FaultKind::StragglerWindow)
            // Same coordinate as the first event: must sort after it.
            .at(2, 0, 3, FaultKind::CameraUp);
        let order: Vec<(usize, usize, usize)> =
            p.iter().map(|e| (e.window, e.mw, e.cam)).collect();
        assert_eq!(order, vec![(0, 0, 2), (0, 1, 1), (2, 0, 0), (2, 0, 3)]);
    }

    #[test]
    fn scenario_is_deterministic_in_seed() {
        let a = FaultPlan::scenario(FaultScenario::Heavy, 8, 6, 42);
        let b = FaultPlan::scenario(FaultScenario::Heavy, 8, 6, 42);
        let c = FaultPlan::scenario(FaultScenario::Heavy, 8, 6, 43);
        assert_eq!(a, b);
        assert_ne!(a, c, "different seeds must change the plan");
    }

    #[test]
    fn heavy_preset_meets_density_guarantees() {
        let n_cams = 10;
        let windows = 5;
        let p = FaultPlan::scenario(FaultScenario::Heavy, n_cams, windows, 7);
        for w in 0..windows {
            let downs = p
                .iter()
                .filter(|e| e.window == w && e.kind == FaultKind::CameraDown)
                .count();
            assert!(
                downs * 10 >= 3 * n_cams,
                "window {w}: only {downs} dropouts for {n_cams} cams"
            );
            let outages = p
                .iter()
                .filter(|e| e.window == w && e.kind == FaultKind::UplinkDown)
                .count();
            assert_eq!(outages, 1, "window {w}: exactly one uplink outage");
        }
        // Every dropout before the last window is paired with a rejoin.
        for ev in p.iter().filter(|e| e.kind == FaultKind::CameraDown) {
            if ev.window + 1 < windows {
                assert!(
                    p.iter().any(|r| r.kind == FaultKind::CameraUp
                        && r.cam == ev.cam
                        && r.window == ev.window + 1),
                    "dropout of cam {} in window {} has no rejoin",
                    ev.cam,
                    ev.window
                );
            }
        }
        assert!(p.max_cam().unwrap() < n_cams);
    }

    #[test]
    fn scenario_handles_degenerate_sizes() {
        assert!(FaultPlan::scenario(FaultScenario::Heavy, 0, 5, 1).is_empty());
        assert!(FaultPlan::scenario(FaultScenario::Light, 4, 0, 1).is_empty());
        let one = FaultPlan::scenario(FaultScenario::Heavy, 1, 3, 1);
        assert!(!one.is_empty());
        assert_eq!(one.max_cam(), Some(0));
    }

    #[test]
    fn embedding_validity_detects_corruption_modes() {
        assert!(embedding_valid(&[0.1, -0.2, 0.3]));
        assert!(!embedding_valid(&[]));
        assert!(!embedding_valid(&[0.1, f32::NAN, 0.3]));
        assert!(!embedding_valid(&[0.1, f32::INFINITY, 0.3]));
        assert!(!embedding_valid(&[0.0, 0.0, 0.0]));
        // A single live channel is enough (real embeddings are unit-norm).
        assert!(embedding_valid(&[0.0, 1.0, 0.0]));
    }
}

//! `ecco::faults` — deterministic fault injection for the camera fleet.
//!
//! A [`FaultPlan`] is a seeded, pre-computed schedule of [`FaultEvent`]s,
//! each pinned to a `(window, micro-window, camera)` coordinate. The
//! coordinator applies due events at micro-window boundaries, so a plan
//! perturbs the run at exactly the same simulated instants regardless of
//! thread count — fault runs inherit the same byte-identical determinism
//! contract as healthy runs.
//!
//! What can fail, and the degradation guarantee per layer:
//!
//! * **Camera dropout / rejoin** ([`FaultKind::CameraDown`] /
//!   [`FaultKind::CameraUp`]): the coordinator detaches the camera from
//!   its job without stalling the group; if the dropout empties the job,
//!   the model is *parked* instead of lost, and a rejoining camera
//!   resumes from it, then re-enters placement through the normal
//!   drift-probe path.
//! * **Uplink outage / degradation** ([`FaultKind::UplinkDown`],
//!   [`FaultKind::UplinkScale`], [`FaultKind::UplinkRestore`]):
//!   `net::NetSim` takes the link down or rescales its capacity; the
//!   camera keeps serving its last good model until a window boundary
//!   after restoration publishes a fresh one.
//! * **Stragglers** ([`FaultKind::StragglerWindow`]): probe and frame
//!   delivery arrive after the micro-window closes — probes count as
//!   lost (bounded retry/backoff), delivered bits are wasted.
//! * **Corrupted probes** ([`FaultKind::CorruptProbe`]): NaN or zeroed
//!   embeddings are detected by [`embedding_valid`] and discarded at
//!   every consumer (drift detection, placement, zoo signatures) so they
//!   can never poison references, dynamics estimates, or the model zoo.
//!
//! The hard zero-cost rule: with [`FaultPlan::none`] attached (the
//! default), the coordinator's fault checks all collapse to cold
//! always-false branches, no extra events are emitted, and no RNG is
//! consumed — event logs stay byte-identical to a build without the
//! subsystem. `rust/tests/faults.rs` pins this A/B.

use crate::util::json::{arr, num, obj, s, Json};
use crate::util::rng::Pcg32;

/// How a corrupted probe embedding manifests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorruptMode {
    /// Every channel is NaN (a poisoned reduction upstream).
    Nan,
    /// Every channel is zero (a truncated/empty payload).
    Zero,
}

/// One kind of injectable fault. All kinds are idempotent at the
/// application site: re-applying a state a camera is already in is a
/// no-op, so hand-built plans cannot corrupt the runtime bookkeeping.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The camera process dies: detached from its job, no probes, no
    /// frames, no model publishes until [`FaultKind::CameraUp`].
    CameraDown,
    /// The camera rejoins the fleet and re-enters placement through the
    /// normal drift-probe path.
    CameraUp,
    /// The camera's uplink goes fully dark (capacity 0).
    UplinkDown,
    /// The camera's uplink capacity is rescaled by `factor` in `(0, 1)`.
    UplinkScale {
        /// Multiplier on the healthy capacity, clamped to `[0, 1]`.
        factor: f64,
    },
    /// The camera's uplink returns to full capacity.
    UplinkRestore,
    /// For the rest of this window, the camera's probe and frame
    /// delivery land after the micro-window closes.
    StragglerWindow,
    /// For the rest of this window, the camera's probe embeddings are
    /// corrupted.
    CorruptProbe {
        /// How the corruption manifests.
        mode: CorruptMode,
    },
}

/// One scheduled fault: `kind` strikes camera `cam` at the boundary of
/// micro-window `mw` of retraining window `window`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Retraining window index the event fires in.
    pub window: usize,
    /// Micro-window boundary within the window (0 = window start).
    pub mw: usize,
    /// Target camera index.
    pub cam: usize,
    /// What happens.
    pub kind: FaultKind,
}

/// Built-in fault intensity presets for [`FaultPlan::scenario`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultScenario {
    /// Occasional churn: a camera flap every few windows, a transient
    /// capacity dip, a rare straggler.
    Light,
    /// Dense churn: every window flaps ≥30% of the fleet, takes one
    /// uplink fully dark, and throws in a straggler plus a corrupted
    /// probe. The chaos-smoke preset.
    Heavy,
}

/// A deterministic, time-sorted schedule of fault events.
///
/// Events are kept sorted by `(window, mw)`; insertion order breaks
/// ties, so a recovery scheduled while generating window `w` applies
/// before a new fault inserted later at the same coordinate.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan: guaranteed zero-cost (see module docs).
    pub fn none() -> Self {
        FaultPlan { events: Vec::new() }
    }

    /// True when no events are scheduled.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Number of scheduled events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// The `i`-th event in schedule order.
    pub fn get(&self, i: usize) -> Option<&FaultEvent> {
        self.events.get(i)
    }

    /// Iterate events in schedule order.
    pub fn iter(&self) -> impl Iterator<Item = &FaultEvent> {
        self.events.iter()
    }

    /// Highest camera index any event targets (validated against the
    /// fleet size at the `RunSpec` boundary).
    pub fn max_cam(&self) -> Option<usize> {
        self.events.iter().map(|e| e.cam).max()
    }

    /// Insert an event, keeping the schedule sorted by `(window, mw)`
    /// with stable (insertion-order) tie-breaking.
    pub fn push(&mut self, ev: FaultEvent) {
        let at = self
            .events
            .partition_point(|e| (e.window, e.mw) <= (ev.window, ev.mw));
        self.events.insert(at, ev);
    }

    /// Builder-style [`FaultPlan::push`].
    pub fn at(mut self, window: usize, mw: usize, cam: usize, kind: FaultKind) -> Self {
        self.push(FaultEvent {
            window,
            mw,
            cam,
            kind,
        });
        self
    }

    /// Generate a preset plan for `n_cams` cameras over `windows`
    /// retraining windows. Generation draws only from a plan-local
    /// [`Pcg32`] — it never touches the run's RNG, so attaching a plan
    /// perturbs the simulation exclusively through the scheduled events.
    pub fn scenario(preset: FaultScenario, n_cams: usize, windows: usize, seed: u64) -> Self {
        let mut plan = FaultPlan::none();
        if n_cams == 0 || windows == 0 {
            return plan;
        }
        let mut rng = Pcg32::new(seed, 0xfa17);
        match preset {
            FaultScenario::Light => {
                for w in 0..windows {
                    if w % 3 == 0 {
                        let cam = rng.index(n_cams);
                        plan.push(FaultEvent {
                            window: w,
                            mw: 0,
                            cam,
                            kind: FaultKind::CameraDown,
                        });
                        if w + 1 < windows {
                            plan.push(FaultEvent {
                                window: w + 1,
                                mw: 0,
                                cam,
                                kind: FaultKind::CameraUp,
                            });
                        }
                    }
                    if w % 2 == 1 {
                        let cam = rng.index(n_cams);
                        plan.push(FaultEvent {
                            window: w,
                            mw: 0,
                            cam,
                            kind: FaultKind::UplinkScale { factor: 0.5 },
                        });
                        if w + 1 < windows {
                            plan.push(FaultEvent {
                                window: w + 1,
                                mw: 0,
                                cam,
                                kind: FaultKind::UplinkRestore,
                            });
                        }
                    }
                    if rng.chance(0.25) {
                        plan.push(FaultEvent {
                            window: w,
                            mw: 0,
                            cam: rng.index(n_cams),
                            kind: FaultKind::StragglerWindow,
                        });
                    }
                }
            }
            FaultScenario::Heavy => {
                // ceil(0.3 * n_cams), at least one: the "≥30% flapping"
                // density guarantee.
                let flappers = (3 * n_cams).div_ceil(10).max(1);
                for w in 0..windows {
                    let mut order: Vec<usize> = (0..n_cams).collect();
                    rng.shuffle(&mut order);
                    for &cam in order.iter().take(flappers) {
                        let mw = rng.index(2);
                        plan.push(FaultEvent {
                            window: w,
                            mw,
                            cam,
                            kind: FaultKind::CameraDown,
                        });
                        if w + 1 < windows {
                            // The rejoin sorts before any window-(w+1)
                            // re-flap of the same camera (stable ties).
                            plan.push(FaultEvent {
                                window: w + 1,
                                mw: 0,
                                cam,
                                kind: FaultKind::CameraUp,
                            });
                        }
                    }
                    // Exactly one full uplink outage per window.
                    let victim = rng.index(n_cams);
                    plan.push(FaultEvent {
                        window: w,
                        mw: 0,
                        cam: victim,
                        kind: FaultKind::UplinkDown,
                    });
                    if w + 1 < windows {
                        plan.push(FaultEvent {
                            window: w + 1,
                            mw: 0,
                            cam: victim,
                            kind: FaultKind::UplinkRestore,
                        });
                    }
                    plan.push(FaultEvent {
                        window: w,
                        mw: 0,
                        cam: rng.index(n_cams),
                        kind: FaultKind::StragglerWindow,
                    });
                    let mode = if w % 2 == 0 {
                        CorruptMode::Nan
                    } else {
                        CorruptMode::Zero
                    };
                    plan.push(FaultEvent {
                        window: w,
                        mw: 0,
                        cam: rng.index(n_cams),
                        kind: FaultKind::CorruptProbe { mode },
                    });
                }
            }
        }
        plan
    }

    /// Wire representation: a JSON array of event objects in schedule
    /// order (the `ecco serve` protocol's `"faults"` field). Inverse of
    /// [`FaultPlan::from_json`]; round-trips any plan exactly.
    pub fn to_json(&self) -> Json {
        arr(self.events.iter().map(FaultEvent::to_json).collect())
    }

    /// Parse a wire plan (see [`FaultPlan::to_json`]). Events are
    /// re-inserted through [`FaultPlan::push`], so a hand-written
    /// out-of-order array still yields a valid sorted schedule.
    pub fn from_json(j: &Json) -> Result<FaultPlan, String> {
        let items = match j {
            Json::Arr(items) => items,
            _ => return Err("faults: expected an array of event objects".into()),
        };
        let mut plan = FaultPlan::none();
        for item in items {
            plan.push(FaultEvent::from_json(item)?);
        }
        Ok(plan)
    }
}

impl FaultEvent {
    /// Wire representation of one scheduled fault.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("window", num(self.window as f64)),
            ("mw", num(self.mw as f64)),
            ("cam", num(self.cam as f64)),
            ("kind", s(self.kind.name())),
        ];
        match self.kind {
            FaultKind::UplinkScale { factor } => fields.push(("factor", num(factor))),
            FaultKind::CorruptProbe { mode } => fields.push((
                "mode",
                s(match mode {
                    CorruptMode::Nan => "nan",
                    CorruptMode::Zero => "zero",
                }),
            )),
            _ => {}
        }
        obj(fields)
    }

    /// Parse one wire fault event; the error string names the bad field.
    pub fn from_json(j: &Json) -> Result<FaultEvent, String> {
        let geti = |key: &str| -> Result<usize, String> {
            j.get(key)
                .and_then(|v| v.as_usize())
                .map_err(|e| format!("fault event {key:?}: {e}"))
        };
        let window = geti("window")?;
        let mw = geti("mw")?;
        let cam = geti("cam")?;
        let kind_name = j
            .get("kind")
            .and_then(|v| v.as_str().map(str::to_string))
            .map_err(|e| format!("fault event \"kind\": {e}"))?;
        let kind = match kind_name.as_str() {
            "camera_down" => FaultKind::CameraDown,
            "camera_up" => FaultKind::CameraUp,
            "uplink_down" => FaultKind::UplinkDown,
            "uplink_restore" => FaultKind::UplinkRestore,
            "straggler_window" => FaultKind::StragglerWindow,
            "uplink_scale" => {
                let factor = j
                    .get("factor")
                    .and_then(|v| v.as_f64())
                    .map_err(|e| format!("uplink_scale \"factor\": {e}"))?;
                if !(factor.is_finite() && (0.0..=1.0).contains(&factor)) {
                    return Err(format!("uplink_scale factor {factor} must lie in [0, 1]"));
                }
                FaultKind::UplinkScale { factor }
            }
            "corrupt_probe" => {
                let mode = match j.get("mode").and_then(|v| v.as_str().map(str::to_string)) {
                    Ok(m) if m == "nan" => CorruptMode::Nan,
                    Ok(m) if m == "zero" => CorruptMode::Zero,
                    Ok(m) => return Err(format!("corrupt_probe mode {m:?} (use nan|zero)")),
                    Err(e) => return Err(format!("corrupt_probe \"mode\": {e}")),
                };
                FaultKind::CorruptProbe { mode }
            }
            other => return Err(format!("unknown fault kind {other:?}")),
        };
        Ok(FaultEvent {
            window,
            mw,
            cam,
            kind,
        })
    }
}

impl FaultKind {
    /// Stable machine-readable name (the wire `"kind"` discriminant).
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::CameraDown => "camera_down",
            FaultKind::CameraUp => "camera_up",
            FaultKind::UplinkDown => "uplink_down",
            FaultKind::UplinkScale { .. } => "uplink_scale",
            FaultKind::UplinkRestore => "uplink_restore",
            FaultKind::StragglerWindow => "straggler_window",
            FaultKind::CorruptProbe { .. } => "corrupt_probe",
        }
    }
}

/// A usable probe embedding: finite everywhere and not the all-zero
/// vector. Genuine embeddings always pass — `runtime::native::features`
/// includes per-channel std terms of at least `sqrt(1e-6)` before unit
/// normalization, so a real embedding can never be all-zero — which
/// makes this check free on healthy runs and exact on
/// [`CorruptMode::Zero`] corruption.
pub fn embedding_valid(emb: &[f32]) -> bool {
    !emb.is_empty()
        && emb.iter().all(|v| v.is_finite())
        && emb.iter().any(|&v| v != 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_empty_and_zero_cost_shaped() {
        let p = FaultPlan::none();
        assert!(p.is_empty());
        assert_eq!(p.len(), 0);
        assert_eq!(p.max_cam(), None);
        assert_eq!(p, FaultPlan::default());
    }

    #[test]
    fn push_keeps_schedule_sorted_with_stable_ties() {
        let p = FaultPlan::none()
            .at(2, 0, 0, FaultKind::CameraDown)
            .at(0, 1, 1, FaultKind::UplinkDown)
            .at(0, 0, 2, FaultKind::StragglerWindow)
            // Same coordinate as the first event: must sort after it.
            .at(2, 0, 3, FaultKind::CameraUp);
        let order: Vec<(usize, usize, usize)> =
            p.iter().map(|e| (e.window, e.mw, e.cam)).collect();
        assert_eq!(order, vec![(0, 0, 2), (0, 1, 1), (2, 0, 0), (2, 0, 3)]);
    }

    #[test]
    fn scenario_is_deterministic_in_seed() {
        let a = FaultPlan::scenario(FaultScenario::Heavy, 8, 6, 42);
        let b = FaultPlan::scenario(FaultScenario::Heavy, 8, 6, 42);
        let c = FaultPlan::scenario(FaultScenario::Heavy, 8, 6, 43);
        assert_eq!(a, b);
        assert_ne!(a, c, "different seeds must change the plan");
    }

    #[test]
    fn heavy_preset_meets_density_guarantees() {
        let n_cams = 10;
        let windows = 5;
        let p = FaultPlan::scenario(FaultScenario::Heavy, n_cams, windows, 7);
        for w in 0..windows {
            let downs = p
                .iter()
                .filter(|e| e.window == w && e.kind == FaultKind::CameraDown)
                .count();
            assert!(
                downs * 10 >= 3 * n_cams,
                "window {w}: only {downs} dropouts for {n_cams} cams"
            );
            let outages = p
                .iter()
                .filter(|e| e.window == w && e.kind == FaultKind::UplinkDown)
                .count();
            assert_eq!(outages, 1, "window {w}: exactly one uplink outage");
        }
        // Every dropout before the last window is paired with a rejoin.
        for ev in p.iter().filter(|e| e.kind == FaultKind::CameraDown) {
            if ev.window + 1 < windows {
                assert!(
                    p.iter().any(|r| r.kind == FaultKind::CameraUp
                        && r.cam == ev.cam
                        && r.window == ev.window + 1),
                    "dropout of cam {} in window {} has no rejoin",
                    ev.cam,
                    ev.window
                );
            }
        }
        assert!(p.max_cam().unwrap() < n_cams);
    }

    #[test]
    fn scenario_handles_degenerate_sizes() {
        assert!(FaultPlan::scenario(FaultScenario::Heavy, 0, 5, 1).is_empty());
        assert!(FaultPlan::scenario(FaultScenario::Light, 4, 0, 1).is_empty());
        let one = FaultPlan::scenario(FaultScenario::Heavy, 1, 3, 1);
        assert!(!one.is_empty());
        assert_eq!(one.max_cam(), Some(0));
    }

    #[test]
    fn embedding_validity_detects_corruption_modes() {
        assert!(embedding_valid(&[0.1, -0.2, 0.3]));
        assert!(!embedding_valid(&[]));
        assert!(!embedding_valid(&[0.1, f32::NAN, 0.3]));
        assert!(!embedding_valid(&[0.1, f32::INFINITY, 0.3]));
        assert!(!embedding_valid(&[0.0, 0.0, 0.0]));
        // A single live channel is enough (real embeddings are unit-norm).
        assert!(embedding_valid(&[0.0, 1.0, 0.0]));
    }

    #[test]
    fn wire_json_round_trips_every_kind() {
        let plan = FaultPlan::none()
            .at(0, 0, 1, FaultKind::CameraDown)
            .at(1, 0, 1, FaultKind::CameraUp)
            .at(1, 1, 2, FaultKind::UplinkDown)
            .at(2, 0, 2, FaultKind::UplinkRestore)
            .at(2, 1, 0, FaultKind::UplinkScale { factor: 0.25 })
            .at(3, 0, 3, FaultKind::StragglerWindow)
            .at(
                3,
                1,
                3,
                FaultKind::CorruptProbe {
                    mode: CorruptMode::Nan,
                },
            )
            .at(
                4,
                0,
                0,
                FaultKind::CorruptProbe {
                    mode: CorruptMode::Zero,
                },
            );
        let j = plan.to_json();
        let back = FaultPlan::from_json(&j).unwrap();
        assert_eq!(plan, back);
        // Text round trip too (the wire is JSONL text).
        let reparsed = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(FaultPlan::from_json(&reparsed).unwrap(), plan);
        // Heavy preset round-trips through its wire form unchanged.
        let heavy = FaultPlan::scenario(FaultScenario::Heavy, 6, 4, 11);
        assert_eq!(FaultPlan::from_json(&heavy.to_json()).unwrap(), heavy);
    }

    #[test]
    fn wire_json_rejects_malformed_events() {
        for bad in [
            r#"{"not":"an array"}"#,
            r#"[{"window":0,"mw":0,"cam":0}]"#,
            r#"[{"window":0,"mw":0,"cam":0,"kind":"explode"}]"#,
            r#"[{"window":-1,"mw":0,"cam":0,"kind":"camera_down"}]"#,
            r#"[{"window":0,"mw":0,"cam":0,"kind":"uplink_scale"}]"#,
            r#"[{"window":0,"mw":0,"cam":0,"kind":"uplink_scale","factor":1.5}]"#,
            r#"[{"window":0,"mw":0,"cam":0,"kind":"corrupt_probe","mode":"purple"}]"#,
            r#"[{"window":0.5,"mw":0,"cam":0,"kind":"camera_down"}]"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(FaultPlan::from_json(&j).is_err(), "accepted: {bad}");
        }
    }
}

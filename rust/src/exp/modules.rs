//! Fig. 10 (ECCO's GPU allocator vs RECL's) and Fig. 11 (transmission
//! controller ablation with per-group bandwidth traces).

use anyhow::Result;

use crate::alloc::AllocKind;
use crate::api::{RunSpec, Session};
use crate::runtime::{Engine, Task};
use crate::scene::scenario;
use crate::server::{Policy, TransmissionKind};
use crate::util::json::{arr, f32s, num, obj, s};

use super::common::{print_table, ExpContext};

/// Fig. 10: two fixed groups (3 cameras vs 1 camera); swap only the GPU
/// allocator; log per-group accuracy and the one-hot micro-window bars.
pub fn fig10(engine: &Engine, ctx: &ExpContext) -> Result<()> {
    let windows = ctx.windows(8);
    let mut json_runs = Vec::new();
    let mut summary = Vec::new();
    for alloc in [AllocKind::Ecco, AllocKind::Utility] {
        let name = match alloc {
            AllocKind::Ecco => "ecco-allocator",
            AllocKind::Utility => "recl-allocator",
            AllocKind::Uniform => unreachable!(),
        };
        let mut policy = Policy::ecco();
        policy.alloc = alloc;
        policy.name = name;
        // Low-noise gain estimates isolate the policy; finer micro-windows
        // than the default so the greedy phase (after the per-window
        // initial pass) dominates the allocation pattern.
        let spec = RunSpec::new(Task::Det, policy)
            .scenario(scenario::three_plus_one(ctx.seed))
            .gpus(1.0)
            .shared_mbps(12.0)
            .uplink_mbps(20.0)
            .windows(windows)
            .seed(ctx.seed)
            .configure(|cfg| {
                cfg.auto_request = false;
                cfg.auto_regroup = false;
                cfg.eval_frames = 32;
                cfg.micro_windows = 8;
            });
        let mut session = Session::new(engine, spec)?;
        let g1 = session.force_group(&[0, 1, 2])?;
        let _g2 = session.force_group(&[3])?;

        let mut acc_g1 = Vec::new();
        let mut acc_g2 = Vec::new();
        for _ in 0..windows {
            let w = session.step_window()?;
            acc_g1.push(w.cam_acc[..3].iter().sum::<f32>() / 3.0);
            acc_g2.push(w.cam_acc[3]);
        }
        // One-hot GPU bars: which job got each micro-window.
        let alloc_log = session.alloc_log();
        let bars: String = alloc_log
            .iter()
            .map(|&(_, _, job)| if job == g1 { '1' } else { '2' })
            .collect();
        let g1_share = alloc_log.iter().filter(|&&(_, _, j)| j == g1).count() as f32
            / alloc_log.len().max(1) as f32;
        let max_gap = acc_g1
            .iter()
            .zip(&acc_g2)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        ctx.line(format!(
            "\n[{name}] micro-window allocation (1=big group, 2=small): {bars}"
        ));
        ctx.line(format!(
            "[{name}] big-group GPU share {:.0}%, max inter-group accuracy gap {:.3}",
            g1_share * 100.0,
            max_gap
        ));
        summary.push(vec![
            name.to_string(),
            format!("{:.3}", acc_g1.last().copied().unwrap_or(0.0)),
            format!("{:.3}", acc_g2.last().copied().unwrap_or(0.0)),
            format!("{max_gap:.3}"),
            format!("{:.0}%", g1_share * 100.0),
        ]);
        json_runs.push(obj(vec![
            ("allocator", s(name)),
            ("acc_group1", f32s(&acc_g1)),
            ("acc_group2", f32s(&acc_g2)),
            ("bars", s(&bars)),
            ("max_gap", num(max_gap as f64)),
            ("g1_share", num(g1_share as f64)),
        ]));
    }
    print_table(
        ctx,
        "Fig 10: allocator comparison (groups of 3 vs 1 camera, 1 GPU)",
        &["allocator", "G1 final", "G2 final", "max gap", "G1 GPU%"],
        &summary,
    );
    ctx.line(
        "shape: paper shows RECL's allocator starving the small group (large gap), ECCO balanced",
    );
    ctx.save(
        "fig10",
        &obj(vec![("experiment", s("fig10")), ("runs", arr(json_runs))]),
    )?;
    Ok(())
}

/// Fig. 11: transmission-controller ablation. Left: accuracy vs shared
/// bandwidth; right: per-group bandwidth at 9 Mbps vs the GPU-proportional
/// target (group A's two cameras are uplink-capped at 1 Mbps).
pub fn fig11(engine: &Engine, ctx: &ExpContext) -> Result<()> {
    let windows = ctx.windows(6);
    let bw_sweep: Vec<f64> = if ctx.fast {
        vec![3.0, 9.0]
    } else {
        vec![3.0, 6.0, 9.0, 12.0, 15.0]
    };
    let local = vec![1.0, 1.0, 20.0, 20.0, 20.0, 20.0]; // group A capped
    let groups: [Vec<usize>; 3] = [vec![0, 1], vec![2, 3], vec![4, 5]];

    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    let mut traces_json = Vec::new();
    for ablated in [false, true] {
        let name = if ablated { "fixed+AIMD" } else { "ecco-controller" };
        let mut row = vec![name.to_string()];
        for &bw in &bw_sweep {
            let mut policy = Policy::ecco();
            if ablated {
                policy.transmission = TransmissionKind::Fixed { fps: 5.0, res: 48 };
            }
            policy.name = name;
            let spec = RunSpec::new(Task::Det, policy)
                .scenario(scenario::grouped_static(&[2, 2, 2], 0.06, 20.0, ctx.seed))
                .gpus(2.0)
                .shared_mbps(bw)
                .uplinks(local.clone())
                .windows(windows)
                .seed(ctx.seed)
                .configure(|cfg| {
                    cfg.auto_request = false;
                    cfg.auto_regroup = false;
                });
            let mut session = Session::new(engine, spec)?;
            for g in &groups {
                session.force_group(g)?;
            }
            let record_traces = (bw - 9.0).abs() < 1e-9;
            if record_traces {
                session.record_net(1.0);
            }
            for _ in 0..windows {
                session.step_window()?;
            }
            let acc = session.mean_accuracy();
            row.push(format!("{acc:.3}"));
            json_rows.push(obj(vec![
                ("mode", s(name)),
                ("bw", num(bw)),
                ("mAP", num(acc as f64)),
            ]));
            if record_traces {
                if let Some(traces) = session.take_net_traces() {
                    // Mean per-group bandwidth over the last two windows.
                    let t1 = session.now();
                    let t0 = t1 - 2.0 * 60.0;
                    let group_bw: Vec<f64> = groups
                        .iter()
                        .map(|g| {
                            g.iter().map(|&c| traces.mean_rate(c, t0, t1)).sum::<f64>()
                        })
                        .collect();
                    // GPU-share targets from the allocator estimates.
                    let shares: Vec<f64> =
                        session.job_shares().iter().map(|&(_, p)| p).collect();
                    ctx.line(format!(
                        "[{name} @9Mbps] group bw A/B/C = {:.2}/{:.2}/{:.2} Mbps; GPU shares {:?}",
                        group_bw[0],
                        group_bw[1],
                        group_bw[2],
                        shares.iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>()
                    ));
                    traces_json.push(obj(vec![
                        ("mode", s(name)),
                        (
                            "group_bw",
                            arr(group_bw.iter().map(|&v| num(v)).collect()),
                        ),
                        ("gpu_shares", arr(shares.iter().map(|&v| num(v)).collect())),
                    ]));
                }
            }
        }
        rows.push(row);
    }
    let mut hdr = vec!["mode".to_string()];
    hdr.extend(bw_sweep.iter().map(|b| format!("{b} Mbps")));
    let hdr_refs: Vec<&str> = hdr.iter().map(|h| h.as_str()).collect();
    print_table(
        ctx,
        "Fig 11: transmission controller ablation (6 cams / 3 groups, 1 GPU; A capped 1 Mbps)",
        &hdr_refs,
        &rows,
    );
    ctx.line(
        "shape: paper has the controller winning at low bandwidth and matching at high; \
         traces approximate GPU-proportional shares",
    );
    ctx.save(
        "fig11",
        &obj(vec![
            ("experiment", s("fig11")),
            ("rows", arr(json_rows)),
            ("traces", arr(traces_json)),
        ]),
    )?;
    Ok(())
}

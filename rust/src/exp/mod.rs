//! Experiment runners: one per table/figure in the paper's evaluation.
//!
//! | id      | paper result                                   |
//! |---------|------------------------------------------------|
//! | fig2c   | motivation: independent vs group retraining    |
//! | fig5    | sampling-config profiling heatmaps             |
//! | tab1    | equal vs GPU-proportional bandwidth            |
//! | fig6det | end-to-end sweeps, object detection            |
//! | fig6seg | end-to-end sweeps, instance segmentation       |
//! | fig7    | scalability with camera count                  |
//! | fig8    | camera-similarity ablation                     |
//! | fig9    | dynamic grouping timeline                      |
//! | fig10   | GPU allocator vs RECL's allocator              |
//! | fig11   | transmission-controller ablation + BW traces   |
//! | fig12   | natural model reuse (staggered joins)          |
//! | fig13   | response time under low uplink bandwidth       |
//!
//! Each runner prints the paper-shaped table/series and writes JSON into
//! the results directory. `ecco exp all` runs everything.

pub mod ablations;
pub mod common;
pub mod endtoend;
pub mod modules;
pub mod motivation;
pub mod profiling;
pub mod responsiveness;
pub mod similarity;

pub use common::{ExpContext, OutSink};

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::runtime::{Engine, Task};

/// All experiment ids in run order.
pub const ALL_EXPERIMENTS: [&str; 12] = [
    "fig2c", "fig5", "tab1", "fig6det", "fig6seg", "fig7", "fig8", "fig9", "fig10", "fig11",
    "fig12", "fig13",
];

/// Streams completed experiment buffers to stdout in id order: buffer `i`
/// prints as soon as every buffer before it has printed, regardless of
/// completion order.
struct InOrderPrinter {
    next: usize,
    pending: BTreeMap<usize, String>,
}

impl InOrderPrinter {
    fn submit(&mut self, idx: usize, text: String) {
        self.pending.insert(idx, text);
        while let Some(t) = self.pending.remove(&self.next) {
            print!("{t}");
            self.next += 1;
        }
    }
}

/// Dispatch one experiment id (or `all`). Sweep runners fan their
/// conditions out over `ctx.threads` concurrent runs sharing `engine`;
/// output order is condition order either way.
pub fn run_experiment(engine: &Engine, id: &str, ctx: &ExpContext) -> Result<()> {
    match id {
        "all" => {
            if ctx.threads <= 1 {
                // Sequential: stream output live, experiment by experiment.
                for id in ALL_EXPERIMENTS {
                    // ecco-lint: allow(D003) wall-clock for the human-read
                    // "[done in Ns]" banner only, not for any result.
                    let t0 = std::time::Instant::now();
                    println!("\n########## {id} ##########");
                    run_experiment(engine, id, ctx)?;
                    println!("[{id} done in {:.0}s]", t0.elapsed().as_secs_f64());
                }
                return Ok(());
            }
            // The experiment ids are independent (none of them read the
            // others' results, and each writes its own JSON file), so they
            // fan out across the engine's worker pool. Every runner writes
            // into a private buffer; whole experiments print in id order,
            // so the combined output has the sequential loop's shape.
            let printer = Mutex::new(InOrderPrinter {
                next: 0,
                pending: BTreeMap::new(),
            });
            let ids: Vec<&str> = ALL_EXPERIMENTS.to_vec();
            engine.pool().try_map(ctx.threads, &ids, |i, &id| {
                let (out, buf) = OutSink::buffered();
                let mut sub = ctx.clone();
                sub.out = out;
                // ecco-lint: allow(D003) wall-clock for the human-read
                // "[done in Ns]" banner only, not for any result.
                let t0 = std::time::Instant::now();
                let result = run_experiment(engine, id, &sub);
                let mut text = format!("\n########## {id} ##########\n");
                text.push_str(&crate::util::sync::plock(&buf));
                text.push_str(&format!("[{id} done in {:.0}s]\n", t0.elapsed().as_secs_f64()));
                crate::util::sync::plock(&printer).submit(i, text);
                result
            })?;
            Ok(())
        }
        "fig2c" => motivation::fig2c(engine, ctx),
        "fig5" => profiling::fig5(engine, ctx),
        "tab1" => profiling::tab1(engine, ctx),
        "fig6det" => endtoend::fig6(engine, ctx, Task::Det),
        "fig6seg" => endtoend::fig6(engine, ctx, Task::Seg),
        "fig7" => endtoend::fig7(engine, ctx),
        "fig8" => similarity::fig8(engine, ctx),
        "fig9" => similarity::fig9(engine, ctx),
        "fig10" => modules::fig10(engine, ctx),
        "fig11" => modules::fig11(engine, ctx),
        "ablations" => ablations::all(engine, ctx),
        "abl_alpha_beta" => ablations::alpha_beta(engine, ctx),
        "abl_filter" => ablations::filter(engine, ctx),
        "abl_teacher" => ablations::teacher(engine, ctx),
        "fig12" => responsiveness::fig12(engine, ctx),
        "fig13" => responsiveness::fig13(engine, ctx),
        _ => bail!(
            "unknown experiment {id:?}; known: {ALL_EXPERIMENTS:?}, ablations, or `all`"
        ),
    }
}

//! Fig. 6 (end-to-end accuracy vs GPUs and vs bandwidth, det + seg) and
//! Fig. 7 (scalability with camera count).

use anyhow::Result;

use crate::api::RunSpec;
use crate::runtime::{Engine, Task};
use crate::scene::scenario;
use crate::util::json::{arr, num, obj, s};

use super::common::{f3, headline_policies, print_table, run_many, ExpContext};

/// Fig. 6 for one task: two sweeps (GPUs at fixed bandwidth; bandwidth at
/// fixed GPUs) across the four systems. All conditions of a sweep run
/// concurrently over the shared engine; results come back in condition
/// order, so the tables are identical to the old sequential loop's.
pub fn fig6(engine: &Engine, ctx: &ExpContext, task: Task) -> Result<()> {
    let windows = ctx.windows(8);
    let gpu_sweep: Vec<f64> = if ctx.fast {
        vec![1.0, 4.0]
    } else {
        vec![1.0, 2.0, 4.0, 8.0]
    };
    let bw_sweep: Vec<f64> = if ctx.fast {
        vec![3.0, 12.0]
    } else {
        vec![1.5, 3.0, 6.0, 12.0]
    };
    let fixed_bw = 6.0;
    let fixed_gpus = 4.0;
    let mut json_rows = Vec::new();

    for (sweep_name, conditions) in [("gpus", &gpu_sweep), ("bandwidth", &bw_sweep)] {
        // Build the whole sweep (policy-major), then fan it out.
        let mut arms: Vec<(crate::server::Policy, f64)> = Vec::new();
        for policy in headline_policies() {
            for &x in conditions.iter() {
                arms.push((policy.clone(), x));
            }
        }
        let specs: Vec<RunSpec> = arms
            .iter()
            .map(|(policy, x)| {
                let (gpus, bw) = if sweep_name == "gpus" {
                    (*x, fixed_bw)
                } else {
                    (fixed_gpus, *x)
                };
                RunSpec::new(task, policy.clone())
                    .scenario(scenario::grouped_static(&[3, 3], 0.06, 30.0, ctx.seed))
                    .gpus(gpus)
                    .shared_mbps(bw)
                    .uplink_mbps(20.0)
                    .windows(windows)
                    .seed(ctx.seed)
            })
            .collect();
        let outs = run_many(engine, specs, ctx.threads)?;
        let mut rows = Vec::new();
        for (policy_idx, policy) in headline_policies().iter().enumerate() {
            let mut row = vec![policy.name.to_string()];
            for (x_idx, &x) in conditions.iter().enumerate() {
                let out = &outs[policy_idx * conditions.len() + x_idx];
                row.push(f3(out.steady));
                json_rows.push(obj(vec![
                    ("sweep", s(sweep_name)),
                    ("x", num(x)),
                    ("policy", s(policy.name)),
                    ("steady", num(out.steady as f64)),
                    ("final", num(out.final_acc as f64)),
                    ("response_s", num(out.response_s)),
                ]));
            }
            rows.push(row);
        }
        let mut hdr = vec!["policy".to_string()];
        hdr.extend(conditions.iter().map(|&x| {
            if sweep_name == "gpus" {
                format!("{x} GPU")
            } else {
                format!("{x} Mbps")
            }
        }));
        let hdr_refs: Vec<&str> = hdr.iter().map(|h| h.as_str()).collect();
        print_table(
            ctx,
            &format!(
                "Fig 6 [{}]: steady mAP vs {} ({} cams, {} windows)",
                task.name(),
                sweep_name,
                6,
                windows
            ),
            &hdr_refs,
            &rows,
        );
    }
    ctx.save(
        &format!("fig6{}", task.name()),
        &obj(vec![
            ("experiment", s(&format!("fig6{}", task.name()))),
            ("rows", arr(json_rows)),
        ]),
    )?;
    Ok(())
}

/// Fig. 7: scalability — accuracy and response time vs number of cameras.
/// The (policy x fleet-size) grid runs concurrently via the fleet driver.
pub fn fig7(engine: &Engine, ctx: &ExpContext) -> Result<()> {
    let windows = ctx.windows(8);
    let cams_sweep: Vec<usize> = if ctx.fast {
        vec![4, 10]
    } else {
        vec![4, 10, 16, 22]
    };
    let policies = headline_policies();
    let specs: Vec<RunSpec> = policies
        .iter()
        .flat_map(|policy| {
            cams_sweep.iter().map(move |&n| {
                RunSpec::new(Task::Det, policy.clone())
                    .scenario(scenario::town(n, ctx.seed))
                    .gpus(4.0)
                    .shared_mbps(50.0)
                    .uplink_mbps(20.0)
                    .windows(windows)
                    .seed(ctx.seed)
            })
        })
        .collect();
    let outs = run_many(engine, specs, ctx.threads)?;
    let mut acc_rows = Vec::new();
    let mut resp_rows = Vec::new();
    let mut json_rows = Vec::new();
    for (pi, policy) in policies.iter().enumerate() {
        let mut acc_row = vec![policy.name.to_string()];
        let mut resp_row = vec![policy.name.to_string()];
        for (ni, &n) in cams_sweep.iter().enumerate() {
            let out = &outs[pi * cams_sweep.len() + ni];
            acc_row.push(f3(out.steady));
            resp_row.push(format!("{:.0}", out.response_s));
            json_rows.push(obj(vec![
                ("cams", num(n as f64)),
                ("policy", s(policy.name)),
                ("steady", num(out.steady as f64)),
                ("response_s", num(out.response_s)),
                ("satisfied", num(out.satisfied as f64)),
                ("requests", num(out.requests as f64)),
            ]));
        }
        acc_rows.push(acc_row);
        resp_rows.push(resp_row);
    }
    let mut hdr = vec!["policy".to_string()];
    hdr.extend(cams_sweep.iter().map(|n| format!("{n} cams")));
    let hdr_refs: Vec<&str> = hdr.iter().map(|h| h.as_str()).collect();
    print_table(ctx, "Fig 7a: steady mAP vs #cameras (4 GPUs, 50 Mbps)", &hdr_refs, &acc_rows);
    print_table(ctx, "Fig 7b: mean response time (s) vs #cameras", &hdr_refs, &resp_rows);
    ctx.save(
        "fig7",
        &obj(vec![("experiment", s("fig7")), ("rows", arr(json_rows))]),
    )?;
    Ok(())
}

//! Design-choice ablations beyond the paper's figures (DESIGN.md §4):
//!
//! * `abl_alpha_beta` — Eq. 1 sensitivity: sweep the allocator's alpha
//!   (average-vs-fairness balance) and beta (group-size exponent).
//! * `abl_filter` — Alg. 2 metadata pre-filter: accuracy AND grouping-eval
//!   cost with the filter disabled.
//! * `abl_teacher` — teacher label-noise sensitivity (oracle / strong /
//!   noisy), i.e. how much of the pipeline's headroom depends on the
//!   annotator.

use anyhow::Result;

use crate::alloc::EccoAllocator;
use crate::api::{RunSpec, RuntimeOpts, Session};
use crate::runtime::{Engine, Task};
use crate::scene::scenario;
use crate::server::Policy;
use crate::teacher::TeacherConfig;
use crate::util::json::{arr, num, obj, s};
use crate::util::pool;

use super::common::{print_table, run_many, ExpContext};

/// Eq. 1 parameter sweep on the Fig. 10 workload (3+1 groups). The combos
/// are scripted runs (forced groups + allocator swap), fanned out across
/// workers sharing the engine; results reduce in combo order.
pub fn alpha_beta(engine: &Engine, ctx: &ExpContext) -> Result<()> {
    let windows = ctx.windows(6);
    let combos: Vec<(f64, f64)> = if ctx.fast {
        vec![(1.0, 0.5), (0.25, 0.5), (4.0, 0.5)]
    } else {
        vec![
            (1.0, 0.5),
            (0.25, 0.5),
            (4.0, 0.5),
            (1.0, 0.0),
            (1.0, 1.0),
        ]
    };
    // Divide eval workers by the combo concurrency (same rule as
    // run_fleet) so concurrent sessions don't oversubscribe the CPU.
    let per_run = pool::per_run_threads(ctx.threads, combos.len());
    let outcomes = engine.pool().try_map(ctx.threads, &combos, |_, &(alpha, beta)| {
        let spec = RunSpec::new(Task::Det, Policy::ecco())
            .scenario(scenario::three_plus_one(ctx.seed))
            .gpus(1.0)
            .shared_mbps(12.0)
            .uplink_mbps(20.0)
            .windows(windows)
            .seed(ctx.seed)
            .runtime(RuntimeOpts::new().threads(per_run))
            .configure(|cfg| {
                cfg.auto_request = false;
                cfg.auto_regroup = false;
                cfg.micro_windows = 8;
            });
        let mut session = Session::new(engine, spec)?;
        session.force_group(&[0, 1, 2])?;
        session.force_group(&[3])?;
        session.set_allocator(Box::new(EccoAllocator { alpha, beta }));
        for _ in 0..windows {
            session.step_window()?;
        }
        let accs = session.camera_accuracies();
        let g1: f32 = accs[..3].iter().sum::<f32>() / 3.0;
        let g2 = accs[3];
        Ok::<(f32, f32), anyhow::Error>((g1, g2))
    })?;
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for (&(alpha, beta), &(g1, g2)) in combos.iter().zip(&outcomes) {
        rows.push(vec![
            format!("a={alpha} b={beta}"),
            format!("{g1:.3}"),
            format!("{g2:.3}"),
            format!("{:.3}", (g1 - g2).abs()),
            format!("{:.3}", (3.0 * g1 + g2) / 4.0),
        ]);
        json_rows.push(obj(vec![
            ("alpha", num(alpha)),
            ("beta", num(beta)),
            ("g1", num(g1 as f64)),
            ("g2", num(g2 as f64)),
        ]));
    }
    print_table(
        ctx,
        "Ablation: Eq.1 alpha/beta sweep (3-cam vs 1-cam groups)",
        &["params", "G1 mAP", "G2 mAP", "gap", "per-cam mean"],
        &rows,
    );
    ctx.line(
        "expectation: larger alpha -> average-optimising (bigger gap); beta->1 weights \
         big groups harder",
    );
    ctx.save(
        "abl_alpha_beta",
        &obj(vec![("experiment", s("abl_alpha_beta")), ("rows", arr(json_rows))]),
    )?;
    Ok(())
}

/// Alg. 2 metadata-filter ablation: accuracy and grouping-eval cost.
///
/// Stays sequential on purpose: the eval-cost metric is a delta over the
/// shared engine's global infer counter, which concurrent runs would
/// pollute.
pub fn filter(engine: &Engine, ctx: &ExpContext) -> Result<()> {
    let windows = ctx.windows(6);
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for enabled in [true, false] {
        let infer_before = engine.stats().infer_calls;
        let spec = RunSpec::new(Task::Det, Policy::ecco())
            .scenario(scenario::town(8, ctx.seed))
            .gpus(2.0)
            .shared_mbps(10.0)
            .uplink_mbps(20.0)
            .windows(windows)
            .seed(ctx.seed)
            .configure(move |cfg| cfg.grouping.metadata_filter = enabled);
        let mut session = Session::new(engine, spec)?;
        for _ in 0..windows {
            session.step_window()?;
        }
        let acc = session.steady_mean(0.4);
        let jobs = session.jobs();
        let evals = session.engine_stats().infer_calls - infer_before;
        rows.push(vec![
            if enabled { "with filter" } else { "no filter" }.into(),
            format!("{acc:.3}"),
            format!("{jobs}"),
            format!("{evals}"),
        ]);
        json_rows.push(obj(vec![
            ("filter", num(enabled as u8 as f64)),
            ("steady", num(acc as f64)),
            ("jobs", num(jobs as f64)),
            ("infer_calls", num(evals as f64)),
        ]));
    }
    print_table(
        ctx,
        "Ablation: Alg.2 metadata pre-filter (8 cameras, 4 regions)",
        &["mode", "steady mAP", "jobs", "infer calls"],
        &rows,
    );
    ctx.line("expectation: similar accuracy, strictly more grouping evals without the filter");
    ctx.save(
        "abl_filter",
        &obj(vec![("experiment", s("abl_filter")), ("rows", arr(json_rows))]),
    )?;
    Ok(())
}

/// Teacher-quality sensitivity. The three teacher arms run concurrently.
pub fn teacher(engine: &Engine, ctx: &ExpContext) -> Result<()> {
    let windows = ctx.windows(6);
    let arms = [
        ("oracle", TeacherConfig::oracle()),
        ("strong", TeacherConfig::strong()),
        ("noisy", TeacherConfig::noisy()),
    ];
    let specs: Vec<RunSpec> = arms
        .iter()
        .map(|(_, tc)| {
            let tc = tc.clone();
            RunSpec::new(Task::Det, Policy::ecco())
                .scenario(scenario::grouped_static(&[3], 0.06, 20.0, ctx.seed))
                .gpus(2.0)
                .shared_mbps(10.0)
                .uplink_mbps(20.0)
                .windows(windows)
                .seed(ctx.seed)
                .configure(move |cfg| cfg.teacher = tc.clone())
        })
        .collect();
    let outs = run_many(engine, specs, ctx.threads)?;
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for ((name, _), out) in arms.iter().zip(&outs) {
        let acc = out.steady;
        rows.push(vec![name.to_string(), format!("{acc:.3}")]);
        json_rows.push(obj(vec![("teacher", s(name)), ("steady", num(acc as f64))]));
    }
    print_table(
        ctx,
        "Ablation: teacher label quality",
        &["teacher", "steady mAP"],
        &rows,
    );
    ctx.line(
        "expectation: monotone in teacher quality; strong ~ oracle (paper's implicit assumption)",
    );
    ctx.save(
        "abl_teacher",
        &obj(vec![("experiment", s("abl_teacher")), ("rows", arr(json_rows))]),
    )?;
    Ok(())
}

/// Run all ablations.
pub fn all(engine: &Engine, ctx: &ExpContext) -> Result<()> {
    alpha_beta(engine, ctx)?;
    filter(engine, ctx)?;
    teacher(engine, ctx)
}

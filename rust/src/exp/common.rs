//! Shared experiment infrastructure: the standard run wrapper over
//! [`crate::api`], table printing, and JSON output.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::api::{RunReport, RunSpec, Session};
use crate::runtime::Engine;
use crate::util::json::Json;

/// Where a runner's human-readable output goes: straight to stdout (the
/// default), or into a per-experiment buffer so `exp all` can fan runners
/// out concurrently and still print whole experiments in id order, never
/// interleaved.
#[derive(Debug, Clone, Default)]
pub struct OutSink {
    buf: Option<Arc<Mutex<String>>>,
}

impl OutSink {
    /// Unbuffered: lines go straight to stdout as they happen.
    pub fn stdout() -> OutSink {
        OutSink { buf: None }
    }

    /// Buffered: lines accumulate in the returned handle until the caller
    /// flushes them (the `exp all` fan-out prints buffers in id order).
    pub fn buffered() -> (OutSink, Arc<Mutex<String>>) {
        let buf = Arc::new(Mutex::new(String::new()));
        let sink = OutSink {
            buf: Some(buf.clone()),
        };
        (sink, buf)
    }

    /// Emit one output line.
    pub fn line(&self, text: impl AsRef<str>) {
        match &self.buf {
            None => println!("{}", text.as_ref()),
            Some(buf) => {
                let mut buf = crate::util::sync::plock(buf);
                buf.push_str(text.as_ref());
                buf.push('\n');
            }
        }
    }
}

/// Experiment context from the CLI.
#[derive(Debug, Clone)]
pub struct ExpContext {
    pub out_dir: String,
    /// Reduced-scale run (CI / smoke): fewer windows and conditions.
    pub fast: bool,
    pub seed: u64,
    /// Concurrent runs for sweep fan-outs (`--threads`; results are always
    /// in condition order, so this only trades wall-clock for cores).
    pub threads: usize,
    /// Output sink for the runner's tables and shape notes.
    pub out: OutSink,
}

impl ExpContext {
    pub fn windows(&self, full: usize) -> usize {
        if self.fast {
            (full / 2).max(2)
        } else {
            full
        }
    }

    /// Emit one line of experiment output (stdout or the `exp all` buffer).
    pub fn line(&self, text: impl AsRef<str>) {
        self.out.line(text);
    }

    pub fn save(&self, name: &str, json: &Json) -> Result<()> {
        let path = format!("{}/{}.json", self.out_dir, name);
        std::fs::write(&path, json.to_string_pretty())?;
        self.line(format!("[saved {path}]"));
        Ok(())
    }
}

/// Run one spec to completion: the standard one-call wrapper for a single
/// condition (replaces the old 10-argument `run_policy`).
pub fn run(engine: &Engine, spec: RunSpec) -> Result<RunReport> {
    Session::new(engine, spec)?.run()
}

/// Run a whole sweep concurrently over the shared engine: reports come
/// back in spec order, each identical to its sequential [`run`]. Sweep
/// runners build their condition list first, fan out here, then print.
pub fn run_many(engine: &Engine, specs: Vec<RunSpec>, threads: usize) -> Result<Vec<RunReport>> {
    crate::api::run_fleet(engine, specs, threads)
}

/// The four systems of the end-to-end comparison, in report order.
pub fn headline_policies() -> Vec<crate::server::Policy> {
    use crate::server::Policy;
    vec![
        Policy::ecco(),
        Policy::recl(),
        Policy::ekya(),
        Policy::naive(),
    ]
}

/// Print a fixed-width table to the context's output sink.
pub fn print_table(ctx: &ExpContext, title: &str, header: &[&str], rows: &[Vec<String>]) {
    ctx.line(format!("\n== {title} =="));
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    ctx.line(fmt_row(
        &header.iter().map(|h| h.to_string()).collect::<Vec<_>>(),
    ));
    for row in rows {
        ctx.line(fmt_row(row));
    }
}

pub fn f3(v: f32) -> String {
    format!("{v:.3}")
}

pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}

//! Shared experiment infrastructure: the standard run wrapper over
//! [`crate::api`], table printing, and JSON output.

use anyhow::Result;

use crate::api::{RunReport, RunSpec, Session};
use crate::runtime::Engine;
use crate::util::json::Json;

/// Experiment context from the CLI.
#[derive(Debug, Clone)]
pub struct ExpContext {
    pub out_dir: String,
    /// Reduced-scale run (CI / smoke): fewer windows and conditions.
    pub fast: bool,
    pub seed: u64,
    /// Concurrent runs for sweep fan-outs (`--threads`; results are always
    /// in condition order, so this only trades wall-clock for cores).
    pub threads: usize,
}

impl ExpContext {
    pub fn windows(&self, full: usize) -> usize {
        if self.fast {
            (full / 2).max(2)
        } else {
            full
        }
    }

    pub fn save(&self, name: &str, json: &Json) -> Result<()> {
        let path = format!("{}/{}.json", self.out_dir, name);
        std::fs::write(&path, json.to_string_pretty())?;
        println!("[saved {path}]");
        Ok(())
    }
}

/// Run one spec to completion: the standard one-call wrapper for a single
/// condition (replaces the old 10-argument `run_policy`).
pub fn run(engine: &Engine, spec: RunSpec) -> Result<RunReport> {
    Session::new(engine, spec)?.run()
}

/// Run a whole sweep concurrently over the shared engine: reports come
/// back in spec order, each identical to its sequential [`run`]. Sweep
/// runners build their condition list first, fan out here, then print.
pub fn run_many(engine: &Engine, specs: Vec<RunSpec>, threads: usize) -> Result<Vec<RunReport>> {
    crate::api::run_fleet(engine, specs, threads)
}

/// The four systems of the end-to-end comparison, in report order.
pub fn headline_policies() -> Vec<crate::server::Policy> {
    use crate::server::Policy;
    vec![
        Policy::ecco(),
        Policy::recl(),
        Policy::ekya(),
        Policy::naive(),
    ]
}

/// Print a fixed-width table.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(&header.iter().map(|h| h.to_string()).collect::<Vec<_>>())
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

pub fn f3(v: f32) -> String {
    format!("{v:.3}")
}

pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}

//! Shared experiment infrastructure: standard run wrapper, result
//! containers, table printing, and JSON output.

use anyhow::Result;

use crate::runtime::{Engine, Task};
use crate::scene::World;
use crate::server::{Policy, System, SystemConfig};
use crate::util::json::{arr, f32s, num, obj, s, Json};

/// Experiment context from the CLI.
#[derive(Debug, Clone)]
pub struct ExpContext {
    pub out_dir: String,
    /// Reduced-scale run (CI / smoke): fewer windows and conditions.
    pub fast: bool,
    pub seed: u64,
}

impl ExpContext {
    pub fn windows(&self, full: usize) -> usize {
        if self.fast {
            (full / 2).max(2)
        } else {
            full
        }
    }

    pub fn save(&self, name: &str, json: &Json) -> Result<()> {
        let path = format!("{}/{}.json", self.out_dir, name);
        std::fs::write(&path, json.to_string_pretty())?;
        println!("[saved {path}]");
        Ok(())
    }
}

/// Everything an experiment typically needs from one system run.
pub struct RunOutcome {
    pub name: String,
    /// Mean accuracy per window (over cameras).
    pub window_acc: Vec<f32>,
    /// Per-camera accuracy series: `cam_acc[cam][window]`.
    pub cam_acc: Vec<Vec<f32>>,
    /// Steady-state mean accuracy (last 40% of windows).
    pub steady: f32,
    pub final_acc: f32,
    /// Mean response time (seconds; unresolved counted at horizon).
    pub response: f64,
    pub satisfied: usize,
    pub requests: usize,
    /// Final number of retraining jobs.
    pub jobs: usize,
    /// (window, micro-window, job id) allocation log.
    pub alloc_log: Vec<(usize, usize, usize)>,
    /// Membership snapshots per window.
    pub membership: Vec<(usize, crate::server::system::MembershipSnapshot)>,
    pub wall_secs: f64,
}

impl RunOutcome {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            ("window_acc", f32s(&self.window_acc)),
            (
                "cam_acc",
                arr(self.cam_acc.iter().map(|c| f32s(c)).collect()),
            ),
            ("steady", num(self.steady as f64)),
            ("final", num(self.final_acc as f64)),
            ("response_s", num(self.response)),
            ("satisfied", num(self.satisfied as f64)),
            ("requests", num(self.requests as f64)),
            ("jobs", num(self.jobs as f64)),
            ("wall_secs", num(self.wall_secs)),
        ])
    }
}

/// Build-config hook so experiments can tweak SystemConfig uniformly.
pub type CfgHook<'a> = &'a dyn Fn(&mut SystemConfig);

/// Run one policy on one world for `windows` retraining windows.
#[allow(clippy::too_many_arguments)]
pub fn run_policy(
    engine: &mut Engine,
    world: World,
    task: Task,
    policy: Policy,
    gpus: f64,
    shared_bw: f64,
    local_bw: &[f64],
    windows: usize,
    seed: u64,
    hook: Option<CfgHook>,
) -> Result<RunOutcome> {
    let name = policy.name.to_string();
    let zoo = policy.zoo_warm_start;
    let mut cfg = SystemConfig::new(task, policy);
    cfg.gpus = gpus;
    cfg.seed = seed;
    if let Some(h) = hook {
        h(&mut cfg);
    }
    let t0 = std::time::Instant::now();
    let mut sys = System::new(cfg, world, local_bw, shared_bw, engine)?;
    if zoo {
        sys.populate_zoo_from_initial(40)?;
    }
    let mut window_acc = Vec::with_capacity(windows);
    for _ in 0..windows {
        sys.run_window()?;
        window_acc.push(sys.mean_accuracy());
    }
    let horizon = sys.now();
    let cam_acc: Vec<Vec<f32>> = sys
        .history
        .series
        .iter()
        .map(|series| series.iter().map(|&(_, a)| a).collect())
        .collect();
    Ok(RunOutcome {
        name,
        steady: sys.history.steady_mean(0.4),
        final_acc: sys.mean_accuracy(),
        window_acc,
        cam_acc,
        response: sys.tracker.mean_response(horizon),
        satisfied: sys.tracker.satisfied(),
        requests: sys.tracker.total(),
        jobs: sys.jobs.len(),
        alloc_log: sys.alloc_log.clone(),
        membership: sys.membership_log.clone(),
        wall_secs: t0.elapsed().as_secs_f64(),
    })
}

/// The four systems of the end-to-end comparison, in report order.
pub fn headline_policies() -> Vec<Policy> {
    vec![
        Policy::ecco(),
        Policy::recl(),
        Policy::ekya(),
        Policy::naive(),
    ]
}

/// Print a fixed-width table.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(&header.iter().map(|h| h.to_string()).collect::<Vec<_>>())
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

pub fn f3(v: f32) -> String {
    format!("{v:.3}")
}

pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}

//! Fig. 8 (impact of camera similarity on group retraining) and Fig. 9
//! (dynamic grouping timeline with a diverging mobile camera).

use anyhow::Result;

use crate::api::{RunSpec, RuntimeOpts, Session};
use crate::runtime::{Engine, Task};
use crate::scene::scenario;
use crate::server::{Policy, TransmissionKind};
use crate::util::json::{arr, f32s, num, obj, s};
use crate::util::pool;

use super::common::{print_table, ExpContext};

/// Fig. 8: manually-formed groups at three similarity levels; group
/// retraining vs independent retraining with equal resources. The six
/// scripted conditions run concurrently — each worker builds its own
/// session over the shared engine — and reduce in condition order.
pub fn fig8(engine: &Engine, ctx: &ExpContext) -> Result<()> {
    let windows = ctx.windows(6);
    let conditions: Vec<(usize, bool)> = (0..3usize)
        .flat_map(|level| [(level, true), (level, false)])
        .collect();
    // Divide eval workers by the condition concurrency (same rule as
    // run_fleet) so concurrent sessions don't oversubscribe the CPU.
    let per_run = pool::per_run_threads(ctx.threads, conditions.len());
    let accs = engine.pool().try_map(ctx.threads, &conditions, |_, &(level, grouped)| {
        let (sc, _) = scenario::similarity_triads(20.0, ctx.seed);
        let triad = sc.groups[level].clone();
        let mut policy = if grouped { Policy::ecco() } else { Policy::ekya() };
        // Grouping module disabled (manual groups) and a fixed
        // transmission pipeline, per the paper's setup.
        policy.transmission = TransmissionKind::Fixed { fps: 4.0, res: 32 };
        policy.name = if grouped { "group" } else { "independent" };
        // Ample bandwidth: similarity (not data volume) is the variable
        // under study; the paper's 3 Mbps maps to a non-binding uplink
        // at our proxy scale for these sampling configs.
        let spec = RunSpec::new(Task::Det, policy)
            .scenario(sc)
            .gpus(3.0)
            .shared_mbps(12.0)
            .uplink_mbps(20.0)
            .windows(windows)
            .seed(ctx.seed)
            .runtime(RuntimeOpts::new().threads(per_run))
            .configure(|cfg| {
                cfg.auto_request = false;
                cfg.auto_regroup = false;
            });
        let mut session = Session::new(engine, spec)?;
        if grouped {
            session.force_group(&triad)?;
        } else {
            for &cam in &triad {
                session.force_group(&[cam])?;
            }
        }
        for _ in 0..windows {
            session.step_window()?;
        }
        // Accuracy over the triad only (other cameras are idle).
        let acc: f32 = triad
            .iter()
            .map(|&c| session.camera_accuracy(c))
            .sum::<f32>()
            / triad.len() as f32;
        Ok::<f32, anyhow::Error>(acc)
    })?;
    let (_, names) = scenario::similarity_triads(20.0, ctx.seed);
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for level in 0..3usize {
        let grouped_acc = accs[level * 2];
        let indep_acc = accs[level * 2 + 1];
        for (acc, grouped) in [(grouped_acc, true), (indep_acc, false)] {
            json_rows.push(obj(vec![
                ("similarity", s(names[level])),
                ("mode", s(if grouped { "group" } else { "independent" })),
                ("mAP", num(acc as f64)),
            ]));
        }
        let gain = grouped_acc - indep_acc;
        rows.push(vec![
            ["high", "medium", "low"][level].to_string(),
            format!("{grouped_acc:.3}"),
            format!("{indep_acc:.3}"),
            format!("{gain:+.3}"),
        ]);
    }
    print_table(
        ctx,
        "Fig 8: group vs independent retraining by camera similarity (3 GPUs)",
        &["similarity", "group mAP", "indep mAP", "group gain"],
        &rows,
    );
    ctx.line("shape: paper has the gain shrinking from high to low similarity");
    ctx.save(
        "fig8",
        &obj(vec![("experiment", s("fig8")), ("rows", arr(json_rows))]),
    )?;
    Ok(())
}

/// Fig. 9: dynamic grouping on a route split — camera 2 drives into a
/// tunnel at t=300s and must be evicted and re-grouped.
pub fn fig9(engine: &Engine, ctx: &ExpContext) -> Result<()> {
    // The route geometry needs ~10 windows regardless of fast mode: the
    // split camera reaches the tunnel around t=320s (window 6).
    let windows = ctx.windows(10).max(10);
    // 1 GPU: the shared model cannot master two diverged distributions at
    // once, so the tunnel camera's accuracy genuinely collapses (paper
    // regime). A slightly tighter eviction threshold matches the paper's
    // prompt regrouping.
    let spec = RunSpec::new(Task::Det, Policy::ecco())
        .scenario(scenario::route_split(2, 240.0, ctx.seed))
        .gpus(1.0)
        .shared_mbps(10.0)
        .uplink_mbps(10.0)
        .windows(windows)
        .seed(ctx.seed)
        .configure(|cfg| cfg.grouping.drop_threshold = 0.12);
    let mut session = Session::new(engine, spec)?;

    ctx.line("\n== Fig 9: dynamic grouping timeline (camera 2 turns off at t=240s) ==");
    ctx.line("window |  t(s) | cam0  cam1  cam2 | groups (job: members)");
    let mut acc_series: Vec<Vec<f32>> = vec![Vec::new(); 3];
    let mut membership_series = Vec::new();
    for _ in 0..windows {
        let w = session.step_window()?;
        for (i, &a) in w.cam_acc.iter().enumerate() {
            acc_series[i].push(a);
        }
        let groups: Vec<String> = w
            .membership
            .iter()
            .map(|(id, members)| format!("{id}:{members:?}"))
            .collect();
        ctx.line(format!(
            "{:>6} | {:>5.0} | {:.3} {:.3} {:.3} | {}",
            w.window,
            w.time,
            w.cam_acc[0],
            w.cam_acc[1],
            w.cam_acc[2],
            groups.join("  ")
        ));
        membership_series.push(w.membership);
    }
    // Shape check: at some window cam2 must be in a different job from cam0.
    let split_observed = membership_series.iter().any(|groups| {
        let job_of = |cam: usize| groups.iter().find(|(_, m)| m.contains(&cam)).map(|(id, _)| *id);
        job_of(0).is_some() && job_of(2).is_some() && job_of(0) != job_of(2)
    });
    let merged_initially = membership_series.first().map(|g| g.len() == 1).unwrap_or(false);
    ctx.line(format!(
        "shape: initially one group: {merged_initially}; cam2 split into its own job later: {split_observed}"
    ));
    ctx.save(
        "fig9",
        &obj(vec![
            ("experiment", s("fig9")),
            (
                "cam_acc",
                arr(acc_series.iter().map(|c| f32s(c)).collect()),
            ),
            (
                "membership",
                arr(membership_series
                    .iter()
                    .map(|groups| {
                        arr(groups
                            .iter()
                            .map(|(id, m)| {
                                obj(vec![
                                    ("job", num(*id as f64)),
                                    (
                                        "members",
                                        arr(m.iter().map(|&c| num(c as f64)).collect()),
                                    ),
                                ])
                            })
                            .collect())
                    })
                    .collect()),
            ),
            ("split_observed", num(split_observed as u8 as f64)),
        ]),
    )?;
    Ok(())
}

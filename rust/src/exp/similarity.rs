//! Fig. 8 (impact of camera similarity on group retraining) and Fig. 9
//! (dynamic grouping timeline with a diverging mobile camera).

use anyhow::Result;

use crate::runtime::{Engine, Task};
use crate::scene::scenario;
use crate::server::{Policy, System, SystemConfig, TransmissionKind};
use crate::util::json::{arr, f32s, num, obj, s};

use super::common::{print_table, ExpContext};

/// Fig. 8: manually-formed groups at three similarity levels; group
/// retraining vs independent retraining with equal resources.
pub fn fig8(engine: &mut Engine, ctx: &ExpContext) -> Result<()> {
    let windows = ctx.windows(6);
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for level in 0..3usize {
        let mut accs = Vec::new();
        for grouped in [true, false] {
            let (sc, names) = scenario::similarity_triads(20.0, ctx.seed);
            let triad = sc.groups[level].clone();
            let n_world = sc.world.cameras.len();
            let mut policy = if grouped {
                let mut p = Policy::ecco();
                // Grouping module disabled (manual groups), per the paper.
                p.transmission = TransmissionKind::Fixed { fps: 4.0, res: 32 };
                p
            } else {
                let mut p = Policy::ekya();
                p.transmission = TransmissionKind::Fixed { fps: 4.0, res: 32 };
                p
            };
            policy.name = if grouped { "group" } else { "independent" };
            let mut cfg = SystemConfig::new(Task::Det, policy);
            cfg.gpus = 3.0;
            cfg.seed = ctx.seed;
            cfg.auto_request = false;
            cfg.auto_regroup = false;
            // Ample bandwidth: similarity (not data volume) is the variable
            // under study; the paper's 3 Mbps maps to a non-binding uplink
            // at our proxy scale for these sampling configs.
            let mut sys = System::new(cfg, sc.world, &vec![20.0; n_world], 12.0, engine)?;
            if grouped {
                sys.force_group(&triad)?;
            } else {
                for &cam in &triad {
                    sys.force_group(&[cam])?;
                }
            }
            sys.run_windows(windows)?;
            // Accuracy over the triad only (other cameras are idle).
            let acc: f32 = triad
                .iter()
                .map(|&c| sys.cams[c].last_acc)
                .sum::<f32>()
                / triad.len() as f32;
            accs.push(acc);
            json_rows.push(obj(vec![
                ("similarity", s(names[level])),
                ("mode", s(if grouped { "group" } else { "independent" })),
                ("mAP", num(acc as f64)),
            ]));
        }
        let gain = accs[0] - accs[1];
        rows.push(vec![
            ["high", "medium", "low"][level].to_string(),
            format!("{:.3}", accs[0]),
            format!("{:.3}", accs[1]),
            format!("{gain:+.3}"),
        ]);
    }
    print_table(
        "Fig 8: group vs independent retraining by camera similarity (3 GPUs)",
        &["similarity", "group mAP", "indep mAP", "group gain"],
        &rows,
    );
    println!("shape: paper has the gain shrinking from high to low similarity");
    ctx.save(
        "fig8",
        &obj(vec![("experiment", s("fig8")), ("rows", arr(json_rows))]),
    )?;
    Ok(())
}

/// Fig. 9: dynamic grouping on a route split — camera 2 drives into a
/// tunnel at t=300s and must be evicted and re-grouped.
pub fn fig9(engine: &mut Engine, ctx: &ExpContext) -> Result<()> {
    // The route geometry needs ~10 windows regardless of fast mode: the
    // split camera reaches the tunnel around t=320s (window 6).
    let windows = ctx.windows(10).max(10);
    let sc = scenario::route_split(2, 240.0, ctx.seed);
    let mut cfg = SystemConfig::new(Task::Det, Policy::ecco());
    cfg.seed = ctx.seed;
    // 1 GPU: the shared model cannot master two diverged distributions at
    // once, so the tunnel camera's accuracy genuinely collapses (paper
    // regime). A slightly tighter eviction threshold matches the paper's
    // prompt regrouping.
    cfg.gpus = 1.0;
    cfg.grouping.drop_threshold = 0.12;
    let mut sys = System::new(cfg, sc.world, &[10.0; 3], 10.0, engine)?;

    println!("\n== Fig 9: dynamic grouping timeline (camera 2 turns off at t=240s) ==");
    println!("window |  t(s) | cam0  cam1  cam2 | groups (job: members)");
    let mut acc_series: Vec<Vec<f32>> = vec![Vec::new(); 3];
    let mut membership_series = Vec::new();
    for w in 0..windows {
        sys.run_window()?;
        let accs: Vec<f32> = sys.cams.iter().map(|c| c.last_acc).collect();
        for (i, &a) in accs.iter().enumerate() {
            acc_series[i].push(a);
        }
        let groups: Vec<String> = sys
            .jobs
            .iter()
            .map(|j| format!("{}:{:?}", j.id, j.members))
            .collect();
        membership_series.push(
            sys.jobs
                .iter()
                .map(|j| (j.id, j.members.clone()))
                .collect::<Vec<_>>(),
        );
        println!(
            "{:>6} | {:>5.0} | {:.3} {:.3} {:.3} | {}",
            w,
            sys.now(),
            accs[0],
            accs[1],
            accs[2],
            groups.join("  ")
        );
    }
    // Shape check: at some window cam2 must be in a different job from cam0.
    let split_observed = membership_series.iter().any(|groups| {
        let job_of = |cam: usize| groups.iter().find(|(_, m)| m.contains(&cam)).map(|(id, _)| *id);
        job_of(0).is_some() && job_of(2).is_some() && job_of(0) != job_of(2)
    });
    let merged_initially = membership_series.first().map(|g| g.len() == 1).unwrap_or(false);
    println!(
        "shape: initially one group: {merged_initially}; cam2 split into its own job later: {split_observed}"
    );
    ctx.save(
        "fig9",
        &obj(vec![
            ("experiment", s("fig9")),
            (
                "cam_acc",
                arr(acc_series.iter().map(|c| f32s(c)).collect()),
            ),
            (
                "membership",
                arr(membership_series
                    .iter()
                    .map(|groups| {
                        arr(groups
                            .iter()
                            .map(|(id, m)| {
                                obj(vec![
                                    ("job", num(*id as f64)),
                                    (
                                        "members",
                                        arr(m.iter().map(|&c| num(c as f64)).collect()),
                                    ),
                                ])
                            })
                            .collect())
                    })
                    .collect()),
            ),
            ("split_observed", num(split_observed as u8 as f64)),
        ]),
    )?;
    Ok(())
}

//! Fig. 5 (sampling-configuration profiling) and Table 1 (equal vs
//! GPU-proportional bandwidth allocation).
//!
//! Both experiments run a direct retraining loop (no full System): they
//! characterise the *transmission* design space, so the GPU allocator and
//! grouping are held fixed by construction, exactly as in §3.2's case
//! studies.

use anyhow::Result;

use crate::net::NetSim;
use crate::runtime::{batch, Engine, ModelState, Task};
use crate::scene::{drift::DriftEvent, scenario::AMBIENT_VOL, DriftProcess, Frame, SceneState};
use crate::scene::{Camera, Mount, World, Zone, ZoneMap};
use crate::server::{eval_model, pretrain};
use crate::teacher::{Teacher, TeacherConfig};
use crate::transmission::BUDGET_LEVELS;
use crate::util::json::{arr, num, obj, s};
use crate::util::rng::Pcg32;
use crate::video::{degrade, transport_window, SamplingConfig, FPS_CHOICES, RES_CHOICES};

use super::common::{print_table, ExpContext};

/// One camera world with the requested mount; drift at t=1.
fn one_cam_world(mount: Mount, seed: u64) -> World {
    let region = DriftProcess::new(SceneState::default_day(), AMBIENT_VOL, seed);
    let cam = Camera {
        id: 0,
        region: 0,
        pos: (0.3, 0.3),
        mount,
        offset_seed: seed ^ 0xca3,
        offset_scale: 0.0,
    };
    let map = ZoneMap {
        cells: vec![vec![Zone::Suburban, Zone::Urban]],
    };
    let mut world = World::new(vec![region], map, vec![cam]);
    world.schedule(vec![
        (1.0, 0, DriftEvent::Appearance(0.5)),
        (1.0, 0, DriftEvent::Palette([0.64, 0.47, 0.33])),
    ]);
    world
}

/// One single-camera retraining condition: the mount under test, the
/// forced sampling config, and the resource envelope.
#[derive(Clone)]
struct RetrainSetup {
    mount: Mount,
    config: SamplingConfig,
    budget_pps: f64,
    bitrate_mbps: f64,
    windows: usize,
    seed: u64,
}

/// Retrain one camera under a fixed pixel budget and bitrate with a forced
/// sampling config; returns final mAP.
fn retrain_with_config(engine: &Engine, setup: &RetrainSetup) -> Result<f32> {
    let RetrainSetup {
        mount,
        config,
        budget_pps,
        bitrate_mbps,
        windows,
        seed,
    } = setup.clone();
    let m = engine.manifest.clone();
    let pre = pretrain::pretrained_default(engine, Task::Det, 300, 0.03, seed ^ 0xbeef)?;
    let mut model = ModelState::from_theta(Task::Det, pre.theta);
    let mut world = one_cam_world(mount, seed);
    let mut teacher = Teacher::new(TeacherConfig::strong(), seed ^ 0x7ea);
    let mut rng = Pcg32::new(seed, 0x515);
    let window_secs = 60.0;
    let mut buffer: Vec<(Frame, crate::scene::GroundTruth)> = Vec::new();

    for w in 0..windows {
        // Transport: fixed bitrate, adaptive compression.
        let delivered_mbit = bitrate_mbps * window_secs;
        let outcome = transport_window(config, window_secs, delivered_mbit);
        // Spread captures across the window so fast scenes differ per frame.
        let n = outcome.frames_delivered.min(400);
        for i in 0..n {
            world.advance(window_secs / n.max(1) as f64);
            let mut frame = world.capture(0, config.res);
            degrade(&mut frame.pixels, config.res, outcome.quality, seed + i as u64);
            let labels = teacher.annotate(&frame.truth);
            buffer.push((frame, labels));
        }
        if n == 0 {
            world.advance(window_secs);
        }
        if buffer.len() > 512 {
            let excess = buffer.len() - 512;
            buffer.drain(..excess);
        }
        // GPU: budget_pps pixels/sec over the window.
        let steps =
            (budget_pps * window_secs / (config.res * config.res * m.train_batch) as f64) as usize;
        if !buffer.is_empty() {
            for _ in 0..steps {
                let picks: Vec<usize> =
                    (0..m.train_batch).map(|_| rng.index(buffer.len())).collect();
                let frames: Vec<&Frame> = picks.iter().map(|&i| &buffer[i].0).collect();
                let truths: Vec<_> = picks.iter().map(|&i| &buffer[i].1).collect();
                let tb = batch::train_batch(
                    Task::Det,
                    &frames,
                    &truths,
                    m.train_batch,
                    config.res,
                    m.classes,
                    m.grid,
                );
                engine.train_step(&mut model, &tb, 0.03)?;
            }
        }
        let _ = w;
    }
    let eval = world.eval_frames(0, 32, 16, 0xe7a1);
    eval_model(engine, Task::Det, &model.theta, &eval)
}

/// Fig. 5: accuracy heatmap over (fps, res) for a static and mobile camera
/// under a fixed GPU budget and 1 Mbps. Also writes the measured profile
/// tables that `transmission::ProfileTable::from_measurements` consumes.
/// Heatmap cells are independent (own world + model per cell), so each
/// mount's grid fans out across the worker pool in cell order.
pub fn fig5(engine: &Engine, ctx: &ExpContext) -> Result<()> {
    let windows = ctx.windows(4);
    let budget = 10_000.0; // pixels/sec (BUDGET_LEVELS[2])
    let mounts: Vec<(&str, Mount)> = vec![
        ("static", Mount::StaticHigh),
        (
            "mobile",
            Mount::Mobile {
                waypoints: vec![(0.05, 0.4), (0.95, 0.4)],
                speed: 0.002,
            },
        ),
    ];
    let mut all_rows = Vec::new();
    for (mname, mount) in &mounts {
        let cells: Vec<SamplingConfig> = RES_CHOICES
            .iter()
            .flat_map(|&res| FPS_CHOICES.iter().map(move |&fps| SamplingConfig { fps, res }))
            .collect();
        let accs = engine.pool().try_map(ctx.threads, &cells, |_, &c| {
            if c.pixels_per_sec() > budget * 1.5 {
                return Ok(f32::NAN); // config can't even fit the budget
            }
            // Two seeds per cell to tame eval noise.
            let setup = RetrainSetup {
                mount: mount.clone(),
                config: c,
                budget_pps: budget,
                bitrate_mbps: 1.0,
                windows,
                seed: ctx.seed,
            };
            let a0 = retrain_with_config(engine, &setup)?;
            let a1 = retrain_with_config(
                engine,
                &RetrainSetup {
                    seed: ctx.seed ^ 0xabcd,
                    ..setup
                },
            )?;
            Ok::<f32, anyhow::Error>((a0 + a1) / 2.0)
        })?;
        let mut rows = Vec::new();
        let mut best: Option<(SamplingConfig, f32)> = None;
        for (ri, &res) in RES_CHOICES.iter().enumerate() {
            let mut row = vec![format!("res {res}")];
            for (fi, &fps) in FPS_CHOICES.iter().enumerate() {
                let c = SamplingConfig { fps, res };
                let acc = accs[ri * FPS_CHOICES.len() + fi];
                if !acc.is_nan() && best.map(|(_, b)| acc > b).unwrap_or(true) {
                    best = Some((c, acc));
                }
                row.push(if acc.is_nan() {
                    "-".into()
                } else {
                    format!("{acc:.3}")
                });
            }
            rows.push(row);
        }
        let mut hdr = vec!["".to_string()];
        hdr.extend(FPS_CHOICES.iter().map(|f| format!("{f} fps")));
        let hdr_refs: Vec<&str> = hdr.iter().map(|h| h.as_str()).collect();
        print_table(
            ctx,
            &format!("Fig 5 ({mname} camera): mAP per sampling config, {budget} px/s, 1 Mbps"),
            &hdr_refs,
            &rows,
        );
        let (bc, ba) = best.unwrap();
        ctx.line(format!("best for {mname}: {bc:?} at {ba:.3}"));
        all_rows.push((mname.to_string(), rows, bc, ba));
    }
    ctx.line(format!(
        "shape: paper finds static favours resolution, mobile favours frame rate — \
         got static=res{}, mobile fps {}",
        all_rows[0].2.res, all_rows[1].2.fps
    ));
    ctx.save(
        "fig5",
        &obj(vec![
            ("experiment", s("fig5")),
            ("budget_pps", num(budget)),
            (
                "best",
                arr(all_rows
                    .iter()
                    .map(|(n, _, c, a)| {
                        obj(vec![
                            ("camera", s(n)),
                            ("fps", num(c.fps as f64)),
                            ("res", num(c.res as f64)),
                            ("acc", num(*a as f64)),
                        ])
                    })
                    .collect()),
            ),
        ]),
    )?;
    Ok(())
}

/// Table 1: equal vs GPU-proportional bandwidth with a 30/70 GPU split.
pub fn tab1(engine: &Engine, ctx: &ExpContext) -> Result<()> {
    let windows = ctx.windows(4);
    let total_bw = 0.8; // Mbps shared uplink (constrained, as in the paper)
    let gpu_pps = 10_000.0;
    let shares = [0.3, 0.7];
    // Camera A static (starts better), camera B mobile (hit harder and
    // given the larger GPU share to catch up, as in the paper's setup).
    let mounts = [
        Mount::StaticHigh,
        Mount::Mobile {
            waypoints: vec![(0.05, 0.4), (0.95, 0.4)],
            speed: 0.002,
        },
    ];
    let schemes: [(&str, [f64; 2]); 2] = [
        ("equal", [1.0, 1.0]),
        ("proportional", [shares[0], shares[1]]),
    ];
    let mut rows = Vec::new();
    let mut results = Vec::new();
    for (scheme, alphas) in &schemes {
        // Net: both cameras share the uplink; GAIMD alphas per scheme.
        let mut net = NetSim::star(&[50.0, 50.0], total_bw);
        let fa = net.add_camera_flow(0, alphas[0], 0.5)?;
        let fb = net.add_camera_flow(1, alphas[1], 0.5)?;
        net.run(30.0); // converge
        net.reset_delivered();
        net.run(60.0 * windows as f64);
        let delivered = [
            net.delivered_mbit(fa) / windows as f64,
            net.delivered_mbit(fb) / windows as f64,
        ];
        // Retrain each camera with its GPU share and measured bandwidth
        // (two seeds per cell: the effect must clear the eval noise floor).
        let mut accs = Vec::new();
        for i in 0..2 {
            let budget = shares[i] * gpu_pps;
            // Optimal config for the budget from the camera-type heuristic.
            let table = crate::transmission::ProfileTable::heuristic(&mounts[i]);
            let config = table.lookup(budget);
            let mut acc = 0.0;
            for r in 0..2u64 {
                let setup = RetrainSetup {
                    mount: mounts[i].clone(),
                    config,
                    budget_pps: budget,
                    bitrate_mbps: delivered[i] / 60.0,
                    windows,
                    seed: ctx.seed + i as u64 + r * 0x9111,
                };
                acc += retrain_with_config(engine, &setup)? / 2.0;
            }
            accs.push(acc);
        }
        let overall = (accs[0] + accs[1]) / 2.0;
        rows.push(vec![
            scheme.to_string(),
            format!("{:.1}/{:.1} Mbps", delivered[0] / 60.0, delivered[1] / 60.0),
            format!("{:.3}", accs[0]),
            format!("{:.3}", accs[1]),
            format!("{overall:.3}"),
        ]);
        results.push((scheme.to_string(), accs[0], accs[1], overall));
    }
    print_table(
        ctx,
        "Table 1: retraining accuracy, equal vs GPU-proportional bandwidth",
        &["scheme", "bw split", "cam A mAP", "cam B mAP", "overall"],
        &rows,
    );
    ctx.line(format!(
        "shape: paper has proportional > equal overall and B(high-GPU) gains most — \
         got overall {} and B {}",
        if results[1].3 >= results[0].3 { "higher ✓" } else { "LOWER ✗" },
        if results[1].2 >= results[0].2 { "higher ✓" } else { "LOWER ✗" },
    ));
    ctx.save(
        "tab1",
        &obj(vec![
            ("experiment", s("tab1")),
            (
                "schemes",
                arr(results
                    .iter()
                    .map(|(n, a, b, o)| {
                        obj(vec![
                            ("scheme", s(n)),
                            ("camA", num(*a as f64)),
                            ("camB", num(*b as f64)),
                            ("overall", num(*o as f64)),
                        ])
                    })
                    .collect()),
            ),
        ]),
    )?;
    let _ = BUDGET_LEVELS;
    Ok(())
}

//! Fig. 2(c) — the motivation study: three correlated mobile cameras,
//! independent retraining (3 GPUs) vs group retraining (3 GPUs) vs group
//! retraining (1 GPU).

use anyhow::Result;

use crate::api::RunSpec;
use crate::runtime::{Engine, Task};
use crate::scene::scenario;
use crate::server::{Policy, TransmissionKind};
use crate::util::json::{arr, f32s, obj, s};

use super::common::{f3, print_table, run_many, ExpContext};

pub fn fig2c(engine: &Engine, ctx: &ExpContext) -> Result<()> {
    let windows = ctx.windows(8);
    // All settings share the fixed transmission pipeline so the comparison
    // isolates the retraining strategy, exactly as the paper's case study.
    let fixed = TransmissionKind::Fixed { fps: 4.0, res: 32 };
    let mut indep = Policy::ekya();
    indep.transmission = fixed.clone();
    indep.name = "independent-3gpu";
    let mut group3 = Policy::ecco();
    group3.transmission = fixed.clone();
    group3.name = "group-3gpu";
    let mut group1 = Policy::ecco();
    group1.transmission = fixed;
    group1.name = "group-1gpu";

    let settings = [(indep, 3.0), (group3, 3.0), (group1, 1.0)];
    let specs: Vec<RunSpec> = settings
        .into_iter()
        .map(|(policy, gpus)| {
            RunSpec::new(Task::Det, policy)
                .scenario(scenario::convoy(3, ctx.seed))
                .gpus(gpus)
                .shared_mbps(30.0)
                .uplink_mbps(10.0)
                .windows(windows)
                .seed(ctx.seed)
        })
        .collect();
    // The three settings run concurrently; outcomes stay in setting order.
    let outcomes = run_many(engine, specs, ctx.threads)?;

    let header: Vec<String> = (0..windows).map(|w| format!("w{w}")).collect();
    let mut hdr: Vec<&str> = vec!["setting", "steady", "resp(s)"];
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    hdr.extend(hrefs);
    let rows: Vec<Vec<String>> = outcomes
        .iter()
        .map(|o| {
            let mut row = vec![
                o.name.clone(),
                f3(o.steady),
                format!("{:.0}", o.response_s),
            ];
            row.extend(o.window_acc.iter().map(|&a| f3(a)));
            row
        })
        .collect();
    print_table(
        ctx,
        "Fig 2(c): accuracy over time, independent vs group retraining",
        &hdr,
        &rows,
    );

    // Paper shape checks (reported, not asserted): group-3gpu >= indep-3gpu,
    // group-1gpu ~ indep-3gpu.
    ctx.line(format!(
        "shape: group3 {} indep3 (paper: group wins)  |  group1 {:.3} vs indep3 {:.3} (paper: comparable)",
        if outcomes[1].steady >= outcomes[0].steady { ">=" } else { "<" },
        outcomes[2].steady,
        outcomes[0].steady
    ));

    ctx.save(
        "fig2c",
        &obj(vec![
            ("experiment", s("fig2c")),
            (
                "settings",
                arr(outcomes.iter().map(|o| o.to_json()).collect()),
            ),
            (
                "window_acc",
                arr(outcomes.iter().map(|o| f32s(&o.window_acc)).collect()),
            ),
        ]),
    )?;
    Ok(())
}

//! Fig. 12 (natural model reuse within a group) and Fig. 13 (response time
//! under low per-camera uplink bandwidth).

use anyhow::Result;

use crate::api::{RunSpec, Session};
use crate::runtime::{Engine, Task};
use crate::scene::scenario;
use crate::server::Policy;
use crate::util::json::{arr, f32s, num, obj, s};

use super::common::{print_table, run_many, ExpContext};

/// Fig. 12: three cameras of one correlated group issue staggered
/// retraining requests (windows 0 / 2 / 4). Later cameras should start
/// from the partially-retrained group model under ECCO ("natural reuse"),
/// vs RECL's static zoo checkpoint.
pub fn fig12(engine: &Engine, ctx: &ExpContext) -> Result<()> {
    // Joins happen at windows 0/2/4, so at least 6 windows must run.
    let windows = ctx.windows(8).max(6);
    let join_at = [0usize, 2, 4];
    let mut rows = Vec::new();
    let mut json_runs = Vec::new();
    for policy in [Policy::ecco(), Policy::recl(), Policy::ecco_recl()] {
        let name = policy.name;
        let spec = RunSpec::new(Task::Det, policy)
            .scenario(scenario::grouped_static(&[3], 0.05, 5.0, ctx.seed))
            .gpus(2.0)
            .shared_mbps(12.0)
            .uplink_mbps(20.0)
            .windows(windows)
            .seed(ctx.seed)
            .configure(|cfg| cfg.auto_request = false); // scripted joins
        let mut session = Session::new(engine, spec)?;
        let mut initial_acc = vec![f32::NAN; 3];
        let mut series: Vec<Vec<f32>> = vec![Vec::new(); 3];
        for w in 0..windows {
            for (cam, &jw) in join_at.iter().enumerate() {
                if w == jw {
                    session.request_now(cam)?;
                }
            }
            let report = session.step_window()?;
            for cam in 0..3 {
                let acc = report.cam_acc[cam];
                series[cam].push(acc);
                if w == join_at[cam] {
                    initial_acc[cam] = acc; // accuracy right after joining
                }
            }
        }
        rows.push(vec![
            name.to_string(),
            format!("{:.3}", initial_acc[0]),
            format!("{:.3}", initial_acc[1]),
            format!("{:.3}", initial_acc[2]),
        ]);
        json_runs.push(obj(vec![
            ("policy", s(name)),
            ("initial_acc", f32s(&initial_acc)),
            ("series", arr(series.iter().map(|c| f32s(c)).collect())),
        ]));
    }
    print_table(
        ctx,
        "Fig 12: per-camera accuracy at join (staggered requests w0/w2/w4)",
        &["policy", "cam1@w0", "cam2@w2", "cam3@w4"],
        &rows,
    );
    ctx.line(
        "shape: paper has ECCO/ECCO+RECL beating RECL for the LATER cameras (2 and 3) \
         via natural model reuse",
    );
    ctx.save(
        "fig12",
        &obj(vec![("experiment", s("fig12")), ("runs", arr(json_runs))]),
    )?;
    Ok(())
}

/// Fig. 13: mean response time (to the mAP threshold) across cameras as
/// the per-camera uplink shrinks. The (policy x uplink) grid fans out
/// over the fleet driver.
pub fn fig13(engine: &Engine, ctx: &ExpContext) -> Result<()> {
    let windows = ctx.windows(10);
    let uplinks: Vec<f64> = if ctx.fast {
        vec![0.1, 0.5]
    } else {
        vec![0.1, 0.25, 0.5, 1.0]
    };
    let policies = vec![
        Policy::ecco_recl(),
        Policy::ecco(),
        Policy::recl(),
        Policy::ekya(),
    ];
    let specs: Vec<RunSpec> = policies
        .iter()
        .flat_map(|policy| {
            uplinks.iter().map(move |&up| {
                RunSpec::new(Task::Det, policy.clone())
                    .scenario(scenario::grouped_static(&[3], 0.05, 10.0, ctx.seed))
                    .gpus(2.0)
                    .shared_mbps(50.0) // shared link is NOT the constraint here
                    .uplink_mbps(up)
                    .windows(windows)
                    .seed(ctx.seed)
                    .configure(|cfg| cfg.response_threshold = 0.45)
            })
        })
        .collect();
    let outs = run_many(engine, specs, ctx.threads)?;
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for (pi, policy) in policies.iter().enumerate() {
        let mut row = vec![policy.name.to_string()];
        for (ui, &up) in uplinks.iter().enumerate() {
            let out = &outs[pi * uplinks.len() + ui];
            row.push(format!("{:.0}", out.response_s));
            json_rows.push(obj(vec![
                ("policy", s(policy.name)),
                ("uplink", num(up)),
                ("response_s", num(out.response_s)),
                ("satisfied", num(out.satisfied as f64)),
            ]));
        }
        rows.push(row);
    }
    let mut hdr = vec!["policy".to_string()];
    hdr.extend(uplinks.iter().map(|u| format!("{u} Mbps")));
    let hdr_refs: Vec<&str> = hdr.iter().map(|h| h.as_str()).collect();
    print_table(
        ctx,
        "Fig 13: mean response time (s) vs per-camera uplink bandwidth",
        &hdr_refs,
        &rows,
    );
    ctx.line(
        "shape: paper has group retraining (ECCO variants) cutting response time up to 5x \
         at low uplink",
    );
    ctx.save(
        "fig13",
        &obj(vec![("experiment", s("fig13")), ("rows", arr(json_rows))]),
    )?;
    Ok(())
}

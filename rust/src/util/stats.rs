//! Streaming statistics, percentiles, and series helpers used by the
//! metrics module, the bench harness, and the experiment runners.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of a sample set (linear interpolation, q in [0,1]).
/// Rank key for NaN-safe descending/argmax comparisons: NaN maps below
/// every real value (the "NaN ranks last" convention shared by the mAP
/// candidate sort and the transmission profile argmax). Compare the
/// returned keys with `total_cmp` for a total order.
pub fn nan_ranks_last(v: f32) -> f32 {
    if v.is_nan() {
        f32::NEG_INFINITY
    } else {
        v
    }
}

pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn mean_f32(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return f32::NAN;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// Exponentially-weighted moving average (used by drift detection and the
/// AMS-style sampling baseline).
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        Ewma { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Cosine similarity of two equal-length vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f32, 0.0f32, 0.0f32);
    for i in 0..a.len() {
        dot += a[i] * b[i];
        na += a[i] * a[i];
        nb += b[i] * b[i];
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Euclidean (L2) distance.
pub fn l2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f32>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nan_ranks_last_orders_below_everything() {
        assert_eq!(nan_ranks_last(0.3), 0.3);
        assert_eq!(nan_ranks_last(f32::NAN), f32::NEG_INFINITY);
        assert_eq!(nan_ranks_last(-f32::NAN), f32::NEG_INFINITY);
        let mut v = [0.2f32, f32::NAN, 0.9, f32::NEG_INFINITY];
        v.sort_by(|a, b| nan_ranks_last(*b).total_cmp(&nan_ranks_last(*a)));
        assert_eq!(v[0], 0.9);
        assert_eq!(v[1], 0.2);
        // NaN and -inf tie at the bottom (stable order preserved).
        assert!(v[2].is_nan() && v[3] == f32::NEG_INFINITY);
    }

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.var() - direct_var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        for _ in 0..32 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-6);
        assert!((cosine(&[1.0, 1.0], &[-1.0, -1.0]) + 1.0).abs() < 1e-6);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn l2_distance() {
        assert!((l2(&[0.0, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }
}

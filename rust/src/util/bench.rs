//! Hand-rolled microbenchmark harness (criterion is unavailable offline).
//!
//! Usage in a `[[bench]]` target with `harness = false`:
//! ```ignore
//! let mut b = BenchSuite::new("coordinator");
//! b.bench("alloc_step", || { ...workload... });
//! b.finish();
//! ```
//! Reports mean / p50 / p99 wall-time per iteration plus throughput, with a
//! calibration phase that picks an iteration count targeting ~200ms per
//! measurement batch.

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

use super::stats::percentile;

pub use std::hint::black_box;

const TARGET_BATCH: Duration = Duration::from_millis(200);
const SAMPLES: usize = 12;

pub struct BenchSuite {
    name: String,
    results: Vec<(String, f64, f64, f64)>, // (name, mean_ns, p50_ns, p99_ns)
    filter: Option<String>,
}

impl BenchSuite {
    pub fn new(name: &str) -> Self {
        // `cargo bench -- <filter>` passes the filter as an argument.
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-') && a != "--bench");
        println!("\n== bench suite: {name} ==");
        BenchSuite {
            name: name.to_string(),
            results: Vec::new(),
            filter,
        }
    }

    /// Benchmark a closure; its return value is black-boxed.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) {
        if let Some(filter) = &self.filter {
            if !name.contains(filter.as_str()) {
                return;
            }
        }
        // Calibrate: how many iterations fit in TARGET_BATCH?
        let mut iters: u64 = 1;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                bb(f());
            }
            let dt = t0.elapsed();
            if dt >= Duration::from_millis(20) || iters >= 1 << 24 {
                let per = dt.as_nanos().max(1) as f64 / iters as f64;
                iters = ((TARGET_BATCH.as_nanos() as f64 / per).ceil() as u64).max(1);
                break;
            }
            iters *= 4;
        }
        // Measure.
        let mut samples = Vec::with_capacity(SAMPLES);
        for _ in 0..SAMPLES {
            let t0 = Instant::now();
            for _ in 0..iters {
                bb(f());
            }
            samples.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p50 = percentile(&samples, 0.5);
        let p99 = percentile(&samples, 0.99);
        println!(
            "{:<40} {:>12}  p50 {:>12}  p99 {:>12}  ({} iters/sample)",
            name,
            fmt_ns(mean),
            fmt_ns(p50),
            fmt_ns(p99),
            iters
        );
        self.results.push((name.to_string(), mean, p50, p99));
    }

    /// Benchmark with explicit per-iteration timing (for workloads that need
    /// per-iteration setup excluded from the measurement).
    pub fn bench_timed<F: FnMut() -> Duration>(&mut self, name: &str, mut f: F) {
        if let Some(filter) = &self.filter {
            if !name.contains(filter.as_str()) {
                return;
            }
        }
        let mut samples = Vec::with_capacity(SAMPLES);
        for _ in 0..SAMPLES {
            samples.push(f().as_nanos() as f64);
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p50 = percentile(&samples, 0.5);
        let p99 = percentile(&samples, 0.99);
        println!(
            "{:<40} {:>12}  p50 {:>12}  p99 {:>12}  (timed)",
            name,
            fmt_ns(mean),
            fmt_ns(p50),
            fmt_ns(p99)
        );
        self.results.push((name.to_string(), mean, p50, p99));
    }

    /// Print a summary table; call at the end of the bench main().
    pub fn finish(self) {
        println!("-- {} done: {} benchmarks --\n", self.name, self.results.len());
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with('s'));
    }
}

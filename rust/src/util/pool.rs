//! Scoped worker pool with **index-ordered reduction** (std-only).
//!
//! The coordinator's hot loops fan out dozens-to-hundreds of independent
//! `eval_model` calls per window (candidate evals in request placement,
//! per-member job evals, the per-camera window pass, the full regroup
//! matrix) and the experiment drivers fan out whole runs. This module is
//! the one concurrency primitive they all share:
//!
//! * built on [`std::thread::scope`] so workers may borrow the caller's
//!   stack (no `'static` bounds, no channels, no extra dependencies);
//! * work is handed out by an atomic cursor (cheap dynamic balancing);
//! * results are written back **by item index**, so the reduced `Vec` is
//!   identical to the serial `items.iter().map(f).collect()` — byte for
//!   byte — at any thread count. Determinism tests rely on this.
//!
//! `threads <= 1` (or a single item) short-circuits to a plain serial map
//! on the caller thread, so a pool size of 1 has zero overhead and zero
//! behavioural difference.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Default worker count: the `ECCO_THREADS` environment variable when set
/// (CI pins this to 1), otherwise the machine's available parallelism,
/// capped at 8 (eval items are coarse; more workers only add contention).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("ECCO_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

/// Eval workers each of `runs` concurrent runs should use when a fleet
/// driver runs them on `fleet_threads` workers: the machine's budget
/// divided by the actual fleet concurrency, floored at 1. One definition
/// so `api::run_fleet` and the scripted exp fan-outs can't drift apart.
pub fn per_run_threads(fleet_threads: usize, runs: usize) -> usize {
    let fleet_workers = fleet_threads.max(1).min(runs.max(1));
    (default_threads() / fleet_workers).max(1)
}

/// Map `f` over `items` on up to `threads` workers; the result vector is
/// index-ordered (`out[i] == f(i, &items[i])`) regardless of thread count.
///
/// Panics in `f` propagate to the caller when the scope joins.
pub fn map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    let workers = threads.max(1).min(n);
    if workers <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut init: Vec<Option<R>> = Vec::with_capacity(n);
    init.resize_with(n, || None);
    let slots = Mutex::new(init);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                slots.lock().expect("pool slots poisoned")[i] = Some(r);
            });
        }
    });
    slots
        .into_inner()
        .expect("pool slots poisoned")
        .into_iter()
        .map(|r| r.expect("every slot filled by a worker"))
        .collect()
}

/// Fallible [`map`]: runs every item, then surfaces the **lowest-index**
/// error (deterministic regardless of which worker failed first).
pub fn try_map<T, R, E, F>(threads: usize, items: &[T], f: F) -> Result<Vec<R>, E>
where
    T: Sync,
    R: Send,
    E: Send,
    F: Fn(usize, &T) -> Result<R, E> + Sync,
{
    map(threads, items, f).into_iter().collect()
}

/// [`map`] over owned items (each consumed exactly once by one worker);
/// used by the fleet driver, where each item is a whole run spec.
pub fn map_owned<T, R, F>(threads: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let workers = threads.max(1).min(n);
    if workers <= 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let handoff: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let cursor = AtomicUsize::new(0);
    let mut init: Vec<Option<R>> = Vec::with_capacity(n);
    init.resize_with(n, || None);
    let slots = Mutex::new(init);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = handoff[i]
                    .lock()
                    .expect("pool handoff poisoned")
                    .take()
                    .expect("item taken twice");
                let r = f(i, item);
                slots.lock().expect("pool slots poisoned")[i] = Some(r);
            });
        }
    });
    slots
        .into_inner()
        .expect("pool slots poisoned")
        .into_iter()
        .map(|r| r.expect("every slot filled by a worker"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn map_is_index_ordered_at_any_thread_count() {
        let items: Vec<usize> = (0..37).collect();
        let serial = map(1, &items, |i, &x| i * 1000 + x * x);
        for threads in [2, 3, 4, 16] {
            let par = map(threads, &items, |i, &x| i * 1000 + x * x);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn per_run_threads_divides_the_budget() {
        let budget = default_threads();
        assert_eq!(per_run_threads(1, 10), budget, "sequential fleet keeps full budget");
        assert_eq!(
            per_run_threads(100, 2),
            (budget / 2).max(1),
            "fleet workers clamp to the run count before dividing"
        );
        assert_eq!(per_run_threads(0, 0), budget, "degenerate inputs stay sane");
        assert!(per_run_threads(budget, 1000) >= 1);
    }

    #[test]
    fn map_handles_empty_and_single() {
        let empty: Vec<u32> = Vec::new();
        assert!(map(4, &empty, |_, &x| x).is_empty());
        assert_eq!(map(4, &[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn try_map_surfaces_lowest_index_error() {
        let items: Vec<usize> = (0..20).collect();
        let r = try_map(4, &items, |i, &x| {
            if x % 7 == 3 {
                Err(format!("bad {i}"))
            } else {
                Ok(x)
            }
        });
        // Items 3, 10, 17 all fail; the reported error must be item 3's.
        assert_eq!(r.unwrap_err(), "bad 3");
    }

    #[test]
    fn map_owned_consumes_each_item_once() {
        let items: Vec<String> = (0..11).map(|i| format!("s{i}")).collect();
        let out = map_owned(4, items, |i, s| format!("{i}:{s}"));
        let want: Vec<String> = (0..11).map(|i| format!("{i}:s{i}")).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn prop_pool_matches_serial_map() {
        prop::check("pool-matches-serial", 30, |g| {
            let n = g.usize(0, 64);
            let threads = g.usize(1, 9);
            let items: Vec<u64> = (0..n).map(|_| g.rng.next_u64() % 1_000_000).collect();
            let f = |i: usize, &x: &u64| x.wrapping_mul(31).wrapping_add(i as u64);
            let serial: Vec<u64> = items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
            let par = map(threads, &items, f);
            if par != serial {
                return Err(format!("mismatch at n={n} threads={threads}"));
            }
            Ok(())
        });
    }
}

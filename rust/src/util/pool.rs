//! Persistent worker pool with **index-ordered reduction** (std-only).
//!
//! The coordinator's hot loops fan out dozens-to-hundreds of independent
//! `eval_model` calls per window (candidate evals in request placement,
//! per-member job evals, the per-camera window pass, the full regroup
//! matrix), the sharded native kernels fan out the batch dimension of
//! every train/infer call, and the experiment drivers fan out whole runs.
//! This module is the one concurrency primitive they all share.
//!
//! # Design
//!
//! A [`Pool`] owns a fixed set of **persistent, parked worker threads**
//! (spawned once, woken by condvar when work arrives). Earlier revisions
//! spawned fresh `std::thread::scope` threads per map call; eval items are
//! ms-scale and kernel shards are sub-ms, so the spawn/join cost was pure
//! overhead on the micro-window hot path. The execution contract:
//!
//! * work is handed out by an **atomic cursor** (cheap dynamic balancing);
//! * results are written back **by item index** into per-slot cells — one
//!   writer per slot, no shared result lock — so the reduced `Vec` is
//!   identical to the serial `items.iter().map(f).collect()`, byte for
//!   byte, at any thread count. Determinism tests rely on this;
//! * the **submitting caller participates**: it drains the same cursor
//!   from its own thread, then waits only for items still in flight on
//!   workers. This also makes nested maps (a pool worker submitting a
//!   sub-map onto the same pool) deadlock-free by construction — a
//!   saturated pool degrades to the caller running its own items serially;
//! * fan-outs below [`SERIAL_BELOW`] items (or `threads <= 1`) run as a
//!   plain serial map on the caller with zero pool interaction and zero
//!   behavioural difference.
//!
//! Lifecycle: the engine owns a pool for its whole life (workers park
//! between windows and die when the engine is dropped); the module-level
//! [`map`]/[`try_map`]/[`map_owned`] helpers share one lazily-spawned
//! process-global pool for engine-less callers (benches, tests).

use std::any::Any;
use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

use crate::util::sync::{plock, pwait, pwait_timeout};

/// Fan-outs below this many items skip the pool entirely: the
/// handout/notify overhead cannot be amortised over a single item.
const SERIAL_BELOW: usize = 2;

/// Default worker count: the `ECCO_THREADS` environment variable when set
/// (CI pins this to 1 and 4), otherwise the machine's available
/// parallelism, capped at 8 (eval items are coarse; more workers only add
/// contention). An unparsable override is ignored **loudly** — a one-time
/// warning — so a typo'd CI pin can't silently fall back to machine
/// parallelism and masquerade as a determinism bug.
pub fn default_threads() -> usize {
    match std::env::var("ECCO_THREADS") {
        Ok(raw) => match parse_thread_override(&raw) {
            Some(n) => n,
            None => {
                warn_bad_override_once(&raw);
                machine_parallelism()
            }
        },
        Err(_) => machine_parallelism(),
    }
}

/// Parse an `ECCO_THREADS` override: a base-10 integer, floored at 1.
/// Empty and garbage values yield `None` (the caller warns and falls back
/// to the machine default).
pub(crate) fn parse_thread_override(raw: &str) -> Option<usize> {
    raw.trim().parse::<usize>().ok().map(|n| n.max(1))
}

fn machine_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

fn warn_bad_override_once(raw: &str) {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        crate::util::logger::log(
            crate::util::logger::Level::Warn,
            module_path!(),
            &format!(
                "ignoring unparsable ECCO_THREADS={raw:?}; \
                 using machine parallelism ({})",
                machine_parallelism()
            ),
        );
    });
}

/// Eval workers each of `runs` concurrent runs should use when a fleet
/// driver runs them on `fleet_threads` workers: the machine's budget
/// divided by the actual fleet concurrency, floored at 1. One definition
/// so `api::run_fleet` and the scripted exp fan-outs can't drift apart.
pub fn per_run_threads(fleet_threads: usize, runs: usize) -> usize {
    let fleet_workers = fleet_threads.max(1).min(runs.max(1));
    (default_threads() / fleet_workers).max(1)
}

// ---------------------------------------------------------------------------
// The persistent pool
// ---------------------------------------------------------------------------

/// One fan-out in flight on the pool.
///
/// The closure is reached through a type-erased `(call, ctx)` pair rather
/// than a trait object so no fat-pointer lifetime juggling is needed: the
/// submitting caller blocks in [`Pool::run_job`] until `done == n`, which
/// keeps the closure (and everything it borrows) alive for as long as any
/// worker can possibly touch `ctx`.
struct Job {
    /// Monomorphised trampoline: `call(ctx, i)` runs item `i`.
    call: unsafe fn(*const (), usize),
    ctx: *const (),
    n: usize,
    /// Next item index to hand out.
    cursor: AtomicUsize,
    /// Items fully finished (incremented *after* the item ran or unwound);
    /// `done == n` is the completion signal.
    done: AtomicUsize,
    /// Threads currently working this job (the caller counts as one).
    active: AtomicUsize,
    /// Concurrency cap for this job: caller + extra pool workers.
    max_workers: usize,
    /// First panic payload from any item; rethrown on the caller.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    /// Completion handshake for the caller's final wait.
    wait: Mutex<()>,
    cv: Condvar,
}

// SAFETY: `ctx` points at a `Sync` closure owned by the stack frame of
// `Pool::run_job`, which does not return before every handed-out item has
// finished (`done == n`), so moving an `Arc<Job>` (and the raw `ctx`
// pointer inside it) to a worker thread cannot let `ctx` outlive the
// closure it points at.
unsafe impl Send for Job {}

// SAFETY: every `Job` field is atomic, lock-guarded, or part of the
// read-only `(call, ctx, n, max_workers)` descriptor of a `Sync` closure,
// so concurrent `&Job` access from the caller and workers is sound.
unsafe impl Sync for Job {}

impl Job {
    fn exhausted(&self) -> bool {
        self.cursor.load(Ordering::Relaxed) >= self.n
    }

    /// Drain the cursor from the current thread, recording panics. The
    /// `done` increment uses release ordering so the caller's acquire load
    /// of `done == n` sees every slot write.
    fn work(&self) {
        loop {
            let i = self.cursor.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                break;
            }
            // SAFETY: `call` is the trampoline monomorphised for the
            // closure `ctx` points at, and `run_job` keeps that closure
            // alive on its stack until `done == n` (see the `Job` safety
            // comments above).
            let call = AssertUnwindSafe(|| unsafe { (self.call)(self.ctx, i) });
            let outcome = panic::catch_unwind(call);
            if let Err(payload) = outcome {
                let mut slot = plock(&self.panic);
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            if self.done.fetch_add(1, Ordering::AcqRel) + 1 == self.n {
                // Lock-then-notify handshake with `run_job`'s final wait:
                // the waiter re-checks `done` under this lock, so the
                // wakeup cannot be lost.
                drop(plock(&self.wait));
                self.cv.notify_all();
            }
        }
    }
}

struct PoolQueue {
    jobs: VecDeque<Arc<Job>>,
    shutdown: bool,
}

struct PoolShared {
    queue: Mutex<PoolQueue>,
    /// Wakes parked workers on job arrival or shutdown.
    cv: Condvar,
}

fn worker_loop(shared: &PoolShared) {
    let mut q = plock(&shared.queue);
    loop {
        // Drop finished jobs, then join the first one with spare slots.
        q.jobs.retain(|j| !j.exhausted());
        let picked = q.jobs.iter().find_map(|j| {
            if j.active.fetch_add(1, Ordering::Relaxed) < j.max_workers {
                Some(j.clone())
            } else {
                j.active.fetch_sub(1, Ordering::Relaxed);
                None
            }
        });
        match picked {
            Some(job) => {
                drop(q);
                job.work();
                job.active.fetch_sub(1, Ordering::Relaxed);
                q = plock(&shared.queue);
            }
            None if q.shutdown => return,
            None => q = pwait(&shared.cv, q),
        }
    }
}

/// A persistent set of parked worker threads plus the job queue they
/// serve. See the module docs for the execution contract.
pub struct Pool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Pool {
    /// Spawn `workers` parked worker threads. Zero workers is valid (every
    /// map runs serially on the caller), which is what `ECCO_THREADS=1`
    /// produces.
    pub fn new(workers: usize) -> Pool {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(PoolQueue {
                jobs: VecDeque::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let workers = (0..workers)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("ecco-pool-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawning pool worker")
            })
            .collect();
        Pool { shared, workers }
    }

    /// A shared zero-worker pool: maps on it always run serially on the
    /// caller thread. For tests and explicitly-serial call sites.
    pub fn serial() -> &'static Pool {
        static SERIAL: OnceLock<Pool> = OnceLock::new();
        SERIAL.get_or_init(|| Pool::new(0))
    }

    /// Worker threads owned by this pool (the caller participates on top).
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Maximum concurrency a map on this pool can reach: the owned workers
    /// plus the submitting caller.
    pub fn parallelism(&self) -> usize {
        self.workers.len() + 1
    }

    /// Submit a job of `n` items, drain it from the calling thread, then
    /// wait for items in flight on workers; rethrows the first item panic.
    fn run_job<F: Fn(usize) + Sync>(&self, n: usize, extra_workers: usize, f: &F) {
        /// Monomorphised trampoline back from the erased context pointer.
        ///
        /// # Safety
        ///
        /// `ctx` must be the `*const F` that `run_job` erased from `f`,
        /// and the closure it points at must be alive for the whole call
        /// — both guaranteed by `run_job`, which borrows `f` on its stack
        /// and does not return until `done == n`.
        unsafe fn trampoline<F: Fn(usize)>(ctx: *const (), i: usize) {
            (*(ctx as *const F))(i);
        }
        let job = Arc::new(Job {
            call: trampoline::<F>,
            ctx: f as *const F as *const (),
            n,
            cursor: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            active: AtomicUsize::new(1), // the caller
            max_workers: extra_workers.saturating_add(1),
            panic: Mutex::new(None),
            wait: Mutex::new(()),
            cv: Condvar::new(),
        });
        {
            let mut q = plock(&self.shared.queue);
            q.jobs.push_back(job.clone());
        }
        self.shared.cv.notify_all();
        // The caller is worker zero.
        job.work();
        // Wait for stragglers on pool workers. The timeout is pure
        // belt-and-braces: the lock-then-notify handshake in `Job::work`
        // already rules out lost wakeups.
        {
            let mut g = plock(&job.wait);
            while job.done.load(Ordering::Acquire) < job.n {
                g = pwait_timeout(&job.cv, g, Duration::from_millis(1)).0;
            }
        }
        // Remove our queue entry if no worker got around to it.
        {
            let mut q = plock(&self.shared.queue);
            q.jobs.retain(|j| !Arc::ptr_eq(j, &job));
        }
        let payload = plock(&job.panic).take();
        if let Some(p) = payload {
            panic::resume_unwind(p);
        }
    }

    /// Map `f` over `items` on up to `threads` concurrent threads (the
    /// caller plus `threads - 1` pool workers); the result vector is
    /// index-ordered (`out[i] == f(i, &items[i])`) regardless of thread
    /// count. Panics in `f` propagate to the caller after the fan-out
    /// settles.
    pub fn map<T, R, F>(&self, threads: usize, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        self.map_n(threads, items.len(), |i| f(i, &items[i]))
    }

    /// [`Pool::map`] over the index range `0..n` (the sharded kernels'
    /// shape: the items are implicit in the closure's captures).
    pub fn map_n<R, F>(&self, threads: usize, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let workers = threads.max(1).min(n);
        if workers <= 1 || n < SERIAL_BELOW {
            return (0..n).map(f).collect();
        }
        let slots: Vec<Slot<R>> = (0..n).map(|_| Slot::empty()).collect();
        let runner = |i: usize| {
            let r = f(i);
            // SAFETY: the cursor hands index `i` to exactly one thread.
            unsafe { *slots[i].0.get() = Some(r) };
        };
        self.run_job(n, workers - 1, &runner);
        slots
            .into_iter()
            .map(|s| s.0.into_inner().expect("every slot filled by a worker"))
            .collect()
    }

    /// Fallible [`Pool::map`]: runs every item, then surfaces the
    /// **lowest-index** error (deterministic regardless of which worker
    /// failed first).
    pub fn try_map<T, R, E, F>(&self, threads: usize, items: &[T], f: F) -> Result<Vec<R>, E>
    where
        T: Sync,
        R: Send,
        E: Send,
        F: Fn(usize, &T) -> Result<R, E> + Sync,
    {
        self.map(threads, items, f).into_iter().collect()
    }

    /// [`Pool::map`] over owned items (each consumed exactly once by one
    /// thread); used by the fleet driver, where each item is a whole run
    /// spec.
    pub fn map_owned<T, R, F>(&self, threads: usize, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        let n = items.len();
        let workers = threads.max(1).min(n);
        if workers <= 1 || n < SERIAL_BELOW {
            return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let src: Vec<Slot<T>> = items.into_iter().map(Slot::filled).collect();
        let slots: Vec<Slot<R>> = (0..n).map(|_| Slot::empty()).collect();
        let runner = |i: usize| {
            // SAFETY: the cursor hands index `i` to exactly one thread, so
            // each source item is taken exactly once.
            let item = unsafe { (*src[i].0.get()).take().expect("item taken twice") };
            let r = f(i, item);
            // SAFETY: same index partition — this thread is the only
            // writer of result slot `i`.
            unsafe { *slots[i].0.get() = Some(r) };
        };
        self.run_job(n, workers - 1, &runner);
        slots
            .into_iter()
            .map(|s| s.0.into_inner().expect("every slot filled by a worker"))
            .collect()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut q = plock(&self.shared.queue);
            q.shutdown = true;
        }
        self.shared.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// A single-writer result cell: the atomic cursor guarantees exactly one
/// thread touches each index, so no per-slot lock is needed (the old
/// implementation funnelled every completion through one `Mutex<Vec<_>>`,
/// serialising write-backs).
struct Slot<V>(UnsafeCell<Option<V>>);

/// # Safety
///
/// `Slot`s are only shared during a pool map, where the atomic cursor
/// partitions item indices: exactly one thread touches each slot's cell,
/// and the caller reads results only after its acquire load of `done == n`
/// pairs with the workers' release increments. The contained value crosses
/// threads by move, hence `V: Send`.
// SAFETY: see the `# Safety` contract above — single writer per slot,
// reads ordered after all writes by the done-counter acquire/release pair.
unsafe impl<V: Send> Sync for Slot<V> {}

impl<V> Slot<V> {
    fn empty() -> Slot<V> {
        Slot(UnsafeCell::new(None))
    }

    fn filled(v: V) -> Slot<V> {
        Slot(UnsafeCell::new(Some(v)))
    }
}

// ---------------------------------------------------------------------------
// Module-level helpers over the process-global pool
// ---------------------------------------------------------------------------

/// The process-global pool backing the module-level helpers, sized so
/// caller + workers equals [`default_threads`]. Spawned on first use;
/// engine-owned code paths use the engine's own pool instead.
fn global() -> &'static Pool {
    static GLOBAL: OnceLock<Pool> = OnceLock::new();
    GLOBAL.get_or_init(|| Pool::new(default_threads().saturating_sub(1)))
}

/// [`Pool::map`] on the process-global pool.
pub fn map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    global().map(threads, items, f)
}

/// [`Pool::try_map`] on the process-global pool.
pub fn try_map<T, R, E, F>(threads: usize, items: &[T], f: F) -> Result<Vec<R>, E>
where
    T: Sync,
    R: Send,
    E: Send,
    F: Fn(usize, &T) -> Result<R, E> + Sync,
{
    global().try_map(threads, items, f)
}

/// [`Pool::map_owned`] on the process-global pool.
pub fn map_owned<T, R, F>(threads: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    global().map_owned(threads, items, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn map_is_index_ordered_at_any_thread_count() {
        let items: Vec<usize> = (0..37).collect();
        let serial = map(1, &items, |i, &x| i * 1000 + x * x);
        for threads in [2, 3, 4, 16] {
            let par = map(threads, &items, |i, &x| i * 1000 + x * x);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn per_run_threads_divides_the_budget() {
        let budget = default_threads();
        assert_eq!(per_run_threads(1, 10), budget, "sequential fleet keeps full budget");
        assert_eq!(
            per_run_threads(100, 2),
            (budget / 2).max(1),
            "fleet workers clamp to the run count before dividing"
        );
        assert_eq!(per_run_threads(0, 0), budget, "degenerate inputs stay sane");
        assert!(per_run_threads(budget, 1000) >= 1);
    }

    #[test]
    fn thread_override_parsing_covers_empty_and_garbage() {
        assert_eq!(parse_thread_override("4"), Some(4));
        assert_eq!(parse_thread_override("  4  "), Some(4));
        assert_eq!(parse_thread_override("0"), Some(1), "zero floors to one");
        assert_eq!(parse_thread_override(""), None, "empty value is rejected");
        assert_eq!(parse_thread_override("   "), None);
        assert_eq!(parse_thread_override("four"), None, "garbage is rejected");
        assert_eq!(parse_thread_override("4x"), None);
        assert_eq!(parse_thread_override("-2"), None);
    }

    #[test]
    fn map_handles_empty_and_single() {
        let empty: Vec<u32> = Vec::new();
        assert!(map(4, &empty, |_, &x| x).is_empty());
        assert_eq!(map(4, &[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn try_map_surfaces_lowest_index_error() {
        let items: Vec<usize> = (0..20).collect();
        let r = try_map(4, &items, |i, &x| {
            if x % 7 == 3 {
                Err(format!("bad {i}"))
            } else {
                Ok(x)
            }
        });
        // Items 3, 10, 17 all fail; the reported error must be item 3's.
        assert_eq!(r.unwrap_err(), "bad 3");
    }

    #[test]
    fn map_owned_consumes_each_item_once() {
        let items: Vec<String> = (0..11).map(|i| format!("s{i}")).collect();
        let out = map_owned(4, items, |i, s| format!("{i}:{s}"));
        let want: Vec<String> = (0..11).map(|i| format!("{i}:s{i}")).collect();
        assert_eq!(out, want);
    }

    #[test]
    #[cfg_attr(miri, ignore = "200-iteration stress loop is too slow under Miri")]
    fn persistent_pool_reuses_workers_across_many_maps() {
        // Hundreds of small maps on one explicit pool: exercises the
        // park/wake path the per-call scoped spawns never had.
        let pool = Pool::new(3);
        assert_eq!(pool.workers(), 3);
        assert_eq!(pool.parallelism(), 4);
        let items: Vec<u64> = (0..23).collect();
        let want: Vec<u64> = items.iter().enumerate().map(|(i, x)| x * 3 + i as u64).collect();
        for _ in 0..200 {
            let got = pool.map(4, &items, |i, &x| x * 3 + i as u64);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn zero_worker_pool_runs_on_the_caller() {
        let pool = Pool::new(0);
        let items: Vec<u32> = (0..9).collect();
        assert_eq!(
            pool.map(8, &items, |_, &x| x + 1),
            (1..10).collect::<Vec<u32>>()
        );
    }

    #[test]
    fn nested_maps_on_one_pool_make_progress() {
        // A worker (or the caller) submitting a sub-map onto the same pool
        // must never deadlock: the submitter drains its own cursor.
        let pool = Pool::new(2);
        let outer: Vec<usize> = (0..8).collect();
        let got = pool.map(3, &outer, |_, &i| {
            let inner: Vec<usize> = (0..6).collect();
            pool.map(3, &inner, |_, &j| i * 10 + j).iter().sum::<usize>()
        });
        let want: Vec<usize> = (0..8).map(|i| (0..6).map(|j| i * 10 + j).sum()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn pool_survives_item_panics() {
        let pool = Pool::new(2);
        let items: Vec<u32> = (0..12).collect();
        let caught = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.map(3, &items, |_, &x| {
                if x == 5 {
                    panic!("boom");
                }
                x
            })
        }));
        assert!(caught.is_err(), "item panic must propagate to the caller");
        // The pool stays fully usable afterwards.
        assert_eq!(pool.map(3, &items, |_, &x| x * 2)[5], 10);
    }

    #[test]
    #[cfg_attr(miri, ignore = "30 randomised property cases are too slow under Miri")]
    fn prop_pool_matches_serial_map() {
        prop::check("pool-matches-serial", 30, |g| {
            let n = g.usize(0, 64);
            let threads = g.usize(1, 9);
            let items: Vec<u64> = (0..n).map(|_| g.rng.next_u64() % 1_000_000).collect();
            let f = |i: usize, &x: &u64| x.wrapping_mul(31).wrapping_add(i as u64);
            let serial: Vec<u64> = items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
            let par = map(threads, &items, f);
            if par != serial {
                return Err(format!("mismatch at n={n} threads={threads}"));
            }
            Ok(())
        });
    }
}

//! Foundation substrates: RNG, JSON, CLI parsing, logging, statistics,
//! property testing, and a microbenchmark harness.
//!
//! These replace `rand` / `serde` / `clap` / `log` / `proptest` /
//! `criterion`, none of which are available in the offline build
//! environment; each is implemented from scratch and unit-tested.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logger;
pub mod prop;
pub mod rng;
pub mod stats;

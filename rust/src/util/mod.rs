//! Foundation substrates: RNG, JSON, CLI parsing, logging, statistics,
//! property testing, a microbenchmark harness, a persistent worker pool,
//! and poison-tolerant lock helpers.
//!
//! These replace `rand` / `serde` / `clap` / `log` / `proptest` /
//! `criterion` / `rayon`, none of which are available in the offline
//! build environment; each is implemented from scratch and unit-tested.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logger;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;

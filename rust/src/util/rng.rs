//! Deterministic pseudo-random number generation (PCG-32).
//!
//! The `rand` crate is unavailable offline, and the simulators need
//! reproducible streams anyway: every component (scene, network, teacher
//! noise) owns a seeded [`Pcg32`] so experiment runs are bit-stable across
//! machines and reruns.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small, fast, statistically solid for
/// simulation workloads.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: seed-only constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator (for per-entity streams).
    pub fn fork(&mut self, stream: u64) -> Pcg32 {
        Pcg32::new(self.next_u64(), stream.wrapping_mul(2654435769).wrapping_add(1))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n) using Lemire's method (unbiased enough for
    /// simulation; exact rejection not worth the cycles here).
    pub fn below(&mut self, n: u32) -> u32 {
        ((self.next_u32() as u64 * n as u64) >> 32) as u32
    }

    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0 && n <= u32::MAX as usize);
        self.below(n as u32) as usize
    }

    /// Standard normal via Box-Muller (no caching; called rarely per tick).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-7);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        if total <= 0.0 {
            return self.index(weights.len());
        }
        let mut t = self.f32() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// The 32-bit LCG shared with `python/compile/aot.py` for golden-test input
/// generation: `x' = 1664525 x + 1013904223 (mod 2^32)`, output `x'/2^32`.
#[derive(Debug, Clone)]
pub struct GoldenLcg {
    state: u32,
}

impl GoldenLcg {
    pub fn new(seed: u32) -> Self {
        GoldenLcg { state: seed }
    }

    pub fn next_f32(&mut self) -> f32 {
        self.state = self
            .state
            .wrapping_mul(1664525)
            .wrapping_add(1013904223);
        self.state as f32 / 4294967296.0
    }

    pub fn fill(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_f32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 2);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(3);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Pcg32::seeded(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f32() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg32::seeded(6);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn weighted_prefers_heavy_index() {
        let mut r = Pcg32::seeded(7);
        let w = [0.05, 0.9, 0.05];
        let hits = (0..10_000).filter(|_| r.weighted(&w) == 1).count();
        assert!(hits > 8_000, "hits={hits}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(8);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn golden_lcg_matches_python_reference() {
        // First values of the python LCG with seed 7 (computed analytically).
        let mut g = GoldenLcg::new(7);
        let v0 = g.next_f32();
        let expect = ((7u64 * 1664525 + 1013904223) % (1u64 << 32)) as f32 / 4294967296.0;
        assert!((v0 - expect).abs() < 1e-9);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg32::seeded(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 2);
    }
}

//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Grammar: `ecco <subcommand> [positional...] [--key value | --key=value |
//! --flag]`. Typed accessors with defaults keep experiment runners terse.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = body.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(body.to_string(), v);
                } else {
                    args.flags.push(body.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        Ok(self.f64_or(name, default as f64)? as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["exp", "fig6det", "extra"]);
        assert_eq!(a.command.as_deref(), Some("exp"));
        assert_eq!(a.positional, vec!["fig6det", "extra"]);
    }

    #[test]
    fn options_both_styles() {
        let a = parse(&["run", "--gpus", "4", "--bw=6.0"]);
        assert_eq!(a.usize_or("gpus", 1).unwrap(), 4);
        assert_eq!(a.f64_or("bw", 0.0).unwrap(), 6.0);
    }

    #[test]
    fn flags_without_values() {
        let a = parse(&["run", "--verbose", "--gpus", "2", "--dry-run"]);
        assert!(a.flag("verbose"));
        assert!(a.flag("dry-run"));
        assert!(!a.flag("gpus"));
        assert_eq!(a.usize_or("gpus", 0).unwrap(), 2);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["run"]);
        assert_eq!(a.usize_or("cams", 6).unwrap(), 6);
        assert_eq!(a.str_or("task", "det"), "det");
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&["run", "--gpus", "four"]);
        assert!(a.usize_or("gpus", 1).is_err());
    }
}

//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Grammar: `ecco <subcommand> [positional...] [--key value | --key=value |
//! --flag]`. Typed accessors with defaults keep experiment runners terse.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = body.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(body.to_string(), v);
                } else {
                    args.flags.push(body.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        Ok(self.f64_or(name, default as f64)? as f32)
    }

    /// Fix up greedy parsing for known value-less flags: `exp --fast
    /// fig6det` parses as option `fast = "fig6det"` because the grammar
    /// cannot know flag names; this moves the name back to the flag list
    /// and the swallowed token back to the positionals. Call before
    /// [`Args::reject_unknown`] on subcommands that take flags.
    /// (The recovered token is appended, so mixing a mid-line flag with
    /// *multiple* positionals can reorder them — no current subcommand
    /// takes more than one.)
    pub fn normalize_flags(&mut self, known_flags: &[&str]) {
        for &flag in known_flags {
            if let Some(value) = self.options.remove(flag) {
                self.flags.push(flag.to_string());
                self.positional.push(value);
            }
        }
    }

    /// Reject any `--option`/`--flag` this subcommand does not know, with a
    /// "did you mean" hint — previously `--windws 20` silently ran the
    /// default. A known flag given a value (or vice versa) is also caught.
    pub fn reject_unknown(&self, known_options: &[&str], known_flags: &[&str]) -> Result<()> {
        let all: Vec<&str> = known_options.iter().chain(known_flags).copied().collect();
        for key in self.options.keys() {
            if known_options.contains(&key.as_str()) {
                continue;
            }
            if known_flags.contains(&key.as_str()) {
                bail!("--{key} does not take a value");
            }
            bail!("{}", unknown_message("option", key, &all));
        }
        for flag in &self.flags {
            if known_flags.contains(&flag.as_str()) {
                continue;
            }
            if known_options.contains(&flag.as_str()) {
                bail!("--{flag} expects a value");
            }
            bail!("{}", unknown_message("flag", flag, &all));
        }
        Ok(())
    }
}

/// Error text for an unknown option, with a nearest-candidate hint when one
/// is plausibly a typo (edit distance <= 2, or a shared prefix).
fn unknown_message(kind: &str, name: &str, candidates: &[&str]) -> String {
    match suggest(name, candidates) {
        Some(hint) => format!("unknown {kind} --{name} (did you mean --{hint}?)"),
        None if candidates.is_empty() => {
            format!("unknown {kind} --{name} (this subcommand takes none)")
        }
        None => format!(
            "unknown {kind} --{name} (known: {})",
            candidates
                .iter()
                .map(|c| format!("--{c}"))
                .collect::<Vec<_>>()
                .join(", ")
        ),
    }
}

/// Closest candidate within edit distance 2 (ties broken by listing order).
fn suggest<'a>(name: &str, candidates: &[&'a str]) -> Option<&'a str> {
    candidates
        .iter()
        .map(|&c| (edit_distance(name, c), c))
        .filter(|&(d, _)| d <= 2)
        .min_by_key(|&(d, _)| d)
        .map(|(_, c)| c)
}

/// Classic Levenshtein distance (small strings; O(len^2) is fine).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, &ca) in a.iter().enumerate() {
        let mut row = vec![i + 1];
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            row.push(sub.min(prev[j + 1] + 1).min(row[j] + 1));
        }
        prev = row;
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["exp", "fig6det", "extra"]);
        assert_eq!(a.command.as_deref(), Some("exp"));
        assert_eq!(a.positional, vec!["fig6det", "extra"]);
    }

    #[test]
    fn options_both_styles() {
        let a = parse(&["run", "--gpus", "4", "--bw=6.0"]);
        assert_eq!(a.usize_or("gpus", 1).unwrap(), 4);
        assert_eq!(a.f64_or("bw", 0.0).unwrap(), 6.0);
    }

    #[test]
    fn flags_without_values() {
        let a = parse(&["run", "--verbose", "--gpus", "2", "--dry-run"]);
        assert!(a.flag("verbose"));
        assert!(a.flag("dry-run"));
        assert!(!a.flag("gpus"));
        assert_eq!(a.usize_or("gpus", 0).unwrap(), 2);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["run"]);
        assert_eq!(a.usize_or("cams", 6).unwrap(), 6);
        assert_eq!(a.str_or("task", "det"), "det");
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&["run", "--gpus", "four"]);
        assert!(a.usize_or("gpus", 1).is_err());
    }

    #[test]
    fn unknown_option_suggests_nearest() {
        let a = parse(&["run", "--windws", "20"]);
        let err = a
            .reject_unknown(&["windows", "gpus", "seed"], &["fast"])
            .unwrap_err()
            .to_string();
        assert!(err.contains("--windws"), "{err}");
        assert!(err.contains("did you mean --windows"), "{err}");
    }

    #[test]
    fn unknown_option_without_close_match_lists_known() {
        let a = parse(&["run", "--zzz", "1"]);
        let err = a
            .reject_unknown(&["windows", "gpus"], &[])
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown option --zzz"), "{err}");
        assert!(err.contains("--windows"), "{err}");
    }

    #[test]
    fn known_names_pass_and_kind_mismatch_errors() {
        let a = parse(&["run", "--gpus", "2", "--fast"]);
        assert!(a.reject_unknown(&["gpus"], &["fast"]).is_ok());
        // A flag used with a value is caught...
        let b = parse(&["run", "--fast", "yes"]);
        let err = b.reject_unknown(&["gpus"], &["fast"]).unwrap_err().to_string();
        assert!(err.contains("does not take a value"), "{err}");
        // ...and an option used as a bare flag too.
        let c = parse(&["run", "--gpus"]);
        let err = c.reject_unknown(&["gpus"], &[]).unwrap_err().to_string();
        assert!(err.contains("expects a value"), "{err}");
    }

    #[test]
    fn normalize_flags_recovers_swallowed_positional() {
        // `exp --fast fig6det`: the parser binds fig6det as --fast's value.
        let mut a = parse(&["exp", "--fast", "fig6det"]);
        assert_eq!(a.get("fast"), Some("fig6det"));
        a.normalize_flags(&["fast"]);
        assert!(a.flag("fast"));
        assert_eq!(a.positional, vec!["fig6det"]);
        assert!(a.reject_unknown(&["out", "seed"], &["fast"]).is_ok());
        // Flag in its natural (trailing) position is untouched.
        let mut b = parse(&["exp", "fig6det", "--fast"]);
        b.normalize_flags(&["fast"]);
        assert!(b.flag("fast"));
        assert_eq!(b.positional, vec!["fig6det"]);
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("windows", "windows"), 0);
        assert_eq!(edit_distance("windws", "windows"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
        assert_eq!(suggest("windws", &["gpus", "windows"]), Some("windows"));
        assert_eq!(suggest("zzz", &["gpus", "windows"]), None);
    }
}

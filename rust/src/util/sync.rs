//! Poison-tolerant lock helpers (the D006 contract).
//!
//! `Mutex::lock().unwrap()` turns one panicked thread into a cascade: every
//! later locker panics on the poison it left behind, which in a long-lived
//! serve host or a worker pool converts a single bad eval item into a dead
//! process. Every lock in this crate guards state whose invariants are
//! restored before each unlock (whole-value inserts, queue push/pop,
//! counter bumps), so recovering the guard from a [`PoisonError`] is always
//! sound — the panic unwound *between* critical sections, not through a
//! half-applied update. These helpers are the one blessed way to do that;
//! the `ecco lint` rule **D006** flags any `.lock().unwrap()` /
//! `.lock().expect(..)` that bypasses them.
//!
//! If a future lock ever guards multi-step state that a mid-update panic
//! could tear, do **not** route it through these helpers — handle the
//! poison explicitly at the call site and document why.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// [`Condvar::wait`], recovering the guard from a poisoned lock.
pub fn pwait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// [`Condvar::wait_timeout`], recovering the guard from a poisoned lock.
pub fn pwait_timeout<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(g, dur).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::{Arc, Mutex};

    fn poisoned_mutex() -> Arc<Mutex<u32>> {
        let m = Arc::new(Mutex::new(7));
        let m2 = Arc::clone(&m);
        let _ = catch_unwind(AssertUnwindSafe(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        }));
        assert!(m.is_poisoned(), "setup: mutex must be poisoned");
        m
    }

    #[test]
    fn plock_recovers_a_poisoned_guard() {
        let m = poisoned_mutex();
        assert_eq!(*plock(&m), 7);
        *plock(&m) += 1;
        assert_eq!(*plock(&m), 8);
    }

    #[test]
    fn pwait_timeout_survives_poison() {
        let m = poisoned_mutex();
        let cv = Condvar::new();
        let g = plock(&m);
        let (g, timed_out) = pwait_timeout(&cv, g, Duration::from_millis(1));
        assert!(timed_out.timed_out());
        assert_eq!(*g, 7);
    }

    #[test]
    fn pwait_wakes_normally() {
        let m = Arc::new(Mutex::new(false));
        let cv = Arc::new(Condvar::new());
        let (m2, cv2) = (Arc::clone(&m), Arc::clone(&cv));
        let h = std::thread::spawn(move || {
            *plock(&m2) = true;
            cv2.notify_all();
        });
        let mut g = plock(&m);
        while !*g {
            g = pwait(&cv, g);
        }
        h.join().expect("notifier thread");
    }
}

//! Leveled stderr logger, controlled by the `ECCO_LOG` env var
//! (`error|warn|info|debug|trace`, default `info`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(255);
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_str(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == 255 {
        let lvl = std::env::var("ECCO_LOG")
            .map(|v| Level::from_str(&v))
            .unwrap_or(Level::Info);
        LEVEL.store(lvl as u8, Ordering::Relaxed);
        return lvl;
    }
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn log(lvl: Level, module: &str, msg: &str) {
    if lvl <= level() {
        let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
        eprintln!("[{t:9.3}s {} {module}] {msg}", lvl.tag());
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, module_path!(), &format!($($arg)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, module_path!(), &format!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, module_path!(), &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn set_and_get() {
        set_level(Level::Warn);
        assert_eq!(level(), Level::Warn);
        set_level(Level::Info);
    }
}

//! Hand-rolled property-testing harness (proptest is unavailable offline).
//!
//! `check` runs a property over `cases` seeded inputs; on failure it retries
//! with progressively simpler generated cases ("shrink by regeneration at
//! smaller size") and reports the failing seed so the case can be replayed
//! deterministically in a unit test.

use super::rng::Pcg32;

/// Context handed to generators: a seeded RNG plus a size hint in [0,1]
/// that grows over the run (small cases first, as shrunk replays stay small).
pub struct Gen {
    pub rng: Pcg32,
    pub size: f64,
}

impl Gen {
    /// Integer in [lo, hi] scaled by current size (at least lo+1 range).
    pub fn int_scaled(&mut self, lo: usize, hi: usize) -> usize {
        let span = ((hi - lo) as f64 * self.size).ceil().max(1.0) as usize;
        lo + self.rng.index(span.min(hi - lo) + 1)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.index(hi - lo + 1)
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range(lo, hi)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.range(lo, hi)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }
}

/// Run `prop` over `cases` generated inputs. Panics (test failure) with the
/// failing seed and message on the first violated case.
pub fn check<F>(name: &str, cases: u32, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base_seed.wrapping_add((case as u64).wrapping_mul(0x9e3779b97f4a7c15));
        let mut g = Gen {
            rng: Pcg32::new(seed, case as u64),
            size: ((case + 1) as f64 / cases as f64).min(1.0),
        };
        if let Err(msg) = prop(&mut g) {
            // Attempt a simpler reproduction at reduced size for the report.
            let mut simplest: Option<(u32, String)> = None;
            for retry in 0..16u32 {
                let rseed = seed.wrapping_add(retry as u64 + 1);
                let mut rg = Gen {
                    rng: Pcg32::new(rseed, retry as u64),
                    size: 0.1,
                };
                if let Err(rmsg) = prop(&mut rg) {
                    simplest = Some((retry, rmsg));
                    break;
                }
            }
            match simplest {
                Some((retry, rmsg)) => panic!(
                    "property '{name}' failed (case {case}, seed {seed:#x}): {msg}\n\
                     simpler repro (size=0.1, retry {retry}): {rmsg}"
                ),
                None => panic!(
                    "property '{name}' failed (case {case}, seed {seed:#x}): {msg}"
                ),
            }
        }
    }
}

/// Replay a specific failing seed (paste from the failure message).
pub fn replay<F>(seed: u64, size: f64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen {
        rng: Pcg32::new(seed, 0),
        size,
    };
    if let Err(msg) = prop(&mut g) {
        panic!("replayed property failed (seed {seed:#x}): {msg}");
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 50, |g| {
            let a = g.f32(-10.0, 10.0);
            let b = g.f32(-10.0, 10.0);
            if (a + b - (b + a)).abs() < 1e-6 {
                Ok(())
            } else {
                Err(format!("{a} + {b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, |_| Err("nope".to_string()));
    }

    #[test]
    fn generators_honour_bounds() {
        check("gen-bounds", 100, |g| {
            let n = g.usize(3, 9);
            if !(3..=9).contains(&n) {
                return Err(format!("usize out of bounds: {n}"));
            }
            let v = g.vec_f32(n, -1.0, 1.0);
            if v.len() != n || v.iter().any(|x| !(-1.0..1.0).contains(x)) {
                return Err("vec_f32 out of bounds".to_string());
            }
            Ok(())
        });
    }
}

//! Minimal JSON parser/writer (serde is unavailable offline).
//!
//! Used to read `artifacts/manifest.json` / `golden.json` written by the
//! python AOT pipeline and to emit machine-readable experiment results.
//! Supports the full JSON grammar minus exotic number formats; numbers are
//! held as f64 (adequate: the manifest only stores shapes and small ints).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    pub fn usize_array(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn f32_array(&self) -> Result<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect()
    }

    // -- writer ----------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    item.write(out, indent + 1, pretty);
                }
                if pretty && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, item)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    item.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers so experiment code reads cleanly.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn f32s(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = utf8_width(c);
                        self.pos = start + width;
                        out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow!("bad number {text:?} at byte {start}"))?;
        Ok(Json::Num(n))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' found {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' found {:?}", c as char),
            }
        }
    }
}

fn utf8_width(b: u8) -> usize {
    if b >= 0xf0 {
        4
    } else if b >= 0xe0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
    }

    #[test]
    fn round_trips() {
        let text = r#"{"shapes":[[8,32,32,3],[6197]],"lr":0.05,"name":"det_train_r32","ok":true}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn unicode_strings() {
        let j = Json::parse(r#""café naïve""#).unwrap();
        assert_eq!(j, Json::Str("café naïve".into()));
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"n": 3, "xs": [1.5, 2.5]}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("xs").unwrap().f32_array().unwrap(), vec![1.5, 2.5]);
        assert!(j.get("missing").is_err());
        assert!(j.get("n").unwrap().as_str().is_err());
    }
}

//! Resource-aware transmission control (§3.2).
//!
//! Two halves, exactly as in the paper:
//!
//! 1. **Sampling configuration** (§3.2.1): each camera owns a profiled
//!    lookup table mapping a GPU budget (pixels/second the group may
//!    consume) to the accuracy-optimal (frame rate, resolution) pair. At
//!    runtime the camera looks up its group's estimated budget `c_j`,
//!    scales the frame rate by `1/n_j` to balance member contributions,
//!    and keeps the resolution.
//! 2. **GAIMD parameterisation** (§3.2.2): bandwidth competition
//!    aggressiveness is tied to the GPU share: `beta = 0.5`,
//!    `alpha = p_j / n_j`, yielding steady-state throughput proportional
//!    to the group's GPU share (throughput ∝ alpha/(1-beta)).
//!
//! Profile tables come either from the Fig. 5 offline profiling experiment
//! (`ProfileTable::from_measurements`) or from the camera-type heuristic
//! the profiling reproduces: static high mounts favour resolution (small
//! distant objects), mobile mounts favour frame rate (fast scene change).

use crate::scene::Mount;
use crate::util::stats::nan_ranks_last;
use crate::video::{SamplingConfig, BPP_LOSSLESS, FPS_CHOICES, RES_CHOICES};

/// GPU budget levels (pixels/second) the table is indexed by. Retraining
/// windows are discretised into micro-windows, so only a handful of levels
/// occur (§3.2.1); intermediate budgets use the nearest lower level.
pub const BUDGET_LEVELS: [f64; 6] = [2_000.0, 5_000.0, 10_000.0, 20_000.0, 40_000.0, 80_000.0];

/// Offline-profiled budget -> best sampling configuration map.
#[derive(Debug, Clone)]
pub struct ProfileTable {
    /// `entries[i]` is the best config for `BUDGET_LEVELS[i]`.
    pub entries: Vec<SamplingConfig>,
}

impl ProfileTable {
    /// Build from measured (budget level, config, accuracy) triples — the
    /// output of the Fig. 5 profiling sweep.
    ///
    /// NaN accuracies (a profiling cell whose eval diverged) rank below
    /// every real measurement instead of panicking the argmax, and ties
    /// break deterministically to the **lowest-index** config of the
    /// level, so a profile table never depends on float quirks or
    /// iteration luck.
    pub fn from_measurements(measured: &[(usize, SamplingConfig, f32)]) -> ProfileTable {
        let mut entries = Vec::with_capacity(BUDGET_LEVELS.len());
        for level in 0..BUDGET_LEVELS.len() {
            let mut best: Option<(SamplingConfig, f32)> = None;
            for (l, c, a) in measured {
                if *l != level {
                    continue;
                }
                // Strict improvement only: equal (and all-NaN) accuracies
                // keep the earliest — lowest-index — measurement.
                let better = match &best {
                    None => true,
                    Some((_, b)) => nan_ranks_last(*a) > nan_ranks_last(*b),
                };
                if better {
                    best = Some((*c, *a));
                }
            }
            let cfg = best
                .map(|(c, _)| c)
                .unwrap_or(SamplingConfig { fps: 1.0, res: 32 });
            entries.push(cfg);
        }
        ProfileTable { entries }
    }

    /// Camera-type heuristic capturing the Fig. 5 finding: under a pixel
    /// budget, static high-mounted cameras spend it on resolution, mobile
    /// cameras on frame rate. Greedy: pick the config fitting the budget
    /// with the preferred dimension maximised first.
    pub fn heuristic(mount: &Mount) -> ProfileTable {
        let prefer_res = !matches!(mount, Mount::Mobile { .. });
        let mut entries = Vec::with_capacity(BUDGET_LEVELS.len());
        for &budget in &BUDGET_LEVELS {
            let mut best: Option<SamplingConfig> = None;
            for &res in &RES_CHOICES {
                for &fps in &FPS_CHOICES {
                    let c = SamplingConfig { fps, res };
                    if c.pixels_per_sec() > budget {
                        continue;
                    }
                    let better = match best {
                        None => true,
                        Some(b) => {
                            if prefer_res {
                                (c.res, c.pixels_per_sec() as u64)
                                    > (b.res, b.pixels_per_sec() as u64)
                            } else {
                                (ordf(c.fps), c.pixels_per_sec() as u64)
                                    > (ordf(b.fps), b.pixels_per_sec() as u64)
                            }
                        }
                    };
                    if better {
                        best = Some(c);
                    }
                }
            }
            entries.push(best.unwrap_or(SamplingConfig {
                fps: FPS_CHOICES[0],
                res: RES_CHOICES[0],
            }));
        }
        ProfileTable { entries }
    }

    /// Look up the best configuration for a raw budget in pixels/second.
    /// Uses the nearest lower profiled level, then downgrades further if
    /// that entry still exceeds the actual budget (budgets below the lowest
    /// level occur when many groups share few GPUs).
    pub fn lookup(&self, budget_pps: f64) -> SamplingConfig {
        let mut idx = 0;
        for (i, &lvl) in BUDGET_LEVELS.iter().enumerate() {
            if budget_pps >= lvl {
                idx = i;
            }
        }
        let mut cfg = self.entries[idx];
        while cfg.pixels_per_sec() > budget_pps && idx > 0 {
            idx -= 1;
            cfg = self.entries[idx];
        }
        if cfg.pixels_per_sec() > budget_pps {
            // Below every profiled level: fall back to the cheapest config.
            cfg = SamplingConfig {
                fps: FPS_CHOICES[0],
                res: RES_CHOICES[0],
            };
        }
        cfg
    }
}

fn ordf(f: f32) -> u32 {
    (f * 1000.0) as u32
}

/// GPU allocation information the server pushes to a camera each window
/// (§3.1 "GPU allocation estimation for transmission control").
#[derive(Debug, Clone, Copy)]
pub struct GpuAllocationInfo {
    /// Estimated GPU resource for the camera's group over the window,
    /// expressed as training pixels/second (`c_j`).
    pub group_budget_pps: f64,
    /// Normalised GPU share weight of the group (`p_j`, sums to 1).
    pub share_weight: f64,
    /// Number of cameras in the group (`n_j`).
    pub group_size: usize,
}

/// What the camera-side controller decides for a window.
#[derive(Debug, Clone, Copy)]
pub struct TransmissionPlan {
    /// Per-camera sampling configuration (f*/n_j, q*).
    pub config: SamplingConfig,
    /// GAIMD additive-increase parameter.
    pub gaimd_alpha: f64,
    /// GAIMD multiplicative-decrease parameter.
    pub gaimd_beta: f64,
    /// Application-level rate cap (Mbit/s): no point sending more bits
    /// than lossless encoding of the sampled stream.
    pub app_limit_mbps: f64,
}

/// ECCO's per-camera transmission controller.
#[derive(Debug, Clone)]
pub struct Controller {
    pub table: ProfileTable,
    /// The last group config resolved from a valid budget — the fallback
    /// when a pushed measurement is missing or NaN (e.g. a fault corrupted
    /// the server's budget estimate mid-window).
    last_cfg: Option<SamplingConfig>,
}

impl Controller {
    pub fn new(table: ProfileTable) -> Controller {
        Controller {
            table,
            last_cfg: None,
        }
    }

    pub fn for_mount(mount: &Mount) -> Controller {
        Controller::new(ProfileTable::heuristic(mount))
    }

    /// Compute the window plan from the server's allocation info (§3.2).
    ///
    /// Degradation contract: a non-finite `group_budget_pps` holds the
    /// last valid profile entry (the cheapest config if there has never
    /// been one), and a non-finite `share_weight` competes at the minimum
    /// GAIMD aggressiveness — the plan is always well-formed, never NaN.
    pub fn plan(&mut self, info: GpuAllocationInfo) -> TransmissionPlan {
        let group_cfg = if info.group_budget_pps.is_finite() {
            let cfg = self.table.lookup(info.group_budget_pps);
            self.last_cfg = Some(cfg);
            cfg
        } else {
            self.last_cfg.unwrap_or(SamplingConfig {
                fps: FPS_CHOICES[0],
                res: RES_CHOICES[0],
            })
        };
        let n = info.group_size.max(1) as f32;
        let config = SamplingConfig {
            fps: group_cfg.fps / n,
            res: group_cfg.res,
        };
        let share = if info.share_weight.is_finite() {
            info.share_weight
        } else {
            0.0
        };
        let alpha = (share / n as f64).max(1e-3);
        let app_limit_mbps =
            config.pixels_per_sec() * 3.0 * BPP_LOSSLESS / 1e6; // channel-pixels
        TransmissionPlan {
            config,
            gaimd_alpha: alpha,
            gaimd_beta: 0.5,
            app_limit_mbps,
        }
    }
}

/// The fixed-configuration baseline (Naive/Ekya): constant sampling, plain
/// AIMD (alpha=1), no coupling to the GPU share.
pub fn baseline_plan(fps: f32, res: usize) -> TransmissionPlan {
    let config = SamplingConfig { fps, res };
    TransmissionPlan {
        config,
        gaimd_alpha: 1.0,
        gaimd_beta: 0.5,
        app_limit_mbps: config.pixels_per_sec() * 3.0 * BPP_LOSSLESS / 1e6,
    }
}

/// AMS-style content-driven frame-rate adaptation used by the RECL
/// baseline: scales a base frame rate by observed scene dynamics (mean
/// embedding change between windows), ignoring GPU allocation entirely.
pub fn ams_plan(base_fps: f32, res: usize, scene_dynamics: f32) -> TransmissionPlan {
    // dynamics in [0,1]: 0 = static scene, 1 = rapidly changing.
    let fps = (base_fps * (0.3 + 0.7 * scene_dynamics.clamp(0.0, 1.0))).max(0.25);
    let config = SamplingConfig { fps, res };
    TransmissionPlan {
        config,
        gaimd_alpha: 1.0,
        gaimd_beta: 0.5,
        app_limit_mbps: config.pixels_per_sec() * 3.0 * BPP_LOSSLESS / 1e6,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::Mount;

    #[test]
    fn heuristic_static_prefers_resolution() {
        let t = ProfileTable::heuristic(&Mount::StaticHigh);
        // At a generous budget a static camera should pick max resolution.
        let c = t.lookup(80_000.0);
        assert_eq!(c.res, 48);
        // At a tight budget it still holds the largest feasible resolution.
        let tight = t.lookup(2_000.0);
        assert!(tight.pixels_per_sec() <= 2_000.0);
        assert!(tight.res >= 32, "static should trade fps for res: {tight:?}");
    }

    #[test]
    fn heuristic_mobile_prefers_fps() {
        let t = ProfileTable::heuristic(&Mount::Mobile {
            waypoints: vec![],
            speed: 0.0,
        });
        let tight = t.lookup(5_000.0);
        assert!(tight.fps >= 4.0, "mobile should trade res for fps: {tight:?}");
        assert!(tight.pixels_per_sec() <= 5_000.0);
    }

    #[test]
    fn lookup_uses_nearest_lower_level() {
        let t = ProfileTable::heuristic(&Mount::StaticHigh);
        assert_eq!(t.lookup(5_500.0), t.entries[1]);
        assert_eq!(t.lookup(1e9), t.entries[5]);
    }

    #[test]
    fn lookup_downgrades_below_lowest_level() {
        // A budget below even the cheapest profiled entry must fall back to
        // a config that fits (ultimately the minimum config).
        let t = ProfileTable::heuristic(&Mount::StaticHigh);
        let tiny = t.lookup(200.0);
        assert!(tiny.pixels_per_sec() <= 200.0 || tiny == SamplingConfig {
            fps: FPS_CHOICES[0],
            res: RES_CHOICES[0],
        });
        let zero = t.lookup(0.0);
        assert_eq!(
            zero,
            SamplingConfig { fps: FPS_CHOICES[0], res: RES_CHOICES[0] }
        );
    }

    #[test]
    fn from_measurements_picks_argmax() {
        let measured = vec![
            (0, SamplingConfig { fps: 1.0, res: 16 }, 0.2),
            (0, SamplingConfig { fps: 0.5, res: 32 }, 0.3),
            (1, SamplingConfig { fps: 2.0, res: 32 }, 0.4),
        ];
        let t = ProfileTable::from_measurements(&measured);
        assert_eq!(t.entries[0], SamplingConfig { fps: 0.5, res: 32 });
        assert_eq!(t.entries[1], SamplingConfig { fps: 2.0, res: 32 });
    }

    #[test]
    fn from_measurements_is_nan_safe_with_low_index_ties() {
        // Regression: a NaN accuracy used to panic the per-level argmax
        // through `partial_cmp(..).unwrap()`. NaN must rank below every
        // real measurement, ties must keep the lowest-index config, and an
        // all-NaN level must deterministically keep its first config.
        let measured = vec![
            (0, SamplingConfig { fps: 1.0, res: 16 }, f32::NAN),
            (0, SamplingConfig { fps: 0.5, res: 32 }, 0.3),
            (0, SamplingConfig { fps: 2.0, res: 48 }, 0.3), // tie: loses to index 1
            (0, SamplingConfig { fps: 4.0, res: 16 }, 0.1),
            (1, SamplingConfig { fps: 2.0, res: 16 }, f32::NAN),
            (1, SamplingConfig { fps: 8.0, res: 48 }, f32::NAN),
        ];
        let t = ProfileTable::from_measurements(&measured);
        assert_eq!(t.entries[0], SamplingConfig { fps: 0.5, res: 32 });
        assert_eq!(
            t.entries[1],
            SamplingConfig { fps: 2.0, res: 16 },
            "all-NaN level keeps its lowest-index config"
        );
        // Unmeasured levels still fall back to the default.
        assert_eq!(t.entries[2], SamplingConfig { fps: 1.0, res: 32 });
    }

    #[test]
    fn plan_scales_fps_by_group_size_and_alpha_by_share() {
        let mut ctl = Controller::for_mount(&Mount::StaticHigh);
        let info1 = GpuAllocationInfo {
            group_budget_pps: 40_000.0,
            share_weight: 0.6,
            group_size: 1,
        };
        let info3 = GpuAllocationInfo {
            group_size: 3,
            ..info1
        };
        let p1 = ctl.plan(info1);
        let p3 = ctl.plan(info3);
        assert!((p1.config.fps / p3.config.fps - 3.0).abs() < 1e-5);
        assert_eq!(p1.config.res, p3.config.res);
        assert!((p1.gaimd_alpha / p3.gaimd_alpha - 3.0).abs() < 1e-5);
        assert_eq!(p1.gaimd_beta, 0.5);
    }

    #[test]
    fn gaimd_weights_proportional_to_group_share() {
        // Two groups with shares 0.75/0.25, sizes 3/1: per-camera weights
        // alpha/(1-beta) must make GROUP totals proportional to shares.
        let mut ctl = Controller::for_mount(&Mount::StaticHigh);
        let pa = ctl.plan(GpuAllocationInfo {
            group_budget_pps: 1e4,
            share_weight: 0.75,
            group_size: 3,
        });
        let pb = ctl.plan(GpuAllocationInfo {
            group_budget_pps: 1e4,
            share_weight: 0.25,
            group_size: 1,
        });
        let group_a = 3.0 * pa.gaimd_alpha / (1.0 - pa.gaimd_beta);
        let group_b = 1.0 * pb.gaimd_alpha / (1.0 - pb.gaimd_beta);
        assert!((group_a / group_b - 3.0).abs() < 1e-6);
    }

    #[test]
    fn app_limit_covers_lossless_stream() {
        let mut ctl = Controller::for_mount(&Mount::StaticHigh);
        let p = ctl.plan(GpuAllocationInfo {
            group_budget_pps: 20_000.0,
            share_weight: 0.5,
            group_size: 2,
        });
        let need = p.config.pixels_per_sec() * 3.0 * BPP_LOSSLESS / 1e6;
        assert!((p.app_limit_mbps - need).abs() < 1e-9);
    }

    #[test]
    fn nan_budget_falls_back_to_last_valid_profile_entry() {
        let mut ctl = Controller::for_mount(&Mount::StaticHigh);
        let healthy = ctl.plan(GpuAllocationInfo {
            group_budget_pps: 40_000.0,
            share_weight: 0.5,
            group_size: 2,
        });
        // Budget goes NaN (lost measurement): the config must hold.
        let degraded = ctl.plan(GpuAllocationInfo {
            group_budget_pps: f64::NAN,
            share_weight: 0.5,
            group_size: 2,
        });
        assert_eq!(degraded.config, healthy.config);
        assert!(degraded.gaimd_alpha.is_finite());
        assert!(degraded.app_limit_mbps.is_finite());
        // A NaN share degrades to minimum aggressiveness, never NaN.
        let no_share = ctl.plan(GpuAllocationInfo {
            group_budget_pps: 40_000.0,
            share_weight: f64::NAN,
            group_size: 2,
        });
        assert_eq!(no_share.gaimd_alpha, 1e-3);
        // A controller that has never seen a valid budget degrades to the
        // cheapest config rather than guessing.
        let mut fresh = Controller::for_mount(&Mount::StaticHigh);
        let first = fresh.plan(GpuAllocationInfo {
            group_budget_pps: f64::INFINITY,
            share_weight: 0.5,
            group_size: 1,
        });
        assert_eq!(
            first.config,
            SamplingConfig {
                fps: FPS_CHOICES[0],
                res: RES_CHOICES[0]
            }
        );
    }

    #[test]
    fn ams_plan_tracks_dynamics() {
        let slow = ams_plan(5.0, 32, 0.0);
        let fast = ams_plan(5.0, 32, 1.0);
        assert!(fast.config.fps > slow.config.fps * 2.0);
        assert_eq!(fast.gaimd_alpha, 1.0, "AMS does not touch CC params");
    }
}

//! Fluid-flow network simulator — the NS-3 substitute.
//!
//! Models the paper's transport setting (§3.2.2): every camera sends its
//! frame stream to the server over an *access link* (its own uplink, which
//! may be weak for mobile cameras) followed by a *shared bottleneck*.
//! Flows run GAIMD congestion control: additive increase `alpha` per RTT,
//! multiplicative decrease `beta` on congestion, giving the steady-state
//! throughput law  rate ∝ alpha / (1 - beta)  (Yang & Lam 2000) that
//! ECCO's transmission controller exploits by setting `alpha = p_j / n_j`,
//! `beta = 0.5`.
//!
//! The simulation is deterministic fluid dynamics at a fixed tick: each
//! tick rates grow additively (unless app-limited), then every saturated
//! link triggers a synchronized multiplicative back-off of the flows
//! crossing it (with a one-RTT cooldown, as real AIMD reacts at most once
//! per window). Delivered bytes integrate the *goodput*: the flow's rate
//! scaled down by each link's overload factor.

pub mod trace;

use anyhow::{bail, Result};

/// Default simulation tick (seconds).
pub const DEFAULT_TICK: f64 = 0.02;
/// Default flow RTT (seconds).
pub const DEFAULT_RTT: f64 = 0.05;

/// A network link with fixed capacity in Mbit/s.
///
/// Fault injection can take a link down (`up = false`) or rescale its
/// capacity (`cap_scale`); both default to healthy and are observed only
/// through [`Link::effective_capacity`], so a fault-free simulation is
/// bit-identical to one without the fields.
#[derive(Debug, Clone)]
pub struct Link {
    pub capacity_mbps: f64,
    pub name: String,
    /// False while the link is dark (outage): effective capacity 0.
    pub up: bool,
    /// Degradation multiplier on the nominal capacity (1.0 = healthy).
    pub cap_scale: f64,
}

impl Link {
    /// A healthy link (up, full capacity).
    pub fn new(capacity_mbps: f64, name: impl Into<String>) -> Link {
        Link {
            capacity_mbps,
            name: name.into(),
            up: true,
            cap_scale: 1.0,
        }
    }

    /// Capacity after outage/degradation state.
    pub fn effective_capacity(&self) -> f64 {
        if self.up {
            self.capacity_mbps * self.cap_scale
        } else {
            0.0
        }
    }
}

/// One GAIMD flow (camera -> server).
#[derive(Debug, Clone)]
pub struct Flow {
    /// Additive increase in Mbit/s per RTT.
    pub alpha: f64,
    /// Multiplicative decrease factor in (0,1).
    pub beta: f64,
    pub rtt: f64,
    /// Current sending rate (Mbit/s).
    pub rate: f64,
    /// Application-limited ceiling (Mbit/s); INFINITY = unlimited.
    pub app_limit: f64,
    /// Links this flow traverses (indices into `NetSim::links`).
    pub path: Vec<usize>,
    /// Accumulated delivered volume (Mbit).
    pub delivered_mbit: f64,
    /// Seconds until this flow reacts to congestion again.
    cooldown: f64,
}

/// Handle for a flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlowId(pub usize);

/// The fluid network simulator.
pub struct NetSim {
    pub links: Vec<Link>,
    pub flows: Vec<Flow>,
    pub time: f64,
    tick: f64,
    recorder: Option<trace::TraceRecorder>,
}

impl NetSim {
    pub fn new(links: Vec<Link>) -> NetSim {
        NetSim {
            links,
            flows: Vec::new(),
            time: 0.0,
            tick: DEFAULT_TICK,
            recorder: None,
        }
    }

    /// Star topology: `local_caps[i]` is camera i's uplink; all cameras then
    /// share one bottleneck of `shared_mbps`. Returns the sim; camera i's
    /// flow path is `[i, n]`.
    pub fn star(local_caps: &[f64], shared_mbps: f64) -> NetSim {
        let mut links: Vec<Link> = local_caps
            .iter()
            .enumerate()
            .map(|(i, &c)| Link::new(c, format!("uplink{i}")))
            .collect();
        links.push(Link::new(shared_mbps, "shared"));
        NetSim::new(links)
    }

    /// Add a flow; starts at a small initial rate.
    pub fn add_flow(&mut self, path: Vec<usize>, alpha: f64, beta: f64) -> Result<FlowId> {
        for &l in &path {
            if l >= self.links.len() {
                bail!("flow path references unknown link {l}");
            }
        }
        if !(0.0 < beta && beta < 1.0) {
            bail!("beta must be in (0,1), got {beta}");
        }
        if alpha <= 0.0 {
            bail!("alpha must be positive, got {alpha}");
        }
        self.flows.push(Flow {
            alpha,
            beta,
            rtt: DEFAULT_RTT,
            rate: 0.1,
            app_limit: f64::INFINITY,
            path,
            delivered_mbit: 0.0,
            cooldown: 0.0,
        });
        Ok(FlowId(self.flows.len() - 1))
    }

    /// Camera flow in a star topology (uplink i -> shared bottleneck).
    pub fn add_camera_flow(&mut self, cam: usize, alpha: f64, beta: f64) -> Result<FlowId> {
        let shared = self.links.len() - 1;
        self.add_flow(vec![cam, shared], alpha, beta)
    }

    /// Update GAIMD parameters mid-run (server pushed a new GPU share).
    pub fn set_params(&mut self, id: FlowId, alpha: f64, beta: f64) {
        let f = &mut self.flows[id.0];
        f.alpha = alpha.max(1e-4);
        f.beta = beta.clamp(0.05, 0.95);
    }

    /// Cap a flow at its application sending rate.
    pub fn set_app_limit(&mut self, id: FlowId, limit_mbps: f64) {
        self.flows[id.0].app_limit = limit_mbps.max(0.0);
    }

    /// Take a link dark (`up = false`) or bring it back. A dark link has
    /// zero effective capacity: every flow crossing it sees full overload
    /// and its goodput drops to zero within a tick.
    pub fn set_link_up(&mut self, link: usize, up: bool) {
        if let Some(l) = self.links.get_mut(link) {
            l.up = up;
        }
    }

    /// Rescale a link's capacity (degradation), `scale` clamped to ≥ 0.
    pub fn set_link_capacity_scale(&mut self, link: usize, scale: f64) {
        if let Some(l) = self.links.get_mut(link) {
            l.cap_scale = scale.max(0.0);
        }
    }

    /// First link on the flow's path — in a star topology, the camera's
    /// own uplink (the fault-injection target).
    pub fn flow_uplink(&self, id: FlowId) -> usize {
        self.flows[id.0].path[0]
    }

    /// Attach a rate-trace recorder sampling every `sample_dt` seconds.
    pub fn record(&mut self, sample_dt: f64) {
        self.recorder = Some(trace::TraceRecorder::new(sample_dt, self.flows.len()));
    }

    pub fn take_traces(&mut self) -> Option<trace::Traces> {
        self.recorder.take().map(|r| r.finish())
    }

    pub fn rate(&self, id: FlowId) -> f64 {
        self.flows[id.0].rate
    }

    pub fn delivered_mbit(&self, id: FlowId) -> f64 {
        self.flows[id.0].delivered_mbit
    }

    /// Reset delivery counters (e.g. at a window boundary).
    pub fn reset_delivered(&mut self) {
        for f in &mut self.flows {
            f.delivered_mbit = 0.0;
        }
    }

    /// Run the simulation for `duration` seconds.
    pub fn run(&mut self, duration: f64) {
        let end = self.time + duration;
        while self.time < end - 1e-9 {
            let dt = self.tick.min(end - self.time);
            self.step(dt);
        }
    }

    fn step(&mut self, dt: f64) {
        // 1. Additive increase (up to the app limit).
        for f in &mut self.flows {
            f.cooldown = (f.cooldown - dt).max(0.0);
            f.rate = (f.rate + f.alpha * dt / f.rtt).min(f.app_limit.max(0.01));
        }
        // 2. Congestion detection: every link's overload factor is computed
        //    from a single post-increase rate snapshot. (Mutating rates
        //    link-by-link here would make later links see already-backed-off
        //    demand, so goodput would depend on link declaration order.)
        let rates: Vec<f64> = self.flows.iter().map(|f| f.rate).collect();
        let mut overload = vec![1.0f64; self.links.len()];
        for (li, link) in self.links.iter().enumerate() {
            let demand: f64 = self
                .flows
                .iter()
                .zip(&rates)
                .filter(|(f, _)| f.path.contains(&li))
                .map(|(_, &r)| r)
                .sum();
            let cap = link.effective_capacity();
            if demand > cap {
                // demand > cap >= 0, so the quotient is well-defined (a
                // dark link yields overload 0: zero goodput through it).
                overload[li] = cap / demand;
            }
        }
        // 3. Synchronized multiplicative decrease: a flow crossing any
        //    saturated link backs off once, then cools down for one RTT —
        //    independent of how its links are ordered or indexed.
        for f in &mut self.flows {
            if f.cooldown <= 0.0 && f.path.iter().any(|&l| overload[l] < 1.0) {
                f.rate *= f.beta;
                f.cooldown = f.rtt;
            }
        }
        // 4. Goodput integration: rate scaled by the worst overload factor
        //    along the path (fluid approximation of queue drops).
        for f in &mut self.flows {
            let scale = f
                .path
                .iter()
                .map(|&l| overload[l])
                .fold(1.0f64, f64::min);
            f.delivered_mbit += f.rate * scale * dt;
        }
        self.time += dt;
        if let Some(rec) = &mut self.recorder {
            rec.sample(self.time, &self.flows);
        }
    }
}

/// The GAIMD steady-state throughput weight: alpha / (1 - beta).
pub fn gaimd_weight(alpha: f64, beta: f64) -> f64 {
    alpha / (1.0 - beta)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_rate_over(sim: &mut NetSim, id: FlowId, secs: f64) -> f64 {
        sim.reset_delivered();
        sim.run(secs);
        sim.delivered_mbit(id) / secs
    }

    #[test]
    fn single_flow_fills_link() {
        let mut sim = NetSim::star(&[100.0], 10.0);
        let f = sim.add_camera_flow(0, 1.0, 0.5).unwrap();
        sim.run(30.0); // converge
        let avg = mean_rate_over(&mut sim, f, 30.0);
        // AIMD with beta=.5 oscillates between C/2-ish and C: average ~0.75C.
        assert!(avg > 6.0 && avg <= 10.0, "avg={avg}");
    }

    #[test]
    fn equal_flows_share_equally() {
        let mut sim = NetSim::star(&[100.0, 100.0], 8.0);
        let a = sim.add_camera_flow(0, 1.0, 0.5).unwrap();
        let b = sim.add_camera_flow(1, 1.0, 0.5).unwrap();
        sim.run(40.0);
        let ra = mean_rate_over(&mut sim, a, 40.0);
        sim.reset_delivered();
        sim.run(40.0);
        let rb = sim.delivered_mbit(b) / 40.0;
        assert!((ra / rb - 1.0).abs() < 0.25, "ra={ra} rb={rb}");
    }

    #[test]
    fn gaimd_shares_proportional_to_weight() {
        // alpha 2:1 with equal beta -> ~2:1 bandwidth share.
        let mut sim = NetSim::star(&[100.0, 100.0], 9.0);
        let a = sim.add_camera_flow(0, 2.0, 0.5).unwrap();
        let b = sim.add_camera_flow(1, 1.0, 0.5).unwrap();
        sim.run(60.0);
        sim.reset_delivered();
        sim.run(60.0);
        let ra = sim.delivered_mbit(a) / 60.0;
        let rb = sim.delivered_mbit(b) / 60.0;
        let ratio = ra / rb;
        assert!(
            (1.6..=2.5).contains(&ratio),
            "expected ~2.0 share ratio, got {ratio} ({ra} vs {rb})"
        );
    }

    #[test]
    fn local_uplink_caps_flow_and_leaves_shared_for_others() {
        // Camera 0 capped at 1 Mbps locally; camera 1 should get the rest.
        let mut sim = NetSim::star(&[1.0, 100.0], 9.0);
        let a = sim.add_camera_flow(0, 1.0, 0.5).unwrap();
        let b = sim.add_camera_flow(1, 1.0, 0.5).unwrap();
        sim.run(60.0);
        sim.reset_delivered();
        sim.run(60.0);
        let ra = sim.delivered_mbit(a) / 60.0;
        let rb = sim.delivered_mbit(b) / 60.0;
        assert!(ra <= 1.05, "capped flow exceeded uplink: {ra}");
        assert!(rb > 5.0, "uncapped flow should use leftover: {rb}");
    }

    #[test]
    fn app_limit_respected() {
        let mut sim = NetSim::star(&[100.0], 50.0);
        let f = sim.add_camera_flow(0, 2.0, 0.5).unwrap();
        sim.set_app_limit(f, 3.0);
        sim.run(30.0);
        assert!(sim.rate(f) <= 3.0 + 1e-6);
    }

    #[test]
    fn goodput_never_exceeds_capacity() {
        let mut sim = NetSim::star(&[100.0, 100.0, 100.0], 6.0);
        let ids: Vec<FlowId> = (0..3)
            .map(|i| sim.add_camera_flow(i, 1.0, 0.5).unwrap())
            .collect();
        sim.run(20.0);
        sim.reset_delivered();
        sim.run(30.0);
        let total: f64 = ids.iter().map(|&i| sim.delivered_mbit(i)).sum();
        assert!(total / 30.0 <= 6.0 + 1e-6, "goodput {} > capacity", total / 30.0);
    }

    #[test]
    fn param_update_shifts_share() {
        let mut sim = NetSim::star(&[100.0, 100.0], 9.0);
        let a = sim.add_camera_flow(0, 1.0, 0.5).unwrap();
        let b = sim.add_camera_flow(1, 1.0, 0.5).unwrap();
        sim.run(40.0);
        sim.set_params(a, 3.0, 0.5);
        sim.run(40.0); // re-converge
        sim.reset_delivered();
        sim.run(60.0);
        let ra = sim.delivered_mbit(a) / 60.0;
        let rb = sim.delivered_mbit(b) / 60.0;
        assert!(ra / rb > 2.0, "after alpha bump expected >2x: {ra} vs {rb}");
    }

    #[test]
    fn goodput_independent_of_link_declaration_order() {
        // Same topology (two uplinks into one shared bottleneck), links
        // declared in permuted order: delivered volumes must be exactly
        // identical. Before the snapshot fix, back-offs were applied
        // link-by-link against already-mutated rates, so goodput depended
        // on link iteration order.
        let caps = [1.5f64, 4.0, 3.0]; // uplink0, uplink1, shared
        let build = |perm: &[usize; 3]| -> (NetSim, FlowId, FlowId) {
            // perm[i] = position of logical link i in the declared list.
            let mut link_caps = [0.0f64; 3];
            for (logical, &pos) in perm.iter().enumerate() {
                link_caps[pos] = caps[logical];
            }
            let links: Vec<Link> = link_caps
                .iter()
                .enumerate()
                .map(|(i, &c)| Link::new(c, format!("l{i}")))
                .collect();
            let mut sim = NetSim::new(links);
            let a = sim.add_flow(vec![perm[0], perm[2]], 1.0, 0.5).unwrap();
            let b = sim.add_flow(vec![perm[1], perm[2]], 2.0, 0.5).unwrap();
            (sim, a, b)
        };
        let (mut s1, a1, b1) = build(&[0, 1, 2]);
        let (mut s2, a2, b2) = build(&[2, 0, 1]);
        let (mut s3, a3, b3) = build(&[1, 2, 0]);
        for s in [&mut s1, &mut s2, &mut s3] {
            s.run(45.0);
        }
        assert_eq!(s1.delivered_mbit(a1), s2.delivered_mbit(a2));
        assert_eq!(s1.delivered_mbit(b1), s2.delivered_mbit(b2));
        assert_eq!(s1.delivered_mbit(a1), s3.delivered_mbit(a3));
        assert_eq!(s1.delivered_mbit(b1), s3.delivered_mbit(b3));
        // The sim actually saturated (the property is non-vacuous).
        assert!(s1.delivered_mbit(a1) + s1.delivered_mbit(b1) <= caps[2] * 45.0 + 1e-6);
        assert!(s1.delivered_mbit(b1) > 0.0);
    }

    #[test]
    fn link_outage_kills_goodput_and_restore_recovers_it() {
        let mut sim = NetSim::star(&[100.0], 10.0);
        let f = sim.add_camera_flow(0, 1.0, 0.5).unwrap();
        sim.run(30.0); // converge healthy
        let healthy = mean_rate_over(&mut sim, f, 20.0);
        assert!(healthy > 5.0, "healthy={healthy}");
        // Outage on the camera's uplink: goodput collapses to ~0.
        let uplink = sim.flow_uplink(f);
        assert_eq!(uplink, 0);
        sim.set_link_up(uplink, false);
        let dark = mean_rate_over(&mut sim, f, 20.0);
        assert!(dark < 0.05, "dark link still delivered {dark}");
        // Restore: AIMD re-converges to the healthy band.
        sim.set_link_up(uplink, true);
        sim.run(30.0);
        let back = mean_rate_over(&mut sim, f, 20.0);
        assert!(back > 5.0, "post-restore={back}");
    }

    #[test]
    fn scaled_uplink_bounds_delivery_like_a_smaller_link() {
        // A 10 Mbps uplink scaled by 0.25 must behave exactly like a
        // 2.5 Mbps link (the product is FP-exact, so bit-identical).
        let mut scaled = NetSim::star(&[10.0], 100.0);
        let fs = scaled.add_camera_flow(0, 1.0, 0.5).unwrap();
        scaled.set_link_capacity_scale(0, 0.25);
        scaled.run(40.0);
        let rs = mean_rate_over(&mut scaled, fs, 40.0);
        let mut small = NetSim::star(&[2.5], 100.0);
        let fm = small.add_camera_flow(0, 1.0, 0.5).unwrap();
        small.run(40.0);
        let rm = mean_rate_over(&mut small, fm, 40.0);
        assert_eq!(rs, rm, "scaled link must equal a natively smaller one");
        assert!(rs <= 2.5 * 1.02, "scaled link over-delivered: {rs}");
    }

    #[test]
    fn healthy_fault_fields_change_nothing() {
        // Zero-cost guarantee at the net layer: toggling a link down and
        // back before any traffic leaves state bit-identical to never
        // having touched it.
        let run = |touch: bool| {
            let mut sim = NetSim::star(&[5.0, 8.0], 6.0);
            let a = sim.add_camera_flow(0, 1.0, 0.5).unwrap();
            let b = sim.add_camera_flow(1, 2.0, 0.5).unwrap();
            if touch {
                sim.set_link_up(0, false);
                sim.set_link_up(0, true);
                sim.set_link_capacity_scale(1, 0.25);
                sim.set_link_capacity_scale(1, 1.0);
            }
            sim.run(50.0);
            (sim.delivered_mbit(a), sim.delivered_mbit(b))
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn rejects_invalid_flows() {
        let mut sim = NetSim::star(&[10.0], 5.0);
        assert!(sim.add_flow(vec![7], 1.0, 0.5).is_err());
        assert!(sim.add_flow(vec![0], 1.0, 1.5).is_err());
        assert!(sim.add_flow(vec![0], -1.0, 0.5).is_err());
    }

    #[test]
    fn gaimd_weight_law() {
        assert_eq!(gaimd_weight(1.0, 0.5), 2.0);
        assert!((gaimd_weight(0.31, 0.875) - 2.48).abs() < 1e-9);
    }
}

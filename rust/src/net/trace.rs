//! Per-flow rate traces (the NS-3 "bandwidth trace" equivalent, consumed
//! by the Fig. 11 experiment and by tests).

use super::Flow;

/// Recorded rate samples for every flow.
#[derive(Debug, Clone)]
pub struct Traces {
    /// Sample times (seconds).
    pub times: Vec<f64>,
    /// `rates[f][k]` = flow f's sending rate at `times[k]` (Mbit/s).
    pub rates: Vec<Vec<f64>>,
}

impl Traces {
    /// Mean rate of flow `f` over samples in [t0, t1].
    pub fn mean_rate(&self, f: usize, t0: f64, t1: f64) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for (k, &t) in self.times.iter().enumerate() {
            if t >= t0 && t <= t1 {
                sum += self.rates[f][k];
                n += 1;
            }
        }
        if n == 0 {
            f64::NAN
        } else {
            sum / n as f64
        }
    }

    /// Sum of groups of flows: returns one trace per group.
    pub fn group_rates(&self, groups: &[Vec<usize>]) -> Vec<Vec<f64>> {
        groups
            .iter()
            .map(|g| {
                (0..self.times.len())
                    .map(|k| g.iter().map(|&f| self.rates[f][k]).sum())
                    .collect()
            })
            .collect()
    }
}

#[derive(Debug)]
pub struct TraceRecorder {
    sample_dt: f64,
    next_sample: f64,
    times: Vec<f64>,
    rates: Vec<Vec<f64>>,
}

impl TraceRecorder {
    pub fn new(sample_dt: f64, n_flows: usize) -> TraceRecorder {
        TraceRecorder {
            sample_dt,
            next_sample: 0.0,
            times: Vec::new(),
            rates: vec![Vec::new(); n_flows],
        }
    }

    pub fn sample(&mut self, time: f64, flows: &[Flow]) {
        if time + 1e-12 < self.next_sample {
            return;
        }
        self.next_sample = time + self.sample_dt;
        self.times.push(time);
        // Flows added after recording started get NaN backfill-free traces:
        // extend the vector lazily.
        while self.rates.len() < flows.len() {
            let mut pad = Vec::with_capacity(self.times.len());
            pad.resize(self.times.len() - 1, f64::NAN);
            self.rates.push(pad);
        }
        for (i, f) in flows.iter().enumerate() {
            self.rates[i].push(f.rate);
        }
    }

    pub fn finish(self) -> Traces {
        Traces {
            times: self.times,
            rates: self.rates,
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::net::{NetSim, };

    #[test]
    fn traces_capture_convergence() {
        let mut sim = NetSim::star(&[100.0], 10.0);
        sim.record(0.5);
        let _f = sim.add_camera_flow(0, 1.0, 0.5).unwrap();
        sim.run(30.0);
        let traces = sim.take_traces().unwrap();
        assert!(!traces.times.is_empty());
        assert_eq!(traces.rates.len(), 1);
        let early = traces.mean_rate(0, 0.0, 3.0);
        let late = traces.mean_rate(0, 20.0, 30.0);
        assert!(late > early, "rate should ramp up: {early} -> {late}");
    }

    #[test]
    fn group_rates_sum_members() {
        let mut sim = NetSim::star(&[100.0, 100.0], 10.0);
        sim.record(0.5);
        sim.add_camera_flow(0, 1.0, 0.5).unwrap();
        sim.add_camera_flow(1, 1.0, 0.5).unwrap();
        sim.run(10.0);
        let traces = sim.take_traces().unwrap();
        let grouped = traces.group_rates(&[vec![0, 1]]);
        for k in 0..traces.times.len() {
            let direct = traces.rates[0][k] + traces.rates[1][k];
            assert!((grouped[0][k] - direct).abs() < 1e-9);
        }
    }
}

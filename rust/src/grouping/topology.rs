//! Camera topology graph — spatial neighbor pruning for Algorithm 2.
//!
//! All-pairs grouping evaluates every (job, request) pair, which is O(n²)
//! in fleet size and caps the simulation at tens of cameras. ReXCam's
//! observation is that cross-camera correlation is overwhelmingly *local*:
//! a camera's drift is correlated with its spatial neighbors, so the
//! similarity search can be pruned to a sparse neighbor graph. This module
//! provides that graph:
//!
//! * [`Topology::from_positions`] builds a k-nearest-neighbor graph over
//!   camera placements (symmetrised: `a ~ b` if either picks the other),
//!   so candidate generation per request is O(degree) instead of O(jobs).
//! * [`Topology::long_range_due`] marks periodic windows on which the
//!   pruning is lifted and *all* jobs are candidates again — the
//!   low-frequency long-range probe that lets distant-but-correlated
//!   cameras still merge.
//!
//! The graph is static (derived from deployment positions); degree `n-1`
//! reproduces all-pairs grouping exactly (pinned by a property test).

use std::collections::BTreeSet;

/// Default cadence of the long-range probe: every 8th window considers
/// every job, not just spatial neighbors' jobs.
pub const DEFAULT_LONG_RANGE_PERIOD: usize = 8;

/// A static spatial neighbor graph over the camera fleet.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    /// Sorted neighbor ids per camera (never contains the camera itself).
    neighbors: Vec<Vec<usize>>,
    /// Every `long_range_period`-th window lifts the pruning entirely;
    /// 0 disables long-range probes.
    pub long_range_period: usize,
}

impl Topology {
    /// Complete graph on `n` cameras: every camera neighbors every other.
    /// Grouping with this topology is exactly the all-pairs pass.
    pub fn full(n: usize) -> Topology {
        let neighbors = (0..n)
            .map(|c| (0..n).filter(|&o| o != c).collect())
            .collect();
        Topology {
            neighbors,
            long_range_period: DEFAULT_LONG_RANGE_PERIOD,
        }
    }

    /// k-nearest-neighbor graph over camera positions, symmetrised: each
    /// camera picks its `degree` nearest peers by Euclidean distance
    /// (ties broken by lower camera id, so the graph is deterministic),
    /// then `a ~ b` holds if either side picked the other. `degree >= n-1`
    /// yields the complete graph.
    pub fn from_positions(positions: &[(f32, f32)], degree: usize) -> Topology {
        let n = positions.len();
        let mut sets: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); n];
        let mut scratch: Vec<(f32, usize)> = Vec::with_capacity(n.saturating_sub(1));
        for cam in 0..n {
            scratch.clear();
            let p = positions[cam];
            for (other, &q) in positions.iter().enumerate() {
                if other == cam {
                    continue;
                }
                let d2 = (p.0 - q.0) * (p.0 - q.0) + (p.1 - q.1) * (p.1 - q.1);
                scratch.push((d2, other));
            }
            let k = degree.min(scratch.len());
            if k > 0 {
                // Partial selection keeps the build O(n²) overall instead
                // of O(n² log n); ties resolve by camera id for determinism.
                scratch.select_nth_unstable_by(k - 1, |a, b| {
                    a.0.total_cmp(&b.0).then(a.1.cmp(&b.1))
                });
                for &(_, other) in &scratch[..k] {
                    sets[cam].insert(other);
                    sets[other].insert(cam);
                }
            }
        }
        Topology {
            neighbors: sets
                .into_iter()
                .map(|s| s.into_iter().collect())
                .collect(),
            long_range_period: DEFAULT_LONG_RANGE_PERIOD,
        }
    }

    /// Override the long-range probe cadence (0 disables it).
    pub fn with_long_range_period(mut self, period: usize) -> Topology {
        self.long_range_period = period;
        self
    }

    pub fn n_cams(&self) -> usize {
        self.neighbors.len()
    }

    /// Sorted neighbor ids of `cam` (empty slice when out of range).
    pub fn neighbors(&self, cam: usize) -> &[usize] {
        self.neighbors.get(cam).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Largest per-camera degree after symmetrisation.
    pub fn max_degree(&self) -> usize {
        self.neighbors.iter().map(|v| v.len()).max().unwrap_or(0)
    }

    /// Is `window` a long-range probe window? On these windows grouping
    /// considers every job, not just neighbors' jobs. Window 0 is never
    /// long-range (the initial request storm is exactly what pruning is
    /// for); with period `p` the probe fires on windows p-1, 2p-1, ...
    pub fn long_range_due(&self, window: usize) -> bool {
        self.long_range_period > 0 && (window + 1) % self.long_range_period == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid(n: usize) -> Vec<(f32, f32)> {
        (0..n)
            .map(|i| ((i % 8) as f32 * 0.1, (i / 8) as f32 * 0.1))
            .collect()
    }

    #[test]
    fn full_graph_links_everyone() {
        let t = Topology::full(4);
        assert_eq!(t.n_cams(), 4);
        for c in 0..4 {
            assert_eq!(t.neighbors(c).len(), 3);
            assert!(!t.neighbors(c).contains(&c));
        }
        assert_eq!(t.max_degree(), 3);
    }

    #[test]
    fn knn_graph_is_symmetric_and_self_free() {
        let t = Topology::from_positions(&grid(20), 3);
        for c in 0..20 {
            for &o in t.neighbors(c) {
                assert_ne!(o, c, "no self loops");
                assert!(
                    t.neighbors(o).contains(&c),
                    "edge {c}~{o} must be symmetric"
                );
            }
            assert!(t.neighbors(c).windows(2).all(|w| w[0] < w[1]), "sorted");
        }
    }

    #[test]
    fn knn_prefers_near_cameras() {
        // A line of cameras: each one's 2-NN are its adjacent peers.
        let pos: Vec<(f32, f32)> = (0..6).map(|i| (i as f32, 0.0)).collect();
        let t = Topology::from_positions(&pos, 2);
        assert_eq!(t.neighbors(0), &[1, 2]);
        assert!(t.neighbors(3).contains(&2) && t.neighbors(3).contains(&4));
        assert!(!t.neighbors(0).contains(&5), "far end is not a neighbor");
    }

    #[test]
    fn degree_n_minus_1_is_complete() {
        let pos = grid(9);
        let t = Topology::from_positions(&pos, 8);
        assert_eq!(t, Topology::full(9));
        // Over-asking is clamped, not a panic.
        let t2 = Topology::from_positions(&pos, 100);
        assert_eq!(t2, Topology::full(9));
    }

    #[test]
    fn coincident_positions_tie_break_by_id() {
        // Three cameras at the same point: 1-NN must pick the lowest id.
        let pos = vec![(0.5, 0.5); 3];
        let t = Topology::from_positions(&pos, 1);
        // cam 0 picks 1, cam 1 picks 0, cam 2 picks 0; symmetrised.
        assert_eq!(t.neighbors(0), &[1, 2]);
        assert_eq!(t.neighbors(1), &[0]);
        assert_eq!(t.neighbors(2), &[0]);
    }

    #[test]
    fn out_of_range_and_empty() {
        let t = Topology::from_positions(&[], 3);
        assert_eq!(t.n_cams(), 0);
        assert!(t.neighbors(7).is_empty());
        let one = Topology::from_positions(&[(0.0, 0.0)], 3);
        assert!(one.neighbors(0).is_empty());
    }

    #[test]
    fn long_range_cadence() {
        let t = Topology::full(2).with_long_range_period(4);
        let due: Vec<usize> = (0..12).filter(|&w| t.long_range_due(w)).collect();
        assert_eq!(due, vec![3, 7, 11]);
        assert!(!t.long_range_due(0), "window 0 must stay pruned");
        let never = Topology::full(2).with_long_range_period(0);
        assert!((0..32).all(|w| !never.long_range_due(w)));
    }
}

//! Dynamic camera grouping — the paper's Algorithm 2.
//!
//! Grouping has two stages, both implemented here as *pure* bookkeeping
//! with the accuracy evaluation injected as a closure (the server wires it
//! to real PJRT inference on the request's sample frames):
//!
//! * [`group_request`] — initial grouping: metadata pre-filter (request
//!   time within `time_eps` AND location within `loc_delta` of *every*
//!   member of a candidate job), then a performance check: the new camera
//!   joins the correlated job whose model scores best on its sampled
//!   frames, provided that beats the camera's own current accuracy.
//! * [`update_grouping`] — periodic re-evaluation at window end: a member
//!   whose accuracy under the group model dropped by more than fraction
//!   `drop_threshold` relative to the previous window is evicted and
//!   re-enters the pipeline as a fresh request.
//!
//! At fleet scale the candidate search itself is the bottleneck: without
//! pruning every request examines every job (O(n²) per window across the
//! fleet). [`group_request_pruned`] accepts an optional candidate-id set —
//! typically the jobs owned by the requester's spatial neighbors from a
//! [`topology::Topology`] graph — restricting both the metadata filter and
//! the expensive model evals to O(degree) jobs per request.

use std::collections::{BTreeMap, BTreeSet};

pub mod topology;

/// Metadata of a retraining request (Alg. 2's r.t / r.loc / r.acc).
#[derive(Debug, Clone)]
pub struct RequestMeta {
    pub cam: usize,
    /// Request (or re-request) time, simulated seconds.
    pub time: f64,
    /// Camera location at request time (normalised map units).
    pub loc: (f32, f32),
    /// The camera's current model accuracy on its own recent frames — the
    /// bar a group model must beat for admission.
    pub acc: f32,
}

/// One retraining job's grouping state.
#[derive(Debug, Clone)]
pub struct GroupJob {
    pub id: usize,
    pub members: Vec<RequestMeta>,
    /// Per-camera accuracy at the end of the previous window (r.acc_{n-1}).
    pub prev_acc: BTreeMap<usize, f32>,
}

impl GroupJob {
    pub fn new(id: usize, first: RequestMeta) -> GroupJob {
        GroupJob {
            id,
            members: vec![first],
            prev_acc: BTreeMap::new(),
        }
    }

    pub fn cams(&self) -> Vec<usize> {
        self.members.iter().map(|m| m.cam).collect()
    }
}

/// Grouping policy knobs.
#[derive(Debug, Clone)]
pub struct GroupingPolicy {
    /// Alg. 2 epsilon: max request-time gap to every member (seconds).
    pub time_eps: f64,
    /// Alg. 2 delta: max location distance to every member.
    pub loc_delta: f32,
    /// Alg. 2 p: relative accuracy drop that triggers eviction.
    pub drop_threshold: f32,
    /// Ablation switch: disable the metadata pre-filter (every job becomes
    /// a candidate and must be eval'd — the expensive path §3.3 avoids).
    pub metadata_filter: bool,
    /// Spatial neighbor graph for candidate pruning (None = all-pairs,
    /// the exact legacy behavior). When set, a request only considers
    /// jobs owning at least one of the requester's neighbors, except on
    /// [`topology::Topology::long_range_due`] windows where every job is
    /// considered again.
    pub topology: Option<topology::Topology>,
}

impl Default for GroupingPolicy {
    fn default() -> Self {
        GroupingPolicy {
            time_eps: 240.0,
            loc_delta: 0.2,
            drop_threshold: 0.25,
            metadata_filter: true,
            topology: None,
        }
    }
}

/// Outcome of initial grouping for one request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Decision {
    /// Joined an existing job.
    Joined(usize),
    /// No correlated job (or none beat the camera's own model): new job id.
    NewJob(usize),
}

fn loc_dist(a: (f32, f32), b: (f32, f32)) -> f32 {
    ((a.0 - b.0).powi(2) + (a.1 - b.1).powi(2)).sqrt()
}

/// Does `req` pass the metadata correlation filter against job `j`?
pub fn metadata_correlated(policy: &GroupingPolicy, job: &GroupJob, req: &RequestMeta) -> bool {
    job.members.iter().all(|r| {
        (r.time - req.time).abs() <= policy.time_eps
            && loc_dist(r.loc, req.loc) <= policy.loc_delta
    })
}

/// Alg. 2 `GroupRequest`. `eval(job_id)` must return the accuracy of that
/// job's current model on the request's sampled frames; it is only invoked
/// for jobs passing the metadata filter (the whole point of the filter).
/// Considers every job — see [`group_request_pruned`] for the
/// topology-restricted variant.
pub fn group_request<F: FnMut(usize) -> f32>(
    jobs: &mut Vec<GroupJob>,
    next_job_id: &mut usize,
    policy: &GroupingPolicy,
    req: RequestMeta,
    eval: F,
) -> Decision {
    group_request_pruned(jobs, next_job_id, policy, None, req, eval)
}

/// [`group_request`] restricted to a candidate set: when `candidates` is
/// `Some`, only jobs whose id is in the set are examined (metadata filter
/// *and* eval both skipped otherwise); `None` is exactly `group_request`.
/// A request whose candidate set rules out every job starts a new job,
/// same as an empty fleet would.
pub fn group_request_pruned<F: FnMut(usize) -> f32>(
    jobs: &mut Vec<GroupJob>,
    next_job_id: &mut usize,
    policy: &GroupingPolicy,
    candidates: Option<&BTreeSet<usize>>,
    req: RequestMeta,
    mut eval: F,
) -> Decision {
    let mut best: Option<(usize, f32)> = None;
    for job in jobs.iter() {
        if let Some(set) = candidates {
            if !set.contains(&job.id) {
                continue;
            }
        }
        if policy.metadata_filter && !metadata_correlated(policy, job, &req) {
            continue;
        }
        let acc = eval(job.id);
        if acc >= req.acc {
            // Performance check passed: candidate.
            if best.map(|(_, a)| acc > a).unwrap_or(true) {
                best = Some((job.id, acc));
            }
        }
    }
    match best {
        Some((job_id, _)) => {
            let job = jobs.iter_mut().find(|j| j.id == job_id).unwrap();
            job.members.push(req);
            Decision::Joined(job_id)
        }
        None => {
            let id = *next_job_id;
            *next_job_id += 1;
            jobs.push(GroupJob::new(id, req));
            Decision::NewJob(id)
        }
    }
}

/// One eviction produced by [`update_grouping`].
#[derive(Debug, Clone)]
pub struct Eviction {
    pub job_id: usize,
    pub meta: RequestMeta,
}

/// Alg. 2 `UpdateGrouping`, run at the end of each retraining window.
/// `eval(job_id, cam)` returns the group model's current accuracy on that
/// camera's fresh subsamples. Members whose accuracy fell by more than
/// `drop_threshold` (relative) are removed and returned; empty jobs are
/// dropped. Callers re-submit evictions through [`group_request`] with
/// refreshed metadata.
pub fn update_grouping<F: FnMut(usize, usize) -> f32>(
    jobs: &mut Vec<GroupJob>,
    policy: &GroupingPolicy,
    now: f64,
    loc_of: impl Fn(usize) -> (f32, f32),
    mut eval: F,
) -> Vec<Eviction> {
    let mut evicted = Vec::new();
    for job in jobs.iter_mut() {
        let mut keep = Vec::with_capacity(job.members.len());
        for member in job.members.drain(..) {
            let acc_now = eval(job.id, member.cam);
            let verdict = match job.prev_acc.get(&member.cam) {
                Some(&prev) if prev > 1e-6 => (acc_now - prev) / prev >= -policy.drop_threshold,
                _ => true, // no baseline yet: keep and record
            };
            if verdict {
                job.prev_acc.insert(member.cam, acc_now);
                keep.push(member);
            } else {
                job.prev_acc.remove(&member.cam);
                evicted.push(Eviction {
                    job_id: job.id,
                    meta: RequestMeta {
                        cam: member.cam,
                        time: now,
                        loc: loc_of(member.cam),
                        acc: acc_now,
                    },
                });
            }
        }
        job.members = keep;
    }
    jobs.retain(|j| !j.members.is_empty());
    evicted
}

/// Invariant checker used by tests and debug assertions: every camera
/// appears in at most one job.
pub fn is_partition(jobs: &[GroupJob]) -> bool {
    let mut seen = std::collections::BTreeSet::new();
    for j in jobs {
        for m in &j.members {
            if !seen.insert(m.cam) {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn req(cam: usize, time: f64, loc: (f32, f32), acc: f32) -> RequestMeta {
        RequestMeta {
            cam,
            time,
            loc,
            acc,
        }
    }

    #[test]
    fn first_request_creates_job() {
        let mut jobs = Vec::new();
        let mut next = 0;
        let d = group_request(
            &mut jobs,
            &mut next,
            &GroupingPolicy::default(),
            req(0, 10.0, (0.1, 0.1), 0.15),
            |_| unreachable!("no jobs to eval"),
        );
        assert_eq!(d, Decision::NewJob(0));
        assert_eq!(jobs.len(), 1);
    }

    #[test]
    fn correlated_request_joins_best_job() {
        let policy = GroupingPolicy::default();
        let mut jobs = vec![
            GroupJob::new(0, req(0, 10.0, (0.1, 0.1), 0.2)),
            GroupJob::new(1, req(1, 12.0, (0.15, 0.1), 0.2)),
        ];
        let mut next = 2;
        // Both jobs pass metadata; job 1's model is better on the request.
        let d = group_request(
            &mut jobs,
            &mut next,
            &policy,
            req(2, 15.0, (0.12, 0.12), 0.1),
            |job_id| if job_id == 1 { 0.3 } else { 0.2 },
        );
        assert_eq!(d, Decision::Joined(1));
        assert_eq!(jobs[1].members.len(), 2);
        assert!(is_partition(&jobs));
    }

    #[test]
    fn metadata_filter_blocks_distant_requests() {
        let policy = GroupingPolicy::default();
        let mut jobs = vec![GroupJob::new(0, req(0, 10.0, (0.1, 0.1), 0.2))];
        let mut next = 1;
        let mut evals = 0;
        // Far away in space: must NOT be eval'd, must start a new job.
        let d = group_request(
            &mut jobs,
            &mut next,
            &policy,
            req(1, 11.0, (0.9, 0.9), 0.1),
            |_| {
                evals += 1;
                0.9
            },
        );
        assert_eq!(d, Decision::NewJob(1));
        assert_eq!(evals, 0, "metadata filter must avoid the eval");
        // Far away in time likewise.
        let d2 = group_request(
            &mut jobs,
            &mut next,
            &policy,
            req(2, 10_000.0, (0.1, 0.1), 0.1),
            |_| {
                evals += 1;
                0.9
            },
        );
        assert_eq!(d2, Decision::NewJob(2));
        assert_eq!(evals, 0);
    }

    #[test]
    fn performance_check_rejects_worse_models() {
        let policy = GroupingPolicy::default();
        let mut jobs = vec![GroupJob::new(0, req(0, 10.0, (0.1, 0.1), 0.2))];
        let mut next = 1;
        // Correlated, but the group model (0.1) is worse than the camera's
        // own accuracy (0.25): start a new job.
        let d = group_request(
            &mut jobs,
            &mut next,
            &policy,
            req(1, 12.0, (0.12, 0.1), 0.25),
            |_| 0.1,
        );
        assert_eq!(d, Decision::NewJob(1));
    }

    #[test]
    fn disabled_filter_evals_everything() {
        let policy = GroupingPolicy {
            metadata_filter: false,
            ..GroupingPolicy::default()
        };
        let mut jobs = vec![GroupJob::new(0, req(0, 10.0, (0.1, 0.1), 0.2))];
        let mut next = 1;
        let mut evals = 0;
        group_request(
            &mut jobs,
            &mut next,
            &policy,
            req(1, 10_000.0, (0.9, 0.9), 0.1),
            |_| {
                evals += 1;
                0.05
            },
        );
        assert_eq!(evals, 1);
    }

    #[test]
    fn update_grouping_evicts_on_drop() {
        let policy = GroupingPolicy::default();
        let mut jobs = vec![GroupJob::new(0, req(0, 0.0, (0.1, 0.1), 0.2))];
        jobs[0].members.push(req(1, 1.0, (0.1, 0.12), 0.2));
        // Window 1: establish baselines (0.4 both).
        let ev1 = update_grouping(&mut jobs, &policy, 100.0, |_| (0.5, 0.5), |_, _| 0.4);
        assert!(ev1.is_empty());
        // Window 2: camera 1 collapses to 0.2 (-50% < -15%).
        let ev2 = update_grouping(
            &mut jobs,
            &policy,
            200.0,
            |_| (0.5, 0.5),
            |_, cam| if cam == 1 { 0.2 } else { 0.42 },
        );
        assert_eq!(ev2.len(), 1);
        assert_eq!(ev2[0].meta.cam, 1);
        assert_eq!(ev2[0].meta.time, 200.0);
        assert!((ev2[0].meta.acc - 0.2).abs() < 1e-6);
        assert_eq!(jobs[0].members.len(), 1);
        assert!(is_partition(&jobs));
    }

    #[test]
    fn update_grouping_drops_empty_jobs() {
        let policy = GroupingPolicy::default();
        let mut jobs = vec![GroupJob::new(0, req(0, 0.0, (0.1, 0.1), 0.2))];
        update_grouping(&mut jobs, &policy, 100.0, |_| (0.0, 0.0), |_, _| 0.4);
        let ev = update_grouping(&mut jobs, &policy, 200.0, |_| (0.0, 0.0), |_, _| 0.01);
        assert_eq!(ev.len(), 1);
        assert!(jobs.is_empty(), "empty job must be removed");
    }

    #[test]
    fn small_fluctuations_do_not_evict() {
        let policy = GroupingPolicy::default();
        let mut jobs = vec![GroupJob::new(0, req(0, 0.0, (0.1, 0.1), 0.2))];
        update_grouping(&mut jobs, &policy, 100.0, |_| (0.0, 0.0), |_, _| 0.40);
        let ev = update_grouping(&mut jobs, &policy, 200.0, |_| (0.0, 0.0), |_, _| 0.37);
        assert!(ev.is_empty(), "-7.5% is within the 15% tolerance");
    }

    #[test]
    fn pruning_blocks_non_candidate_jobs() {
        let policy = GroupingPolicy::default();
        let mut jobs = vec![
            GroupJob::new(0, req(0, 10.0, (0.1, 0.1), 0.2)),
            GroupJob::new(1, req(1, 12.0, (0.15, 0.1), 0.2)),
        ];
        let mut next = 2;
        // Job 1 scores better but is not a candidate: job 0 must win.
        let set: BTreeSet<usize> = [0].into_iter().collect();
        let mut evals = Vec::new();
        let d = group_request_pruned(
            &mut jobs,
            &mut next,
            &policy,
            Some(&set),
            req(2, 15.0, (0.12, 0.12), 0.1),
            |job_id| {
                evals.push(job_id);
                if job_id == 1 {
                    0.9
                } else {
                    0.2
                }
            },
        );
        assert_eq!(d, Decision::Joined(0));
        assert_eq!(evals, vec![0], "pruned job must not even be eval'd");
        // Empty candidate set: new job, zero evals.
        let empty = BTreeSet::new();
        let d2 = group_request_pruned(
            &mut jobs,
            &mut next,
            &policy,
            Some(&empty),
            req(3, 15.0, (0.12, 0.12), 0.0),
            |_| unreachable!("no candidates to eval"),
        );
        assert_eq!(d2, Decision::NewJob(2));
        assert!(is_partition(&jobs));
    }

    /// ISSUE 7 satellite: a complete candidate set (what a degree n-1
    /// topology produces) must reproduce all-pairs grouping decisions
    /// exactly, under random request storms.
    #[test]
    fn prop_full_candidate_set_matches_all_pairs() {
        prop::check("grouping-pruned-full-equiv", 60, |g| {
            let policy = GroupingPolicy::default();
            let mut jobs_a: Vec<GroupJob> = Vec::new();
            let mut jobs_b: Vec<GroupJob> = Vec::new();
            let (mut next_a, mut next_b) = (0usize, 0usize);
            let n_cams = g.usize(2, 12);
            for cam in 0..n_cams {
                let r = req(
                    cam,
                    g.f32(0.0, 300.0) as f64,
                    (g.f32(0.0, 1.0), g.f32(0.0, 1.0)),
                    g.f32(0.0, 0.4),
                );
                let acc = g.f32(0.0, 0.6);
                let d_a = group_request(&mut jobs_a, &mut next_a, &policy, r.clone(), |_| acc);
                let all: BTreeSet<usize> = jobs_b.iter().map(|j| j.id).collect();
                let d_b = group_request_pruned(
                    &mut jobs_b,
                    &mut next_b,
                    &policy,
                    Some(&all),
                    r,
                    |_| acc,
                );
                if d_a != d_b {
                    return Err(format!("decision diverged: {d_a:?} vs {d_b:?}"));
                }
            }
            if next_a != next_b || jobs_a.len() != jobs_b.len() {
                return Err("job sets diverged".to_string());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_partition_invariant_under_random_churn() {
        prop::check("grouping-partition", 40, |g| {
            let policy = GroupingPolicy::default();
            let mut jobs: Vec<GroupJob> = Vec::new();
            let mut next = 0usize;
            let n_cams = g.usize(2, 10);
            // Random request storm.
            for cam in 0..n_cams {
                let r = req(
                    cam,
                    g.f32(0.0, 100.0) as f64,
                    (g.f32(0.0, 1.0), g.f32(0.0, 1.0)),
                    g.f32(0.0, 0.4),
                );
                let acc = g.f32(0.0, 0.6);
                group_request(&mut jobs, &mut next, &policy, r, |_| acc);
                if !is_partition(&jobs) {
                    return Err("partition violated after request".to_string());
                }
            }
            // Random churn: evict some, re-request them.
            for round in 0..3 {
                let flaky = g.usize(0, n_cams.saturating_sub(1));
                let evs = update_grouping(
                    &mut jobs,
                    &policy,
                    1000.0 + round as f64,
                    |_| (0.5, 0.5),
                    |_, cam| if cam == flaky { 0.01 } else { 0.5 },
                );
                if !is_partition(&jobs) {
                    return Err("partition violated after update".to_string());
                }
                for ev in evs {
                    group_request(&mut jobs, &mut next, &policy, ev.meta, |_| 0.0);
                }
                if !is_partition(&jobs) {
                    return Err("partition violated after re-request".to_string());
                }
            }
            // Every camera still present exactly once.
            let total: usize = jobs.iter().map(|j| j.members.len()).sum();
            if total != n_cams {
                return Err(format!("lost cameras: {total} != {n_cams}"));
            }
            Ok(())
        });
    }
}

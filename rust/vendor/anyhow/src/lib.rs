//! Offline shim of the `anyhow` API surface this workspace uses.
//!
//! The build environment has no crates.io access, so the real `anyhow`
//! cannot be fetched. This vendored stand-in implements the subset the
//! codebase relies on — `Result`, `Error`, `anyhow!`, `bail!`, and the
//! `Context` extension trait — with the same semantics: an opaque error
//! value carrying a display message and an optional chain of contexts.
//!
//! Like the real crate, [`Error`] deliberately does NOT implement
//! `std::error::Error`; that is what allows the blanket
//! `From<E: std::error::Error>` conversion used by `?`.

use std::fmt;

/// `Result` specialised to [`Error`], matching `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a message plus outer context frames (most recent first).
pub struct Error {
    /// Context chain: `chain[0]` is the outermost (most recently attached).
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Attach an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Outermost message (what `Display` leads with).
    pub fn root_message(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.root_message())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.root_message())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, frame) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {frame}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

mod private {
    /// Sealed marker unifying "things that convert into [`Error`]" for the
    /// [`Context`](super::Context) impls, mirroring anyhow's internal
    /// `StdError` trait trick.
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> super::Error {
            super::Error::from(self)
        }
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }
}

/// Extension trait adding context to `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: private::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let text = std::fs::read_to_string("/definitely/not/a/real/path")
            .context("reading config")?;
        Ok(text)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert_eq!(err.to_string(), "reading config");
        let dbg = format!("{err:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn bail_and_anyhow_format() {
        fn f(x: usize) -> Result<()> {
            if x > 3 {
                bail!("x too large: {x}");
            }
            Ok(())
        }
        assert!(f(2).is_ok());
        assert_eq!(f(9).unwrap_err().to_string(), "x too large: 9");
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(e.to_string(), "outer 1");
        assert!(format!("{e:?}").contains("inner"));

        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
    }
}

//! Integration pins for `ecco lint`: every rule fires on a fixture tree,
//! the shipped sources are clean through the real binary, and the JSON
//! report round-trips as a `--baseline`.

use std::fs;
use std::path::PathBuf;
use std::process::{Command, Output};

use ecco::lint::lint_root;
use ecco::util::json::Json;

const BIN: &str = env!("CARGO_BIN_EXE_ecco");

/// A scratch fixture tree under the OS temp dir, removed on drop. Tagged
/// per test so parallel tests don't collide.
struct Scratch(PathBuf);

impl Scratch {
    fn new(tag: &str) -> Scratch {
        let dir = std::env::temp_dir().join(format!("ecco-lint-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).expect("create scratch dir");
        Scratch(dir)
    }

    fn write(&self, rel: &str, src: &str) {
        let path = self.0.join(rel);
        fs::create_dir_all(path.parent().expect("fixture has a parent")).expect("mkdir");
        fs::write(&path, src).expect("write fixture");
    }

    fn path(&self) -> &str {
        self.0.to_str().expect("utf-8 temp path")
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.0);
    }
}

fn run_lint(args: &[&str]) -> Output {
    Command::new(BIN).arg("lint").args(args).output().expect("spawn ecco lint")
}

fn stdout_of(out: &Output) -> String {
    String::from_utf8(out.stdout.clone()).expect("utf-8 stdout")
}

/// One known-bad file per rule; the library walk must flag all six.
#[test]
fn every_rule_fires_across_a_fixture_tree() {
    let scratch = Scratch::new("rules");
    scratch.write("serve/d001.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n");
    scratch.write("api/d002.rs", "use std::collections::HashMap;\n");
    scratch.write("scene/d003.rs", "fn f() { let t = Instant::now(); }\n");
    scratch.write("scene/d004.rs", "fn f(p: *const u32) -> u32 { unsafe { *p } }\n");
    scratch.write(
        "metrics/d005.rs",
        "fn f(a: f64, b: f64) { let _ = a.partial_cmp(&b); }\n",
    );
    scratch.write("zoo/d006.rs", "fn f(m: &Mutex<u32>) { let _g = m.lock().unwrap(); }\n");

    let report = lint_root(&scratch.0).expect("lint fixture tree");
    assert_eq!(report.files_scanned, 6);
    let rules: Vec<&str> = report.findings.iter().map(|f| f.rule.as_str()).collect();
    for rule in ["D001", "D002", "D003", "D004", "D005", "D006"] {
        assert!(rules.contains(&rule), "{rule} missing from {rules:?}");
    }
    // Paths come back root-relative with `/` separators.
    assert!(report.findings.iter().any(|f| f.path == "serve/d001.rs"));
}

/// The same assertion CI's `rust-lint` job makes: the shipped tree is
/// clean through the real binary (exit 0), and the summary line says so.
#[test]
fn shipped_tree_is_clean_via_binary() {
    let out = run_lint(&[]);
    let text = stdout_of(&out);
    assert!(
        out.status.success(),
        "ecco lint found violations:\n{text}{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(text.contains("0 finding(s)"), "{text}");
}

/// A dirty tree exits 1 with JSON findings; feeding that JSON back as
/// `--baseline` suppresses them and exits 0 — the round-trip CI relies on
/// to introduce the linter over a tree with known debt.
#[test]
fn json_report_round_trips_as_a_baseline() {
    let scratch = Scratch::new("baseline");
    scratch.write("serve/bad.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n");
    scratch.write("zoo/bad.rs", "fn f(m: &Mutex<u32>) { let _g = m.lock().unwrap(); }\n");

    let dirty = run_lint(&[scratch.path(), "--format", "json"]);
    assert_eq!(dirty.status.code(), Some(1), "dirty tree must exit 1");
    let json = stdout_of(&dirty);
    let parsed = Json::parse(&json).expect("findings are valid json");
    let total = parsed.get("total").unwrap().as_usize().unwrap();
    assert_eq!(total, 2, "{json}");

    let baseline_file = scratch.0.join("baseline.json");
    fs::write(&baseline_file, &json).expect("write baseline");
    let clean = run_lint(&[
        scratch.path(),
        "--format",
        "json",
        "--baseline",
        baseline_file.to_str().unwrap(),
    ]);
    assert!(
        clean.status.success(),
        "baselined run should exit 0:\n{}",
        stdout_of(&clean)
    );
    let reparsed = Json::parse(&stdout_of(&clean)).expect("json");
    assert_eq!(reparsed.get("total").unwrap().as_usize().unwrap(), 0);
}

/// Inline suppressions silence a finding only with a written reason; a
/// bare `allow(..)` keeps the finding and adds a LINT complaint.
#[test]
fn suppressions_require_reasons_through_the_binary() {
    let scratch = Scratch::new("suppress");
    scratch.write(
        "serve/ok.rs",
        "fn f(x: Option<u32>) -> u32 {\n\
         \x20   // ecco-lint: allow(D001) fixture: x is Some by construction\n\
         \x20   x.unwrap()\n\
         }\n",
    );
    let out = run_lint(&[scratch.path()]);
    assert!(out.status.success(), "{}", stdout_of(&out));

    scratch.write(
        "serve/bare.rs",
        "fn f(x: Option<u32>) -> u32 {\n\
         \x20   // ecco-lint: allow(D001)\n\
         \x20   x.unwrap()\n\
         }\n",
    );
    let out = run_lint(&[scratch.path()]);
    assert_eq!(out.status.code(), Some(1));
    let text = stdout_of(&out);
    assert!(text.contains("[LINT]"), "{text}");
    assert!(text.contains("[D001]"), "{text}");
}

/// `--fix-hints` appends per-rule remediation lines; bad `--format`
/// values are rejected with a non-zero exit.
#[test]
fn cli_hints_and_format_validation() {
    let scratch = Scratch::new("cli");
    scratch.write("serve/bad.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n");

    let hinted = run_lint(&[scratch.path(), "--fix-hints"]);
    let text = stdout_of(&hinted);
    assert!(text.contains("hint[D001]:"), "{text}");

    let bad_format = run_lint(&[scratch.path(), "--format", "yaml"]);
    assert!(!bad_format.status.success());
    let err = String::from_utf8_lossy(&bad_format.stderr).to_string();
    assert!(err.contains("format"), "{err}");
}

//! End-to-end serve-host pins over real sockets: FIFO admission fairness,
//! snapshot/resume byte-equality with an uninterrupted run, bounded
//! subscriber buffers under a deliberately slow consumer, and protocol
//! robustness against malformed lines.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::thread;

use ecco::api::{CoalesceOpts, RunSpec, RuntimeOpts, SimOpts};
use ecco::runtime::{Engine, Task};
use ecco::serve::{Bind, ServeConfig, Server};
use ecco::server::Policy;
use ecco::util::json::{obj, s, Json};

/// A reduced-scale deterministic spec that still exercises grouping and
/// retraining (3 cameras, 3 windows, short windows, few eval frames).
fn small_spec(seed: u64) -> RunSpec {
    RunSpec::new(Task::Det, Policy::ecco())
        .cams(3)
        .gpus(1.0)
        .shared_mbps(10.0)
        .windows(3)
        .seed(seed)
        .sim(
            SimOpts::new()
                .window_secs(30.0)
                .micro_windows(2)
                .eval_frames(4)
                .pretrain_steps(40),
        )
}

fn spec_json(seed: u64) -> String {
    small_spec(seed).to_wire_json().to_string_compact()
}

/// Bind on an ephemeral port, run the server on a scoped thread, hand the
/// address to the test body, then shut the server down.
fn with_server<F>(cfg: ServeConfig, f: F)
where
    F: FnOnce(SocketAddr) + Send,
{
    let engine = Engine::open_default().unwrap();
    let server = Server::bind(&engine, &Bind::Tcp("127.0.0.1:0".into()), cfg).unwrap();
    let addr = server.local_addr().unwrap();
    thread::scope(|scope| {
        let host = scope.spawn(move || server.run().unwrap());
        f(addr);
        // Always send shutdown, even if the body already did (idempotent:
        // a second connection either errors or goes unanswered).
        if let Ok(mut conn) = TcpStream::connect(addr) {
            let _ = writeln!(conn, "{}", r#"{"cmd":"shutdown"}"#);
        }
        host.join().unwrap();
    });
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        Client {
            reader: BufReader::new(stream.try_clone().unwrap()),
            writer: stream,
        }
    }

    fn read_line(&mut self) -> Option<String> {
        let mut buf = String::new();
        match self.reader.read_line(&mut buf) {
            Ok(0) => None,
            Ok(_) => Some(buf.trim_end().to_string()),
            Err(e) => panic!("client read failed: {e}"),
        }
    }

    fn read_json(&mut self) -> Json {
        let line = self.read_line().expect("connection closed mid-response");
        Json::parse(&line).unwrap_or_else(|e| panic!("bad response {line:?}: {e}"))
    }

    /// Send one request line and read the one-line response.
    fn send(&mut self, line: &str) -> Json {
        writeln!(self.writer, "{line}").unwrap();
        self.read_json()
    }

    /// Read stream frames until (and including) the `end` frame.
    fn drain_frames(&mut self) -> Vec<String> {
        let mut frames = Vec::new();
        loop {
            let line = self.read_line().expect("stream closed before end frame");
            let done = line.contains(r#""frame":"end""#);
            frames.push(line);
            if done {
                return frames;
            }
        }
    }
}

fn assert_ok(resp: &Json) {
    assert_eq!(
        resp.get("ok").ok().cloned(),
        Some(Json::Bool(true)),
        "expected ok response, got {}",
        resp.to_string_compact()
    );
}

fn session_id(resp: &Json) -> u64 {
    assert_ok(resp);
    resp.get("session").unwrap().as_usize().unwrap() as u64
}

fn event_frames(frames: &[String]) -> Vec<String> {
    frames
        .iter()
        .filter(|f| f.contains(r#""frame":"event""#))
        .cloned()
        .collect()
}

fn frame_seq(frame: &str) -> u64 {
    Json::parse(frame).unwrap().get("seq").unwrap().as_usize().unwrap() as u64
}

#[test]
fn single_runner_completes_sessions_in_fifo_order() {
    let cfg = ServeConfig {
        runners: 1,
        ..ServeConfig::default()
    };
    with_server(cfg, |addr| {
        // Submit 4 sessions on 4 connections, strictly in order; each
        // subscribes to its own event stream at submit time.
        let mut clients: Vec<Client> = Vec::new();
        let mut ids: Vec<u64> = Vec::new();
        for i in 0..4u64 {
            let mut client = Client::connect(addr);
            let resp = client.send(&format!(
                r#"{{"cmd":"submit","spec":{},"events":true}}"#,
                spec_json(100 + i)
            ));
            ids.push(session_id(&resp));
            clients.push(client);
        }
        // Drain all 4 streams concurrently — completion order must not
        // depend on which consumer reads first.
        let streams: Vec<Vec<String>> = thread::scope(|scope| {
            let handles: Vec<_> = clients
                .iter_mut()
                .map(|c| scope.spawn(move || c.drain_frames()))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // Every session ran to completion and logged a complete stream:
        // seq contiguous from 0 and one window_closed per window.
        for (i, frames) in streams.iter().enumerate() {
            let events = event_frames(frames);
            assert!(!events.is_empty(), "session {i} forwarded no events");
            for (k, frame) in events.iter().enumerate() {
                assert_eq!(frame_seq(frame), k as u64, "session {i} seq gap");
            }
            let closed = events
                .iter()
                .filter(|f| f.contains(r#""type":"window_closed""#))
                .count();
            assert_eq!(closed, 3, "session {i} window_closed count");
            assert_eq!(
                frames.last().unwrap().as_str(),
                r#"{"frame":"end","state":"done"}"#
            );
        }
        // FIFO: with one runner, start order equals submit order.
        let mut ctl = Client::connect(addr);
        let mut starts = Vec::new();
        for &id in &ids {
            let resp = ctl.send(&format!(r#"{{"cmd":"status","session":{id}}}"#));
            assert_ok(&resp);
            assert_eq!(resp.get("state").unwrap().as_str().unwrap(), "done");
            starts.push(resp.get("started").unwrap().as_usize().unwrap());
        }
        assert_eq!(starts, vec![0, 1, 2, 3], "admission order violated");
    });
}

#[test]
fn snapshot_resume_replays_byte_identically() {
    with_server(ServeConfig::default(), |addr| {
        // Reference: the uninterrupted run's event frames.
        let mut fresh = Client::connect(addr);
        let resp = fresh.send(&format!(
            r#"{{"cmd":"submit","spec":{},"events":true}}"#,
            spec_json(91)
        ));
        let fresh_id = session_id(&resp);
        let fresh_frames = event_frames(&fresh.drain_frames());
        assert!(!fresh_frames.is_empty());

        // Same spec, interrupted by a scheduled snapshot after 1 window.
        let mut part1 = Client::connect(addr);
        let resp = part1.send(&format!(
            r#"{{"cmd":"submit","spec":{},"events":true,"pause_after":1}}"#,
            spec_json(91)
        ));
        let paused_id = session_id(&resp);
        assert_ne!(paused_id, fresh_id);
        let part1_all = part1.drain_frames();
        assert_eq!(
            part1_all.last().unwrap().as_str(),
            r#"{"frame":"end","state":"snapshotted"}"#
        );
        let part1_frames = event_frames(&part1_all);
        assert!(!part1_frames.is_empty(), "nothing ran before the snapshot");
        assert!(part1_frames.len() < fresh_frames.len());

        // Fetch the snapshot and resume it on a new connection.
        let resp = part1.send(&format!(r#"{{"cmd":"snapshot","session":{paused_id}}}"#));
        assert_ok(&resp);
        let snapshot = resp.get("snapshot").unwrap().clone();
        assert_eq!(snapshot.get("completed").unwrap().as_usize().unwrap(), 1);
        let mut part2 = Client::connect(addr);
        let resume = obj(vec![
            ("cmd", s("resume")),
            ("events", Json::Bool(true)),
            ("snapshot", snapshot),
        ])
        .to_string_compact();
        let resp = part2.send(&resume);
        assert_ok(&resp);
        assert_eq!(resp.get("replay").unwrap().as_usize().unwrap(), 1);
        let part2_all = part2.drain_frames();
        assert_eq!(
            part2_all.last().unwrap().as_str(),
            r#"{"frame":"end","state":"done"}"#
        );
        let part2_frames = event_frames(&part2_all);

        // The pin: interrupted + resumed equals uninterrupted, byte for
        // byte — replayed windows are suppressed but still counted, so
        // the resumed stream continues seq-contiguously.
        assert_eq!(
            frame_seq(&part2_frames[0]),
            part1_frames.len() as u64,
            "resumed stream must continue where the snapshot stopped"
        );
        let stitched: Vec<String> = part1_frames
            .iter()
            .chain(part2_frames.iter())
            .cloned()
            .collect();
        assert_eq!(stitched, fresh_frames, "stitched stream diverged");
    });
}

#[test]
fn slow_consumer_gets_bounded_buffer_and_drop_accounting() {
    let cfg = ServeConfig {
        runners: 1,
        sub_buffer: 4,
        ..ServeConfig::default()
    };
    with_server(cfg, |addr| {
        // throttle_ms paces the server's writes to this consumer, so the
        // 4-frame buffer must overflow while the session trains.
        let mut slow = Client::connect(addr);
        let resp = slow.send(&format!(
            r#"{{"cmd":"submit","spec":{},"events":true,"throttle_ms":25}}"#,
            spec_json(17)
        ));
        let id = session_id(&resp);
        let frames = slow.drain_frames();
        assert_eq!(
            frames.last().unwrap().as_str(),
            r#"{"frame":"end","state":"done"}"#
        );
        let delivered = event_frames(&frames).len() as u64;
        let dropped: u64 = frames
            .iter()
            .filter(|f| f.contains(r#""frame":"dropped""#))
            .map(|f| {
                Json::parse(f).unwrap().get("count").unwrap().as_usize().unwrap() as u64
            })
            .sum();
        assert!(dropped > 0, "slow consumer never overflowed the buffer");
        // Conservation: every published event was either delivered or
        // counted in a drop marker.
        let mut ctl = Client::connect(addr);
        let resp = ctl.send(&format!(r#"{{"cmd":"status","session":{id}}}"#));
        assert_ok(&resp);
        let seq = resp.get("seq").unwrap().as_usize().unwrap() as u64;
        assert_eq!(delivered + dropped, seq, "drop accounting leaked frames");
        // The report survived the lossy stream (authoritative record is
        // server-side).
        let resp = ctl.send(&format!(r#"{{"cmd":"report","session":{id}}}"#));
        assert_ok(&resp);
        assert!(resp.get("final").unwrap().as_f64().unwrap().is_finite());
    });
}

#[test]
fn concurrent_coalescing_sessions_stream_byte_identically() {
    // Two tenants submit the same spec with micro-batch coalescing
    // enabled and drain their streams concurrently on a 2-runner host
    // sharing one engine — so their eval fan-outs can merge into shared
    // mega-batched kernel launches. The pin: both event streams are
    // byte-identical to each other AND to a per-call (coalescing off)
    // reference run, i.e. the submission layer never leaks into the
    // deterministic event surface.
    let mut reference: Vec<String> = Vec::new();
    with_server(
        ServeConfig {
            runners: 2,
            ..ServeConfig::default()
        },
        |addr| {
            let mut c = Client::connect(addr);
            let resp = c.send(&format!(
                r#"{{"cmd":"submit","spec":{},"events":true}}"#,
                spec_json(63)
            ));
            session_id(&resp);
            reference = event_frames(&c.drain_frames());
        },
    );
    assert!(!reference.is_empty(), "reference run forwarded no events");

    let spec_on = small_spec(63)
        .runtime(RuntimeOpts::new().coalesce(CoalesceOpts::on()))
        .to_wire_json()
        .to_string_compact();
    let mut streams: Vec<Vec<String>> = Vec::new();
    with_server(
        ServeConfig {
            runners: 2,
            ..ServeConfig::default()
        },
        |addr| {
            let mut a = Client::connect(addr);
            let mut b = Client::connect(addr);
            for client in [&mut a, &mut b] {
                let resp = client.send(&format!(
                    r#"{{"cmd":"submit","spec":{spec_on},"events":true}}"#
                ));
                session_id(&resp);
            }
            streams = thread::scope(|scope| {
                let ha = scope.spawn(move || a.drain_frames());
                let hb = scope.spawn(move || b.drain_frames());
                vec![ha.join().unwrap(), hb.join().unwrap()]
            });
        },
    );
    let ea = event_frames(&streams[0]);
    let eb = event_frames(&streams[1]);
    assert_eq!(ea, eb, "concurrent coalescing tenants diverged");
    assert_eq!(ea, reference, "coalesced stream diverged from per-call run");
}

#[test]
fn malformed_lines_get_error_responses_and_the_server_survives() {
    with_server(ServeConfig::default(), |addr| {
        let mut client = Client::connect(addr);
        for bad in [
            "not json at all",
            "[1,2,3]",
            r#"{"spec":{}}"#,
            r#"{"cmd":"launch"}"#,
            r#"{"cmd":"ping","bogus":1}"#,
            r#"{"cmd":"submit","spec":{"task":"det","policy":"warp"}}"#,
            r#"{"cmd":"submit","spec":{"task":"det","zzz":1}}"#,
            r#"{"cmd":"status","session":999}"#,
            r#"{"cmd":"resume","snapshot":{"completed":99,"spec":{"windows":3}}}"#,
        ] {
            let resp = client.send(bad);
            assert_eq!(
                resp.get("ok").ok().cloned(),
                Some(Json::Bool(false)),
                "{bad} should be rejected, got {}",
                resp.to_string_compact()
            );
            assert!(resp.get("error").is_ok(), "{bad} missing error");
        }
        // Same connection still works...
        assert_ok(&client.send(r#"{"cmd":"ping"}"#));
        // ...and so does a real session afterwards.
        let resp = client.send(&format!(
            r#"{{"cmd":"submit","spec":{},"events":true}}"#,
            spec_json(5)
        ));
        session_id(&resp);
        let frames = client.drain_frames();
        assert_eq!(
            frames.last().unwrap().as_str(),
            r#"{"frame":"end","state":"done"}"#
        );
    });
}

#[cfg(unix)]
#[test]
fn unix_socket_serves_the_same_protocol() {
    use std::os::unix::net::UnixStream;

    let path = std::env::temp_dir().join(format!("ecco-serve-test-{}.sock", std::process::id()));
    let engine = Engine::open_default().unwrap();
    let server = Server::bind(&engine, &Bind::Unix(path.clone()), ServeConfig::default()).unwrap();
    thread::scope(|scope| {
        let host = scope.spawn(move || server.run().unwrap());
        let stream = UnixStream::connect(&path).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writeln!(writer, "{}", r#"{"cmd":"ping"}"#).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), r#"{"ok":true}"#);
        writeln!(writer, "{}", r#"{"cmd":"shutdown"}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), r#"{"ok":true}"#);
        host.join().unwrap();
    });
    let _ = std::fs::remove_file(&path);
}

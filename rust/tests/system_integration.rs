//! Integration tests over the full system: scene -> network -> teacher ->
//! grouping -> allocation -> engine retraining -> metrics, at reduced
//! scale, driven exclusively through the `ecco::api` façade (the `System`
//! internals are crate-private).
//!
//! These are the "does the whole machine hold together" checks; the
//! per-module behaviour is covered by unit tests, and the paper-shape
//! results by `ecco exp ...`.

use ecco::api::{RunSpec, Session};
use ecco::runtime::{Engine, Task};
use ecco::scene::scenario;
use ecco::server::Policy;

/// Reduced-scale config shared by every test (fast, deterministic).
fn small_spec(task: Task, policy: Policy) -> RunSpec {
    RunSpec::new(task, policy)
        .gpus(1.0)
        .shared_mbps(10.0)
        .uplink_mbps(20.0)
        .seed(99)
        .configure(|cfg| {
            cfg.micro_windows = 4;
            cfg.window_secs = 40.0;
            cfg.eval_frames = 8;
            cfg.pretrain_steps = 120;
        })
}

#[test]
fn ecco_full_loop_groups_and_recovers() {
    let mut engine = Engine::open_default().unwrap();
    let spec = small_spec(Task::Det, Policy::ecco())
        .scenario(scenario::grouped_static(&[3], 0.05, 20.0, 5))
        .windows(5);
    let mut session = Session::new(&mut engine, spec).unwrap();
    let mut reports = Vec::new();
    for _ in 0..5 {
        reports.push(session.step_window().unwrap());
    }
    // All cameras requested retraining (the drift event is strong); Alg. 2
    // churn may add re-requests on top.
    assert!(session.requests_total() >= 3, "all cameras must request");
    // Cross-camera correlation => few jobs covering all three cameras.
    assert!(
        session.jobs() <= 2,
        "correlated cameras must mostly group: {} jobs",
        session.jobs()
    );
    let membership = session.membership();
    let members: usize = membership.iter().map(|(_, m)| m.len()).sum();
    assert_eq!(members, 3);
    assert!(membership.iter().any(|(_, m)| m.len() >= 2));
    assert!(session.is_partition());
    // Accuracy must be sane and improving from the immediate post-drift dip.
    let acc = session.mean_accuracy();
    assert!((0.0..=1.0).contains(&acc));
    let w0 = reports[0].cam_acc[0];
    assert!(
        acc > w0,
        "retraining should improve accuracy: w0 {w0} -> final {acc}"
    );
}

#[test]
fn independent_policy_never_groups() {
    let mut engine = Engine::open_default().unwrap();
    let spec = small_spec(Task::Det, Policy::ekya())
        .scenario(scenario::grouped_static(&[3], 0.05, 20.0, 6))
        .windows(4);
    let mut session = Session::new(&mut engine, spec).unwrap();
    for _ in 0..4 {
        session.step_window().unwrap();
    }
    assert_eq!(session.jobs(), 3, "independent retraining: one job per camera");
    for (_, members) in session.membership() {
        assert_eq!(members.len(), 1);
    }
}

#[test]
fn seg_task_runs_end_to_end() {
    let mut engine = Engine::open_default().unwrap();
    let spec = small_spec(Task::Seg, Policy::ecco())
        .scenario(scenario::grouped_static(&[2], 0.05, 20.0, 7))
        .windows(3);
    let mut session = Session::new(&mut engine, spec).unwrap();
    for _ in 0..3 {
        session.step_window().unwrap();
    }
    let acc = session.mean_accuracy();
    assert!((0.0..=1.0).contains(&acc));
    assert!(
        session.engine_stats().train_steps > 0,
        "seg training must run"
    );
}

#[test]
fn gpu_budget_controls_training_volume() {
    let mut engine = Engine::open_default().unwrap();
    let mut steps = Vec::new();
    for gpus in [1.0, 4.0] {
        let before = engine.stats().train_steps;
        let spec = small_spec(Task::Det, Policy::ecco())
            .scenario(scenario::grouped_static(&[2], 0.05, 10.0, 8))
            .gpus(gpus)
            .windows(3);
        let mut session = Session::new(&mut engine, spec).unwrap();
        for _ in 0..3 {
            session.step_window().unwrap();
        }
        steps.push(session.engine_stats().train_steps - before);
    }
    assert!(
        steps[1] > steps[0] * 2,
        "4 GPUs must train much more than 1: {steps:?}"
    );
}

#[test]
fn bandwidth_starvation_reduces_delivered_data() {
    let mut engine = Engine::open_default().unwrap();
    let mut labelled = Vec::new();
    // Fixed-config policy (naive) so the stream demand is constant and the
    // uplink is the only variable; count teacher annotations (the job
    // buffer is ring-capped so it can't be compared directly).
    for bw in [0.05, 20.0] {
        let spec = small_spec(Task::Det, Policy::naive())
            .scenario(scenario::grouped_static(&[2], 0.05, 10.0, 9))
            .uplink_mbps(bw)
            .shared_mbps(50.0)
            .windows(3);
        let mut session = Session::new(&mut engine, spec).unwrap();
        for _ in 0..3 {
            session.step_window().unwrap();
        }
        labelled.push(session.teacher_annotated());
    }
    assert!(
        labelled[1] > labelled[0],
        "more uplink must deliver more training data: {labelled:?}"
    );
}

#[test]
fn forced_groups_and_scripted_requests() {
    let mut engine = Engine::open_default().unwrap();
    let spec = small_spec(Task::Det, Policy::ecco())
        .scenario(scenario::grouped_static(&[4], 0.05, 10.0, 10))
        .windows(3)
        .configure(|cfg| {
            cfg.auto_request = false;
            cfg.auto_regroup = false;
        });
    let mut session = Session::new(&mut engine, spec).unwrap();
    // Nothing happens without requests.
    session.step_window().unwrap();
    assert_eq!(session.jobs(), 0);
    // Forced group of 3 + scripted request from a correlated camera: the
    // grouping pipeline should absorb it into the existing job.
    session.force_group(&[0, 1, 2]).unwrap();
    session.request_now(3).unwrap();
    for _ in 0..2 {
        session.step_window().unwrap();
    }
    assert!(session.is_partition());
    let membership = session.membership();
    let members: usize = membership.iter().map(|(_, m)| m.len()).sum();
    assert_eq!(members, 4);
    assert!(
        membership.iter().any(|(_, m)| m.len() >= 3),
        "the forced group must persist"
    );
}

#[test]
fn force_group_reassignment_preserves_partition() {
    // Regression: force_group used to add an already-grouped camera to the
    // new job without removing it from its old one, breaking the
    // one-job-per-camera invariant.
    let mut engine = Engine::open_default().unwrap();
    let spec = small_spec(Task::Det, Policy::ecco())
        .scenario(scenario::grouped_static(&[4], 0.05, 10.0, 21))
        .windows(2)
        .configure(|cfg| {
            cfg.auto_request = false;
            cfg.auto_regroup = false;
        });
    let mut session = Session::new(&mut engine, spec).unwrap();
    let first = session.force_group(&[0, 1]).unwrap();
    // Camera 1 is pulled into a second forced group: it must leave the
    // first job, and the partition must hold.
    let second = session.force_group(&[1, 2]).unwrap();
    assert!(session.is_partition());
    let membership = session.membership();
    let total: usize = membership.iter().map(|(_, m)| m.len()).sum();
    assert_eq!(total, 3, "cameras 0,1,2 exactly once: {membership:?}");
    let job_of = |cam: usize| {
        membership
            .iter()
            .find(|(_, m)| m.contains(&cam))
            .map(|(id, _)| *id)
    };
    assert_eq!(job_of(1), Some(second), "cam 1 must move to the new job");
    assert_eq!(job_of(0), Some(first), "cam 0 stays in the old job");
    // Re-grouping EVERY member of a job must drop the emptied job.
    let third = session.force_group(&[0]).unwrap();
    let membership = session.membership();
    assert!(
        membership.iter().all(|(id, _)| *id != first),
        "emptied job {first} must be dropped: {membership:?}"
    );
    assert!(membership.iter().any(|(id, _)| *id == third));
    assert!(session.is_partition());
    // The system still runs fine afterwards.
    session.step_window().unwrap();
    assert!(session.is_partition());
}

#[test]
fn uncorrelated_request_starts_new_job() {
    let mut engine = Engine::open_default().unwrap();
    // Tight metadata policy: the second request arrives much later than the
    // first group's requests, so the time filter must reject it.
    let spec = small_spec(Task::Det, Policy::ecco())
        .scenario(scenario::grouped_static(&[2, 1], 0.05, 10.0, 13))
        .windows(3)
        .configure(|cfg| {
            cfg.auto_request = false;
            cfg.auto_regroup = false;
            cfg.grouping.time_eps = 60.0;
        });
    let mut session = Session::new(&mut engine, spec).unwrap();
    session.force_group(&[0, 1]).unwrap();
    for _ in 0..3 {
        session.step_window().unwrap(); // now > time_eps past the forced requests
    }
    session.request_now(2).unwrap();
    assert_eq!(session.jobs(), 2, "stale-time request must start a new job");
    assert!(session.is_partition());
}

#[test]
fn zoo_warm_start_populates_and_selects() {
    let mut engine = Engine::open_default().unwrap();
    let spec = small_spec(Task::Det, Policy::recl())
        .scenario(scenario::grouped_static(&[2], 0.05, 20.0, 11))
        .zoo_init_steps(20)
        .windows(3);
    let mut session = Session::new(&mut engine, spec).unwrap();
    // Session::new prefilled the zoo from each camera's initial
    // distribution (the policy has zoo_warm_start).
    assert_eq!(session.zoo_len(), 2);
    for _ in 0..3 {
        session.step_window().unwrap();
    }
    // Retrained models are added back to the zoo each window.
    assert!(
        session.zoo_len() > 2,
        "zoo must grow with retrained checkpoints"
    );
}

#[test]
fn response_tracker_consistent_with_history() {
    let mut engine = Engine::open_default().unwrap();
    let spec = small_spec(Task::Det, Policy::ecco())
        .scenario(scenario::grouped_static(&[3], 0.05, 20.0, 12))
        .windows(5);
    let mut session = Session::new(&mut engine, spec).unwrap();
    let mut reports = Vec::new();
    for _ in 0..5 {
        reports.push(session.step_window().unwrap());
    }
    let horizon = session.now();
    let resp = session.mean_response();
    assert!(resp > 0.0 && resp <= horizon);
    // If any camera ever exceeded the threshold after its request, at least
    // one request must be satisfied.
    let crossed = reports
        .iter()
        .any(|w| w.cam_acc.iter().any(|&a| a >= 0.35));
    if crossed {
        assert!(session.requests_satisfied() > 0);
    }
}

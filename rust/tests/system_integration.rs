//! Integration tests over the full system: scene -> network -> teacher ->
//! grouping -> allocation -> PJRT retraining -> metrics, at reduced scale.
//!
//! These are the "does the whole machine hold together" checks; the
//! per-module behaviour is covered by unit tests, and the paper-shape
//! results by `ecco exp ...`.

use ecco::grouping::is_partition;
use ecco::runtime::{Engine, Task};
use ecco::scene::scenario;
use ecco::server::{Policy, System, SystemConfig};

fn small_cfg(task: Task, policy: Policy) -> SystemConfig {
    let mut cfg = SystemConfig::new(task, policy);
    cfg.gpus = 1.0;
    cfg.micro_windows = 4;
    cfg.window_secs = 40.0;
    cfg.eval_frames = 8;
    cfg.pretrain_steps = 120;
    cfg.seed = 99;
    cfg
}

#[test]
fn ecco_full_loop_groups_and_recovers() {
    let mut engine = Engine::open_default().unwrap();
    let sc = scenario::grouped_static(&[3], 0.05, 20.0, 5);
    let cfg = small_cfg(Task::Det, Policy::ecco());
    let mut sys = System::new(cfg, sc.world, &[20.0; 3], 10.0, &mut engine).unwrap();
    sys.run_windows(5).unwrap();
    // All cameras requested retraining (the drift event is strong); Alg. 2
    // churn may add re-requests on top.
    assert!(sys.tracker.total() >= 3, "all cameras must request");
    // Cross-camera correlation => few jobs covering all three cameras.
    assert!(
        sys.jobs.len() <= 2,
        "correlated cameras must mostly group: {} jobs",
        sys.jobs.len()
    );
    let members: usize = sys.jobs.iter().map(|j| j.members.len()).sum();
    assert_eq!(members, 3);
    assert!(sys.jobs.iter().any(|j| j.members.len() >= 2));
    assert!(is_partition(&sys.group_meta));
    // Accuracy must be sane and improving from the immediate post-drift dip.
    let acc = sys.mean_accuracy();
    assert!((0.0..=1.0).contains(&acc));
    let w0 = sys.history.series[0][0].1;
    assert!(
        acc > w0,
        "retraining should improve accuracy: w0 {w0} -> final {acc}"
    );
}

#[test]
fn independent_policy_never_groups() {
    let mut engine = Engine::open_default().unwrap();
    let sc = scenario::grouped_static(&[3], 0.05, 20.0, 6);
    let cfg = small_cfg(Task::Det, Policy::ekya());
    let mut sys = System::new(cfg, sc.world, &[20.0; 3], 10.0, &mut engine).unwrap();
    sys.run_windows(4).unwrap();
    assert_eq!(sys.jobs.len(), 3, "independent retraining: one job per camera");
    for j in &sys.jobs {
        assert_eq!(j.members.len(), 1);
    }
}

#[test]
fn seg_task_runs_end_to_end() {
    let mut engine = Engine::open_default().unwrap();
    let sc = scenario::grouped_static(&[2], 0.05, 20.0, 7);
    let cfg = small_cfg(Task::Seg, Policy::ecco());
    let mut sys = System::new(cfg, sc.world, &[20.0; 2], 10.0, &mut engine).unwrap();
    sys.run_windows(3).unwrap();
    let acc = sys.mean_accuracy();
    assert!((0.0..=1.0).contains(&acc));
    assert!(sys.engine.stats.train_steps > 0, "seg training must run");
}

#[test]
fn gpu_budget_controls_training_volume() {
    let mut engine = Engine::open_default().unwrap();
    let mut steps = Vec::new();
    for gpus in [1.0, 4.0] {
        let sc = scenario::grouped_static(&[2], 0.05, 10.0, 8);
        let mut cfg = small_cfg(Task::Det, Policy::ecco());
        cfg.gpus = gpus;
        let before = engine.stats.train_steps;
        let mut sys = System::new(cfg, sc.world, &[20.0; 2], 10.0, &mut engine).unwrap();
        sys.run_windows(3).unwrap();
        steps.push(sys.engine.stats.train_steps - before);
    }
    assert!(
        steps[1] > steps[0] * 2,
        "4 GPUs must train much more than 1: {steps:?}"
    );
}

#[test]
fn bandwidth_starvation_reduces_delivered_data() {
    let mut engine = Engine::open_default().unwrap();
    let mut labelled = Vec::new();
    // Fixed-config policy (naive) so the stream demand is constant and the
    // uplink is the only variable; count teacher annotations (the job
    // buffer is ring-capped so it can't be compared directly).
    for bw in [0.05, 20.0] {
        let sc = scenario::grouped_static(&[2], 0.05, 10.0, 9);
        let cfg = small_cfg(Task::Det, Policy::naive());
        let mut sys = System::new(cfg, sc.world, &[bw; 2], 50.0, &mut engine).unwrap();
        sys.run_windows(3).unwrap();
        labelled.push(sys.teacher.annotated);
    }
    assert!(
        labelled[1] > labelled[0],
        "more uplink must deliver more training data: {labelled:?}"
    );
}

#[test]
fn forced_groups_and_scripted_requests() {
    let mut engine = Engine::open_default().unwrap();
    let sc = scenario::grouped_static(&[4], 0.05, 10.0, 10);
    let mut cfg = small_cfg(Task::Det, Policy::ecco());
    cfg.auto_request = false;
    cfg.auto_regroup = false;
    let mut sys = System::new(cfg, sc.world, &[20.0; 4], 10.0, &mut engine).unwrap();
    // Nothing happens without requests.
    sys.run_windows(1).unwrap();
    assert_eq!(sys.jobs.len(), 0);
    // Forced group of 3 + scripted request from a correlated camera: the
    // grouping pipeline should absorb it into the existing job.
    sys.force_group(&[0, 1, 2]).unwrap();
    sys.request_now(3).unwrap();
    sys.run_windows(2).unwrap();
    assert!(is_partition(&sys.group_meta));
    let members: usize = sys.jobs.iter().map(|j| j.members.len()).sum();
    assert_eq!(members, 4);
    assert!(
        sys.jobs.iter().any(|j| j.members.len() >= 3),
        "the forced group must persist"
    );
}

#[test]
fn uncorrelated_request_starts_new_job() {
    let mut engine = Engine::open_default().unwrap();
    let sc = scenario::grouped_static(&[2, 1], 0.05, 10.0, 13);
    let mut cfg = small_cfg(Task::Det, Policy::ecco());
    cfg.auto_request = false;
    cfg.auto_regroup = false;
    // Tight metadata policy: the second request arrives much later than the
    // first group's requests, so the time filter must reject it.
    cfg.grouping.time_eps = 60.0;
    let mut sys = System::new(cfg, sc.world, &[20.0; 3], 10.0, &mut engine).unwrap();
    sys.force_group(&[0, 1]).unwrap();
    sys.run_windows(3).unwrap(); // now > time_eps past the forced requests
    sys.request_now(2).unwrap();
    assert_eq!(sys.jobs.len(), 2, "stale-time request must start a new job");
    assert!(is_partition(&sys.group_meta));
}

#[test]
fn zoo_warm_start_populates_and_selects() {
    let mut engine = Engine::open_default().unwrap();
    let sc = scenario::grouped_static(&[2], 0.05, 20.0, 11);
    let cfg = small_cfg(Task::Det, Policy::recl());
    let mut sys = System::new(cfg, sc.world, &[20.0; 2], 10.0, &mut engine).unwrap();
    sys.populate_zoo_from_initial(20).unwrap();
    assert_eq!(sys.zoo.len(), 2);
    sys.run_windows(3).unwrap();
    // Retrained models are added back to the zoo each window.
    assert!(sys.zoo.len() > 2, "zoo must grow with retrained checkpoints");
}

#[test]
fn response_tracker_consistent_with_history() {
    let mut engine = Engine::open_default().unwrap();
    let sc = scenario::grouped_static(&[3], 0.05, 20.0, 12);
    let cfg = small_cfg(Task::Det, Policy::ecco());
    let mut sys = System::new(cfg, sc.world, &[20.0; 3], 10.0, &mut engine).unwrap();
    sys.run_windows(5).unwrap();
    let horizon = sys.now();
    let resp = sys.tracker.mean_response(horizon);
    assert!(resp > 0.0 && resp <= horizon);
    // If any camera ever exceeded the threshold after its request, at least
    // one request must be satisfied.
    let crossed = sys
        .history
        .series
        .iter()
        .any(|s| s.iter().any(|&(_, a)| a >= 0.35));
    if crossed {
        assert!(sys.tracker.satisfied() > 0);
    }
}

//! Scheduler equivalence pins: the event/time-wheel driver with uniform
//! camera windows must replay the lockstep loop **byte-identically**
//! (events, accuracy series, alloc log, membership) at any eval-pool
//! width, with or without a fault plan; topology pruning at degree n-1
//! must reproduce all-pairs grouping exactly; and heterogeneous camera
//! windows must run end to end with per-camera cadence visible in the
//! accuracy history.

use ecco::api::{RunReport, RunSpec, RuntimeOpts, Session};
use ecco::faults::{FaultKind, FaultPlan};
use ecco::runtime::{Engine, Task};
use ecco::scene::scenario;
use ecco::server::{Policy, Scheduler};

/// A reduced-scale deterministic spec (4 cameras in two pairs, 3 windows).
fn small_spec(seed: u64) -> RunSpec {
    RunSpec::new(Task::Det, Policy::ecco())
        .scenario(scenario::grouped_static(&[2, 2], 0.05, 20.0, seed))
        .gpus(1.0)
        .shared_mbps(10.0)
        .uplink_mbps(20.0)
        .windows(3)
        .seed(seed)
        .configure(|cfg| {
            cfg.micro_windows = 4;
            cfg.window_secs = 40.0;
            cfg.eval_frames = 8;
            cfg.pretrain_steps = 120;
        })
}

fn run(engine: &Engine, spec: RunSpec) -> (RunReport, String) {
    let report = Session::new(engine, spec).unwrap().run().unwrap();
    let jsonl: String = report
        .events
        .iter()
        .map(|e| e.to_json().to_string_compact())
        .collect::<Vec<_>>()
        .join("\n");
    (report, jsonl)
}

fn assert_identical(a: &(RunReport, String), b: &(RunReport, String), what: &str) {
    assert!(!a.0.events.is_empty(), "{what}: run must emit events");
    assert_eq!(a.1, b.1, "{what}: event streams diverged");
    assert_eq!(a.0.events, b.0.events, "{what}");
    assert_eq!(a.0.window_acc, b.0.window_acc, "{what}");
    assert_eq!(a.0.cam_acc, b.0.cam_acc, "{what}");
    assert_eq!(a.0.alloc_log, b.0.alloc_log, "{what}");
    assert_eq!(a.0.membership, b.0.membership, "{what}");
    assert_eq!(a.0.final_acc, b.0.final_acc, "{what}");
    assert_eq!(a.0.response_s, b.0.response_s, "{what}");
}

#[test]
fn event_driven_uniform_windows_is_byte_identical_to_lockstep() {
    // The tentpole contract: with every camera on the global window, the
    // wheel replays the lockstep body statement for statement — at a
    // serial pool and at a 4-wide pool.
    let engine = Engine::open_default().unwrap();
    for threads in [1usize, 4] {
        let lockstep = run(
            &engine,
            small_spec(51).runtime(
                RuntimeOpts::new()
                    .threads(threads)
                    .scheduler(Scheduler::Lockstep),
            ),
        );
        let events = run(
            &engine,
            small_spec(51).runtime(
                RuntimeOpts::new()
                    .threads(threads)
                    .scheduler(Scheduler::EventDriven),
            ),
        );
        assert_identical(&lockstep, &events, &format!("uniform, {threads} threads"));
    }
}

#[test]
fn scheduler_equivalence_holds_under_a_fault_plan() {
    // Fault drains are inline pre-advance steps in both drivers; a plan
    // spanning mid-window events and a recovery must not open a gap.
    let engine = Engine::open_default().unwrap();
    let plan = || {
        FaultPlan::none()
            .at(1, 1, 0, FaultKind::CameraDown)
            .at(1, 3, 3, FaultKind::UplinkScale { factor: 0.4 })
            .at(2, 0, 0, FaultKind::CameraUp)
    };
    let with = |scheduler: Scheduler| {
        run(
            &engine,
            small_spec(52)
                .faults(plan())
                .runtime(RuntimeOpts::new().threads(2).scheduler(scheduler)),
        )
    };
    let lockstep = with(Scheduler::Lockstep);
    let events = with(Scheduler::EventDriven);
    assert_identical(&lockstep, &events, "fault plan");
}

#[test]
fn topology_degree_n_minus_1_reproduces_all_pairs_grouping() {
    // degree >= n-1 makes every camera a spatial neighbor of every other,
    // so the pruned candidate scan examines exactly the all-pairs set and
    // the whole run — placement decisions included — is byte-identical.
    let engine = Engine::open_default().unwrap();
    let all_pairs = run(&engine, small_spec(53));
    let full_topo = run(&engine, small_spec(53).topology_degree(3));
    assert_identical(&all_pairs, &full_topo, "degree n-1 topology");
}

#[test]
fn heterogeneous_camera_windows_run_at_their_own_cadence() {
    // Camera 0 gets a half-length window: the event driver (forced by the
    // override) must publish + measure it at its own mid-window
    // boundaries, doubling its accuracy-history cadence relative to the
    // uniform cameras, while the run stays a valid partition throughout.
    let engine = Engine::open_default().unwrap();
    let windows = 3usize;
    // Pin W to 8 regardless of job count so every tick is an exact 5s
    // (power-of-two divisor of the 40s window) — boundary slot math stays
    // deterministic across windows.
    let spec = small_spec(54)
        .camera(0, |c| c.window_len(20.0))
        .configure(|cfg| {
            cfg.micro_windows = 8;
            cfg.max_micro_windows = 8;
        });
    let mut session = Session::new(&engine, spec).unwrap();
    for _ in 0..windows {
        session.step_window().unwrap();
        assert!(session.is_partition());
    }
    let report = session.into_report();
    // One boundary sample per 20s camera window inside each 40s server
    // window, plus the end-of-window pass: 2 samples per server window.
    assert_eq!(report.cam_acc[0].len(), 2 * windows, "half-window camera");
    for series in &report.cam_acc[1..] {
        assert_eq!(series.len(), windows, "uniform cameras keep one sample");
    }
    assert_eq!(report.window_acc.len(), windows);
}

#[test]
fn explicit_event_scheduler_with_phase_stagger_completes() {
    // A staggered phase shifts boundaries without changing their count;
    // smoke-pin that phases inside (0, len) run end to end and report.
    let engine = Engine::open_default().unwrap();
    let spec = small_spec(55)
        .camera(1, |c| c.window_len(20.0).phase(10.0))
        .runtime(RuntimeOpts::new().scheduler(Scheduler::EventDriven))
        .configure(|cfg| {
            cfg.micro_windows = 8;
            cfg.max_micro_windows = 8;
        });
    let report = Session::new(&engine, spec).unwrap().run().unwrap();
    assert_eq!(report.window_acc.len(), 3);
    assert!(!report.events.is_empty());
    // Boundaries at 10/30 inside each 40s window -> 2 extras + 1 end pass.
    assert_eq!(report.cam_acc[1].len(), 3 * 3, "staggered camera cadence");
}
